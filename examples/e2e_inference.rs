//! END-TO-END DRIVER: every layer of the stack composes on a real workload.
//!
//! 1. Loads the AOT artifacts (L2 JAX model + L1 Pallas kernels, lowered to
//!    HLO text by `make artifacts`) into the PJRT runtime — Python is not
//!    involved at run time.
//! 2. Cross-checks numerics: PJRT-executed weights generation ≡ the rust
//!    cycle-level TiWGen simulator ≡ the Python oracle's reference vectors.
//! 3. Plans ResNet18-OVSF50 on the Z7045 via DSE, then serves a batched
//!    request stream through the multi-worker `ServerPool`, where each
//!    worker executes the AOT model forward, reporting latency/throughput.
//!
//! Skips gracefully (with instructions) when the artifacts are missing or
//! the crate was built without the `pjrt` feature.
//!
//! Results are recorded in EXPERIMENTS.md §E2E. Run with:
//! ```sh
//! make artifacts && cargo run --release --features pjrt --example e2e_inference
//! ```

use std::time::Instant;
use unzipfpga::arch::Platform;
use unzipfpga::coordinator::pool::{PoolConfig, ServerPool};
use unzipfpga::coordinator::server::Request;
use unzipfpga::engine::Engine;
use unzipfpga::runtime::{artifacts_dir, ArtifactRegistry};
use unzipfpga::sim::hw_weights::HwOvsfWeights;
use unzipfpga::sim::wgen::WGenSim;
use unzipfpga::util::prng::Xoshiro256;
use unzipfpga::workload::{resnet, RatioProfile};

const N_IN: usize = 16;
const N_BASIS: usize = 8;
const N_OUT: usize = 32;

fn load_f32(path: &std::path::Path) -> Vec<f32> {
    std::fs::read(path)
        .unwrap_or_else(|e| panic!("missing {path:?} — run `make artifacts` ({e})"))
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect()
}

fn main() -> unzipfpga::Result<()> {
    let dir = artifacts_dir();
    let mut reg = ArtifactRegistry::new(dir.clone())?;
    println!("== stage 1: PJRT runtime ({}) ==", reg.client().platform_name());
    for name in ["ovsf_wgen", "ovsf_conv", "gemm", "model_fwd"] {
        let t = Instant::now();
        match reg.get(name) {
            Ok(_) => println!("  compiled {name:<10} in {:?}", t.elapsed()),
            Err(e) => {
                println!("SKIP e2e: {name} unavailable ({e})");
                println!("  → run `make artifacts` and build with `--features pjrt`");
                return Ok(());
            }
        }
    }

    println!("\n== stage 2: three-layer numeric agreement ==");
    let alphas = load_f32(&dir.join("wgen_test_alphas.f32"));
    let expected = load_f32(&dir.join("wgen_test_expected.f32"));
    let out = reg
        .get("ovsf_wgen")?
        .run_f32(&[(&alphas, &[N_IN, N_BASIS, N_OUT])])?;
    let max_py = out[0]
        .iter()
        .zip(&expected)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    // Rust cycle-level TiWGen over the same α (layout transposed).
    let mut rust_alphas = vec![0.0f32; alphas.len()];
    for c in 0..N_IN {
        for j in 0..N_BASIS {
            for o in 0..N_OUT {
                rust_alphas[(o * N_IN + c) * N_BASIS + j] = alphas[(c * N_BASIS + j) * N_OUT + o];
            }
        }
    }
    let hw = HwOvsfWeights {
        n_out: N_OUT,
        n_in: N_IN,
        k_ovsf: 4,
        k: 3,
        n_basis: N_BASIS,
        alphas: rust_alphas,
    };
    let sim = WGenSim::new(&unzipfpga::arch::DesignPoint::new(32, 16, 16, 16), &hw).generate();
    let max_rs = out[0]
        .iter()
        .zip(&sim.weights)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("  PJRT vs python-oracle : max |Δ| = {max_py:.2e}");
    println!("  PJRT vs rust TiWGen   : max |Δ| = {max_rs:.2e}");
    assert!(max_py < 1e-4 && max_rs < 1e-4, "three-layer disagreement!");
    println!(
        "  TiWGen cycle walk: {} cycles/output-tile, {} vector MACs",
        sim.cycles_per_output_tile, sim.vector_macs
    );
    drop(reg);

    println!("\n== stage 3: DSE + ServerPool serving ==");
    let net = resnet::resnet18();
    let profile = RatioProfile::ovsf50(&net);
    let plat = Platform::z7045();
    // The Engine builder runs the DSE when no design point is given.
    let plan = Engine::builder()
        .platform(plat.clone())
        .bandwidth(4)
        .network(net)
        .profile(profile)
        .plan()?;
    println!(
        "  σ* = {} → modelled {:.1} inf/s on {}",
        plan.sigma,
        1.0 / plan.schedule.latency_s,
        plat.name
    );
    let device_latency = plan.schedule.latency_s;

    // The served model: the AOT small-CNN forward (run per request). Each
    // pool worker re-opens its own registry: PJRT clients are not Send.
    let mut rng = Xoshiro256::seed_from_u64(7);
    let width = 16usize;
    let w2 = 32usize;
    let nb = 8usize;
    let head_b = vec![0.0f32; 10];
    let head_w = rng.normal_vec(w2 * 10);
    let ovsf1 = rng.normal_vec(width * nb * width);
    let ovsf2 = rng.normal_vec(width * nb * width);
    let ovsf3 = rng.normal_vec(width * nb * w2);
    let ovsf4 = rng.normal_vec(w2 * nb * w2);
    let stem = rng.normal_vec(3 * 3 * 3 * width);
    let params = std::sync::Arc::new((head_b, head_w, ovsf1, ovsf2, ovsf3, ovsf4, stem));
    let cfg = PoolConfig {
        workers: 2,
        queue_depth: 128,
        max_batch: 4,
        linger: std::time::Duration::from_millis(1),
        slo: None,
        ..PoolConfig::default()
    };
    let pool = ServerPool::start(plan.schedule.clone(), cfg, move |worker| {
        let params = std::sync::Arc::clone(&params);
        let mut reg = ArtifactRegistry::new(artifacts_dir()).expect("client");
        reg.get("model_fwd").expect("precompile");
        println!("  worker {worker}: model_fwd compiled");
        move |req: &Request| {
            let (head_b, head_w, ovsf1, ovsf2, ovsf3, ovsf4, stem) = &*params;
            let exe = reg.get("model_fwd").expect("cached");
            exe.run_f32(&[
                (&req.input, &[8, 16, 16, 3]),
                (head_b, &[10]),
                (head_w, &[w2, 10]),
                (ovsf1, &[width, nb, width]),
                (ovsf2, &[width, nb, width]),
                (ovsf3, &[width, nb, w2]),
                (ovsf4, &[w2, nb, w2]),
                (stem, &[3, 3, 3, width]),
            ])
            .expect("PJRT model forward")
            .into_iter()
            .next()
            .unwrap()
        }
    })?;

    let n_req = 64u64;
    let mut rng2 = Xoshiro256::seed_from_u64(8);
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_req)
        .map(|id| {
            let input = rng2.normal_vec(8 * 16 * 16 * 3);
            pool.submit(Request::numeric(id, input))
        })
        .collect::<unzipfpga::Result<_>>()?;
    for h in handles {
        let resp = h.wait()?;
        assert_eq!(resp.output.len(), 80);
        assert!(resp.output.iter().all(|v| v.is_finite()));
    }
    let wall = t0.elapsed();
    let metrics = pool.shutdown()?;
    println!("  served {n_req} requests in {wall:?}");
    println!("  host  : {}", metrics.summary());
    println!(
        "  device: {:.2} ms/inf modelled ⇒ {:.1} inf/s (ResNet18-OVSF50 @ 4x)",
        device_latency * 1e3,
        1.0 / device_latency
    );
    println!("\nE2E OK — all three layers compose behind the Engine/ServerPool API.");
    Ok(())
}
