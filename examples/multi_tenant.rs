//! Multi-tenant co-location study — the paper's concluding vision: CNN
//! engines sharing off-chip memory with other applications. On-the-fly
//! weights generation is what keeps throughput usable as per-tenant
//! bandwidth shrinks.
//!
//! Each co-location point is evaluated through the unified `Engine` API
//! (DSE picks σ, the analytical backend executes the plan) — see
//! `coordinator::multi_tenant::co_location_sweep`.
//!
//! ```sh
//! cargo run --release --example multi_tenant [network] [platform]
//! ```

use unzipfpga::arch::Platform;
use unzipfpga::coordinator::multi_tenant::co_location_sweep;
use unzipfpga::workload::Network;

fn main() -> unzipfpga::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "resnet18".into());
    let net = Network::by_name(&name)
        .ok_or_else(|| unzipfpga::Error::InvalidConfig(format!("unknown network {name}")))?;
    let plat = match std::env::args().nth(2).as_deref() {
        Some("z7045") => Platform::z7045(),
        _ => Platform::zu7ev(),
    };
    println!(
        "co-location study: {} on {} ({}x total bandwidth shared with co-located apps)\n",
        net.name, plat.name, plat.peak_bw_mult
    );
    println!(
        "{:<8} {:>10} {:>14} {:>14} {:>9}",
        "tenants", "bw/tenant", "baseline inf/s", "unzip inf/s", "speedup"
    );
    let reports = co_location_sweep(&plat, plat.peak_bw_mult, &net, 6)?;
    for r in &reports {
        println!(
            "{:<8} {:>9}x {:>14.1} {:>14.1} {:>8.2}x",
            r.tenants,
            r.bw_per_tenant,
            r.baseline_inf_s,
            r.unzip_inf_s,
            r.speedup()
        );
    }
    let first = reports.first().unwrap().speedup();
    let last = reports.last().unwrap().speedup();
    println!(
        "\nunzipFPGA's advantage grows {:.2}x → {:.2}x as co-location intensifies —",
        first, last
    );
    println!("the memory-wall mitigation the paper's conclusion anticipates.");
    Ok(())
}
