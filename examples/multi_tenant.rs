//! Multi-tenant co-location study — the paper's concluding vision: CNN
//! engines sharing off-chip memory with other applications. On-the-fly
//! weights generation is what keeps throughput usable as per-tenant
//! bandwidth shrinks.
//!
//! Every co-location level runs on the **real serving stack**: the models
//! are compiled once (`Compiler`, one DSE-pinned σ per level), registered
//! in a `ModelRegistry` under one shared slab-cache budget, and served
//! interleaved through a registry-routed `ServerPool` on the simulator
//! backend — including real numeric inferences through the tile-streamed
//! datapath (see `coordinator::multi_tenant::co_location_sweep`).
//!
//! ```sh
//! cargo run --release --example multi_tenant [network[,network...]] [platform]
//! ```
//!
//! `EXAMPLES_SMOKE=1` shrinks the sweep for CI.

use unzipfpga::arch::Platform;
use unzipfpga::coordinator::multi_tenant::{co_location_sweep, CoLocationConfig};
use unzipfpga::workload::Network;

fn main() -> unzipfpga::Result<()> {
    let names = std::env::args().nth(1).unwrap_or_else(|| "resnet18".into());
    let nets: Vec<Network> = Network::by_names(&names)?;
    let plat = match std::env::args().nth(2).as_deref() {
        Some("z7045") => Platform::z7045(),
        _ => Platform::zu7ev(),
    };
    let smoke = std::env::var("EXAMPLES_SMOKE").is_ok();
    let cfg = CoLocationConfig {
        max_tenants: if smoke { 2 } else { 6 },
        timing_requests: 4,
        numeric_requests: 1,
        ..CoLocationConfig::default()
    };
    println!(
        "co-location study: {} on {} ({}x total bandwidth shared with co-located apps)",
        names, plat.name, plat.peak_bw_mult
    );
    println!(
        "each level serves {} timing + {} numeric requests per model through one \
         registry-routed pool\n",
        cfg.timing_requests, cfg.numeric_requests
    );
    println!(
        "{:<8} {:>10} {:<14} {:>14} {:>14} {:>9}",
        "tenants", "bw/tenant", "model", "baseline inf/s", "unzip inf/s", "speedup"
    );
    let reports = co_location_sweep(&plat, plat.peak_bw_mult, &nets, &cfg)?;
    for r in &reports {
        for m in &r.models {
            println!(
                "{:<8} {:>9}x {:<14} {:>14.1} {:>14.1} {:>8.2}x",
                r.tenants,
                r.bw_per_tenant,
                m.model,
                m.baseline_inf_s,
                m.unzip_inf_s,
                m.speedup()
            );
        }
        println!(
            "         served {} requests ({} model switches); slab cache: {} hits / {} \
             misses / {} evictions, peak resident {:.1} KiB",
            r.requests_served,
            r.model_switches,
            r.cache_hits,
            r.cache_misses,
            r.cache_evictions,
            r.peak_resident_bytes as f64 / 1024.0
        );
    }
    let first = reports.first().unwrap().speedup();
    let last = reports.last().unwrap().speedup();
    println!(
        "\nunzipFPGA's advantage grows {:.2}x → {:.2}x as co-location intensifies —",
        first, last
    );
    println!("the memory-wall mitigation the paper's conclusion anticipates.");
    Ok(())
}
