//! Multi-model serving quickstart: the compile-once / serve-many
//! lifecycle on one shared computation engine.
//!
//! 1. **Compile** each CNN into an immutable `CompiledModel` artifact
//!    (plan + schedule + weights-key namespace + pre-fitted OVSF α sets).
//!    One `Compiler` pins a single design point σ — the paper's premise:
//!    the fabric is never reconfigured between models.
//! 2. **Register** the artifacts in a `ModelRegistry` under string ids.
//!    All models' generated weight slabs share ONE bounded cache — they
//!    compete for resident bytes like co-resident models compete for
//!    on-chip BRAM.
//! 3. **Submit** model-named requests to a registry-routed `ServerPool`:
//!    batches never mix models, workers swap plans on model switch, and
//!    unknown ids / wrong shapes fail fast with typed errors.
//!
//! ```sh
//! cargo run --release --example multi_model [network,network,...]
//! ```

use std::sync::Arc;
use unzipfpga::arch::Platform;
use unzipfpga::coordinator::pool::{PoolConfig, ServerPool};
use unzipfpga::coordinator::registry::ModelRegistry;
use unzipfpga::coordinator::server::Request;
use unzipfpga::engine::{BackendKind, Compiler};
use unzipfpga::workload::{Network, RatioProfile};
use unzipfpga::Error;

fn main() -> unzipfpga::Result<()> {
    let names = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "resnet18,squeezenet".into());
    let nets: Vec<Network> = Network::by_names(&names)?;

    // 1. Compile: one σ (DSE optimum of the first model) for every model.
    let compiler = Compiler::new().platform(Platform::z7045()).bandwidth(4);
    let registry = Arc::new(ModelRegistry::with_budget(8 << 20));
    for net in &nets {
        let profile = RatioProfile::ovsf50(net);
        let artifact = compiler.compile(net.clone(), profile)?;
        let compiled = registry.register(net.name.clone(), artifact)?;
        println!(
            "compiled '{}': σ = {}, {} OVSF layers, {:.1}M α words, \
             in/out = {}/{} activations, device latency {:.2} ms",
            net.name,
            compiled.sigma(),
            compiled.weights_keys().len(),
            compiled.alpha_words() as f64 / 1e6,
            compiled.input_len(),
            compiled.output_len(),
            compiled.latency_s() * 1e3
        );
    }

    // 2./3. Serve interleaved traffic across all registered models.
    let pool = ServerPool::serve(
        Arc::clone(&registry),
        BackendKind::Analytical,
        PoolConfig::default(),
    )?;
    let per_model = 40u64;
    let mut handles = Vec::new();
    let mut id = 0u64;
    for _ in 0..per_model {
        for net in &nets {
            handles.push(pool.submit(Request::for_model(id, net.name.clone(), vec![]))?);
            id += 1;
        }
    }
    for h in handles {
        let resp = h.wait()?;
        assert!(!resp.model.is_empty(), "responses carry the routed model id");
    }

    // Typed fail-fast admission: unknown ids and bad shapes never queue.
    match pool.submit(Request::for_model(9999, "not-a-model", vec![])) {
        Err(Error::UnknownModel(m)) => println!("\nrejected unknown model id: '{m}'"),
        Err(e) => panic!("expected a typed UnknownModel error, got {e}"),
        Ok(_) => panic!("expected a typed UnknownModel error, got Ok"),
    }
    match pool.submit(Request::for_model(9999, nets[0].name.clone(), vec![0.0; 3])) {
        Err(Error::ShapeMismatch(_)) => println!("rejected wrong-length input (typed)"),
        Err(e) => panic!("expected a typed ShapeMismatch error, got {e}"),
        Ok(_) => panic!("expected a typed ShapeMismatch error, got Ok"),
    }

    // Runtime eviction: the model unregisters and its resident slabs leave
    // the shared cache; later requests for it fail typed.
    let evicted = registry.evict(&nets[0].name)?;
    println!("evicted '{}' at runtime", evicted.network_name());
    assert!(matches!(
        pool.submit(Request::for_model(10000, nets[0].name.clone(), vec![])),
        Err(Error::UnknownModel(_))
    ));

    let metrics = pool.shutdown()?;
    println!("\npool: {}", metrics.summary());
    Ok(())
}
