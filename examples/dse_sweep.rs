//! Bandwidth-sensitivity sweep (the Fig. 8 scenario): how the unzipFPGA
//! designs and the baselines scale with off-chip memory bandwidth on both
//! platforms, including the multi-tenant motivation — bandwidth shrinking
//! as co-located apps contend for memory.
//!
//! ```sh
//! cargo run --release --example dse_sweep [network]
//! ```

use unzipfpga::arch::Platform;
use unzipfpga::baselines::faithful::evaluate_faithful;
use unzipfpga::baselines::pruning::TaylorPruner;
use unzipfpga::dse::search::{optimise, sweep, DseConfig};
use unzipfpga::engine::{BackendKind, Engine};
use unzipfpga::workload::{Network, RatioProfile};

fn main() -> unzipfpga::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "resnet34".into());
    let net = Network::by_name(&name)
        .ok_or_else(|| unzipfpga::Error::InvalidConfig(format!("unknown network {name}")))?;
    let cfg = DseConfig::default();

    for plat in Platform::all() {
        println!("\n== {} ({}) ==", plat.name, plat.board);
        println!(
            "{:<6} {:>10} {:>10} {:>10} {:>10} {:>9} {:>9}",
            "bw", "vanilla", "Tay82", "OVSF50", "OVSF25", "spd50", "spd25"
        );
        for bw in [1u32, 2, 4, 8, 12] {
            if bw > plat.peak_bw_mult {
                continue;
            }
            let vanilla = evaluate_faithful(&plat, bw, &net)?.perf.inf_per_s;
            let tay = evaluate_faithful(&plat, bw, &TaylorPruner::new(0.82).prune(&net))?
                .perf
                .inf_per_s;
            let o50 = optimise(&cfg, &plat, bw, &net, &RatioProfile::ovsf50(&net), true)?
                .perf
                .inf_per_s;
            let o25 = optimise(&cfg, &plat, bw, &net, &RatioProfile::ovsf25(&net), true)?
                .perf
                .inf_per_s;
            println!(
                "{:<6} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>8.2}x {:>8.2}x",
                format!("{bw}x"),
                vanilla,
                tay,
                o50,
                o25,
                o50 / vanilla,
                o25 / vanilla
            );
        }
    }

    // Feasible-space visualisation data: throughput vs DSP allocation split
    // between the engine and CNN-WGen at 1× bandwidth.
    println!("\n== design-space slice (Z7045 @ 1x, OVSF50): wgen share vs inf/s ==");
    let plat = Platform::z7045();
    let profile = RatioProfile::ovsf50(&net);
    let points = sweep(&cfg, &plat, 1, &net, &profile, true);
    let mut best_by_share: std::collections::BTreeMap<u64, f64> = Default::default();
    for p in &points {
        let share = p.sigma.m * 100 / (p.sigma.m + p.sigma.engine_macs());
        let bucket = share / 5 * 5;
        let e = best_by_share.entry(bucket).or_insert(0.0);
        *e = e.max(p.inf_per_s());
    }
    for (share, inf) in best_by_share {
        println!(
            "  wgen {share:>2}–{:<2}% of DSPs: best {inf:>7.1} inf/s  {}",
            share + 4,
            "#".repeat((inf / 2.0) as usize)
        );
    }

    // Cross-validate the 1× optimum on the unified Engine: analytical vs
    // cycle-level simulator backends must agree (DMA burst rounding only).
    // The sweep above already evaluated every feasible point — take its
    // argmax instead of re-running the DSE.
    let Some(best) = points
        .iter()
        .max_by(|a, b| a.inf_per_s().partial_cmp(&b.inf_per_s()).unwrap())
    else {
        return Ok(());
    };
    let builder = Engine::builder()
        .platform(plat)
        .bandwidth(1)
        .design_point(best.sigma)
        .network(net)
        .profile(profile);
    let ana = builder
        .clone()
        .backend(BackendKind::Analytical)
        .build()?
        .infer_timing()?;
    let sim = builder
        .backend(BackendKind::Simulator)
        .build()?
        .infer_timing()?;
    println!(
        "\nengine cross-check @ 1x, σ = {}: analytical {:.1} inf/s vs simulator {:.1} inf/s",
        best.sigma,
        ana.inf_per_s(),
        sim.inf_per_s()
    );
    Ok(())
}
