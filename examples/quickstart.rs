//! Quickstart: derive an OVSF variant of ResNet18, run the hardware-aware
//! design flow (DSE) for a ZC706 board, and report the resulting design —
//! the `Converter → Optimiser → DSE` pipeline of the paper's Fig. 2.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use unzipfpga::accuracy::AccuracyModel;
use unzipfpga::arch::Platform;
use unzipfpga::baselines::faithful::evaluate_faithful;
use unzipfpga::dse::search::{optimise, DseConfig};
use unzipfpga::workload::{resnet, RatioProfile};

fn main() -> unzipfpga::Result<()> {
    // 1. The deep-learning expert supplies a CNN + target platform.
    let net = resnet::resnet18();
    let platform = Platform::z7045();
    println!(
        "network : {} — {:.1}M params, {:.2} GOps",
        net.name,
        net.params() as f64 / 1e6,
        net.gops()
    );
    println!(
        "platform: {} ({}): {} DSP, {:.2} MB BRAM, {} kLUT @ {} MHz\n",
        platform.name,
        platform.board,
        platform.dsp,
        platform.bram_bytes as f64 / 1e6,
        platform.luts / 1000,
        platform.clock_hz / 1e6
    );

    // 2. The Converter derives the OVSF model (hand-tuned OVSF50 ratios).
    let profile = RatioProfile::ovsf50(&net);
    let acc = AccuracyModel::for_network(&net);
    println!(
        "OVSF variant: {} — {:.1}M α-params (effective ρ {:.2}), top-1 {:.1}%",
        profile.name,
        net.params_compressed(&profile) as f64 / 1e6,
        profile.effective_rho(&net),
        acc.top1(&net, &profile)
    );

    // 3. The Optimiser explores the design space per bandwidth budget.
    for bw in [1u32, 2, 4] {
        let unzip = optimise(&DseConfig::default(), &platform, bw, &net, &profile, true)?;
        let baseline = evaluate_faithful(&platform, bw, &net)?;
        println!(
            "{bw}x bandwidth: σ* = {} → {:>6.1} inf/s  (faithful baseline {:>6.1}, speedup {:.2}x)",
            unzip.sigma,
            unzip.perf.inf_per_s,
            baseline.perf.inf_per_s,
            unzip.perf.inf_per_s / baseline.perf.inf_per_s
        );
    }
    Ok(())
}
