//! Quickstart: derive an OVSF variant of ResNet18, run the hardware-aware
//! design flow (DSE) for a ZC706 board, and report the resulting design —
//! the `Converter → Optimiser → DSE` pipeline of the paper's Fig. 2.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use unzipfpga::accuracy::AccuracyModel;
use unzipfpga::arch::Platform;
use unzipfpga::baselines::faithful::evaluate_faithful;
use unzipfpga::dse::search::{optimise, DseConfig};
use unzipfpga::engine::{BackendKind, Engine};
use unzipfpga::workload::{resnet, RatioProfile};

fn main() -> unzipfpga::Result<()> {
    // 1. The deep-learning expert supplies a CNN + target platform.
    let net = resnet::resnet18();
    let platform = Platform::z7045();
    println!(
        "network : {} — {:.1}M params, {:.2} GOps",
        net.name,
        net.params() as f64 / 1e6,
        net.gops()
    );
    println!(
        "platform: {} ({}): {} DSP, {:.2} MB BRAM, {} kLUT @ {} MHz\n",
        platform.name,
        platform.board,
        platform.dsp,
        platform.bram_bytes as f64 / 1e6,
        platform.luts / 1000,
        platform.clock_hz / 1e6
    );

    // 2. The Converter derives the OVSF model (hand-tuned OVSF50 ratios).
    let profile = RatioProfile::ovsf50(&net);
    let acc = AccuracyModel::for_network(&net);
    println!(
        "OVSF variant: {} — {:.1}M α-params (effective ρ {:.2}), top-1 {:.1}%",
        profile.name,
        net.params_compressed(&profile) as f64 / 1e6,
        profile.effective_rho(&net),
        acc.top1(&net, &profile)
    );

    // 3. The Optimiser explores the design space per bandwidth budget.
    let mut best_sigma = None;
    for bw in [1u32, 2, 4] {
        let unzip = optimise(&DseConfig::default(), &platform, bw, &net, &profile, true)?;
        let baseline = evaluate_faithful(&platform, bw, &net)?;
        println!(
            "{bw}x bandwidth: σ* = {} → {:>6.1} inf/s  (faithful baseline {:>6.1}, speedup {:.2}x)",
            unzip.sigma,
            unzip.perf.inf_per_s,
            baseline.perf.inf_per_s,
            unzip.perf.inf_per_s / baseline.perf.inf_per_s
        );
        best_sigma = Some(unzip.sigma);
    }

    // 4. The unified Engine executes the chosen design on interchangeable
    //    backends — here the analytical model and the cycle-level
    //    simulator cross-validate each other through one API.
    let builder = Engine::builder()
        .platform(platform)
        .bandwidth(4)
        .design_point(best_sigma.expect("DSE ran"))
        .network(net)
        .profile(profile);
    println!();
    for kind in [BackendKind::Analytical, BackendKind::Simulator] {
        let mut engine = builder.clone().backend(kind).build()?;
        let report = engine.infer_timing()?;
        println!(
            "engine[{:<10}] : {:>10.0} cycles/inf = {:>6.1} inf/s",
            report.backend,
            report.total_cycles,
            report.inf_per_s()
        );
    }
    Ok(())
}
