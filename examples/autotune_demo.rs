//! Hardware-aware OVSF ratio autotuning walkthrough (paper §6.2, Fig. 7,
//! Table 1): bottleneck analysis per layer, ratio raising within pipeline
//! slack, and the resulting accuracy-at-no-cost gain.
//!
//! ```sh
//! cargo run --release --example autotune_demo [network] [bw]
//! ```

use unzipfpga::accuracy::AccuracyModel;
use unzipfpga::arch::Platform;
use unzipfpga::autotune::autotune;
use unzipfpga::dse::search::DseConfig;
use unzipfpga::engine::{BackendKind, Engine};
use unzipfpga::workload::{Network, RatioProfile};

fn main() -> unzipfpga::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "resnet18".into());
    let net = Network::by_name(&name)
        .ok_or_else(|| unzipfpga::Error::InvalidConfig(format!("unknown network {name}")))?;
    let plat = Platform::z7045();
    let acc = AccuracyModel::for_network(&net);
    let initial = RatioProfile::ovsf25(&net);
    let cfg = DseConfig::default();

    println!("hardware-aware OVSF ratio autotuning — {} on {}", net.name, plat.name);
    println!(
        "starting point: OVSF25 (effective ρ {:.3}, modelled top-1 {:.1}%)\n",
        initial.effective_rho(&net),
        acc.top1(&net, &initial)
    );

    for bw in [1u32, 2, 4] {
        let r = autotune(&cfg, &plat, bw, &net)?;
        let raised = initial
            .rhos
            .iter()
            .zip(&r.profile.rhos)
            .filter(|(a, b)| *b > *a)
            .count();
        println!("— {bw}x bandwidth (σ = {}):", r.sigma);
        // Per-layer bound histogram before tuning (the ② analysis).
        let mut hist = std::collections::BTreeMap::new();
        for b in &r.initial_bounds {
            *hist.entry(b.label()).or_insert(0usize) += 1;
        }
        let hist_s: Vec<String> = hist.iter().map(|(k, v)| format!("{k}:{v}")).collect();
        println!("  bottlenecks at OVSF25 : {}", hist_s.join("  "));
        println!(
            "  ratios raised          : {raised}/{} OVSF layers (effective ρ {:.3} → {:.3})",
            net.layers.iter().filter(|l| l.ovsf).count(),
            initial.effective_rho(&net),
            r.profile.effective_rho(&net)
        );
        println!(
            "  throughput             : {:.1} → {:.1} inf/s (preserved)",
            r.initial_inf_per_s, r.final_inf_per_s
        );
        println!(
            "  modelled top-1         : {:.1}% → {:.1}% (+{:.1}pp at zero cost)",
            acc.top1(&net, &initial),
            acc.top1(&net, &r.profile),
            acc.top1(&net, &r.profile) - acc.top1(&net, &initial)
        );
        // Confirm the tuned profile on the unified Engine: the cycle-level
        // simulator backend must reproduce the preserved throughput.
        let mut engine = Engine::builder()
            .platform(plat.clone())
            .bandwidth(bw)
            .design_point(r.sigma)
            .network(net.clone())
            .profile(r.profile.clone())
            .backend(BackendKind::Simulator)
            .build()?;
        let report = engine.infer_timing()?;
        println!(
            "  engine[{}] check: {:.1} inf/s\n",
            report.backend,
            report.inf_per_s()
        );
    }
    Ok(())
}
