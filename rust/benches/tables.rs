//! Bench: regenerate every paper table end-to-end, timing each harness.
//! (`cargo bench --bench tables`; criterion is unavailable offline — the
//! in-repo `util::bench` harness provides warmup + stats.)

use unzipfpga::report::tables;
use unzipfpga::util::bench::bench_auto;

fn main() {
    println!("== paper-table regeneration benches ==");
    let t1 = bench_auto("table1 (ratio methods × bounds)", 400, || {
        tables::table1().unwrap().len()
    });
    let t3 = bench_auto("table3 (basis × extraction)", 100, || {
        tables::table3().unwrap().len()
    });
    let t4 = bench_auto("table4 (ResNet34 compression)", 400, || {
        tables::table4().unwrap().len()
    });
    let t5 = bench_auto("table5 (ResNet18 compression)", 400, || {
        tables::table5().unwrap().len()
    });
    let t6 = bench_auto("table6 (SqueezeNet)", 400, || {
        tables::table6().unwrap().len()
    });
    let t7 = bench_auto("table7 (prior work R18/34/SqN)", 400, || {
        tables::table7().unwrap().len()
    });
    let t8 = bench_auto("table8 (prior work R50)", 400, || {
        tables::table8().unwrap().len()
    });
    let t9 = bench_auto("table9 (resource breakdown)", 400, || {
        tables::table9().unwrap().len()
    });
    let t10 = bench_auto("table10 (selective-PE ablation)", 400, || {
        tables::table10().unwrap().len()
    });
    let total_ms = [&t1, &t3, &t4, &t5, &t6, &t7, &t8, &t9, &t10]
        .iter()
        .map(|r| r.mean_ns / 1e6)
        .sum::<f64>();
    println!("\nfull table suite: {total_ms:.1} ms (sum of means)");
}
