//! Replicated-serving benchmark: steady state vs mid-stream replica
//! failover.
//!
//! Drives a [`ReplicaSet`] (N independent registry + pool stacks behind
//! one dispatcher) with the `coordinator::traffic` load generator through
//! three phases:
//!
//! 1. **steady state** — open-loop Poisson stream against N healthy
//!    replicas (baseline p50/p99);
//! 2. **failover** — the same stream while a kill switch permanently
//!    destroys one replica's sole worker mid-stream (restart budget 0):
//!    requests caught on the dying replica re-dispatch as failover hedges,
//!    later arrivals spill past the closed queue, and the supervisor
//!    rebuilds the replica from the model catalog. Reports the during-
//!    failover tail and the kill → N-live-replicas recovery time;
//! 3. **recovered** — a final stream at full restored capacity.
//!
//! Emits `BENCH_replica.json` (override: `BENCH_REPLICA_JSON`). Arrival
//! schedules are pure functions of the seed. `BENCH_SMOKE=1` shrinks
//! stream durations for CI; the steady-state smoke run must complete
//! loss-free (asserted here — that is what fails CI on a dispatch or
//! drain regression).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::coordinator::pool::PoolConfig;
use unzipfpga::coordinator::registry::BackendWrap;
use unzipfpga::coordinator::replica::{HedgePolicy, ReplicaConfig, ReplicaSet, ReplicaState};
use unzipfpga::coordinator::traffic::{
    ArrivalProcess, RequestClass, TrafficReport, TrafficSpec,
};
use unzipfpga::engine::{
    CompiledModel, Compiler, EnginePlan, ExecutionBackend, ExecutionReport, LayerOutcome,
};
use unzipfpga::error::Result;
use unzipfpga::util::bench::smoke_mode;
use unzipfpga::util::prng::Xoshiro256;
use unzipfpga::workload::tiny::small_resnet;
use unzipfpga::workload::RatioProfile;

const SEED: u64 = 0x9e11;
const REPLICAS: usize = 3;
const RATE_RPS: f64 = 300.0;

/// Backend decorator that panics on the next execution once armed — the
/// bench's "pull the plug on this replica" lever.
struct KillSwitch {
    inner: Box<dyn ExecutionBackend>,
    armed: Arc<AtomicBool>,
}

impl ExecutionBackend for KillSwitch {
    fn name(&self) -> &'static str {
        "kill-switch"
    }

    fn plan(&mut self, plan: &EnginePlan) -> Result<()> {
        self.inner.plan(plan)
    }

    fn preload(&mut self, model: &Arc<CompiledModel>) -> Result<()> {
        self.inner.preload(model)
    }

    fn execute_layer(&mut self, idx: usize, input: &[f32]) -> Result<LayerOutcome> {
        if self.armed.load(Ordering::SeqCst) {
            panic!("kill switch fired");
        }
        self.inner.execute_layer(idx, input)
    }

    fn finish(&mut self) -> Result<ExecutionReport> {
        self.inner.finish()
    }
}

fn report_json(label: &str, r: &TrafficReport) -> String {
    format!(
        "    \"{label}\": {{\"offered\": {}, \"completed\": {}, \"shed\": {}, \
         \"queue_full\": {}, \"expired\": {}, \"failed\": {}, \
         \"p50_us\": {:.1}, \"p99_us\": {:.1}}}",
        r.offered,
        r.completed,
        r.shed,
        r.queue_full,
        r.expired,
        r.failed,
        r.percentile_us(50.0),
        r.percentile_us(99.0),
    )
}

fn accounted(r: &TrafficReport) {
    assert_eq!(
        r.offered,
        r.submitted + r.shed + r.queue_full + r.expired + r.failed,
        "every arrival must be accounted: {}",
        r.summary()
    );
    assert_eq!(r.harness_failures, 0, "harness must survive: {}", r.summary());
}

fn main() {
    println!("== replicated serving: steady state vs mid-stream failover ==");
    let smoke = smoke_mode();
    let duration_s = if smoke { 0.25 } else { 1.5 };

    let armed = Arc::new(AtomicBool::new(false));
    let armed_in_wrap = Arc::clone(&armed);
    let wrap: BackendWrap = Arc::new(move |backend, _worker| {
        Box::new(KillSwitch {
            inner: backend,
            armed: Arc::clone(&armed_in_wrap),
        })
    });
    let mut wraps: Vec<Option<BackendWrap>> = vec![None; REPLICAS];
    wraps[0] = Some(wrap);

    let mut cfg = ReplicaConfig::new(REPLICAS);
    cfg.pool = PoolConfig::single_worker();
    cfg.pool.queue_depth = 256;
    // One panic destroys the replica below the replica layer: the bench
    // measures the *set's* failover, not the pool's respawn path (that is
    // benches/serving.rs territory).
    cfg.pool.restart_budget = 0;
    cfg.pool.retries = 0;
    cfg.health.supervisor_tick = Duration::from_millis(2);
    cfg.hedge = Some(HedgePolicy::default());
    let set = ReplicaSet::start_with_wraps(cfg, wraps).unwrap();

    let net = small_resnet();
    let model = Compiler::new()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(DesignPoint::new(8, 4, 8, 4))
        .compile(net.clone(), RatioProfile::uniform(&net, 0.5))
        .unwrap();
    let input_len = model.input_len();
    set.register_model(net.name.clone(), model).unwrap();
    let input = Xoshiro256::seed_from_u64(SEED).normal_vec(input_len);

    let spec = |seed: u64| TrafficSpec {
        process: ArrivalProcess::Poisson { rate_rps: RATE_RPS },
        duration_s,
        seed,
        classes: vec![RequestClass::timing(net.name.clone()).with_input(input.clone())],
    };

    // -- 1. steady state: all replicas healthy, loss-free by contract.
    let steady = spec(SEED + 1).run_open_loop(&set);
    accounted(&steady);
    assert_eq!(
        steady.failed + steady.shed + steady.expired,
        0,
        "steady state must be loss-free: {}",
        steady.summary()
    );
    println!("   steady    {}", steady.summary());

    // -- 2. failover: arm the kill switch a third into the stream, disarm
    // shortly after (so supervisor rebuilds can succeed), and time the
    // kill → full-capacity recovery.
    let (failover, recovery) = std::thread::scope(|s| {
        let set_ref = &set;
        let failover_spec = spec(SEED + 2);
        let stream = s.spawn(move || failover_spec.run_open_loop(set_ref));
        std::thread::sleep(Duration::from_secs_f64(duration_s / 3.0));
        armed.store(true, Ordering::SeqCst);
        let t_kill = Instant::now();
        // Stay armed until the kill has provably landed (the supervisor
        // took replica 0 out of Healthy), then let the rebuild succeed.
        while set_ref.states()[0] == ReplicaState::Healthy {
            assert!(
                t_kill.elapsed() < Duration::from_secs(10),
                "kill switch never fired — no stream request reached replica 0"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        armed.store(false, Ordering::SeqCst);
        while !(set_ref.rebuilds() >= 1
            && set_ref.live_replicas() == REPLICAS
            && set_ref.states()[0] == ReplicaState::Healthy)
        {
            assert!(
                t_kill.elapsed() < Duration::from_secs(10),
                "supervisor failed to restore capacity within 10 s"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        let recovery = t_kill.elapsed();
        (stream.join().expect("traffic thread"), recovery)
    });
    accounted(&failover);
    assert!(failover.completed > 0, "{}", failover.summary());
    println!(
        "   failover  {} (recovered in {:.1} ms, hedges {}, wins {})",
        failover.summary(),
        recovery.as_secs_f64() * 1e3,
        set.hedges(),
        set.hedge_wins(),
    );

    // -- 3. recovered: full capacity again, loss-free.
    let recovered = spec(SEED + 3).run_open_loop(&set);
    accounted(&recovered);
    assert_eq!(
        recovered.failed + recovered.shed + recovered.expired,
        0,
        "restored capacity must serve loss-free: {}",
        recovered.summary()
    );
    println!("   recovered {}", recovered.summary());

    let hedges = set.hedges();
    let hedge_wins = set.hedge_wins();
    let rebuilds = set.rebuilds();
    assert!(rebuilds >= 1, "the failover phase must have forced a rebuild");
    let m = set.shutdown().unwrap();
    println!(
        "   shutdown: rebuilds {} hedges {} wins {} panicked_workers {}",
        rebuilds,
        hedges,
        hedge_wins,
        m.panicked_workers()
    );

    // -- JSON artifact.
    let path = std::env::var("BENCH_REPLICA_JSON")
        .unwrap_or_else(|_| "BENCH_replica.json".to_string());
    let mut out = String::from("{\n  \"bench\": \"replica-failover\",\n");
    out.push_str(&format!(
        "  \"smoke\": {smoke},\n  \"seed\": {SEED},\n  \"replicas\": {REPLICAS},\n  \
         \"rate_rps\": {RATE_RPS:.1},\n  \"duration_s\": {duration_s},\n  \"phases\": {{\n"
    ));
    out.push_str(&report_json("steady", &steady));
    out.push_str(",\n");
    out.push_str(&report_json("during_failover", &failover));
    out.push_str(",\n");
    out.push_str(&report_json("recovered", &recovered));
    out.push_str("\n  },\n");
    out.push_str(&format!(
        "  \"recovery_ms\": {:.1},\n  \"hedges\": {hedges},\n  \
         \"hedge_wins\": {hedge_wins},\n  \"rebuilds\": {rebuilds},\n  \
         \"panicked_workers\": {}\n}}\n",
        recovery.as_secs_f64() * 1e3,
        m.panicked_workers(),
    ));
    std::fs::write(&path, &out).expect("write BENCH_replica.json");
    println!("   wrote {path}");
}
