//! Bench: regenerate every paper figure's data series.

use unzipfpga::report::figures;
use unzipfpga::util::bench::bench_auto;

fn main() {
    println!("== paper-figure regeneration benches ==");
    bench_auto("fig8 (speedup vs bandwidth)", 800, || {
        figures::fig8().unwrap().len()
    });
    bench_auto("fig9 (accuracy-time Pareto)", 800, || {
        figures::fig9().unwrap().len()
    });
    bench_auto("fig10 (energy efficiency vs TX2)", 400, || {
        figures::fig10().unwrap().len()
    });
}
