//! PJRT runtime benches: artifact compile time and hot-path dispatch
//! latency (the coordinator's per-request cost). Skips cleanly when
//! artifacts are absent.

use unzipfpga::runtime::{artifacts_dir, ArtifactRegistry};
use unzipfpga::util::bench::bench_auto;
use unzipfpga::util::prng::Xoshiro256;

fn main() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP runtime benches: artifacts missing — run `make artifacts`");
        return;
    }
    println!("== PJRT runtime benches ==");
    let mut reg = ArtifactRegistry::new(dir).expect("client");

    bench_auto("compile: ovsf_wgen artifact (cold-ish)", 1500, || {
        // Re-load from text each iteration: measures parse+compile.
        let client = unzipfpga::runtime::RuntimeClient::cpu().unwrap();
        unzipfpga::runtime::LoadedExecutable::load(
            &client,
            &unzipfpga::runtime::artifacts_dir().join("ovsf_wgen.hlo.txt"),
        )
        .unwrap()
        .path
        .exists()
    });

    let mut rng = Xoshiro256::seed_from_u64(3);
    let alphas = rng.normal_vec(16 * 8 * 32);
    reg.get("ovsf_wgen").unwrap();
    bench_auto("execute: ovsf_wgen (α 16×8×32 → 144×32)", 800, || {
        reg.get("ovsf_wgen")
            .unwrap()
            .run_f32(&[(&alphas, &[16, 8, 32])])
            .unwrap()[0][0]
    });

    let a = rng.normal_vec(64 * 144);
    let w = rng.normal_vec(144 * 32);
    reg.get("gemm").unwrap();
    bench_auto("execute: gemm 64×144×32", 800, || {
        reg.get("gemm")
            .unwrap()
            .run_f32(&[(&a, &[64, 144]), (&w, &[144, 32])])
            .unwrap()[0][0]
    });

    let x = rng.normal_vec(16 * 16 * 16);
    reg.get("ovsf_conv").unwrap();
    bench_auto("execute: ovsf_conv 16×16×16 → ×32", 800, || {
        reg.get("ovsf_conv")
            .unwrap()
            .run_f32(&[(&x, &[1, 16, 16, 16]), (&alphas, &[16, 8, 32])])
            .unwrap()[0][0]
    });
}
