//! Serving-under-load benchmark: deterministic traffic vs the SLO-aware
//! pool.
//!
//! Drives a registry-routed `ServerPool` with the `coordinator::traffic`
//! load generator and reports achieved throughput and latency tails
//! (p50/p99/p999) against offered load:
//!
//! 1. **capacity calibration** — closed loop (one request in flight per
//!    client) measures the sustainable request rate for the mix;
//! 2. **offered-load grid** — open-loop Poisson / bursty / diurnal
//!    streams at low (0.25×), mid (0.5×) and over (1.2×) the calibrated
//!    capacity, a mixed two-model request stream with a deadline-carrying
//!    class, all against one pool with a queue-delay SLO;
//! 3. **warm vs cold model phases** — a warmed single-model stream, then
//!    a mixed stream whose second model is freshly registered (cold
//!    slabs);
//! 4. **overload policy comparison** — the same overload stream against
//!    an unthrottled FIFO pool (slo = None) and the SLO pool: FIFO lets
//!    queue delay grow unboundedly, admission control sheds typed
//!    `Overloaded` and keeps the admitted tail bounded.
//!
//! Emits `BENCH_serving.json` (override: `BENCH_SERVING_JSON`). Arrival
//! schedules are pure functions of the seed — re-runs offer the identical
//! request streams. `BENCH_SMOKE=1` shrinks stream durations for CI; the
//! low-load smoke run must complete shed-free and expiry-free (asserted
//! here, which is what fails CI on an admission-control regression).

use std::sync::Arc;
use std::time::Duration;

use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::coordinator::pool::{PoolConfig, PoolMetrics, ServerPool};
use unzipfpga::coordinator::registry::ModelRegistry;
use unzipfpga::coordinator::traffic::{
    run_closed_loop, ArrivalProcess, RequestClass, TrafficReport, TrafficSpec,
};
use unzipfpga::engine::{BackendKind, Compiler};
use unzipfpga::util::bench::smoke_mode;
use unzipfpga::util::prng::Xoshiro256;
use unzipfpga::workload::tiny::{small_mobilenet, small_resnet};
use unzipfpga::workload::RatioProfile;

const SEED: u64 = 0x5e21;
const WORKERS: usize = 2;
/// Admission threshold expressed in queued requests: the SLO is sized so
/// shedding starts near this queue depth — safely below `queue_depth`,
/// so overload surfaces as typed `Overloaded`, not `QueueFull`.
const SLO_QUEUE_REQUESTS: f64 = 64.0;
const QUEUE_DEPTH: usize = 256;

fn compiler() -> Compiler {
    Compiler::new()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(DesignPoint::new(8, 4, 8, 4))
}

fn pool_config(slo: Option<Duration>) -> PoolConfig {
    PoolConfig {
        workers: WORKERS,
        queue_depth: QUEUE_DEPTH,
        max_batch: 8,
        linger: Duration::from_micros(200),
        slo,
        ..PoolConfig::default()
    }
}

/// One emitted measurement row.
struct Row {
    process: &'static str,
    level: &'static str,
    report: TrafficReport,
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn row_json(r: &Row) -> String {
    format!(
        "    {{\"process\": \"{}\", \"level\": \"{}\", \"offered\": {}, \
         \"offered_rps\": {:.1}, \"achieved_rps\": {:.1}, \"completed\": {}, \
         \"shed\": {}, \"queue_full\": {}, \"expired\": {}, \"failed\": {}, \
         \"p50_us\": {:.1}, \"p99_us\": {:.1}, \"p999_us\": {:.1}}}",
        json_escape(r.process),
        json_escape(r.level),
        r.report.offered,
        r.report.offered_rps(),
        r.report.achieved_rps(),
        r.report.completed,
        r.report.shed,
        r.report.queue_full,
        r.report.expired,
        r.report.failed,
        r.report.percentile_us(50.0),
        r.report.percentile_us(99.0),
        r.report.percentile_us(99.9),
    )
}

fn print_row(r: &Row) {
    println!("   {:<8} {:<6} {}", r.process, r.level, r.report.summary());
}

fn main() {
    println!("== serving under load (traffic harness vs SLO pool) ==");
    let smoke = smoke_mode();
    let duration_s = if smoke { 0.2 } else { 1.5 };

    // -- registry: start with one warm model; the second registers later
    // (cold-phase measurement). Budget fits both models' slabs.
    let c = compiler();
    let registry = Arc::new(ModelRegistry::with_budget(1 << 20));
    let net_a = small_resnet();
    let net_b = small_mobilenet();
    let model_a = registry
        .register(
            net_a.name.clone(),
            c.compile(net_a.clone(), RatioProfile::uniform(&net_a, 0.5)).unwrap(),
        )
        .unwrap();
    let mut rng = Xoshiro256::seed_from_u64(SEED);
    let input_a = rng.normal_vec(model_a.input_len());

    // SLO sized in queued-request units of model A's plan latency.
    let slo = Duration::from_secs_f64(
        model_a.latency_s() * SLO_QUEUE_REQUESTS / WORKERS as f64,
    );
    println!(
        "   slo = {:.2} ms (≈{} queued requests at plan latency {:.1} µs)",
        slo.as_secs_f64() * 1e3,
        SLO_QUEUE_REQUESTS as u64,
        model_a.latency_s() * 1e6
    );
    let pool = ServerPool::serve(
        Arc::clone(&registry),
        BackendKind::Simulator,
        pool_config(Some(slo)),
    )
    .unwrap();

    let class_a = || {
        RequestClass::timing(net_a.name.clone())
            .with_input(input_a.clone())
            .with_weight(1.0)
    };

    // -- 1. capacity calibration (closed loop, one model, warm slabs).
    let calib = run_closed_loop(
        &pool,
        &[class_a()],
        2 * WORKERS,
        if smoke { 50 } else { 400 },
        SEED,
    );
    let capacity_rps = calib.achieved_rps();
    // Open-loop pacing is sleep-based: beyond ~20 krps the scheduler
    // cannot honour individual gaps, so clamp the rate the levels scale
    // from (recorded separately in the JSON).
    let paced_rps = capacity_rps.min(20_000.0);
    println!(
        "   capacity: {:.0} req/s closed-loop ({} clients); pacing from {:.0} req/s",
        capacity_rps,
        2 * WORKERS,
        paced_rps
    );
    assert!(capacity_rps > 0.0, "calibration served nothing");
    assert_eq!(
        calib.shed + calib.expired,
        0,
        "closed loop at {} clients must never trip admission: {}",
        2 * WORKERS,
        calib.summary()
    );

    // -- 2. warm vs cold phases at mid load.
    let mid = 0.5 * paced_rps;
    let warm_spec = TrafficSpec {
        process: ArrivalProcess::Poisson { rate_rps: mid },
        duration_s,
        seed: SEED + 1,
        classes: vec![class_a()],
    };
    let mut rows = vec![Row {
        process: "poisson",
        level: "warm_single",
        report: warm_spec.run_open_loop(&pool),
    }];
    print_row(&rows[0]);

    let model_b = registry
        .register(
            net_b.name.clone(),
            c.compile(net_b.clone(), RatioProfile::uniform(&net_b, 0.5)).unwrap(),
        )
        .unwrap();
    let input_b = rng.normal_vec(model_b.input_len());
    let class_b = || {
        RequestClass::timing(net_b.name.clone())
            .with_input(input_b.clone())
            .with_weight(0.5)
    };
    let cold_spec = TrafficSpec {
        process: ArrivalProcess::Poisson { rate_rps: mid },
        duration_s,
        seed: SEED + 2,
        classes: vec![class_a(), class_b()],
    };
    rows.push(Row {
        process: "poisson",
        level: "cold_mix",
        report: cold_spec.run_open_loop(&pool),
    });
    print_row(rows.last().unwrap());

    // -- 3. offered-load grid: 3 processes × 3 levels, mixed two-model
    // stream plus a deadline-carrying class (deadline = the SLO itself).
    let mix = || {
        vec![
            class_a().with_weight(0.55),
            class_b().with_weight(0.3),
            class_a().with_weight(0.15).with_deadline(slo).with_priority(1),
        ]
    };
    let processes: [(&'static str, Box<dyn Fn(f64) -> ArrivalProcess>); 3] = [
        (
            "poisson",
            Box::new(|r| ArrivalProcess::Poisson { rate_rps: r }),
        ),
        (
            "bursty",
            Box::new(|r| ArrivalProcess::Bursty {
                // Same long-run mean r: quiet at r/2, bursts at 5r/2,
                // one mean burst per three phase lengths.
                base_rps: 0.5 * r,
                burst_rps: 2.5 * r,
                mean_on_s: 0.05,
                mean_off_s: 0.10,
            }),
        ),
        (
            "diurnal",
            Box::new(|r| ArrivalProcess::Diurnal {
                mean_rps: r,
                period_s: 0.5,
                swing: 0.8,
            }),
        ),
    ];
    let levels: [(&'static str, f64); 3] = [("low", 0.25), ("mid", 0.5), ("over", 1.2)];
    for (pi, (pname, make)) in processes.iter().enumerate() {
        for (li, (lname, frac)) in levels.iter().enumerate() {
            let spec = TrafficSpec {
                process: make(frac * paced_rps),
                duration_s,
                seed: SEED + 10 + (pi * levels.len() + li) as u64,
                classes: mix(),
            };
            let report = spec.run_open_loop(&pool);
            let row = Row {
                process: *pname,
                level: *lname,
                report,
            };
            print_row(&row);
            if *lname == "low" {
                // CI gate: a quarter of capacity must never trip
                // admission control or deadlines — shedding here means
                // the queue-delay estimate (or EDF expiry sweep) broke.
                assert_eq!(
                    row.report.shed, 0,
                    "{pname}/low shed {} requests: {}",
                    row.report.shed,
                    row.report.summary()
                );
                assert_eq!(
                    row.report.expired, 0,
                    "{pname}/low expired {} requests: {}",
                    row.report.expired,
                    row.report.summary()
                );
            }
            rows.push(row);
        }
    }
    let pm = pool.shutdown().unwrap();
    println!("   grid pool: {}", pm.summary());

    // -- 4. overload policy comparison on fresh pools: FIFO (no SLO)
    // vs admission control, identical 1.5× overload stream.
    let over_spec = |seed: u64| TrafficSpec {
        process: ArrivalProcess::Poisson {
            rate_rps: 1.5 * paced_rps,
        },
        duration_s,
        seed,
        classes: mix(),
    };
    let run_policy = |slo: Option<Duration>| -> (TrafficReport, PoolMetrics) {
        let pool = ServerPool::serve(
            Arc::clone(&registry),
            BackendKind::Simulator,
            pool_config(slo),
        )
        .unwrap();
        let report = over_spec(SEED + 99).run_open_loop(&pool);
        (report, pool.shutdown().unwrap())
    };
    let (fifo_report, fifo_pm) = run_policy(None);
    let (slo_report, slo_pm) = run_policy(Some(slo));
    let fifo_qd99 = fifo_pm.merged().queue_delay_percentile_us(99.0);
    let slo_qd99 = slo_pm.merged().queue_delay_percentile_us(99.0);
    println!(
        "   overload 1.5×: FIFO queue-delay p99 {:.0} µs (shed {}), \
         SLO queue-delay p99 {:.0} µs (shed {})",
        fifo_qd99, fifo_report.shed, slo_qd99, slo_report.shed
    );
    assert_eq!(
        fifo_report.shed, 0,
        "a pool without an SLO must never shed: {}",
        fifo_report.summary()
    );

    // -- JSON artifact.
    let path = std::env::var("BENCH_SERVING_JSON")
        .unwrap_or_else(|_| "BENCH_serving.json".to_string());
    let mut out = String::from("{\n  \"bench\": \"serving-under-load\",\n");
    out.push_str(&format!(
        "  \"smoke\": {},\n  \"seed\": {},\n  \"workers\": {},\n  \
         \"queue_depth\": {},\n  \"slo_ms\": {:.3},\n  \
         \"capacity_rps\": {:.1},\n  \"paced_rps\": {:.1},\n  \"runs\": [\n",
        smoke,
        SEED,
        WORKERS,
        QUEUE_DEPTH,
        slo.as_secs_f64() * 1e3,
        capacity_rps,
        paced_rps
    ));
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&row_json(r));
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n  \"overload_comparison\": {\n");
    out.push_str(&format!(
        "    \"offered_rps\": {:.1},\n    \"fifo_queue_delay_p99_us\": {:.1},\n    \
         \"slo_queue_delay_p99_us\": {:.1},\n    \"fifo_shed\": {},\n    \
         \"fifo_queue_full\": {},\n    \"slo_shed\": {},\n    \
         \"slo_admitted_p99_us\": {:.1},\n    \"fifo_p99_us\": {:.1}\n  }}\n}}\n",
        fifo_report.offered_rps(),
        fifo_qd99,
        slo_qd99,
        fifo_report.shed,
        fifo_report.queue_full,
        slo_report.shed,
        slo_report.percentile_us(99.0),
        fifo_report.percentile_us(99.0),
    ));
    std::fs::write(&path, &out).expect("write BENCH_serving.json");
    println!("   wrote {path}");
}
