//! Hot-path microbenches: the inner loops the §Perf pass optimises.
//!
//! * analytical perf-model evaluation (DSE inner loop)
//! * full DSE sweep (feasible-point enumeration rate)
//! * cycle-level network simulation
//! * TiWGen numeric weight generation
//! * OVSF reconstruction algebra (matrix-free FWHT path)
//! * autotuner end-to-end
//!
//! The OVSF weights-generation section additionally measures ResNet-18/50
//! layer shapes against the dense-matrix baseline and emits a
//! machine-readable `BENCH_ovsf.json` (path override: `BENCH_OVSF_JSON`)
//! so the perf trajectory is tracked across PRs. The end-to-end numeric
//! `Engine::infer` section measures tile-streamed inference throughput and
//! peak resident generated-weight bytes on ResNet-18/50 and emits
//! `BENCH_infer.json` (override: `BENCH_INFER_JSON`). `BENCH_SMOKE=1`
//! clamps budgets for CI.

use std::sync::Arc;

use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::autotune::autotune;
use unzipfpga::dse::search::{optimise, sweep, DseConfig};
use unzipfpga::engine::{BackendKind, Engine, SlabCache};
use unzipfpga::ovsf::basis::{select, BasisSelection, SelectedBasis};
use unzipfpga::ovsf::codes::OvsfBasis;
use unzipfpga::ovsf::reconstruct::{Filter3x3Mode, OvsfLayer};
use unzipfpga::perf::model::PerfModel;
use unzipfpga::sim::engine::simulate_network_timing;
use unzipfpga::sim::hw_weights::HwOvsfWeights;
use unzipfpga::sim::ovsf_gen::OvsfGenerator;
use unzipfpga::sim::wgen::WGenSim;
use unzipfpga::util::bench::{bench, bench_auto, smoke_mode};
use unzipfpga::util::prng::Xoshiro256;
use unzipfpga::workload::{resnet, RatioProfile};

/// Dense Sylvester materialisation — the pre-rewrite O(L²) baseline the
/// matrix-free path is compared against (production code no longer builds
/// this; the bench keeps its own copy for the before/after numbers).
fn dense_sylvester(len: usize) -> Vec<i8> {
    let mut codes = vec![1i8];
    let mut cur = 1usize;
    while cur < len {
        let next = cur * 2;
        let mut out = vec![0i8; next * next];
        for r in 0..cur {
            for c in 0..cur {
                let v = codes[r * cur + c];
                out[r * next + c] = v;
                out[r * next + cur + c] = v;
                out[(cur + r) * next + c] = v;
                out[(cur + r) * next + cur + c] = -v;
            }
        }
        codes = out;
        cur = next;
    }
    codes
}

/// Dense-matrix per-filter regression + reconstruction (the old
/// `from_weights`/`reconstruct` inner loop): L dot products + |sel|·L
/// accumulation.
fn dense_filter_roundtrip(dense: &[i8], l: usize, target: &[f32], rho: f64) -> f32 {
    let inv_l = 1.0f64 / l as f64;
    let alphas: Vec<f32> = (0..l)
        .map(|j| {
            let mut acc = 0.0f64;
            for (t, &v) in target.iter().enumerate() {
                acc += v as f64 * dense[j * l + t] as f64;
            }
            (acc * inv_l) as f32
        })
        .collect();
    let basis = OvsfBasis::new(l).unwrap();
    let sel: SelectedBasis = select(BasisSelection::IterativeDrop, &basis, &alphas, rho);
    let mut out = vec![0.0f32; l];
    for (k, &j) in sel.indices.iter().enumerate() {
        let a = sel.alphas[k];
        for (t, o) in out.iter_mut().enumerate() {
            *o += a * dense[j * l + t] as f32;
        }
    }
    out[0]
}

struct OvsfRow {
    name: String,
    shape: String,
    l: usize,
    rho: f64,
    /// Dense-matrix baseline, when one was actually measured (`None` for
    /// paths that have no dense counterpart — no fabricated speedups).
    before_ns_per_layer: Option<f64>,
    after_ns_per_layer: f64,
    layers_per_s: f64,
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn write_bench_json(rows: &[OvsfRow]) {
    let path =
        std::env::var("BENCH_OVSF_JSON").unwrap_or_else(|_| "BENCH_ovsf.json".to_string());
    let mut out = String::from("{\n  \"bench\": \"ovsf-weights-generation\",\n");
    out.push_str(&format!("  \"smoke\": {},\n  \"entries\": [\n", smoke_mode()));
    for (i, r) in rows.iter().enumerate() {
        let before = match r.before_ns_per_layer {
            Some(b) if r.after_ns_per_layer > 0.0 => format!(
                "\"before_ns_per_layer\": {:.1}, \"speedup\": {:.2}, ",
                b,
                b / r.after_ns_per_layer
            ),
            _ => String::new(),
        };
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"shape\": \"{}\", \"l\": {}, \"rho\": {}, \
             {}\"after_ns_per_layer\": {:.1}, \"layers_per_s\": {:.3}}}{}\n",
            json_escape(&r.name),
            json_escape(&r.shape),
            r.l,
            r.rho,
            before,
            r.after_ns_per_layer,
            r.layers_per_s,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write(&path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// OVSF weights-generation hot path at real layer shapes: the FWHT
/// `from_weights` + `reconstruct` roundtrip and the TiWGen walk, with the
/// dense-matrix baseline extrapolated from a few filters (running it for
/// all N_out would take minutes at L=8192 — that was the point).
fn bench_ovsf_weights_generation() -> Vec<OvsfRow> {
    println!("-- OVSF weights generation (ResNet layer shapes) --");
    let rho = 0.5;
    // (label, n_out, n_in) at K=3: ResNet-18 stage-1, stage-3, and the
    // ResNet-18/50 worst case 512×512 (L = 512·16 = 8192).
    let shapes: [(&str, usize, usize); 3] =
        [("64x64x3x3", 64, 64), ("256x256x3x3", 256, 256), ("512x512x3x3", 512, 512)];
    let mut rows = Vec::new();
    for (label, n_out, n_in) in shapes {
        let k = 3usize;
        let k_ovsf = 4usize;
        let l = n_in * k_ovsf * k_ovsf;
        let mut rng = Xoshiro256::seed_from_u64(0xb0b0 ^ l as u64);
        let weights = rng.normal_vec(n_out * n_in * k * k);

        // After: matrix-free FWHT path, full layer.
        let fwht = bench_auto(
            &format!("ovsf: from_weights+reconstruct {label} (FWHT)"),
            600,
            || {
                let layer = OvsfLayer::from_weights(
                    &weights,
                    n_out,
                    n_in,
                    k,
                    rho,
                    BasisSelection::IterativeDrop,
                    Filter3x3Mode::Crop,
                )
                .unwrap();
                layer.reconstruct().unwrap()[0]
            },
        );

        // Before: dense-matrix baseline, measured on a few filters and
        // extrapolated to the full layer (linear in N_out).
        let dense = dense_sylvester(l);
        let bench_filters = if l >= 4096 { 2usize } else { 8 };
        let dense_r = bench_auto(
            &format!("ovsf: {bench_filters}-filter roundtrip {label} (dense baseline)"),
            400,
            || {
                let mut acc = 0.0f32;
                for o in 0..bench_filters {
                    let target = &weights[o * n_in * k * k..(o + 1) * n_in * k * k];
                    // Zero-pad the 3×3 filter into the K'×K' frame.
                    let mut frame = vec![0.0f32; l];
                    for c in 0..n_in {
                        for kh in 0..k {
                            for kw in 0..k {
                                frame[(c * k_ovsf + kh) * k_ovsf + kw] =
                                    target[(c * k + kh) * k + kw];
                            }
                        }
                    }
                    acc += dense_filter_roundtrip(&dense, l, &frame, rho);
                }
                acc
            },
        );
        let before_ns = dense_r.mean_ns * n_out as f64 / bench_filters as f64;
        rows.push(OvsfRow {
            name: "from_weights+reconstruct".into(),
            shape: label.into(),
            l,
            rho,
            before_ns_per_layer: Some(before_ns),
            after_ns_per_layer: fwht.mean_ns,
            layers_per_s: 1e9 / fwht.mean_ns,
        });

        // TiWGen numeric generation at the same shape (chunk-basis form).
        let hw = HwOvsfWeights::random(&mut rng, n_out, n_in, k, rho).unwrap();
        let sigma = DesignPoint::new(64, 64, 16, 64);
        let wg = bench_auto(
            &format!("sim: TiWGen generate {label} (ρ=.5)"),
            500,
            || WGenSim::new(&sigma, &hw).generate().vector_macs,
        );
        rows.push(OvsfRow {
            name: "wgen_generate".into(),
            shape: label.into(),
            l,
            rho,
            before_ns_per_layer: None, // no dense counterpart for the walk
            after_ns_per_layer: wg.mean_ns,
            layers_per_s: 1e9 / wg.mean_ns,
        });
    }
    rows
}

struct InferRow {
    network: String,
    input_len: usize,
    slab_budget_bytes: usize,
    peak_resident_weight_bytes: usize,
    dense_ovsf_weight_bytes: u64,
    ns_per_infer: f64,
    inf_per_s: f64,
}

fn write_infer_json(rows: &[InferRow]) {
    let path =
        std::env::var("BENCH_INFER_JSON").unwrap_or_else(|_| "BENCH_infer.json".to_string());
    let mut out = String::from("{\n  \"bench\": \"engine-infer-tile-streamed\",\n");
    out.push_str(&format!("  \"smoke\": {},\n  \"entries\": [\n", smoke_mode()));
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"network\": \"{}\", \"input_len\": {}, \"slab_budget_bytes\": {}, \
             \"peak_resident_weight_bytes\": {}, \"dense_ovsf_weight_bytes\": {}, \
             \"ns_per_infer\": {:.1}, \"inf_per_s\": {:.4}}}{}\n",
            json_escape(&r.network),
            r.input_len,
            r.slab_budget_bytes,
            r.peak_resident_weight_bytes,
            r.dense_ovsf_weight_bytes,
            r.ns_per_infer,
            r.inf_per_s,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write(&path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// End-to-end numeric `Engine::infer` on the simulator backend: real
/// activations through the PE array with per-tile on-the-fly weights
/// generation under a bounded slab budget. Reports throughput plus the
/// memory-footprint comparison (full dense materialisation vs measured
/// peak resident slab bytes).
fn bench_engine_infer() -> Vec<InferRow> {
    println!("-- end-to-end Engine::infer (tile-streamed numerics) --");
    let budget = 8usize << 20; // 8 MiB — a fraction of any ImageNet model
    let mut rows = Vec::new();
    for net in [resnet::resnet18(), resnet::resnet50()] {
        let profile = RatioProfile::ovsf50(&net);
        let dense_ovsf_weight_bytes: u64 = net
            .layers
            .iter()
            .filter(|l| l.ovsf)
            .map(|l| {
                let g = l.gemm();
                g.p * g.c * std::mem::size_of::<f32>() as u64
            })
            .sum();
        let cache = Arc::new(SlabCache::with_budget(budget));
        let mut engine = Engine::builder()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(DesignPoint::new(64, 64, 16, 48))
            .network(net.clone())
            .profile(profile)
            .backend(BackendKind::Simulator)
            .weights_cache(Arc::clone(&cache))
            .build()
            .unwrap();
        let l0 = &net.layers[0];
        let input_len = (l0.h * l0.w * l0.n_in) as usize;
        let mut rng = Xoshiro256::seed_from_u64(0x1f3);
        let input = rng.normal_vec(input_len);
        // A full ImageNet inference is seconds of scalar GEMM: size the
        // iteration count directly instead of auto-calibrating (the probe
        // iteration alone would blow the smoke budget).
        let iters = if smoke_mode() { 1 } else { 3 };
        let r = bench(
            &format!("engine: {} numeric infer (slab budget 8 MiB)", net.name),
            0,
            iters,
            || engine.infer(&input).unwrap().output[0],
        );
        let peak = cache.peak_resident_bytes();
        assert!(
            peak <= budget,
            "{}: peak resident weights {peak} exceed the {budget}-byte budget",
            net.name
        );
        println!(
            "   {}: dense OVSF weights {:.1} MiB vs peak resident {:.2} MiB (budget 8 MiB)",
            net.name,
            dense_ovsf_weight_bytes as f64 / (1 << 20) as f64,
            peak as f64 / (1 << 20) as f64
        );
        rows.push(InferRow {
            network: net.name.clone(),
            input_len,
            slab_budget_bytes: budget,
            peak_resident_weight_bytes: peak,
            dense_ovsf_weight_bytes,
            ns_per_infer: r.mean_ns,
            inf_per_s: 1e9 / r.mean_ns,
        });
    }
    rows
}

fn main() {
    println!("== L3 hot-path microbenches ==");
    let net = resnet::resnet18();
    let profile = RatioProfile::ovsf50(&net);
    let plat = Platform::z7045();
    let sigma = DesignPoint::new(64, 64, 16, 48);
    let model = PerfModel::new(plat.clone(), 4);

    bench_auto("perf_model: ResNet18 network_perf", 600, || {
        model.network_perf(&sigma, &net, &profile).total_cycles
    });

    let cfg = DseConfig::default();
    bench_auto("dse: full sweep (1200 pts, ResNet18)", 1500, || {
        sweep(&cfg, &plat, 4, &net, &profile, true).len()
    });

    bench_auto("dse: optimise (argmax incl. sweep)", 1500, || {
        optimise(&cfg, &plat, 4, &net, &profile, true)
            .unwrap()
            .perf
            .inf_per_s
    });

    bench_auto("sim: ResNet18 timing walk", 800, || {
        simulate_network_timing(&sigma, &plat, 4, true, &net, &profile).len()
    });

    let basis = OvsfBasis::new(16).unwrap();
    bench_auto("sim: OVSF FIFO/aligner 10k emits (M=48)", 400, || {
        let mut g = OvsfGenerator::new(&basis, 8, 48);
        let mut buf = Vec::with_capacity(48);
        let mut acc = 0i32;
        for _ in 0..10_000 {
            g.emit_into(&mut buf);
            acc += buf[0] as i32;
        }
        acc
    });

    let basis256 = OvsfBasis::new(256).unwrap();
    let mut rng2 = Xoshiro256::seed_from_u64(2);
    let target = rng2.normal_vec(256);
    bench_auto("ovsf: project+reconstruct L=256 (FWHT)", 400, || {
        let alphas = unzipfpga::ovsf::regress::project(&basis256, &target);
        let sel = select(BasisSelection::IterativeDrop, &basis256, &alphas, 0.5);
        unzipfpga::ovsf::regress::reconstruct_vec(&basis256, &sel)[0]
    });

    let rows = bench_ovsf_weights_generation();
    write_bench_json(&rows);

    let infer_rows = bench_engine_infer();
    write_infer_json(&infer_rows);

    bench_auto("autotune: ResNet18 @ 2x end-to-end", 2000, || {
        autotune(&cfg, &plat, 2, &net).unwrap().final_inf_per_s
    });
}
