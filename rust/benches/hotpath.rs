//! Hot-path microbenches: the inner loops the §Perf pass optimises.
//!
//! * analytical perf-model evaluation (DSE inner loop)
//! * full DSE sweep (feasible-point enumeration rate)
//! * cycle-level network simulation
//! * TiWGen numeric weight generation
//! * OVSF reconstruction algebra
//! * autotuner end-to-end

use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::autotune::autotune;
use unzipfpga::dse::search::{optimise, sweep, DseConfig};
use unzipfpga::ovsf::codes::OvsfBasis;
use unzipfpga::perf::model::PerfModel;
use unzipfpga::sim::engine::simulate_network_timing;
use unzipfpga::sim::hw_weights::HwOvsfWeights;
use unzipfpga::sim::ovsf_gen::OvsfGenerator;
use unzipfpga::sim::wgen::WGenSim;
use unzipfpga::util::bench::bench_auto;
use unzipfpga::util::prng::Xoshiro256;
use unzipfpga::workload::{resnet, RatioProfile};

fn main() {
    println!("== L3 hot-path microbenches ==");
    let net = resnet::resnet18();
    let profile = RatioProfile::ovsf50(&net);
    let plat = Platform::z7045();
    let sigma = DesignPoint::new(64, 64, 16, 48);
    let model = PerfModel::new(plat.clone(), 4);

    bench_auto("perf_model: ResNet18 network_perf", 600, || {
        model.network_perf(&sigma, &net, &profile).total_cycles
    });

    let cfg = DseConfig::default();
    bench_auto("dse: full sweep (1200 pts, ResNet18)", 1500, || {
        sweep(&cfg, &plat, 4, &net, &profile, true).len()
    });

    bench_auto("dse: optimise (argmax incl. sweep)", 1500, || {
        optimise(&cfg, &plat, 4, &net, &profile, true)
            .unwrap()
            .perf
            .inf_per_s
    });

    bench_auto("sim: ResNet18 timing walk", 800, || {
        simulate_network_timing(&sigma, &plat, 4, true, &net, &profile).len()
    });

    let mut rng = Xoshiro256::seed_from_u64(1);
    let hw = HwOvsfWeights::random(&mut rng, 64, 64, 3, 0.5).unwrap();
    let wg_sigma = DesignPoint::new(64, 64, 16, 64);
    bench_auto("sim: TiWGen generate 64×64×3×3 (ρ=.5)", 900, || {
        WGenSim::new(&wg_sigma, &hw).generate().vector_macs
    });

    let basis = OvsfBasis::new(16).unwrap();
    bench_auto("sim: OVSF FIFO/aligner 10k emits (M=48)", 400, || {
        let mut g = OvsfGenerator::new(&basis, 8, 48);
        let mut buf = Vec::with_capacity(48);
        let mut acc = 0i32;
        for _ in 0..10_000 {
            g.emit_into(&mut buf);
            acc += buf[0] as i32;
        }
        acc
    });

    let basis256 = OvsfBasis::new(256).unwrap();
    let mut rng2 = Xoshiro256::seed_from_u64(2);
    let target = rng2.normal_vec(256);
    bench_auto("ovsf: project+reconstruct L=256", 400, || {
        let alphas = unzipfpga::ovsf::regress::project(&basis256, &target);
        let sel = unzipfpga::ovsf::basis::select(
            unzipfpga::ovsf::basis::BasisSelection::IterativeDrop,
            &basis256,
            &alphas,
            0.5,
        );
        unzipfpga::ovsf::regress::reconstruct_vec(&basis256, &sel)[0]
    });

    bench_auto("autotune: ResNet18 @ 2x end-to-end", 2000, || {
        autotune(&cfg, &plat, 2, &net).unwrap().final_inf_per_s
    });
}
