//! Hot-path microbenches: the inner loops the §Perf pass optimises.
//!
//! * analytical perf-model evaluation (DSE inner loop)
//! * full DSE sweep (feasible-point enumeration rate)
//! * cycle-level network simulation
//! * TiWGen numeric weight generation
//! * OVSF reconstruction algebra (matrix-free FWHT path)
//! * autotuner end-to-end
//!
//! The OVSF weights-generation section additionally measures ResNet-18/50
//! layer shapes against the dense-matrix baseline and emits a
//! machine-readable `BENCH_ovsf.json` (path override: `BENCH_OVSF_JSON`)
//! so the perf trajectory is tracked across PRs. The end-to-end numeric
//! `Engine::infer` section measures the serial generate-then-multiply
//! schedule against the pipelined slab-prefetch datapath on ResNet-18/50
//! (throughput, speedup, hidden-generation fraction, peak resident
//! generated-weight bytes) and emits `BENCH_infer.json` (override:
//! `BENCH_INFER_JSON`); each network is measured at both f32 and i8
//! precision (the i8 rows carry a `-i8` label suffix plus warm-pass
//! cache hit-rate columns, and the microkernel section reports the
//! i8×i8→i32 strip's speedup over the f32 blocked kernel).
//! `BENCH_WRITE_BASELINE=1` additionally refreshes
//! the committed `BENCH_baseline.json` the CI regression gate reads.
//! The multi-model section serves ResNet-18 + SqueezeNet interleaved
//! through one registry-routed `ServerPool` under a shared slab budget
//! and emits `BENCH_multimodel.json` (override: `BENCH_MULTIMODEL_JSON`)
//! — per-model latency percentiles, model-switch counts and shared-cache
//! contention counters. `BENCH_SMOKE=1` clamps budgets for CI.

use std::sync::Arc;

use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::autotune::autotune;
use unzipfpga::dse::search::{optimise, sweep, DseConfig};
use unzipfpga::engine::{Engine, FaultPlan, FaultyBackend, Precision, SimBackend, SlabCache};
use unzipfpga::ovsf::basis::{select, BasisSelection, SelectedBasis};
use unzipfpga::ovsf::codes::OvsfBasis;
use unzipfpga::ovsf::reconstruct::{Filter3x3Mode, OvsfLayer};
use unzipfpga::perf::model::PerfModel;
use unzipfpga::sim::engine::simulate_network_timing;
use unzipfpga::sim::hw_weights::HwOvsfWeights;
use unzipfpga::sim::ovsf_gen::OvsfGenerator;
use unzipfpga::sim::quant::i8_error_bound;
use unzipfpga::sim::wgen::WGenSim;
use unzipfpga::util::bench::{bench, bench_auto, smoke_mode};
use unzipfpga::util::fixed::I8Scheme;
use unzipfpga::util::prng::Xoshiro256;
use unzipfpga::workload::{resnet, Network, RatioProfile};

/// Dense Sylvester materialisation — the pre-rewrite O(L²) baseline the
/// matrix-free path is compared against (production code no longer builds
/// this; the bench keeps its own copy for the before/after numbers).
fn dense_sylvester(len: usize) -> Vec<i8> {
    let mut codes = vec![1i8];
    let mut cur = 1usize;
    while cur < len {
        let next = cur * 2;
        let mut out = vec![0i8; next * next];
        for r in 0..cur {
            for c in 0..cur {
                let v = codes[r * cur + c];
                out[r * next + c] = v;
                out[r * next + cur + c] = v;
                out[(cur + r) * next + c] = v;
                out[(cur + r) * next + cur + c] = -v;
            }
        }
        codes = out;
        cur = next;
    }
    codes
}

/// Dense-matrix per-filter regression + reconstruction (the old
/// `from_weights`/`reconstruct` inner loop): L dot products + |sel|·L
/// accumulation.
fn dense_filter_roundtrip(dense: &[i8], l: usize, target: &[f32], rho: f64) -> f32 {
    let inv_l = 1.0f64 / l as f64;
    let alphas: Vec<f32> = (0..l)
        .map(|j| {
            let mut acc = 0.0f64;
            for (t, &v) in target.iter().enumerate() {
                acc += v as f64 * dense[j * l + t] as f64;
            }
            (acc * inv_l) as f32
        })
        .collect();
    let basis = OvsfBasis::new(l).unwrap();
    let sel: SelectedBasis = select(BasisSelection::IterativeDrop, &basis, &alphas, rho);
    let mut out = vec![0.0f32; l];
    for (k, &j) in sel.indices.iter().enumerate() {
        let a = sel.alphas[k];
        for (t, o) in out.iter_mut().enumerate() {
            *o += a * dense[j * l + t] as f32;
        }
    }
    out[0]
}

struct OvsfRow {
    name: String,
    shape: String,
    l: usize,
    rho: f64,
    /// Dense-matrix baseline, when one was actually measured (`None` for
    /// paths that have no dense counterpart — no fabricated speedups).
    before_ns_per_layer: Option<f64>,
    after_ns_per_layer: f64,
    layers_per_s: f64,
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn write_bench_json(rows: &[OvsfRow]) {
    let path =
        std::env::var("BENCH_OVSF_JSON").unwrap_or_else(|_| "BENCH_ovsf.json".to_string());
    let mut out = String::from("{\n  \"bench\": \"ovsf-weights-generation\",\n");
    out.push_str(&format!("  \"smoke\": {},\n  \"entries\": [\n", smoke_mode()));
    for (i, r) in rows.iter().enumerate() {
        let before = match r.before_ns_per_layer {
            Some(b) if r.after_ns_per_layer > 0.0 => format!(
                "\"before_ns_per_layer\": {:.1}, \"speedup\": {:.2}, ",
                b,
                b / r.after_ns_per_layer
            ),
            _ => String::new(),
        };
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"shape\": \"{}\", \"l\": {}, \"rho\": {}, \
             {}\"after_ns_per_layer\": {:.1}, \"layers_per_s\": {:.3}}}{}\n",
            json_escape(&r.name),
            json_escape(&r.shape),
            r.l,
            r.rho,
            before,
            r.after_ns_per_layer,
            r.layers_per_s,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write(&path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// OVSF weights-generation hot path at real layer shapes: the FWHT
/// `from_weights` + `reconstruct` roundtrip and the TiWGen walk, with the
/// dense-matrix baseline extrapolated from a few filters (running it for
/// all N_out would take minutes at L=8192 — that was the point).
fn bench_ovsf_weights_generation() -> Vec<OvsfRow> {
    println!("-- OVSF weights generation (ResNet layer shapes) --");
    let rho = 0.5;
    // (label, n_out, n_in) at K=3: ResNet-18 stage-1, stage-3, and the
    // ResNet-18/50 worst case 512×512 (L = 512·16 = 8192).
    let shapes: [(&str, usize, usize); 3] =
        [("64x64x3x3", 64, 64), ("256x256x3x3", 256, 256), ("512x512x3x3", 512, 512)];
    let mut rows = Vec::new();
    for (label, n_out, n_in) in shapes {
        let k = 3usize;
        let k_ovsf = 4usize;
        let l = n_in * k_ovsf * k_ovsf;
        let mut rng = Xoshiro256::seed_from_u64(0xb0b0 ^ l as u64);
        let weights = rng.normal_vec(n_out * n_in * k * k);

        // After: matrix-free FWHT path, full layer.
        let fwht = bench_auto(
            &format!("ovsf: from_weights+reconstruct {label} (FWHT)"),
            600,
            || {
                let layer = OvsfLayer::from_weights(
                    &weights,
                    n_out,
                    n_in,
                    k,
                    rho,
                    BasisSelection::IterativeDrop,
                    Filter3x3Mode::Crop,
                )
                .unwrap();
                layer.reconstruct().unwrap()[0]
            },
        );

        // Before: dense-matrix baseline, measured on a few filters and
        // extrapolated to the full layer (linear in N_out).
        let dense = dense_sylvester(l);
        let bench_filters = if l >= 4096 { 2usize } else { 8 };
        let dense_r = bench_auto(
            &format!("ovsf: {bench_filters}-filter roundtrip {label} (dense baseline)"),
            400,
            || {
                let mut acc = 0.0f32;
                for o in 0..bench_filters {
                    let target = &weights[o * n_in * k * k..(o + 1) * n_in * k * k];
                    // Zero-pad the 3×3 filter into the K'×K' frame.
                    let mut frame = vec![0.0f32; l];
                    for c in 0..n_in {
                        for kh in 0..k {
                            for kw in 0..k {
                                frame[(c * k_ovsf + kh) * k_ovsf + kw] =
                                    target[(c * k + kh) * k + kw];
                            }
                        }
                    }
                    acc += dense_filter_roundtrip(&dense, l, &frame, rho);
                }
                acc
            },
        );
        let before_ns = dense_r.mean_ns * n_out as f64 / bench_filters as f64;
        rows.push(OvsfRow {
            name: "from_weights+reconstruct".into(),
            shape: label.into(),
            l,
            rho,
            before_ns_per_layer: Some(before_ns),
            after_ns_per_layer: fwht.mean_ns,
            layers_per_s: 1e9 / fwht.mean_ns,
        });

        // TiWGen numeric generation at the same shape (chunk-basis form).
        let hw = HwOvsfWeights::random(&mut rng, n_out, n_in, k, rho).unwrap();
        let sigma = DesignPoint::new(64, 64, 16, 64);
        let wg = bench_auto(
            &format!("sim: TiWGen generate {label} (ρ=.5)"),
            500,
            || WGenSim::new(&sigma, &hw).generate().vector_macs,
        );
        rows.push(OvsfRow {
            name: "wgen_generate".into(),
            shape: label.into(),
            l,
            rho,
            before_ns_per_layer: None, // no dense counterpart for the walk
            after_ns_per_layer: wg.mean_ns,
            layers_per_s: 1e9 / wg.mean_ns,
        });
    }
    rows
}

struct InferRow {
    network: String,
    precision: Precision,
    input_len: usize,
    slab_budget_bytes: usize,
    peak_resident_weight_bytes: usize,
    /// Full dense materialisation of the OVSF GEMM weights at this row's
    /// precision word width (f32: 4 B/word, i8: 1 B/word).
    dense_ovsf_weight_bytes: u64,
    /// Warm-pass slab-cache telemetry from the pipelined datapath: the i8
    /// rows hold strictly more slabs per byte, so at a fixed budget their
    /// hit rate dominates the f32 rows'.
    cache_hits: u64,
    cache_misses: u64,
    hit_rate: f64,
    /// Serial (generate-then-multiply) datapath — the committed-baseline
    /// comparator, measured in the same run so the comparison is
    /// hardware-normalised.
    serial_ns_per_infer: f64,
    serial_inf_per_s: f64,
    /// Pipelined prefetch datapath (the default).
    ns_per_infer: f64,
    inf_per_s: f64,
    /// Pipelined datapath behind a zero-probability fault-injection
    /// wrapper — the before/after row for the fault-tolerance layer's
    /// fault-free overhead (target: within 3% of `inf_per_s`).
    guarded_ns_per_infer: f64,
    guarded_inf_per_s: f64,
    speedup: f64,
    /// Overlap telemetry from a cold (empty-cache) pipelined pass.
    gen_ns: u64,
    hidden_ns: u64,
    hidden_frac: f64,
}

fn write_infer_json(rows: &[InferRow], kernel_speedup: f64, kernel_i8_speedup: f64) {
    let path =
        std::env::var("BENCH_INFER_JSON").unwrap_or_else(|_| "BENCH_infer.json".to_string());
    let mut out = String::from("{\n  \"bench\": \"engine-infer-tile-streamed\",\n");
    out.push_str(&format!(
        "  \"smoke\": {},\n  \"kernel_speedup\": {:.3},\n  \
         \"kernel_i8_speedup\": {:.3},\n  \"entries\": [\n",
        smoke_mode(),
        kernel_speedup,
        kernel_i8_speedup
    ));
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"network\": \"{}\", \"precision\": \"{}\", \"input_len\": {}, \
             \"slab_budget_bytes\": {}, \
             \"peak_resident_weight_bytes\": {}, \"dense_ovsf_weight_bytes\": {}, \
             \"cache_hits\": {}, \"cache_misses\": {}, \"hit_rate\": {:.4}, \
             \"serial_ns_per_infer\": {:.1}, \"serial_inf_per_s\": {:.4}, \
             \"ns_per_infer\": {:.1}, \"inf_per_s\": {:.4}, \
             \"guarded_ns_per_infer\": {:.1}, \"guarded_inf_per_s\": {:.4}, \
             \"speedup\": {:.3}, \
             \"gen_ns\": {}, \"hidden_ns\": {}, \"hidden_frac\": {:.3}}}{}\n",
            json_escape(&r.network),
            r.precision.label(),
            r.input_len,
            r.slab_budget_bytes,
            r.peak_resident_weight_bytes,
            r.dense_ovsf_weight_bytes,
            r.cache_hits,
            r.cache_misses,
            r.hit_rate,
            r.serial_ns_per_infer,
            r.serial_inf_per_s,
            r.ns_per_infer,
            r.inf_per_s,
            r.guarded_ns_per_infer,
            r.guarded_inf_per_s,
            r.speedup,
            r.gen_ns,
            r.hidden_ns,
            r.hidden_frac,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write(&path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// Refresh the committed baseline (`BENCH_baseline.json`) from this run:
/// `BENCH_WRITE_BASELINE=1 cargo bench --bench hotpath`. Serial `ns`/`inf
/// per s` record the comparator; `speedup` records the **measured**
/// pipelined/serial speedup — that normalised figure is what the CI gate
/// defends (within 20%), so a refresh on real hardware ratchets the gate
/// up to the achieved overlap win. (The bootstrap baseline committed with
/// the pipelining PR carries speedup 1.0 — the conservative
/// "overlap must never lose to serial" floor — until a toolchain run
/// refreshes it.)
fn maybe_write_baseline(rows: &[InferRow]) {
    if std::env::var("BENCH_WRITE_BASELINE").is_err() {
        return;
    }
    let path = std::env::var("BENCH_BASELINE_JSON")
        .unwrap_or_else(|_| "BENCH_baseline.json".to_string());
    let mut out = String::from("{\n  \"bench\": \"engine-infer-serial-baseline\",\n");
    out.push_str(
        "  \"note\": \"Engine::infer reference: serial comparator numbers plus the \
         measured pipelined/serial speedup the CI gate defends. Refresh with \
         BENCH_WRITE_BASELINE=1 cargo bench --bench hotpath; absolute ns depend \
         on the host and are informational.\",\n",
    );
    out.push_str(&format!("  \"smoke\": {},\n  \"entries\": [\n", smoke_mode()));
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"network\": \"{}\", \"ns_per_infer\": {:.1}, \
             \"inf_per_s\": {:.4}, \"speedup\": {:.3}}}{}\n",
            json_escape(&r.network),
            r.serial_ns_per_infer,
            r.serial_inf_per_s,
            r.speedup,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write(&path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// The pre-rewrite scalar axpy strip kernel (bench-local copy, like the
/// dense Sylvester baseline above — production code now runs the
/// register-blocked microkernel): before/after numbers for the GEMM inner
/// loop at a ResNet-18 strip×slab shape.
#[allow(clippy::too_many_arguments)]
fn scalar_strip_kernel(
    act: &[f32],
    slab: &[f32],
    rows: usize,
    p: usize,
    cols: usize,
    out: &mut [f32],
    t_p: usize,
) {
    for p0 in (0..p).step_by(t_p) {
        let p1 = (p0 + t_p).min(p);
        for ri in 0..rows {
            let arow = &act[ri * p..(ri + 1) * p];
            let orow = &mut out[ri * cols..(ri + 1) * cols];
            for pi in p0..p1 {
                let a = arow[pi];
                let wrow = &slab[pi * cols..(pi + 1) * cols];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += a * wv;
                }
            }
        }
    }
}

/// Microkernel before/after at the ResNet-18 stage-2 tile shape
/// (`T_R×P×T_C = 64×1152×48`): scalar axpy loop vs the register-blocked
/// `PeArraySim::execute_strip`, plus the i8×i8→i32 strip on a quantised
/// twin of the same slab. Returns `(f32_speedup_vs_scalar,
/// i8_speedup_vs_f32_blocked)`.
fn bench_microkernel() -> (f64, f64) {
    println!("-- PE strip GEMM microkernel (64×1152×48 tile) --");
    let (rows, p, cols) = (64usize, 1152usize, 48usize);
    let mut rng = Xoshiro256::seed_from_u64(0x5eed);
    let act = rng.normal_vec(rows * p);
    let slab = rng.normal_vec(p * cols);
    let sigma = DesignPoint::new(64, rows as u64, 16, cols as u64);
    let pe = unzipfpga::sim::pe_array::PeArraySim::new(&sigma, true);
    let mut out = vec![0.0f32; rows * cols];
    let before = bench_auto("pe: scalar axpy strip (baseline)", 400, || {
        out.iter_mut().for_each(|v| *v = 0.0);
        scalar_strip_kernel(&act, &slab, rows, p, cols, &mut out, 16);
        out[0]
    });
    let mut out2 = vec![0.0f32; rows * cols];
    let after = bench_auto("pe: register-blocked strip (microkernel)", 400, || {
        out2.iter_mut().for_each(|v| *v = 0.0);
        pe.execute_strip(&act, &slab, rows, p, cols, &mut out2, cols, 0);
        out2[0]
    });
    assert_eq!(out, out2, "microkernel must be bit-identical to the scalar loop");
    let speedup = before.mean_ns / after.mean_ns;
    println!("   microkernel speedup: {speedup:.2}×");

    // i8 twin: quantise the slab once (as slab generation does), then run
    // the widened i8×i8→i32 strip on the same activations.
    let max_w = slab.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let scheme = I8Scheme::from_max_abs(max_w);
    let codes: Vec<i8> = slab.iter().map(|&v| scheme.quantise(v)).collect();
    let mut out3 = vec![0.0f32; rows * cols];
    let after_i8 = bench_auto("pe: i8 strip (i8×i8→i32 microkernel)", 400, || {
        out3.iter_mut().for_each(|v| *v = 0.0);
        pe.execute_strip_i8(&act, &codes, scheme.scale, rows, p, cols, &mut out3, cols, 0);
        out3[0]
    });
    let max_a = act.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let bound = i8_error_bound(p, max_w, max_a, scheme.scale);
    let max_err = out2
        .iter()
        .zip(&out3)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_err <= bound,
        "i8 strip error {max_err} exceeds the analytic bound {bound}"
    );
    let i8_speedup = after.mean_ns / after_i8.mean_ns;
    println!("   i8 kernel speedup over f32 blocked: {i8_speedup:.2}×");
    (speedup, i8_speedup)
}

/// Two-model interleaved-traffic serving bench: ResNet-18 + SqueezeNet
/// compiled onto one σ, registered in one `ModelRegistry` under a shared
/// 8 MiB slab budget, served through one registry-routed `ServerPool` with
/// strictly alternating numeric requests — the adversarial multi-model
/// pattern (every batch boundary is a model switch). Emits
/// `BENCH_multimodel.json` (override: `BENCH_MULTIMODEL_JSON`).
fn bench_multimodel() {
    use unzipfpga::coordinator::pool::{PoolConfig, ServerPool};
    use unzipfpga::coordinator::registry::ModelRegistry;
    use unzipfpga::coordinator::server::Request;
    use unzipfpga::engine::{BackendKind, Compiler};
    use unzipfpga::workload::squeezenet;

    println!("-- multi-model serving (ResNet18 + SqueezeNet, interleaved) --");
    let budget = 8usize << 20;
    let nets = [resnet::resnet18(), squeezenet::squeezenet1_1()];
    let compiler = Compiler::new()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(DesignPoint::new(64, 64, 16, 48));
    let registry = Arc::new(ModelRegistry::with_budget(budget));
    let mut inputs = Vec::new();
    let mut rng = Xoshiro256::seed_from_u64(0x2d0d);
    for net in &nets {
        let profile = RatioProfile::ovsf50(net);
        let artifact = compiler.compile(net.clone(), profile).unwrap();
        let compiled = registry.register(net.name.clone(), artifact).unwrap();
        inputs.push(rng.normal_vec(compiled.input_len()));
    }
    let per_model = if smoke_mode() { 2u64 } else { 6 };
    let pool = ServerPool::serve(
        Arc::clone(&registry),
        BackendKind::Simulator,
        PoolConfig {
            workers: 2,
            queue_depth: 64,
            max_batch: 4,
            linger: std::time::Duration::from_micros(200),
            slo: None,
            ..PoolConfig::default()
        },
    )
    .unwrap();
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    let mut id = 0u64;
    for _ in 0..per_model {
        for (net, input) in nets.iter().zip(&inputs) {
            handles.push(
                pool.submit(Request::for_model(id, net.name.clone(), input.clone()))
                    .unwrap(),
            );
            id += 1;
        }
    }
    for h in handles {
        let resp = h.wait().unwrap();
        assert!(!resp.output.is_empty(), "numeric responses carry data");
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let pm = pool.shutdown().unwrap();
    let cache = registry.cache();
    let total = pm.total_requests();
    assert!(
        cache.peak_resident_bytes() <= budget,
        "peak resident {} exceeds the shared budget {budget}",
        cache.peak_resident_bytes()
    );
    println!(
        "   {total} interleaved requests over 2 models in {wall_s:.2}s \
         ({:.2} req/s); {} model switches, cache {} hits / {} misses / {} \
         evictions, peak resident {:.2} MiB / {:.0} MiB budget",
        total as f64 / wall_s,
        pm.model_switches(),
        cache.hits(),
        cache.misses(),
        cache.evictions(),
        cache.peak_resident_bytes() as f64 / (1 << 20) as f64,
        budget as f64 / (1 << 20) as f64
    );
    let merged = pm.merged();
    let path = std::env::var("BENCH_MULTIMODEL_JSON")
        .unwrap_or_else(|_| "BENCH_multimodel.json".to_string());
    let mut out = String::from("{\n  \"bench\": \"multi-model-interleaved-serving\",\n");
    out.push_str(&format!(
        "  \"smoke\": {},\n  \"requests\": {},\n  \"wall_s\": {:.3},\n  \
         \"req_per_s\": {:.3},\n  \"model_switches\": {},\n  \
         \"slab_budget_bytes\": {},\n  \"peak_resident_weight_bytes\": {},\n  \
         \"cache_hits\": {},\n  \"cache_misses\": {},\n  \"cache_evictions\": {},\n  \
         \"models\": [\n",
        smoke_mode(),
        total,
        wall_s,
        total as f64 / wall_s,
        pm.model_switches(),
        budget,
        cache.peak_resident_bytes(),
        cache.hits(),
        cache.misses(),
        cache.evictions()
    ));
    for (i, net) in nets.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"model\": \"{}\", \"requests\": {}, \"p50_us\": {:.1}, \
             \"p99_us\": {:.1}}}{}\n",
            json_escape(&net.name),
            merged.model_count(&net.name),
            merged.model_percentile_us(&net.name, 50.0),
            merged.model_percentile_us(&net.name, 99.0),
            if i + 1 < nets.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write(&path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn build_infer_engine(
    net: &Network,
    pipelined: bool,
    cache: Arc<SlabCache>,
    precision: Precision,
) -> Engine {
    build_infer_engine_inner(net, pipelined, cache, false, precision)
}

/// Same datapath with the zero-probability [`FaultyBackend`] wrapper in
/// the backend seat — measures the fault-tolerance layer's fault-free
/// overhead (one PRNG roll guard per layer call; nothing injected).
fn build_guarded_engine(
    net: &Network,
    pipelined: bool,
    cache: Arc<SlabCache>,
    precision: Precision,
) -> Engine {
    build_infer_engine_inner(net, pipelined, cache, true, precision)
}

fn build_infer_engine_inner(
    net: &Network,
    pipelined: bool,
    cache: Arc<SlabCache>,
    guarded: bool,
    precision: Precision,
) -> Engine {
    let profile = RatioProfile::ovsf50(net);
    let plan = Engine::builder()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(DesignPoint::new(64, 64, 16, 48))
        .network(net.clone())
        .profile(profile)
        .plan()
        .unwrap();
    let mut backend = SimBackend::with_cache(cache);
    backend.pipelined = pipelined;
    backend.precision = precision;
    if guarded {
        let wrapped = FaultyBackend::new(backend, FaultPlan::none());
        Engine::with_backend(plan, Box::new(wrapped)).unwrap()
    } else {
        Engine::with_backend(plan, Box::new(backend)).unwrap()
    }
}

/// End-to-end numeric `Engine::infer` on the simulator backend: real
/// activations through the PE array with per-tile on-the-fly weights
/// generation under a bounded slab budget. Measures the serial
/// generate-then-multiply schedule against the pipelined slab-prefetch
/// datapath (both warm), captures the cold pass's overlap telemetry, and
/// reports the memory-footprint comparison (full dense materialisation vs
/// measured peak resident slab bytes).
fn bench_engine_infer() -> Vec<InferRow> {
    println!("-- end-to-end Engine::infer (serial vs pipelined, f32 vs i8) --");
    let budget = 8usize << 20; // 8 MiB — a fraction of any ImageNet model
    let mut rows = Vec::new();
    for net in [resnet::resnet18(), resnet::resnet50()] {
        for precision in [Precision::F32, Precision::I8] {
            // The dense comparator at this row's word width: what full
            // materialisation of the OVSF GEMM weights would occupy.
            let dense_ovsf_weight_bytes: u64 = net
                .layers
                .iter()
                .filter(|l| l.ovsf)
                .map(|l| {
                    let g = l.gemm();
                    g.p * g.c * precision.word_bytes() as u64
                })
                .sum();
            let label = match precision {
                Precision::F32 => net.name.clone(),
                Precision::I8 => format!("{}-i8", net.name),
            };
            let l0 = &net.layers[0];
            let input_len = (l0.h * l0.w * l0.n_in) as usize;
            let mut rng = Xoshiro256::seed_from_u64(0x1f3);
            let input = rng.normal_vec(input_len);
            // A full ImageNet inference is a lot of GEMM: size the
            // iteration count directly instead of auto-calibrating (the
            // probe iteration alone would blow the smoke budget).
            let iters = if smoke_mode() { 1 } else { 3 };

            // Serial schedule — the pre-pipeline datapath and the
            // committed baseline's comparator. One warm-up pass fills the
            // slab cache so both schedules are measured steady-state.
            let cache_s = Arc::new(SlabCache::with_budget(budget));
            let mut serial =
                build_infer_engine(&net, false, Arc::clone(&cache_s), precision);
            serial.infer(&input).unwrap();
            let rs = bench(
                &format!("engine: {label} numeric infer (serial)"),
                0,
                iters,
                || serial.infer(&input).unwrap().output[0],
            );

            // Pipelined prefetch datapath. The cold first pass supplies
            // the overlap telemetry (warm passes hit the cache and
            // generate ~0). The warm-pass hit/miss counters are this
            // row's fixed-budget hit-rate figure.
            let cache_p = Arc::new(SlabCache::with_budget(budget));
            let mut piped =
                build_infer_engine(&net, true, Arc::clone(&cache_p), precision);
            let cold = piped.infer(&input).unwrap();
            let overlap = cold.report.overlap();
            let (cold_hits, cold_misses) = (cache_p.hits(), cache_p.misses());
            let rp = bench(
                &format!("engine: {label} numeric infer (pipelined)"),
                0,
                iters,
                || piped.infer(&input).unwrap().output[0],
            );
            let cache_hits = cache_p.hits() - cold_hits;
            let cache_misses = cache_p.misses() - cold_misses;
            let lookups = cache_hits + cache_misses;
            let hit_rate = if lookups > 0 {
                cache_hits as f64 / lookups as f64
            } else {
                0.0
            };
            let peak = cache_p.peak_resident_bytes();
            assert!(
                peak <= budget,
                "{label}: peak resident weights {peak} exceed the {budget}-byte budget"
            );

            // Guarded pass: the identical pipelined datapath behind a
            // zero-probability FaultyBackend — the fault-tolerance
            // layer's fault-free overhead, measured in the same run.
            let cache_g = Arc::new(SlabCache::with_budget(budget));
            let mut guarded =
                build_guarded_engine(&net, true, Arc::clone(&cache_g), precision);
            guarded.infer(&input).unwrap();
            let rg = bench(
                &format!("engine: {label} numeric infer (guarded)"),
                0,
                iters,
                || guarded.infer(&input).unwrap().output[0],
            );

            let speedup = rs.mean_ns / rp.mean_ns;
            println!(
                "   {label}: serial {:.2} inf/s → pipelined {:.2} inf/s \
                 ({speedup:.2}×); guarded {:.2} inf/s ({:+.1}% fault-guard \
                 overhead); cold pass hid {:.0}% of generation; warm hit rate \
                 {:.1}%; dense OVSF weights {:.1} MiB vs peak resident \
                 {:.2} MiB (budget 8 MiB)",
                1e9 / rs.mean_ns,
                1e9 / rp.mean_ns,
                1e9 / rg.mean_ns,
                (rg.mean_ns / rp.mean_ns - 1.0) * 100.0,
                overlap.hidden_frac() * 100.0,
                hit_rate * 100.0,
                dense_ovsf_weight_bytes as f64 / (1 << 20) as f64,
                peak as f64 / (1 << 20) as f64
            );
            rows.push(InferRow {
                network: label,
                precision,
                input_len,
                slab_budget_bytes: budget,
                peak_resident_weight_bytes: peak,
                dense_ovsf_weight_bytes,
                cache_hits,
                cache_misses,
                hit_rate,
                serial_ns_per_infer: rs.mean_ns,
                serial_inf_per_s: 1e9 / rs.mean_ns,
                ns_per_infer: rp.mean_ns,
                inf_per_s: 1e9 / rp.mean_ns,
                guarded_ns_per_infer: rg.mean_ns,
                guarded_inf_per_s: 1e9 / rg.mean_ns,
                speedup,
                gen_ns: overlap.gen_ns,
                hidden_ns: overlap.hidden_ns,
                hidden_frac: overlap.hidden_frac(),
            });
        }
    }
    rows
}

fn main() {
    println!("== L3 hot-path microbenches ==");
    let net = resnet::resnet18();
    let profile = RatioProfile::ovsf50(&net);
    let plat = Platform::z7045();
    let sigma = DesignPoint::new(64, 64, 16, 48);
    let model = PerfModel::new(plat.clone(), 4);

    bench_auto("perf_model: ResNet18 network_perf", 600, || {
        model.network_perf(&sigma, &net, &profile).total_cycles
    });

    let cfg = DseConfig::default();
    bench_auto("dse: full sweep (1200 pts, ResNet18)", 1500, || {
        sweep(&cfg, &plat, 4, &net, &profile, true).len()
    });

    bench_auto("dse: optimise (argmax incl. sweep)", 1500, || {
        optimise(&cfg, &plat, 4, &net, &profile, true)
            .unwrap()
            .perf
            .inf_per_s
    });

    bench_auto("sim: ResNet18 timing walk", 800, || {
        simulate_network_timing(&sigma, &plat, 4, true, &net, &profile).len()
    });

    let basis = OvsfBasis::new(16).unwrap();
    bench_auto("sim: OVSF FIFO/aligner 10k emits (M=48)", 400, || {
        let mut g = OvsfGenerator::new(&basis, 8, 48);
        let mut buf = Vec::with_capacity(48);
        let mut acc = 0i32;
        for _ in 0..10_000 {
            g.emit_into(&mut buf);
            acc += buf[0] as i32;
        }
        acc
    });

    let basis256 = OvsfBasis::new(256).unwrap();
    let mut rng2 = Xoshiro256::seed_from_u64(2);
    let target = rng2.normal_vec(256);
    bench_auto("ovsf: project+reconstruct L=256 (FWHT)", 400, || {
        let alphas = unzipfpga::ovsf::regress::project(&basis256, &target);
        let sel = select(BasisSelection::IterativeDrop, &basis256, &alphas, 0.5);
        unzipfpga::ovsf::regress::reconstruct_vec(&basis256, &sel)[0]
    });

    let rows = bench_ovsf_weights_generation();
    write_bench_json(&rows);

    let (kernel_speedup, kernel_i8_speedup) = bench_microkernel();
    let infer_rows = bench_engine_infer();
    write_infer_json(&infer_rows, kernel_speedup, kernel_i8_speedup);
    maybe_write_baseline(&infer_rows);

    bench_multimodel();

    bench_auto("autotune: ResNet18 @ 2x end-to-end", 2000, || {
        autotune(&cfg, &plat, 2, &net).unwrap().final_inf_per_s
    });
}
