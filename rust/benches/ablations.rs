//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * OVSF basis **storage designs** (§4.2.2's three options);
//! * **dataflow** (output- vs weight-stationary wgen pressure, §4.2.1);
//! * **DSE strategy** (exhaustive vs greedy hill-climbing);
//! * **selective-PE** average gain across the benchmark suite (Table 10's
//!   mechanism as a single number);
//! * **multi-tenant** bandwidth contention (the paper's conclusion).

use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::coordinator::multi_tenant::{co_location_sweep, CoLocationConfig};
use unzipfpga::dse::greedy::greedy_optimise;
use unzipfpga::dse::search::{optimise, DseConfig};
use unzipfpga::perf::dataflow::{max_affordable_rho, Dataflow};
use unzipfpga::perf::model::PerfModel;
use unzipfpga::sim::ovsf_storage;
use unzipfpga::util::bench::bench_auto;
use unzipfpga::workload::{resnet, Network, RatioProfile};

fn main() {
    println!("== ablation 1: OVSF basis storage designs (§4.2.2) ==");
    for (m, t_p, t_c, k2, nb) in [(64u64, 16u64, 48u64, 16u64, 8u64), (128, 8, 96, 16, 16)] {
        let (mono, mux, fifo) = ovsf_storage::compare(m, t_p, t_c, k2, nb, 2);
        println!(
            "  M={m:>3} T_P={t_p:>2} T_C={t_c:>3}: monolithic {:>8} bits | mux {:>5} bits + {:>5} LUTs | FIFO+aligner {:>5} bits + {:>3} LUTs",
            mono.storage_bits, mux.storage_bits, mux.selection_luts,
            fifo.storage_bits, fifo.selection_luts
        );
    }

    println!("\n== ablation 2: dataflow (wgen pressure OS vs WS, §4.2.1) ==");
    let model = PerfModel::new(Platform::z7045(), 4);
    let sigma = DesignPoint::new(8, 64, 16, 96); // deliberately small wgen
    let net = resnet::resnet18();
    let mut os_sum = 0.0;
    let mut ws_sum = 0.0;
    let mut n = 0;
    for layer in net.layers.iter().filter(|l| l.ovsf) {
        os_sum += max_affordable_rho(&model, Dataflow::OutputStationary, &sigma, layer);
        ws_sum += max_affordable_rho(&model, Dataflow::WeightStationary, &sigma, layer);
        n += 1;
    }
    println!(
        "  mean max-affordable ρ at M=8: output-stationary {:.3}, weight-stationary {:.3}",
        os_sum / n as f64,
        ws_sum / n as f64
    );

    println!("\n== ablation 3: DSE strategy (exhaustive vs greedy) ==");
    let cfg = DseConfig::default();
    let profile = RatioProfile::ovsf50(&net);
    let plat = Platform::z7045();
    let ex = bench_auto("dse: exhaustive (1200 pts)", 1200, || {
        optimise(&cfg, &plat, 4, &net, &profile, true)
            .unwrap()
            .perf
            .inf_per_s
    });
    let gr = bench_auto("dse: greedy hill-climb", 1200, || {
        greedy_optimise(&cfg, &plat, 4, &net, &profile)
            .unwrap()
            .inf_per_s
    });
    let ex_r = optimise(&cfg, &plat, 4, &net, &profile, true).unwrap();
    let gr_r = greedy_optimise(&cfg, &plat, 4, &net, &profile).unwrap();
    println!(
        "  quality: greedy {:.2} / exhaustive {:.2} inf/s = {:.1}% at {}/{} evaluations ({:.1}x faster wall-clock)",
        gr_r.inf_per_s,
        ex_r.perf.inf_per_s,
        100.0 * gr_r.inf_per_s / ex_r.perf.inf_per_s,
        gr_r.evaluations,
        ex_r.explored,
        ex.mean_ns / gr.mean_ns
    );

    println!("\n== ablation 4: selective PEs across the suite ==");
    let mut gains = Vec::new();
    for net in Network::benchmarks() {
        let plat = Platform::z7045();
        let profile = RatioProfile::ovsf50(&net);
        if let Ok(with) = optimise(&cfg, &plat, 4, &net, &profile, true) {
            let mut m = PerfModel::new(plat.clone(), 4);
            m.selective_pes = false;
            let without = m.network_perf(&with.sigma, &net, &profile);
            gains.push(with.perf.inf_per_s / without.inf_per_s);
        }
    }
    println!(
        "  mean gain {:.3}x (geo {:.3}x) over {} benchmarks",
        unzipfpga::util::stats::mean(&gains),
        unzipfpga::util::stats::geo_mean(&gains),
        gains.len()
    );

    println!("\n== ablation 5: multi-tenant bandwidth contention ==");
    let cfg = CoLocationConfig {
        max_tenants: 4,
        timing_requests: 1,
        workers: 1,
        ..CoLocationConfig::default()
    };
    let reports =
        co_location_sweep(&Platform::zu7ev(), 12, &[resnet::resnet18()], &cfg).unwrap();
    for r in &reports {
        let m = &r.models[0];
        println!(
            "  {} tenant(s) @ {}x/tenant: baseline {:>6.1} vs unzipFPGA {:>6.1} inf/s  ({:.2}x)",
            r.tenants,
            r.bw_per_tenant,
            m.baseline_inf_s,
            m.unzip_inf_s,
            m.speedup()
        );
    }
}
