//! Pipeline-parallel serving benchmark: K-stage layer-range pipelines vs
//! the single-engine baseline.
//!
//! Splits ResNet18-small (CIFAR) into K ∈ {1, 2, 4} MACs-balanced stages
//! ([`Compiler::split_balanced`]) and drives each [`StagePipeline`]
//! closed-loop with numeric requests (each client keeps one request in
//! flight, so the achieved rate *is* the pipeline's sustainable
//! capacity). The baseline is the same model unsplit behind the same
//! dispatch machinery (a one-replica [`ReplicaSet`]). Reports per-stage
//! occupancy, bubble fraction, and activation-queue high-water marks —
//! the knobs that explain where a K-stage split's speedup goes.
//!
//! Emits `BENCH_pipeline.json` (override: `BENCH_PIPELINE_JSON`).
//! `BENCH_SMOKE=1` shrinks the request counts for CI; every run must
//! complete loss-free with the accounting identity intact, and the K=2
//! pipeline must sustain at least the single-engine throughput (asserted
//! here — that is what fails CI on a stage-handoff regression).

use std::time::Instant;

use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::coordinator::pool::PoolConfig;
use unzipfpga::coordinator::replica::{ReplicaConfig, ReplicaSet};
use unzipfpga::coordinator::stage::{PipelineConfig, PipelineMetrics, StagePipeline};
use unzipfpga::coordinator::traffic::{run_closed_loop, RequestClass, TrafficReport};
use unzipfpga::engine::Compiler;
use unzipfpga::util::bench::smoke_mode;
use unzipfpga::util::prng::Xoshiro256;
use unzipfpga::workload::resnet::resnet18_cifar_small;
use unzipfpga::workload::RatioProfile;

const SEED: u64 = 0x51a6;
const CLIENTS: usize = 6;

fn compiler() -> Compiler {
    Compiler::new()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(DesignPoint::new(8, 4, 8, 4))
}

fn accounted(r: &TrafficReport, what: &str) {
    assert_eq!(
        r.offered,
        r.submitted + r.shed + r.queue_full + r.expired + r.failed,
        "{what}: every request must be accounted: {}",
        r.summary()
    );
    assert_eq!(
        r.harness_failures, 0,
        "{what}: harness must survive: {}",
        r.summary()
    );
    assert_eq!(
        r.failed + r.shed + r.queue_full + r.expired,
        0,
        "{what}: closed-loop blocking admission must be loss-free: {}",
        r.summary()
    );
}

fn report_json(r: &TrafficReport) -> String {
    format!(
        "\"completed\": {}, \"achieved_rps\": {:.2}, \"p50_us\": {:.1}, \"p99_us\": {:.1}",
        r.completed,
        r.achieved_rps(),
        r.percentile_us(50.0),
        r.percentile_us(99.0),
    )
}

fn stages_json(m: &PipelineMetrics) -> String {
    let entries: Vec<String> = m
        .occupancy
        .iter()
        .enumerate()
        .map(|(k, occ)| {
            format!(
                "{{\"stage\": {k}, \"occupancy\": {:.3}, \"bubble\": {:.3}, \
                 \"queue_high_water\": {}}}",
                occ,
                m.bubble_fraction(k),
                m.queue_high_water[k]
            )
        })
        .collect();
    format!("[{}]", entries.join(", "))
}

fn main() {
    println!("== pipeline-parallel stages: K-stage throughput vs single engine ==");
    let smoke = smoke_mode();
    let per_client = if smoke { 4 } else { 16 };

    let net = resnet18_cifar_small();
    let profile = RatioProfile::uniform(&net, 0.5);
    let c = compiler();
    let input_len = {
        let l0 = &net.layers[0];
        (l0.h * l0.w * l0.n_in) as usize
    };
    let input = Xoshiro256::seed_from_u64(SEED).normal_vec(input_len);
    let classes = vec![RequestClass::timing(net.name.clone()).with_input(input)];

    // -- Baseline: the unsplit model behind the same dispatch machinery.
    let mut base_cfg = ReplicaConfig::new(1);
    base_cfg.pool = PoolConfig::single_worker();
    let baseline_set = ReplicaSet::start(base_cfg).unwrap();
    baseline_set
        .register_model(
            net.name.clone(),
            c.compile(net.clone(), profile.clone()).unwrap(),
        )
        .unwrap();
    let t0 = Instant::now();
    let baseline = run_closed_loop(&baseline_set, &classes, CLIENTS, per_client, SEED + 1);
    accounted(&baseline, "single-engine");
    println!(
        "   single-engine        {} ({:.2} rps)",
        baseline.summary(),
        baseline.achieved_rps()
    );
    baseline_set.shutdown().unwrap();
    let baseline_rps = baseline.achieved_rps();

    // -- K-stage pipelines.
    let mut pipeline_rows: Vec<String> = Vec::new();
    let mut k2_rps = 0.0f64;
    for k in [1usize, 2, 4] {
        let stages = c
            .split_balanced(net.clone(), profile.clone(), k)
            .unwrap_or_else(|e| panic!("K={k} split must be feasible: {e}"));
        let mut cfg = PipelineConfig::new();
        cfg.pool = PoolConfig::single_worker();
        cfg.queue_depth = 8;
        let pipe = StagePipeline::start(cfg, net.name.clone(), stages).unwrap();
        let report = run_closed_loop(&pipe, &classes, CLIENTS, per_client, SEED + 10 + k as u64);
        accounted(&report, &format!("K={k}"));
        let rps = report.achieved_rps();
        if k == 2 {
            k2_rps = rps;
        }
        let metrics = pipe.shutdown().unwrap();
        println!(
            "   K={k} pipeline        {} ({:.2} rps, {:.2}x) | {}",
            report.summary(),
            rps,
            rps / baseline_rps,
            metrics.summary()
        );
        pipeline_rows.push(format!(
            "    \"k{k}\": {{{}, \"speedup_vs_single\": {:.3}, \"stages\": {}}}",
            report_json(&report),
            rps / baseline_rps,
            stages_json(&metrics)
        ));
    }

    // The headline acceptance: a two-stage split must not serve slower
    // than the single engine it replaces.
    assert!(
        k2_rps >= baseline_rps,
        "K=2 steady-state throughput ({k2_rps:.2} rps) fell below the \
         single-engine baseline ({baseline_rps:.2} rps)"
    );
    println!(
        "   total wall {:.2} s, K=2 speedup {:.2}x",
        t0.elapsed().as_secs_f64(),
        k2_rps / baseline_rps
    );

    // -- JSON artifact.
    let path = std::env::var("BENCH_PIPELINE_JSON")
        .unwrap_or_else(|_| "BENCH_pipeline.json".to_string());
    let mut out = String::from("{\n  \"bench\": \"pipeline-stages\",\n");
    out.push_str(&format!(
        "  \"smoke\": {smoke},\n  \"seed\": {SEED},\n  \"model\": \"{}\",\n  \
         \"clients\": {CLIENTS},\n  \"requests_per_client\": {per_client},\n",
        net.name
    ));
    out.push_str(&format!(
        "  \"single_engine\": {{{}}},\n  \"pipelines\": {{\n",
        report_json(&baseline)
    ));
    out.push_str(&pipeline_rows.join(",\n"));
    out.push_str("\n  }\n}\n");
    std::fs::write(&path, &out).expect("write BENCH_pipeline.json");
    println!("   wrote {path}");
}
