//! Pipeline-parallel serving acceptance: a model split into K layer-range
//! stages behind bounded activation queues must
//!
//! * stay **bit-identical** to the single-engine reference across
//!   ρ ∈ {0.25, 1.0} and both PE schedules (selective and dense),
//! * serve a deep model with **no stage exceeding its per-stage slab
//!   budget** — budgets deliberately too small to ever hold the full
//!   model's weights on one cache,
//! * keep **disjoint weight-key/seed namespaces** across stages,
//! * **backpressure, not deadlock**: a full downstream queue stalls
//!   upstream hops and ultimately admission, while every accepted request
//!   still settles,
//! * settle every request **typed-or-correct through a mid-stream stage
//!   kill**, with the stage's supervisor restoring capacity.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::coordinator::pool::PoolConfig;
use unzipfpga::coordinator::registry::BackendWrap;
use unzipfpga::coordinator::stage::{PipelineConfig, StagePipeline};
use unzipfpga::coordinator::server::Request;
use unzipfpga::coordinator::traffic::SettleHandle;
use unzipfpga::engine::{
    CompiledModel, Compiler, Engine, EnginePlan, ExecutionBackend, ExecutionReport, LayerOutcome,
    SimBackend,
};
use unzipfpga::error::{Error, Result};
use unzipfpga::util::prng::Xoshiro256;
use unzipfpga::workload::resnet::resnet18_cifar_small;
use unzipfpga::workload::tiny::{small_resnet, tiny_resnet};
use unzipfpga::workload::{Network, RatioProfile};

fn compiler() -> Compiler {
    Compiler::new()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(DesignPoint::new(8, 4, 8, 4))
}

fn input_for(net: &Network, seed: u64) -> Vec<f32> {
    let l0 = &net.layers[0];
    let n = (l0.h * l0.w * l0.n_in) as usize;
    Xoshiro256::seed_from_u64(seed).normal_vec(n)
}

/// Single-engine reference output under an explicit PE schedule.
fn reference(net: &Network, profile: &RatioProfile, input: &[f32], selective: bool) -> Vec<f32> {
    let plan = Engine::builder()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(DesignPoint::new(8, 4, 8, 4))
        .network(net.clone())
        .profile(profile.clone())
        .plan()
        .unwrap();
    let mut backend = SimBackend::new();
    backend.selective = selective;
    let mut engine = Engine::with_backend(plan, Box::new(backend)).unwrap();
    engine.infer(input).unwrap().output
}

fn quick_cfg() -> PipelineConfig {
    let mut cfg = PipelineConfig::new();
    cfg.pool = PoolConfig::single_worker();
    cfg.queue_depth = 4;
    cfg.health.supervisor_tick = Duration::from_millis(2);
    cfg
}

/// Acceptance grid: split serving is bit-identical to the single engine
/// across ρ ∈ {0.25, 1.0} × both PE schedules. The reference pair also
/// pins schedule-invariance: selective and dense PEs must agree, so one
/// pipeline response is checked against both.
#[test]
fn pipeline_matches_single_engine_across_rho_and_schedules() {
    let net = small_resnet();
    let input = input_for(&net, 31);
    for rho in [0.25, 1.0] {
        let profile = RatioProfile::uniform(&net, rho);
        let stages = compiler()
            .split_balanced(net.clone(), profile.clone(), 2)
            .unwrap();
        let pipe = StagePipeline::start(quick_cfg(), "small", stages).unwrap();
        let got = pipe
            .submit(Request::for_model(1, "small", input.clone()))
            .unwrap()
            .wait()
            .unwrap();
        for selective in [true, false] {
            let want = reference(&net, &profile, &input, selective);
            assert_eq!(
                got.output, want,
                "ρ={rho} selective={selective}: pipeline diverged from reference"
            );
        }
        pipe.shutdown().unwrap();
    }
}

/// Deep model under deliberately tight per-stage budgets: each stage's
/// budget is far below the full model's generated-weight bytes, so the
/// split is the only way this model serves — and no stage's cache may
/// ever exceed its own budget. Stage namespaces (runtime weight keys and
/// synthesis seeds) must be pairwise disjoint, and the split must stay
/// bit-identical to the unsplit reference.
#[test]
fn deep_model_splits_under_per_stage_budgets_with_disjoint_namespaces() {
    let net = resnet18_cifar_small();
    let profile = RatioProfile::uniform(&net, 0.5);
    let full_weight_bytes: u64 = net
        .layers
        .iter()
        .map(|l| {
            let g = l.gemm();
            g.p * g.c * 4
        })
        .sum();
    // A third of the dense footprint per stage: three stages never hold
    // the model co-resident, and a single-cache engine at this budget
    // would thrash.
    let budget = (full_weight_bytes / 3) as usize;
    assert!(
        (budget as u64) < full_weight_bytes,
        "budget must not admit the whole model"
    );

    let k = 3;
    let stages = compiler()
        .split_balanced(net.clone(), profile.clone(), k)
        .unwrap();

    // Namespace disjointness: every (runtime weight key, synthesis seed)
    // is unique across all stages.
    let mut keys = std::collections::BTreeSet::new();
    let mut seeds = std::collections::BTreeSet::new();
    for stage in &stages {
        for key in stage.weights_keys() {
            assert!(keys.insert(format!("{key:?}")), "duplicate key {key:?}");
        }
        for &seed in stage.weight_seeds() {
            assert!(seeds.insert(seed), "duplicate layer seed {seed:#x}");
        }
    }

    let mut cfg = quick_cfg();
    cfg.slab_budgets = Some(vec![budget; k]);
    let pipe = StagePipeline::start(cfg, "r18s", stages).unwrap();

    let input = input_for(&net, 47);
    let got = pipe
        .submit(Request::for_model(1, "r18s", input.clone()))
        .unwrap()
        .wait()
        .unwrap();
    let want = reference(&net, &profile, &input, true);
    assert_eq!(got.output, want, "split ResNet18-small diverged");

    for stage in 0..k {
        let reg = pipe
            .stage_registry(stage, 0)
            .unwrap_or_else(|| panic!("stage {stage} registry missing"));
        let peak = reg.cache().peak_resident_bytes();
        assert!(peak > 0, "stage {stage} never generated weights");
        assert!(
            peak <= budget,
            "stage {stage} peak resident {peak} B exceeds its budget {budget} B"
        );
    }
    pipe.shutdown().unwrap();
}

/// Malformed splits fail typed at the compiler, and stage artifacts that
/// do not chain fail typed at pipeline start.
#[test]
fn invalid_splits_and_topologies_are_typed() {
    let net = small_resnet();
    let profile = RatioProfile::uniform(&net, 0.5);
    let c = compiler();
    for ranges in [
        vec![],                 // no ranges
        vec![0..3],             // gap at the tail
        vec![0..2, 3..5],       // hole
        vec![0..3, 2..5],       // overlap
        vec![0..2, 2..4, 3..5], // regression after the second cut
        vec![0..5, 5..6],       // out of bounds
    ] {
        match c.split(net.clone(), profile.clone(), &ranges) {
            Err(Error::InvalidConfig(msg)) => {
                assert!(!msg.is_empty(), "ranges {ranges:?}: empty diagnostic")
            }
            Err(e) => panic!("ranges {ranges:?} failed with the wrong type: {e}"),
            Ok(_) => panic!("ranges {ranges:?} must be rejected"),
        }
    }
    // small_resnet's strided block1.conv2 → block2.conv1 boundary chains,
    // but cutting inside a shape-incompatible pair is refused: tiny_resnet
    // has no valid cut at 3 (strided conv feeds the flattening fc).
    let tiny = tiny_resnet();
    let tiny_profile = RatioProfile::uniform(&tiny, 0.5);
    assert!(matches!(
        c.split(tiny.clone(), tiny_profile.clone(), &[0..3, 3..4]),
        Err(Error::InvalidConfig(_))
    ));
    // Reordered (hence unchained) artifacts are refused at start.
    let mut stages = c.split(tiny, tiny_profile, &[0..2, 2..4]).unwrap();
    stages.swap(0, 1);
    assert!(matches!(
        StagePipeline::start(quick_cfg(), "tiny", stages),
        Err(Error::InvalidConfig(_))
    ));
}

/// Backpressure, not deadlock: tiny activation queues and single-slot
/// pool queues, a burst bigger than total pipeline capacity, submitted
/// with blocking admission from one thread while another occasionally
/// probes `try_submit` (which must observe typed `QueueFull` raw, the
/// admission-level backpressure signal). Every accepted request settles
/// bit-identically; nothing hangs.
#[test]
fn full_downstream_queues_backpressure_admission_without_deadlock() {
    let net = tiny_resnet();
    let profile = RatioProfile::uniform(&net, 0.5);
    let stages = compiler()
        .split(net.clone(), profile.clone(), &[0..2, 2..4])
        .unwrap();
    let mut cfg = quick_cfg();
    cfg.queue_depth = 2;
    cfg.pool.queue_depth = 1;
    cfg.pool.max_batch = 1;
    let pipe = StagePipeline::start(cfg, "tiny", stages).unwrap();
    let input = input_for(&net, 7);
    let want = reference(&net, &profile, &input, true);

    let n_burst: u64 = 48;
    let t0 = Instant::now();
    let (queue_full_seen, outputs) = std::thread::scope(|s| {
        let pipe_ref = &pipe;
        let input_ref = &input;
        let submitter = s.spawn(move || {
            let mut handles = Vec::new();
            for i in 0..n_burst {
                handles.push(
                    pipe_ref
                        .submit(Request::for_model(i, "tiny", input_ref.clone()))
                        .expect("blocking admission must backpressure, not fail"),
                );
            }
            handles
                .into_iter()
                .map(|h| h.wait().expect("burst request must settle Ok"))
                .map(|r| r.output)
                .collect::<Vec<_>>()
        });
        // Probe non-blocking admission while the burst saturates the
        // pipeline: at least one probe must be rejected typed.
        let mut queue_full = 0u32;
        for i in 0..200 {
            match pipe_ref.try_submit(Request::for_model(10_000 + i, "tiny", input_ref.clone())) {
                Err(Error::QueueFull) | Err(Error::Overloaded { .. }) => queue_full += 1,
                Ok(h) => {
                    let r = h.wait().expect("accepted probe must settle Ok");
                    assert_eq!(r.output, want, "probe {i} diverged");
                }
                Err(e) => panic!("probe {i}: unexpected admission error {e}"),
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        (queue_full, submitter.join().unwrap())
    });
    assert!(
        queue_full_seen >= 1,
        "saturating burst never tripped typed admission backpressure"
    );
    assert_eq!(outputs.len(), n_burst as usize);
    for (i, out) in outputs.iter().enumerate() {
        assert_eq!(out, &want, "burst request {i} diverged under backpressure");
    }
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "burst settled too slowly — suspicious of a near-deadlock"
    );

    let metrics = pipe.shutdown().unwrap();
    for (k, &hw) in metrics.queue_high_water.iter().enumerate() {
        assert!(hw >= 1, "stage {k} queue never held an in-flight request");
        assert!(hw <= 2, "stage {k} queue exceeded its configured bound");
    }
}

/// Backend decorator that panics on the next execution once armed — the
/// deterministic "pull the plug on this stage" lever.
struct KillSwitch {
    inner: Box<dyn ExecutionBackend>,
    armed: Arc<AtomicBool>,
}

impl ExecutionBackend for KillSwitch {
    fn name(&self) -> &'static str {
        "kill-switch"
    }

    fn plan(&mut self, plan: &EnginePlan) -> Result<()> {
        self.inner.plan(plan)
    }

    fn preload(&mut self, model: &Arc<CompiledModel>) -> Result<()> {
        self.inner.preload(model)
    }

    fn execute_layer(&mut self, idx: usize, input: &[f32]) -> Result<LayerOutcome> {
        if self.armed.load(Ordering::SeqCst) {
            panic!("kill switch fired");
        }
        self.inner.execute_layer(idx, input)
    }

    fn finish(&mut self) -> Result<ExecutionReport> {
        self.inner.finish()
    }
}

/// Mid-stream stage kill: stage 1's sole replica dies with an exhausted
/// restart budget while a burst is in flight. Every burst request settles
/// typed ([`Error::StageFailed`] naming the sick stage) or correct;
/// nothing hangs. After disarming, the stage's supervisor rebuilds the
/// replica from the catalog (respins preserve the split's seed namespace)
/// and the pipeline serves bit-identical numerics again.
#[test]
fn stage_kill_mid_stream_settles_typed_or_correct_then_recovers() {
    let net = tiny_resnet();
    let profile = RatioProfile::uniform(&net, 0.5);
    let stages = compiler()
        .split(net.clone(), profile.clone(), &[0..2, 2..4])
        .unwrap();
    let mut cfg = quick_cfg();
    // A single panic permanently kills the stage's sole worker: the outage
    // is unrecoverable below the replica layer by construction.
    cfg.pool.restart_budget = 0;
    cfg.pool.retries = 0;

    let armed = Arc::new(AtomicBool::new(false));
    let armed_in_wrap = Arc::clone(&armed);
    let wrap: BackendWrap = Arc::new(move |backend, _worker| {
        Box::new(KillSwitch {
            inner: backend,
            armed: Arc::clone(&armed_in_wrap),
        })
    });
    let pipe =
        StagePipeline::start_with_stage_wraps(cfg, "tiny", stages, vec![None, Some(wrap)]).unwrap();
    let input = input_for(&net, 7);
    let want = reference(&net, &profile, &input, true);

    // Phase A — steady state.
    for i in 0..8u64 {
        let r = pipe
            .submit(Request::for_model(i, "tiny", input.clone()))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.output, want, "steady-state request {i} diverged");
    }

    // Phase B — the outage: arm stage 1's kill switch, burst, and require
    // every settle to be typed-or-correct.
    armed.store(true, Ordering::SeqCst);
    let handles: Vec<_> = (0..16u64)
        .map(|i| {
            pipe.submit(Request::for_model(100 + i, "tiny", input.clone()))
                .expect("admission stays open during a downstream outage")
        })
        .collect();
    let mut failed = 0usize;
    for (i, h) in handles.into_iter().enumerate() {
        match h.wait() {
            Ok(r) => assert_eq!(r.output, want, "outage request {i} diverged"),
            Err(Error::StageFailed { stage, source }) => {
                assert_eq!(stage, 1, "only stage 1 was killed: {source}");
                failed += 1;
            }
            Err(e) => panic!("outage request {i}: untyped failure {e}"),
        }
    }
    assert!(failed >= 1, "the kill switch must have claimed a request");

    // Phase C — recovery: disarm, wait for the stage supervisor to
    // rebuild, then require intact numerics and restored capacity.
    armed.store(false, Ordering::SeqCst);
    let t0 = Instant::now();
    while pipe.rebuilds(1) < 1 || pipe.live_replicas(1) < 1 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "stage 1 supervisor never restored capacity (rebuilds={}, live={})",
            pipe.rebuilds(1),
            pipe.live_replicas(1)
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    // The rebuilt stage may need a few attempts while it warms back up.
    let t0 = Instant::now();
    let recovered = loop {
        let r = pipe
            .submit(Request::for_model(1000, "tiny", input.clone()))
            .unwrap()
            .wait();
        match r {
            Ok(r) => break r,
            Err(_) if t0.elapsed() < Duration::from_secs(10) => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => panic!("pipeline never recovered after rebuild: {e}"),
        }
    };
    assert_eq!(
        recovered.output, want,
        "post-rebuild numerics diverged — respin lost the seed namespace"
    );

    let metrics = pipe.shutdown().unwrap();
    assert!(
        metrics.per_stage[1].rebuilds >= 1,
        "stage 1 must have been rebuilt"
    );
    assert!(
        metrics.panicked_workers() >= 1,
        "the kill switch's panic must survive into stage metrics"
    );
    assert_eq!(metrics.per_stage.len(), 2);
}
