//! FWHT-path properties: the matrix-free O(L log L) OVSF kernel must be
//! numerically indistinguishable (≤1e-4) from the dense-matrix oracle the
//! repo used before the rewrite, across ResNet-relevant lengths, ratios
//! and both 3×3-extraction modes — plus exactness regressions at ρ=1.
//!
//! The dense Sylvester oracle is re-implemented here (test-only): building
//! the full L×L ±1 matrix and running O(L²) projections is precisely what
//! production code is no longer allowed to do.

use unzipfpga::ovsf::basis::{select, BasisSelection, SelectedBasis};
use unzipfpga::ovsf::codes::OvsfBasis;
use unzipfpga::ovsf::regress::{fwht, mse, project, reconstruct_vec};
use unzipfpga::ovsf::reconstruct::{extract_kxk, Filter3x3Mode, OvsfLayer};
use unzipfpga::util::check::forall;
use unzipfpga::util::prng::Xoshiro256;

/// Dense Sylvester materialisation (the pre-rewrite construction).
fn dense_sylvester(len: usize) -> Vec<i8> {
    assert!(len.is_power_of_two());
    let mut codes = vec![1i8];
    let mut cur = 1usize;
    while cur < len {
        let next = cur * 2;
        let mut out = vec![0i8; next * next];
        for r in 0..cur {
            for c in 0..cur {
                let v = codes[r * cur + c];
                out[r * next + c] = v;
                out[r * next + cur + c] = v;
                out[(cur + r) * next + c] = v;
                out[(cur + r) * next + cur + c] = -v;
            }
        }
        codes = out;
        cur = next;
    }
    codes
}

/// Dense-matrix projection oracle: `α_j = ⟨t, b_j⟩ / L` via L dot products.
fn project_dense(dense: &[i8], l: usize, target: &[f32]) -> Vec<f32> {
    let inv_l = 1.0f64 / l as f64;
    (0..l)
        .map(|j| {
            let mut acc = 0.0f64;
            for (t, &v) in target.iter().enumerate() {
                acc += v as f64 * dense[j * l + t] as f64;
            }
            (acc * inv_l) as f32
        })
        .collect()
}

/// Dense-matrix reconstruction oracle.
fn reconstruct_dense(dense: &[i8], l: usize, sel: &SelectedBasis) -> Vec<f32> {
    let mut out = vec![0.0f32; l];
    for (k, &j) in sel.indices.iter().enumerate() {
        let a = sel.alphas[k] as f64;
        for (t, o) in out.iter_mut().enumerate() {
            *o += (a * dense[j * l + t] as f64) as f32;
        }
    }
    out
}

fn check_length(l: usize, rho: f64, rng: &mut Xoshiro256) {
    let basis = OvsfBasis::new(l).unwrap();
    let dense = dense_sylvester(l);
    let target = rng.normal_vec(l);
    let fast_alphas = project(&basis, &target);
    let slow_alphas = project_dense(&dense, l, &target);
    for (j, (a, e)) in fast_alphas.iter().zip(&slow_alphas).enumerate() {
        assert!(
            (a - e).abs() < 1e-4,
            "α_{j}: FWHT {a} vs dense {e} (L={l}, ρ={rho})"
        );
    }
    for strategy in [BasisSelection::Sequential, BasisSelection::IterativeDrop] {
        let sel = select(strategy, &basis, &fast_alphas, rho);
        let fast = reconstruct_vec(&basis, &sel);
        let slow = reconstruct_dense(&dense, l, &sel);
        for (t, (a, e)) in fast.iter().zip(&slow).enumerate() {
            assert!(
                (a - e).abs() < 1e-4,
                "recon[{t}]: FWHT {a} vs dense {e} (L={l}, ρ={rho}, {strategy})"
            );
        }
        // Selection-aware mse agrees with the dense reconstruction error.
        let analytic = mse(&basis, &sel, &target);
        let explicit: f64 = target
            .iter()
            .zip(&slow)
            .map(|(&t, &r)| ((t - r) as f64).powi(2))
            .sum::<f64>()
            / l as f64;
        assert!(
            (analytic - explicit).abs() < 1e-4 * explicit.max(1.0),
            "mse {analytic} vs dense {explicit} (L={l}, ρ={rho}, {strategy})"
        );
    }
}

#[test]
fn fwht_matches_dense_oracle_small_lengths() {
    forall("fwht-vs-dense-small", 40, |rng| {
        let l = 1usize << rng.gen_range(1, 10); // 2..1024
        let rho = *rng.choose(&[0.25, 0.5, 1.0]);
        check_length(l, rho, rng);
    });
}

#[test]
fn fwht_matches_dense_oracle_resnet_scale() {
    // L = 4096 (256-ch) and L = 8192 (512-ch 3×3, the ResNet-50 worst
    // case): one deterministic case each — the dense oracle is O(L²).
    let mut rng = Xoshiro256::seed_from_u64(0x0f57);
    check_length(4096, 0.5, &mut rng);
    check_length(8192, 0.25, &mut rng);
}

#[test]
fn fwht_involution_recovers_input() {
    // H² = L·I: transforming twice and dividing by L is the identity.
    forall("fwht-involution", 24, |rng| {
        let l = 1usize << rng.gen_range(0, 13); // 1..8192
        let v = rng.normal_vec(l);
        let mut data: Vec<f64> = v.iter().map(|&x| x as f64).collect();
        fwht(&mut data);
        fwht(&mut data);
        for (orig, twice) in v.iter().zip(&data) {
            let back = twice / l as f64;
            assert!((*orig as f64 - back).abs() < 1e-9, "L={l}");
        }
    });
}

#[test]
fn layer_roundtrip_matches_oracle_both_modes() {
    // OvsfLayer::from_weights + reconstruct against a per-filter dense
    // oracle, for both 3×3-extraction strategies and partial ρ.
    forall("ovsf-layer-fwht-vs-dense", 10, |rng| {
        let n_in = 1usize << rng.gen_range(1, 4); // 2..8
        let n_out = rng.gen_range(1, 4) as usize;
        let k = 3usize;
        let k_ovsf = 4usize;
        let l = n_in * k_ovsf * k_ovsf;
        let rho = *rng.choose(&[0.25, 0.5, 1.0]);
        let mode = *rng.choose(&[Filter3x3Mode::Crop, Filter3x3Mode::AdaptivePool]);
        let strategy = *rng.choose(&[BasisSelection::Sequential, BasisSelection::IterativeDrop]);
        let w = rng.normal_vec(n_out * n_in * k * k);
        let layer =
            OvsfLayer::from_weights(&w, n_out, n_in, k, rho, strategy, mode).unwrap();
        let fast = layer.reconstruct().unwrap();

        // Dense oracle: project each zero-padded filter on the dense
        // matrix, select with the same strategy, reconstruct, extract.
        let dense = dense_sylvester(l);
        let basis = OvsfBasis::new(l).unwrap();
        for o in 0..n_out {
            let mut target = vec![0.0f32; l];
            for c in 0..n_in {
                for kh in 0..k {
                    for kw in 0..k {
                        target[(c * k_ovsf + kh) * k_ovsf + kw] =
                            w[((o * n_in + c) * k + kh) * k + kw];
                    }
                }
            }
            let alphas = project_dense(&dense, l, &target);
            let sel = select(strategy, &basis, &alphas, rho);
            let full = reconstruct_dense(&dense, l, &sel);
            for c in 0..n_in {
                let plane = &full[c * k_ovsf * k_ovsf..(c + 1) * k_ovsf * k_ovsf];
                let expect = extract_kxk(plane, k_ovsf, k, mode);
                for (pos, e) in expect.iter().enumerate() {
                    let got = fast[(o * n_in + c) * k * k + pos];
                    assert!(
                        (got - e).abs() < 1e-4,
                        "o={o} c={c} pos={pos}: {got} vs {e} (ρ={rho}, {mode}, {strategy})"
                    );
                }
            }
        }
    });
}

#[test]
fn rho_one_reconstruction_stays_exact_after_rewrite() {
    // Regression: the FWHT rewrite must preserve ρ=1 exactness — pow2
    // kernels directly, K=3 via the zero-padded frame + crop.
    forall("fwht-rho1-exact", 12, |rng| {
        let n_in = 1usize << rng.gen_range(1, 4);
        let n_out = rng.gen_range(1, 5) as usize;
        let k = *rng.choose(&[2usize, 3, 4]);
        let w = rng.normal_vec(n_out * n_in * k * k);
        let layer = OvsfLayer::from_weights(
            &w,
            n_out,
            n_in,
            k,
            1.0,
            BasisSelection::IterativeDrop,
            Filter3x3Mode::Crop,
        )
        .unwrap();
        let r = layer.reconstruct().unwrap();
        for (a, b) in w.iter().zip(&r) {
            assert!((a - b).abs() < 1e-4, "ρ=1 no longer exact: {a} vs {b}");
        }
    });
}
