//! Seeded chaos soak: bursty traffic through a supervised pool whose
//! backend injects panics, transient errors, latency spikes and slab
//! bit-flips from a deterministic [`FaultPlan`]. The fault-tolerance
//! claims under test:
//!
//! * **No hangs, no silent drops** — every submitted request settles with
//!   a response or a *typed* error; the traffic accounting identity holds.
//! * **Bit-identical numerics** — a request that succeeds under chaos
//!   returns exactly the fault-free engine's output (injection happens
//!   before delegation; slab corruption is caught by checksums and
//!   regenerated, never served).
//! * **Capacity is restored** — every injected worker panic is answered
//!   by a supervisor respawn, so the pool ends the soak with its full
//!   configured worker count.
//! * **Breaker transitions are deterministic** — a scripted failure
//!   sequence drives closed → open → half-open → closed with exact trip
//!   counts and typed fast rejections.
//!
//! Set `CHAOS_SOAK=1` for a longer run (CI does); the default is sized
//! for the regular test suite.

use std::sync::Arc;
use std::time::Duration;

use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::coordinator::breaker::{BreakerConfig, BreakerState};
use unzipfpga::coordinator::plan::InferencePlan;
use unzipfpga::coordinator::pool::{PoolConfig, RequestExecutor, ServerPool};
use unzipfpga::coordinator::server::Request;
use unzipfpga::engine::fault::{FaultPlan, FaultStats, FaultyBackend};
use unzipfpga::engine::{Engine, SimBackend, SlabCache};
use unzipfpga::error::{Error, Result};
use unzipfpga::util::prng::Xoshiro256;
use unzipfpga::workload::{Layer, Network, RatioProfile};

/// Small 3-layer network: big enough to exercise the slab cache across
/// layer passes, small enough that a soak of hundreds of requests stays
/// inside the regular suite's time budget.
fn tiny_net() -> Network {
    Network {
        name: "chaos-tiny".into(),
        layers: vec![
            Layer::conv("stem", 8, 8, 4, 8, 3, 1, 1, false),
            Layer::conv("b.conv1", 8, 8, 8, 8, 3, 1, 1, true),
            Layer::fc("fc", 8, 5),
        ],
    }
}

fn engine_plan() -> unzipfpga::engine::EnginePlan {
    let net = tiny_net();
    let profile = RatioProfile::uniform(&net, 0.5);
    Engine::builder()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(DesignPoint::new(8, 4, 8, 4))
        .network(net)
        .profile(profile)
        .plan()
        .unwrap()
}

fn pool_plan() -> InferencePlan {
    let net = tiny_net();
    let profile = RatioProfile::uniform(&net, 0.5);
    InferencePlan::build(
        &Platform::z7045(),
        4,
        DesignPoint::new(8, 4, 8, 4),
        &net,
        &profile,
    )
}

fn chaos_input() -> Vec<f32> {
    Xoshiro256::seed_from_u64(2024).normal_vec(8 * 8 * 4)
}

/// Pool executor that runs a real engine per request — the production
/// shape, with the fault wrapper in the backend seat.
struct ChaosExec {
    engine: Engine,
}

impl RequestExecutor for ChaosExec {
    fn execute(&mut self, req: &Request) -> Result<Vec<f32>> {
        Ok(self.engine.infer(&req.input)?.output)
    }
}

#[test]
fn chaos_soak_types_every_failure_and_restores_capacity() {
    let soak = std::env::var("CHAOS_SOAK").is_ok();
    let (bursts, per_burst) = if soak { (40, 25) } else { (10, 20) };

    // Fault-free reference: the bit-identical target for every request
    // that succeeds under chaos.
    let input = chaos_input();
    let mut reference = Engine::with_backend(
        engine_plan(),
        Box::new(SimBackend::with_cache(Arc::new(SlabCache::new()))),
    )
    .unwrap();
    let expect = reference.infer(&input).unwrap().output;
    assert!(!expect.is_empty());

    // One shared slab cache (so bit-flips corrupt state other workers
    // read) and one shared stats block (so a respawned worker's
    // replacement backend keeps accumulating).
    let cache = Arc::new(SlabCache::new());
    let stats = Arc::new(FaultStats::default());
    let fault_plan = FaultPlan {
        seed: 0xC0FFEE,
        transient: 0.04,
        permanent: 0.0,
        panic_p: 0.004,
        latency_spike: 0.01,
        spike: Duration::from_micros(200),
        bitflip: 0.05,
    };

    let workers = 2;
    let cfg = PoolConfig {
        workers,
        queue_depth: 256,
        max_batch: 4,
        linger: Duration::from_micros(200),
        retries: 2,
        retry_backoff: Duration::from_micros(100),
        restart_budget: 64,
        restart_backoff: Duration::from_micros(200),
        ..PoolConfig::default()
    };
    let eplan = engine_plan();
    let pool = ServerPool::start(pool_plan(), cfg, {
        let cache = Arc::clone(&cache);
        let stats = Arc::clone(&stats);
        let fault_plan = fault_plan.clone();
        move |worker| {
            let backend = FaultyBackend::with_cache(
                SimBackend::with_cache(Arc::clone(&cache)),
                fault_plan.clone().for_worker(worker),
                Arc::clone(&cache),
            )
            .sharing_stats(Arc::clone(&stats));
            ChaosExec {
                engine: Engine::with_backend(eplan.clone(), Box::new(backend)).unwrap(),
            }
        }
    })
    .unwrap();

    // Bursty offered load: a burst of submissions, a quiet gap, repeat.
    let mut handles = Vec::new();
    let mut id = 0u64;
    for burst in 0..bursts {
        for _ in 0..per_burst {
            handles.push(pool.submit(Request::numeric(id, input.clone())).unwrap());
            id += 1;
        }
        if burst % 2 == 1 {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    let offered = handles.len();

    // Every handle settles — a hang here fails the suite's timeout — and
    // every outcome is either the bit-identical output or a typed error
    // from the fault-tolerance taxonomy.
    let mut completed = 0usize;
    let mut failed = 0usize;
    for h in handles {
        match h.wait() {
            Ok(resp) => {
                assert_eq!(
                    resp.output, expect,
                    "a successful response under chaos must be bit-identical \
                     to the fault-free run"
                );
                completed += 1;
            }
            Err(e) => {
                assert!(
                    matches!(
                        e,
                        Error::WorkerPanic { .. }
                            | Error::Transient(_)
                            | Error::Coordinator(_)
                    ),
                    "every chaos failure must be typed, got: {e}"
                );
                failed += 1;
            }
        }
    }
    assert_eq!(completed + failed, offered, "no request may vanish");
    assert!(completed > 0, "the soak must make forward progress");
    assert!(
        stats.total() > 0,
        "the seeded plan must have injected something"
    );

    // Capacity restored: every panic was answered by a respawn.
    assert_eq!(
        pool.live_workers(),
        workers,
        "supervisor must have respawned every panicked worker \
         (injected panics: {})",
        stats.panics()
    );

    let pm = pool.shutdown().unwrap();
    // total_requests counts every request an executor settled (success or
    // typed failure); panic-path replies bypass the executor metrics.
    assert!(
        pm.total_requests() >= completed && pm.total_requests() <= completed + failed,
        "settled {} outside [{completed}, {}]",
        pm.total_requests(),
        completed + failed
    );
    assert_eq!(
        pm.panicked_workers as u64, pm.worker_restarts,
        "each caught panic must map to exactly one respawn"
    );
    assert!(
        pm.worker_restarts <= 64,
        "restart budget bounds respawns"
    );
    // Slab integrity: the injected bit-flips were caught by checksums
    // (corruptions counted, slabs regenerated) — the bit-identical
    // assertion above proves none reached an output.
    if stats.bitflips() > 0 {
        assert!(
            cache.corruptions() > 0,
            "checksum verification must catch injected slab corruption \
             ({} flips injected)",
            stats.bitflips()
        );
    }
}

#[test]
fn open_loop_traffic_identity_holds_under_chaos() {
    use unzipfpga::coordinator::traffic::{ArrivalProcess, RequestClass, TrafficSpec};

    let cache = Arc::new(SlabCache::new());
    let stats = Arc::new(FaultStats::default());
    let fault_plan = FaultPlan {
        seed: 7,
        transient: 0.03,
        permanent: 0.0,
        panic_p: 0.003,
        latency_spike: 0.0,
        spike: Duration::from_millis(1),
        bitflip: 0.02,
    };
    let cfg = PoolConfig {
        workers: 2,
        queue_depth: 128,
        max_batch: 4,
        linger: Duration::from_micros(200),
        retries: 1,
        restart_budget: 32,
        restart_backoff: Duration::from_micros(200),
        ..PoolConfig::default()
    };
    let eplan = engine_plan();
    let pool = ServerPool::start(pool_plan(), cfg, {
        let cache = Arc::clone(&cache);
        let stats = Arc::clone(&stats);
        move |worker| {
            let backend = FaultyBackend::with_cache(
                SimBackend::with_cache(Arc::clone(&cache)),
                fault_plan.clone().for_worker(worker),
                Arc::clone(&cache),
            )
            .sharing_stats(Arc::clone(&stats));
            ChaosExec {
                engine: Engine::with_backend(eplan.clone(), Box::new(backend)).unwrap(),
            }
        }
    })
    .unwrap();

    let spec = TrafficSpec {
        process: ArrivalProcess::Bursty {
            base_rps: 300.0,
            burst_rps: 2500.0,
            mean_on_s: 0.02,
            mean_off_s: 0.05,
        },
        duration_s: 0.25,
        seed: 99,
        classes: vec![RequestClass::timing("").with_input(chaos_input())],
    };
    let report = spec.run_open_loop(&pool);
    assert!(report.offered > 0);
    // Full identity: every offered arrival is completed or typed away.
    assert_eq!(
        report.offered,
        report.completed + report.shed + report.queue_full + report.expired + report.failed,
        "every arrival must be accounted under chaos: {}",
        report.summary()
    );
    assert_eq!(report.harness_failures, 0, "{}", report.summary());
    assert!(report.completed > 0, "{}", report.summary());
    assert_eq!(pool.live_workers(), 2, "capacity restored before shutdown");
    let pm = pool.shutdown().unwrap();
    assert_eq!(pm.panicked_workers as u64, pm.worker_restarts);
}

#[test]
fn breaker_transitions_are_deterministic_under_a_scripted_fault_burst() {
    /// Fails its first three calls, succeeds afterwards — a scripted
    /// outage with a sharp recovery edge.
    struct Scripted {
        calls: u64,
    }
    impl RequestExecutor for Scripted {
        fn execute(&mut self, req: &Request) -> Result<Vec<f32>> {
            self.calls += 1;
            if self.calls <= 3 {
                Err(Error::Coordinator("scripted outage".into()))
            } else {
                Ok(vec![req.id as f32])
            }
        }
    }

    let cfg = PoolConfig {
        workers: 1,
        queue_depth: 64,
        max_batch: 1,
        linger: Duration::ZERO,
        retries: 0,
        breaker: Some(BreakerConfig {
            failure_threshold: 3,
            open_for: Duration::from_millis(40),
            half_open_probes: 2,
        }),
        ..PoolConfig::default()
    };
    let pool = ServerPool::start(pool_plan(), cfg, |_| Scripted { calls: 0 }).unwrap();
    let breaker_state =
        |pool: &ServerPool| pool.breaker().expect("breaker configured").state("(default)");

    // Three consecutive failures: closed → open, exactly one trip.
    for id in 0..3u64 {
        let err = pool
            .submit(Request::timing(id))
            .unwrap()
            .wait()
            .err()
            .expect("scripted outage must fail");
        assert!(matches!(err, Error::Coordinator(_)), "got: {err}");
    }
    assert_eq!(breaker_state(&pool), BreakerState::Open);

    // While open: fast typed rejection at submission, no queueing.
    let err = pool.submit(Request::timing(3)).err().expect("must reject");
    match err {
        Error::CircuitOpen { model, retry_after } => {
            assert_eq!(model, "(default)");
            assert!(retry_after > Duration::ZERO);
            assert!(retry_after <= Duration::from_millis(40));
        }
        other => panic!("expected CircuitOpen, got: {other}"),
    }

    // After the open window: half-open probes. The scripted executor now
    // succeeds, so two probes close the breaker deterministically.
    std::thread::sleep(Duration::from_millis(60));
    let r = pool.submit(Request::timing(10)).unwrap().wait().unwrap();
    assert_eq!(r.output, vec![10.0]);
    assert_eq!(breaker_state(&pool), BreakerState::HalfOpen);
    let r = pool.submit(Request::timing(11)).unwrap().wait().unwrap();
    assert_eq!(r.output, vec![11.0]);
    assert_eq!(breaker_state(&pool), BreakerState::Closed);

    let pm = pool.shutdown().unwrap();
    assert_eq!(pm.breaker_trips, 1, "exactly one trip in the script");
    assert_eq!(
        pm.breaker_states.get("(default)").copied(),
        Some(BreakerState::Closed)
    );
    assert_eq!(pm.panicked_workers, 0);
    assert!(pm.summary().contains("breaker_trips=1"), "{}", pm.summary());
}

#[test]
fn restart_budget_exhaustion_shrinks_capacity_but_keeps_serving() {
    /// Panics on a sentinel input, serves everything else.
    struct PanicOnSentinel;
    impl RequestExecutor for PanicOnSentinel {
        fn execute(&mut self, req: &Request) -> Result<Vec<f32>> {
            if req.input.first() == Some(&999.0) {
                panic!("sentinel-triggered executor panic");
            }
            Ok(vec![req.id as f32])
        }
    }

    fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
        let t0 = std::time::Instant::now();
        while !cond() {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "timed out waiting for {what}"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    let workers = 2;
    let cfg = PoolConfig {
        workers,
        queue_depth: 64,
        max_batch: 1,
        linger: Duration::ZERO,
        retries: 0,
        restart_budget: 1,
        restart_backoff: Duration::from_micros(200),
        ..PoolConfig::default()
    };
    let pool = ServerPool::start(pool_plan(), cfg, |_| PanicOnSentinel).unwrap();
    assert_eq!(pool.configured_workers(), workers);
    assert_eq!(pool.restart_budget_left(), 1);

    // First panic: typed error, and the budget pays for a respawn.
    let err = pool
        .submit(Request::numeric(0, vec![999.0]))
        .unwrap()
        .wait()
        .err()
        .expect("sentinel must fail the request");
    assert!(matches!(err, Error::WorkerPanic { .. }), "got: {err}");
    wait_until("respawn to restore capacity", || {
        pool.live_workers() == workers
    });
    assert_eq!(pool.restart_budget_left(), 0);

    // Second panic: budget exhausted — capacity shrinks permanently.
    let err = pool
        .submit(Request::numeric(1, vec![999.0]))
        .unwrap()
        .wait()
        .err()
        .expect("second sentinel must fail too");
    assert!(matches!(err, Error::WorkerPanic { .. }), "got: {err}");
    wait_until("capacity loss to register", || pool.live_workers() == workers - 1);
    assert_eq!(pool.restart_budget_left(), 0);
    assert_eq!(
        pool.configured_workers(),
        workers,
        "configured capacity is immutable; only live capacity shrinks"
    );

    // The shrunken pool neither hangs nor drops: every request is served
    // by the surviving worker.
    let handles: Vec<_> = (10..30u64)
        .map(|id| pool.submit(Request::numeric(id, vec![1.0])).unwrap())
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.wait().expect("surviving worker must serve");
        assert_eq!(r.output, vec![(10 + i) as f32]);
    }
    // The in-flight gauge settles via RAII just *after* responses are
    // delivered, so poll rather than asserting a single snapshot.
    wait_until("gauges to quiesce after every handle settled", || {
        pool.queue_len() == 0 && pool.in_flight() == 0
    });

    let pm = pool.shutdown().unwrap();
    assert_eq!(pm.panicked_workers, 2, "both sentinel panics were caught");
    assert_eq!(pm.worker_restarts, 1, "exactly the budget's worth of respawns");
}
