//! Integration: DSE → cycle-level simulator → report pipeline, end to end,
//! across networks and platforms.

use unzipfpga::arch::Platform;
use unzipfpga::autotune::autotune;
use unzipfpga::baselines::faithful::evaluate_faithful;
use unzipfpga::dse::search::{optimise, DseConfig};
use unzipfpga::perf::model::PerfModel;
use unzipfpga::sim::engine::simulate_network_timing;
use unzipfpga::workload::{Network, RatioProfile};

/// The central cross-check: for every benchmark × platform the simulator's
/// walked totals agree with the analytical model at the DSE optimum.
#[test]
fn simulator_agrees_with_model_on_all_benchmarks() {
    let cfg = DseConfig::default();
    for net in Network::benchmarks() {
        for plat in Platform::all() {
            let profile = RatioProfile::ovsf50(&net);
            let bw = plat.peak_bw_mult;
            let r = optimise(&cfg, &plat, bw, &net, &profile, true).unwrap();
            let traces = simulate_network_timing(&r.sigma, &plat, bw, true, &net, &profile);
            let sim_total: u64 = traces.iter().map(|t| t.total_cycles).sum();
            let dev = (sim_total as f64 - r.perf.total_cycles).abs() / r.perf.total_cycles;
            assert!(
                dev < 0.01,
                "{} on {}: sim {} vs model {} ({dev:.4})",
                net.name,
                plat.name,
                sim_total,
                r.perf.total_cycles
            );
        }
    }
}

/// Table-4-shaped end-to-end claim: at 1× bandwidth, unzipFPGA's OVSF50
/// beats the faithful baseline by a large factor on ResNet34 and the gap
/// closes with bandwidth (the paper reports 2.1× → 1.1×).
#[test]
fn headline_speedups_follow_paper_shape() {
    let net = unzipfpga::workload::resnet::resnet34();
    let plat = Platform::z7045();
    let cfg = DseConfig::default();
    let profile = RatioProfile::ovsf50(&net);
    let mut speedups = Vec::new();
    for bw in [1u32, 2, 4] {
        let base = evaluate_faithful(&plat, bw, &net).unwrap().perf.inf_per_s;
        let unzip = optimise(&cfg, &plat, bw, &net, &profile, true)
            .unwrap()
            .perf
            .inf_per_s;
        speedups.push(unzip / base);
    }
    assert!(
        speedups[0] > 1.5,
        "1× speedup {:.2} too small (paper: 2.1×)",
        speedups[0]
    );
    // Decay with bandwidth, allowing ~2% slack for DSE grid discreteness
    // between adjacent points.
    assert!(
        speedups[0] * 1.02 > speedups[1] && speedups[1] > speedups[2],
        "speedups must decay with bandwidth: {speedups:?}"
    );
    assert!(
        speedups[2] < 1.7,
        "4× speedup {:.2} should be modest (paper: 1.1×)",
        speedups[2]
    );
}

/// Autotuning composes with the DSE across bandwidths and platforms:
/// throughput preserved, effective ρ raised, accuracy model rewards it.
#[test]
fn autotune_pipeline_improves_accuracy_at_no_cost() {
    let net = unzipfpga::workload::resnet::resnet18();
    let cfg = DseConfig::default();
    for bw in [1u32, 2, 4] {
        let plat = Platform::z7045();
        let r = autotune(&cfg, &plat, bw, &net).unwrap();
        let acc = unzipfpga::accuracy::AccuracyModel::for_network(&net);
        let base_acc = acc.top1(&net, &RatioProfile::ovsf25(&net));
        let tuned_acc = acc.top1(&net, &r.profile);
        assert!(
            tuned_acc >= base_acc,
            "{bw}×: tuned accuracy {tuned_acc} below OVSF25 {base_acc}"
        );
        assert!(r.final_inf_per_s >= r.initial_inf_per_s * 0.98);
        // More bandwidth-constrained ⇒ more wgen slack ⇒ more accuracy
        // recovered (Table 1's 1.2pp at 1.1 GB/s vs 0.3pp at 4.4 GB/s).
        if bw == 1 {
            assert!(
                tuned_acc - base_acc > 0.4,
                "1× should recover substantial accuracy: +{:.2}pp",
                tuned_acc - base_acc
            );
        }
    }
}

/// The DSE allocates resources sensibly: big platforms get bigger engines,
/// and constrained bandwidth shifts the optimum toward more wgen lanes
/// relative to what unconstrained bandwidth picks.
#[test]
fn dse_resource_allocation_is_sane() {
    let net = unzipfpga::workload::resnet::resnet50();
    let cfg = DseConfig::default();
    let profile = RatioProfile::ovsf50(&net);
    let z = optimise(&cfg, &Platform::z7045(), 4, &net, &profile, true).unwrap();
    let u = optimise(&cfg, &Platform::zu7ev(), 4, &net, &profile, true).unwrap();
    assert!(u.sigma.engine_macs() >= z.sigma.engine_macs());
    assert!(z.usage.dsps <= 900 && u.usage.dsps <= 1728);
    // Both allocate nonzero wgen lanes (OVSF layers dominate ResNet50's
    // runtime at these bandwidths).
    assert!(z.sigma.m > 0 && u.sigma.m > 0);
}

/// Bottleneck classifications from the simulator match the analytical
/// model layer by layer (the signal driving Table 1 and the autotuner).
#[test]
fn bounds_agree_between_sim_and_model() {
    let net = unzipfpga::workload::resnet::resnet18();
    let plat = Platform::z7045();
    let profile = RatioProfile::ovsf25(&net);
    let sigma = unzipfpga::arch::DesignPoint::new(64, 64, 16, 48);
    let model = PerfModel::new(plat.clone(), 1);
    let perf = model.network_perf(&sigma, &net, &profile);
    let traces = simulate_network_timing(&sigma, &plat, 1, true, &net, &profile);
    let mut agree = 0;
    for (t, p) in traces.iter().zip(&perf.layers) {
        if t.bound == p.bound {
            agree += 1;
        }
    }
    // DMA ceilings can flip razor-edge ties; demand ≥ 90% agreement.
    assert!(
        agree * 10 >= traces.len() * 9,
        "bound agreement {agree}/{}",
        traces.len()
    );
}
