//! Integration: the coordinator serves a request stream where each request
//! executes REAL numerics through the PJRT runtime (the AOT model forward)
//! — Python is nowhere on this path. Serving goes through the multi-worker
//! `ServerPool` with caller-provided executors (`ServerPool::start`).

use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::coordinator::pool::{PoolConfig, ServerPool};
use unzipfpga::coordinator::plan::InferencePlan;
use unzipfpga::coordinator::server::Request;
use unzipfpga::runtime::{artifacts_dir, ArtifactRegistry};
use unzipfpga::workload::{resnet, RatioProfile};

fn plan() -> InferencePlan {
    let net = resnet::resnet18();
    let profile = RatioProfile::ovsf50(&net);
    InferencePlan::build(
        &Platform::z7045(),
        4,
        DesignPoint::new(64, 64, 16, 48),
        &net,
        &profile,
    )
}

#[test]
fn serve_requests_through_pjrt() {
    let dir = artifacts_dir();
    if !dir.join("ovsf_conv.hlo.txt").exists() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return;
    }
    {
        // Also needs the real runtime, not the stub.
        let mut probe = ArtifactRegistry::new(dir.clone()).expect("client");
        if probe.get("ovsf_conv").is_err() {
            eprintln!("SKIP: PJRT unavailable — build with `--features pjrt`");
            return;
        }
    }

    // Each worker builds its own registry: PJRT clients are not Send.
    let mut rng = unzipfpga::util::prng::Xoshiro256::seed_from_u64(11);
    let alphas = std::sync::Arc::new(rng.normal_vec(16 * 8 * 32));
    let cfg = PoolConfig {
        workers: 2,
        queue_depth: 32,
        max_batch: 4,
        linger: std::time::Duration::from_millis(1),
        slo: None,
        ..PoolConfig::default()
    };
    let pool = ServerPool::start(plan(), cfg, move |_worker| {
        let alphas = std::sync::Arc::clone(&alphas);
        let mut reg = ArtifactRegistry::new(artifacts_dir()).expect("client");
        reg.get("ovsf_conv").expect("precompile");
        move |req: &Request| {
            let exe = reg.get("ovsf_conv").expect("cached");
            let out = exe
                .run_f32(&[
                    (&req.input, &[1, 16, 16, 16]),
                    (&alphas, &[16, 8, 32]),
                ])
                .expect("PJRT execution");
            out.into_iter().next().unwrap()
        }
    })
    .unwrap();

    let mut rng2 = unzipfpga::util::prng::Xoshiro256::seed_from_u64(12);
    let handles: Vec<_> = (0..8u64)
        .map(|id| {
            let input = rng2.normal_vec(16 * 16 * 16);
            pool.submit(Request::numeric(id, input)).unwrap()
        })
        .collect();
    let mut outputs = Vec::new();
    for (id, h) in handles.into_iter().enumerate() {
        let resp = h.wait().unwrap();
        assert_eq!(resp.id, id as u64);
        assert_eq!(resp.output.len(), 16 * 16 * 32);
        assert!(resp.output.iter().all(|v| v.is_finite()));
        assert!(resp.host_latency_s > 0.0);
        outputs.push(resp.output);
    }
    // Different inputs ⇒ different outputs (the runtime is really running).
    assert_ne!(outputs[0], outputs[1]);

    let metrics = pool.shutdown().unwrap();
    assert_eq!(metrics.total_requests(), 8);
    assert!(metrics.merged().mean_us() > 0.0);
}

#[test]
fn identical_requests_are_deterministic_across_workers() {
    let dir = artifacts_dir();
    if !dir.join("ovsf_wgen.hlo.txt").exists() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return;
    }
    {
        let mut probe = ArtifactRegistry::new(dir.clone()).expect("client");
        if probe.get("ovsf_wgen").is_err() {
            eprintln!("SKIP: PJRT unavailable — build with `--features pjrt`");
            return;
        }
    }
    let cfg = PoolConfig {
        workers: 2,
        queue_depth: 16,
        max_batch: 1,
        linger: std::time::Duration::ZERO,
        slo: None,
        ..PoolConfig::default()
    };
    let pool = ServerPool::start(plan(), cfg, move |_worker| {
        let mut reg = ArtifactRegistry::new(artifacts_dir()).expect("client");
        reg.get("ovsf_wgen").expect("precompile");
        move |req: &Request| {
            let exe = reg.get("ovsf_wgen").expect("cached");
            exe.run_f32(&[(&req.input, &[16, 8, 32])])
                .expect("execution")
                .into_iter()
                .next()
                .unwrap()
        }
    })
    .unwrap();
    let mut rng = unzipfpga::util::prng::Xoshiro256::seed_from_u64(3);
    let input = rng.normal_vec(16 * 8 * 32);
    let a = pool
        .submit(Request::numeric(0, input.clone()))
        .unwrap()
        .wait()
        .unwrap();
    let b = pool
        .submit(Request::numeric(1, input))
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(a.output, b.output, "PJRT execution must be deterministic");
    pool.shutdown().unwrap();
}
