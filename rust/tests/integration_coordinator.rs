//! Integration: the coordinator serves a request stream where each request
//! executes REAL numerics through the PJRT runtime (the AOT model forward)
//! — Python is nowhere on this path.

use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::coordinator::scheduler::InferencePlan;
use unzipfpga::coordinator::server::{InferenceServer, Request};
use unzipfpga::runtime::{artifacts_dir, ArtifactRegistry};
use unzipfpga::workload::{resnet, RatioProfile};

#[test]
fn serve_requests_through_pjrt() {
    let dir = artifacts_dir();
    if !dir.join("ovsf_conv.hlo.txt").exists() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return;
    }
    let net = resnet::resnet18();
    let profile = RatioProfile::ovsf50(&net);
    let plan = InferencePlan::build(
        &Platform::z7045(),
        4,
        DesignPoint::new(64, 64, 16, 48),
        &net,
        &profile,
    );

    // The worker builds its own registry: PJRT clients are not Send.
    let mut rng = unzipfpga::util::prng::Xoshiro256::seed_from_u64(11);
    let alphas = rng.normal_vec(16 * 8 * 32);
    let server = InferenceServer::spawn(plan, move || {
        let mut reg = ArtifactRegistry::new(dir).expect("client");
        reg.get("ovsf_conv").expect("precompile");
        move |req: &Request| {
            let exe = reg.get("ovsf_conv").expect("cached");
            let out = exe
                .run_f32(&[
                    (&req.input, &[1, 16, 16, 16]),
                    (&alphas, &[16, 8, 32]),
                ])
                .expect("PJRT execution");
            out.into_iter().next().unwrap()
        }
    });

    let mut rng2 = unzipfpga::util::prng::Xoshiro256::seed_from_u64(12);
    let mut outputs = Vec::new();
    for id in 0..8u64 {
        let input = rng2.normal_vec(16 * 16 * 16);
        let resp = server.infer(Request { id, input }).unwrap();
        assert_eq!(resp.id, id);
        assert_eq!(resp.output.len(), 16 * 16 * 32);
        assert!(resp.output.iter().all(|v| v.is_finite()));
        assert!(resp.host_latency_s > 0.0);
        outputs.push(resp.output);
    }
    // Different inputs ⇒ different outputs (the runtime is really running).
    assert_ne!(outputs[0], outputs[1]);

    let metrics = server.shutdown().unwrap();
    assert_eq!(metrics.count(), 8);
    assert!(metrics.mean_us() > 0.0);
}

#[test]
fn identical_requests_are_deterministic() {
    let dir = artifacts_dir();
    if !dir.join("ovsf_wgen.hlo.txt").exists() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return;
    }
    let net = resnet::resnet18();
    let profile = RatioProfile::ovsf50(&net);
    let plan = InferencePlan::build(
        &Platform::z7045(),
        4,
        DesignPoint::new(64, 64, 16, 48),
        &net,
        &profile,
    );
    let server = InferenceServer::spawn(plan, move || {
        let mut reg = ArtifactRegistry::new(dir).expect("client");
        reg.get("ovsf_wgen").expect("precompile");
        move |req: &Request| {
            let exe = reg.get("ovsf_wgen").expect("cached");
            exe.run_f32(&[(&req.input, &[16, 8, 32])])
                .expect("execution")
                .into_iter()
                .next()
                .unwrap()
        }
    });
    let mut rng = unzipfpga::util::prng::Xoshiro256::seed_from_u64(3);
    let input = rng.normal_vec(16 * 8 * 32);
    let a = server
        .infer(Request {
            id: 0,
            input: input.clone(),
        })
        .unwrap();
    let b = server.infer(Request { id: 1, input }).unwrap();
    assert_eq!(a.output, b.output, "PJRT execution must be deterministic");
    server.shutdown().unwrap();
}
