//! Integration tests for the unified `Engine`/`ExecutionBackend` API and
//! the multi-worker batched `ServerPool`:
//!
//! * builder validation errors,
//! * cross-backend agreement (analytical vs cycle-level simulator),
//! * pool ordering/backpressure under concurrent submitters,
//! * clean shutdown with in-flight batches,
//! * the acceptance check: ≥ 4 workers serving ≥ 100 requests with
//!   per-request responses matching the single-worker path.

use std::sync::Arc;
use std::time::Duration;
use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::coordinator::pool::{PoolConfig, ServerPool};
use unzipfpga::coordinator::plan::InferencePlan;
use unzipfpga::coordinator::server::Request;
use unzipfpga::engine::{BackendKind, Engine};
use unzipfpga::workload::{resnet, squeezenet, RatioProfile};
use unzipfpga::Error;

fn builder() -> unzipfpga::engine::EngineBuilder {
    let net = resnet::resnet18();
    let profile = RatioProfile::ovsf50(&net);
    Engine::builder()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(DesignPoint::new(64, 64, 16, 48))
        .network(net)
        .profile(profile)
}

fn plan() -> InferencePlan {
    builder().plan().unwrap().schedule
}

#[test]
fn builder_validation_errors() {
    // Missing network.
    let err = Engine::builder().build().err().expect("network is required");
    assert!(matches!(err, Error::InvalidConfig(_)), "{err}");

    // Profile/network length mismatch.
    let net = resnet::resnet18();
    let wrong = RatioProfile::ovsf50(&squeezenet::squeezenet1_1());
    let err = Engine::builder()
        .network(net.clone())
        .profile(wrong)
        .build()
        .err()
        .expect("mismatched profile");
    assert!(err.to_string().contains("entries"), "{err}");

    // Zero bandwidth.
    let err = Engine::builder()
        .network(net.clone())
        .bandwidth(0)
        .build()
        .err()
        .expect("bw 0");
    assert!(matches!(err, Error::InvalidConfig(_)));

    // Bandwidth beyond the platform peak.
    let err = Engine::builder()
        .platform(Platform::z7045())
        .bandwidth(99)
        .network(net.clone())
        .build()
        .err()
        .expect("bw beyond peak");
    assert!(err.to_string().contains("peak"), "{err}");

    // A wgen-less design point cannot serve an OVSF profile.
    let err = Engine::builder()
        .network(net.clone())
        .design_point(DesignPoint::new(0, 64, 16, 48))
        .build()
        .err()
        .expect("no wgen");
    assert!(err.to_string().contains("CNN-WGen"), "{err}");

    // Degenerate tile sizes.
    let err = Engine::builder()
        .network(net)
        .design_point(DesignPoint::new(64, 0, 16, 48))
        .build()
        .err()
        .expect("degenerate sigma");
    assert!(matches!(err, Error::InvalidConfig(_)));
}

#[test]
fn cross_backend_agreement_on_resnet18() {
    // The simulator walks the same schedules the closed forms describe:
    // totals agree within DMA burst rounding (< 1%), layer by layer.
    let mut ana = builder().backend(BackendKind::Analytical).build().unwrap();
    let mut sim = builder().backend(BackendKind::Simulator).build().unwrap();
    let ra = ana.infer_timing().unwrap();
    let rs = sim.infer_timing().unwrap();
    assert_eq!(ra.layers.len(), rs.layers.len());
    let rel = (ra.total_cycles - rs.total_cycles).abs() / ra.total_cycles;
    assert!(
        rel < 0.01,
        "backends disagree: analytical {} vs simulator {} ({rel:.4})",
        ra.total_cycles,
        rs.total_cycles
    );
    for (a, s) in ra.layers.iter().zip(&rs.layers) {
        assert_eq!(a.name, s.name);
        let lrel = (a.cycles - s.cycles).abs() / a.cycles.max(1.0);
        assert!(lrel < 0.02, "{}: {} vs {} ({lrel:.4})", a.name, a.cycles, s.cycles);
    }
}

#[test]
fn pool_ordering_under_concurrent_submitters() {
    // Many submitter threads against a small bounded queue: every request
    // is served exactly once with its own id, and a single worker preserves
    // FIFO order per submission (ids are unique across submitters).
    let cfg = PoolConfig {
        workers: 1,
        queue_depth: 4,
        max_batch: 2,
        linger: Duration::from_micros(200),
        slo: None,
        ..PoolConfig::default()
    };
    let pool = Arc::new(
        ServerPool::start(plan(), cfg, |_| |req: &Request| vec![req.id as f32 * 2.0]).unwrap(),
    );
    let mut joins = Vec::new();
    for t in 0..8u64 {
        let pool = Arc::clone(&pool);
        joins.push(std::thread::spawn(move || {
            let mut got = Vec::new();
            for i in 0..20u64 {
                let id = t * 100 + i;
                let resp = pool.submit(Request::timing(id)).unwrap().wait().unwrap();
                assert_eq!(resp.id, id);
                assert_eq!(resp.output, vec![id as f32 * 2.0]);
                got.push(resp.id);
            }
            got
        }));
    }
    let mut all = Vec::new();
    for j in joins {
        all.extend(j.join().unwrap());
    }
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len(), 160, "each request served exactly once");
    let pool = Arc::into_inner(pool).expect("all submitters joined");
    let pm = pool.shutdown().unwrap();
    assert_eq!(pm.total_requests(), 160);
}

#[test]
fn clean_shutdown_with_in_flight_batches() {
    let cfg = PoolConfig {
        workers: 3,
        queue_depth: 128,
        max_batch: 8,
        linger: Duration::from_millis(2),
        slo: None,
        ..PoolConfig::default()
    };
    let pool = ServerPool::start(plan(), cfg, |_| {
        |req: &Request| {
            std::thread::sleep(Duration::from_millis(1));
            vec![req.id as f32]
        }
    })
    .unwrap();
    let handles: Vec<_> = (0..60u64)
        .map(|id| pool.submit(Request::timing(id)).unwrap())
        .collect();
    // Shut down while batches are still in flight: every accepted request
    // must complete, none may hang or be dropped.
    let pm = pool.shutdown().unwrap();
    assert_eq!(pm.panicked_workers, 0);
    assert_eq!(pm.total_requests(), 60);
    for (id, h) in handles.into_iter().enumerate() {
        let resp = h.wait().unwrap();
        assert_eq!(resp.id, id as u64);
        assert_eq!(resp.output, vec![id as f32]);
    }
}

/// Acceptance: a ≥4-worker pool serving ≥100 requests produces, per
/// request, exactly the response the single-worker path produces.
#[test]
fn multi_worker_pool_matches_single_worker_path() {
    fn executor(_worker: usize) -> impl FnMut(&Request) -> Vec<f32> {
        // Deterministic function of the request.
        |req: &Request| vec![req.id as f32, (req.id * 7 % 13) as f32]
    }
    let n_req = 120u64;

    // Reference: single worker, batch 1.
    let single = ServerPool::start(plan(), PoolConfig::single_worker(), executor).unwrap();
    let mut expect = Vec::new();
    for id in 0..n_req {
        let resp = single.submit(Request::timing(id)).unwrap().wait().unwrap();
        expect.push((resp.id, resp.output));
    }
    single.shutdown().unwrap();

    // Subject: 4 workers, batched.
    let cfg = PoolConfig {
        workers: 4,
        queue_depth: 32,
        max_batch: 8,
        linger: Duration::from_micros(500),
        slo: None,
        ..PoolConfig::default()
    };
    let pool = ServerPool::start(plan(), cfg, executor).unwrap();
    let handles: Vec<_> = (0..n_req)
        .map(|id| pool.submit(Request::timing(id)).unwrap())
        .collect();
    let mut got: Vec<(u64, Vec<f32>)> = handles
        .into_iter()
        .map(|h| {
            let r = h.wait().unwrap();
            (r.id, r.output)
        })
        .collect();
    let pm = pool.shutdown().unwrap();

    got.sort_by_key(|(id, _)| *id);
    assert_eq!(got, expect, "multi-worker responses diverge from single-worker");
    assert_eq!(pm.total_requests(), n_req as usize);
    assert_eq!(pm.per_worker.len(), 4);
}

/// The same acceptance shape through the Engine facade: an engine-backed
/// pool (analytical backend per worker) serves timing-only requests whose
/// device latency matches a directly-built engine's report.
#[test]
fn engine_pool_serves_through_unified_api() {
    let mut reference = builder().backend(BackendKind::Analytical).build().unwrap();
    let expect_latency = reference.infer_timing().unwrap().latency_s;

    let pool = builder()
        .backend(BackendKind::Analytical)
        .build_pool(PoolConfig {
            workers: 4,
            queue_depth: 64,
            max_batch: 8,
            linger: Duration::from_micros(500),
            slo: None,
            ..PoolConfig::default()
        })
        .unwrap();
    let handles: Vec<_> = (0..100u64)
        .map(|id| pool.submit(Request::timing(id)).unwrap())
        .collect();
    for (id, h) in handles.into_iter().enumerate() {
        let resp = h.wait().unwrap();
        assert_eq!(resp.id, id as u64);
        assert!(resp.output.is_empty(), "analytical backend is timing-only");
        assert_eq!(
            resp.model, "ResNet18",
            "default route resolves to the pool's sole registered model"
        );
        assert!(
            (resp.device_latency_s - expect_latency).abs() < 1e-9 * expect_latency,
            "pool device latency {} != engine latency {}",
            resp.device_latency_s,
            expect_latency
        );
    }
    let pm = pool.shutdown().unwrap();
    assert_eq!(pm.total_requests(), 100);
    let merged = pm.merged();
    assert_eq!(
        merged.model_count("ResNet18"),
        100,
        "per-model metrics attribute every request to the routed model"
    );
    assert_eq!(pm.model_switches(), 0, "one model ⇒ no switches");
}
