//! Seeded replica-failover soak: kill one of N replicas mid-stream and
//! hold the replicated-serving claims:
//!
//! * **Every request settles typed-or-correct** — a request caught on the
//!   dying replica either completes (rescued by a hedge leg) or fails with
//!   a typed error; nothing hangs, nothing is silently dropped.
//! * **Bit-identical numerics** — every successful response equals the
//!   single-engine reference output, across replicas, across the outage,
//!   and across supervisor rebuilds ([`CompiledModel::respin`] is
//!   deterministic).
//! * **Capacity is restored** — the supervisor notices the dead replica
//!   (restart budget exhausted, live workers below configured), rebuilds
//!   it from the model catalog, and returns the set to N live replicas.
//! * **Administrative drain/rejoin loses nothing** — a drain → rejoin
//!   cycle under open-loop traffic completes with zero failed requests.
//!
//! Set `CHAOS_SOAK=1` for a longer run (CI does); the default is sized
//! for the regular test suite.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::coordinator::pool::PoolConfig;
use unzipfpga::coordinator::registry::BackendWrap;
use unzipfpga::coordinator::replica::{
    HedgePolicy, ReplicaConfig, ReplicaSet, ReplicaState,
};
use unzipfpga::coordinator::server::Request;
use unzipfpga::coordinator::traffic::{ArrivalProcess, RequestClass, TrafficSpec};
use unzipfpga::engine::fault::{FaultPlan, FaultyBackend};
use unzipfpga::engine::{
    BackendKind, CompiledModel, Engine, EnginePlan, ExecutionBackend, ExecutionReport,
    LayerOutcome, Precision, SlabCache,
};
use unzipfpga::error::Result;
use unzipfpga::util::prng::Xoshiro256;
use unzipfpga::workload::{Layer, Network, RatioProfile};

fn soak() -> bool {
    std::env::var("CHAOS_SOAK").as_deref() == Ok("1")
}

fn tiny_plan(name: &str) -> EnginePlan {
    let net = Network {
        name: name.into(),
        layers: vec![
            Layer::conv("stem", 8, 8, 4, 8, 3, 1, 1, false),
            Layer::conv("c1", 8, 8, 8, 8, 3, 1, 1, true),
        ],
    };
    let profile = RatioProfile::uniform(&net, 0.5);
    Engine::builder()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(DesignPoint::new(8, 4, 8, 4))
        .network(net)
        .profile(profile)
        .plan()
        .unwrap()
}

fn compiled(name: &str) -> CompiledModel {
    CompiledModel::from_plan_at(tiny_plan(name), Precision::F32).unwrap()
}

fn input() -> Vec<f32> {
    Xoshiro256::seed_from_u64(11).normal_vec(8 * 8 * 4)
}

/// The fault-free single-engine output the replicated path must match
/// bit-for-bit.
fn reference_output() -> Vec<f32> {
    let proto = Arc::new(compiled("tiny"));
    let mut engine =
        Engine::from_compiled(&proto, &BackendKind::Simulator, &Arc::new(SlabCache::new()))
            .unwrap();
    engine.infer(&input()).unwrap().output
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "timed out waiting for {what}"
        );
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Backend decorator that panics on the next execution once armed — the
/// deterministic "pull the plug on this replica" lever.
struct KillSwitch {
    inner: Box<dyn ExecutionBackend>,
    armed: Arc<AtomicBool>,
}

impl ExecutionBackend for KillSwitch {
    fn name(&self) -> &'static str {
        "kill-switch"
    }

    fn plan(&mut self, plan: &EnginePlan) -> Result<()> {
        self.inner.plan(plan)
    }

    fn preload(&mut self, model: &Arc<CompiledModel>) -> Result<()> {
        self.inner.preload(model)
    }

    fn execute_layer(&mut self, idx: usize, input: &[f32]) -> Result<LayerOutcome> {
        if self.armed.load(Ordering::SeqCst) {
            panic!("kill switch fired");
        }
        self.inner.execute_layer(idx, input)
    }

    fn finish(&mut self) -> Result<ExecutionReport> {
        self.inner.finish()
    }
}

/// The headline acceptance soak: arm a kill switch on replica 0, burst
/// requests through the set while its sole worker dies with an exhausted
/// restart budget, and require every burst request to complete with the
/// reference numerics — requests caught on the dying replica are rescued
/// by failover hedges, later arrivals spill past the closed queue at
/// dispatch. Then the supervisor restores all three replicas.
#[test]
fn replica_kill_mid_stream_settles_every_request_bit_identically() {
    let n_steady = if soak() { 60 } else { 12 };
    let n_burst = if soak() { 120 } else { 24 };

    let mut cfg = ReplicaConfig::new(3);
    cfg.pool = PoolConfig::single_worker();
    // A single panic permanently kills the replica's sole worker: the
    // outage is unrecoverable below the replica layer by construction.
    cfg.pool.restart_budget = 0;
    cfg.pool.retries = 0;
    cfg.health.supervisor_tick = Duration::from_millis(2);
    cfg.hedge = Some(HedgePolicy {
        deadline_fraction: 0.25,
        min_wait: Duration::from_millis(1),
    });

    let armed = Arc::new(AtomicBool::new(false));
    let armed_in_wrap = Arc::clone(&armed);
    let wrap: BackendWrap = Arc::new(move |backend, _worker| {
        Box::new(KillSwitch {
            inner: backend,
            armed: Arc::clone(&armed_in_wrap),
        })
    });
    let set = ReplicaSet::start_with_wraps(cfg, vec![Some(wrap), None, None]).unwrap();
    set.register_model("tiny", compiled("tiny")).unwrap();
    let want = reference_output();
    assert!(!want.is_empty());

    // Phase A — steady state: every response matches the reference.
    for i in 0..n_steady as u64 {
        let r = set
            .submit(Request::for_model(i, "tiny", input()))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.output, want, "steady-state request {i} diverged");
    }
    assert_eq!(set.hedges(), 0, "no hedges while all replicas are healthy");

    // Phase B — the outage: arm, then burst. All queues are empty, so the
    // rotation tie-break routes one of the first dispatches to replica 0,
    // whose first execution panics and (budget 0) closes its queue:
    // requests queued there settle typed and re-dispatch as failover
    // hedges; later arrivals spill past the closed queue at submission.
    armed.store(true, Ordering::SeqCst);
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_burst as u64)
        .map(|i| {
            set.submit(Request::for_model(1000 + i, "tiny", input()))
                .unwrap()
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.wait().unwrap_or_else(|e| {
            panic!("burst request {i} must be rescued, got typed error: {e}")
        });
        assert_eq!(r.output, want, "burst request {i} diverged mid-outage");
    }
    let outage_wall = t0.elapsed();
    assert!(
        outage_wall < Duration::from_secs(20),
        "outage burst settled too slowly ({outage_wall:?}) — hedges must \
         bound the tail, not wait out the dead replica"
    );
    assert!(
        set.hedges() >= 1,
        "at least one request must have been rescued off the dead replica"
    );
    assert!(set.hedge_wins() >= 1, "a hedge leg must have won");

    // Phase C — recovery: disarm, let the supervisor rebuild replica 0
    // from the catalog, and require full capacity plus intact numerics.
    armed.store(false, Ordering::SeqCst);
    wait_until("supervisor to restore 3 live replicas", || {
        set.rebuilds() >= 1
            && set.live_replicas() == 3
            && set.states()[0] == ReplicaState::Healthy
    });
    for i in 0..n_steady as u64 {
        let r = set
            .submit(Request::for_model(2000 + i, "tiny", input()))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.output, want, "post-recovery request {i} diverged");
    }
    assert!(
        set.states().iter().all(|s| *s == ReplicaState::Healthy),
        "{:?}",
        set.states()
    );

    let m = set.shutdown().unwrap();
    assert!(m.rebuilds >= 1, "the outage must have forced a rebuild");
    assert!(
        m.panicked_workers() >= 1,
        "the kill switch's panic must survive into the retired metrics"
    );
    assert!(!m.retired.is_empty());
}

/// Administrative drain → rejoin cycles under open-loop traffic: the
/// quiesce must lose zero requests and shed nothing (the other replica
/// keeps the set above the degraded-mode floor).
#[test]
fn drain_rejoin_under_load_completes_with_zero_failures() {
    let duration_s = if soak() { 1.2 } else { 0.4 };
    let mut cfg = ReplicaConfig::new(2);
    cfg.health.supervisor_tick = Duration::from_millis(2);
    let set = ReplicaSet::start(cfg).unwrap();
    set.register_model("tiny", compiled("tiny")).unwrap();

    let spec = TrafficSpec {
        process: ArrivalProcess::Bursty {
            base_rps: 300.0,
            burst_rps: 900.0,
            mean_on_s: 0.05,
            mean_off_s: 0.1,
        },
        duration_s,
        seed: 77,
        classes: vec![RequestClass::timing("tiny")],
    };
    let report = std::thread::scope(|s| {
        let set_ref = &set;
        let stream = s.spawn(move || spec.run_open_loop(set_ref));
        for cycle in 0..2 {
            std::thread::sleep(Duration::from_secs_f64(duration_s / 6.0));
            set_ref
                .drain(0, Duration::from_secs(10))
                .unwrap_or_else(|e| panic!("drain cycle {cycle} failed: {e}"));
            assert_eq!(set_ref.states()[0], ReplicaState::Drained);
            assert_eq!(set_ref.live_replicas(), 1);
            std::thread::sleep(Duration::from_millis(10));
            set_ref.rejoin(0).unwrap();
            assert_eq!(set_ref.live_replicas(), 2);
        }
        stream.join().expect("traffic thread must survive")
    });

    assert_eq!(
        report.offered,
        report.submitted + report.shed + report.queue_full + report.expired + report.failed,
        "every arrival must be accounted: {}",
        report.summary()
    );
    assert!(report.completed > 0, "{}", report.summary());
    assert_eq!(report.failed, 0, "drain/rejoin must fail zero requests");
    assert_eq!(report.completed, report.submitted, "nothing admitted is lost");
    assert_eq!(report.shed, 0, "one live replica keeps admission open");
    assert_eq!(report.expired, 0);
    assert_eq!(report.harness_failures, 0);

    let m = set.shutdown().unwrap();
    assert_eq!(m.rebuilds, 0, "administrative drain is not a failure");
    assert_eq!(m.degraded_shed, 0);
}

/// Seeded chaos across *all* replicas with per-replica decorrelated fault
/// schedules ([`FaultPlan::for_replica`]): transient errors, latency
/// spikes and occasional worker panics. The accounting identity holds over
/// an open-loop stream and the supervisor ends the run at full capacity.
#[test]
fn decorrelated_chaos_soak_accounts_every_arrival_and_recovers_capacity() {
    let duration_s = if soak() { 2.0 } else { 0.5 };
    let replicas = 3;
    let mut cfg = ReplicaConfig::new(replicas);
    cfg.pool.workers = 2;
    cfg.pool.retries = 1;
    cfg.pool.restart_budget = 2;
    cfg.health.supervisor_tick = Duration::from_millis(2);
    cfg.hedge = Some(HedgePolicy::default());

    let base = FaultPlan {
        seed: 2026,
        transient: 0.04,
        panic_p: 0.01,
        latency_spike: 0.05,
        spike: Duration::from_micros(300),
        ..FaultPlan::none()
    };
    let wraps: Vec<Option<BackendWrap>> = (0..replicas)
        .map(|r| {
            let plan = base.clone().for_replica(r);
            let wrap: BackendWrap = Arc::new(move |backend, worker| {
                Box::new(FaultyBackend::new(backend, plan.clone().for_worker(worker)))
            });
            Some(wrap)
        })
        .collect();
    let set = ReplicaSet::start_with_wraps(cfg, wraps).unwrap();
    set.register_model("tiny", compiled("tiny")).unwrap();

    let spec = TrafficSpec {
        process: ArrivalProcess::Poisson { rate_rps: 400.0 },
        duration_s,
        seed: 4242,
        classes: vec![RequestClass::timing("tiny").with_input(input())],
    };
    let report = spec.run_open_loop(&set);

    assert_eq!(
        report.offered,
        report.submitted + report.shed + report.queue_full + report.expired + report.failed,
        "every arrival must be accounted: {}",
        report.summary()
    );
    assert_eq!(report.harness_failures, 0, "collector must survive chaos");
    assert!(
        report.completed > report.offered / 2,
        "most requests must survive light chaos: {}",
        report.summary()
    );

    // Whatever the chaos killed, the supervisor must restore.
    wait_until("supervisor to restore full capacity", || {
        set.live_replicas() == replicas
    });
    let m = set.shutdown().unwrap();
    let merged = m.merged();
    assert!(merged.count() > 0, "merged metrics must cover the stream");
}
