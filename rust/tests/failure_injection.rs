//! Failure injection: the runtime and coordinator must fail loudly and
//! cleanly on corrupted artifacts, bad shapes and dead workers — the
//! operational half of "production-quality".

use std::io::Write;
use unzipfpga::runtime::{ArtifactRegistry, LoadedExecutable, RuntimeClient};

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("unzipfpga-failtest-{tag}"));
    let _ = std::fs::create_dir_all(&d);
    d
}

#[test]
fn truncated_hlo_text_is_rejected() {
    let dir = tmp_dir("trunc");
    let src = unzipfpga::runtime::artifacts_dir().join("ovsf_wgen.hlo.txt");
    if !src.exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let text = std::fs::read_to_string(&src).unwrap();
    let path = dir.join("broken.hlo.txt");
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(&text.as_bytes()[..text.len() / 2]).unwrap();
    drop(f);
    let client = RuntimeClient::cpu().unwrap();
    assert!(
        LoadedExecutable::load(&client, &path).is_err(),
        "half an HLO module must not compile"
    );
}

#[test]
fn garbage_file_is_rejected() {
    let dir = tmp_dir("garbage");
    let path = dir.join("garbage.hlo.txt");
    std::fs::write(&path, "this is not an HLO module at all {{{").unwrap();
    let client = RuntimeClient::cpu().unwrap();
    assert!(LoadedExecutable::load(&client, &path).is_err());
}

#[test]
fn wrong_input_arity_is_an_error_not_a_crash() {
    let dir = unzipfpga::runtime::artifacts_dir();
    if !dir.join("gemm.hlo.txt").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let mut reg = ArtifactRegistry::new(dir).unwrap();
    let Ok(exe) = reg.get("gemm") else {
        eprintln!("SKIP: PJRT unavailable — build with `--features pjrt`");
        return;
    };
    // gemm expects two buffers; give it one.
    let a = vec![0.0f32; 64 * 144];
    let r = exe.run_f32(&[(&a, &[64, 144])]);
    assert!(r.is_err(), "arity mismatch must surface as Err");
}

#[test]
fn registry_missing_artifact_error_is_actionable() {
    let dir = tmp_dir("empty-registry");
    let mut reg = ArtifactRegistry::new(dir).unwrap();
    let err = reg.get("never-built").err().expect("must fail");
    assert!(err.to_string().contains("make artifacts"));
}

#[test]
fn pool_survives_panicking_worker_shutdown() {
    use unzipfpga::arch::{DesignPoint, Platform};
    use unzipfpga::coordinator::pool::{PoolConfig, ServerPool};
    use unzipfpga::coordinator::plan::InferencePlan;
    use unzipfpga::coordinator::server::Request;
    use unzipfpga::workload::{resnet, RatioProfile};
    use unzipfpga::Error;

    let net = resnet::resnet18();
    let profile = RatioProfile::ovsf50(&net);
    let plan = InferencePlan::build(
        &Platform::z7045(),
        4,
        DesignPoint::new(64, 64, 16, 48),
        &net,
        &profile,
    );
    // The single worker panics on request id 3.
    let pool = ServerPool::start(plan, PoolConfig::single_worker(), |_worker| {
        |req: &Request| {
            if req.id == 3 {
                panic!("injected worker failure");
            }
            vec![req.id as f32]
        }
    })
    .unwrap();
    for id in 0..3u64 {
        assert!(pool.submit(Request::timing(id)).unwrap().wait().is_ok());
    }
    // The poisoned request fails with the typed panic error — not a hang,
    // not an opaque disconnect.
    let err = pool
        .submit(Request::timing(3))
        .unwrap()
        .wait()
        .err()
        .expect("panicking request must surface as Err");
    assert!(
        matches!(err, Error::WorkerPanic { .. }),
        "expected WorkerPanic, got: {err}"
    );
    // Supervision: the panic consumed one worker thread, the supervisor
    // respawned a replacement, and later requests are served normally.
    for id in 4..8u64 {
        let resp = pool.submit(Request::timing(id)).unwrap().wait().unwrap();
        assert_eq!(resp.output, vec![id as f32]);
    }
    assert_eq!(pool.live_workers(), 1, "capacity restored after respawn");
    let pm = pool.shutdown().expect("respawned pool shuts down cleanly");
    assert_eq!(pm.panicked_workers, 1);
    assert_eq!(pm.worker_restarts, 1);
    assert_eq!(pm.total_requests(), 7, "3 before + 4 after the panic");
}

#[test]
fn dse_with_empty_grid_is_clean_error() {
    use unzipfpga::dse::search::{optimise, DseConfig};
    use unzipfpga::workload::{resnet, RatioProfile};

    let net = resnet::resnet18();
    let profile = RatioProfile::ovsf50(&net);
    let cfg = DseConfig {
        m: vec![],
        t_r: vec![64],
        t_p: vec![16],
        t_c: vec![48],
        threads: 2,
    };
    let r = optimise(&cfg, &unzipfpga::arch::Platform::z7045(), 4, &net, &profile, true);
    assert!(matches!(
        r,
        Err(unzipfpga::Error::NoFeasibleDesign { .. })
    ));
}
