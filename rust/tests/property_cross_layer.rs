//! Cross-module property tests: invariants that span ovsf ↔ sim ↔ perf ↔
//! dse — the coordinator-level guarantees of the system.

use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::ovsf::basis::{select, BasisSelection};
use unzipfpga::ovsf::codes::OvsfBasis;
use unzipfpga::ovsf::regress::{project, reconstruct_vec};
use unzipfpga::perf::model::PerfModel;
use unzipfpga::sim::hw_weights::HwOvsfWeights;
use unzipfpga::sim::wgen::WGenSim;
use unzipfpga::util::check::forall;
use unzipfpga::workload::layer::Layer;
use unzipfpga::workload::{resnet, RatioProfile};

/// TiWGen's generated weights are invariant to the design point σ — tiling
/// must never change numerics, only scheduling.
#[test]
fn wgen_numerics_invariant_to_tiling() {
    forall("wgen-tiling-invariance", 12, |rng| {
        let w = HwOvsfWeights::random(rng, 8, 4, 3, 0.5).unwrap();
        let s1 = DesignPoint::new(8, 16, 4, 4);
        let s2 = DesignPoint::new(64, 16, 16, 8);
        let r1 = WGenSim::new(&s1, &w).generate();
        let r2 = WGenSim::new(&s2, &w).generate();
        assert_eq!(r1.weights.len(), r2.weights.len());
        for (a, b) in r1.weights.iter().zip(&r2.weights) {
            assert!((a - b).abs() < 1e-5, "tiling changed numerics: {a} vs {b}");
        }
    });
}

/// Parseval-style consistency: energy of the α vector × L equals the
/// energy of the reconstructed vector (orthogonal basis).
#[test]
fn alpha_energy_matches_reconstruction_energy() {
    forall("parseval", 24, |rng| {
        let l = 1usize << rng.gen_range(2, 6);
        let basis = OvsfBasis::new(l).unwrap();
        let target = rng.normal_vec(l);
        let alphas = project(&basis, &target);
        let sel = select(BasisSelection::Sequential, &basis, &alphas, 1.0);
        let recon = reconstruct_vec(&basis, &sel);
        let e_alpha: f64 = alphas.iter().map(|&a| (a as f64).powi(2)).sum::<f64>() * l as f64;
        let e_recon: f64 = recon.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!(
            (e_alpha - e_recon).abs() < 1e-3 * e_recon.max(1.0),
            "Parseval violated: {e_alpha} vs {e_recon}"
        );
    });
}

/// Raising any single layer's ρ never *improves* throughput (wgen only
/// gets slower) — the monotonicity the autotuner's ceiling search relies on.
#[test]
fn throughput_monotone_nonincreasing_in_rho() {
    forall("rho-monotonicity", 16, |rng| {
        let net = resnet::resnet18();
        let plat = Platform::z7045();
        let model = PerfModel::new(plat, *rng.choose(&[1u32, 2, 4]));
        let sigma = DesignPoint::new(
            1 << rng.gen_range(4, 7),
            64,
            16,
            1 << rng.gen_range(4, 6),
        );
        let mut profile = RatioProfile::ovsf25(&net);
        let before = model.network_perf(&sigma, &net, &profile).inf_per_s;
        // Raise one random OVSF layer's ρ.
        let ovsf_layers: Vec<usize> = net
            .layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.ovsf)
            .map(|(i, _)| i)
            .collect();
        let pick = *rng.choose(&ovsf_layers);
        profile.rhos[pick] = 1.0;
        let after = model.network_perf(&sigma, &net, &profile).inf_per_s;
        assert!(
            after <= before * 1.0001,
            "raising ρ sped things up: {before} → {after}"
        );
    });
}

/// The II decomposition is consistent: total cycles of a layer are bounded
/// by II × tiles (exactly equal when the layer tiles evenly — edge row and
/// column strips are cheaper), and II is attained by at least one stage.
#[test]
fn ii_decomposition_consistent() {
    forall("ii-decomposition", 24, |rng| {
        let plat = Platform::z7045();
        let model = PerfModel::new(plat, *rng.choose(&[1u32, 2, 4]));
        let sigma = DesignPoint::new(
            1 << rng.gen_range(3, 7),
            1 << rng.gen_range(4, 8),
            1 << rng.gen_range(2, 5),
            1 << rng.gen_range(3, 7),
        );
        let layer = Layer::conv(
            "t",
            rng.gen_range(7, 56),
            rng.gen_range(7, 56),
            1 << rng.gen_range(4, 8),
            1 << rng.gen_range(4, 8),
            3,
            1,
            1,
            true,
        );
        let p = model.layer_perf(
            &sigma,
            &layer,
            unzipfpga::perf::model::WeightsSource::OnTheFly { rho: 0.5 },
        );
        assert!(p.total_cycles <= p.ii * p.tiles as f64 + 1e-6);
        assert!(p.total_cycles > 0.0);
        let g = layer.gemm();
        let tiles_evenly =
            g.r % sigma.t_r == 0 && (g.c % sigma.t_c == 0 || g.c < sigma.t_c);
        if tiles_evenly {
            assert!((p.total_cycles - p.ii * p.tiles as f64).abs() < 1e-6);
        }
        let stages = [p.t_mem_in, p.t_wgen, p.t_eng, p.t_mem_out];
        assert!(stages.iter().any(|&s| (s - p.ii).abs() < 1e-9));
        assert!(stages.iter().all(|&s| s <= p.ii + 1e-9));
    });
}

/// Fixed-point quantisation of α (the 16-bit hardware datapath) perturbs
/// TiWGen-generated weights by at most n_basis · step/2 per weight.
#[test]
fn quantised_alphas_bound_weight_error() {
    use unzipfpga::util::fixed::QFormat;
    forall("fixed-point-error-bound", 12, |rng| {
        let w = HwOvsfWeights::random(rng, 4, 4, 3, 0.5).unwrap();
        let mut wq = w.clone();
        let fmt = QFormat::Q16;
        for a in wq.alphas.iter_mut() {
            *a = fmt.quantise(*a);
        }
        let sigma = DesignPoint::new(16, 16, 8, 4);
        let exact = WGenSim::new(&sigma, &w).generate();
        let quant = WGenSim::new(&sigma, &wq).generate();
        let bound = w.n_basis as f32 * fmt.step() / 2.0 + 1e-5;
        for (a, b) in exact.weights.iter().zip(&quant.weights) {
            assert!(
                (a - b).abs() <= bound,
                "quantisation error {} exceeds bound {bound}",
                (a - b).abs()
            );
        }
    });
}

/// Compressed parameter accounting is consistent between the profile
/// arithmetic and the per-layer hardware form.
#[test]
fn alpha_counts_agree_across_modules() {
    forall("alpha-count-agreement", 10, |rng| {
        let n_in = 1usize << rng.gen_range(2, 5);
        let n_out = 1usize << rng.gen_range(2, 5);
        let rho = *rng.choose(&[0.125, 0.25, 0.5, 1.0]);
        let hw = HwOvsfWeights::random(rng, n_out, n_in, 3, rho).unwrap();
        let layer = Layer::conv("x", 14, 14, n_in as u64, n_out as u64, 3, 1, 1, true);
        assert_eq!(hw.n_alphas() as u64, layer.params_with_rho(rho));
    });
}

/// The OVSF generator's FIFO/aligner bit stream drives a TiWGen-equivalent
/// accumulation that must equal WGenSim's weights — tying the rate-matching
/// hardware model (§4.2.2) into the generation schedule (Alg. 1). Holds
/// when T_P is chunk-aligned (the aligner's single-shift regime).
#[test]
fn fifo_aligner_stream_reproduces_tiwgen_weights() {
    use unzipfpga::sim::ovsf_gen::OvsfGenerator;
    forall("fifo-drives-tiwgen", 10, |rng| {
        // K=4 (chunk=16), T_P multiple of 16 → pure periodic stream.
        let n_out = 4usize;
        let n_in = 2usize;
        let k = 4usize;
        let chunk = 16usize;
        let nb = [2usize, 4, 8][rng.gen_range(0, 2) as usize];
        let m = [8usize, 16, 48][rng.gen_range(0, 2) as usize];
        let t_p = 16u64;
        let t_c = n_out as u64;
        let mut w =
            unzipfpga::sim::hw_weights::HwOvsfWeights::random(rng, n_out, n_in, k, 1.0).unwrap();
        // Truncate to nb basis vectors.
        let mut alphas = Vec::new();
        for o in 0..n_out {
            for c in 0..n_in {
                for j in 0..nb {
                    alphas.push(w.alpha(o, c, j));
                }
            }
        }
        w.n_basis = nb;
        w.alphas = alphas;
        let sigma = DesignPoint::new(m as u64, 16, t_p, t_c);
        let expect = WGenSim::new(&sigma, &w).generate();

        // Re-generate by streaming bits from the FIFO/aligner.
        let basis = OvsfBasis::new(chunk).unwrap();
        let p_dim = w.p_dim();
        let mut weights = vec![0.0f32; p_dim * n_out];
        let p_tiles = (p_dim as u64).div_ceil(t_p);
        let subtiles = sigma.subtiles_per_tile();
        let mut gen = OvsfGenerator::new(&basis, nb, m);
        let mut buf = Vec::with_capacity(m);
        for t in 0..p_tiles {
            for i in 0..subtiles {
                for j in 0..nb {
                    gen.emit_into(&mut buf);
                    for (e, &sign) in buf.iter().enumerate() {
                        let g = (i as usize) * m + e;
                        if g >= (t_p * t_c) as usize {
                            break;
                        }
                        let o = g / t_p as usize;
                        let p = (t as usize) * t_p as usize + g % t_p as usize;
                        if o >= n_out || p >= p_dim {
                            continue;
                        }
                        let c = p / chunk;
                        weights[p * n_out + o] += w.alpha(o, c, j) * sign as f32;
                    }
                }
            }
        }
        for (i, (a, b)) in weights.iter().zip(&expect.weights).enumerate() {
            assert!(
                (a - b).abs() < 1e-4,
                "idx {i}: FIFO-stream {a} vs TiWGen {b} (M={m}, nb={nb})"
            );
        }
    });
}

/// Simulator ≡ analytical model on RANDOM layer shapes and design points —
/// not just the benchmark networks.
#[test]
fn sim_equals_model_on_random_layers() {
    use unzipfpga::sim::engine::LayerSim;
    forall("sim-vs-model-random", 30, |rng| {
        let plat = Platform::z7045();
        let bw = *rng.choose(&[1u32, 2, 4]);
        let sigma = DesignPoint::new(
            1 << rng.gen_range(3, 8),
            1 << rng.gen_range(4, 9),
            1 << rng.gen_range(2, 6),
            1 << rng.gen_range(3, 8),
        );
        let layer = Layer::conv(
            "rand",
            rng.gen_range(7, 120),
            rng.gen_range(7, 120),
            1 << rng.gen_range(3, 9),
            rng.gen_range(8, 600),
            *rng.choose(&[1u64, 3]),
            *rng.choose(&[1u64, 2]),
            1,
            true,
        );
        let rho = *rng.choose(&[0.25, 0.5, 1.0]);
        let model = PerfModel::new(plat.clone(), bw);
        let perf = model.layer_perf(
            &sigma,
            &layer,
            unzipfpga::perf::model::WeightsSource::OnTheFly { rho },
        );
        let sim = LayerSim::new(&sigma, &plat, bw);
        let wgen_cycles = layer.basis_per_chunk(rho)
            * sigma.subtiles_per_tile()
            * unzipfpga::util::ceil_div(layer.gemm().p, sigma.t_p);
        let trace = sim.run_timing(&layer, Some(wgen_cycles));
        let rel = (trace.total_cycles as f64 - perf.total_cycles).abs()
            / perf.total_cycles.max(1.0);
        assert!(
            rel < 0.02,
            "sim {} vs model {} ({rel:.4}) at {sigma}, layer {:?}",
            trace.total_cycles,
            perf.total_cycles,
            layer.gemm()
        );
    });
}
