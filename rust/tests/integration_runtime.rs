//! Integration: the PJRT runtime executes the AOT artifacts and its
//! numerics agree with BOTH the Python oracle (reference vectors emitted
//! by `aot.py`) and the rust cycle-level TiWGen simulator — the three-layer
//! agreement at the heart of the reproduction.
//!
//! These tests need `make artifacts`; they skip (pass vacuously, loudly)
//! when the artifacts are absent so `cargo test` works pre-AOT.

use unzipfpga::runtime::{artifacts_dir, ArtifactRegistry};

fn registry() -> Option<ArtifactRegistry> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return None;
    }
    let mut reg = ArtifactRegistry::new(dir).expect("PJRT client");
    if let Err(e) = reg.get("ovsf_wgen") {
        eprintln!("SKIP: PJRT runtime unavailable ({e}) — build with `--features pjrt`");
        return None;
    }
    Some(reg)
}

fn load_f32(path: &std::path::Path) -> Vec<f32> {
    let bytes = std::fs::read(path).expect("reference vector file");
    bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect()
}

/// Artifact shapes fixed by python/compile/aot.py.
const N_IN: usize = 16;
const N_BASIS: usize = 8;
const N_OUT: usize = 32;
const K: usize = 3;

#[test]
fn wgen_artifact_matches_python_oracle() {
    let Some(mut reg) = registry() else { return };
    let dir = artifacts_dir();
    let alphas = load_f32(&dir.join("wgen_test_alphas.f32"));
    let expected = load_f32(&dir.join("wgen_test_expected.f32"));
    assert_eq!(alphas.len(), N_IN * N_BASIS * N_OUT);
    assert_eq!(expected.len(), N_IN * K * K * N_OUT);
    let exe = reg.get("ovsf_wgen").expect("compiled");
    let out = exe
        .run_f32(&[(&alphas, &[N_IN, N_BASIS, N_OUT])])
        .expect("execution");
    assert_eq!(out.len(), 1, "single-output tuple");
    assert_eq!(out[0].len(), expected.len());
    for (i, (g, e)) in out[0].iter().zip(&expected).enumerate() {
        assert!((g - e).abs() < 1e-4, "idx {i}: PJRT {g} vs oracle {e}");
    }
}

#[test]
fn wgen_artifact_matches_rust_simulator() {
    let Some(mut reg) = registry() else { return };
    let dir = artifacts_dir();
    let alphas = load_f32(&dir.join("wgen_test_alphas.f32"));
    // Rust TiWGen cycle-level simulation of the same generation.
    let hw = unzipfpga::sim::hw_weights::HwOvsfWeights {
        n_out: N_OUT,
        n_in: N_IN,
        k_ovsf: 4,
        k: K,
        n_basis: N_BASIS,
        // python layout (n_in, nb, n_out) → rust layout (n_out, n_in, nb).
        alphas: {
            let mut a = vec![0.0f32; alphas.len()];
            for c in 0..N_IN {
                for j in 0..N_BASIS {
                    for o in 0..N_OUT {
                        a[(o * N_IN + c) * N_BASIS + j] =
                            alphas[(c * N_BASIS + j) * N_OUT + o];
                    }
                }
            }
            a
        },
    };
    let sigma = unzipfpga::arch::DesignPoint::new(32, 16, 16, 16);
    let sim = unzipfpga::sim::wgen::WGenSim::new(&sigma, &hw).generate();

    let exe = reg.get("ovsf_wgen").expect("compiled");
    let out = exe
        .run_f32(&[(&alphas, &[N_IN, N_BASIS, N_OUT])])
        .expect("execution");
    assert_eq!(out[0].len(), sim.weights.len());
    for (i, (g, s)) in out[0].iter().zip(&sim.weights).enumerate() {
        assert!(
            (g - s).abs() < 1e-4,
            "idx {i}: PJRT {g} vs rust TiWGen sim {s}"
        );
    }
}

#[test]
fn gemm_artifact_multiplies_correctly() {
    let Some(mut reg) = registry() else { return };
    let (r, p, c) = (64usize, 144usize, 32usize);
    // Deterministic pseudo-random inputs.
    let mut rng = unzipfpga::util::prng::Xoshiro256::seed_from_u64(99);
    let a = rng.normal_vec(r * p);
    let w = rng.normal_vec(p * c);
    let exe = reg.get("gemm").expect("compiled");
    let out = exe
        .run_f32(&[(&a, &[r, p]), (&w, &[p, c])])
        .expect("execution");
    // Reference matmul.
    for ri in (0..r).step_by(17) {
        for ci in (0..c).step_by(7) {
            let mut acc = 0.0f64;
            for pi in 0..p {
                acc += a[ri * p + pi] as f64 * w[pi * c + ci] as f64;
            }
            let got = out[0][ri * c + ci] as f64;
            assert!(
                (got - acc).abs() < 1e-2 * acc.abs().max(1.0),
                "({ri},{ci}): {got} vs {acc}"
            );
        }
    }
}

#[test]
fn conv_artifact_runs_and_is_finite() {
    let Some(mut reg) = registry() else { return };
    let mut rng = unzipfpga::util::prng::Xoshiro256::seed_from_u64(5);
    let x = rng.normal_vec(16 * 16 * N_IN);
    let alphas = rng.normal_vec(N_IN * N_BASIS * N_OUT);
    let exe = reg.get("ovsf_conv").expect("compiled");
    let out = exe
        .run_f32(&[
            (&x, &[1, 16, 16, N_IN]),
            (&alphas, &[N_IN, N_BASIS, N_OUT]),
        ])
        .expect("execution");
    assert_eq!(out[0].len(), 16 * 16 * N_OUT);
    assert!(out[0].iter().all(|v| v.is_finite()));
    // SAME-padded conv of non-trivial inputs is non-trivial output.
    assert!(out[0].iter().any(|v| v.abs() > 1e-3));
}

#[test]
fn model_forward_artifact_produces_logits() {
    let Some(mut reg) = registry() else { return };
    // model_fwd takes (x, *flat_params) — 8 param leaves in tree order
    // (dict keys sorted: head_b, head_w, ovsf1..4, stem).
    let mut rng = unzipfpga::util::prng::Xoshiro256::seed_from_u64(1);
    let x = rng.normal_vec(8 * 16 * 16 * 3);
    let width = 16usize;
    let w2 = 2 * width;
    let nb = 8usize;
    let head_b = vec![0.0f32; 10];
    let head_w = rng.normal_vec(w2 * 10);
    let ovsf1 = rng.normal_vec(width * nb * width);
    let ovsf2 = rng.normal_vec(width * nb * width);
    let ovsf3 = rng.normal_vec(width * nb * w2);
    let ovsf4 = rng.normal_vec(w2 * nb * w2);
    let stem = rng.normal_vec(3 * 3 * 3 * width);
    let exe = reg.get("model_fwd").expect("compiled");
    let out = exe
        .run_f32(&[
            (&x, &[8, 16, 16, 3]),
            (&head_b, &[10]),
            (&head_w, &[w2, 10]),
            (&ovsf1, &[width, nb, width]),
            (&ovsf2, &[width, nb, width]),
            (&ovsf3, &[width, nb, w2]),
            (&ovsf4, &[w2, nb, w2]),
            (&stem, &[3, 3, 3, width]),
        ])
        .expect("execution");
    assert_eq!(out[0].len(), 8 * 10, "batch of 10-class logits");
    assert!(out[0].iter().all(|v| v.is_finite()));
}

#[test]
fn fused_artifact_matches_unfused_pipeline() {
    // The fused wgen+GEMM kernel (no weight round-trip, DESIGN.md
    // §Hardware-Adaptation) must equal gemm(act, wgen(α)).
    let Some(mut reg) = registry() else { return };
    if !reg.has("ovsf_gemm_fused") {
        eprintln!("SKIP: fused artifact missing — re-run `make artifacts`");
        return;
    }
    let mut rng = unzipfpga::util::prng::Xoshiro256::seed_from_u64(42);
    let (r, p) = (64usize, N_IN * K * K);
    let a = rng.normal_vec(r * p);
    let alphas = rng.normal_vec(N_IN * N_BASIS * N_OUT);
    let fused = reg
        .get("ovsf_gemm_fused")
        .expect("compiled")
        .run_f32(&[(&a, &[r, p]), (&alphas, &[N_IN, N_BASIS, N_OUT])])
        .expect("fused execution");
    let w = reg
        .get("ovsf_wgen")
        .expect("compiled")
        .run_f32(&[(&alphas, &[N_IN, N_BASIS, N_OUT])])
        .expect("wgen execution");
    let unfused = reg
        .get("gemm")
        .expect("compiled")
        .run_f32(&[(&a, &[r, p]), (&w[0], &[p, N_OUT])])
        .expect("gemm execution");
    assert_eq!(fused[0].len(), unfused[0].len());
    for (i, (f, u)) in fused[0].iter().zip(&unfused[0]).enumerate() {
        assert!(
            (f - u).abs() < 1e-3 * u.abs().max(1.0),
            "idx {i}: fused {f} vs unfused {u}"
        );
    }
}

#[test]
fn simulator_conv_matches_pjrt_conv_artifact() {
    // The strongest cross-check: the rust simulator's full conv layer
    // (im2col → TiWGen weights generation → PE-array GEMM) against the
    // PJRT-executed JAX conv artifact (SAME padding, HWIO weights from the
    // same α) — hardware model ≡ compiled model, end to end.
    let Some(mut reg) = registry() else { return };
    let mut rng = unzipfpga::util::prng::Xoshiro256::seed_from_u64(77);
    let x = rng.normal_vec(16 * 16 * N_IN);
    let alphas_py = rng.normal_vec(N_IN * N_BASIS * N_OUT);
    let pjrt = reg
        .get("ovsf_conv")
        .expect("compiled")
        .run_f32(&[
            (&x, &[1, 16, 16, N_IN]),
            (&alphas_py, &[N_IN, N_BASIS, N_OUT]),
        ])
        .expect("execution");

    // Rust side: same α in hardware layout.
    let mut alphas_rs = vec![0.0f32; alphas_py.len()];
    for c in 0..N_IN {
        for j in 0..N_BASIS {
            for o in 0..N_OUT {
                alphas_rs[(o * N_IN + c) * N_BASIS + j] =
                    alphas_py[(c * N_BASIS + j) * N_OUT + o];
            }
        }
    }
    let hw = unzipfpga::sim::hw_weights::HwOvsfWeights {
        n_out: N_OUT,
        n_in: N_IN,
        k_ovsf: 4,
        k: K,
        n_basis: N_BASIS,
        alphas: alphas_rs,
    };
    let layer = unzipfpga::workload::layer::Layer::conv(
        "artifact-conv",
        16,
        16,
        N_IN as u64,
        N_OUT as u64,
        3,
        1,
        1,
        true,
    );
    let act = unzipfpga::sim::im2col::im2col(&layer, &x);
    let sigma = unzipfpga::arch::DesignPoint::new(32, 64, 16, 16);
    let plat = unzipfpga::arch::Platform::z7045();
    let sim = unzipfpga::sim::engine::LayerSim::new(&sigma, &plat, 4);
    let (trace, out) = sim.execute_ovsf(&layer, &hw, &act);
    assert!(trace.total_cycles > 0);
    assert_eq!(out.len(), pjrt[0].len());
    let mut max_d = 0.0f32;
    for (a, b) in out.iter().zip(&pjrt[0]) {
        max_d = max_d.max((a - b).abs());
    }
    assert!(
        max_d < 1e-3,
        "simulator conv vs PJRT conv artifact: max |Δ| = {max_d}"
    );
}
