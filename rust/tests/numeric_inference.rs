//! Numeric parity of the tile-streamed inference datapath.
//!
//! The simulator backend computes layer outputs strip-by-strip with
//! weights generated slab-by-slab through the bounded cache. These tests
//! pin that streamed path against a dense-oracle GEMM (full `P×C`
//! materialisation + naive matmul), across:
//!
//! * both PE schedules (plain and input-selective work stealing),
//! * ρ ∈ {0.25, 1.0},
//! * a `C < T_C` layer (the work-stealing regime),
//! * a slab budget of a single slab (eviction active every tile),
//!
//! plus a byte-budget/eviction property test for the slab cache itself.

use std::sync::Arc;

use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::engine::sim::{synth_dense_slab, synth_hw_weights};
use unzipfpga::engine::{BackendKind, Engine, SimBackend, Slab, SlabCache, SlabKey, WeightsKey};
use unzipfpga::sim::im2col::im2col;
use unzipfpga::util::check::forall;
use unzipfpga::util::prng::Xoshiro256;
use unzipfpga::workload::{Layer, Network, RatioProfile};

/// Dense-oracle forward pass of one layer: full `P×C` weights
/// materialisation plus a naive (untiled) GEMM — everything the streamed
/// engine path is *not* allowed to do, used as ground truth.
fn oracle_forward(model: &str, idx: usize, layer: &Layer, rho: f64, x: &[f32]) -> Vec<f32> {
    let g = layer.gemm();
    let (r, p, c) = (g.r as usize, g.p as usize, g.c as usize);
    let act = im2col(layer, x);
    let dense: Vec<f32> = if layer.ovsf {
        let hw = synth_hw_weights(model, idx, layer, rho).unwrap();
        hw.dense_gemm().unwrap()
    } else {
        let mut w = Vec::new();
        synth_dense_slab(model, idx, layer, 0, c, &mut w);
        w
    };
    let mut out = vec![0.0f32; r * c];
    for ri in 0..r {
        for pi in 0..p {
            let a = act[ri * p + pi];
            for ci in 0..c {
                out[ri * c + ci] += a * dense[pi * c + ci];
            }
        }
    }
    out
}

fn oracle_network(net: &Network, profile: &RatioProfile, input: &[f32]) -> Vec<f32> {
    let mut x = input.to_vec();
    for (idx, layer) in net.layers.iter().enumerate() {
        x = oracle_forward(&net.name, idx, layer, profile.rho(idx), &x);
    }
    x
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// A ResNet-18 basic block — two 3×3 OVSF convolutions at the stage-1
/// channel geometry (64 → 64, stride 1, pad 1) — at a reduced spatial size
/// so the dense oracle stays cheap in debug builds. The weights path is
/// spatial-size-invariant, so the parity statement carries to the full
/// 56×56 maps.
fn resnet18_block() -> Network {
    Network {
        name: "r18block".into(),
        layers: vec![
            Layer::conv("layer1.0.conv1", 14, 14, 64, 64, 3, 1, 1, true),
            Layer::conv("layer1.0.conv2", 14, 14, 64, 64, 3, 1, 1, true),
        ],
    }
}

fn block_input() -> Vec<f32> {
    let mut rng = Xoshiro256::seed_from_u64(0xb10c);
    rng.normal_vec(14 * 14 * 64)
}

fn block_engine(rho: f64, selective: bool, cache: Arc<SlabCache>) -> Engine {
    let net = resnet18_block();
    let profile = RatioProfile::uniform(&net, rho);
    let plan = Engine::builder()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(DesignPoint::new(64, 16, 16, 48))
        .network(net)
        .profile(profile)
        .plan()
        .unwrap();
    let mut backend = SimBackend::with_cache(cache);
    backend.selective = selective;
    Engine::with_backend(plan, Box::new(backend)).unwrap()
}

/// Acceptance: the streamed tiled path matches the dense oracle to
/// ≤ 1e-3 max abs error on a ResNet-18 block, under both schedules and
/// both compression ratios.
#[test]
fn resnet18_block_matches_dense_oracle() {
    let input = block_input();
    for rho in [0.25, 1.0] {
        let net = resnet18_block();
        let profile = RatioProfile::uniform(&net, rho);
        let expect = oracle_network(&net, &profile, &input);
        for selective in [true, false] {
            let mut engine = block_engine(rho, selective, Arc::new(SlabCache::new()));
            let got = engine.infer(&input).unwrap().output;
            let err = max_abs_diff(&got, &expect);
            assert!(
                err <= 1e-3,
                "streamed path diverges from oracle: max abs err {err} \
                 (ρ={rho}, selective={selective})"
            );
        }
    }
}

/// The same block under a single-slab byte budget: eviction runs on every
/// column tile, numerics are unchanged, and peak resident generated
/// weights stay under the configured budget.
#[test]
fn resnet18_block_streams_under_a_single_slab_budget() {
    let input = block_input();
    let reference = {
        let mut engine = block_engine(1.0, true, Arc::new(SlabCache::new()));
        engine.infer(&input).unwrap().output
    };
    // One slab: P×T_C×4 = 576·48·4 bytes.
    let budget = 576 * 48 * 4;
    let cache = Arc::new(SlabCache::with_budget(budget));
    let mut engine = block_engine(1.0, true, Arc::clone(&cache));
    let got = engine.infer(&input).unwrap().output;
    assert_eq!(got, reference, "eviction must not change numerics");
    assert!(
        cache.peak_resident_bytes() <= budget,
        "peak resident {} exceeds the {budget}-byte slab budget",
        cache.peak_resident_bytes()
    );
    assert!(cache.evictions() > 0, "a one-slab budget must evict");
    // A second request regenerates (nothing could stay resident) but still
    // agrees bit-for-bit.
    let again = engine.infer(&input).unwrap().output;
    assert_eq!(again, reference);
}

/// A `C < T_C` OVSF layer: the input-selective work-stealing schedule is
/// active for the whole layer. Numerics must be schedule-invariant and
/// match the oracle; the selective schedule may only be faster.
#[test]
fn small_c_layer_matches_oracle_under_both_schedules() {
    let net = Network {
        name: "narrow".into(),
        layers: vec![
            Layer::conv("stem", 8, 8, 4, 16, 3, 1, 1, false),
            Layer::conv("narrow.conv", 8, 8, 16, 8, 3, 1, 1, true),
        ],
    };
    let sigma = DesignPoint::new(16, 8, 8, 16); // T_C = 16 > C = 8
    for rho in [0.25, 1.0] {
        let profile = RatioProfile::uniform(&net, rho);
        let mut rng = Xoshiro256::seed_from_u64(7);
        let input = rng.normal_vec(8 * 8 * 4);
        let expect = oracle_network(&net, &profile, &input);
        let mut outputs = Vec::new();
        let mut cycles = Vec::new();
        for selective in [true, false] {
            let plan = Engine::builder()
                .platform(Platform::z7045())
                .bandwidth(4)
                .design_point(sigma)
                .network(net.clone())
                .profile(profile.clone())
                .plan()
                .unwrap();
            let mut backend = SimBackend::new();
            backend.selective = selective;
            let mut engine = Engine::with_backend(plan, Box::new(backend)).unwrap();
            let o = engine.infer(&input).unwrap();
            cycles.push(o.report.total_cycles);
            outputs.push(o.output);
        }
        assert_eq!(outputs[0], outputs[1], "schedules must not change numerics");
        assert!(
            cycles[0] <= cycles[1],
            "work stealing slower than plain: {} vs {}",
            cycles[0],
            cycles[1]
        );
        let err = max_abs_diff(&outputs[0], &expect);
        assert!(err <= 1e-3, "max abs err {err} at ρ={rho}");
    }
}

/// ServerPool responses carry the same numerics the engine computes
/// directly — the end of the issue's "empty vectors to millions of users".
#[test]
fn pool_responses_carry_real_numerics() {
    use unzipfpga::coordinator::pool::PoolConfig;
    use unzipfpga::coordinator::server::Request;

    let net = resnet18_block();
    let profile = RatioProfile::uniform(&net, 0.25);
    let builder = Engine::builder()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(DesignPoint::new(64, 16, 16, 48))
        .network(net)
        .profile(profile)
        .backend(BackendKind::Simulator);
    let input = block_input();
    let mut reference = builder.clone().build().unwrap();
    let expect = reference.infer(&input).unwrap().output;
    assert!(!expect.is_empty());

    let pool = builder
        .build_pool(PoolConfig {
            workers: 2,
            queue_depth: 16,
            max_batch: 4,
            linger: std::time::Duration::from_micros(200),
            slo: None,
            ..PoolConfig::default()
        })
        .unwrap();
    let handles: Vec<_> = (0..6u64)
        .map(|id| pool.submit(Request::numeric(id, input.clone())).unwrap())
        .collect();
    for h in handles {
        let resp = h.wait().unwrap();
        assert_eq!(resp.output, expect, "pool numerics diverge from engine");
    }
    // Timing-only (empty-input) requests still serve.
    let resp = pool.submit(Request::timing(99)).unwrap().wait().unwrap();
    assert!(resp.output.is_empty());
    // Malformed input lengths fail fast at submit with a typed error —
    // they never reach a worker.
    let err = pool
        .submit(Request::numeric(100, vec![0.0; 13]))
        .err()
        .expect("wrong-length input must be rejected at admission");
    assert!(
        matches!(err, unzipfpga::Error::ShapeMismatch(_)),
        "typed: {err}"
    );
    pool.shutdown().unwrap();
}

fn block_engine_with(
    rho: f64,
    selective: bool,
    pipelined: bool,
    cache: Arc<SlabCache>,
) -> Engine {
    let net = resnet18_block();
    let profile = RatioProfile::uniform(&net, rho);
    let plan = Engine::builder()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(DesignPoint::new(64, 16, 16, 48))
        .network(net)
        .profile(profile)
        .plan()
        .unwrap();
    let mut backend = SimBackend::with_cache(cache);
    backend.selective = selective;
    backend.pipelined = pipelined;
    Engine::with_backend(plan, Box::new(backend)).unwrap()
}

/// Acceptance: the pipelined prefetch datapath is **bit-identical** to the
/// serial generate-then-multiply schedule — same seeds, same outputs — for
/// ρ ∈ {0.25, 1.0} under both PE schedules, with nonzero generation/compute
/// telemetry and hidden time never exceeding generation time.
#[test]
fn pipelined_datapath_is_bit_identical_to_serial() {
    let input = block_input();
    for rho in [0.25, 1.0] {
        for selective in [true, false] {
            let mut serial =
                block_engine_with(rho, selective, false, Arc::new(SlabCache::new()));
            let expect = serial.infer(&input).unwrap();
            let mut piped =
                block_engine_with(rho, selective, true, Arc::new(SlabCache::new()));
            let got = piped.infer(&input).unwrap();
            assert_eq!(
                got.output, expect.output,
                "pipelined output differs from serial (ρ={rho}, selective={selective})"
            );
            let overlap = got.report.overlap();
            assert!(overlap.gen_ns > 0, "cold OVSF slabs must charge generation");
            assert!(overlap.compute_ns > 0, "PE compute must be timed");
            assert!(
                overlap.hidden_ns <= overlap.gen_ns,
                "cannot hide more generation than ran"
            );
            assert_eq!(
                expect.report.overlap().hidden_ns,
                0,
                "the serial schedule overlaps nothing"
            );
        }
    }
}

/// Batched numeric serving: a `ServerPool` run with `max_batch > 1` must
/// return outputs identical to per-request serial inference, and the
/// shared slab cache's misses must not scale with the batch size — each
/// layer's slabs are generated once for the whole run.
#[test]
fn batched_pool_serving_matches_serial_and_amortises_slab_misses() {
    use unzipfpga::coordinator::pool::PoolConfig;
    use unzipfpga::coordinator::server::Request;

    let net = resnet18_block();
    let profile = RatioProfile::uniform(&net, 0.25);
    let builder = Engine::builder()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(DesignPoint::new(64, 16, 16, 48))
        .network(net.clone())
        .profile(profile)
        .backend(BackendKind::Simulator);

    // Distinct inputs per request so batching cannot hide behind identical
    // tensors.
    let mut rng = Xoshiro256::seed_from_u64(0xba7c);
    let inputs: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(14 * 14 * 64)).collect();
    let mut reference = builder.clone().build().unwrap();
    let expect: Vec<Vec<f32>> = inputs
        .iter()
        .map(|input| reference.infer(input).unwrap().output)
        .collect();

    // Budget of exactly one slab (P×T_C×4 = 576·48·4 bytes): nothing
    // survives between layer passes, so the miss count discriminates real
    // batch folding — per-request execution would regenerate all 4 slabs
    // for every request, while a folded batch generates 4 per *batch*.
    let cache = Arc::new(SlabCache::with_budget(576 * 48 * 4));
    let pool = builder
        .weights_cache(Arc::clone(&cache))
        .build_pool(PoolConfig {
            workers: 1, // deterministic batching: one worker pops the queue
            queue_depth: 16,
            max_batch: 4,
            linger: std::time::Duration::from_millis(20),
            slo: None,
            ..PoolConfig::default()
        })
        .unwrap();
    let handles: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(id, input)| pool.submit(Request::numeric(id as u64, input.clone())).unwrap())
        .collect();
    for (h, want) in handles.into_iter().zip(&expect) {
        let resp = h.wait().unwrap();
        assert_eq!(
            &resp.output, want,
            "batched pool numerics diverge from per-request serial inference"
        );
    }
    let misses = cache.misses();
    let pm = pool.shutdown().unwrap();
    assert_eq!(pm.total_requests(), 8);
    assert!(
        pm.max_batch() > 1,
        "the run must actually have batched: max batch {}",
        pm.max_batch()
    );
    // Both OVSF layers have C = 64 on T_C = 48 ⇒ 2 column tiles each: a
    // folded batch generates exactly 4 slabs regardless of how many
    // requests it carries, so misses are bounded by 4·batches — without
    // folding, under the one-slab budget, they would be 4·requests = 32.
    assert!(
        misses <= 4 * pm.total_batches(),
        "slab misses must scale with batches, not requests: {misses} misses \
         over {} batches",
        pm.total_batches()
    );
}

/// Byte-budget/eviction property: under arbitrary access patterns the
/// cache never holds more than the budget, counters reconcile, and every
/// fetch returns the key's own data.
#[test]
fn slab_cache_byte_budget_property() {
    forall("slab-cache-budget", 24, |rng| {
        let slab_floats = rng.gen_range(1, 64) as usize;
        let n_keys = rng.gen_range(1, 24) as u32;
        let budget = rng.gen_range(1, 8) as usize * slab_floats * 4;
        let cache = SlabCache::with_budget(budget);
        let accesses = 120;
        for _ in 0..accesses {
            let ct = rng.gen_range(0, n_keys as u64) as u32;
            let key = SlabKey {
                layer: WeightsKey::new("m", 0, (1, 1, 1), DesignPoint::new(8, 8, 8, 8), 0.5),
                col_tile: ct,
            };
            let v = cache
                .try_get_or_generate(key, || Ok(Slab::F32(vec![ct as f32; slab_floats])))
                .unwrap();
            assert_eq!(v.len(), slab_floats);
            assert!(
                v.f32_data().iter().all(|&x| x == ct as f32),
                "wrong slab served"
            );
            assert!(
                cache.resident_bytes() <= budget,
                "resident {} over budget {budget}",
                cache.resident_bytes()
            );
        }
        assert!(cache.peak_resident_bytes() <= budget);
        assert_eq!(cache.hits() + cache.misses(), accesses);
        assert_eq!(
            cache.len() as u64,
            cache.misses() - cache.evictions(),
            "inserts minus evictions must equal residency"
        );
    });
}
