//! SLO scheduling acceptance tests for the serving pool.
//!
//! * **Backpressure**: `submit` blocks while the bounded queue is full and
//!   resumes the moment a slot frees; `try_submit` fails fast with the
//!   typed `Error::QueueFull`.
//! * **EDF + priority pop order**: queued requests with deadlines pop
//!   earliest-deadline-first; priority dominates deadline; deadline-less
//!   traffic keeps FIFO order behind both.
//! * **No starvation**: under a flood of deadline traffic for one model, a
//!   deadline-less minority-model request is still served — the model-pure
//!   batcher never skips over it once it heads the key-sorted queue.
//! * **Deadline expiry**: a queued request whose deadline passes fails
//!   fast with the typed `Error::DeadlineExceeded` and is counted.
//! * **Overload regression**: with a queue-delay SLO configured, admission
//!   control sheds typed `Error::Overloaded` and the queue delay of
//!   *admitted* requests stays within the SLO, while the same traffic on
//!   an unthrottled FIFO pool drives queue delay far past it.
//!
//! Determinism idiom (shared with the pool's unit tests): a gated executor
//! holds the single worker inside `execute` while the test arranges the
//! queue, so pop order and occupancy are exact, not timing-dependent.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use unzipfpga::arch::DesignPoint;
use unzipfpga::coordinator::plan::InferencePlan;
use unzipfpga::coordinator::pool::{PoolConfig, RequestExecutor, ServerPool};
use unzipfpga::coordinator::server::Request;
use unzipfpga::{Error, Result};

/// A shared open/closed latch the test-side controls and executors block on.
type Gate = Arc<(Mutex<bool>, Condvar)>;

fn gate() -> Gate {
    Arc::new((Mutex::new(false), Condvar::new()))
}

fn open_gate(g: &Gate) {
    let (lock, cv) = &**g;
    *lock.lock().unwrap() = true;
    cv.notify_all();
}

fn block_on_gate(g: &Gate) {
    let (lock, cv) = &**g;
    let mut open = lock.lock().unwrap();
    while !*open {
        open = cv.wait(open).unwrap();
    }
}

/// Poll `cond` until it holds, failing the test after a generous timeout so
/// a scheduling bug reads as an assertion, never as a hung test binary.
fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// A plan literal with an exact, test-controlled admission-time service
/// estimate — `InferencePlan`'s fields are public precisely so tests can
/// pin `latency_s` without routing through the analytical model.
fn synthetic_plan(latency_s: f64) -> InferencePlan {
    InferencePlan {
        network: "synthetic".into(),
        sigma: DesignPoint::new(8, 4, 8, 4),
        layers: Vec::new(),
        total_cycles: latency_s * 1e9,
        latency_s,
    }
}

fn cfg(workers: usize, queue_depth: usize, max_batch: usize, slo: Option<Duration>) -> PoolConfig {
    PoolConfig {
        workers,
        queue_depth,
        max_batch,
        linger: Duration::ZERO,
        slo,
        ..PoolConfig::default()
    }
}

/// The sentinel id the gated executors block on: the worker pops it first,
/// then stalls inside `execute` while the test stages the queue.
const SENTINEL: u64 = 999;

/// Single gated worker recording execution order: pops `SENTINEL`, blocks
/// until the gate opens, then serves the staged queue one request per
/// batch. Returns (pool, order).
fn ordering_pool(g: &Gate) -> (ServerPool, Arc<Mutex<Vec<u64>>>) {
    let order: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let g2 = Arc::clone(g);
    let o2 = Arc::clone(&order);
    let pool = ServerPool::start(synthetic_plan(1e-6), cfg(1, 64, 1, None), move |_| {
        let gate = Arc::clone(&g2);
        let order = Arc::clone(&o2);
        move |req: &Request| {
            if req.id == SENTINEL {
                block_on_gate(&gate);
            }
            order.lock().unwrap().push(req.id);
            vec![req.id as f32]
        }
    })
    .unwrap();
    (pool, order)
}

#[test]
fn submit_blocks_on_a_full_queue_until_a_slot_frees() {
    let g = gate();
    let g2 = Arc::clone(&g);
    // Depth-2 queue, single gated worker: one request in flight + two
    // queued is a deterministically full pool.
    let pool = ServerPool::start(synthetic_plan(1e-6), cfg(1, 2, 1, None), move |_| {
        let gate = Arc::clone(&g2);
        move |req: &Request| {
            block_on_gate(&gate);
            vec![req.id as f32]
        }
    })
    .unwrap();
    let h0 = pool.submit(Request::timing(0)).unwrap();
    wait_until("worker to pop request 0", || pool.queue_len() == 0);
    let h1 = pool.submit(Request::timing(1)).unwrap();
    let h2 = pool.submit(Request::timing(2)).unwrap();
    assert_eq!(pool.queue_len(), 2, "queue must be at capacity");
    // Fail-fast path first: the non-blocking probe sees a full queue.
    match pool.try_submit(Request::timing(90)) {
        Err(Error::QueueFull) => {}
        other => panic!("expected QueueFull, got {other:?}"),
    }
    // Blocking path: a submitter parks until the gate opens a slot.
    let submitted = AtomicBool::new(false);
    std::thread::scope(|s| {
        let blocked = s.spawn(|| {
            let h = pool.submit(Request::timing(3));
            submitted.store(true, Ordering::SeqCst);
            h
        });
        std::thread::sleep(Duration::from_millis(50));
        assert!(
            !submitted.load(Ordering::SeqCst),
            "submit must block while the queue is full"
        );
        open_gate(&g);
        let h3 = blocked.join().unwrap().unwrap();
        assert!(submitted.load(Ordering::SeqCst));
        for h in [h0, h1, h2, h3] {
            h.wait().unwrap();
        }
    });
    let pm = pool.shutdown().unwrap();
    assert_eq!(pm.total_requests(), 4);
    assert_eq!(pm.total_shed(), 0, "no SLO configured ⇒ nothing sheds");
}

#[test]
fn queued_requests_pop_earliest_deadline_first() {
    let g = gate();
    let (pool, order) = ordering_pool(&g);
    let sentinel = pool.submit(Request::timing(SENTINEL)).unwrap();
    wait_until("worker to pop the sentinel", || pool.queue_len() == 0);
    let far = Instant::now() + Duration::from_secs(100);
    let sec = Duration::from_secs(1);
    // Staged arrival order: a deadline-less request first, then deadlines
    // out of order — EDF must serve 2, 4, 3, 1 and leave 5 for last.
    let handles = vec![
        pool.submit(Request::timing(5)).unwrap(),
        pool.submit(Request::timing(1).with_deadline(far + 40 * sec)).unwrap(),
        pool.submit(Request::timing(2).with_deadline(far + 10 * sec)).unwrap(),
        pool.submit(Request::timing(3).with_deadline(far + 30 * sec)).unwrap(),
        pool.submit(Request::timing(4).with_deadline(far + 20 * sec)).unwrap(),
    ];
    open_gate(&g);
    sentinel.wait().unwrap();
    for h in handles {
        h.wait().unwrap();
    }
    pool.shutdown().unwrap();
    assert_eq!(
        *order.lock().unwrap(),
        vec![SENTINEL, 2, 4, 3, 1, 5],
        "pop order must be earliest-deadline-first, deadline-less last"
    );
}

#[test]
fn priority_dominates_deadline_order() {
    let g = gate();
    let (pool, order) = ordering_pool(&g);
    let sentinel = pool.submit(Request::timing(SENTINEL)).unwrap();
    wait_until("worker to pop the sentinel", || pool.queue_len() == 0);
    let far = Instant::now() + Duration::from_secs(100);
    // Arrival order 1, 2, 3, 4 — but priority tiers pop first, and within
    // a tier a deadline beats deadline-less traffic.
    let handles = vec![
        pool.submit(Request::timing(1).with_deadline(far)).unwrap(), // pri 0 + deadline
        pool.submit(Request::timing(2).with_priority(3)).unwrap(),   // pri 3
        pool.submit(Request::timing(3).with_priority(3).with_deadline(far)).unwrap(),
        pool.submit(Request::timing(4).with_priority(9)).unwrap(), // top priority
    ];
    open_gate(&g);
    sentinel.wait().unwrap();
    for h in handles {
        h.wait().unwrap();
    }
    pool.shutdown().unwrap();
    assert_eq!(
        *order.lock().unwrap(),
        vec![SENTINEL, 4, 3, 2, 1],
        "priority tiers pop before any deadline ordering"
    );
}

#[test]
fn minority_model_is_served_under_deadline_pressure() {
    // A flood of "hot" requests with deadlines vs one deadline-less "cold"
    // request. EDF sorts every hot ahead of cold, but the model-pure
    // batcher takes the maximal same-model *prefix* of the sorted queue —
    // once the hots drain, cold heads the queue and seeds its own batch.
    let g = gate();
    let batches: Arc<Mutex<Vec<Vec<(String, u64)>>>> = Arc::new(Mutex::new(Vec::new()));
    struct Recording {
        gate: Gate,
        batches: Arc<Mutex<Vec<Vec<(String, u64)>>>>,
    }
    impl RequestExecutor for Recording {
        fn execute(&mut self, _req: &Request) -> Result<Vec<f32>> {
            unreachable!("execute_batch is overridden")
        }
        fn execute_batch(&mut self, batch: &[Request]) -> Vec<Result<Vec<f32>>> {
            if batch[0].id == SENTINEL {
                block_on_gate(&self.gate);
            }
            self.batches
                .lock()
                .unwrap()
                .push(batch.iter().map(|r| (r.model.clone(), r.id)).collect());
            batch.iter().map(|r| Ok(vec![r.id as f32])).collect()
        }
    }
    let g2 = Arc::clone(&g);
    let b2 = Arc::clone(&batches);
    let pool = ServerPool::start(
        synthetic_plan(1e-6),
        PoolConfig {
            workers: 1,
            queue_depth: 64,
            max_batch: 4,
            linger: Duration::from_millis(5),
            slo: None,
            ..PoolConfig::default()
        },
        move |_| Recording {
            gate: Arc::clone(&g2),
            batches: Arc::clone(&b2),
        },
    )
    .unwrap();
    let sentinel = pool.submit(Request::for_model(SENTINEL, "w", vec![])).unwrap();
    wait_until("worker to pop the sentinel", || pool.queue_len() == 0);
    let far = Instant::now() + Duration::from_secs(100);
    let sec = Duration::from_secs(1);
    let mut handles = Vec::new();
    // Three hots with late deadlines…
    for (id, dl) in [(1u64, 20u32), (2, 21), (3, 22)] {
        handles.push(
            pool.submit(Request::for_model(id, "hot", vec![]).with_deadline(far + dl * sec))
                .unwrap(),
        );
    }
    // …the minority request in the middle of the arrival stream…
    let cold = pool.submit(Request::for_model(100, "cold", vec![])).unwrap();
    // …then three more hots with *earlier* deadlines than the first three.
    for (id, dl) in [(4u64, 10u32), (5, 11), (6, 12)] {
        handles.push(
            pool.submit(Request::for_model(id, "hot", vec![]).with_deadline(far + dl * sec))
                .unwrap(),
        );
    }
    open_gate(&g);
    sentinel.wait().unwrap();
    let resp = cold.wait().unwrap();
    assert_eq!(resp.model, "cold", "minority request must be served");
    for h in handles {
        h.wait().unwrap();
    }
    pool.shutdown().unwrap();
    let recorded = batches.lock().unwrap().clone();
    let ids = |b: &[(String, u64)]| b.iter().map(|(_, id)| *id).collect::<Vec<_>>();
    assert_eq!(recorded.len(), 4, "sentinel + 2 hot batches + cold: {recorded:?}");
    assert_eq!(ids(&recorded[0]), vec![SENTINEL]);
    // EDF across the hots: the late-arriving earlier deadlines pop first.
    assert_eq!(ids(&recorded[1]), vec![4, 5, 6, 1], "max_batch caps the first batch");
    assert_eq!(ids(&recorded[2]), vec![2, 3]);
    assert_eq!(recorded[3], vec![("cold".to_string(), 100)]);
    for batch in &recorded {
        let m0 = &batch[0].0;
        assert!(batch.iter().all(|(m, _)| m == m0), "batch mixes models: {batch:?}");
    }
}

#[test]
fn queued_deadline_expiry_fails_typed_and_is_counted() {
    let g = gate();
    let (pool, order) = ordering_pool(&g);
    let sentinel = pool.submit(Request::timing(SENTINEL)).unwrap();
    wait_until("worker to pop the sentinel", || pool.queue_len() == 0);
    let victim = pool
        .submit(Request::timing(1).with_timeout(Duration::from_millis(25)))
        .unwrap();
    let survivor = pool.submit(Request::timing(2)).unwrap();
    // Hold the worker past the victim's deadline before letting it pop.
    std::thread::sleep(Duration::from_millis(60));
    open_gate(&g);
    sentinel.wait().unwrap();
    match victim.wait() {
        Err(Error::DeadlineExceeded { late_by }) => {
            assert!(late_by > Duration::ZERO, "expired while queued ⇒ late");
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    survivor.wait().unwrap();
    let pm = pool.shutdown().unwrap();
    assert_eq!(pm.expired, 1, "queue-side expiry must be counted");
    assert_eq!(pm.total_shed(), 0);
    assert_eq!(pm.merged().count(), 2, "sentinel + survivor served");
    assert!(
        !order.lock().unwrap().contains(&1),
        "an expired request must never reach the executor"
    );
}

#[test]
fn shed_counts_key_on_the_request_model() {
    let g = gate();
    let g2 = Arc::clone(&g);
    // 10 ms admission estimate per request vs a 1 ns SLO: any non-empty
    // queue sheds the next submission.
    let pool = ServerPool::start(
        synthetic_plan(0.010),
        cfg(1, 64, 1, Some(Duration::from_nanos(1))),
        move |_| {
            let gate = Arc::clone(&g2);
            move |req: &Request| {
                if req.id == 0 {
                    block_on_gate(&gate);
                }
                vec![req.id as f32]
            }
        },
    )
    .unwrap();
    let h0 = pool.submit(Request::for_model(0, "hot", vec![])).unwrap();
    wait_until("worker to pop request 0", || pool.queue_len() == 0);
    let h1 = pool.submit(Request::for_model(1, "hot", vec![])).unwrap();
    for (id, model) in [(2u64, "cold"), (3, "hot")] {
        match pool.submit(Request::for_model(id, model, vec![])) {
            Err(Error::Overloaded { queue_delay, slo }) => assert!(queue_delay > slo),
            other => panic!("expected Overloaded for {model}, got {other:?}"),
        }
    }
    open_gate(&g);
    h0.wait().unwrap();
    h1.wait().unwrap();
    let pm = pool.shutdown().unwrap();
    assert_eq!(pm.total_shed(), 2);
    assert_eq!(pm.shed_by_model.get("hot"), Some(&1));
    assert_eq!(pm.shed_by_model.get("cold"), Some(&1));
}

/// The overload regression the ISSUE pins: identical burst traffic through
/// an unthrottled FIFO pool and an SLO pool. FIFO queue delay grows with
/// the backlog (~1 ms of service per queued request, 100 deep); the SLO
/// pool sheds typed `Overloaded` once its estimated queue delay passes the
/// SLO, keeping the *admitted* requests' realized queue delay inside it.
#[test]
fn slo_bounds_admitted_queue_delay_while_fifo_backlog_grows() {
    const N: u64 = 100;
    let service = Duration::from_millis(1);
    // Admission prices each request at 10 ms on 1 worker; a 50 ms SLO
    // therefore admits ~5 queued requests and sheds the rest of a burst.
    let plan = synthetic_plan(0.010);
    let slo = Duration::from_millis(50);
    let run = |slo: Option<Duration>| {
        let pool = ServerPool::start(plan.clone(), cfg(1, 256, 1, slo), move |_| {
            move |req: &Request| {
                std::thread::sleep(service);
                vec![req.id as f32]
            }
        })
        .unwrap();
        let mut admitted = Vec::new();
        let mut shed = 0u64;
        for id in 0..N {
            match pool.submit(Request::timing(id)) {
                Ok(h) => admitted.push(h),
                Err(Error::Overloaded { queue_delay, slo }) => {
                    assert!(queue_delay > slo, "{queue_delay:?} vs {slo:?}");
                    shed += 1;
                }
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        for h in admitted {
            h.wait().unwrap();
        }
        (pool.shutdown().unwrap(), shed)
    };

    let (fifo, fifo_shed) = run(None);
    let (slo_pm, slo_shed) = run(Some(slo));

    // Unthrottled FIFO accepts the whole burst and its tail pays for it.
    assert_eq!(fifo_shed, 0, "no SLO ⇒ nothing sheds");
    assert_eq!(fifo.total_shed(), 0);
    assert_eq!(fifo.merged().count() as u64, N);
    // The SLO pool sheds most of the burst, typed, without hanging.
    assert!(slo_shed > 0, "a 100-deep burst must trip the 50 ms SLO");
    assert_eq!(slo_pm.total_shed(), slo_shed);
    assert_eq!(
        slo_pm.merged().count() as u64 + slo_shed,
        N,
        "every request is either served or shed — none lost"
    );

    let fifo_p99 = fifo.merged().queue_delay_percentile_us(99.0);
    let slo_p99 = slo_pm.merged().queue_delay_percentile_us(99.0);
    // The i-th of 100 back-to-back 1 ms requests waits ~i ms: the FIFO
    // p99 sits near 99 ms — far beyond the 50 ms SLO.
    assert!(
        fifo_p99 > 60_000.0,
        "FIFO backlog should push p99 queue delay past 60 ms, got {fifo_p99} µs"
    );
    // Admission keeps the backlog ≲ 5 requests ⇒ admitted requests wait
    // a few ms; the realized p99 must stay inside the SLO itself.
    assert!(
        slo_p99 < 50_000.0,
        "admitted p99 queue delay must stay inside the 50 ms SLO, got {slo_p99} µs"
    );
    assert!(
        slo_p99 * 2.0 < fifo_p99,
        "SLO pool p99 ({slo_p99} µs) must be well below FIFO ({fifo_p99} µs)"
    );
}
