//! Multi-model serving acceptance tests: ≥ 2 distinct networks through one
//! `ServerPool` under a single shared slab budget.
//!
//! * interleaved requests route to the correct model and match dedicated
//!   single-model `Engine::infer` **bit-identically**;
//! * batches never mix models (covered structurally by the pool's unit
//!   tests; here per-model response routing + metrics pin the behaviour);
//! * cross-model cache contention: two networks under one small budget
//!   evict each other's slabs without changing any output bit;
//! * lifecycle/typed-error guarantees: fail-fast `submit` validation,
//!   eviction of a model with queued requests fails them typed (no hangs),
//!   per-model metrics and the `model_switches` counter.
//!
//! The two workloads are reduced-geometry profiles of ResNet-18 (stem +
//! OVSF basic-block convs + classifier) and MobileNetV1 (strided stem +
//! pointwise + 3×3 + classifier) so the dense-path maths stays cheap in
//! debug builds; the weights path is spatial-size-invariant.

use std::sync::Arc;

use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::coordinator::pool::{PoolConfig, ServerPool};
use unzipfpga::coordinator::registry::ModelRegistry;
use unzipfpga::coordinator::server::Request;
use unzipfpga::engine::{BackendKind, Compiler, Engine};
use unzipfpga::util::prng::Xoshiro256;
use unzipfpga::workload::{Layer, Network, RatioProfile};
use unzipfpga::Error;

/// Reduced ResNet-18 profile: dense stem, two OVSF block convs (one
/// strided), folded-pool classifier. Input 8·8·4 = 256, output 10.
fn resnet_mini() -> Network {
    Network {
        name: "resnet18-mini".into(),
        layers: vec![
            Layer::conv("stem", 8, 8, 4, 8, 3, 1, 1, false),
            Layer::conv("block.conv1", 8, 8, 8, 8, 3, 1, 1, true),
            Layer::conv("block.conv2", 8, 8, 8, 16, 3, 2, 1, true),
            Layer::fc("fc", 16, 10),
        ],
    }
}

/// Reduced MobileNetV1 profile: strided dense stem, pointwise 1×1, an
/// OVSF 3×3, pointwise expansion, classifier. Input 10·10·3 = 300 (a
/// different shape than resnet-mini, so shape validation discriminates),
/// output 7.
fn mobilenet_mini() -> Network {
    Network {
        name: "mobilenet-mini".into(),
        layers: vec![
            Layer::conv("stem", 10, 10, 3, 8, 3, 2, 1, false),
            Layer::conv("pw1", 5, 5, 8, 16, 1, 1, 0, false),
            Layer::conv("dw3", 5, 5, 16, 16, 3, 1, 1, true),
            Layer::conv("pw2", 5, 5, 16, 24, 1, 1, 0, false),
            Layer::fc("fc", 24, 7),
        ],
    }
}

const SIGMA: DesignPoint = DesignPoint {
    m: 8,
    t_r: 4,
    t_p: 8,
    t_c: 4,
};

/// OVSF slab bytes at σ: resnet-mini 2·1152 + 4·1152 = 6912, mobilenet-mini
/// 4·2304 = 9216 — together 16128, so an 8 KiB budget forces cross-model
/// eviction while every single slab (≤ 2304 B) still fits.
const BUDGET: usize = 8 << 10;

fn compiler() -> Compiler {
    Compiler::new()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(SIGMA)
}

/// Dedicated single-model reference engine (private cache).
fn dedicated_engine(net: &Network) -> Engine {
    Engine::builder()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(SIGMA)
        .network(net.clone())
        .profile(RatioProfile::uniform(net, 0.5))
        .backend(BackendKind::Simulator)
        .build()
        .unwrap()
}

fn registry_with_both() -> Arc<ModelRegistry> {
    let c = compiler();
    let registry = Arc::new(ModelRegistry::with_budget(BUDGET));
    for net in [resnet_mini(), mobilenet_mini()] {
        let profile = RatioProfile::uniform(&net, 0.5);
        let id = net.name.clone();
        registry.register(id, c.compile(net, profile).unwrap()).unwrap();
    }
    registry
}

fn inputs_for(net: &Network, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let l0 = &net.layers[0];
    let len = (l0.h * l0.w * l0.n_in) as usize;
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..n).map(|_| rng.normal_vec(len)).collect()
}

/// Acceptance: two distinct networks, one pool, interleaved numeric
/// requests under one shared slab budget — responses route to the correct
/// model and match dedicated single-model engines bit-identically, while
/// the shared cache shows real cross-model contention under its budget.
#[test]
fn two_models_serve_interleaved_bit_identical_under_one_budget() {
    let r18 = resnet_mini();
    let mbn = mobilenet_mini();
    let r18_inputs = inputs_for(&r18, 3, 0xaaaa);
    let mbn_inputs = inputs_for(&mbn, 3, 0xbbbb);

    // Dedicated single-model references.
    let mut r18_engine = dedicated_engine(&r18);
    let mut mbn_engine = dedicated_engine(&mbn);
    let r18_expect: Vec<Vec<f32>> = r18_inputs
        .iter()
        .map(|x| r18_engine.infer(x).unwrap().output)
        .collect();
    let mbn_expect: Vec<Vec<f32>> = mbn_inputs
        .iter()
        .map(|x| mbn_engine.infer(x).unwrap().output)
        .collect();
    assert_eq!(r18_expect[0].len(), 10);
    assert_eq!(mbn_expect[0].len(), 7);

    let registry = registry_with_both();
    let pool = ServerPool::serve(
        Arc::clone(&registry),
        BackendKind::Simulator,
        PoolConfig {
            workers: 2,
            queue_depth: 64,
            max_batch: 4,
            linger: std::time::Duration::from_micros(200),
            slo: None,
            ..PoolConfig::default()
        },
    )
    .unwrap();

    // Interleave: r18, mbn, r18, mbn, ... with two rounds of each input
    // set, so warm slabs, cold slabs and evicted slabs all get exercised.
    let mut handles = Vec::new();
    let mut id = 0u64;
    for _round in 0..2 {
        for i in 0..3 {
            handles.push((
                "resnet18-mini",
                i,
                pool.submit(Request::for_model(id, "resnet18-mini", r18_inputs[i].clone()))
                    .unwrap(),
            ));
            id += 1;
            handles.push((
                "mobilenet-mini",
                i,
                pool.submit(Request::for_model(id, "mobilenet-mini", mbn_inputs[i].clone()))
                    .unwrap(),
            ));
            id += 1;
        }
    }
    for (model, i, h) in handles {
        let resp = h.wait().unwrap();
        assert_eq!(resp.model, model, "response routed to the wrong model");
        let expect = if model == "resnet18-mini" {
            &r18_expect[i]
        } else {
            &mbn_expect[i]
        };
        assert_eq!(
            &resp.output, expect,
            "pool-served numerics diverge from the dedicated {model} engine"
        );
    }
    let pm = pool.shutdown().unwrap();
    assert_eq!(pm.total_requests(), 12);
    let merged = pm.merged();
    assert_eq!(merged.model_count("resnet18-mini"), 6);
    assert_eq!(merged.model_count("mobilenet-mini"), 6);
    assert!(pm.summary().contains("model_switches="), "{}", pm.summary());

    let cache = registry.cache();
    assert!(
        cache.peak_resident_bytes() <= BUDGET,
        "peak resident {} exceeds the shared {BUDGET}-byte budget",
        cache.peak_resident_bytes()
    );
    assert!(
        cache.evictions() > 0,
        "16 KiB of cross-model slabs under an 8 KiB budget must evict"
    );
    assert_eq!(cache.hits() + cache.misses(), cache.lookups());
}

/// Cross-model cache contention, deterministically sequenced on one
/// worker: model A fills the cache, model B evicts A's slabs, A's next
/// request regenerates — outputs stay bit-identical throughout.
#[test]
fn cross_model_contention_evicts_and_regenerates_without_changing_bits() {
    let r18 = resnet_mini();
    let mbn = mobilenet_mini();
    let r18_input = inputs_for(&r18, 1, 0x1).remove(0);
    let mbn_input = inputs_for(&mbn, 1, 0x2).remove(0);
    let r18_expect = dedicated_engine(&r18).infer(&r18_input).unwrap().output;
    let mbn_expect = dedicated_engine(&mbn).infer(&mbn_input).unwrap().output;

    let registry = registry_with_both();
    let cache = Arc::clone(registry.cache());
    let pool = ServerPool::serve(
        Arc::clone(&registry),
        BackendKind::Simulator,
        PoolConfig::single_worker(),
    )
    .unwrap();
    let serve = |model: &str, input: &[f32]| {
        pool.submit(Request::for_model(0, model, input.to_vec()))
            .unwrap()
            .wait()
            .unwrap()
            .output
    };

    // A (6912 B of OVSF slabs) fits the 8 KiB budget alone.
    assert_eq!(serve("resnet18-mini", &r18_input), r18_expect);
    assert_eq!(cache.evictions(), 0, "A alone must fit the budget");
    let misses_a = cache.misses();
    assert!(misses_a > 0);

    // B (9216 B) forces real cross-model eviction.
    assert_eq!(serve("mobilenet-mini", &mbn_input), mbn_expect);
    assert!(cache.evictions() > 0, "B must evict A's resident slabs");
    assert!(cache.peak_resident_bytes() <= BUDGET);

    // A again: its evicted slabs regenerate (misses grow) — and the output
    // is still bit-identical.
    let misses_before = cache.misses();
    assert_eq!(serve("resnet18-mini", &r18_input), r18_expect);
    assert!(
        cache.misses() > misses_before,
        "A's slabs were evicted, so re-serving A must regenerate"
    );
    assert_eq!(cache.hits() + cache.misses(), cache.lookups());
    pool.shutdown().unwrap();
}

/// Per-model metrics + the model-switch counter: a single worker serving
/// the FIFO run a a a b b a performs exactly two plan swaps, and every
/// request lands in its model's latency series.
#[test]
fn per_model_metrics_count_requests_and_switches() {
    let registry = registry_with_both();
    let pool = ServerPool::serve(
        Arc::clone(&registry),
        BackendKind::Simulator,
        PoolConfig::single_worker(),
    )
    .unwrap();
    // Timing-only requests: routing/switching without the GEMM cost.
    // Sequential submit+wait keeps the served order exactly a a a b b a.
    for (id, model) in [
        "resnet18-mini",
        "resnet18-mini",
        "resnet18-mini",
        "mobilenet-mini",
        "mobilenet-mini",
        "resnet18-mini",
    ]
    .iter()
    .enumerate()
    {
        let resp = pool
            .submit(Request::for_model(id as u64, *model, vec![]))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(resp.model, *model);
        assert!(resp.output.is_empty(), "timing-only requests carry no data");
        assert!(resp.device_latency_s > 0.0, "per-model device latency");
    }
    let pm = pool.shutdown().unwrap();
    let merged = pm.merged();
    assert_eq!(merged.model_count("resnet18-mini"), 4);
    assert_eq!(merged.model_count("mobilenet-mini"), 2);
    assert_eq!(
        pm.model_switches(),
        2,
        "a a a b b a = two plan swaps (a→b, b→a); first activation is free"
    );
    let s = pm.summary();
    assert!(
        s.contains("resnet18-mini:") && s.contains("mobilenet-mini:"),
        "summary must break latencies out per model: {s}"
    );
    assert!(s.contains("model_switches=2"), "{s}");
}

/// Fail-fast typed admission: unknown ids, ambiguous default routes and
/// wrong input shapes are rejected at `submit`, before queueing.
#[test]
fn submit_validates_model_and_shape_with_typed_errors() {
    let registry = registry_with_both();
    let pool = ServerPool::serve(
        Arc::clone(&registry),
        BackendKind::Simulator,
        PoolConfig::single_worker(),
    )
    .unwrap();
    // Unknown id.
    let err = pool
        .submit(Request::for_model(0, "vgg16", vec![]))
        .err()
        .expect("unknown model must be rejected");
    assert!(matches!(err, Error::UnknownModel(_)), "{err}");
    // Default route is ambiguous with two models registered.
    let err = pool.submit(Request::timing(1)).err().expect("ambiguous route");
    assert!(matches!(err, Error::UnknownModel(_)), "{err}");
    // Wrong input length for a known model.
    let err = pool
        .submit(Request::for_model(2, "resnet18-mini", vec![0.0; 7]))
        .err()
        .expect("bad shape must be rejected");
    assert!(matches!(err, Error::ShapeMismatch(_)), "{err}");
    // The right shape for the *other* model is still wrong for this one.
    let err = pool
        .submit(Request::for_model(3, "resnet18-mini", vec![0.0; 10 * 10 * 3]))
        .err()
        .expect("cross-model shape must be rejected");
    assert!(matches!(err, Error::ShapeMismatch(_)), "{err}");
    // A valid request still serves.
    let resp = pool
        .submit(Request::for_model(4, "resnet18-mini", vec![]))
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(resp.model, "resnet18-mini");
    pool.shutdown().unwrap();
}

/// A PJRT pool executes one fixed AOT artifact: serving it over a
/// registry with more than one model is rejected up front with a typed
/// error instead of silently answering with the wrong network's numerics.
#[test]
fn pjrt_pools_refuse_multi_model_routing() {
    use unzipfpga::engine::PjrtConfig;
    let registry = registry_with_both();
    let cfg = PjrtConfig::new("/nonexistent-artifacts", "model_fwd", vec![1]);
    let err = ServerPool::serve(
        Arc::clone(&registry),
        BackendKind::Pjrt(cfg),
        PoolConfig::single_worker(),
    )
    .err()
    .expect("two registered models must be rejected for a PJRT pool");
    assert!(
        matches!(err, Error::InvalidConfig(_)),
        "typed, and before any runtime probe: {err}"
    );
    assert!(err.to_string().contains("PJRT"), "{err}");
}

/// Regression (shutdown/eviction drain): evicting a model while its
/// requests are queued fails exactly those requests with the typed
/// `UnknownModel` error — nothing hangs, and other models keep serving.
#[test]
fn evicting_a_model_fails_its_queued_requests_typed() {
    // A deliberately heavier model keeps the single worker busy long
    // enough for the eviction (microseconds on this thread) to win the
    // race against the queued victims.
    let slow = Network {
        name: "slow".into(),
        layers: vec![
            Layer::conv("stem", 16, 16, 8, 16, 3, 1, 1, false),
            Layer::conv("c1", 16, 16, 16, 32, 3, 1, 1, true),
            Layer::fc("fc", 32, 4),
        ],
    };
    let victim = resnet_mini();
    let c = compiler();
    let registry = Arc::new(ModelRegistry::with_budget(1 << 20));
    let slow_model = c.compile(slow.clone(), RatioProfile::uniform(&slow, 0.5)).unwrap();
    registry.register("slow", slow_model).unwrap();
    let victim_model = c.compile(victim.clone(), RatioProfile::uniform(&victim, 0.5)).unwrap();
    registry.register("victim", victim_model).unwrap();
    let pool = ServerPool::serve(
        Arc::clone(&registry),
        BackendKind::Simulator,
        PoolConfig::single_worker(),
    )
    .unwrap();

    // Occupy the worker with a numeric inference, queue victims behind it,
    // then evict their model while they are still pending.
    let slow_input = inputs_for(&slow, 1, 0x51).remove(0);
    let busy = pool
        .submit(Request::for_model(0, "slow", slow_input))
        .unwrap();
    let victims: Vec<_> = (1..=8u64)
        .map(|id| pool.submit(Request::for_model(id, "victim", vec![])).unwrap())
        .collect();
    let evicted = registry.evict("victim").unwrap();
    assert_eq!(evicted.network_name(), "resnet18-mini");

    assert!(!busy.wait().unwrap().output.is_empty(), "slow request serves");
    for h in victims {
        let err = h
            .wait()
            .err()
            .expect("queued request for an evicted model must fail, not hang");
        assert!(matches!(err, Error::UnknownModel(_)), "typed: {err}");
    }
    // New submissions for the evicted id fail fast at admission.
    let err = pool
        .submit(Request::for_model(99, "victim", vec![]))
        .err()
        .expect("evicted model must be rejected at submit");
    assert!(matches!(err, Error::UnknownModel(_)), "{err}");
    // The surviving model still serves.
    assert!(pool
        .submit(Request::for_model(100, "slow", vec![]))
        .unwrap()
        .wait()
        .is_ok());
    pool.shutdown().unwrap();
}

/// Runtime registration: a model added after the pool started serves
/// without a restart — the compile-once/serve-many lifecycle end to end.
#[test]
fn models_register_into_a_live_pool() {
    let registry = Arc::new(ModelRegistry::with_budget(BUDGET));
    let pool = ServerPool::serve(
        Arc::clone(&registry),
        BackendKind::Simulator,
        PoolConfig::single_worker(),
    )
    .unwrap();
    // Nothing registered yet: even the default route is typed-unknown.
    assert!(matches!(
        pool.submit(Request::timing(0)),
        Err(Error::UnknownModel(_))
    ));
    let net = resnet_mini();
    let compiled = compiler()
        .compile(net.clone(), RatioProfile::uniform(&net, 0.5))
        .unwrap();
    registry.register("late", compiled).unwrap();
    let input = inputs_for(&net, 1, 0x7).remove(0);
    let expect = dedicated_engine(&net).infer(&input).unwrap().output;
    // The default route now resolves (single model) — and numerics match.
    let resp = pool.submit(Request::numeric(1, input)).unwrap().wait().unwrap();
    assert_eq!(resp.model, "late");
    assert_eq!(resp.output, expect);
    pool.shutdown().unwrap();
}
