//! End-to-end properties of the int8 weight datapath.
//!
//! Three pillars, mirroring the i8 design's claims:
//!
//! * **Parity** — layer by layer, the i8 engine's outputs track the f32
//!   engine's within the analytic quantisation-error bound
//!   (`sim::quant::i8_error_bound`), across ρ ∈ {0.25, 1.0} and both PE
//!   schedules; dense (non-OVSF) layers are bit-identical because they
//!   stay f32 on either precision (they model the DRAM stream, not
//!   generated weights).
//! * **Density** — an i8 slab charges ¼ the byte budget of its f32 twin,
//!   so the same budget holds 4× the resident slabs and a budget that
//!   thrashes at f32 serves warm at i8.
//! * **Coexistence** — f32 and i8 artifacts of the *same* network share
//!   one slab cache without key aliasing, each serving its own numerics.

use std::sync::Arc;

use unzipfpga::arch::{DesignPoint, Platform};
use unzipfpga::engine::sim::synth_hw_weights;
use unzipfpga::engine::{
    BackendKind, Engine, EnginePlan, ExecutionBackend, Precision, SimBackend, SlabCache,
};
use unzipfpga::sim::quant::i8_error_bound;
use unzipfpga::util::prng::Xoshiro256;
use unzipfpga::workload::{Layer, Network, RatioProfile};

/// Dense stem, two OVSF convs (one strided, and at T_C = 4 the 8-wide
/// conv1 exercises multiple column tiles), dense classifier.
fn tiny_net() -> Network {
    Network {
        name: "qtiny".into(),
        layers: vec![
            Layer::conv("stem", 8, 8, 4, 8, 3, 1, 1, false),
            Layer::conv("b.conv1", 8, 8, 8, 8, 3, 1, 1, true),
            Layer::conv("b.conv2", 8, 8, 8, 16, 3, 2, 1, true),
            Layer::fc("fc", 16, 10),
        ],
    }
}

fn tiny_plan(rho: f64) -> EnginePlan {
    let net = tiny_net();
    let profile = RatioProfile::uniform(&net, rho);
    Engine::builder()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(DesignPoint::new(8, 4, 8, 4))
        .network(net)
        .profile(profile)
        .plan()
        .unwrap()
}

fn tiny_builder(rho: f64) -> unzipfpga::engine::EngineBuilder {
    let net = tiny_net();
    let profile = RatioProfile::uniform(&net, rho);
    Engine::builder()
        .platform(Platform::z7045())
        .bandwidth(4)
        .design_point(DesignPoint::new(8, 4, 8, 4))
        .network(net)
        .profile(profile)
        .backend(BackendKind::Simulator)
}

fn tiny_input(seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    rng.normal_vec(8 * 8 * 4)
}

#[test]
fn i8_layers_stay_within_the_analytic_bound_across_rho_and_schedules() {
    for rho in [0.25, 1.0] {
        for selective in [true, false] {
            let plan = tiny_plan(rho);
            let input = tiny_input(0x51ab);
            let mut fb = SimBackend::new();
            fb.selective = selective;
            fb.plan(&plan).unwrap();
            let mut qb = SimBackend::new();
            qb.selective = selective;
            qb.precision = Precision::I8;
            qb.plan(&plan).unwrap();
            // Walk the layers in lockstep, feeding BOTH engines the f32
            // path's activations so each layer's error is measured in
            // isolation (no cross-layer error accumulation to untangle).
            let mut cur = input;
            for (idx, layer) in plan.network.layers.iter().enumerate() {
                let of = fb
                    .execute_layer(idx, &cur)
                    .unwrap()
                    .output
                    .expect("numeric f32 output");
                let oq = qb
                    .execute_layer(idx, &cur)
                    .unwrap()
                    .output
                    .expect("numeric i8 output");
                assert_eq!(of.len(), oq.len());
                if layer.ovsf {
                    let hw = synth_hw_weights("qtiny", idx, layer, rho).unwrap();
                    let w_scale = hw.i8_scale();
                    let p = layer.gemm().p as usize;
                    let max_a = cur.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                    // |w| ≤ 127·w_scale: the α-derived scale is an upper
                    // bound on any reconstructed weight.
                    let bound = i8_error_bound(p, 127.0 * w_scale, max_a, w_scale);
                    let max_err = of
                        .iter()
                        .zip(&oq)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0f32, f32::max);
                    assert!(
                        max_err <= bound,
                        "layer {idx} (ρ={rho}, selective={selective}): \
                         error {max_err} exceeds bound {bound}"
                    );
                    assert!(
                        max_err > 0.0,
                        "layer {idx}: the quantised kernel must actually differ"
                    );
                } else {
                    // Dense layers stay f32 on the i8 datapath.
                    assert_eq!(of, oq, "dense layer {idx} must be bit-identical");
                }
                cur = of;
            }
            fb.finish().unwrap();
            qb.finish().unwrap();
        }
    }
}

#[test]
fn i8_slabs_are_four_times_denser_under_the_same_budget() {
    // Budget of exactly one f32 slab (P·T_C·4 = 72·4·4 B). The tiny net
    // streams 6 OVSF slabs of 288 elements each: at f32 only one is ever
    // resident; at i8 (288 B/slab) four fit.
    let budget = 72 * 4 * 4;
    let input = tiny_input(0xd3);
    for (precision, want_resident) in [(Precision::F32, 1), (Precision::I8, 4)] {
        let cache = Arc::new(SlabCache::with_budget(budget));
        let mut engine = tiny_builder(0.5)
            .weights_cache(Arc::clone(&cache))
            .precision(precision)
            .build()
            .unwrap();
        engine.infer(&input).unwrap();
        assert_eq!(
            cache.len(),
            want_resident,
            "{precision}: wrong resident slab count under budget {budget}"
        );
        assert!(cache.resident_bytes() <= budget);
        assert_eq!(cache.misses(), 6);
    }
}

#[test]
fn i8_hit_rate_is_strictly_higher_at_a_budget_that_thrashes_f32() {
    // Two f32 slabs' worth of budget: f32 cycles 6 slabs through 2 seats
    // (the LRU scan pattern never hits), while i8 fits all 6 slabs
    // (6·288 = 1728 B ≤ 2304 B) and the second request is all hits.
    let budget = 2 * 72 * 4 * 4;
    let input = tiny_input(0xd4);
    let mut hits = Vec::new();
    for precision in [Precision::F32, Precision::I8] {
        let cache = Arc::new(SlabCache::with_budget(budget));
        let mut engine = tiny_builder(0.5)
            .weights_cache(Arc::clone(&cache))
            .precision(precision)
            .build()
            .unwrap();
        let a = engine.infer(&input).unwrap().output;
        let b = engine.infer(&input).unwrap().output;
        assert_eq!(a, b, "{precision}: warm and cold requests must agree");
        hits.push(cache.hits());
    }
    assert_eq!(hits[0], 0, "f32 must thrash at this budget");
    assert_eq!(hits[1], 6, "i8 must serve the whole second request warm");
}

#[test]
fn mixed_precision_engines_share_one_cache_without_aliasing() {
    let input = tiny_input(0xc0);
    // Solo references, each on a private cache.
    let solo_f = tiny_builder(0.5)
        .build()
        .unwrap()
        .infer(&input)
        .unwrap()
        .output;
    let solo_q = tiny_builder(0.5)
        .precision(Precision::I8)
        .build()
        .unwrap()
        .infer(&input)
        .unwrap()
        .output;
    assert_ne!(solo_f, solo_q);
    // Same network, both precisions, one shared cache.
    let cache = Arc::new(SlabCache::new());
    let mut ef = tiny_builder(0.5)
        .weights_cache(Arc::clone(&cache))
        .build()
        .unwrap();
    let mut eq = tiny_builder(0.5)
        .weights_cache(Arc::clone(&cache))
        .precision(Precision::I8)
        .build()
        .unwrap();
    let out_f = ef.infer(&input).unwrap().output;
    let out_q = eq.infer(&input).unwrap().output;
    assert_eq!(out_f, solo_f, "sharing must not alias f32 numerics");
    assert_eq!(out_q, solo_q, "sharing must not alias i8 numerics");
    assert_eq!(cache.len(), 12, "6 slabs per precision, no aliasing");
    assert_eq!(cache.misses(), 12);
    // Warm re-serves hit their own precision's slabs.
    ef.infer(&input).unwrap();
    eq.infer(&input).unwrap();
    assert_eq!(cache.misses(), 12);
    assert_eq!(cache.hits(), 12);
}
