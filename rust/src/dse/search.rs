//! Exhaustive DSE for unzipFPGA (paper Eq. 10):
//!
//! `max_σ T(σ, W)  s.t.  rsc(σ) ≤ rsc_avail`
//!
//! The search enumerates the candidate grid, prunes infeasible points on
//! the cheap DSP test first, evaluates the survivors with the analytical
//! model and keeps the argmax. The grid is sharded across threads (the
//! offline crate set has no rayon; plain `std::thread` scoped workers).

use crate::arch::{DesignPoint, Platform};
use crate::error::{Error, Result};
use crate::perf::model::{NetworkPerf, PerfModel};
use crate::rsc::model::{ResourceModel, ResourceUsage};
use crate::workload::{Network, RatioProfile};

/// Candidate grids for each tunable parameter.
#[derive(Clone, Debug)]
pub struct DseConfig {
    /// Candidate M values (wgen vector width).
    pub m: Vec<u64>,
    /// Candidate T_R values.
    pub t_r: Vec<u64>,
    /// Candidate T_P values.
    pub t_p: Vec<u64>,
    /// Candidate T_C values.
    pub t_c: Vec<u64>,
    /// Worker threads for the sweep.
    pub threads: usize,
}

impl Default for DseConfig {
    fn default() -> Self {
        DseConfig {
            m: vec![8, 16, 32, 64, 128, 256],
            t_r: vec![16, 32, 64, 128, 256],
            t_p: vec![4, 8, 16, 32, 64],
            t_c: vec![8, 16, 32, 64, 96, 128, 192, 256],
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(16),
        }
    }
}

impl DseConfig {
    /// Size of the raw candidate grid, without materialising it.
    pub fn n_candidates(&self) -> usize {
        self.m.len() * self.t_r.len() * self.t_p.len() * self.t_c.len()
    }

    /// Enumerate the raw candidate grid.
    pub fn candidates(&self) -> Vec<DesignPoint> {
        let mut out =
            Vec::with_capacity(self.m.len() * self.t_r.len() * self.t_p.len() * self.t_c.len());
        for &m in &self.m {
            for &t_r in &self.t_r {
                for &t_p in &self.t_p {
                    for &t_c in &self.t_c {
                        out.push(DesignPoint::new(m, t_r, t_p, t_c));
                    }
                }
            }
        }
        out
    }
}

/// Outcome of a DSE run.
#[derive(Clone, Debug)]
pub struct DseResult {
    /// Winning design point.
    pub sigma: DesignPoint,
    /// Its predicted performance.
    pub perf: NetworkPerf,
    /// Its resource usage.
    pub usage: ResourceUsage,
    /// Points enumerated.
    pub explored: usize,
    /// Points that passed the resource constraints.
    pub feasible: usize,
}

/// One evaluated feasible point (for sweeps / figures).
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// The design point.
    pub sigma: DesignPoint,
    /// Full predicted performance (per-layer figures included), so the
    /// sweep's argmax can be returned without re-running the model.
    pub perf: NetworkPerf,
    /// Resource usage.
    pub usage: ResourceUsage,
}

impl SweepPoint {
    /// Throughput in inf/s (shorthand for `perf.inf_per_s`).
    pub fn inf_per_s(&self) -> f64 {
        self.perf.inf_per_s
    }
}

/// Evaluate every feasible candidate; returns all of them (unsorted).
pub fn sweep(
    cfg: &DseConfig,
    platform: &Platform,
    bw_mult: u32,
    net: &Network,
    profile: &RatioProfile,
    selective_pes: bool,
) -> Vec<SweepPoint> {
    let candidates = cfg.candidates();
    let rsc = ResourceModel {
        platform: platform.clone(),
        wl_bytes: 2,
        selective_pes,
    };
    let mut perf = PerfModel::new(platform.clone(), bw_mult);
    perf.selective_pes = selective_pes;

    let n_threads = cfg.threads.max(1).min(candidates.len().max(1));
    let chunk = candidates.len().div_ceil(n_threads);
    let mut results: Vec<SweepPoint> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for shard in candidates.chunks(chunk.max(1)) {
            let rsc = &rsc;
            let perf = &perf;
            handles.push(scope.spawn(move || {
                let mut local = Vec::new();
                for &sigma in shard {
                    // Cheap prune: DSP budget (paper prunes violating
                    // configurations "to accelerate the exploration").
                    if sigma.dsps(rsc.platform.dsp_per_mac) > rsc.platform.dsp {
                        continue;
                    }
                    let usage = rsc.usage(&sigma, net, profile);
                    if !rsc.feasible(&usage) {
                        continue;
                    }
                    let p = perf.network_perf(&sigma, net, profile);
                    local.push(SweepPoint {
                        sigma,
                        perf: p,
                        usage,
                    });
                }
                local
            }));
        }
        for h in handles {
            // A DSE worker evaluates a pure analytical model over its grid
            // shard; a panic there is a modelling bug worth crashing the
            // sweep for (silently dropping a shard would corrupt the
            // argmax).
            #[allow(clippy::expect_used)]
            results.extend(h.join().expect("DSE worker panicked"));
        }
    });
    results
}

/// Run the full optimisation (Eq. 10) and return the best design.
pub fn optimise(
    cfg: &DseConfig,
    platform: &Platform,
    bw_mult: u32,
    net: &Network,
    profile: &RatioProfile,
    selective_pes: bool,
) -> Result<DseResult> {
    // One enumeration: the grid size is computed without materialising the
    // candidates a second time, and the winner's NetworkPerf rides along in
    // its SweepPoint — no re-evaluation of the argmax.
    let explored = cfg.n_candidates();
    let points = sweep(cfg, platform, bw_mult, net, profile, selective_pes);
    let feasible = points.len();
    let best = points
        .into_iter()
        .max_by(|a, b| a.inf_per_s().total_cmp(&b.inf_per_s()))
        .ok_or_else(|| Error::NoFeasibleDesign {
            network: net.name.clone(),
            platform: platform.name.to_string(),
        })?;
    Ok(DseResult {
        sigma: best.sigma,
        perf: best.perf,
        usage: best.usage,
        explored,
        feasible,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::resnet;

    #[test]
    fn n_candidates_matches_enumeration() {
        let cfg = DseConfig::default();
        assert_eq!(cfg.n_candidates(), cfg.candidates().len());
    }

    #[test]
    fn finds_feasible_optimum_on_z7045() {
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf50(&net);
        let cfg = DseConfig::default();
        let r = optimise(&cfg, &Platform::z7045(), 4, &net, &profile, true).unwrap();
        assert!(r.feasible > 0 && r.feasible <= r.explored);
        assert!(r.usage.dsps <= 900);
        assert!(r.perf.inf_per_s > 1.0, "ResNet18 should exceed 1 inf/s");
        // The optimum should use a substantial share of the DSP budget.
        assert!(
            r.usage.dsps as f64 >= 0.5 * 900.0,
            "optimum uses only {} DSPs",
            r.usage.dsps
        );
    }

    #[test]
    fn optimum_is_argmax_of_sweep() {
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf50(&net);
        let mut cfg = DseConfig::default();
        cfg.m = vec![32, 64];
        cfg.t_r = vec![32, 64];
        cfg.t_p = vec![8, 16];
        cfg.t_c = vec![32, 64];
        let pts = sweep(&cfg, &Platform::z7045(), 4, &net, &profile, true);
        let best_sweep = pts
            .iter()
            .map(|p| p.inf_per_s())
            .fold(f64::MIN, f64::max);
        let r = optimise(&cfg, &Platform::z7045(), 4, &net, &profile, true).unwrap();
        assert!((r.perf.inf_per_s - best_sweep).abs() < 1e-9);
    }

    #[test]
    fn more_bandwidth_never_hurts() {
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf50(&net);
        let cfg = DseConfig::default();
        let r1 = optimise(&cfg, &Platform::z7045(), 1, &net, &profile, true).unwrap();
        let r4 = optimise(&cfg, &Platform::z7045(), 4, &net, &profile, true).unwrap();
        assert!(r4.perf.inf_per_s >= r1.perf.inf_per_s * 0.999);
    }

    #[test]
    fn bigger_platform_is_faster() {
        let net = resnet::resnet50();
        let profile = RatioProfile::ovsf50(&net);
        let cfg = DseConfig::default();
        let z = optimise(&cfg, &Platform::z7045(), 4, &net, &profile, true).unwrap();
        let u = optimise(&cfg, &Platform::zu7ev(), 4, &net, &profile, true).unwrap();
        assert!(u.perf.inf_per_s > z.perf.inf_per_s);
    }

    #[test]
    fn infeasible_when_grid_exceeds_platform() {
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf50(&net);
        let cfg = DseConfig {
            m: vec![512],
            t_r: vec![64],
            t_p: vec![64],
            t_c: vec![256], // 512 + 16384 MACs ≫ 900 DSPs
            threads: 2,
        };
        assert!(optimise(&cfg, &Platform::z7045(), 4, &net, &profile, true).is_err());
    }
}
