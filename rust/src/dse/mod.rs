//! Design-space exploration (paper §5.3, Eq. 10): exhaustive search over
//! `σ = ⟨M, T_R, T_P, T_C⟩` under the platform's resource constraints —
//! plus the layer-range partitioner that carves a deep model into
//! pipeline-parallel stages, each free to pick its own σ.

pub mod greedy;
pub mod partition;
pub mod roofline;
pub mod search;

pub use partition::{partition_stages, valid_boundaries};
pub use roofline::baseline_optimise;
pub use search::{optimise, sweep, DseConfig, DseResult};
