//! Design-space exploration (paper §5.3, Eq. 10): exhaustive search over
//! `σ = ⟨M, T_R, T_P, T_C⟩` under the platform's resource constraints.

pub mod greedy;
pub mod roofline;
pub mod search;

pub use roofline::baseline_optimise;
pub use search::{optimise, sweep, DseConfig, DseResult};
