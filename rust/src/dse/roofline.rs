//! Baseline DSE — the paper's optimised conventional engine (§7.1.4):
//! the same `⟨T_R, T_P, T_C⟩` tile space explored with roofline-style
//! modelling (Zhang et al. [102]), with weights streamed from off-chip or
//! pinned on-chip when they fit the leftover BRAM.

use crate::arch::{DesignPoint, Platform};
use crate::error::{Error, Result};
use crate::perf::model::{NetworkPerf, PerfModel, WeightsSource};
use crate::rsc::model::{ResourceModel, ResourceUsage};
use crate::workload::{Network, RatioProfile};

use super::search::DseConfig;

/// Decide each layer's weights source for a baseline design: weights that
/// fit the BRAM left over after the activation buffers are pinned on-chip,
/// everything else streams per-tile.
pub fn baseline_sources(
    platform: &Platform,
    sigma: &DesignPoint,
    net: &Network,
    wl_bytes: u64,
) -> Vec<WeightsSource> {
    // Leftover after double-buffered I/O activations + the T_P×T_C
    // double-buffered weights tile buffer of the conventional engine.
    let io = 2 * (sigma.t_r * sigma.t_p + sigma.t_r * sigma.t_c) * wl_bytes;
    let wtile = 2 * sigma.t_p * sigma.t_c * wl_bytes;
    let mut leftover = platform.bram_bytes.saturating_sub(io + wtile);
    net.layers
        .iter()
        .map(|l| {
            let bytes = l.params() * wl_bytes;
            if bytes <= leftover {
                leftover -= bytes;
                WeightsSource::OnChip
            } else {
                WeightsSource::OffChip
            }
        })
        .collect()
}

/// Result of a baseline DSE run.
#[derive(Clone, Debug)]
pub struct BaselineResult {
    /// Winning tile configuration (M = 0: no weights generator).
    pub sigma: DesignPoint,
    /// Predicted performance.
    pub perf: NetworkPerf,
    /// Resource usage.
    pub usage: ResourceUsage,
}

/// Optimise the conventional engine for a network (vanilla or pruned).
pub fn baseline_optimise(
    cfg: &DseConfig,
    platform: &Platform,
    bw_mult: u32,
    net: &Network,
) -> Result<BaselineResult> {
    let rsc = ResourceModel {
        platform: platform.clone(),
        wl_bytes: 2,
        selective_pes: false,
    };
    let mut perf_model = PerfModel::new(platform.clone(), bw_mult);
    perf_model.selective_pes = false;
    // The baseline ignores OVSF ratios entirely; a dummy profile keeps the
    // resource-model interface uniform (α volume is zero with M = 0).
    let dummy = RatioProfile::uniform(net, 1.0);

    let mut best: Option<BaselineResult> = None;
    for &t_r in &cfg.t_r {
        for &t_p in &cfg.t_p {
            for &t_c in &cfg.t_c {
                let sigma = DesignPoint::new(0, t_r, t_p, t_c);
                if sigma.dsps(platform.dsp_per_mac) > platform.dsp {
                    continue;
                }
                let usage = rsc.usage(&sigma, net, &dummy);
                if !rsc.feasible(&usage) {
                    continue;
                }
                let sources = baseline_sources(platform, &sigma, net, 2);
                let perf = perf_model.network_perf_with_sources(&sigma, net, &sources);
                if best
                    .as_ref()
                    .map(|b| perf.inf_per_s > b.perf.inf_per_s)
                    .unwrap_or(true)
                {
                    best = Some(BaselineResult { sigma, perf, usage });
                }
            }
        }
    }
    best.ok_or_else(|| Error::NoFeasibleDesign {
        network: net.name.clone(),
        platform: platform.name.to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{resnet, squeezenet};

    #[test]
    fn small_layers_get_pinned_on_chip() {
        let net = squeezenet::squeezenet1_1();
        let sigma = DesignPoint::new(0, 64, 16, 48);
        let srcs = baseline_sources(&Platform::zu7ev(), &sigma, &net, 2);
        // SqueezeNet is only 2.5 MB at 16-bit: most layers fit ZU7EV BRAM.
        let on_chip = srcs
            .iter()
            .filter(|s| matches!(s, WeightsSource::OnChip))
            .count();
        assert!(on_chip > net.layers.len() / 2, "{on_chip} pinned");
    }

    #[test]
    fn big_resnet_streams_weights() {
        let net = resnet::resnet50();
        let sigma = DesignPoint::new(0, 64, 16, 48);
        let srcs = baseline_sources(&Platform::z7045(), &sigma, &net, 2);
        let off_chip = srcs
            .iter()
            .filter(|s| matches!(s, WeightsSource::OffChip))
            .count();
        assert!(
            off_chip > net.layers.len() / 2,
            "ResNet50 (51 MB) cannot fit Z7045 BRAM"
        );
    }

    #[test]
    fn baseline_dse_runs() {
        let net = resnet::resnet18();
        let cfg = DseConfig::default();
        let r = baseline_optimise(&cfg, &Platform::z7045(), 4, &net).unwrap();
        assert_eq!(r.sigma.m, 0, "baseline has no weights generator");
        assert!(r.perf.inf_per_s > 1.0);
    }

    #[test]
    fn baseline_improves_with_bandwidth() {
        let net = resnet::resnet34();
        let cfg = DseConfig::default();
        let r1 = baseline_optimise(&cfg, &Platform::z7045(), 1, &net).unwrap();
        let r4 = baseline_optimise(&cfg, &Platform::z7045(), 4, &net).unwrap();
        // The vanilla baseline is memory-bound at 1×: quadrupling bandwidth
        // should give a large (≫1.5×) gain, mirroring Tables 4–5.
        assert!(
            r4.perf.inf_per_s / r1.perf.inf_per_s > 1.5,
            "got {}→{}",
            r1.perf.inf_per_s,
            r4.perf.inf_per_s
        );
    }
}
