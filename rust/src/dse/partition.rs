//! Layer-range partitioning for pipeline-parallel engine stages.
//!
//! A deep model is split into K contiguous layer ranges, each compiled and
//! served by its own engine stage
//! ([`Compiler::split`](crate::engine::compile::Compiler::split),
//! [`StagePipeline`](crate::coordinator::stage::StagePipeline)). Not every
//! boundary is cuttable: a stage hands its raw output buffer to the next
//! stage's admission check, so a cut is valid only where the producing
//! layer's output feature map is *exactly* the consuming layer's input
//! shape ([`Layer::chains_to`](crate::workload::Layer::chains_to)) — the
//! workload's layer lists fold pooling/residual wiring away, and those
//! folded reshapes can only happen inside a stage, never across one.
//!
//! Among the valid cut points the partitioner balances per-stage MACs (the
//! throughput of a pipeline is set by its slowest stage): each of the K−1
//! cuts greedily picks the valid boundary whose MACs prefix is closest to
//! the ideal `total·j/K`, while always leaving enough boundaries for the
//! cuts still to be placed.

use std::ops::Range;

use crate::error::{Error, Result};
use crate::workload::Network;

/// The boundaries of `net` where a pipeline cut is valid: every `b` such
/// that layer `b−1` chains exactly into layer `b` (a cut at `b` puts
/// layers `..b` and `b..` in different stages).
pub fn valid_boundaries(net: &Network) -> Vec<usize> {
    (1..net.layers.len())
        .filter(|&b| net.layers[b - 1].chains_to(&net.layers[b]))
        .collect()
}

/// Choose K contiguous, MACs-balanced layer ranges over `net`'s valid cut
/// points. Returns ranges covering `0..layers.len()` exactly; typed
/// [`Error::InvalidConfig`] when `k` is 0, the network is empty, or the
/// network has fewer than `k−1` valid boundaries.
pub fn partition_stages(net: &Network, k: usize) -> Result<Vec<Range<usize>>> {
    let n = net.layers.len();
    if k == 0 {
        return Err(Error::InvalidConfig(
            "a pipeline needs at least one stage (K = 0)".into(),
        ));
    }
    if n == 0 {
        return Err(Error::InvalidConfig(format!(
            "cannot partition empty network '{}'",
            net.name
        )));
    }
    if k == 1 {
        return Ok(vec![0..n]);
    }
    let candidates = valid_boundaries(net);
    if candidates.len() < k - 1 {
        return Err(Error::InvalidConfig(format!(
            "network '{}' has {} valid cut points but K = {k} stages need {}: \
             only exact activation hand-offs are cuttable",
            net.name,
            candidates.len(),
            k - 1
        )));
    }
    let mut prefix = vec![0u64; n + 1];
    for (i, l) in net.layers.iter().enumerate() {
        prefix[i + 1] = prefix[i] + l.macs();
    }
    let total = prefix[n];
    // Greedy balanced cuts: for the j-th cut aim at the `total·j/K` MACs
    // prefix, restricted to candidates after the previous cut and leaving
    // one candidate per cut still unplaced (so the choice is always
    // completable).
    let mut cuts = Vec::with_capacity(k - 1);
    let mut lo = 0usize;
    for j in 1..k {
        let target = total as f64 * j as f64 / k as f64;
        let hi = candidates.len() - (k - 1 - j);
        let mut best = lo;
        for i in lo..hi {
            let d = (prefix[candidates[i]] as f64 - target).abs();
            if d < (prefix[candidates[best]] as f64 - target).abs() {
                best = i;
            }
        }
        cuts.push(candidates[best]);
        lo = best + 1;
    }
    let mut ranges = Vec::with_capacity(k);
    let mut start = 0usize;
    for c in cuts {
        ranges.push(start..c);
        start = c;
    }
    ranges.push(start..n);
    Ok(ranges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::tiny::{small_resnet, tiny_resnet};

    #[test]
    fn boundaries_respect_exact_chaining_only() {
        let net = tiny_resnet();
        // stem→conv1 and conv1→conv2 chain; conv2 (strided, 4·4·16 out)
        // does not chain into the flat fc (1·1·16 in).
        assert_eq!(valid_boundaries(&net), vec![1, 2]);
        let net = small_resnet();
        assert_eq!(valid_boundaries(&net), vec![1, 2, 3]);
    }

    #[test]
    fn partitions_cover_and_balance() {
        let net = small_resnet();
        for k in 1..=4 {
            let ranges = partition_stages(&net, k).unwrap();
            assert_eq!(ranges.len(), k);
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, net.layers.len());
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "contiguous");
                let b = w[0].end;
                assert!(
                    net.layers[b - 1].chains_to(&net.layers[b]),
                    "cut at {b} must be a valid boundary"
                );
            }
        }
        // K=2 puts the cut at the MACs midpoint among {1, 2, 3}: the heavy
        // middle convs must not all land in one stage.
        let halves = partition_stages(&net, 2).unwrap();
        let macs = |r: &Range<usize>| -> u64 { net.layers[r.clone()].iter().map(|l| l.macs()).sum() };
        let (a, b) = (macs(&halves[0]), macs(&halves[1]));
        let imbalance = a.abs_diff(b) as f64 / (a + b) as f64;
        assert!(imbalance < 0.8, "grossly unbalanced split: {a} vs {b}");
    }

    #[test]
    fn infeasible_counts_are_typed() {
        let net = tiny_resnet();
        assert!(matches!(
            partition_stages(&net, 0),
            Err(Error::InvalidConfig(_))
        ));
        // tiny_resnet has 2 valid boundaries → K=4 needs 3.
        assert!(matches!(
            partition_stages(&net, 4),
            Err(Error::InvalidConfig(_))
        ));
        assert_eq!(partition_stages(&net, 1).unwrap(), vec![0..4]);
        assert_eq!(partition_stages(&net, 3).unwrap(), vec![0..1, 1..2, 2..4]);
    }
}
