//! Thin wrapper around the PJRT CPU client (`xla` crate).
//!
//! The `xla` crate cannot be resolved in the offline build environment, so
//! the real client is gated behind the `pjrt` feature. Without it, an
//! API-compatible stub stands in: construction succeeds (so registries and
//! path logic keep working) but any attempt to execute returns
//! [`Error::RuntimeUnavailable`](crate::Error::RuntimeUnavailable).

#[cfg(feature = "pjrt")]
mod real {
    use crate::error::Result;

    /// A PJRT client handle. One per process; executables borrow it.
    pub struct RuntimeClient {
        client: xla::PjRtClient,
    }

    impl RuntimeClient {
        /// Create the CPU client.
        pub fn cpu() -> Result<Self> {
            Ok(Self {
                client: xla::PjRtClient::cpu()?,
            })
        }

        /// Platform name reported by PJRT.
        pub fn platform_name(&self) -> String {
            self.client.platform_name()
        }

        /// Device count.
        pub fn device_count(&self) -> usize {
            self.client.device_count()
        }

        /// Access the raw client (for compilation).
        pub(crate) fn raw(&self) -> &xla::PjRtClient {
            &self.client
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use crate::error::Result;

    /// Stub PJRT client used when the crate is built without the `pjrt`
    /// feature. Construction succeeds so higher layers (registries, path
    /// resolution, skip-if-missing tests) behave identically; execution
    /// paths report [`crate::Error::RuntimeUnavailable`].
    pub struct RuntimeClient;

    impl RuntimeClient {
        /// Create the (stub) CPU client.
        pub fn cpu() -> Result<Self> {
            Ok(Self)
        }

        /// Platform name; flags the stub so logs are unambiguous.
        pub fn platform_name(&self) -> String {
            "cpu (pjrt feature disabled — stub)".into()
        }

        /// Device count: the stub exposes no devices.
        pub fn device_count(&self) -> usize {
            0
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::RuntimeClient;
#[cfg(not(feature = "pjrt"))]
pub use stub::RuntimeClient;

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "pjrt")]
    #[test]
    fn cpu_client_comes_up() {
        let c = RuntimeClient::cpu().expect("PJRT CPU client");
        assert!(c.device_count() >= 1);
        assert!(!c.platform_name().is_empty());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_client_is_inert_but_constructible() {
        let c = RuntimeClient::cpu().expect("stub client");
        assert_eq!(c.device_count(), 0);
        assert!(c.platform_name().contains("stub"));
    }
}
