//! Thin wrapper around the PJRT CPU client (`xla` crate).

use crate::error::Result;

/// A PJRT client handle. One per process; executables borrow it.
pub struct RuntimeClient {
    client: xla::PjRtClient,
}

impl RuntimeClient {
    /// Create the CPU client.
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu()?,
        })
    }

    /// Platform name reported by PJRT.
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Device count.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Access the raw client (for compilation).
    pub(crate) fn raw(&self) -> &xla::PjRtClient {
        &self.client
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let c = RuntimeClient::cpu().expect("PJRT CPU client");
        assert!(c.device_count() >= 1);
        assert!(!c.platform_name().is_empty());
    }
}
