//! Loading and executing AOT artifacts.
//!
//! Interchange format is **HLO text** (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md). All artifacts are
//! lowered with `return_tuple=True`, so results unwrap with `to_tuple`.
//!
//! Without the `pjrt` feature the loader performs the same existence checks
//! (so "missing artifact" errors stay actionable) but compilation and
//! execution return [`Error::RuntimeUnavailable`].

use crate::error::{Error, Result};
use crate::runtime::client::RuntimeClient;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled PJRT executable loaded from an HLO-text artifact.
pub struct LoadedExecutable {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    /// Artifact path (diagnostics).
    pub path: PathBuf,
}

impl LoadedExecutable {
    /// Load + compile an HLO-text file.
    pub fn load(client: &RuntimeClient, path: &Path) -> Result<Self> {
        if !path.exists() {
            return Err(Error::MissingArtifact {
                path: path.display().to_string(),
                source: std::io::Error::new(std::io::ErrorKind::NotFound, "no such file"),
            });
        }
        Self::compile(client, path)
    }

    #[cfg(feature = "pjrt")]
    fn compile(client: &RuntimeClient, path: &Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::InvalidConfig(format!("non-utf8 path {path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.raw().compile(&comp)?;
        Ok(Self {
            exe,
            path: path.to_path_buf(),
        })
    }

    #[cfg(not(feature = "pjrt"))]
    fn compile(_client: &RuntimeClient, _path: &Path) -> Result<Self> {
        Err(Error::RuntimeUnavailable)
    }

    /// Execute with f32 buffers: each input is `(data, dims)`. The artifact
    /// must return a tuple; all tuple elements are returned as flat f32
    /// vectors with their dimensions.
    #[cfg(feature = "pjrt")]
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data);
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims_i64).map_err(Error::from)
            })
            .collect::<Result<_>>()?;
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let tuple = result.decompose_tuple()?;
        let mut out = Vec::with_capacity(tuple.len());
        for t in tuple {
            out.push(t.to_vec::<f32>()?);
        }
        Ok(out)
    }

    /// Execute with f32 buffers (stub: always
    /// [`Error::RuntimeUnavailable`]).
    #[cfg(not(feature = "pjrt"))]
    pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        Err(Error::RuntimeUnavailable)
    }
}

/// A registry of named artifacts in a directory, compiled lazily and cached.
pub struct ArtifactRegistry {
    client: RuntimeClient,
    dir: PathBuf,
    cache: HashMap<String, LoadedExecutable>,
}

impl ArtifactRegistry {
    /// Registry over `dir` with a fresh CPU client.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        Ok(Self {
            client: RuntimeClient::cpu()?,
            dir: dir.into(),
            cache: HashMap::new(),
        })
    }

    /// Artifact path for a name (`<dir>/<name>.hlo.txt`).
    pub fn path_of(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// `true` if the artifact file exists.
    pub fn has(&self, name: &str) -> bool {
        self.path_of(name).exists()
    }

    /// Get (compile-on-first-use) an executable by name.
    pub fn get(&mut self, name: &str) -> Result<&LoadedExecutable> {
        if !self.cache.contains_key(name) {
            let exe = LoadedExecutable::load(&self.client, &self.path_of(name))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// The underlying client.
    pub fn client(&self) -> &RuntimeClient {
        &self.client
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let client = RuntimeClient::cpu().unwrap();
        let err = LoadedExecutable::load(&client, Path::new("/nonexistent/x.hlo.txt"))
            .err()
            .expect("must fail");
        let msg = err.to_string();
        assert!(msg.contains("make artifacts"), "actionable message: {msg}");
    }

    #[test]
    fn registry_paths() {
        let reg = ArtifactRegistry::new("/tmp/unzipfpga-test-artifacts").unwrap();
        assert_eq!(
            reg.path_of("model"),
            PathBuf::from("/tmp/unzipfpga-test-artifacts/model.hlo.txt")
        );
        assert!(!reg.has("definitely-not-there"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn present_artifact_without_pjrt_reports_runtime_unavailable() {
        let dir = std::env::temp_dir().join("unzipfpga-stub-artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("present.hlo.txt");
        std::fs::write(&path, "HloModule present").unwrap();
        let client = RuntimeClient::cpu().unwrap();
        let err = LoadedExecutable::load(&client, &path).err().expect("stub must refuse");
        assert!(matches!(err, Error::RuntimeUnavailable));
    }
}
