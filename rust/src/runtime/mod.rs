//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts (HLO text,
//! see `python/compile/aot.py`) and executes them on the XLA CPU client.
//! Python never runs on this path — the artifacts are self-contained.
//!
//! The `xla` crate behind the client is gated by the `pjrt` feature (it
//! cannot be resolved in the offline build). Without the feature, the same
//! API compiles as inert stubs whose execution paths return
//! [`Error::RuntimeUnavailable`](crate::Error::RuntimeUnavailable), so the
//! rest of the stack (engine, pool, tests) keeps working and skips loudly.

pub mod client;
pub mod executable;

pub use client::RuntimeClient;
pub use executable::{ArtifactRegistry, LoadedExecutable};

/// Default artifacts directory, overridable with `UNZIPFPGA_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("UNZIPFPGA_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
