//! Static comparison rows for Tables 7–8: published figures of prior FPGA
//! accelerators, quoted from the paper (those systems are closed-source;
//! the paper itself compares against their published numbers).

/// One prior-work accelerator row.
#[derive(Clone, Debug)]
pub struct PriorWork {
    /// System name / citation tag.
    pub name: &'static str,
    /// Benchmark network.
    pub network: &'static str,
    /// Target FPGA.
    pub fpga: &'static str,
    /// Clock in MHz.
    pub clock_mhz: u32,
    /// Precision description.
    pub precision: &'static str,
    /// DSP blocks on the device.
    pub dsps: u32,
    /// Logic capacity in kLUTs (or kALMs for Intel parts).
    pub klut: f64,
    /// Block RAM in MB.
    pub bram_mb: f64,
    /// Reported throughput (inf/s).
    pub inf_s: f64,
    /// Reported inf/s/DSP (precision-adjusted as in the paper: ×0.5 for 8b).
    pub inf_s_dsp: f64,
    /// Reported inf/s/kLUT.
    pub inf_s_logic: f64,
}

/// Table 7 rows (ResNet18/34 + SqueezeNet designs).
pub fn table7_rows() -> Vec<PriorWork> {
    vec![
        PriorWork {
            name: "Compiler-based [17]",
            network: "ResNet18",
            fpga: "Z7045",
            clock_mhz: 250,
            precision: "16b fixed",
            dsps: 900,
            klut: 218.6,
            bram_mb: 2.40,
            inf_s: 21.38,
            inf_s_dsp: 0.0237,
            inf_s_logic: 0.0978,
        },
        PriorWork {
            name: "Sparse-CNN (Deep Compression) [59]",
            network: "ResNet34",
            fpga: "Z7045",
            clock_mhz: 166,
            precision: "16b fixed",
            dsps: 900,
            klut: 218.6,
            bram_mb: 2.40,
            inf_s: 27.84,
            inf_s_dsp: 0.0309,
            inf_s_logic: 0.1273,
        },
        PriorWork {
            name: "Light-OPU [100]",
            network: "SqueezeNet",
            fpga: "K325T",
            clock_mhz: 200,
            precision: "8b fixed",
            dsps: 840,
            klut: 203.8,
            bram_mb: 1.95,
            inf_s: 420.90,
            inf_s_dsp: 0.2505,
            inf_s_logic: 2.0652,
        },
        PriorWork {
            name: "Multi-accelerator V485T [75]",
            network: "SqueezeNet",
            fpga: "V485T",
            clock_mhz: 170,
            precision: "16b fixed",
            dsps: 2800,
            klut: 303.6,
            bram_mb: 4.52,
            inf_s: 913.40,
            inf_s_dsp: 0.3260,
            inf_s_logic: 3.0085,
        },
        PriorWork {
            name: "Multi-accelerator V690T [75]",
            network: "SqueezeNet",
            fpga: "V690T",
            clock_mhz: 170,
            precision: "16b fixed",
            dsps: 3600,
            klut: 433.2,
            bram_mb: 6.46,
            inf_s: 1173.00,
            inf_s_dsp: 0.3258,
            inf_s_logic: 2.7077,
        },
    ]
}

/// Table 8 rows (ResNet50 designs).
pub fn table8_rows() -> Vec<PriorWork> {
    vec![
        PriorWork {
            name: "Snowflake [31]",
            network: "ResNet50",
            fpga: "Z7045",
            clock_mhz: 250,
            precision: "16b fixed",
            dsps: 900,
            klut: 218.6,
            bram_mb: 2.40,
            inf_s: 17.7,
            inf_s_dsp: 0.0196,
            inf_s_logic: 0.0809,
        },
        PriorWork {
            name: "xDNN [95]",
            network: "ResNet50",
            fpga: "VU9P",
            clock_mhz: 500,
            precision: "8b fixed",
            dsps: 6840,
            klut: 1182.0,
            bram_mb: 9.48,
            inf_s: 153.57,
            inf_s_dsp: 0.0112,
            inf_s_logic: 0.0649,
        },
        PriorWork {
            name: "DNNVM [96]",
            network: "ResNet50",
            fpga: "ZU9",
            clock_mhz: 500,
            precision: "8b fixed",
            dsps: 2520,
            klut: 274.0,
            bram_mb: 4.01,
            inf_s: 80.95,
            inf_s_dsp: 0.016,
            inf_s_logic: 0.1477,
        },
        PriorWork {
            name: "ALAMO (Arria10) [62]",
            network: "ResNet50",
            fpga: "GX1150",
            clock_mhz: 240,
            precision: "16b fixed",
            dsps: 3036,
            klut: 427.2,
            bram_mb: 6.60,
            inf_s: 71.38,
            inf_s_dsp: 0.0235,
            inf_s_logic: 0.1671,
        },
        PriorWork {
            name: "ALAMO (Stratix10) [62]",
            network: "ResNet50",
            fpga: "GX2800",
            clock_mhz: 150,
            precision: "16b fixed",
            dsps: 11520,
            klut: 933.0,
            bram_mb: 28.62,
            inf_s: 77.55,
            inf_s_dsp: 0.0067,
            inf_s_logic: 0.0831,
        },
        PriorWork {
            name: "ResNetAccel [63]",
            network: "ResNet50",
            fpga: "GX1150",
            clock_mhz: 300,
            precision: "16b fixed",
            dsps: 3036,
            klut: 427.2,
            bram_mb: 6.60,
            inf_s: 33.93,
            inf_s_dsp: 0.0111,
            inf_s_logic: 0.0794,
        },
        PriorWork {
            name: "FTDL [76]",
            network: "ResNet50",
            fpga: "VU125",
            clock_mhz: 650,
            precision: "16b fixed",
            dsps: 1200,
            klut: 716.0,
            bram_mb: 11.075,
            inf_s: 151.22,
            inf_s_dsp: 0.1260,
            inf_s_logic: 0.2112,
        },
        PriorWork {
            name: "Cloud-DNN [19]",
            network: "ResNet50",
            fpga: "VU9P",
            clock_mhz: 125,
            precision: "16b fixed",
            dsps: 6840,
            klut: 1182.0,
            bram_mb: 43.23,
            inf_s: 71.94,
            inf_s_dsp: 0.0105,
            inf_s_logic: 0.0608,
        },
        PriorWork {
            name: "Interconnect-aware [73]",
            network: "ResNet50",
            fpga: "VU37P",
            clock_mhz: 650,
            precision: "8b fixed",
            dsps: 9024,
            klut: 1304.0,
            bram_mb: 42.61,
            inf_s: 766.0,
            inf_s_dsp: 0.0424,
            inf_s_logic: 0.5874,
        },
        PriorWork {
            name: "Full-stack [58]",
            network: "ResNet50",
            fpga: "GX1150",
            clock_mhz: 200,
            precision: "8b fixed",
            dsps: 3036,
            klut: 427.2,
            bram_mb: 6.60,
            inf_s: 197.23,
            inf_s_dsp: 0.0324,
            inf_s_logic: 0.4616,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_internally_consistent() {
        // inf/s/LUT must equal inf_s / klut within the paper's rounding —
        // for the 16-bit rows. (The paper applies its ×0.5 8-bit adjustment
        // to the logic column of some 8-bit rows but not others; those rows
        // are quoted verbatim.)
        for row in table7_rows().iter().chain(table8_rows().iter()) {
            if row.precision.starts_with("8b") {
                continue;
            }
            let derived = row.inf_s / row.klut;
            assert!(
                (derived - row.inf_s_logic).abs() / row.inf_s_logic < 0.02,
                "{}: derived {derived} vs quoted {}",
                row.name,
                row.inf_s_logic
            );
        }
    }

    #[test]
    fn precision_adjustment_applied_to_8b_rows() {
        // 8-bit rows carry the paper's ×0.5 DSP adjustment: their quoted
        // inf/s/DSP is half the raw inf_s/dsps.
        for row in table8_rows() {
            let raw = row.inf_s / row.dsps as f64;
            let factor = row.inf_s_dsp / raw;
            if row.precision.starts_with("8b") {
                assert!((factor - 0.5).abs() < 0.05, "{}: {factor}", row.name);
            } else {
                assert!((factor - 1.0).abs() < 0.05, "{}: {factor}", row.name);
            }
        }
    }

    #[test]
    fn table_sizes() {
        assert_eq!(table7_rows().len(), 5);
        assert_eq!(table8_rows().len(), 10);
    }
}
