//! Taylor channel pruning baseline (paper §7.1.4-b, Molchanov et al. [65]).
//!
//! The paper prunes channels by their first-order Taylor contribution to
//! the loss, iteratively, until a target keep-ratio is reached ("Tay82"
//! keeps 82% of filters). For the *hardware* evaluation only the pruned
//! layer shapes matter. The paper's reported parameter counts scale
//! ≈ linearly with the keep-ratio (e.g. ResNet34 Tay82: 17.4M ≈ 0.80×
//! 21.8M), which corresponds to scaling each prunable layer's channel
//! count by √keep. Accuracy anchors come from Tables 4–5.
//!
//! A *criterion-level* implementation (scores → iterative drop) is also
//! provided and exercised on synthetic gradients, preserving the paper's
//! mechanism even though ImageNet gradients are out of scope.

use crate::workload::layer::LayerKind;
use crate::workload::Network;

/// Channel-pruning transformer.
#[derive(Clone, Debug)]
pub struct TaylorPruner {
    /// Fraction of filters kept (e.g. 0.82 for Tay82).
    pub keep: f64,
}

impl TaylorPruner {
    /// Pruner at a keep-ratio.
    pub fn new(keep: f64) -> Self {
        assert!(keep > 0.0 && keep <= 1.0);
        Self { keep }
    }

    /// The paper's naming: `Tay82` etc.
    pub fn name(&self) -> String {
        format!("Tay{:.0}", self.keep * 100.0)
    }

    /// Scale a channel count by √keep, keeping at least 1 and rounding to a
    /// hardware-friendly multiple of 4 where possible.
    fn scale(&self, ch: u64) -> u64 {
        let s = (ch as f64 * self.keep.sqrt()).round() as u64;
        let s = s.max(1);
        if s >= 8 {
            (s / 4) * 4
        } else {
            s
        }
    }

    /// Produce the pruned network: channel counts shrink by √keep on every
    /// prunable layer, with input channels chained to the producing layer.
    /// The stem input (3) and classifier output (1000) stay fixed.
    pub fn prune(&self, net: &Network) -> Network {
        let mut layers = Vec::with_capacity(net.layers.len());
        for (i, l) in net.layers.iter().enumerate() {
            let mut nl = l.clone();
            // Input channels follow the upstream pruning except the stem.
            if i > 0 && l.n_in > 3 {
                nl.n_in = self.scale(l.n_in);
            }
            // Output channels pruned except the final classifier.
            let is_classifier =
                i == net.layers.len() - 1 || (l.kind == LayerKind::Fc) || l.n_out == 1000;
            if !is_classifier {
                nl.n_out = self.scale(l.n_out);
            }
            layers.push(nl);
        }
        Network {
            name: format!("{}-{}", net.name, self.name()),
            layers,
        }
    }

    /// Paper-anchored top-1 accuracy for the pruned variant of a benchmark
    /// (linear interpolation between the reported keep-ratio anchors).
    pub fn top1(&self, net: &Network) -> Option<f64> {
        let anchors: &[(f64, f64)] = match net.name.as_str() {
            "ResNet34" => &[(0.45, 63.1), (0.56, 67.8), (0.72, 71.9), (0.82, 72.7), (1.0, 73.3)],
            "ResNet18" => &[(0.56, 58.3), (0.72, 64.8), (0.82, 67.3), (0.88, 68.8), (1.0, 69.8)],
            _ => return None,
        };
        let k = self.keep;
        if k <= anchors[0].0 {
            return Some(anchors[0].1);
        }
        for w in anchors.windows(2) {
            let (x0, y0) = w[0];
            let (x1, y1) = w[1];
            if k <= x1 {
                return Some(y0 + (y1 - y0) * (k - x0) / (x1 - x0));
            }
        }
        Some(anchors[anchors.len() - 1].1)
    }
}

/// First-order Taylor importance of a filter: `|Σ w·g|` over its weights
/// and gradients (Molchanov et al.). Exercised on synthetic models in tests
/// and the Python trainer.
pub fn taylor_score(weights: &[f32], grads: &[f32]) -> f64 {
    assert_eq!(weights.len(), grads.len());
    weights
        .iter()
        .zip(grads)
        .map(|(&w, &g)| (w as f64) * (g as f64))
        .sum::<f64>()
        .abs()
}

/// Iteratively drop the lowest-scoring filters until `keep`·N survive;
/// returns the surviving indices (ascending).
pub fn iterative_taylor_prune(scores: &[f64], keep: f64) -> Vec<usize> {
    let n = scores.len();
    let target = ((n as f64 * keep).round() as usize).clamp(1, n);
    let mut live: Vec<usize> = (0..n).collect();
    while live.len() > target {
        let Some((pos, _)) = live
            .iter()
            .enumerate()
            .min_by(|(_, &a), (_, &b)| scores[a].total_cmp(&scores[b]))
        else {
            break; // unreachable: live.len() > target ≥ 1
        };
        live.remove(pos);
    }
    live
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::resnet;

    #[test]
    fn params_scale_linearly_with_keep() {
        // The calibration target: Tay82 on ResNet34 ⇒ ≈17.4M params.
        let net = resnet::resnet34();
        let pruned = TaylorPruner::new(0.82).prune(&net);
        let ratio = pruned.params() as f64 / net.params() as f64;
        assert!(
            (ratio - 0.80).abs() < 0.06,
            "Tay82 params ratio {ratio:.3} vs paper ≈0.80"
        );
        let p_m = pruned.params() as f64 / 1e6;
        assert!((p_m - 17.4).abs() < 1.6, "Tay82 {p_m}M vs paper 17.4M");
    }

    #[test]
    fn deeper_prune_means_fewer_params() {
        let net = resnet::resnet18();
        let mut prev = net.params();
        for keep in [0.88, 0.82, 0.72, 0.56] {
            let p = TaylorPruner::new(keep).prune(&net).params();
            assert!(p < prev, "params must shrink at keep={keep}");
            prev = p;
        }
    }

    #[test]
    fn classifier_shape_preserved() {
        let net = resnet::resnet18();
        let pruned = TaylorPruner::new(0.56).prune(&net);
        assert_eq!(pruned.layers.last().unwrap().n_out, 1000);
        assert_eq!(pruned.layers[0].n_in, 3);
    }

    #[test]
    fn accuracy_anchors_match_tables() {
        let net34 = resnet::resnet34();
        assert!((TaylorPruner::new(0.82).top1(&net34).unwrap() - 72.7).abs() < 0.01);
        assert!((TaylorPruner::new(0.56).top1(&net34).unwrap() - 67.8).abs() < 0.01);
        let net18 = resnet::resnet18();
        assert!((TaylorPruner::new(0.72).top1(&net18).unwrap() - 64.8).abs() < 0.01);
    }

    #[test]
    fn iterative_prune_keeps_top_scores() {
        let scores = vec![0.5, 0.1, 0.9, 0.3, 0.7, 0.2];
        let kept = iterative_taylor_prune(&scores, 0.5);
        assert_eq!(kept, vec![0, 2, 4]);
    }

    #[test]
    fn taylor_score_is_abs_inner_product() {
        let w = vec![1.0f32, -2.0, 3.0];
        let g = vec![0.5f32, 0.5, -0.5];
        assert!((taylor_score(&w, &g) - 2.0).abs() < 1e-9);
    }
}
