//! Evaluation baselines (paper §7.1.4, §7.2.2, §7.6): the optimised
//! conventional engine (faithful), Taylor-pruned variants, the embedded-GPU
//! (Jetson TX2) model and the static prior-FPGA-work comparison rows.

pub mod faithful;
pub mod gpu;
pub mod prior_work;
pub mod pruning;

pub use faithful::evaluate_faithful;
pub use gpu::Tx2Model;
pub use pruning::TaylorPruner;
