//! Embedded-GPU (NVIDIA Jetson TX2) model for the Fig. 10 energy-efficiency
//! comparison (paper §7.6).
//!
//! The paper measures TensorRT + cuDNN FP16 at batch 1 in the Max-Q mode
//! (GPU at 850 MHz, best perf/W). We model the GPU as an FP16 roofline with
//! per-network achieved-efficiency factors: batch-1 inference on small
//! kernels leaves much of the 256-core GPU idle — most severely for
//! SqueezeNet-class models — which is exactly the effect the paper's
//! comparison rests on. See DESIGN.md §Substitutions.

/// Jetson TX2 in Max-Q mode.
#[derive(Clone, Debug)]
pub struct Tx2Model {
    /// GPU clock (Hz) — Max-Q sets 850 MHz.
    pub clock_hz: f64,
    /// CUDA cores.
    pub cores: u32,
    /// FP16 ops per core per cycle (2-wide FMA ⇒ 4 ops).
    pub fp16_ops_per_core_cycle: f64,
    /// Idle-subtracted board power during inference (W).
    pub dynamic_power_w: f64,
}

impl Default for Tx2Model {
    fn default() -> Self {
        Tx2Model {
            clock_hz: 850e6,
            cores: 256,
            fp16_ops_per_core_cycle: 4.0,
            dynamic_power_w: 9.0,
        }
    }
}

impl Tx2Model {
    /// Peak FP16 GOp/s.
    pub fn peak_gops(&self) -> f64 {
        self.cores as f64 * self.fp16_ops_per_core_cycle * self.clock_hz / 1e9
    }

    /// Achieved fraction of peak for batch-1 TensorRT inference, per
    /// network class. Calibrated against published TX2 TensorRT batch-1
    /// figures: deep uniform convs utilise the GPU best; small/1×1-heavy
    /// networks poorly.
    pub fn efficiency(network: &str) -> f64 {
        match network {
            "ResNet18" => 0.13,
            "ResNet34" => 0.15,
            "ResNet50" => 0.17,
            "SqueezeNet" => 0.10,
            _ => 0.14,
        }
    }

    /// Modelled batch-1 throughput (inf/s) for a network of `gops` work.
    pub fn inf_per_s(&self, network: &str, gops: f64) -> f64 {
        self.peak_gops() * Self::efficiency(network) / gops
    }

    /// Energy efficiency in inf/s/W.
    pub fn inf_per_s_per_w(&self, network: &str, gops: f64) -> f64 {
        self.inf_per_s(network, gops) / self.dynamic_power_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Network;

    #[test]
    fn peak_matches_spec() {
        let m = Tx2Model::default();
        // 256 cores × 4 × 0.85 GHz = 870.4 GOp/s FP16.
        assert!((m.peak_gops() - 870.4).abs() < 0.5);
    }

    #[test]
    fn throughputs_in_plausible_range() {
        let m = Tx2Model::default();
        for net in Network::benchmarks() {
            let t = m.inf_per_s(&net.name, net.gops());
            assert!(
                t > 10.0 && t < 1000.0,
                "{}: {t} inf/s outside plausible TX2 range",
                net.name
            );
        }
    }

    #[test]
    fn squeezenet_underutilises_most() {
        assert!(Tx2Model::efficiency("SqueezeNet") < Tx2Model::efficiency("ResNet18"));
        assert!(Tx2Model::efficiency("ResNet18") < Tx2Model::efficiency("ResNet50"));
    }
}
