//! The faithful baseline: an optimised conventional single computation
//! engine (paper Fig. 3 / §7.1.4-a) executing the *vanilla* CNN, with
//! weights streamed from off-chip (or pinned on-chip when they fit) and the
//! tile configuration chosen by roofline-style DSE.

use crate::arch::Platform;
use crate::dse::roofline::{baseline_optimise, BaselineResult};
use crate::dse::search::DseConfig;
use crate::error::Result;
use crate::workload::Network;

/// Run the baseline DSE and return the optimised conventional-engine design
/// for `net` at a bandwidth multiplier.
pub fn evaluate_faithful(
    platform: &Platform,
    bw_mult: u32,
    net: &Network,
) -> Result<BaselineResult> {
    baseline_optimise(&DseConfig::default(), platform, bw_mult, net)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::search::optimise;
    use crate::workload::{resnet, RatioProfile};

    #[test]
    fn unzip_beats_faithful_at_1x_bandwidth() {
        // The paper's core claim (Tables 4–5): at constrained bandwidth
        // on-the-fly generation wins substantially.
        let net = resnet::resnet34();
        let plat = Platform::z7045();
        let faithful = evaluate_faithful(&plat, 1, &net).unwrap();
        let profile = RatioProfile::ovsf50(&net);
        let unzip = optimise(&DseConfig::default(), &plat, 1, &net, &profile, true).unwrap();
        let speedup = unzip.perf.inf_per_s / faithful.perf.inf_per_s;
        assert!(
            speedup > 1.3,
            "expected ≳2× speedup at 1× bandwidth, got {speedup:.2}×"
        );
    }

    #[test]
    fn gap_closes_at_high_bandwidth() {
        let net = resnet::resnet34();
        let plat = Platform::z7045();
        let profile = RatioProfile::ovsf50(&net);
        let s = |bw: u32| {
            let f = evaluate_faithful(&plat, bw, &net).unwrap();
            let u = optimise(&DseConfig::default(), &plat, bw, &net, &profile, true).unwrap();
            u.perf.inf_per_s / f.perf.inf_per_s
        };
        let s1 = s(1);
        let s4 = s(4);
        assert!(s4 < s1, "speedup must shrink with bandwidth: {s1:.2}→{s4:.2}");
    }
}
