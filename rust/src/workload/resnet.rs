//! Residual networks (He et al.) — the paper's primary benchmarks:
//! ImageNet ResNet18 / ResNet34 / ResNet50, plus the small CIFAR-10
//! variants (ResNet18†/34† in the paper's Table 3).
//!
//! Only compute layers (conv, fc) are materialised; pooling and elementwise
//! ops are folded, matching the paper's engine model. OVSF conversion
//! targets the 3×3 convolutions inside residual blocks (paper §7.1.3).

use super::layer::Layer;
use super::Network;

/// Block counts per stage.
struct Stages {
    blocks: [u64; 4],
    bottleneck: bool,
}

fn build_imagenet_resnet(name: &str, stages: Stages) -> Network {
    let mut layers = Vec::new();
    // Stem: 7×7/2 conv, 224→112, then 3×3/2 maxpool → 56.
    layers.push(Layer::conv("conv1", 224, 224, 3, 64, 7, 2, 3, false));
    let widths = [64u64, 128, 256, 512];
    let mut fmap = 56u64; // after maxpool
    let mut in_ch = 64u64;
    for (s, &n_blocks) in stages.blocks.iter().enumerate() {
        let w = widths[s];
        for b in 0..n_blocks {
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let in_fmap = fmap;
            if stride == 2 {
                fmap /= 2;
            }
            let prefix = format!("layer{}.{}", s + 1, b);
            if stages.bottleneck {
                let out_ch = w * 4;
                // 1×1 reduce → 3×3 (OVSF) → 1×1 expand.
                layers.push(Layer::conv(
                    format!("{prefix}.conv1"),
                    in_fmap,
                    in_fmap,
                    in_ch,
                    w,
                    1,
                    1,
                    0,
                    false,
                ));
                layers.push(Layer::conv(
                    format!("{prefix}.conv2"),
                    in_fmap,
                    in_fmap,
                    w,
                    w,
                    3,
                    stride,
                    1,
                    true,
                ));
                layers.push(Layer::conv(
                    format!("{prefix}.conv3"),
                    fmap,
                    fmap,
                    w,
                    out_ch,
                    1,
                    1,
                    0,
                    false,
                ));
                if b == 0 {
                    layers.push(Layer::conv(
                        format!("{prefix}.downsample"),
                        in_fmap,
                        in_fmap,
                        in_ch,
                        out_ch,
                        1,
                        stride,
                        0,
                        false,
                    ));
                }
                in_ch = out_ch;
            } else {
                // Basic block: 3×3 (OVSF) → 3×3 (OVSF).
                layers.push(Layer::conv(
                    format!("{prefix}.conv1"),
                    in_fmap,
                    in_fmap,
                    in_ch,
                    w,
                    3,
                    stride,
                    1,
                    true,
                ));
                layers.push(Layer::conv(
                    format!("{prefix}.conv2"),
                    fmap,
                    fmap,
                    w,
                    w,
                    3,
                    1,
                    1,
                    true,
                ));
                if b == 0 && (in_ch != w || stride == 2) {
                    layers.push(Layer::conv(
                        format!("{prefix}.downsample"),
                        in_fmap,
                        in_fmap,
                        in_ch,
                        w,
                        1,
                        stride,
                        0,
                        false,
                    ));
                }
                in_ch = w;
            }
        }
    }
    layers.push(Layer::fc("fc", in_ch, 1000));
    Network {
        name: name.to_string(),
        layers,
    }
}

/// ImageNet ResNet18 (11.7M params, 4.03 GOps per the paper).
pub fn resnet18() -> Network {
    build_imagenet_resnet(
        "ResNet18",
        Stages {
            blocks: [2, 2, 2, 2],
            bottleneck: false,
        },
    )
}

/// ImageNet ResNet34 (21.8M params, 7.40 GOps).
pub fn resnet34() -> Network {
    build_imagenet_resnet(
        "ResNet34",
        Stages {
            blocks: [3, 4, 6, 3],
            bottleneck: false,
        },
    )
}

/// ImageNet ResNet50 (25.6M params, 8.41 GOps).
pub fn resnet50() -> Network {
    build_imagenet_resnet(
        "ResNet50",
        Stages {
            blocks: [3, 4, 6, 3],
            bottleneck: true,
        },
    )
}

/// CIFAR-10 ResNet18† — the much smaller variant of He et al. used in the
/// paper's Table 3 (0.27M params): 3 stages of n=3 basic blocks at widths
/// 16/32/64 on 32×32 inputs.
pub fn resnet18_cifar_small() -> Network {
    build_cifar_small("ResNet18-small", 3)
}

/// CIFAR-10 ResNet34† analogue (n=5, 0.46M params).
pub fn resnet34_cifar_small() -> Network {
    build_cifar_small("ResNet34-small", 5)
}

fn build_cifar_small(name: &str, n: u64) -> Network {
    let mut layers = Vec::new();
    layers.push(Layer::conv("conv1", 32, 32, 3, 16, 3, 1, 1, false));
    let widths = [16u64, 32, 64];
    let mut fmap = 32u64;
    let mut in_ch = 16u64;
    for (s, &w) in widths.iter().enumerate() {
        for b in 0..n {
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let in_fmap = fmap;
            if stride == 2 {
                fmap /= 2;
            }
            let prefix = format!("stage{}.{}", s + 1, b);
            layers.push(Layer::conv(
                format!("{prefix}.conv1"),
                in_fmap,
                in_fmap,
                in_ch,
                w,
                3,
                stride,
                1,
                true,
            ));
            layers.push(Layer::conv(
                format!("{prefix}.conv2"),
                fmap,
                fmap,
                w,
                w,
                3,
                1,
                1,
                true,
            ));
            if b == 0 && in_ch != w {
                layers.push(Layer::conv(
                    format!("{prefix}.downsample"),
                    in_fmap,
                    in_fmap,
                    in_ch,
                    w,
                    1,
                    stride,
                    0,
                    false,
                ));
            }
            in_ch = w;
        }
    }
    layers.push(Layer::fc("fc", in_ch, 10));
    Network {
        name: name.to_string(),
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_geometry() {
        let n = resnet18();
        // 1 stem + 16 block convs + 3 downsamples + 1 fc = 21 layers.
        assert_eq!(n.layers.len(), 21);
        // Paper quotes 11.7M params and 4.03 GOps (≈ within rounding: biases
        // and BN excluded here).
        let params_m = n.params() as f64 / 1e6;
        assert!(
            (params_m - 11.7).abs() < 0.2,
            "ResNet18 params {params_m}M vs paper 11.7M"
        );
        // Our MAC-only count gives 3.63 GOps; the paper's 4.03 includes
        // elementwise/BN ops the engine does not schedule.
        let gops = n.gops();
        assert!((3.4..4.2).contains(&gops), "ResNet18 {gops} GOps vs 4.03");
    }

    #[test]
    fn resnet34_geometry() {
        let n = resnet34();
        assert_eq!(n.layers.len(), 1 + 32 + 3 + 1);
        let params_m = n.params() as f64 / 1e6;
        assert!(
            (params_m - 21.8).abs() < 0.3,
            "ResNet34 params {params_m}M vs paper 21.8M"
        );
        let gops = n.gops();
        assert!((gops - 7.40).abs() < 0.5, "ResNet34 {gops} GOps vs 7.40");
    }

    #[test]
    fn resnet50_geometry() {
        let n = resnet50();
        assert_eq!(n.layers.len(), 1 + 48 + 4 + 1);
        let params_m = n.params() as f64 / 1e6;
        assert!(
            (params_m - 25.5).abs() < 0.5,
            "ResNet50 params {params_m}M vs paper 25.56M"
        );
        let gops = n.gops();
        assert!((gops - 8.41).abs() < 0.8, "ResNet50 {gops} GOps vs 8.41");
    }

    #[test]
    fn ovsf_flags_only_on_3x3_block_convs() {
        for net in [resnet18(), resnet34(), resnet50()] {
            for l in &net.layers {
                if l.ovsf {
                    assert_eq!(l.k, 3, "{}: only 3×3 convs are OVSF", l.name);
                    assert!(l.name.contains("conv"), "{}", l.name);
                }
            }
            assert!(!net.layers[0].ovsf, "stem stays dense");
            assert!(!net.layers.last().unwrap().ovsf, "fc stays dense");
        }
    }

    #[test]
    fn cifar_small_params_match_table3() {
        let s18 = resnet18_cifar_small();
        let p18 = s18.params() as f64 / 1e6;
        assert!((p18 - 0.27).abs() < 0.02, "ResNet18† {p18}M vs 0.27M");
        let s34 = resnet34_cifar_small();
        let p34 = s34.params() as f64 / 1e6;
        assert!((p34 - 0.46).abs() < 0.03, "ResNet34† {p34}M vs 0.46M");
    }

    #[test]
    fn feature_maps_shrink_monotonically() {
        let n = resnet50();
        let mut last = u64::MAX;
        for l in &n.layers {
            assert!(l.h <= last || l.h == 1, "fmap grew at {}", l.name);
            last = last.max(l.h);
        }
    }
}
