//! Reduced-geometry serving workloads.
//!
//! Networks with the *structure* of the paper's benchmarks (dense stem →
//! OVSF convs → classifier) but feature maps shrunk so serving tests and
//! benches can drive thousands of requests through a real
//! [`ServerPool`](crate::coordinator::pool::ServerPool) per run — the
//! scheduling/admission behaviour under test is shape-invariant, so
//! nothing is lost by shrinking. Two weight classes:
//!
//! * [`tiny_resnet`] / [`tiny_mobilenet`] — microsecond-scale (≪ 1 M
//!   MACs), for debug-build unit/integration tests;
//! * [`small_resnet`] / [`small_mobilenet`] — millisecond-scale (a few
//!   M MACs), for `benches/serving.rs`, whose load generator needs
//!   service times long enough that offered-load levels around the
//!   pool's capacity are meaningfully paceable.
//!
//! Paired nets deliberately disagree on input length so shape validation
//! and model routing stay observable.

use crate::workload::{Layer, Network};

/// Reduced ResNet-style profile: dense stem, two OVSF block convs (one
/// strided), folded-pool classifier. Input `8·8·4 = 256`, output 10.
pub fn tiny_resnet() -> Network {
    Network {
        name: "tiny-resnet".into(),
        layers: vec![
            Layer::conv("stem", 8, 8, 4, 8, 3, 1, 1, false),
            Layer::conv("block.conv1", 8, 8, 8, 8, 3, 1, 1, true),
            Layer::conv("block.conv2", 8, 8, 8, 16, 3, 2, 1, true),
            Layer::fc("fc", 16, 10),
        ],
    }
}

/// Reduced MobileNet-style profile: strided dense stem, pointwise 1×1,
/// an OVSF 3×3, pointwise expansion, classifier. Input `10·10·3 = 300`
/// (a different shape than [`tiny_resnet`], so validation discriminates),
/// output 7.
pub fn tiny_mobilenet() -> Network {
    Network {
        name: "tiny-mobilenet".into(),
        layers: vec![
            Layer::conv("stem", 10, 10, 3, 8, 3, 2, 1, false),
            Layer::conv("pw1", 5, 5, 8, 16, 1, 1, 0, false),
            Layer::conv("dw3", 5, 5, 16, 16, 3, 1, 1, true),
            Layer::conv("pw2", 5, 5, 16, 24, 1, 1, 0, false),
            Layer::fc("fc", 24, 7),
        ],
    }
}

/// Serving-weight ResNet-style profile (~7 M MACs): millisecond-scale
/// release-build inference. Input `32·32·8 = 8192`, output 10.
pub fn small_resnet() -> Network {
    Network {
        name: "small-resnet".into(),
        layers: vec![
            Layer::conv("stem", 32, 32, 8, 16, 3, 1, 1, false),
            Layer::conv("block1.conv1", 32, 32, 16, 16, 3, 1, 1, true),
            Layer::conv("block1.conv2", 32, 32, 16, 32, 3, 2, 1, true),
            Layer::conv("block2.conv1", 16, 16, 32, 32, 3, 1, 1, true),
            Layer::fc("fc", 32, 10),
        ],
    }
}

/// Serving-weight MobileNet-style profile (~2 M MACs). Input
/// `24·24·6 = 3456` (distinct from [`small_resnet`]), output 7.
pub fn small_mobilenet() -> Network {
    Network {
        name: "small-mobilenet".into(),
        layers: vec![
            Layer::conv("stem", 24, 24, 6, 16, 3, 2, 1, false),
            Layer::conv("pw1", 12, 12, 16, 32, 1, 1, 0, false),
            Layer::conv("dw3", 12, 12, 32, 32, 3, 1, 1, true),
            Layer::conv("pw2", 12, 12, 32, 48, 1, 1, 0, false),
            Layer::fc("fc", 48, 7),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_nets_are_small_and_shape_distinct() {
        let r = tiny_resnet();
        let m = tiny_mobilenet();
        assert!(r.macs() < 1_000_000, "tiny nets must stay debug-cheap");
        assert!(m.macs() < 1_000_000);
        let r0 = &r.layers[0];
        let m0 = &m.layers[0];
        assert_eq!(r0.h * r0.w * r0.n_in, 256);
        assert_eq!(m0.h * m0.w * m0.n_in, 300);
        assert!(r.layers.iter().any(|l| l.ovsf), "OVSF path must be exercised");
        assert!(m.layers.iter().any(|l| l.ovsf));
    }

    #[test]
    fn small_nets_sit_in_the_serving_weight_class() {
        let r = small_resnet();
        let m = small_mobilenet();
        assert!(
            (1_000_000..50_000_000).contains(&r.macs()),
            "small-resnet {} MACs outside the ms-scale band",
            r.macs()
        );
        assert!(
            (500_000..50_000_000).contains(&m.macs()),
            "small-mobilenet {} MACs outside the ms-scale band",
            m.macs()
        );
        let r0 = &r.layers[0];
        let m0 = &m.layers[0];
        assert_ne!(r0.h * r0.w * r0.n_in, m0.h * m0.w * m0.n_in);
        assert!(r.layers.iter().any(|l| l.ovsf));
        assert!(m.layers.iter().any(|l| l.ovsf));
    }
}
