//! Layer descriptors and their GEMM view (paper §4.1).
//!
//! A CONV layer with `N_in` input channels of `H×W`, `N_out` output
//! channels, `K×K` filters, padding `p` and stride `S` maps to the
//! multiplication of an `R×P` activations matrix with a `P×C` weights
//! matrix: `R = out_h·out_w`, `P = N_in·K²`, `C = N_out`.

use crate::util::{is_pow2, n_basis, next_pow2};

/// Kind of compute layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// Spatial convolution.
    Conv,
    /// Fully connected (K=1, spatial 1×1 view).
    Fc,
}

/// The `⟨R, P, C⟩` GEMM workload tuple of a layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmShape {
    /// Output rows (spatial positions).
    pub r: u64,
    /// Reduction depth (`N_in·K²`).
    pub p: u64,
    /// Output columns (`N_out`).
    pub c: u64,
}

impl GemmShape {
    /// MACs of the GEMM.
    pub fn macs(&self) -> u64 {
        self.r * self.p * self.c
    }
}

/// One compute layer of a CNN.
#[derive(Clone, Debug, PartialEq)]
pub struct Layer {
    /// Human-readable name (e.g. "layer2.0.conv1").
    pub name: String,
    /// Conv or FC.
    pub kind: LayerKind,
    /// Input feature-map height.
    pub h: u64,
    /// Input feature-map width.
    pub w: u64,
    /// Input channels.
    pub n_in: u64,
    /// Output channels.
    pub n_out: u64,
    /// Kernel size `K` (1 for FC).
    pub k: u64,
    /// Stride.
    pub stride: u64,
    /// Padding.
    pub pad: u64,
    /// Whether this layer is replaced by an OVSF-CONV layer (the first conv
    /// of a network stays dense, paper §6.2).
    pub ovsf: bool,
}

impl Layer {
    /// Convenience conv constructor.
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        name: impl Into<String>,
        h: u64,
        w: u64,
        n_in: u64,
        n_out: u64,
        k: u64,
        stride: u64,
        pad: u64,
        ovsf: bool,
    ) -> Self {
        Layer {
            name: name.into(),
            kind: LayerKind::Conv,
            h,
            w,
            n_in,
            n_out,
            k,
            stride,
            pad,
            ovsf,
        }
    }

    /// Convenience FC constructor.
    pub fn fc(name: impl Into<String>, n_in: u64, n_out: u64) -> Self {
        Layer {
            name: name.into(),
            kind: LayerKind::Fc,
            h: 1,
            w: 1,
            n_in,
            n_out,
            k: 1,
            stride: 1,
            pad: 0,
            ovsf: false,
        }
    }

    /// Output spatial height.
    pub fn out_h(&self) -> u64 {
        (self.h + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Output spatial width.
    pub fn out_w(&self) -> u64 {
        (self.w + 2 * self.pad - self.k) / self.stride + 1
    }

    /// GEMM view `⟨R, P, C⟩`.
    pub fn gemm(&self) -> GemmShape {
        GemmShape {
            r: self.out_h() * self.out_w(),
            p: self.n_in * self.k * self.k,
            c: self.n_out,
        }
    }

    /// Dense parameter count (no bias, as in the paper's accounting).
    pub fn params(&self) -> u64 {
        self.n_out * self.n_in * self.k * self.k
    }

    /// MACs for one inference.
    pub fn macs(&self) -> u64 {
        self.gemm().macs()
    }

    /// OVSF code length for this layer: `L = N_in·K'²` with `K'` the
    /// power-of-two kernel frame (4 for K=3).
    pub fn ovsf_code_len(&self) -> u64 {
        let k = if is_pow2(self.k as usize) {
            self.k
        } else {
            next_pow2(self.k as usize) as u64
        };
        self.n_in * k * k
    }

    /// Number of basis vectors per filter at ratio ρ. The paper streams the
    /// generation per `K²`-sized chunk, so the per-subtile count is
    /// `⌊ρ·K'²⌉` (Alg. 1's `ρK²` loop bound).
    pub fn basis_per_chunk(&self, rho: f64) -> u64 {
        let k = if is_pow2(self.k as usize) {
            self.k
        } else {
            next_pow2(self.k as usize) as u64
        };
        n_basis(rho, (k * k) as usize) as u64
    }

    /// Parameter count when stored as OVSF α coefficients at ratio ρ
    /// (paper: `N_in·N_out·⌈ρ_l·K_l²⌉` α values for layer `l`);
    /// non-OVSF layers keep their dense parameters.
    pub fn params_with_rho(&self, rho: f64) -> u64 {
        if !self.ovsf || rho >= 1.0 {
            if self.ovsf {
                // ρ=1 OVSF layer stores N_in·N_out·K'² alphas.
                let k = if is_pow2(self.k as usize) {
                    self.k
                } else {
                    next_pow2(self.k as usize) as u64
                };
                return self.n_in * self.n_out * k * k;
            }
            return self.params();
        }
        self.n_in * self.n_out * self.basis_per_chunk(rho)
    }

    /// Whether this layer's output feature map is exactly the input shape
    /// of `next`: `(out_h, out_w, n_out) == (h, w, n_in)`. This is the
    /// condition for a pipeline cut between the two layers to carry
    /// activations across byte-for-byte — within one plan the simulator may
    /// re-fit mismatched shapes, but a stage boundary hands the raw output
    /// buffer to the next stage's admission check, so only exact chains are
    /// valid cut points (see `Compiler::split`).
    pub fn chains_to(&self, next: &Layer) -> bool {
        self.out_h() == next.h && self.out_w() == next.w && self.n_out == next.n_in
    }

    /// Input feature-map elements (what `t_mem_in` streams per row tile is
    /// `T_R·P`; per full layer the paper's model moves `R·P`).
    pub fn ifm_elems(&self) -> u64 {
        self.gemm().r * self.gemm().p
    }

    /// Output feature-map elements.
    pub fn ofm_elems(&self) -> u64 {
        self.gemm().r * self.gemm().c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_gemm_view() {
        // 3×3 s1 p1 conv on 56×56×64 → 56×56×64.
        let l = Layer::conv("c", 56, 56, 64, 64, 3, 1, 1, true);
        let g = l.gemm();
        assert_eq!(g.r, 56 * 56);
        assert_eq!(g.p, 64 * 9);
        assert_eq!(g.c, 64);
        assert_eq!(l.params(), 36_864);
    }

    #[test]
    fn strided_conv_output_dims() {
        // ResNet stem: 7×7 s2 p3 on 224 → 112.
        let l = Layer::conv("stem", 224, 224, 3, 64, 7, 2, 3, false);
        assert_eq!(l.out_h(), 112);
        assert_eq!(l.out_w(), 112);
    }

    #[test]
    fn fc_view() {
        let l = Layer::fc("fc", 512, 1000);
        let g = l.gemm();
        assert_eq!((g.r, g.p, g.c), (1, 512, 1000));
        assert_eq!(l.params(), 512_000);
    }

    #[test]
    fn ovsf_code_len_rounds_kernel() {
        let l3 = Layer::conv("c3", 14, 14, 256, 256, 3, 1, 1, true);
        assert_eq!(l3.ovsf_code_len(), 256 * 16, "3×3 uses a 4×4 frame");
        let l1 = Layer::conv("c1", 14, 14, 256, 64, 1, 1, 0, true);
        assert_eq!(l1.ovsf_code_len(), 256);
    }

    #[test]
    fn alpha_params_scale_with_rho() {
        let l = Layer::conv("c", 28, 28, 128, 128, 3, 1, 1, true);
        let full = l.params_with_rho(1.0);
        assert_eq!(full, 128 * 128 * 16);
        let half = l.params_with_rho(0.5);
        assert_eq!(half, 128 * 128 * 8);
        let quarter = l.params_with_rho(0.25);
        assert_eq!(quarter, 128 * 128 * 4);
        // Dense (non-OVSF) layers ignore ρ.
        let dense = Layer::conv("d", 28, 28, 128, 128, 3, 1, 1, false);
        assert_eq!(dense.params_with_rho(0.25), dense.params());
    }

    #[test]
    fn chains_to_requires_exact_shape_handoff() {
        let a = Layer::conv("a", 8, 8, 4, 8, 3, 1, 1, false);
        let b = Layer::conv("b", 8, 8, 8, 8, 3, 1, 1, true);
        assert!(a.chains_to(&b), "same-spatial conv chains");
        let strided = Layer::conv("s", 8, 8, 8, 16, 3, 2, 1, true);
        assert!(b.chains_to(&strided));
        // Strided conv halves the map: 8→4, so an 8×8 consumer mismatches.
        assert!(!strided.chains_to(&b));
        // FC consumes a flat vector; only a 1×1×n_in producer chains.
        let fc = Layer::fc("fc", 16, 10);
        assert!(!strided.chains_to(&fc), "4·4·16 ≠ 1·1·16");
    }

    #[test]
    fn basis_per_chunk_matches_paper_ratios() {
        let l = Layer::conv("c", 28, 28, 128, 128, 3, 1, 1, true);
        assert_eq!(l.basis_per_chunk(1.0), 16);
        assert_eq!(l.basis_per_chunk(0.5), 8);
        assert_eq!(l.basis_per_chunk(0.25), 4);
        assert_eq!(l.basis_per_chunk(0.125), 2);
        assert_eq!(l.basis_per_chunk(0.4), 6); // ⌊6.4⌉
    }
}
