//! SqueezeNet1.1 (Iandola et al.) — the paper's lightweight benchmark
//! (1.24M params, 0.78 GOps). Fire modules: a 1×1 squeeze conv followed by
//! parallel 1×1 and 3×3 expand convs. OVSF conversion follows the paper's
//! scheme for Fire modules (§7.1.3): the 3×3 expand convs become OVSF.

use super::layer::Layer;
use super::Network;

struct Fire {
    squeeze: u64,
    expand1: u64,
    expand3: u64,
}

/// SqueezeNet v1.1 for 224×224 ImageNet input.
pub fn squeezenet1_1() -> Network {
    let mut layers = Vec::new();
    // conv1: 3×3/2, 64 filters: 224 → 111.
    layers.push(Layer::conv("conv1", 224, 224, 3, 64, 3, 2, 0, false));
    // maxpool/2 → 55.
    let fires: [(u64, Fire); 8] = [
        (
            55,
            Fire {
                squeeze: 16,
                expand1: 64,
                expand3: 64,
            },
        ),
        (
            55,
            Fire {
                squeeze: 16,
                expand1: 64,
                expand3: 64,
            },
        ),
        // maxpool → 27
        (
            27,
            Fire {
                squeeze: 32,
                expand1: 128,
                expand3: 128,
            },
        ),
        (
            27,
            Fire {
                squeeze: 32,
                expand1: 128,
                expand3: 128,
            },
        ),
        // maxpool → 13
        (
            13,
            Fire {
                squeeze: 48,
                expand1: 192,
                expand3: 192,
            },
        ),
        (
            13,
            Fire {
                squeeze: 48,
                expand1: 192,
                expand3: 192,
            },
        ),
        (
            13,
            Fire {
                squeeze: 64,
                expand1: 256,
                expand3: 256,
            },
        ),
        (
            13,
            Fire {
                squeeze: 64,
                expand1: 256,
                expand3: 256,
            },
        ),
    ];
    let mut in_ch = 64u64;
    for (i, (fmap, fire)) in fires.iter().enumerate() {
        let idx = i + 2; // torchvision numbering: fire2..fire9
        layers.push(Layer::conv(
            format!("fire{idx}.squeeze"),
            *fmap,
            *fmap,
            in_ch,
            fire.squeeze,
            1,
            1,
            0,
            false,
        ));
        layers.push(Layer::conv(
            format!("fire{idx}.expand1x1"),
            *fmap,
            *fmap,
            fire.squeeze,
            fire.expand1,
            1,
            1,
            0,
            false,
        ));
        layers.push(Layer::conv(
            format!("fire{idx}.expand3x3"),
            *fmap,
            *fmap,
            fire.squeeze,
            fire.expand3,
            3,
            1,
            1,
            true,
        ));
        in_ch = fire.expand1 + fire.expand3;
    }
    // Classifier conv10: 1×1 to 1000 classes at 13×13.
    layers.push(Layer::conv("conv10", 13, 13, in_ch, 1000, 1, 1, 0, false));
    Network {
        name: "SqueezeNet".to_string(),
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_match_paper() {
        let n = squeezenet1_1();
        let p = n.params() as f64 / 1e6;
        assert!((p - 1.24).abs() < 0.05, "SqueezeNet params {p}M vs 1.24M");
    }

    #[test]
    fn gops_match_paper() {
        let n = squeezenet1_1();
        let g = n.gops();
        assert!((g - 0.78).abs() < 0.12, "SqueezeNet {g} GOps vs 0.78");
    }

    #[test]
    fn structure() {
        let n = squeezenet1_1();
        // conv1 + 8 fires × 3 convs + conv10 = 26 layers.
        assert_eq!(n.layers.len(), 26);
        let ovsf_count = n.layers.iter().filter(|l| l.ovsf).count();
        assert_eq!(ovsf_count, 8, "one OVSF 3×3 expand per fire module");
        // Squeeze ratio: expand3x3 layers have non-pow2-unfriendly squeeze
        // inputs handled by the code-length rounding.
        for l in n.layers.iter().filter(|l| l.ovsf) {
            assert_eq!(l.k, 3);
        }
    }
}
