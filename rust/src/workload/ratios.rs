//! Per-layer OVSF ratio profiles (paper §6.2, §7.1.3).
//!
//! The hand-tuned profiles assign one ratio per residual *block group*:
//! `OVSF50 = [1.0, 0.5, 0.5, 0.5]` and `OVSF25 = [1.0, 0.4, 0.25, 0.125]`
//! across the four ResNet stages (Fire-module groups for SqueezeNet). The
//! hardware-aware autotuner (crate::autotune) refines these per layer.

use super::Network;

/// A per-layer assignment of OVSF ratios (entries for non-OVSF layers are
/// kept at 1.0 and ignored).
#[derive(Clone, Debug, PartialEq)]
pub struct RatioProfile {
    /// Profile name (e.g. "OVSF50").
    pub name: String,
    /// One ρ per network layer.
    pub rhos: Vec<f64>,
}

impl RatioProfile {
    /// ρ for layer `i`.
    pub fn rho(&self, i: usize) -> f64 {
        self.rhos[i]
    }

    /// Number of layer entries.
    pub fn len(&self) -> usize {
        self.rhos.len()
    }

    /// `true` when empty.
    pub fn is_empty(&self) -> bool {
        self.rhos.is_empty()
    }

    /// Mean ρ over OVSF layers, weighted by dense parameter count — the
    /// "effective compression" figure used by the accuracy model.
    pub fn effective_rho(&self, net: &Network) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for (i, l) in net.layers.iter().enumerate() {
            if l.ovsf {
                let w = l.params() as f64;
                num += self.rhos[i] * w;
                den += w;
            }
        }
        if den == 0.0 {
            1.0
        } else {
            num / den
        }
    }

    /// Uniform profile: the same ρ on every OVSF layer (the paper's
    /// `uniform-ρ` baseline; the first conv is never OVSF by construction).
    pub fn uniform(net: &Network, rho: f64) -> Self {
        RatioProfile {
            name: format!("uniform-{rho}"),
            rhos: net
                .layers
                .iter()
                .map(|l| if l.ovsf { rho } else { 1.0 })
                .collect(),
        }
    }

    /// Hand-tuned per-stage profile: maps 4 stage ratios onto the layers.
    pub fn per_stage(net: &Network, name: &str, stage_rhos: [f64; 4]) -> Self {
        let rhos = net
            .layers
            .iter()
            .map(|l| {
                if !l.ovsf {
                    return 1.0;
                }
                stage_rhos[stage_of(net, &l.name)]
            })
            .collect();
        RatioProfile {
            name: name.to_string(),
            rhos,
        }
    }

    /// The paper's OVSF50 profile: `[1.0, 0.5, 0.5, 0.5]`.
    pub fn ovsf50(net: &Network) -> Self {
        Self::per_stage(net, "OVSF50", [1.0, 0.5, 0.5, 0.5])
    }

    /// The paper's OVSF25 profile: `[1.0, 0.4, 0.25, 0.125]`.
    pub fn ovsf25(net: &Network) -> Self {
        Self::per_stage(net, "OVSF25", [1.0, 0.4, 0.25, 0.125])
    }
}

/// Stage (0..4) of a layer by name for both ResNets ("layerN.") and
/// SqueezeNet ("fireN."): Fire modules pair up into four groups
/// (2–3, 4–5, 6–7, 8–9).
fn stage_of(_net: &Network, name: &str) -> usize {
    if let Some(rest) = name.strip_prefix("layer") {
        let n: usize = rest[..1].parse().unwrap_or(1);
        return n - 1;
    }
    if let Some(rest) = name.strip_prefix("stage") {
        let n: usize = rest[..1].parse().unwrap_or(1);
        // CIFAR-small has 3 stages; map onto the last three groups.
        return n.min(3);
    }
    if let Some(rest) = name.strip_prefix("fire") {
        let n: usize = rest[..1].parse().unwrap_or(2);
        return ((n - 2) / 2).min(3);
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{resnet, squeezenet};

    #[test]
    fn ovsf25_matches_paper_table1_layout() {
        let net = resnet::resnet18();
        let p = RatioProfile::ovsf25(&net);
        // Stage-1 OVSF layers get ρ=1.0, stage-2 0.4, stage-3 0.25, stage-4 0.125.
        for (i, l) in net.layers.iter().enumerate() {
            if !l.ovsf {
                assert_eq!(p.rho(i), 1.0);
                continue;
            }
            let expect = match &l.name {
                n if n.starts_with("layer1") => 1.0,
                n if n.starts_with("layer2") => 0.4,
                n if n.starts_with("layer3") => 0.25,
                _ => 0.125,
            };
            assert_eq!(p.rho(i), expect, "{}", l.name);
        }
    }

    #[test]
    fn uniform_skips_dense_layers() {
        let net = resnet::resnet18();
        let p = RatioProfile::uniform(&net, 0.5);
        assert_eq!(p.rho(0), 1.0, "stem stays dense");
        let any_ovsf = net.layers.iter().position(|l| l.ovsf).unwrap();
        assert_eq!(p.rho(any_ovsf), 0.5);
    }

    #[test]
    fn effective_rho_ordering() {
        let net = resnet::resnet34();
        let e50 = RatioProfile::ovsf50(&net).effective_rho(&net);
        let e25 = RatioProfile::ovsf25(&net).effective_rho(&net);
        let e100 = RatioProfile::uniform(&net, 1.0).effective_rho(&net);
        assert!(e25 < e50 && e50 < e100);
        assert!(e100 <= 1.0 + 1e-12);
        // OVSF25 ratios concentrate compression on the deep (param-heavy)
        // stages, so the effective ρ sits well below 0.4.
        assert!(e25 < 0.3, "effective ρ of OVSF25 = {e25}");
    }

    #[test]
    fn squeezenet_fire_grouping() {
        let net = squeezenet::squeezenet1_1();
        let p = RatioProfile::ovsf25(&net);
        let fire_rho = |f: usize| {
            let (i, _) = net
                .layers
                .iter()
                .enumerate()
                .find(|(_, l)| l.name == format!("fire{f}.expand3x3"))
                .unwrap();
            p.rho(i)
        };
        assert_eq!(fire_rho(2), 1.0);
        assert_eq!(fire_rho(4), 0.4);
        assert_eq!(fire_rho(7), 0.25);
        assert_eq!(fire_rho(9), 0.125);
    }

    #[test]
    fn compressed_params_shrink() {
        let net = resnet::resnet34();
        let dense = net.params();
        let p50 = net.params_compressed(&RatioProfile::ovsf50(&net));
        let p25 = net.params_compressed(&RatioProfile::ovsf25(&net));
        assert!(p25 < p50, "OVSF25 smaller than OVSF50");
        assert!(p25 < dense / 2, "OVSF25 well under half the dense params");
    }
}
