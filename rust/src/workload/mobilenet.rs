//! MobileNetV1 — depthwise-separable workload. A stress test for the PE
//! array, not the memory wall: depthwise layers have a GEMM depth of only
//! `K² = 9` (each filter sees one channel), so the engine's `T_P`-deep dot
//! products and `T_C`-wide array are chronically underfilled — exactly the
//! mismatch the input-selective PEs (paper §4.3) address.
//!
//! Depthwise convolutions map to the engine as grouped GEMMs: `N` parallel
//! `R×K²×1` problems ⇒ a layer descriptor with `n_in = 1, n_out = N`
//! (each output column owns its K²-deep filter). 1×1 and depthwise layers
//! stay dense (the paper applies OVSF to 3×3 multi-channel filters).

use super::layer::Layer;
use super::Network;

/// ImageNet MobileNetV1 (width 1.0).
pub fn mobilenet_v1() -> Network {
    let mut layers = Vec::new();
    layers.push(Layer::conv("conv1", 224, 224, 3, 32, 3, 2, 1, false));
    // (fmap_in, channels_in, channels_out, stride of the dw conv)
    let blocks: [(u64, u64, u64, u64); 13] = [
        (112, 32, 64, 1),
        (112, 64, 128, 2),
        (56, 128, 128, 1),
        (56, 128, 256, 2),
        (28, 256, 256, 1),
        (28, 256, 512, 2),
        (14, 512, 512, 1),
        (14, 512, 512, 1),
        (14, 512, 512, 1),
        (14, 512, 512, 1),
        (14, 512, 512, 1),
        (14, 512, 1024, 2),
        (7, 1024, 1024, 1),
    ];
    for (i, &(fmap, c_in, c_out, s)) in blocks.iter().enumerate() {
        let out_fmap = fmap / s;
        // Depthwise 3×3: grouped — engine view n_in = 1, n_out = c_in.
        let mut dw = Layer::conv(
            format!("dw{}", i + 1),
            fmap,
            fmap,
            1,
            c_in,
            3,
            s,
            1,
            false,
        );
        // The spatial extent is per-channel; R stays the featuremap size.
        dw.name = format!("dw{}", i + 1);
        layers.push(dw);
        // Pointwise 1×1.
        layers.push(Layer::conv(
            format!("pw{}", i + 1),
            out_fmap,
            out_fmap,
            c_in,
            c_out,
            1,
            1,
            0,
            false,
        ));
    }
    layers.push(Layer::fc("fc", 1024, 1000));
    Network {
        name: "MobileNetV1".to_string(),
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{DesignPoint, Platform};
    use crate::perf::model::{PerfModel, WeightsSource};

    #[test]
    fn params_and_gops() {
        let n = mobilenet_v1();
        let p = n.params() as f64 / 1e6;
        // 4.2M params (conv+fc, no BN).
        assert!((p - 4.2).abs() < 0.3, "MobileNetV1 {p}M vs ~4.2M");
        let g = n.gops();
        assert!((g - 1.1).abs() < 0.25, "MobileNetV1 {g} GOps vs ~1.1");
    }

    #[test]
    fn depthwise_layers_have_tiny_gemm_depth() {
        let n = mobilenet_v1();
        for l in n.layers.iter().filter(|l| l.name.starts_with("dw")) {
            assert_eq!(l.gemm().p, 9, "{}: depthwise depth is K²", l.name);
        }
    }

    #[test]
    fn selective_pes_help_depthwise_edge_tiles() {
        // dw layers with C = 32 on a 48-wide array: the steal schedule
        // recovers the idle 16 PEs.
        let plat = Platform::z7045();
        let model = PerfModel::new(plat, 4);
        let sigma = DesignPoint::new(16, 128, 4, 48);
        let n = mobilenet_v1();
        let dw1 = n.layers.iter().find(|l| l.name == "dw1").unwrap();
        let with = model.layer_perf(&sigma, dw1, WeightsSource::OffChip);
        let without = model
            .clone()
            .without_selective_pes()
            .layer_perf(&sigma, dw1, WeightsSource::OffChip);
        assert!(with.t_eng <= without.t_eng);
    }
}
