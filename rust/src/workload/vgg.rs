//! VGG16 — a classic weights-heavy workload (138M params) used by many of
//! the accelerators unzipFPGA compares against. Not in the paper's Table
//! benchmarks, but the extreme case for the memory wall: its FC layers are
//! >100 MB of weights, making it the stress test for on-the-fly generation
//! vs off-chip streaming.

use super::layer::Layer;
use super::Network;

/// ImageNet VGG16 (convolutional trunk + 3 FC layers).
pub fn vgg16() -> Network {
    let mut layers = Vec::new();
    let cfg: [(u64, u64, u64); 13] = [
        // (fmap, in, out)
        (224, 3, 64),
        (224, 64, 64),
        (112, 64, 128),
        (112, 128, 128),
        (56, 128, 256),
        (56, 256, 256),
        (56, 256, 256),
        (28, 256, 512),
        (28, 512, 512),
        (28, 512, 512),
        (14, 512, 512),
        (14, 512, 512),
        (14, 512, 512),
    ];
    for (i, &(fmap, n_in, n_out)) in cfg.iter().enumerate() {
        // All 3×3 convs except the very first become OVSF (paper keeps the
        // first conv dense).
        layers.push(Layer::conv(
            format!("conv{}", i + 1),
            fmap,
            fmap,
            n_in,
            n_out,
            3,
            1,
            1,
            i > 0,
        ));
    }
    layers.push(Layer::fc("fc6", 512 * 7 * 7, 4096));
    layers.push(Layer::fc("fc7", 4096, 4096));
    layers.push(Layer::fc("fc8", 4096, 1000));
    Network {
        name: "VGG16".to_string(),
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::baselines::faithful::evaluate_faithful;
    use crate::dse::search::{optimise, DseConfig};
    use crate::workload::RatioProfile;

    #[test]
    fn params_and_gops() {
        let n = vgg16();
        let p = n.params() as f64 / 1e6;
        assert!((p - 138.0).abs() < 2.0, "VGG16 params {p}M vs ~138M");
        let g = n.gops();
        assert!((g - 30.9).abs() < 2.0, "VGG16 {g} GOps vs ~30.9");
    }

    #[test]
    fn fc_layers_dominate_params() {
        let n = vgg16();
        let fc: u64 = n
            .layers
            .iter()
            .filter(|l| l.kind == crate::workload::LayerKind::Fc)
            .map(|l| l.params())
            .sum();
        assert!(fc * 10 > n.params() * 8, "FC ≈ 89% of VGG16 params");
    }

    #[test]
    fn memory_wall_stress_case() {
        // VGG16's weights-heavy profile makes on-the-fly generation shine
        // even harder than on ResNets at constrained bandwidth.
        let n = vgg16();
        let plat = Platform::z7045();
        let profile = RatioProfile::uniform(&n, 0.5);
        let base = evaluate_faithful(&plat, 1, &n).unwrap().perf.inf_per_s;
        let unzip = optimise(&DseConfig::default(), &plat, 1, &n, &profile, true)
            .unwrap()
            .perf
            .inf_per_s;
        // FC layers (89% of params) stay dense per the paper, so the gain
        // comes from the conv trunk only — still a solid win at 1×.
        assert!(
            unzip / base > 1.15,
            "VGG16 OVSF at 1×: {unzip:.2} vs baseline {base:.2}"
        );
    }
}
