//! CNN workload descriptions: per-layer GEMM views `⟨R, P, C⟩` for the
//! paper's benchmark networks (ResNet18/34/50, SqueezeNet1.1) and the
//! per-layer OVSF ratio profiles.

pub mod layer;
pub mod mobilenet;
pub mod ratios;
pub mod resnet;
pub mod squeezenet;
pub mod tiny;
pub mod vgg;

pub use layer::{GemmShape, Layer, LayerKind};
pub use ratios::RatioProfile;

/// A full network workload: ordered compute layers.
#[derive(Clone, Debug)]
pub struct Network {
    /// Network name, e.g. "ResNet18".
    pub name: String,
    /// Compute layers in execution order (conv + fc; pooling/activation are
    /// bandwidth-negligible and folded away, as in the paper's engine).
    pub layers: Vec<Layer>,
}

impl Network {
    /// Total parameters (dense, uncompressed).
    pub fn params(&self) -> u64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    /// Total multiply-accumulates for one inference.
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// GOps per inference (2 ops per MAC), the figure the paper quotes
    /// (ResNet18 4.03, ResNet34 7.40, ResNet50 8.41, SqueezeNet 0.78).
    pub fn gops(&self) -> f64 {
        2.0 * self.macs() as f64 / 1e9
    }

    /// Parameters after OVSF compression with the given per-layer profile
    /// (α coefficients replace dense weights on OVSF layers).
    pub fn params_compressed(&self, profile: &RatioProfile) -> u64 {
        self.layers
            .iter()
            .enumerate()
            .map(|(i, l)| l.params_with_rho(profile.rho(i)))
            .sum()
    }

    /// The four benchmark networks of the paper's evaluation.
    pub fn benchmarks() -> Vec<Network> {
        vec![
            resnet::resnet18(),
            resnet::resnet34(),
            resnet::resnet50(),
            squeezenet::squeezenet1_1(),
        ]
    }

    /// Additional (non-paper) workloads supported by the framework.
    pub fn extended() -> Vec<Network> {
        vec![vgg::vgg16(), mobilenet::mobilenet_v1()]
    }

    /// Look a workload up by (case-insensitive) name, covering the paper
    /// benchmarks plus the extended set.
    pub fn by_name(name: &str) -> Option<Network> {
        let lower = name.to_lowercase();
        Self::benchmarks()
            .into_iter()
            .chain(Self::extended())
            .find(|n| n.name.to_lowercase() == lower)
    }

    /// Resolve a comma-separated list of workload names (the multi-model
    /// serving CLI/example convention), erroring on the first unknown one.
    pub fn by_names(csv: &str) -> crate::error::Result<Vec<Network>> {
        csv.split(',')
            .map(|name| {
                Self::by_name(name.trim()).ok_or_else(|| {
                    crate::error::Error::InvalidConfig(format!(
                        "unknown network '{}' (try \
                         resnet18/resnet34/resnet50/squeezenet/vgg16/mobilenetv1)",
                        name.trim()
                    ))
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn benchmark_lookup() {
        assert!(Network::by_name("resnet18").is_some());
        assert!(Network::by_name("ResNet50").is_some());
        // Extended (non-paper) workloads resolve too.
        assert!(Network::by_name("vgg16").is_some());
        assert!(Network::by_name("MobileNetV1").is_some());
        assert!(Network::by_name("lenet").is_none());
    }

    #[test]
    fn csv_lookup() {
        let nets = Network::by_names("resnet18, squeezenet").unwrap();
        assert_eq!(nets.len(), 2);
        assert_eq!(nets[1].name, "SqueezeNet");
        assert!(Network::by_names("resnet18,lenet").is_err());
    }
}
