//! The unified execution engine (tentpole of the API redesign).
//!
//! The paper's system is *one* engine with interchangeable weight paths;
//! this module makes the repro match: a single [`Engine`] facade drives any
//! [`ExecutionBackend`] — the analytical model ([`AnalyticalBackend`]), the
//! cycle-level simulator ([`SimBackend`]) or the PJRT runtime
//! ([`PjrtBackend`]) — through the same `plan → execute_layer → finish`
//! contract, so the three execution paths stay comparable by construction.
//!
//! ```no_run
//! use unzipfpga::engine::{BackendKind, Engine};
//! use unzipfpga::arch::{DesignPoint, Platform};
//! use unzipfpga::workload::{resnet, RatioProfile};
//!
//! let net = resnet::resnet18();
//! let profile = RatioProfile::ovsf50(&net);
//! let mut engine = Engine::builder()
//!     .platform(Platform::z7045())
//!     .bandwidth(4)
//!     .design_point(DesignPoint::new(64, 64, 16, 48))
//!     .network(net)
//!     .profile(profile)
//!     .backend(BackendKind::Simulator)
//!     .build()?;
//! let report = engine.infer_timing()?;
//! println!("{:.1} inf/s on {}", report.inf_per_s(), report.backend);
//! # Ok::<(), unzipfpga::Error>(())
//! ```
//!
//! For serving, the API splits **compile-once / serve-many**: a
//! [`Compiler`] produces immutable [`CompiledModel`] artifacts, a
//! [`ModelRegistry`](crate::coordinator::registry::ModelRegistry) holds
//! them under string ids over one shared slab cache, and
//! [`ServerPool::serve`](crate::coordinator::pool::ServerPool::serve)
//! routes model-named requests onto backend workers that swap plans on
//! model switch (PJRT clients are not `Send`, so each worker builds its
//! backend in-thread). [`EngineBuilder::build_pool`] remains as the
//! single-model convenience over that path.

pub mod analytical;
pub mod backend;
pub mod compile;
pub mod fault;
pub mod pjrt;
pub mod sim;
pub mod wcache;

pub use analytical::AnalyticalBackend;
pub use backend::{
    EnginePlan, ExecutionBackend, ExecutionReport, LayerCost, LayerOutcome, OverlapTelemetry,
};
pub use compile::{CompiledModel, Compiler};
pub use fault::{FaultPlan, FaultStats, FaultyBackend};
pub use pjrt::{PjrtBackend, PjrtConfig};
pub use sim::SimBackend;
pub use wcache::{Slab, SlabCache, SlabKey, WeightsKey};

use std::sync::Arc;

use crate::arch::{DesignPoint, Platform};
pub use crate::util::fixed::Precision;
use crate::coordinator::pool::{PoolConfig, ServerPool};
use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::plan::InferencePlan;
use crate::dse::search::{optimise, DseConfig};
use crate::error::{Error, Result};
use crate::workload::{Network, RatioProfile};

/// Which built-in backend an [`EngineBuilder`] should instantiate.
#[derive(Clone, Debug)]
pub enum BackendKind {
    /// Closed-form analytical model (Eqs. 5–8).
    Analytical,
    /// Cycle-level simulator (tile-walked schedules).
    Simulator,
    /// PJRT runtime executing an AOT artifact (real numerics).
    Pjrt(PjrtConfig),
}

/// The unified execution facade: a validated [`EnginePlan`] plus the
/// backend that executes it.
pub struct Engine {
    plan: EnginePlan,
    backend: Box<dyn ExecutionBackend>,
}

/// Result of one inference through an [`Engine`].
#[derive(Clone, Debug)]
pub struct InferenceOutcome {
    /// Cost/trace report from the backend.
    pub report: ExecutionReport,
    /// Output activations (empty for timing-only backends and timing-only
    /// requests).
    pub output: Vec<f32>,
}

impl Engine {
    /// Start building an engine.
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// Construct an engine from a validated plan and a backend kind. The
    /// backend's `plan` hook runs here (artifact compilation, cost
    /// precomputation). The simulator backend gets a private weights
    /// cache; use [`EngineBuilder::weights_cache`] to share one.
    pub fn from_plan(plan: EnginePlan, kind: &BackendKind) -> Result<Self> {
        let backend = make_backend(kind, &Arc::new(SlabCache::new()), Precision::F32)?;
        Self::with_backend(plan, backend)
    }

    /// Construct an engine from a validated plan and a caller-provided
    /// backend (the extension point for custom execution paths).
    pub fn with_backend(plan: EnginePlan, mut backend: Box<dyn ExecutionBackend>) -> Result<Self> {
        backend.plan(&plan)?;
        Ok(Self { plan, backend })
    }

    /// Construct an engine serving a [`CompiledModel`]: the backend is
    /// planned with the artifact's plan and handed the artifact
    /// ([`ExecutionBackend::preload`]; α state is adopted on first numeric
    /// use), generating slabs through `cache`.
    pub fn from_compiled(
        model: &Arc<CompiledModel>,
        kind: &BackendKind,
        cache: &Arc<SlabCache>,
    ) -> Result<Self> {
        let mut backend = make_backend(kind, cache, model.precision())?;
        backend.plan(model.plan())?;
        backend.preload(model)?;
        Ok(Self {
            plan: model.plan().clone(),
            backend,
        })
    }

    /// Like [`from_compiled`](Self::from_compiled), but over a
    /// caller-provided (possibly decorated) backend: the backend is planned
    /// with the artifact's plan and handed the artifact. This is the seam
    /// replicated serving uses to wrap a replica's backends (e.g. in
    /// [`FaultyBackend`](crate::engine::fault::FaultyBackend) for chaos
    /// testing) without touching the production construction path.
    pub fn from_compiled_with(
        model: &Arc<CompiledModel>,
        mut backend: Box<dyn ExecutionBackend>,
    ) -> Result<Self> {
        backend.plan(model.plan())?;
        backend.preload(model)?;
        Ok(Self {
            plan: model.plan().clone(),
            backend,
        })
    }

    /// Swap the active model on this engine **between requests**: re-plan
    /// the backend with the artifact's plan and hand it the artifact.
    /// This is the model-switch primitive of multi-model serving — the
    /// fabric (backend instance, shared slab cache) stays, only the plan
    /// and the (lazily adopted) α state move.
    pub fn activate(&mut self, model: &Arc<CompiledModel>) -> Result<()> {
        self.backend.plan(model.plan())?;
        self.backend.preload(model)?;
        self.plan = model.plan().clone();
        Ok(())
    }

    /// The validated plan this engine executes.
    pub fn plan(&self) -> &EnginePlan {
        &self.plan
    }

    /// The active backend's name.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Run one inference: walk every layer through the backend, threading
    /// activations between layers, then collect the cost/trace report.
    ///
    /// A non-empty `input` must be exactly the first layer's `h·w·c_in`
    /// NHWC activations ([`Error::InvalidConfig`] otherwise); on the
    /// simulator backend the output then carries real numerics computed
    /// tile-by-tile with on-the-fly generated weights. An empty `input` is
    /// a timing-only request (the
    /// [`Request`](crate::coordinator::server::Request) convention): no
    /// numerics are computed and no weights are generated.
    pub fn infer(&mut self, input: &[f32]) -> Result<InferenceOutcome> {
        if !input.is_empty() {
            if let Some(l0) = self.plan.network.layers.first() {
                let expect = (l0.h * l0.w * l0.n_in) as usize;
                if input.len() != expect {
                    return Err(Error::InvalidConfig(format!(
                        "input length {} does not match first layer '{}' \
                         h·w·c_in = {}·{}·{} = {expect}",
                        input.len(),
                        l0.name,
                        l0.h,
                        l0.w,
                        l0.n_in
                    )));
                }
            }
        }
        let n = self.plan.n_layers();
        let mut current: Vec<f32> = Vec::new();
        let mut produced = false;
        for idx in 0..n {
            let layer_input = if produced { current.as_slice() } else { input };
            let outcome = match self.backend.execute_layer(idx, layer_input) {
                Ok(o) => o,
                Err(e) => {
                    // Flush the backend's per-request state (partial layer
                    // costs, threading shape) so the next request over this
                    // engine starts clean instead of inheriting the failed
                    // request's layers in its report.
                    let _ = self.backend.finish();
                    return Err(e);
                }
            };
            if let Some(out) = outcome.output {
                current = out;
                produced = true;
            }
        }
        let report = self.backend.finish()?;
        Ok(InferenceOutcome {
            report,
            output: if produced { current } else { Vec::new() },
        })
    }

    /// Timing-only inference (no activations), returning just the report.
    pub fn infer_timing(&mut self) -> Result<ExecutionReport> {
        self.infer(&[]).map(|o| o.report)
    }

    /// Run one **batched** inference: every input walks the network
    /// together, layer by layer, through
    /// [`ExecutionBackend::execute_layer_batch`] — on the simulator backend
    /// the batch dimension folds into GEMM rows, so each weight slab is
    /// generated once per layer pass and multiplied against the whole
    /// batch. Outputs are bit-identical to running [`infer`](Self::infer)
    /// per input.
    ///
    /// Every input must be non-empty and exactly the first layer's
    /// `h·w·c_in` activations (timing-only requests don't batch — use
    /// [`infer_timing`](Self::infer_timing)). The report charges each
    /// layer once with the whole batch's cycles. Inputs are taken by value:
    /// they seed the activation threading directly, with no internal copy.
    pub fn infer_batch(
        &mut self,
        inputs: Vec<Vec<f32>>,
    ) -> Result<(Vec<Vec<f32>>, ExecutionReport)> {
        if inputs.is_empty() {
            return Err(Error::InvalidConfig(
                "infer_batch needs at least one input".into(),
            ));
        }
        if let Some(l0) = self.plan.network.layers.first() {
            let expect = (l0.h * l0.w * l0.n_in) as usize;
            for (i, input) in inputs.iter().enumerate() {
                if input.len() != expect {
                    return Err(Error::InvalidConfig(format!(
                        "batch input {i} has length {} but first layer '{}' \
                         expects h·w·c_in = {expect}",
                        input.len(),
                        l0.name
                    )));
                }
            }
        }
        let n = self.plan.n_layers();
        let batch_size = inputs.len();
        let mut current: Vec<Vec<f32>> = inputs;
        let mut produced = false;
        for idx in 0..n {
            let refs: Vec<&[f32]> = current.iter().map(|v| v.as_slice()).collect();
            let outcomes = match self.backend.execute_layer_batch(idx, &refs) {
                Ok(o) => o,
                Err(e) => {
                    // Same flush discipline as `infer`: the failed
                    // request's partial layer costs must not leak into the
                    // next report.
                    let _ = self.backend.finish();
                    return Err(e);
                }
            };
            if outcomes.len() != current.len() {
                let _ = self.backend.finish();
                return Err(Error::InvalidConfig(format!(
                    "backend returned {} outcomes for a batch of {}",
                    outcomes.len(),
                    current.len()
                )));
            }
            if outcomes.iter().all(|o| o.output.is_some()) {
                current = outcomes.into_iter().filter_map(|o| o.output).collect();
                produced = true;
            }
        }
        let report = self.backend.finish()?;
        let outputs = if produced {
            current
        } else {
            vec![Vec::new(); batch_size]
        };
        Ok((outputs, report))
    }
}

/// Builder for [`Engine`]s (and engine-backed server pools).
///
/// Required: [`network`](Self::network). Everything else has defaults:
/// platform Z7045, bandwidth 4×, OVSF50 profile, analytical backend, and a
/// design point chosen by the DSE when none is given.
#[derive(Clone, Debug, Default)]
pub struct EngineBuilder {
    platform: Option<Platform>,
    bw_mult: Option<u32>,
    sigma: Option<DesignPoint>,
    network: Option<Network>,
    profile: Option<RatioProfile>,
    backend: Option<BackendKind>,
    weights_cache: Option<Arc<SlabCache>>,
    slab_budget: Option<usize>,
    precision: Option<Precision>,
}

/// Instantiate a backend of `kind`, wiring the simulator onto `cache` at
/// the requested weight-datapath precision. Only the simulator has an i8
/// datapath: the analytical model is precision-neutral (cycle counts are
/// word-length independent on the modelled fixed-point engine) and the
/// PJRT runtime executes a fixed AOT-compiled f32 artifact, so `I8` there
/// is a configuration error. `pub(crate)` so the registry's worker
/// executor can construct a raw backend to decorate (the chaos-wrap seam)
/// before planning it via [`Engine::from_compiled_with`].
pub(crate) fn make_backend(
    kind: &BackendKind,
    cache: &Arc<SlabCache>,
    precision: Precision,
) -> Result<Box<dyn ExecutionBackend>> {
    Ok(match kind {
        BackendKind::Analytical => Box::new(AnalyticalBackend::new()),
        BackendKind::Simulator => {
            let mut b = SimBackend::with_cache(Arc::clone(cache));
            b.precision = precision;
            Box::new(b)
        }
        BackendKind::Pjrt(cfg) => {
            if precision != Precision::F32 {
                return Err(Error::InvalidConfig(format!(
                    "PJRT backend executes a fixed AOT f32 artifact; it cannot \
                     serve a {precision} model"
                )));
            }
            Box::new(PjrtBackend::new(cfg.clone())?)
        }
    })
}

impl EngineBuilder {
    /// Target platform (default: Z7045).
    pub fn platform(mut self, platform: Platform) -> Self {
        self.platform = Some(platform);
        self
    }

    /// Off-chip bandwidth multiplier (default: 4).
    pub fn bandwidth(mut self, bw_mult: u32) -> Self {
        self.bw_mult = Some(bw_mult);
        self
    }

    /// Design point σ (default: run the DSE and take the optimum).
    pub fn design_point(mut self, sigma: DesignPoint) -> Self {
        self.sigma = Some(sigma);
        self
    }

    /// The CNN workload (required).
    pub fn network(mut self, network: Network) -> Self {
        self.network = Some(network);
        self
    }

    /// Per-layer OVSF ratio profile (default: OVSF50 for the network).
    pub fn profile(mut self, profile: RatioProfile) -> Self {
        self.profile = Some(profile);
        self
    }

    /// Execution backend (default: [`BackendKind::Analytical`]).
    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Weight-datapath precision (default: `F32`). At `I8` the simulator
    /// backend quantises OVSF slabs at emission (4× denser in the slab
    /// cache) and multiplies them on the i8×i8→i32 microkernel; only the
    /// simulator supports it. [`build_pool`](Self::build_pool) compiles
    /// its artifact at this precision.
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = Some(precision);
        self
    }

    /// Share a generated-weights slab cache across every engine built from
    /// this builder (default: [`build`](Self::build) gets a private cache;
    /// [`build_pool`](Self::build_pool) always shares one across workers).
    /// A shared cache keeps its own byte budget —
    /// [`slab_budget`](Self::slab_budget) only sizes builder-created
    /// caches.
    pub fn weights_cache(mut self, cache: Arc<SlabCache>) -> Self {
        self.weights_cache = Some(cache);
        self
    }

    /// Byte budget for the generated-weights slab cache the builder
    /// creates (default: [`SlabCache::DEFAULT_BUDGET`]). Peak resident
    /// generated weights stay under this budget — the knob trading
    /// regeneration work for memory, per the paper's on-the-fly premise.
    pub fn slab_budget(mut self, bytes: usize) -> Self {
        self.slab_budget = Some(bytes);
        self
    }

    /// The slab cache this builder will wire into engines: the shared one
    /// if given, else a fresh cache sized by the configured budget.
    fn make_cache(&self) -> Arc<SlabCache> {
        self.weights_cache.clone().unwrap_or_else(|| {
            Arc::new(match self.slab_budget {
                Some(b) => SlabCache::with_budget(b),
                None => SlabCache::new(),
            })
        })
    }

    /// Validate the configuration into an [`EnginePlan`] without
    /// instantiating a backend (useful for admission control and tests).
    pub fn plan(&self) -> Result<EnginePlan> {
        let network = self
            .network
            .clone()
            .ok_or_else(|| Error::InvalidConfig("EngineBuilder: network is required".into()))?;
        let platform = self.platform.clone().unwrap_or_else(Platform::z7045);
        let bw_mult = self.bw_mult.unwrap_or(4);
        if bw_mult == 0 {
            return Err(Error::InvalidConfig(
                "EngineBuilder: bandwidth multiplier must be ≥ 1".into(),
            ));
        }
        if self.slab_budget == Some(0) {
            return Err(Error::InvalidConfig(
                "EngineBuilder: slab budget must be ≥ 1 byte".into(),
            ));
        }
        if bw_mult > platform.peak_bw_mult {
            return Err(Error::InvalidConfig(format!(
                "EngineBuilder: bandwidth {bw_mult}× exceeds {} peak ({}×)",
                platform.name, platform.peak_bw_mult
            )));
        }
        let profile = self
            .profile
            .clone()
            .unwrap_or_else(|| RatioProfile::ovsf50(&network));
        if profile.len() != network.layers.len() {
            return Err(Error::InvalidConfig(format!(
                "EngineBuilder: profile '{}' has {} entries for {} layers of {}",
                profile.name,
                profile.len(),
                network.layers.len(),
                network.name
            )));
        }
        let sigma = match self.sigma {
            Some(s) => s,
            None => {
                optimise(
                    &DseConfig::default(),
                    &platform,
                    bw_mult,
                    &network,
                    &profile,
                    true,
                )?
                .sigma
            }
        };
        if sigma.t_r == 0 || sigma.t_p == 0 || sigma.t_c == 0 {
            return Err(Error::InvalidConfig(format!(
                "EngineBuilder: degenerate design point {sigma}"
            )));
        }
        let has_ovsf = network.layers.iter().any(|l| l.ovsf);
        if has_ovsf && !sigma.has_wgen() {
            return Err(Error::InvalidConfig(format!(
                "EngineBuilder: {sigma} disables CNN-WGen (M = 0) but {} has OVSF layers",
                network.name
            )));
        }
        let schedule = InferencePlan::build(&platform, bw_mult, sigma, &network, &profile);
        Ok(EnginePlan {
            platform,
            bw_mult,
            sigma,
            network,
            profile,
            schedule,
        })
    }

    /// Validate and construct the [`Engine`].
    pub fn build(self) -> Result<Engine> {
        let plan = self.plan()?;
        let cache = self.make_cache();
        let kind = self.backend.unwrap_or(BackendKind::Analytical);
        let precision = self.precision.unwrap_or_default();
        Engine::with_backend(plan, make_backend(&kind, &cache, precision)?)
    }

    /// Validate once, compile the model, and stand up a **registry-routed**
    /// [`ServerPool`](crate::coordinator::pool::ServerPool) serving it as
    /// the sole registered model (under the network's name; requests may
    /// use the default route). This is now a thin adapter over the
    /// multi-model path — [`Compiler`] +
    /// [`ModelRegistry`](crate::coordinator::registry::ModelRegistry) +
    /// [`ServerPool::serve`](crate::coordinator::pool::ServerPool::serve) —
    /// with one bounded slab cache shared by every worker. Register more
    /// models on the returned pool's registry at any time.
    pub fn build_pool(self, cfg: PoolConfig) -> Result<ServerPool> {
        let plan = self.plan()?;
        // One bounded slab cache for the whole pool: every worker's
        // simulator backend shares it, so a hot slab is generated at most
        // once per process and the byte budget bounds the pool's *cached*
        // generated weights (each worker additionally pins at most the one
        // slab it is currently streaming).
        let cache = self.make_cache();
        let kind = self.backend.unwrap_or(BackendKind::Analytical);
        let compiled = CompiledModel::from_plan_at(plan, self.precision.unwrap_or_default())?;
        let registry = Arc::new(ModelRegistry::with_cache(cache));
        let id = compiled.network_name().to_string();
        registry.register(id, compiled)?;
        ServerPool::serve(registry, kind, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::resnet;

    fn builder() -> EngineBuilder {
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf50(&net);
        Engine::builder()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(DesignPoint::new(64, 64, 16, 48))
            .network(net)
            .profile(profile)
    }

    #[test]
    fn analytical_engine_matches_perf_model() {
        use crate::perf::model::PerfModel;
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf50(&net);
        let expect = PerfModel::new(Platform::z7045(), 4)
            .network_perf(&DesignPoint::new(64, 64, 16, 48), &net, &profile);
        let mut engine = builder().backend(BackendKind::Analytical).build().unwrap();
        let report = engine.infer_timing().unwrap();
        assert_eq!(report.backend, "analytical");
        assert_eq!(report.layers.len(), net.layers.len());
        assert!((report.total_cycles - expect.total_cycles).abs() < 1e-6);
        assert!((report.inf_per_s() - expect.inf_per_s).abs() < 1e-9 * expect.inf_per_s);
    }

    #[test]
    fn engine_is_reusable_across_requests() {
        let mut engine = builder().backend(BackendKind::Simulator).build().unwrap();
        let a = engine.infer_timing().unwrap();
        let b = engine.infer_timing().unwrap();
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.layers.len(), b.layers.len());
    }

    #[test]
    fn pjrt_backend_fails_cleanly_without_artifacts() {
        let cfg = PjrtConfig::new("/nonexistent-artifacts", "model_fwd", vec![1]);
        let err = builder()
            .backend(BackendKind::Pjrt(cfg))
            .build()
            .err()
            .expect("must fail: no artifacts");
        let msg = err.to_string();
        assert!(
            msg.contains("make artifacts") || msg.contains("pjrt"),
            "actionable: {msg}"
        );
    }

    fn tiny_builder() -> EngineBuilder {
        let net = crate::workload::Network {
            name: "tiny".into(),
            layers: vec![
                crate::workload::Layer::conv("stem", 8, 8, 4, 8, 3, 1, 1, false),
                crate::workload::Layer::conv("b.conv1", 8, 8, 8, 8, 3, 1, 1, true),
                crate::workload::Layer::conv("b.conv2", 8, 8, 8, 16, 3, 2, 1, true),
            ],
        };
        let profile = RatioProfile::uniform(&net, 0.5);
        Engine::builder()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(DesignPoint::new(8, 4, 8, 4))
            .network(net)
            .profile(profile)
    }

    #[test]
    fn builder_shares_weights_cache_across_engines() {
        let cache = Arc::new(SlabCache::new());
        let b = tiny_builder()
            .backend(BackendKind::Simulator)
            .weights_cache(Arc::clone(&cache));
        let mut e1 = b.clone().build().unwrap();
        let mut e2 = b.build().unwrap();
        let input = vec![0.5f32; 8 * 8 * 4];
        // Timing-only requests never generate.
        e1.infer_timing().unwrap();
        assert!(cache.is_empty());
        // Numeric requests stream slabs through the shared cache: 2 + 4
        // column tiles at T_C = 4.
        let o1 = e1.infer(&input).unwrap();
        assert_eq!(cache.misses(), 6);
        let o2 = e2.infer(&input).unwrap();
        assert_eq!(cache.misses(), 6, "second engine reuses every slab");
        assert_eq!(cache.hits(), 6);
        assert_eq!(o1.output, o2.output, "engines agree on the numerics");
        assert!(!o1.output.is_empty());
    }

    #[test]
    fn infer_validates_input_length() {
        let mut engine = tiny_builder()
            .backend(BackendKind::Simulator)
            .build()
            .unwrap();
        let err = engine.infer(&[0.0; 7]).err().expect("wrong length");
        assert!(matches!(err, Error::InvalidConfig(_)), "{err}");
        assert!(err.to_string().contains("h·w·c_in"), "{err}");
        // The exact length and the timing-only (empty) convention both pass.
        engine.infer(&vec![0.0; 8 * 8 * 4]).unwrap();
        engine.infer(&[]).unwrap();
    }

    /// Backend that errors once at layer 2, then serves normally — for
    /// checking that `Engine::infer` flushes per-request backend state on
    /// failure instead of leaking it into the next request's report.
    struct FailOnce {
        failed: bool,
        executed: Vec<LayerCost>,
    }

    impl ExecutionBackend for FailOnce {
        fn name(&self) -> &'static str {
            "fail-once"
        }

        fn plan(&mut self, _plan: &EnginePlan) -> Result<()> {
            Ok(())
        }

        fn execute_layer(&mut self, idx: usize, _input: &[f32]) -> Result<LayerOutcome> {
            if !self.failed && idx == 2 {
                self.failed = true;
                return Err(Error::ShapeMismatch("injected mid-request failure".into()));
            }
            self.executed.push(LayerCost {
                name: format!("l{idx}"),
                cycles: 1.0,
                bound: crate::perf::Bound::Compute,
                overlap: OverlapTelemetry::default(),
            });
            Ok(LayerOutcome {
                name: format!("l{idx}"),
                cycles: 1.0,
                bound: crate::perf::Bound::Compute,
                output: None,
                overlap: OverlapTelemetry::default(),
            })
        }

        fn finish(&mut self) -> Result<ExecutionReport> {
            let layers = std::mem::take(&mut self.executed);
            let total_cycles: f64 = layers.iter().map(|l| l.cycles).sum();
            Ok(ExecutionReport {
                backend: "fail-once",
                layers,
                total_cycles,
                latency_s: 0.0,
            })
        }
    }

    #[test]
    fn failed_request_does_not_leak_layers_into_the_next_report() {
        let plan = tiny_builder().plan().unwrap();
        let n = plan.n_layers();
        let backend = FailOnce {
            failed: false,
            executed: Vec::new(),
        };
        let mut engine = Engine::with_backend(plan, Box::new(backend)).unwrap();
        assert!(engine.infer_timing().is_err(), "first request must fail");
        let report = engine.infer_timing().unwrap();
        assert_eq!(
            report.layers.len(),
            n,
            "failed request's partial layers leaked into the next report"
        );
        assert!((report.total_cycles - n as f64).abs() < 1e-9);
    }

    #[test]
    fn infer_batch_matches_per_request_and_amortises_slabs() {
        // Budget of exactly one slab (P×T_C×4 = 72·4·4 bytes for both OVSF
        // layers): nothing survives between layer passes, so the miss count
        // discriminates real batch folding — per-image execution would
        // regenerate every slab per image (4 × 6 misses), while one folded
        // pass generates each slab exactly once.
        let cache = Arc::new(SlabCache::with_budget(72 * 4 * 4));
        let b = tiny_builder()
            .backend(BackendKind::Simulator)
            .weights_cache(Arc::clone(&cache));
        let mut rng = crate::util::prng::Xoshiro256::seed_from_u64(7);
        let inputs: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(8 * 8 * 4)).collect();
        // Per-request reference on a separate engine with its own cache.
        let mut solo = tiny_builder().backend(BackendKind::Simulator).build().unwrap();
        let expect: Vec<Vec<f32>> = inputs
            .iter()
            .map(|input| solo.infer(input).unwrap().output)
            .collect();
        let mut engine = b.build().unwrap();
        let (outs, report) = engine.infer_batch(inputs.clone()).unwrap();
        assert_eq!(outs, expect, "batched outputs must match per-request");
        // 2 + 4 column tiles at T_C = 4, generated once for the whole
        // batch despite the one-slab budget.
        assert_eq!(cache.misses(), 6, "slab misses must not scale with batch");
        assert_eq!(report.layers.len(), engine.plan().network.layers.len());
        // Shape validation rejects a bad batch member.
        let mut bad = inputs.clone();
        bad[2] = vec![0.0; 7];
        assert!(engine.infer_batch(bad).is_err());
        assert!(engine.infer_batch(Vec::new()).is_err());
    }

    #[test]
    fn builder_precision_reaches_the_datapath_and_rejects_pjrt() {
        let input = vec![0.5f32; 8 * 8 * 4];
        let cache = Arc::new(SlabCache::new());
        let mut engine = tiny_builder()
            .backend(BackendKind::Simulator)
            .weights_cache(Arc::clone(&cache))
            .precision(Precision::I8)
            .build()
            .unwrap();
        let out = engine.infer(&input).unwrap();
        assert!(!out.output.is_empty());
        // 6 OVSF slabs, all i8 ⇒ P·T_C bytes each instead of 4·P·T_C.
        assert_eq!(cache.resident_bytes(), 6 * 72 * 4);
        // The PJRT runtime executes a fixed f32 AOT artifact.
        let cfg = PjrtConfig::new("/nonexistent-artifacts", "model_fwd", vec![1]);
        let err = builder()
            .backend(BackendKind::Pjrt(cfg))
            .precision(Precision::I8)
            .build()
            .err()
            .expect("PJRT at i8 must be rejected");
        assert!(err.to_string().contains("f32 artifact"), "{err}");
    }

    #[test]
    fn slab_budget_must_be_positive() {
        let built = tiny_builder().slab_budget(0).build();
        let err = built.err().expect("budget 0 must be rejected");
        assert!(matches!(err, Error::InvalidConfig(_)), "{err}");
    }

    #[test]
    fn dse_picks_sigma_when_not_given() {
        let net = resnet::resnet18();
        let engine = Engine::builder()
            .platform(Platform::z7045())
            .bandwidth(1)
            .network(net)
            .build()
            .unwrap();
        assert!(engine.plan().sigma.engine_macs() > 0);
    }
}
