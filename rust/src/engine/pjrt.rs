//! [`PjrtBackend`] — executes requests on the PJRT runtime via the
//! AOT-compiled HLO artifacts ([`ArtifactRegistry`]).
//!
//! The artifacts are whole-model programs (e.g. `model_fwd`), not per-layer
//! kernels, so the backend runs the artifact once per inference — at layer
//! 0, where the request activations are available — and reports the
//! remaining layers as passthrough, charged with their admission-time
//! (analytical) cycle estimates from the [`EnginePlan`] schedule. This
//! keeps the cost/trace contract of [`ExecutionBackend`] while the
//! numerics come from the real compiled model.
//!
//! PJRT clients are not `Send`: construct this backend (or the
//! [`Engine`](crate::engine::Engine) owning it) inside the thread that
//! serves it — the [`ServerPool`](crate::coordinator::pool::ServerPool)
//! worker factory does exactly that.

use crate::engine::backend::{
    EnginePlan, ExecutionBackend, ExecutionReport, LayerCost, LayerOutcome, OverlapTelemetry,
};
use crate::error::{Error, Result};
use crate::runtime::ArtifactRegistry;
use std::path::PathBuf;

/// One extra (non-request) input buffer fed to the artifact.
pub type ParamBuffer = (Vec<f32>, Vec<usize>);

/// Configuration of a [`PjrtBackend`]: which artifact to run and how the
/// request input + parameter buffers map onto its arguments.
#[derive(Clone, Debug)]
pub struct PjrtConfig {
    /// Artifact directory (see [`crate::runtime::artifacts_dir`]).
    pub artifacts_dir: PathBuf,
    /// Artifact name (`<dir>/<name>.hlo.txt`).
    pub artifact: String,
    /// Dimensions of the request input buffer (argument 0).
    pub input_dims: Vec<usize>,
    /// Parameter buffers appended after the request input, in order.
    pub params: Vec<ParamBuffer>,
}

impl PjrtConfig {
    /// Config for an artifact taking only the request input.
    pub fn new(
        artifacts_dir: impl Into<PathBuf>,
        artifact: impl Into<String>,
        input_dims: Vec<usize>,
    ) -> Self {
        Self {
            artifacts_dir: artifacts_dir.into(),
            artifact: artifact.into(),
            input_dims,
            params: Vec::new(),
        }
    }
}

/// Backend over the PJRT runtime.
pub struct PjrtBackend {
    cfg: PjrtConfig,
    registry: ArtifactRegistry,
    schedule: Vec<LayerCost>,
    clock_hz: f64,
    executed: Vec<LayerCost>,
}

impl PjrtBackend {
    /// Create the backend (opens the PJRT client; artifact compilation
    /// happens at [`plan`](ExecutionBackend::plan) time).
    pub fn new(cfg: PjrtConfig) -> Result<Self> {
        let registry = ArtifactRegistry::new(cfg.artifacts_dir.clone())?;
        Ok(Self {
            cfg,
            registry,
            schedule: Vec::new(),
            clock_hz: 1.0,
            executed: Vec::new(),
        })
    }
}

impl ExecutionBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn plan(&mut self, plan: &EnginePlan) -> Result<()> {
        // Compile (or fail fast: missing artifact / stub runtime).
        self.registry.get(&self.cfg.artifact)?;
        self.schedule = plan
            .schedule
            .layers
            .iter()
            .map(|l| LayerCost {
                name: l.name.clone(),
                cycles: l.cycles,
                bound: l.bound,
                overlap: OverlapTelemetry::default(),
            })
            .collect();
        self.clock_hz = plan.platform.clock_hz;
        self.executed.clear();
        Ok(())
    }

    fn execute_layer(&mut self, idx: usize, input: &[f32]) -> Result<LayerOutcome> {
        let cost = self.schedule.get(idx).cloned().ok_or_else(|| {
            Error::InvalidConfig(format!(
                "layer index {idx} out of range ({} layers)",
                self.schedule.len()
            ))
        })?;
        let output = if idx == 0 {
            // The whole-model artifact consumes the request activations here.
            let exe = self.registry.get(&self.cfg.artifact)?;
            let mut inputs: Vec<(&[f32], &[usize])> =
                vec![(input, self.cfg.input_dims.as_slice())];
            for (data, dims) in &self.cfg.params {
                inputs.push((data.as_slice(), dims.as_slice()));
            }
            let mut out = exe.run_f32(&inputs)?;
            let first = if out.is_empty() { Vec::new() } else { out.swap_remove(0) };
            Some(first)
        } else {
            None
        };
        self.executed.push(cost.clone());
        Ok(LayerOutcome {
            name: cost.name,
            cycles: cost.cycles,
            bound: cost.bound,
            output,
            overlap: OverlapTelemetry::default(),
        })
    }

    fn finish(&mut self) -> Result<ExecutionReport> {
        let layers = std::mem::take(&mut self.executed);
        let total_cycles: f64 = layers.iter().map(|l| l.cycles).sum();
        Ok(ExecutionReport {
            backend: self.name(),
            layers,
            total_cycles,
            latency_s: total_cycles / self.clock_hz,
        })
    }
}
