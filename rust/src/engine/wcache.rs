//! Generated-weights cache for the engine (paper's on-the-fly generation,
//! amortised across serving).
//!
//! CNN-WGen regenerates weights *per tile* in hardware; in the software
//! engine the equivalent reconstruction used to be redone for every
//! request that walked a layer. The cache keys the reconstructed dense
//! GEMM weights by `(model, layer, design point, ρ)` so a layer's weights
//! are generated exactly once per configuration — across repeated requests
//! *and* across [`ServerPool`](crate::coordinator::pool::ServerPool)
//! workers sharing the cache through an `Arc`.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::arch::DesignPoint;

/// Identity of one generated-weights entry. `(model, layer, shape, ρ)`
/// determine the numerics (TiWGen tiling is numerics-invariant — a tested
/// property); σ is part of the key per the engine's (model, layer, design
/// point) cache contract, which means engines differing *only* in σ do not
/// share entries — a deliberate trade of some duplication for per-plan
/// identity. The layer shape is part of the key so two same-named networks
/// with different geometry can never alias each other's weights.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct WeightsKey {
    /// Network name (the model identity).
    pub model: String,
    /// Layer index within the network.
    pub layer: usize,
    /// Layer geometry `(n_in, n_out, k)`.
    pub shape: (u64, u64, u64),
    /// Design point σ the weights are generated for.
    pub sigma: DesignPoint,
    /// Layer OVSF ratio ρ, as raw f64 bits (`f64` is not `Eq`/`Hash`).
    pub rho_bits: u64,
}

impl WeightsKey {
    /// Build a key from the plain configuration values.
    pub fn new(
        model: impl Into<String>,
        layer: usize,
        shape: (u64, u64, u64),
        sigma: DesignPoint,
        rho: f64,
    ) -> Self {
        Self {
            model: model.into(),
            layer,
            shape,
            sigma,
            rho_bits: rho.to_bits(),
        }
    }
}

/// One cache slot: filled exactly once, readable lock-free afterwards.
type Slot = Arc<OnceLock<Arc<Vec<f32>>>>;

/// Thread-safe generated-weights cache with hit/miss accounting.
#[derive(Debug, Default)]
pub struct WeightsCache {
    entries: Mutex<HashMap<WeightsKey, Slot>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl WeightsCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch the weights for `key`, running `generate` only if absent.
    ///
    /// The map lock is held only to resolve the key to its slot;
    /// generation runs outside it, so pool workers warming *different*
    /// layers proceed in parallel while racers on the *same* key block on
    /// that key's `OnceLock` — each layer is still reconstructed at most
    /// once per key.
    pub fn get_or_generate(
        &self,
        key: WeightsKey,
        generate: impl FnOnce() -> Vec<f32>,
    ) -> Arc<Vec<f32>> {
        let (slot, fresh) = {
            let mut map = self.entries.lock().expect("weights cache poisoned");
            match map.entry(key) {
                Entry::Occupied(e) => (Arc::clone(e.get()), false),
                Entry::Vacant(v) => (Arc::clone(v.insert(Arc::new(OnceLock::new()))), true),
            }
        };
        if fresh {
            self.misses.fetch_add(1, Ordering::Relaxed);
        } else {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        Arc::clone(slot.get_or_init(|| Arc::new(generate())))
    }

    /// Lookups served from the cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to generate (== number of reconstructions run).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Number of resident entries.
    pub fn len(&self) -> usize {
        self.entries.lock().expect("weights cache poisoned").len()
    }

    /// `true` when nothing has been generated yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes of weight data held by the cache (in-flight slots count 0).
    pub fn resident_bytes(&self) -> usize {
        self.entries
            .lock()
            .expect("weights cache poisoned")
            .values()
            .filter_map(|slot| slot.get())
            .map(|w| w.len() * std::mem::size_of::<f32>())
            .sum()
    }

    /// Drop every entry (counters are preserved).
    pub fn clear(&self) {
        self.entries.lock().expect("weights cache poisoned").clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(layer: usize) -> WeightsKey {
        WeightsKey::new("net", layer, (4, 8, 3), DesignPoint::new(8, 16, 4, 4), 0.5)
    }

    #[test]
    fn generates_once_per_key() {
        let cache = WeightsCache::new();
        let mut calls = 0;
        for _ in 0..3 {
            let v = cache.get_or_generate(key(0), || {
                calls += 1;
                vec![1.0, 2.0]
            });
            assert_eq!(v.as_slice(), &[1.0, 2.0]);
        }
        assert_eq!(calls, 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 2);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.resident_bytes(), 8);
    }

    #[test]
    fn distinct_keys_are_distinct_entries() {
        let cache = WeightsCache::new();
        cache.get_or_generate(key(0), || vec![0.0]);
        cache.get_or_generate(key(1), || vec![1.0]);
        let mut k = key(0);
        k.rho_bits = 0.25f64.to_bits();
        cache.get_or_generate(k, || vec![2.0]);
        // Same name/index/σ/ρ but different geometry ⇒ distinct entry.
        let mut k = key(0);
        k.shape = (8, 8, 3);
        cache.get_or_generate(k, || vec![3.0]);
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.misses(), 4);
        assert_eq!(cache.hits(), 0);
    }

    #[test]
    fn shared_across_threads_generates_once() {
        let cache = Arc::new(WeightsCache::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = Arc::clone(&cache);
            handles.push(std::thread::spawn(move || {
                c.get_or_generate(key(7), || vec![7.0]).len()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 1);
        }
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 3);
    }
}
