//! Bounded tile-slab store for on-the-fly generated weights.
//!
//! CNN-WGen's central property is that dense weights never exist in memory
//! as a whole: the generator re-materialises one weight *tile* at a time
//! while the PE array consumes it. The engine-level cache mirrors that
//! discipline. Instead of caching each OVSF layer's full dense `P×C` GEMM
//! matrix (O(model) resident bytes), [`SlabCache`] stores `P×T_C` column
//! *slabs* — the tile-granular unit
//! [`HwOvsfWeights::slab_into`](crate::sim::hw_weights::HwOvsfWeights::slab_into)
//! generates — under a configurable byte budget with LRU eviction, so peak
//! resident generated weights are O(slab budget) regardless of model size.
//! Slabs are precision-aware ([`Slab`]): an int8 slab is charged its true
//! 1-byte word width, so an i8-compiled model keeps ~4× the slabs of its
//! f32 twin resident under one budget.
//! The budget (and the [`peak_resident_bytes`](SlabCache::peak_resident_bytes)
//! gauge) covers the bytes the *cache* holds; a consumer additionally pins
//! at most the one slab it is currently streaming through its `Arc`
//! handle — an evicted slab's memory is freed when the last in-flight
//! handle drops. Re-generating an evicted slab is cheap (a handful of
//! FWHTs); that recompute-for-memory trade is exactly the paper's premise.
//!
//! The cache is shared across repeated requests *and* across
//! [`ServerPool`](crate::coordinator::pool::ServerPool) workers through an
//! `Arc` (see
//! [`EngineBuilder::build_pool`](crate::engine::EngineBuilder::build_pool));
//! hit/miss/eviction counters and resident/peak byte gauges make the
//! streaming behaviour observable.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use crate::arch::DesignPoint;
use crate::error::Result;
use crate::util::fixed::Precision;

/// Identity of one layer's generated weights. `(model, layer, shape, ρ)`
/// determine the numerics (TiWGen tiling is numerics-invariant — a tested
/// property); σ is part of the key because the slab geometry (`T_C` column
/// granularity) follows the design point, which means engines differing
/// *only* in σ do not share entries — a deliberate trade of some
/// duplication for per-plan identity. The layer shape is part of the key so
/// two same-named networks with different geometry can never alias each
/// other's weights.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct WeightsKey {
    /// Network name (the model identity).
    pub model: String,
    /// Layer index within the network.
    pub layer: usize,
    /// Layer geometry `(n_in, n_out, k)`.
    pub shape: (u64, u64, u64),
    /// Design point σ the weights are generated for.
    pub sigma: DesignPoint,
    /// Layer OVSF ratio ρ, as raw f64 bits (`f64` is not `Eq`/`Hash`).
    pub rho_bits: u64,
    /// Registration generation. Every
    /// [`ModelRegistry::register`](crate::coordinator::registry::ModelRegistry::register)
    /// stamps the artifact's keys with a fresh process-wide generation, so
    /// a batch still in flight when its model is evicted carries the *old*
    /// generation — it can never alias a later registration of the same
    /// model id, and the cache refuses to (re)insert slabs whose
    /// generation has been retired via
    /// [`SlabCache::retire_generation`], closing the evict-vs-in-flight
    /// reinsertion race at insert time. Engines without a registry
    /// artifact use generation 0 (never retired).
    pub generation: u64,
    /// Numeric precision the slabs are generated at. Part of the key so an
    /// f32 and an i8 compilation of the *same* network can coexist in one
    /// shared cache without ever aliasing each other's payloads.
    pub precision: Precision,
}

impl WeightsKey {
    /// Build a key from the plain configuration values (generation 0 —
    /// the unregistered/default generation).
    pub fn new(
        model: impl Into<String>,
        layer: usize,
        shape: (u64, u64, u64),
        sigma: DesignPoint,
        rho: f64,
    ) -> Self {
        Self {
            model: model.into(),
            layer,
            shape,
            sigma,
            rho_bits: rho.to_bits(),
            generation: 0,
            precision: Precision::F32,
        }
    }

    /// The same key under a different registration generation.
    #[must_use]
    pub fn with_generation(mut self, generation: u64) -> Self {
        self.generation = generation;
        self
    }

    /// The same key at a different numeric precision.
    #[must_use]
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }
}

/// Identity of one cached slab: a layer's weight columns
/// `[col_tile·T_C, min((col_tile+1)·T_C, C))` in the engine `P×C` layout.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct SlabKey {
    /// The layer the slab belongs to.
    pub layer: WeightsKey,
    /// Column-tile index within the layer (`0..⌈C/T_C⌉`).
    pub col_tile: u32,
}

/// Payload of one cached slab, at its generated precision.
///
/// The cache charges each variant its **true** byte width against the
/// budget: an i8 slab costs ¼ the bytes of its f32 twin, so an i8 model
/// keeps ~4× as many slabs resident under the same budget — the
/// cache-hit-rate half of the int8 datapath's win.
#[derive(Clone, Debug, PartialEq)]
pub enum Slab {
    /// Reference f32 weight words in the engine `P×T_C` layout.
    F32(Vec<f32>),
    /// Symmetric per-layer int8 codes (`real = code · scale`) in the same
    /// layout. The scale is stamped at generation time from the layer's
    /// fitted α sets and rides with the payload so a consumer can never
    /// pair codes with the wrong dequantise factor.
    I8 {
        /// Quantised weight codes.
        codes: Vec<i8>,
        /// Per-layer dequantise scale (> 0).
        scale: f32,
    },
}

impl Slab {
    /// The payload's precision.
    pub fn precision(&self) -> Precision {
        match self {
            Slab::F32(_) => Precision::F32,
            Slab::I8 { .. } => Precision::I8,
        }
    }

    /// Number of weight elements (layout positions, not bytes).
    pub fn len(&self) -> usize {
        match self {
            Slab::F32(d) => d.len(),
            Slab::I8 { codes, .. } => codes.len(),
        }
    }

    /// `true` when the slab holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Payload bytes at the precision's true word width — what the cache
    /// charges against its budget.
    pub fn bytes(&self) -> usize {
        self.len() * self.precision().word_bytes()
    }

    /// The f32 words, or `None` for an i8 slab.
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Slab::F32(d) => Some(d),
            Slab::I8 { .. } => None,
        }
    }

    /// The i8 codes and their dequantise scale, or `None` for an f32 slab.
    pub fn as_i8(&self) -> Option<(&[i8], f32)> {
        match self {
            Slab::F32(_) => None,
            Slab::I8 { codes, scale } => Some((codes, *scale)),
        }
    }

    /// The f32 words; panics on an i8 slab (test/bench convenience for
    /// call sites that construct the slab themselves).
    pub fn f32_data(&self) -> &[f32] {
        match self {
            Slab::F32(d) => d,
            Slab::I8 { .. } => panic!("f32_data() called on an i8 slab"),
        }
    }

    /// FNV-1a over the payload (and, for i8, the scale bits): covers
    /// exactly the bytes a consumer would stream, at either precision.
    pub fn checksum(&self) -> u64 {
        match self {
            Slab::F32(d) => slab_checksum(d),
            Slab::I8 { codes, scale } => {
                let mut h: u64 = 0xcbf2_9ce4_8422_2325;
                for c in codes {
                    h ^= *c as u8 as u64;
                    h = h.wrapping_mul(0x0000_0100_0000_01B3);
                }
                // The scale is part of the served numerics — cover it too.
                h ^= u64::from(scale.to_bits());
                h.wrapping_mul(0x0000_0100_0000_01B3)
            }
        }
    }
}

impl From<Vec<f32>> for Slab {
    fn from(data: Vec<f32>) -> Self {
        Slab::F32(data)
    }
}

struct SlabEntry {
    data: Arc<Slab>,
    last_used: u64,
    /// FNV-1a over the slab payload, stamped at insert and verified on
    /// every hit: a corrupted slab is evicted and regenerated instead of
    /// silently feeding garbage weights to the PE array.
    checksum: u64,
}

/// FNV-1a over a slab's raw `f32` bit patterns (word-at-a-time — the
/// verify cost per hit is a small constant factor of the copy the consumer
/// does anyway).
fn slab_checksum(data: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in data {
        h ^= u64::from(v.to_bits());
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

struct SlabMap {
    entries: HashMap<SlabKey, SlabEntry>,
    /// Monotonic access clock for LRU ordering.
    tick: u64,
    /// Highest retired registration generation per model name. Inserts
    /// whose key generation is `<=` the retired watermark are refused
    /// (the straggler still gets its generated slab back — it just cannot
    /// re-seed the cache for an evicted model). Lives *inside* the map so
    /// the retire/insert decision and the map mutation share one lock:
    /// there is no window where a straggler can slip an old-generation
    /// slab in between `retire_generation` and the eviction sweep.
    retired: HashMap<String, u64>,
}

/// Thread-safe bounded slab store with hit/miss/eviction accounting.
///
/// Metrics discipline: the `lookups`/`hits`/`misses`/`evictions` counters
/// are lock-free atomics mutated strictly **outside** the map lock (a
/// counter bump never extends the critical section), and the
/// `resident`/`peak_resident` byte gauges are atomics updated at the map
/// mutation points so every metric reads without touching the lock.
/// Counters reconcile exactly: `hits + misses == lookups` at any quiescent
/// point (a racer that regenerates an entry counts as a miss — the counter
/// tracks generation work).
pub struct SlabCache {
    budget: usize,
    map: Mutex<SlabMap>,
    lookups: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    corruptions: AtomicU64,
    /// Inserts refused because the key's generation was retired — each one
    /// is a straggler batch caught trying to re-seed an evicted model.
    retired_inserts: AtomicU64,
    resident: AtomicUsize,
    peak_resident: AtomicUsize,
}

impl Default for SlabMap {
    fn default() -> Self {
        Self {
            entries: HashMap::new(),
            tick: 0,
            retired: HashMap::new(),
        }
    }
}

impl Default for SlabCache {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for SlabCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SlabCache")
            .field("budget", &self.budget)
            .field("resident", &self.resident_bytes())
            .field("hits", &self.hits())
            .field("misses", &self.misses())
            .field("evictions", &self.evictions())
            .field("corruptions", &self.corruptions())
            .finish()
    }
}

impl SlabCache {
    /// Default byte budget: enough for every slab of a typical serving
    /// working set at `T_C ≤ 64` without thrashing, yet a small fraction of
    /// any ImageNet model's dense weights.
    pub const DEFAULT_BUDGET: usize = 16 << 20;

    /// Cache with the default budget.
    pub fn new() -> Self {
        Self::with_budget(Self::DEFAULT_BUDGET)
    }

    /// Cache holding at most ~`budget` bytes of slab data. A single slab
    /// larger than the budget is still admitted (alone) — generation must
    /// never deadlock — but the sizing is then reported by
    /// [`peak_resident_bytes`](Self::peak_resident_bytes) exceeding the
    /// budget.
    pub fn with_budget(budget: usize) -> Self {
        Self {
            budget,
            map: Mutex::new(SlabMap::default()),
            lookups: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            corruptions: AtomicU64::new(0),
            retired_inserts: AtomicU64::new(0),
            resident: AtomicUsize::new(0),
            peak_resident: AtomicUsize::new(0),
        }
    }

    /// The configured byte budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    fn lock(&self) -> MutexGuard<'_, SlabMap> {
        // Keep serving through poisoning: a panicking worker must not take
        // every other worker's weights path down with it.
        self.map.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Fetch the slab for `key`, running `generate` only on a miss.
    ///
    /// The map lock is dropped while `generate` runs, so workers streaming
    /// *different* slabs generate in parallel; racers on the *same* key may
    /// both generate (each counted as a miss — the counter tracks
    /// generation work) and the first insertion wins. Before inserting,
    /// least-recently-used slabs are evicted until the new slab fits the
    /// budget, so resident bytes never exceed `budget` while any other
    /// entry could still be dropped. Each slab is charged its **own**
    /// precision's byte width ([`Slab::bytes`]), so f32 and i8 slabs
    /// compete accurately under one budget.
    pub fn try_get_or_generate(
        &self,
        key: SlabKey,
        generate: impl FnOnce() -> Result<Slab>,
    ) -> Result<Arc<Slab>> {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        let found = {
            let mut m = self.lock();
            m.tick += 1;
            let tick = m.tick;
            match m.entries.get_mut(&key) {
                Some(e) => {
                    e.last_used = tick;
                    Some((Arc::clone(&e.data), e.checksum))
                }
                None => None,
            }
        };
        if let Some((data, stamped)) = found {
            // Verify outside the lock (the checksum walk must not extend
            // the critical section).
            if data.checksum() == stamped {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(data);
            }
            // Integrity failure: evict the corrupted slab (only if it is
            // still the *same* Arc — a racer may have replaced it already)
            // and fall through to regenerate instead of serving garbage.
            let removed = {
                let mut m = self.lock();
                let stale = m
                    .entries
                    .get(&key)
                    .is_some_and(|e| Arc::ptr_eq(&e.data, &data));
                if stale {
                    if let Some(e) = m.entries.remove(&key) {
                        self.resident.fetch_sub(e.data.bytes(), Ordering::Relaxed);
                        true
                    } else {
                        false
                    }
                } else {
                    false
                }
            };
            self.corruptions.fetch_add(1, Ordering::Relaxed);
            if removed {
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let data = Arc::new(generate()?);
        let bytes = data.bytes();
        let mut evicted_count = 0u64;
        let mut refused_retired = false;
        let adopted = {
            let mut m = self.lock();
            m.tick += 1;
            let tick = m.tick;
            if let Some(e) = m.entries.get_mut(&key) {
                // A racer generated and inserted first; adopt its copy (the
                // lookup stays counted as a miss — generation work ran).
                e.last_used = tick;
                Some(Arc::clone(&e.data))
            } else if key.layer.generation != 0
                && m.retired
                    .get(&key.layer.model)
                    .is_some_and(|&g| key.layer.generation <= g)
            {
                // The model registration this slab belongs to was retired
                // (evicted) while the generating batch was in flight. Serve
                // the straggler its own copy but refuse to cache it — an
                // old-generation slab must never re-seed the cache after
                // `evict_layer` swept it (the evict-vs-in-flight
                // reinsertion race). Checked under the same lock that
                // guards the map, so retire → sweep → refuse is airtight.
                refused_retired = true;
                None
            } else {
                // Evict-before-insert keeps the resident gauge under the
                // budget at every instant (given each slab individually
                // fits). The gauge is only ever mutated by the lock holder,
                // so reading it here is consistent.
                while self.resident.load(Ordering::Relaxed) + bytes > self.budget {
                    let Some(victim) = m
                        .entries
                        .iter()
                        .min_by_key(|(_, e)| e.last_used)
                        .map(|(k, _)| k.clone())
                    else {
                        break; // map empty: the slab is admitted alone
                    };
                    if let Some(evicted) = m.entries.remove(&victim) {
                        self.resident
                            .fetch_sub(evicted.data.bytes(), Ordering::Relaxed);
                        evicted_count += 1;
                    }
                }
                let now = self.resident.fetch_add(bytes, Ordering::Relaxed) + bytes;
                self.peak_resident.fetch_max(now, Ordering::Relaxed);
                let entry = SlabEntry {
                    data: Arc::clone(&data),
                    last_used: tick,
                    checksum: data.checksum(),
                };
                m.entries.insert(key, entry);
                None
            }
        };
        if evicted_count > 0 {
            self.evictions.fetch_add(evicted_count, Ordering::Relaxed);
        }
        if refused_retired {
            self.retired_inserts.fetch_add(1, Ordering::Relaxed);
        }
        Ok(adopted.unwrap_or(data))
    }

    /// Retire every registration generation of `model` up to and including
    /// `generation`: from this call on, a miss-path insert whose key
    /// carries a generation `<= generation` for this model is refused (the
    /// generating caller still gets its slab; the cache just won't keep
    /// it). Call *before* sweeping the model's slabs with
    /// [`evict_layer`](Self::evict_layer) — the retire watermark and the
    /// map share one lock, so any straggler insert either lands before the
    /// watermark (and is swept) or after (and is refused). Watermarks only
    /// move forward; generation 0 (unregistered engines) is never retired.
    pub fn retire_generation(&self, model: &str, generation: u64) {
        if generation == 0 {
            return;
        }
        let mut m = self.lock();
        let w = m.retired.entry(model.to_string()).or_insert(0);
        *w = (*w).max(generation);
    }

    /// Drop every slab of one layer (e.g. on model unload or profile
    /// change). Returns the number of slabs removed.
    pub fn evict_layer(&self, layer: &WeightsKey) -> usize {
        let n_victims = {
            let mut m = self.lock();
            let victims: Vec<SlabKey> = m
                .entries
                .keys()
                .filter(|k| &k.layer == layer)
                .cloned()
                .collect();
            for k in &victims {
                if let Some(e) = m.entries.remove(k) {
                    self.resident.fetch_sub(e.data.bytes(), Ordering::Relaxed);
                }
            }
            victims.len()
        };
        self.evictions.fetch_add(n_victims as u64, Ordering::Relaxed);
        n_victims
    }

    /// Total lookups (`hits() + misses()` at any quiescent point).
    pub fn lookups(&self) -> u64 {
        self.lookups.load(Ordering::Relaxed)
    }

    /// Lookups served from the cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to generate (== number of slab generations run).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Slabs dropped to stay under the byte budget (plus explicit
    /// [`evict_layer`](Self::evict_layer) removals and corruption
    /// evictions).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Checksum mismatches detected on hit: each one evicted the corrupted
    /// slab and regenerated it on the fly. Nonzero means memory corruption
    /// (or injected chaos) was caught before it reached the PE array.
    pub fn corruptions(&self) -> u64 {
        self.corruptions.load(Ordering::Relaxed)
    }

    /// Miss-path inserts refused because the key's registration generation
    /// was retired (see [`retire_generation`](Self::retire_generation)).
    /// Each one is a straggler batch that would otherwise have re-seeded
    /// slabs for an evicted model.
    pub fn retired_inserts(&self) -> u64 {
        self.retired_inserts.load(Ordering::Relaxed)
    }

    /// Chaos hook: flip one bit of one resident slab's payload *without*
    /// restamping its checksum, so the next hit on that slab detects the
    /// corruption. `nth` seeds the (deterministic, given a stable map)
    /// choice of entry/word/bit. Returns `false` when nothing is resident.
    /// Used by [`FaultyBackend`](crate::engine::fault::FaultyBackend) and
    /// the chaos-soak tests; harmless (and useless) in production.
    pub fn flip_bit(&self, nth: u64) -> bool {
        let mut m = self.lock();
        if m.entries.is_empty() {
            return false;
        }
        let idx = (nth as usize) % m.entries.len();
        let Some(key) = m.entries.keys().nth(idx).cloned() else {
            return false;
        };
        let Some(e) = m.entries.get_mut(&key) else {
            return false;
        };
        if e.data.is_empty() {
            return false;
        }
        let mut data = e.data.as_ref().clone();
        match &mut data {
            Slab::F32(words) => {
                let word = (nth as usize / 7) % words.len();
                let bit = (nth % 32) as u32;
                words[word] = f32::from_bits(words[word].to_bits() ^ (1u32 << bit));
            }
            Slab::I8 { codes, .. } => {
                let word = (nth as usize / 7) % codes.len();
                let bit = (nth % 8) as u32;
                codes[word] = (codes[word] as u8 ^ (1u8 << bit)) as i8;
            }
        }
        // Same length and precision ⇒ the resident gauge is unchanged; the
        // stale checksum is the point.
        e.data = Arc::new(data);
        true
    }

    /// Number of resident slabs.
    pub fn len(&self) -> usize {
        self.lock().entries.len()
    }

    /// `true` when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes of slab data currently resident (lock-free gauge read).
    pub fn resident_bytes(&self) -> usize {
        self.resident.load(Ordering::Relaxed)
    }

    /// High-water mark of [`resident_bytes`](Self::resident_bytes) — the
    /// figure the memory-wall claim is judged on.
    pub fn peak_resident_bytes(&self) -> usize {
        self.peak_resident.load(Ordering::Relaxed)
    }

    /// Drop every entry (counters and the peak gauge are preserved).
    pub fn clear(&self) {
        let mut m = self.lock();
        m.entries.clear();
        self.resident.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer_key(layer: usize) -> WeightsKey {
        WeightsKey::new("net", layer, (4, 8, 3), DesignPoint::new(8, 16, 4, 4), 0.5)
    }

    fn key(layer: usize, ct: u32) -> SlabKey {
        SlabKey {
            layer: layer_key(layer),
            col_tile: ct,
        }
    }

    fn slab(cache: &SlabCache, k: SlabKey, val: f32, len: usize) -> Arc<Slab> {
        let make = move || Ok(Slab::F32(vec![val; len]));
        cache.try_get_or_generate(k, make).unwrap()
    }

    #[test]
    fn generates_once_per_key_within_budget() {
        let cache = SlabCache::with_budget(1 << 10);
        let mut calls = 0;
        for _ in 0..3 {
            let v = cache
                .try_get_or_generate(key(0, 0), || {
                    calls += 1;
                    Ok(Slab::F32(vec![1.0, 2.0]))
                })
                .unwrap();
            assert_eq!(v.f32_data(), &[1.0, 2.0]);
        }
        assert_eq!(calls, 1);
        assert_eq!(cache.lookups(), 3);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 2);
        assert_eq!(cache.evictions(), 0);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.resident_bytes(), 8);
        assert_eq!(cache.peak_resident_bytes(), 8);
    }

    #[test]
    fn distinct_keys_are_distinct_entries() {
        let cache = SlabCache::new();
        slab(&cache, key(0, 0), 0.0, 1);
        slab(&cache, key(0, 1), 1.0, 1);
        slab(&cache, key(1, 0), 2.0, 1);
        let mut k = key(0, 0);
        k.layer.rho_bits = 0.25f64.to_bits();
        slab(&cache, k, 3.0, 1);
        // Same name/index/σ/ρ but different geometry ⇒ distinct entry.
        let mut k = key(0, 0);
        k.layer.shape = (8, 8, 3);
        slab(&cache, k, 4.0, 1);
        assert_eq!(cache.len(), 5);
        assert_eq!(cache.misses(), 5);
        assert_eq!(cache.hits(), 0);
    }

    #[test]
    fn lru_eviction_keeps_resident_under_budget() {
        // Budget of 3 slabs of 100 floats each.
        let cache = SlabCache::with_budget(3 * 400);
        for ct in 0..5 {
            slab(&cache, key(0, ct), ct as f32, 100);
            assert!(cache.resident_bytes() <= cache.budget());
        }
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.evictions(), 2);
        assert!(cache.peak_resident_bytes() <= cache.budget());
        // Oldest slabs (0, 1) are gone; 2..5 survive — re-fetching 4 hits,
        // re-fetching 0 regenerates.
        slab(&cache, key(0, 4), 4.0, 100);
        assert_eq!(cache.hits(), 1);
        let misses_before = cache.misses();
        slab(&cache, key(0, 0), 0.0, 100);
        assert_eq!(cache.misses(), misses_before + 1);
    }

    #[test]
    fn recently_used_slab_survives_eviction() {
        let cache = SlabCache::with_budget(2 * 400);
        slab(&cache, key(0, 0), 0.0, 100);
        slab(&cache, key(0, 1), 1.0, 100);
        // Touch slab 0 so slab 1 is now the LRU victim.
        slab(&cache, key(0, 0), 0.0, 100);
        slab(&cache, key(0, 2), 2.0, 100);
        assert_eq!(cache.evictions(), 1);
        let misses = cache.misses();
        slab(&cache, key(0, 0), 0.0, 100);
        assert_eq!(cache.misses(), misses, "MRU slab must have survived");
    }

    #[test]
    fn oversized_slab_is_admitted_alone() {
        let cache = SlabCache::with_budget(100);
        slab(&cache, key(0, 0), 0.0, 10);
        slab(&cache, key(0, 1), 1.0, 1000); // 4000 B > budget
        assert_eq!(cache.len(), 1, "everything else evicted");
        assert_eq!(cache.resident_bytes(), 4000);
    }

    #[test]
    fn evict_layer_drops_only_that_layer() {
        let cache = SlabCache::new();
        for ct in 0..3 {
            slab(&cache, key(0, ct), 0.0, 10);
            slab(&cache, key(1, ct), 1.0, 10);
        }
        assert_eq!(cache.evict_layer(&layer_key(0)), 3);
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.resident_bytes(), 3 * 40);
        assert_eq!(cache.evict_layer(&layer_key(0)), 0);
    }

    #[test]
    fn generation_errors_propagate_and_cache_nothing() {
        let cache = SlabCache::new();
        let err = cache.try_get_or_generate(key(0, 0), || {
            Err(crate::error::Error::ShapeMismatch("boom".into()))
        });
        assert!(err.is_err());
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.misses(), 1, "the failed generation was attempted");
        // The key is not poisoned: a later generation succeeds.
        assert_eq!(slab(&cache, key(0, 0), 7.0, 2).f32_data(), &[7.0, 7.0]);
    }

    #[test]
    fn concurrent_hammer_reconciles_counters() {
        // 8 threads × 200 lookups over 16 keys under a budget of 5 slabs:
        // eviction churns constantly, yet the lock-free counters must
        // reconcile exactly and the byte gauges must respect the budget.
        let cache = Arc::new(SlabCache::with_budget(5 * 400));
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let c = Arc::clone(&cache);
            handles.push(std::thread::spawn(move || {
                let mut state = t.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
                for _ in 0..200 {
                    state ^= state << 13;
                    state ^= state >> 7;
                    state ^= state << 17;
                    let ct = (state % 16) as u32;
                    let v = c
                        .try_get_or_generate(key(0, ct), || Ok(Slab::F32(vec![ct as f32; 100])))
                        .unwrap();
                    assert_eq!(v.f32_data()[0], ct as f32, "wrong slab adopted for key {ct}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cache.lookups(), 8 * 200);
        assert_eq!(
            cache.hits() + cache.misses(),
            cache.lookups(),
            "counters must reconcile after concurrent churn"
        );
        assert!(cache.evictions() > 0, "the 5-slab budget must have evicted");
        assert!(cache.len() <= 5);
        assert_eq!(cache.resident_bytes(), cache.len() * 400);
        assert!(cache.resident_bytes() <= cache.budget());
        assert!(cache.peak_resident_bytes() <= cache.budget());
    }

    #[test]
    fn bit_flip_is_detected_evicted_and_regenerated() {
        let cache = SlabCache::new();
        slab(&cache, key(0, 0), 3.0, 8);
        assert!(cache.flip_bit(12345), "a resident slab must be flippable");
        let mut calls = 0;
        let v = cache
            .try_get_or_generate(key(0, 0), || {
                calls += 1;
                Ok(Slab::F32(vec![3.0; 8]))
            })
            .unwrap();
        assert_eq!(calls, 1, "corrupted slab must regenerate, not hit");
        assert_eq!(v.f32_data(), &[3.0; 8], "regenerated numerics are clean");
        assert_eq!(cache.corruptions(), 1);
        assert_eq!(cache.evictions(), 1, "the corrupted slab was evicted");
        assert_eq!(cache.hits(), 0);
        assert_eq!(cache.misses(), 2);
        assert_eq!(
            cache.hits() + cache.misses(),
            cache.lookups(),
            "counters still reconcile through a corruption"
        );
        // The regenerated slab now hits cleanly.
        slab(&cache, key(0, 0), 3.0, 8);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.corruptions(), 1);
    }

    #[test]
    fn flip_bit_on_empty_cache_is_a_noop() {
        let cache = SlabCache::new();
        assert!(!cache.flip_bit(0));
        assert_eq!(cache.corruptions(), 0);
    }

    #[test]
    fn generations_are_distinct_cache_entries() {
        // The evict-vs-in-flight reinsertion race: a straggler batch for an
        // evicted registration re-inserts under the OLD generation and must
        // never be served to the NEW registration of the same model id.
        let cache = SlabCache::new();
        let old = SlabKey {
            layer: layer_key(0).with_generation(1),
            col_tile: 0,
        };
        let new = SlabKey {
            layer: layer_key(0).with_generation(2),
            col_tile: 0,
        };
        slab(&cache, old.clone(), 1.0, 4); // straggler reinsertion
        let v = slab(&cache, new, 2.0, 4); // fresh registration's lookup
        assert_eq!(v.f32_data(), &[2.0; 4], "new generation must regenerate");
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.hits(), 0);
        // Evicting the old generation leaves the new one resident.
        assert_eq!(cache.evict_layer(&layer_key(0).with_generation(1)), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn retired_generation_insert_is_refused_at_insert_time() {
        // The full evict-vs-in-flight race, closed at insert time: retire
        // the straggler's generation (as ModelRegistry::evict does) and a
        // subsequent old-generation insert must NOT land in the cache —
        // not even transiently, waiting for LRU pressure to age it out.
        let cache = SlabCache::new();
        let old = SlabKey {
            layer: layer_key(0).with_generation(1),
            col_tile: 0,
        };
        cache.retire_generation("net", 1);
        // The straggler still gets its generated slab back (its batch
        // completes with correct numerics)...
        let v = slab(&cache, old.clone(), 1.0, 4);
        assert_eq!(v.f32_data(), &[1.0; 4]);
        // ...but the cache refused to keep it.
        assert_eq!(cache.len(), 0, "retired generation must not be cached");
        assert_eq!(cache.retired_inserts(), 1);
        assert_eq!(cache.resident_bytes(), 0);
        // Every repeat attempt regenerates and is refused again.
        slab(&cache, old, 1.0, 4);
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.retired_inserts(), 2);
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.hits(), 0);
        // A NEWER generation of the same model inserts normally.
        let fresh = SlabKey {
            layer: layer_key(0).with_generation(2),
            col_tile: 0,
        };
        slab(&cache, fresh, 2.0, 4);
        assert_eq!(cache.len(), 1, "newer generation is admitted");
        // Watermarks only move forward: retiring an older generation after
        // a newer one is a no-op for the newer one.
        cache.retire_generation("net", 1);
        cache.retire_generation("net", 2);
        assert_eq!(cache.evict_layer(&layer_key(0).with_generation(2)), 1);
        let fresh2 = SlabKey {
            layer: layer_key(0).with_generation(2),
            col_tile: 1,
        };
        slab(&cache, fresh2, 3.0, 4);
        assert_eq!(cache.len(), 0, "gen 2 is now retired too");
        assert_eq!(cache.retired_inserts(), 3);
    }

    #[test]
    fn generation_zero_is_never_retired() {
        // Engines without a registry artifact key slabs at generation 0;
        // retirement must never touch them.
        let cache = SlabCache::new();
        cache.retire_generation("net", 0); // no-op by contract
        cache.retire_generation("net", 5);
        slab(&cache, key(0, 0), 4.0, 4); // layer_key() is generation 0
        assert_eq!(cache.len(), 1, "generation-0 slabs are always admitted");
        assert_eq!(cache.retired_inserts(), 0);
    }

    #[test]
    fn shared_across_threads_generates_coherently() {
        let cache = Arc::new(SlabCache::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = Arc::clone(&cache);
            handles.push(std::thread::spawn(move || {
                let v = c.try_get_or_generate(key(7, 0), || Ok(Slab::F32(vec![7.0])));
                v.unwrap().len()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 1);
        }
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.hits() + cache.misses(), 4);
        assert!(cache.misses() >= 1);
    }

    fn i8_slab(cache: &SlabCache, k: SlabKey, code: i8, len: usize) -> Arc<Slab> {
        let make = move || {
            Ok(Slab::I8 {
                codes: vec![code; len],
                scale: 0.25,
            })
        };
        cache.try_get_or_generate(k, make).unwrap()
    }

    #[test]
    fn i8_slab_charges_quarter_bytes_so_four_times_fit() {
        // Budget of exactly one 100-float f32 slab. At i8 the same element
        // count costs ¼, so four i8 slabs are resident where one f32 was.
        let cache = SlabCache::with_budget(400);
        for ct in 0..4 {
            let k = SlabKey {
                layer: layer_key(0).with_precision(Precision::I8),
                col_tile: ct,
            };
            let v = i8_slab(&cache, k, ct as i8, 100);
            assert_eq!(v.bytes(), 100);
            assert_eq!(v.precision(), Precision::I8);
        }
        assert_eq!(cache.len(), 4, "4 i8 slabs fit one f32 slab's budget");
        assert_eq!(cache.resident_bytes(), 400);
        assert_eq!(cache.evictions(), 0);
        // The f32 twin of one more slab evicts everything but itself.
        slab(&cache, key(0, 9), 1.0, 100);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.resident_bytes(), 400);
    }

    #[test]
    fn mixed_precision_keys_never_alias() {
        // The SAME (model, layer, σ, ρ, generation, col_tile) at two
        // precisions must be two distinct entries, each serving its own
        // payload kind.
        let cache = SlabCache::new();
        let f32_key = key(0, 0);
        let i8_key = SlabKey {
            layer: layer_key(0).with_precision(Precision::I8),
            col_tile: 0,
        };
        let vf = slab(&cache, f32_key.clone(), 5.0, 8);
        let vq = i8_slab(&cache, i8_key.clone(), 20, 8);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.misses(), 2);
        assert_eq!(vf.as_f32().unwrap(), &[5.0; 8]);
        let (codes, scale) = vq.as_i8().unwrap();
        assert_eq!(codes, &[20i8; 8]);
        assert_eq!(scale, 0.25);
        // Re-fetching each precision hits its own entry.
        slab(&cache, f32_key, 5.0, 8);
        i8_slab(&cache, i8_key, 20, 8);
        assert_eq!(cache.hits(), 2);
        assert_eq!(cache.resident_bytes(), 8 * 4 + 8);
    }

    #[test]
    fn i8_bit_flip_is_detected_and_regenerated() {
        let cache = SlabCache::new();
        let k = SlabKey {
            layer: layer_key(3).with_precision(Precision::I8),
            col_tile: 0,
        };
        i8_slab(&cache, k.clone(), 7, 16);
        assert!(cache.flip_bit(999));
        let v = i8_slab(&cache, k, 7, 16);
        assert_eq!(cache.corruptions(), 1, "i8 checksum must catch the flip");
        assert_eq!(v.as_i8().unwrap().0, &[7i8; 16], "regenerated clean");
        assert_eq!(cache.misses(), 2);
    }
}
