//! [`AnalyticalBackend`] — executes the plan on the closed-form performance
//! model (Eqs. 5–8). Timing only; no numerics.

use crate::engine::backend::{
    EnginePlan, ExecutionBackend, ExecutionReport, LayerCost, LayerOutcome, OverlapTelemetry,
};
use crate::error::{Error, Result};
use crate::perf::model::{NetworkPerf, PerfModel};

/// Backend over [`PerfModel`]: per-layer costs are the analytical model's
/// closed forms, evaluated once at [`plan`](ExecutionBackend::plan) time.
#[derive(Default)]
pub struct AnalyticalBackend {
    state: Option<State>,
    executed: Vec<LayerCost>,
}

struct State {
    perf: NetworkPerf,
    clock_hz: f64,
}

impl AnalyticalBackend {
    /// New, unplanned backend.
    pub fn new() -> Self {
        Self::default()
    }

    fn state(&self) -> Result<&State> {
        self.state
            .as_ref()
            .ok_or_else(|| Error::InvalidConfig("backend used before plan()".into()))
    }
}

impl ExecutionBackend for AnalyticalBackend {
    fn name(&self) -> &'static str {
        "analytical"
    }

    fn plan(&mut self, plan: &EnginePlan) -> Result<()> {
        let model = PerfModel::new(plan.platform.clone(), plan.bw_mult);
        let perf = model.network_perf(&plan.sigma, &plan.network, &plan.profile);
        self.state = Some(State {
            perf,
            clock_hz: plan.platform.clock_hz,
        });
        self.executed.clear();
        Ok(())
    }

    fn execute_layer(&mut self, idx: usize, _input: &[f32]) -> Result<LayerOutcome> {
        let (name, cycles, bound) = {
            let st = self.state()?;
            let lp = st.perf.layers.get(idx).ok_or_else(|| {
                Error::InvalidConfig(format!(
                    "layer index {idx} out of range ({} layers)",
                    st.perf.layers.len()
                ))
            })?;
            (lp.name.clone(), lp.total_cycles, lp.bound)
        };
        self.executed.push(LayerCost {
            name: name.clone(),
            cycles,
            bound,
            overlap: OverlapTelemetry::default(),
        });
        Ok(LayerOutcome {
            name,
            cycles,
            bound,
            output: None,
            overlap: OverlapTelemetry::default(),
        })
    }

    fn finish(&mut self) -> Result<ExecutionReport> {
        let clock_hz = self.state()?.clock_hz;
        let layers = std::mem::take(&mut self.executed);
        let total_cycles: f64 = layers.iter().map(|l| l.cycles).sum();
        Ok(ExecutionReport {
            backend: self.name(),
            layers,
            total_cycles,
            latency_s: total_cycles / clock_hz,
        })
    }
}
