//! [`FaultyBackend`] — deterministic, seeded fault injection over any
//! [`ExecutionBackend`].
//!
//! The serving stack's fault-tolerance claims (worker supervision, typed
//! retryable errors, circuit breakers, slab-integrity checksums) are only
//! worth anything if every failure mode is *reproducible* under test. This
//! wrapper applies a [`FaultPlan`] — per-call probabilities of typed
//! transient errors, permanent errors, latency spikes, worker panics and
//! slab bit-flips — drawn from a seeded [`Xoshiro256`], so a chaos soak
//! replays the exact same fault schedule on every run of the same seed.
//!
//! Faults are injected **before** delegating to the wrapped backend, so a
//! call that is not selected for injection executes exactly the code the
//! production path runs — successful responses stay bit-identical to a
//! fault-free run. Injected slab bit-flips corrupt the *cache* (via
//! [`SlabCache::flip_bit`]), not the in-flight computation: the integrity
//! checksum must catch them on the next hit, which is precisely the
//! property under test.
//!
//! A zero-probability plan (the default) makes the wrapper a transparent
//! pass-through — the configuration the hotpath bench uses to measure the
//! fault-tolerance layer's overhead.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::engine::backend::{EnginePlan, ExecutionBackend, ExecutionReport, LayerOutcome};
use crate::engine::compile::CompiledModel;
use crate::engine::wcache::SlabCache;
use crate::error::{Error, Result};
use crate::util::prng::Xoshiro256;

/// Seeded per-call fault probabilities. Each backend call rolls each class
/// independently, in a fixed order (panic, latency spike, bit-flip,
/// transient, permanent), so the schedule is a pure function of the seed
/// and the call sequence.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// PRNG seed — same seed, same call sequence ⇒ same fault schedule.
    pub seed: u64,
    /// Probability of a typed [`Error::Transient`] (retryable) per call.
    pub transient: f64,
    /// Probability of a permanent (non-retryable) error per call.
    pub permanent: f64,
    /// Probability of a worker panic per call.
    pub panic_p: f64,
    /// Probability of a latency spike (sleep of [`spike`](Self::spike)).
    pub latency_spike: f64,
    /// Duration of one injected latency spike.
    pub spike: Duration,
    /// Probability of flipping one bit of one resident cached slab.
    pub bitflip: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self::none()
    }
}

impl FaultPlan {
    /// A plan that injects nothing — the transparent pass-through used to
    /// measure the wrapper's fault-free overhead.
    pub fn none() -> Self {
        Self {
            seed: 0,
            transient: 0.0,
            permanent: 0.0,
            panic_p: 0.0,
            latency_spike: 0.0,
            spike: Duration::from_millis(1),
            bitflip: 0.0,
        }
    }

    /// The same plan re-seeded for one worker, so a pool of workers sharing
    /// one logical plan still draw independent (but reproducible) fault
    /// schedules.
    #[must_use]
    pub fn for_worker(mut self, worker: usize) -> Self {
        self.seed ^= (worker as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        self
    }

    /// The same plan re-seeded for one replica (a different mixing constant
    /// than [`for_worker`](Self::for_worker), so replica 1's worker 0 and
    /// replica 0's worker 1 draw decorrelated schedules even though both
    /// mixes start from the same base seed).
    #[must_use]
    pub fn for_replica(mut self, replica: usize) -> Self {
        self.seed ^= (replica as u64 + 1).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        self
    }

    fn validate(&self) {
        debug_assert!(
            [
                self.transient,
                self.permanent,
                self.panic_p,
                self.latency_spike,
                self.bitflip
            ]
            .iter()
            .all(|p| (0.0..=1.0).contains(p)),
            "fault probabilities must lie in [0, 1]"
        );
    }
}

/// Lock-free injection counters, shared across backend instances through an
/// `Arc` so a respawned worker's replacement backend keeps accumulating
/// into the same tallies (a panicking worker must not lose its stats).
#[derive(Debug, Default)]
pub struct FaultStats {
    transients: AtomicU64,
    permanents: AtomicU64,
    panics: AtomicU64,
    spikes: AtomicU64,
    bitflips: AtomicU64,
}

impl FaultStats {
    /// Injected transient errors.
    pub fn transients(&self) -> u64 {
        self.transients.load(Ordering::Relaxed)
    }

    /// Injected permanent errors.
    pub fn permanents(&self) -> u64 {
        self.permanents.load(Ordering::Relaxed)
    }

    /// Injected worker panics.
    pub fn panics(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }

    /// Injected latency spikes.
    pub fn spikes(&self) -> u64 {
        self.spikes.load(Ordering::Relaxed)
    }

    /// Injected slab bit-flips (attempted; a flip on an empty cache is
    /// still counted as an attempt by the caller rolling it, but only
    /// successful flips count here).
    pub fn bitflips(&self) -> u64 {
        self.bitflips.load(Ordering::Relaxed)
    }

    /// Total injected faults of every class.
    pub fn total(&self) -> u64 {
        self.transients()
            + self.permanents()
            + self.panics()
            + self.spikes()
            + self.bitflips()
    }
}

/// Fault-injecting wrapper over any [`ExecutionBackend`]. Construct with
/// [`new`](Self::new) (or [`with_cache`](Self::with_cache) to enable slab
/// bit-flip injection) and hand to
/// [`Engine::with_backend`](crate::engine::Engine::with_backend) — every
/// engine/pool path then runs through the fault schedule.
pub struct FaultyBackend<B: ExecutionBackend> {
    inner: B,
    plan: FaultPlan,
    rng: Xoshiro256,
    stats: Arc<FaultStats>,
    /// Cache to corrupt on bit-flip injection (usually the same shared
    /// cache the wrapped simulator generates through). `None` disables the
    /// bit-flip class.
    cache: Option<Arc<SlabCache>>,
}

impl<B: ExecutionBackend> FaultyBackend<B> {
    /// Wrap `inner` under `plan` (bit-flip injection disabled — no cache).
    pub fn new(inner: B, plan: FaultPlan) -> Self {
        plan.validate();
        let rng = Xoshiro256::seed_from_u64(plan.seed);
        Self {
            inner,
            plan,
            rng,
            stats: Arc::new(FaultStats::default()),
            cache: None,
        }
    }

    /// Wrap `inner` under `plan`, flipping bits in `cache` when the
    /// bit-flip class fires.
    pub fn with_cache(inner: B, plan: FaultPlan, cache: Arc<SlabCache>) -> Self {
        let mut b = Self::new(inner, plan);
        b.cache = Some(cache);
        b
    }

    /// Accumulate injections into an existing stats block (e.g. one shared
    /// across every worker of a pool, surviving worker respawns).
    #[must_use]
    pub fn sharing_stats(mut self, stats: Arc<FaultStats>) -> Self {
        self.stats = stats;
        self
    }

    /// The injection counters (clone the `Arc` to read after the backend
    /// moved into an engine).
    pub fn stats(&self) -> Arc<FaultStats> {
        Arc::clone(&self.stats)
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &B {
        &self.inner
    }

    /// Roll the fault schedule for one backend call. Non-fatal classes
    /// (spike, bit-flip) apply their side effect and fall through; fatal
    /// classes return/panic. The roll order is fixed so the schedule is
    /// seed-deterministic.
    fn inject(&mut self) -> Result<()> {
        let p = self.plan.clone();
        if p.panic_p > 0.0 && self.rng.next_f64() < p.panic_p {
            self.stats.panics.fetch_add(1, Ordering::Relaxed);
            panic!("injected worker panic (chaos)");
        }
        if p.latency_spike > 0.0 && self.rng.next_f64() < p.latency_spike {
            self.stats.spikes.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(p.spike);
        }
        if p.bitflip > 0.0 && self.rng.next_f64() < p.bitflip {
            if let Some(cache) = &self.cache {
                if cache.flip_bit(self.rng.next_u64()) {
                    self.stats.bitflips.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        if p.transient > 0.0 && self.rng.next_f64() < p.transient {
            self.stats.transients.fetch_add(1, Ordering::Relaxed);
            return Err(Error::Transient("injected backend hiccup (chaos)".into()));
        }
        if p.permanent > 0.0 && self.rng.next_f64() < p.permanent {
            self.stats.permanents.fetch_add(1, Ordering::Relaxed);
            return Err(Error::Coordinator(
                "injected permanent fault (chaos)".into(),
            ));
        }
        Ok(())
    }
}

impl<B: ExecutionBackend> ExecutionBackend for FaultyBackend<B> {
    fn name(&self) -> &'static str {
        "faulty"
    }

    fn plan(&mut self, plan: &EnginePlan) -> Result<()> {
        self.inner.plan(plan)
    }

    fn preload(&mut self, model: &Arc<CompiledModel>) -> Result<()> {
        self.inner.preload(model)
    }

    fn execute_layer(&mut self, idx: usize, input: &[f32]) -> Result<LayerOutcome> {
        self.inject()?;
        self.inner.execute_layer(idx, input)
    }

    fn execute_layer_batch(&mut self, idx: usize, inputs: &[&[f32]]) -> Result<Vec<LayerOutcome>> {
        self.inject()?;
        self.inner.execute_layer_batch(idx, inputs)
    }

    fn finish(&mut self) -> Result<ExecutionReport> {
        self.inner.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{DesignPoint, Platform};
    use crate::engine::{Engine, SimBackend};
    use crate::util::prng::Xoshiro256;
    use crate::workload::{Layer, Network, RatioProfile};

    fn tiny_plan() -> EnginePlan {
        let net = Network {
            name: "tiny".into(),
            layers: vec![
                Layer::conv("stem", 8, 8, 4, 8, 3, 1, 1, false),
                Layer::conv("b.conv1", 8, 8, 8, 8, 3, 1, 1, true),
                Layer::fc("fc", 8, 5),
            ],
        };
        let profile = RatioProfile::uniform(&net, 0.5);
        Engine::builder()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(DesignPoint::new(8, 4, 8, 4))
            .network(net)
            .profile(profile)
            .plan()
            .unwrap()
    }

    fn tiny_input() -> Vec<f32> {
        Xoshiro256::seed_from_u64(99).normal_vec(8 * 8 * 4)
    }

    #[test]
    fn zero_probability_plan_is_a_transparent_passthrough() {
        let plan = tiny_plan();
        let input = tiny_input();
        let mut bare = Engine::with_backend(plan.clone(), Box::new(SimBackend::new())).unwrap();
        let expect = bare.infer(&input).unwrap().output;
        let faulty = FaultyBackend::new(SimBackend::new(), FaultPlan::none());
        let stats = faulty.stats();
        let mut guarded = Engine::with_backend(plan, Box::new(faulty)).unwrap();
        let got = guarded.infer(&input).unwrap().output;
        assert_eq!(got, expect, "pass-through must not change a single bit");
        assert_eq!(stats.total(), 0, "nothing may be injected at p = 0");
    }

    #[test]
    fn transient_injection_is_typed_and_seed_deterministic() {
        let run = |seed: u64| -> (Vec<bool>, u64) {
            let cfg = FaultPlan {
                seed,
                transient: 0.5,
                ..FaultPlan::none()
            };
            let mut backend = FaultyBackend::new(SimBackend::new(), cfg);
            backend.plan(&tiny_plan()).unwrap();
            let stats = backend.stats();
            let mut outcomes = Vec::new();
            for _ in 0..32 {
                match backend.execute_layer(0, &[]) {
                    Ok(_) => outcomes.push(true),
                    Err(e) => {
                        assert!(
                            matches!(e, Error::Transient(_)),
                            "injection must be typed: {e}"
                        );
                        assert!(e.is_transient());
                        outcomes.push(false);
                    }
                }
            }
            (outcomes, stats.transients())
        };
        let (a, n_a) = run(7);
        let (b, n_b) = run(7);
        assert_eq!(a, b, "same seed must replay the same fault schedule");
        assert_eq!(n_a, n_b);
        assert!(n_a > 0, "p = 0.5 over 32 calls must fire");
        assert!(a.iter().any(|ok| *ok), "and must not fire every time");
        let (c, _) = run(8);
        assert_ne!(a, c, "different seeds draw different schedules");
    }

    #[test]
    fn bitflip_injection_corrupts_the_cache_and_checksums_catch_it() {
        let cache = Arc::new(SlabCache::new());
        let plan = tiny_plan();
        let input = tiny_input();
        // Reference numerics, fault-free.
        let mut bare = Engine::with_backend(
            plan.clone(),
            Box::new(SimBackend::with_cache(Arc::new(SlabCache::new()))),
        )
        .unwrap();
        let expect = bare.infer(&input).unwrap().output;
        // Flip a cached bit on every call: the checksum path must evict and
        // regenerate, keeping the numerics bit-identical.
        let cfg = FaultPlan {
            seed: 3,
            bitflip: 1.0,
            ..FaultPlan::none()
        };
        let faulty = FaultyBackend::with_cache(
            SimBackend::with_cache(Arc::clone(&cache)),
            cfg,
            Arc::clone(&cache),
        );
        let stats = faulty.stats();
        let mut guarded = Engine::with_backend(plan, Box::new(faulty)).unwrap();
        let first = guarded.infer(&input).unwrap().output;
        let second = guarded.infer(&input).unwrap().output;
        assert_eq!(first, expect, "corruption must never reach the output");
        assert_eq!(second, expect, "corruption must never reach the output");
        assert!(stats.bitflips() > 0, "flips must have been injected");
        assert!(
            cache.corruptions() > 0,
            "checksums must have caught at least one flip"
        );
    }

    #[test]
    fn panic_injection_panics() {
        let cfg = FaultPlan {
            seed: 1,
            panic_p: 1.0,
            ..FaultPlan::none()
        };
        let mut backend = FaultyBackend::new(SimBackend::new(), cfg);
        backend.plan(&tiny_plan()).unwrap();
        let stats = backend.stats();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = backend.execute_layer(0, &[]);
        }));
        assert!(r.is_err(), "p = 1 must panic");
        assert_eq!(stats.panics(), 1);
    }
}
