//! The compile-once / serve-many split: [`Compiler`] turns a
//! (network, profile) pair into an immutable [`CompiledModel`] artifact,
//! and serving layers ([`ModelRegistry`](crate::coordinator::registry::ModelRegistry),
//! [`ServerPool::serve`](crate::coordinator::pool::ServerPool::serve))
//! route requests onto those artifacts without re-validating or re-fitting
//! anything per request.
//!
//! A `CompiledModel` is everything that used to be scattered across
//! `EngineBuilder::plan`, the scheduler and the simulator backend's lazy
//! per-layer weight synthesis:
//!
//! * the validated [`EnginePlan`] (platform + bandwidth operating point,
//!   design point σ, workload, ρ profile, admission-time schedule);
//! * the model's [`WeightsKey`] namespace — one key per OVSF layer, the
//!   identity its generated weight slabs live under in the shared
//!   [`SlabCache`](crate::engine::wcache::SlabCache);
//! * the per-layer synthetic-checkpoint seeds and the **per-artifact
//!   compressed OVSF α sets** (the resident model state the slab generator
//!   reads; fitted once, lazily on first numeric use), so model switches
//!   on a serving worker adopt the artifact's α's instead of re-fitting
//!   them — and timing-only pools never pay the fit;
//! * the expected input/output activation lengths, checked at admission so
//!   a malformed request fails fast at `submit` with a typed error.
//!
//! The `Compiler` pins the design point after its first compile: every
//! model compiled through one `Compiler` shares one σ — the single
//! computation engine the paper serves all CNNs from, with only the
//! per-model α state differing (unzipFPGA §1: resources reused across
//! layers *and* CNN models without reconfiguring the fabric).

use std::ops::Range;
use std::sync::{Arc, Mutex, OnceLock, PoisonError};

use crate::arch::{DesignPoint, Platform};
use crate::engine::backend::EnginePlan;
use crate::engine::sim::{layer_seed, synth_hw_weights};
use crate::engine::wcache::WeightsKey;
use crate::engine::Engine;
use crate::error::{Error, Result};
use crate::sim::hw_weights::HwOvsfWeights;
use crate::util::fixed::Precision;
use crate::workload::{Network, RatioProfile};

/// An immutable, shareable model artifact: the output of
/// [`Compiler::compile`], the unit a
/// [`ModelRegistry`](crate::coordinator::registry::ModelRegistry) holds.
pub struct CompiledModel {
    plan: EnginePlan,
    input_len: usize,
    output_len: usize,
    alpha_words: u64,
    weights_keys: Vec<WeightsKey>,
    weight_seeds: Vec<u64>,
    /// Registration generation stamped into every weights key (0 until the
    /// artifact is registered — see
    /// [`ModelRegistry::register`](crate::coordinator::registry::ModelRegistry::register)).
    generation: u64,
    /// Numeric precision of the weight datapath this artifact serves at.
    precision: Precision,
    /// Network name the per-layer weight *seeds* derive from. Equal to the
    /// plan's network name for whole-model artifacts; for layer-range
    /// stages produced by [`Compiler::split`] it stays the **original**
    /// model's name so every stage synthesises the very same weights the
    /// unsplit artifact would — while runtime [`WeightsKey`]s keep the
    /// stage's own (disjoint) network name.
    seed_name: String,
    /// Absolute layer index of this artifact's first layer within the
    /// original network (0 for whole-model artifacts). Seeds are pure
    /// functions of `(seed_name, layer_offset + local_idx, layer)`.
    layer_offset: usize,
    /// Fitted once per artifact, on first use by a numeric backend —
    /// timing-only (analytical) pools never pay the fit.
    hw: OnceLock<Vec<Option<Arc<HwOvsfWeights>>>>,
    /// Per-layer α-derived int8 weight scales (`None` for dense layers),
    /// derived from [`hw`](Self::hw) on first use for `I8` artifacts.
    i8_scales: OnceLock<Vec<Option<f32>>>,
}

impl std::fmt::Debug for CompiledModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompiledModel")
            .field("network", &self.plan.network.name)
            .field("sigma", &self.plan.sigma)
            .field("input_len", &self.input_len)
            .field("output_len", &self.output_len)
            .field("alpha_words", &self.alpha_words)
            .field("ovsf_layers", &self.weights_keys.len())
            .field("precision", &self.precision)
            .finish()
    }
}

impl CompiledModel {
    /// Compile an already-validated plan into an artifact: derive the
    /// weights-key namespace, the per-layer synthetic-checkpoint seeds and
    /// the α-volume accounting. The compressed OVSF α sets themselves are
    /// fitted once per artifact, lazily on first use by a numeric backend
    /// (see [`hw`](Self::hw)). Compiles at the reference `F32` precision;
    /// use [`from_plan_at`](Self::from_plan_at) (or
    /// [`Compiler::precision`]) for the int8 datapath.
    pub fn from_plan(plan: EnginePlan) -> Result<Self> {
        Self::from_plan_at(plan, Precision::F32)
    }

    /// Compile an already-validated plan at an explicit weight-datapath
    /// precision. The precision is stamped into every [`WeightsKey`] so an
    /// f32 and an i8 artifact of the same network can never alias each
    /// other's slabs in a shared cache.
    pub fn from_plan_at(plan: EnginePlan, precision: Precision) -> Result<Self> {
        let seed_name = plan.network.name.clone();
        Self::from_plan_seeded(plan, precision, seed_name, 0)
    }

    /// Compile a plan whose weight identity lives in another model's seed
    /// namespace: seeds derive from `(seed_name, layer_offset + idx)`
    /// instead of the plan's own network name. This is how
    /// [`Compiler::split`] gives each layer-range stage the *original*
    /// model's weights (bit-identical numerics) while runtime slab keys
    /// stay under the stage's own disjoint network name.
    pub(crate) fn from_plan_seeded(
        plan: EnginePlan,
        precision: Precision,
        seed_name: String,
        layer_offset: usize,
    ) -> Result<Self> {
        let n = plan.n_layers();
        let mut weights_keys = Vec::new();
        let mut weight_seeds = Vec::with_capacity(n);
        let mut alpha_words = 0u64;
        for (idx, layer) in plan.network.layers.iter().enumerate() {
            weight_seeds.push(layer_seed(&seed_name, layer_offset + idx, layer));
            if layer.ovsf {
                let rho = plan.profile.rho(idx);
                alpha_words += layer.n_in * layer.n_out * layer.basis_per_chunk(rho);
                weights_keys.push(
                    WeightsKey::new(
                        plan.network.name.clone(),
                        idx,
                        (layer.n_in, layer.n_out, layer.k),
                        plan.sigma,
                        rho,
                    )
                    .with_precision(precision),
                );
            }
        }
        let input_len = plan
            .network
            .layers
            .first()
            .map(|l| (l.h * l.w * l.n_in) as usize)
            .unwrap_or(0);
        let output_len = plan
            .network
            .layers
            .last()
            .map(|l| {
                let g = l.gemm();
                (g.r * g.c) as usize
            })
            .unwrap_or(0);
        Ok(Self {
            plan,
            input_len,
            output_len,
            alpha_words,
            weights_keys,
            weight_seeds,
            generation: 0,
            precision,
            seed_name,
            layer_offset,
            hw: OnceLock::new(),
            i8_scales: OnceLock::new(),
        })
    }

    /// The registration generation this artifact's slab identities live
    /// under (0 for unregistered artifacts).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Re-derive a fresh artifact from this one's plan and precision —
    /// generation 0, lazy α state unfit. Compilation is deterministic (the
    /// plan embeds σ and the profile; seeds are pure functions of the
    /// network), so the respin serves **bit-identical numerics**: this is
    /// how a replica supervisor rebuilds a dead replica's models from the
    /// survivors' catalog entries. Registering the respin stamps it a new
    /// generation, so it can never adopt the dead incarnation's slabs.
    pub fn respin(&self) -> Result<Self> {
        // Preserve the seed namespace: a respun stage artifact must keep
        // synthesising the original model's weights at its layer offset.
        Self::from_plan_seeded(
            self.plan.clone(),
            self.precision,
            self.seed_name.clone(),
            self.layer_offset,
        )
    }

    /// Stamp a registration generation into the artifact and every
    /// [`WeightsKey`] it owns. Called by
    /// [`ModelRegistry::register`](crate::coordinator::registry::ModelRegistry::register)
    /// before the artifact is shared, so slabs generated for an earlier
    /// (evicted) registration of the same model id can never be adopted by
    /// this one.
    pub(crate) fn assign_generation(&mut self, generation: u64) {
        self.generation = generation;
        for k in &mut self.weights_keys {
            k.generation = generation;
        }
    }

    /// The validated plan this artifact executes.
    pub fn plan(&self) -> &EnginePlan {
        &self.plan
    }

    /// The compiled network's name (the conventional registry id).
    pub fn network_name(&self) -> &str {
        &self.plan.network.name
    }

    /// Design point σ the model was compiled for.
    pub fn sigma(&self) -> DesignPoint {
        self.plan.sigma
    }

    /// Expected request input length: the first layer's `h·w·c_in` NHWC
    /// activations. Admission control rejects other non-empty lengths.
    pub fn input_len(&self) -> usize {
        self.input_len
    }

    /// Output activation length a numeric request returns (the last
    /// layer's `R·C`).
    pub fn output_len(&self) -> usize {
        self.output_len
    }

    /// α words that must be resident for this model — the state (and the
    /// only weight traffic) a model switch moves.
    pub fn alpha_words(&self) -> u64 {
        self.alpha_words
    }

    /// The model's generated-weights namespace: one [`WeightsKey`] per
    /// OVSF layer. Evicting the model drops these from the shared cache.
    pub fn weights_keys(&self) -> &[WeightsKey] {
        &self.weights_keys
    }

    /// Deterministic per-layer synthetic-checkpoint seeds (the repro's
    /// stand-in for trained weights identity).
    pub fn weight_seeds(&self) -> &[u64] {
        &self.weight_seeds
    }

    /// Network name the weight seeds derive from — the original model for
    /// [`Compiler::split`] stages, the plan's own name otherwise.
    pub fn seed_name(&self) -> &str {
        &self.seed_name
    }

    /// Absolute index of this artifact's first layer within the original
    /// network (0 for whole-model artifacts).
    pub fn layer_offset(&self) -> usize {
        self.layer_offset
    }

    /// The artifact's compressed OVSF α sets, one entry per layer (`None`
    /// for dense layers) — the resident model state the slab generator
    /// reads. Fitted deterministically on first call and cached in the
    /// artifact, so model switches adopt shared `Arc`s instead of
    /// re-fitting, while timing-only pools never pay the fit. Backends
    /// adopt these via
    /// [`ExecutionBackend::preload`](crate::engine::ExecutionBackend::preload).
    pub fn hw(&self) -> Result<&[Option<Arc<HwOvsfWeights>>]> {
        if let Some(fitted) = self.hw.get() {
            return Ok(fitted);
        }
        let mut fitted = Vec::with_capacity(self.plan.n_layers());
        for (idx, layer) in self.plan.network.layers.iter().enumerate() {
            if layer.ovsf {
                let rho = self.plan.profile.rho(idx);
                let h = synth_hw_weights(&self.seed_name, self.layer_offset + idx, layer, rho)?;
                fitted.push(Some(Arc::new(h)));
            } else {
                fitted.push(None);
            }
        }
        // A racer may have fitted concurrently; both fits are
        // deterministic and identical, so whichever landed first wins.
        Ok(self.hw.get_or_init(|| fitted))
    }

    /// Numeric precision of the weight datapath this artifact serves at.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Per-layer symmetric int8 weight scales (`None` for dense layers),
    /// derived from the artifact's fitted α sets
    /// ([`HwOvsfWeights::i8_scale`]: `scale = max Σ|α| / 127`, an upper
    /// bound on any reconstructed weight — quantisation never clips).
    /// Computed on first call and cached; forces the lazy α fit.
    pub fn i8_scales(&self) -> Result<&[Option<f32>]> {
        if let Some(s) = self.i8_scales.get() {
            return Ok(s);
        }
        let fitted = self.hw()?;
        let scales: Vec<Option<f32>> = fitted
            .iter()
            .map(|h| h.as_ref().map(|hw| hw.i8_scale()))
            .collect();
        Ok(self.i8_scales.get_or_init(|| scales))
    }

    /// The artifact's accuracy/throughput point at each precision — the
    /// trade-off the `Compiler` surfaces per model: representative post-
    /// training-quantisation top-1 deltas from
    /// [`AccuracyModel`](crate::accuracy::model::AccuracyModel) against the
    /// analytical throughput with the weight word length set to each
    /// precision's byte width.
    pub fn precision_tradeoff(&self) -> Vec<crate::accuracy::model::PrecisionPoint> {
        crate::accuracy::model::precision_tradeoff(&self.plan)
    }

    /// Admission-time device latency per inference (seconds).
    pub fn latency_s(&self) -> f64 {
        self.plan.schedule.latency_s
    }
}

/// Compiles (network, ρ-profile) pairs into [`CompiledModel`] artifacts
/// for one engine configuration. The design point is pinned on the first
/// compile (explicitly via [`design_point`](Self::design_point), or by the
/// DSE optimum of the first model), so every artifact from one `Compiler`
/// targets the same fabric.
pub struct Compiler {
    platform: Option<Platform>,
    bw_mult: Option<u32>,
    precision: Precision,
    sigma: Mutex<Option<DesignPoint>>,
}

impl Default for Compiler {
    fn default() -> Self {
        Self::new()
    }
}

impl Compiler {
    /// Compiler with builder defaults (Z7045, 4× bandwidth, DSE-chosen σ).
    pub fn new() -> Self {
        Self {
            platform: None,
            bw_mult: None,
            precision: Precision::F32,
            sigma: Mutex::new(None),
        }
    }

    /// Target platform (default: Z7045).
    pub fn platform(mut self, platform: Platform) -> Self {
        self.platform = Some(platform);
        self
    }

    /// Off-chip bandwidth multiplier (default: 4).
    pub fn bandwidth(mut self, bw_mult: u32) -> Self {
        self.bw_mult = Some(bw_mult);
        self
    }

    /// Weight-datapath precision compiled into every artifact from this
    /// compiler (default: `F32`). At `I8`, slab generation quantises
    /// weights during reconstruction and the PE array runs the
    /// i8×i8→i32 microkernel; use
    /// [`CompiledModel::precision_tradeoff`] to inspect the
    /// accuracy/throughput point either choice lands on.
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    fn pinned(&self) -> std::sync::MutexGuard<'_, Option<DesignPoint>> {
        self.sigma.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Pin the design point σ up front (default: the first compile runs
    /// the DSE and pins its optimum for every later compile).
    pub fn design_point(self, sigma: DesignPoint) -> Self {
        *self.pinned() = Some(sigma);
        self
    }

    /// The pinned design point, once one exists.
    pub fn sigma(&self) -> Option<DesignPoint> {
        *self.pinned()
    }

    /// Validate and compile one model. Runs the plan validation
    /// (`EngineBuilder::plan`), derives the schedule, fits the synthetic
    /// OVSF α sets, and freezes the result into a [`CompiledModel`].
    pub fn compile(&self, network: Network, profile: RatioProfile) -> Result<CompiledModel> {
        let mut b = Engine::builder().network(network).profile(profile);
        if let Some(p) = self.platform.clone() {
            b = b.platform(p);
        }
        if let Some(bw) = self.bw_mult {
            b = b.bandwidth(bw);
        }
        if let Some(s) = self.sigma() {
            b = b.design_point(s);
        }
        let plan = b.plan()?;
        // One fabric for every model compiled here: pin the (possibly
        // DSE-chosen) design point for all subsequent compiles.
        *self.pinned() = Some(plan.sigma);
        CompiledModel::from_plan_at(plan, self.precision)
    }

    /// Partition `network` into contiguous layer-range stages and compile
    /// each range as its own artifact — the compile side of pipeline-
    /// parallel serving ([`StagePipeline`](crate::coordinator::stage::StagePipeline)).
    ///
    /// Validation (typed [`Error::InvalidConfig`] on violation):
    /// * `ranges` must be non-empty, each range non-empty, contiguous, and
    ///   cover `0..layers.len()` exactly;
    /// * every internal boundary must be an exact activation hand-off —
    ///   [`Layer::chains_to`](crate::workload::Layer::chains_to): the
    ///   producing layer's `(out_h, out_w, n_out)` equals the consuming
    ///   layer's `(h, w, n_in)` — so stage `k`'s raw output buffer *is*
    ///   stage `k+1`'s admission-valid input and the split serves
    ///   bit-identical numerics.
    ///
    /// Each stage artifact gets:
    /// * its own network/profile named `"{name}::s{k}"`, which keeps the
    ///   runtime [`WeightsKey`] namespaces of different stages (and of the
    ///   unsplit model) disjoint in any shared cache;
    /// * the **original** model's seed namespace at the stage's layer
    ///   offset ([`CompiledModel::seed_name`]/[`layer_offset`](CompiledModel::layer_offset)),
    ///   so every stage synthesises exactly the weights the unsplit
    ///   artifact would for those layers;
    /// * its own design point: a pinned compiler σ applies to every stage,
    ///   otherwise each stage runs its own DSE over just its layer range —
    ///   per-stage fabric shapes for free. `split` never pins the
    ///   compiler's σ (stage optima are range-local, not whole-model).
    pub fn split(
        &self,
        network: Network,
        profile: RatioProfile,
        ranges: &[Range<usize>],
    ) -> Result<Vec<CompiledModel>> {
        let n = network.layers.len();
        if ranges.is_empty() {
            return Err(Error::InvalidConfig(
                "split requires at least one layer range".into(),
            ));
        }
        if profile.len() != n {
            return Err(Error::InvalidConfig(format!(
                "ρ profile '{}' has {} entries but network '{}' has {} layers",
                profile.name,
                profile.len(),
                network.name,
                n
            )));
        }
        let mut expect = 0usize;
        for (k, r) in ranges.iter().enumerate() {
            if r.start >= r.end {
                return Err(Error::InvalidConfig(format!(
                    "stage {k} range {}..{} is empty",
                    r.start, r.end
                )));
            }
            if r.start != expect {
                return Err(Error::InvalidConfig(format!(
                    "stage {k} starts at layer {} but the previous stage ends at {expect}: \
                     ranges must be contiguous",
                    r.start
                )));
            }
            if r.end > n {
                return Err(Error::InvalidConfig(format!(
                    "stage {k} range {}..{} exceeds the {n}-layer network",
                    r.start, r.end
                )));
            }
            expect = r.end;
        }
        if expect != n {
            return Err(Error::InvalidConfig(format!(
                "ranges cover layers 0..{expect} but the network has {n}: \
                 every layer must belong to exactly one stage"
            )));
        }
        for (k, r) in ranges[..ranges.len() - 1].iter().enumerate() {
            let prev = &network.layers[r.end - 1];
            let next = &network.layers[r.end];
            if !prev.chains_to(next) {
                return Err(Error::InvalidConfig(format!(
                    "cut between layers {} ('{}') and {} ('{}') is not an exact \
                     activation hand-off: {}×{}×{} out vs {}×{}×{} in — stage {k} \
                     cannot hand its output buffer to stage {}",
                    r.end - 1,
                    prev.name,
                    r.end,
                    next.name,
                    prev.out_h(),
                    prev.out_w(),
                    prev.n_out,
                    next.h,
                    next.w,
                    next.n_in,
                    k + 1
                )));
            }
        }
        let mut stages = Vec::with_capacity(ranges.len());
        for (k, r) in ranges.iter().enumerate() {
            let stage_net = Network {
                name: format!("{}::s{k}", network.name),
                layers: network.layers[r.clone()].to_vec(),
            };
            let stage_profile = RatioProfile {
                name: format!("{}::s{k}", profile.name),
                rhos: profile.rhos[r.clone()].to_vec(),
            };
            let mut b = Engine::builder().network(stage_net).profile(stage_profile);
            if let Some(p) = self.platform.clone() {
                b = b.platform(p);
            }
            if let Some(bw) = self.bw_mult {
                b = b.bandwidth(bw);
            }
            if let Some(s) = self.sigma() {
                b = b.design_point(s);
            }
            let plan = b.plan()?;
            stages.push(CompiledModel::from_plan_seeded(
                plan,
                self.precision,
                network.name.clone(),
                r.start,
            )?);
        }
        Ok(stages)
    }

    /// [`split`](Self::split) with ranges chosen automatically: MACs-
    /// balanced over the network's valid cut points
    /// ([`partition_stages`](crate::dse::partition_stages)).
    pub fn split_balanced(
        &self,
        network: Network,
        profile: RatioProfile,
        k: usize,
    ) -> Result<Vec<CompiledModel>> {
        let ranges = crate::dse::partition_stages(&network, k)?;
        self.split(network, profile, &ranges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{resnet, squeezenet, Layer};

    fn tiny_net() -> Network {
        Network {
            name: "tiny".into(),
            layers: vec![
                Layer::conv("stem", 8, 8, 4, 8, 3, 1, 1, false),
                Layer::conv("b.conv1", 8, 8, 8, 8, 3, 1, 1, true),
                Layer::conv("b.conv2", 8, 8, 8, 16, 3, 2, 1, true),
                Layer::fc("fc", 16, 10),
            ],
        }
    }

    #[test]
    fn compiled_model_carries_shapes_keys_and_alphas() {
        let net = tiny_net();
        let profile = RatioProfile::uniform(&net, 0.5);
        let compiler = Compiler::new()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(DesignPoint::new(8, 4, 8, 4));
        let m = compiler.compile(net.clone(), profile).unwrap();
        assert_eq!(m.network_name(), "tiny");
        assert_eq!(m.input_len(), 8 * 8 * 4);
        assert_eq!(m.output_len(), 10);
        assert_eq!(m.weights_keys().len(), 2, "one key per OVSF layer");
        assert_eq!(m.weight_seeds().len(), net.layers.len());
        assert!(m.alpha_words() > 0);
        assert!(m.latency_s() > 0.0);
        // Per-layer α state exists exactly for the OVSF layers and matches
        // the simulator's own lazy synthesis (same seeds, same fit).
        let fitted = m.hw().unwrap();
        assert_eq!(fitted.len(), net.layers.len());
        for (idx, layer) in net.layers.iter().enumerate() {
            match &fitted[idx] {
                Some(hw) => {
                    assert!(layer.ovsf);
                    let lazy = synth_hw_weights("tiny", idx, layer, 0.5).unwrap();
                    assert_eq!(hw.alphas, lazy.alphas, "compiled α ≠ lazy fit");
                }
                None => assert!(!layer.ovsf),
            }
        }
    }

    #[test]
    fn compiler_pins_sigma_across_models() {
        let r18 = resnet::resnet18();
        let sqn = squeezenet::squeezenet1_1();
        let compiler = Compiler::new().platform(Platform::zu7ev()).bandwidth(12);
        assert!(compiler.sigma().is_none());
        let a = compiler
            .compile(r18.clone(), RatioProfile::ovsf50(&r18))
            .unwrap();
        let pinned = compiler.sigma().expect("first compile pins σ");
        assert_eq!(a.sigma(), pinned);
        let b = compiler
            .compile(sqn.clone(), RatioProfile::ovsf50(&sqn))
            .unwrap();
        assert_eq!(b.sigma(), pinned, "one fabric serves every model");
    }

    #[test]
    fn i8_artifact_stamps_keys_and_derives_positive_scales() {
        let net = tiny_net();
        let profile = RatioProfile::uniform(&net, 0.5);
        let compiler = Compiler::new()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(DesignPoint::new(8, 4, 8, 4))
            .precision(Precision::I8);
        let m = compiler.compile(net.clone(), profile.clone()).unwrap();
        assert_eq!(m.precision(), Precision::I8);
        for k in m.weights_keys() {
            assert_eq!(k.precision, Precision::I8, "key must carry precision");
        }
        // Scales exist exactly for OVSF layers, are positive/finite, and
        // match a direct derivation from the fitted α sets.
        let scales = m.i8_scales().unwrap();
        assert_eq!(scales.len(), net.layers.len());
        let fitted = m.hw().unwrap();
        for (idx, s) in scales.iter().enumerate() {
            match (s, &fitted[idx]) {
                (Some(scale), Some(hw)) => {
                    assert!(scale.is_finite() && *scale > 0.0);
                    assert_eq!(*scale, hw.i8_scale());
                }
                (None, None) => assert!(!net.layers[idx].ovsf),
                _ => panic!("scale/α presence mismatch at layer {idx}"),
            }
        }
        // An F32 twin of the same network lives under different keys.
        let compiler_f = Compiler::new()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(DesignPoint::new(8, 4, 8, 4));
        let mf = compiler_f.compile(net, profile).unwrap();
        assert_eq!(mf.precision(), Precision::F32);
        for (ki, kf) in m.weights_keys().iter().zip(mf.weights_keys()) {
            assert_ne!(ki, kf, "precision must split the key namespace");
        }
        // Both precisions appear in the surfaced trade-off.
        let points = m.precision_tradeoff();
        assert!(points.iter().any(|p| p.precision == Precision::F32));
        assert!(points.iter().any(|p| p.precision == Precision::I8));
    }

    #[test]
    fn compile_rejects_invalid_configs() {
        let net = tiny_net();
        let profile = RatioProfile::uniform(&net, 0.5);
        // A wgen-less σ cannot serve an OVSF model.
        let compiler = Compiler::new().design_point(DesignPoint::new(0, 4, 8, 4));
        assert!(compiler.compile(net, profile).is_err());
    }

    fn pinned_compiler() -> Compiler {
        Compiler::new()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(DesignPoint::new(8, 4, 8, 4))
    }

    #[test]
    fn split_produces_chained_stages_in_the_original_seed_namespace() {
        let net = tiny_net();
        let profile = RatioProfile::uniform(&net, 0.5);
        let compiler = pinned_compiler();
        let whole = compiler.compile(net.clone(), profile.clone()).unwrap();
        let stages = compiler.split(net.clone(), profile, &[0..2, 2..4]).unwrap();
        assert_eq!(stages.len(), 2);
        // Disjoint runtime namespaces: stage networks are renamed.
        assert_eq!(stages[0].network_name(), "tiny::s0");
        assert_eq!(stages[1].network_name(), "tiny::s1");
        // Shared weight identity: seeds live in the ORIGINAL namespace at
        // each stage's absolute layer offset.
        assert_eq!(stages[0].seed_name(), "tiny");
        assert_eq!(stages[1].seed_name(), "tiny");
        assert_eq!(stages[0].layer_offset(), 0);
        assert_eq!(stages[1].layer_offset(), 2);
        assert_eq!(stages[0].weight_seeds(), &whole.weight_seeds()[..2]);
        assert_eq!(stages[1].weight_seeds(), &whole.weight_seeds()[2..]);
        // Activation shapes chain exactly across the cut.
        assert_eq!(stages[0].input_len(), whole.input_len());
        assert_eq!(stages[0].output_len(), stages[1].input_len());
        assert_eq!(stages[1].output_len(), whole.output_len());
        // The fitted α sets are the unsplit model's, re-indexed.
        let whole_hw = whole.hw().unwrap();
        let s1_hw = stages[1].hw().unwrap();
        assert_eq!(
            s1_hw[0].as_ref().unwrap().alphas,
            whole_hw[2].as_ref().unwrap().alphas,
            "stage α ≠ unsplit α at absolute layer 2"
        );
        // WeightsKeys are disjoint across stages and vs the unsplit model.
        let mut all_keys: Vec<_> = whole.weights_keys().to_vec();
        all_keys.extend(stages.iter().flat_map(|s| s.weights_keys().to_vec()));
        for (i, a) in all_keys.iter().enumerate() {
            for b in &all_keys[i + 1..] {
                assert_ne!(a, b, "slab key namespaces must not alias");
            }
        }
        // Respins preserve the stage's seed namespace (the supervisor
        // rebuild path must keep serving the original model's weights).
        let re = stages[1].respin().unwrap();
        assert_eq!(re.seed_name(), "tiny");
        assert_eq!(re.layer_offset(), 2);
        assert_eq!(re.weight_seeds(), stages[1].weight_seeds());
    }

    #[test]
    fn split_rejects_bad_ranges_typed() {
        let net = tiny_net();
        let profile = RatioProfile::uniform(&net, 0.5);
        let compiler = pinned_compiler();
        let bad: &[&[std::ops::Range<usize>]] = &[
            &[],               // no ranges at all
            &[0..2],           // does not cover the tail
            &[0..2, 3..4],     // gap at layer 2
            &[0..2, 1..4],     // overlap
            &[0..0, 0..4],     // empty range
            &[0..2, 2..5],     // out of bounds
            &[1..4],           // does not start at 0
            &[0..3, 3..4],     // conv2→fc: 4·4·16 out vs 1·1·16 in
        ];
        for ranges in bad {
            let err = compiler
                .split(net.clone(), profile.clone(), ranges)
                .expect_err(&format!("ranges {ranges:?} must be rejected"));
            assert!(
                matches!(err, crate::error::Error::InvalidConfig(_)),
                "expected InvalidConfig for {ranges:?}, got {err}"
            );
        }
        // A short ρ profile is caught before any slicing.
        let short = RatioProfile {
            name: "short".into(),
            rhos: vec![0.5; 2],
        };
        assert!(matches!(
            compiler.split(net, short, &[0..2, 2..4]),
            Err(crate::error::Error::InvalidConfig(_))
        ));
    }

    #[test]
    fn split_balanced_picks_valid_contiguous_cuts() {
        let net = crate::workload::tiny::small_resnet();
        let profile = RatioProfile::uniform(&net, 0.5);
        let compiler = pinned_compiler();
        let stages = compiler
            .split_balanced(net.clone(), profile, 2)
            .expect("small_resnet has valid cuts for K=2");
        assert_eq!(stages.len(), 2);
        let total: usize = stages.iter().map(|s| s.plan().n_layers()).sum();
        assert_eq!(total, net.layers.len());
        assert_eq!(stages[0].output_len(), stages[1].input_len());
    }
}
