//! The compile-once / serve-many split: [`Compiler`] turns a
//! (network, profile) pair into an immutable [`CompiledModel`] artifact,
//! and serving layers ([`ModelRegistry`](crate::coordinator::registry::ModelRegistry),
//! [`ServerPool::serve`](crate::coordinator::pool::ServerPool::serve))
//! route requests onto those artifacts without re-validating or re-fitting
//! anything per request.
//!
//! A `CompiledModel` is everything that used to be scattered across
//! `EngineBuilder::plan`, the scheduler and the simulator backend's lazy
//! per-layer weight synthesis:
//!
//! * the validated [`EnginePlan`] (platform + bandwidth operating point,
//!   design point σ, workload, ρ profile, admission-time schedule);
//! * the model's [`WeightsKey`] namespace — one key per OVSF layer, the
//!   identity its generated weight slabs live under in the shared
//!   [`SlabCache`](crate::engine::wcache::SlabCache);
//! * the per-layer synthetic-checkpoint seeds and the **per-artifact
//!   compressed OVSF α sets** (the resident model state the slab generator
//!   reads; fitted once, lazily on first numeric use), so model switches
//!   on a serving worker adopt the artifact's α's instead of re-fitting
//!   them — and timing-only pools never pay the fit;
//! * the expected input/output activation lengths, checked at admission so
//!   a malformed request fails fast at `submit` with a typed error.
//!
//! The `Compiler` pins the design point after its first compile: every
//! model compiled through one `Compiler` shares one σ — the single
//! computation engine the paper serves all CNNs from, with only the
//! per-model α state differing (unzipFPGA §1: resources reused across
//! layers *and* CNN models without reconfiguring the fabric).

use std::sync::{Arc, Mutex, OnceLock, PoisonError};

use crate::arch::{DesignPoint, Platform};
use crate::engine::backend::EnginePlan;
use crate::engine::sim::{layer_seed, synth_hw_weights};
use crate::engine::wcache::WeightsKey;
use crate::engine::Engine;
use crate::error::Result;
use crate::sim::hw_weights::HwOvsfWeights;
use crate::util::fixed::Precision;
use crate::workload::{Network, RatioProfile};

/// An immutable, shareable model artifact: the output of
/// [`Compiler::compile`], the unit a
/// [`ModelRegistry`](crate::coordinator::registry::ModelRegistry) holds.
pub struct CompiledModel {
    plan: EnginePlan,
    input_len: usize,
    output_len: usize,
    alpha_words: u64,
    weights_keys: Vec<WeightsKey>,
    weight_seeds: Vec<u64>,
    /// Registration generation stamped into every weights key (0 until the
    /// artifact is registered — see
    /// [`ModelRegistry::register`](crate::coordinator::registry::ModelRegistry::register)).
    generation: u64,
    /// Numeric precision of the weight datapath this artifact serves at.
    precision: Precision,
    /// Fitted once per artifact, on first use by a numeric backend —
    /// timing-only (analytical) pools never pay the fit.
    hw: OnceLock<Vec<Option<Arc<HwOvsfWeights>>>>,
    /// Per-layer α-derived int8 weight scales (`None` for dense layers),
    /// derived from [`hw`](Self::hw) on first use for `I8` artifacts.
    i8_scales: OnceLock<Vec<Option<f32>>>,
}

impl std::fmt::Debug for CompiledModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompiledModel")
            .field("network", &self.plan.network.name)
            .field("sigma", &self.plan.sigma)
            .field("input_len", &self.input_len)
            .field("output_len", &self.output_len)
            .field("alpha_words", &self.alpha_words)
            .field("ovsf_layers", &self.weights_keys.len())
            .field("precision", &self.precision)
            .finish()
    }
}

impl CompiledModel {
    /// Compile an already-validated plan into an artifact: derive the
    /// weights-key namespace, the per-layer synthetic-checkpoint seeds and
    /// the α-volume accounting. The compressed OVSF α sets themselves are
    /// fitted once per artifact, lazily on first use by a numeric backend
    /// (see [`hw`](Self::hw)). Compiles at the reference `F32` precision;
    /// use [`from_plan_at`](Self::from_plan_at) (or
    /// [`Compiler::precision`]) for the int8 datapath.
    pub fn from_plan(plan: EnginePlan) -> Result<Self> {
        Self::from_plan_at(plan, Precision::F32)
    }

    /// Compile an already-validated plan at an explicit weight-datapath
    /// precision. The precision is stamped into every [`WeightsKey`] so an
    /// f32 and an i8 artifact of the same network can never alias each
    /// other's slabs in a shared cache.
    pub fn from_plan_at(plan: EnginePlan, precision: Precision) -> Result<Self> {
        let n = plan.n_layers();
        let mut weights_keys = Vec::new();
        let mut weight_seeds = Vec::with_capacity(n);
        let mut alpha_words = 0u64;
        for (idx, layer) in plan.network.layers.iter().enumerate() {
            weight_seeds.push(layer_seed(&plan.network.name, idx, layer));
            if layer.ovsf {
                let rho = plan.profile.rho(idx);
                alpha_words += layer.n_in * layer.n_out * layer.basis_per_chunk(rho);
                weights_keys.push(
                    WeightsKey::new(
                        plan.network.name.clone(),
                        idx,
                        (layer.n_in, layer.n_out, layer.k),
                        plan.sigma,
                        rho,
                    )
                    .with_precision(precision),
                );
            }
        }
        let input_len = plan
            .network
            .layers
            .first()
            .map(|l| (l.h * l.w * l.n_in) as usize)
            .unwrap_or(0);
        let output_len = plan
            .network
            .layers
            .last()
            .map(|l| {
                let g = l.gemm();
                (g.r * g.c) as usize
            })
            .unwrap_or(0);
        Ok(Self {
            plan,
            input_len,
            output_len,
            alpha_words,
            weights_keys,
            weight_seeds,
            generation: 0,
            precision,
            hw: OnceLock::new(),
            i8_scales: OnceLock::new(),
        })
    }

    /// The registration generation this artifact's slab identities live
    /// under (0 for unregistered artifacts).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Re-derive a fresh artifact from this one's plan and precision —
    /// generation 0, lazy α state unfit. Compilation is deterministic (the
    /// plan embeds σ and the profile; seeds are pure functions of the
    /// network), so the respin serves **bit-identical numerics**: this is
    /// how a replica supervisor rebuilds a dead replica's models from the
    /// survivors' catalog entries. Registering the respin stamps it a new
    /// generation, so it can never adopt the dead incarnation's slabs.
    pub fn respin(&self) -> Result<Self> {
        Self::from_plan_at(self.plan.clone(), self.precision)
    }

    /// Stamp a registration generation into the artifact and every
    /// [`WeightsKey`] it owns. Called by
    /// [`ModelRegistry::register`](crate::coordinator::registry::ModelRegistry::register)
    /// before the artifact is shared, so slabs generated for an earlier
    /// (evicted) registration of the same model id can never be adopted by
    /// this one.
    pub(crate) fn assign_generation(&mut self, generation: u64) {
        self.generation = generation;
        for k in &mut self.weights_keys {
            k.generation = generation;
        }
    }

    /// The validated plan this artifact executes.
    pub fn plan(&self) -> &EnginePlan {
        &self.plan
    }

    /// The compiled network's name (the conventional registry id).
    pub fn network_name(&self) -> &str {
        &self.plan.network.name
    }

    /// Design point σ the model was compiled for.
    pub fn sigma(&self) -> DesignPoint {
        self.plan.sigma
    }

    /// Expected request input length: the first layer's `h·w·c_in` NHWC
    /// activations. Admission control rejects other non-empty lengths.
    pub fn input_len(&self) -> usize {
        self.input_len
    }

    /// Output activation length a numeric request returns (the last
    /// layer's `R·C`).
    pub fn output_len(&self) -> usize {
        self.output_len
    }

    /// α words that must be resident for this model — the state (and the
    /// only weight traffic) a model switch moves.
    pub fn alpha_words(&self) -> u64 {
        self.alpha_words
    }

    /// The model's generated-weights namespace: one [`WeightsKey`] per
    /// OVSF layer. Evicting the model drops these from the shared cache.
    pub fn weights_keys(&self) -> &[WeightsKey] {
        &self.weights_keys
    }

    /// Deterministic per-layer synthetic-checkpoint seeds (the repro's
    /// stand-in for trained weights identity).
    pub fn weight_seeds(&self) -> &[u64] {
        &self.weight_seeds
    }

    /// The artifact's compressed OVSF α sets, one entry per layer (`None`
    /// for dense layers) — the resident model state the slab generator
    /// reads. Fitted deterministically on first call and cached in the
    /// artifact, so model switches adopt shared `Arc`s instead of
    /// re-fitting, while timing-only pools never pay the fit. Backends
    /// adopt these via
    /// [`ExecutionBackend::preload`](crate::engine::ExecutionBackend::preload).
    pub fn hw(&self) -> Result<&[Option<Arc<HwOvsfWeights>>]> {
        if let Some(fitted) = self.hw.get() {
            return Ok(fitted);
        }
        let mut fitted = Vec::with_capacity(self.plan.n_layers());
        for (idx, layer) in self.plan.network.layers.iter().enumerate() {
            if layer.ovsf {
                let rho = self.plan.profile.rho(idx);
                let h = synth_hw_weights(&self.plan.network.name, idx, layer, rho)?;
                fitted.push(Some(Arc::new(h)));
            } else {
                fitted.push(None);
            }
        }
        // A racer may have fitted concurrently; both fits are
        // deterministic and identical, so whichever landed first wins.
        Ok(self.hw.get_or_init(|| fitted))
    }

    /// Numeric precision of the weight datapath this artifact serves at.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Per-layer symmetric int8 weight scales (`None` for dense layers),
    /// derived from the artifact's fitted α sets
    /// ([`HwOvsfWeights::i8_scale`]: `scale = max Σ|α| / 127`, an upper
    /// bound on any reconstructed weight — quantisation never clips).
    /// Computed on first call and cached; forces the lazy α fit.
    pub fn i8_scales(&self) -> Result<&[Option<f32>]> {
        if let Some(s) = self.i8_scales.get() {
            return Ok(s);
        }
        let fitted = self.hw()?;
        let scales: Vec<Option<f32>> = fitted
            .iter()
            .map(|h| h.as_ref().map(|hw| hw.i8_scale()))
            .collect();
        Ok(self.i8_scales.get_or_init(|| scales))
    }

    /// The artifact's accuracy/throughput point at each precision — the
    /// trade-off the `Compiler` surfaces per model: representative post-
    /// training-quantisation top-1 deltas from
    /// [`AccuracyModel`](crate::accuracy::model::AccuracyModel) against the
    /// analytical throughput with the weight word length set to each
    /// precision's byte width.
    pub fn precision_tradeoff(&self) -> Vec<crate::accuracy::model::PrecisionPoint> {
        crate::accuracy::model::precision_tradeoff(&self.plan)
    }

    /// Admission-time device latency per inference (seconds).
    pub fn latency_s(&self) -> f64 {
        self.plan.schedule.latency_s
    }
}

/// Compiles (network, ρ-profile) pairs into [`CompiledModel`] artifacts
/// for one engine configuration. The design point is pinned on the first
/// compile (explicitly via [`design_point`](Self::design_point), or by the
/// DSE optimum of the first model), so every artifact from one `Compiler`
/// targets the same fabric.
pub struct Compiler {
    platform: Option<Platform>,
    bw_mult: Option<u32>,
    precision: Precision,
    sigma: Mutex<Option<DesignPoint>>,
}

impl Default for Compiler {
    fn default() -> Self {
        Self::new()
    }
}

impl Compiler {
    /// Compiler with builder defaults (Z7045, 4× bandwidth, DSE-chosen σ).
    pub fn new() -> Self {
        Self {
            platform: None,
            bw_mult: None,
            precision: Precision::F32,
            sigma: Mutex::new(None),
        }
    }

    /// Target platform (default: Z7045).
    pub fn platform(mut self, platform: Platform) -> Self {
        self.platform = Some(platform);
        self
    }

    /// Off-chip bandwidth multiplier (default: 4).
    pub fn bandwidth(mut self, bw_mult: u32) -> Self {
        self.bw_mult = Some(bw_mult);
        self
    }

    /// Weight-datapath precision compiled into every artifact from this
    /// compiler (default: `F32`). At `I8`, slab generation quantises
    /// weights during reconstruction and the PE array runs the
    /// i8×i8→i32 microkernel; use
    /// [`CompiledModel::precision_tradeoff`] to inspect the
    /// accuracy/throughput point either choice lands on.
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    fn pinned(&self) -> std::sync::MutexGuard<'_, Option<DesignPoint>> {
        self.sigma.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Pin the design point σ up front (default: the first compile runs
    /// the DSE and pins its optimum for every later compile).
    pub fn design_point(self, sigma: DesignPoint) -> Self {
        *self.pinned() = Some(sigma);
        self
    }

    /// The pinned design point, once one exists.
    pub fn sigma(&self) -> Option<DesignPoint> {
        *self.pinned()
    }

    /// Validate and compile one model. Runs the plan validation
    /// (`EngineBuilder::plan`), derives the schedule, fits the synthetic
    /// OVSF α sets, and freezes the result into a [`CompiledModel`].
    pub fn compile(&self, network: Network, profile: RatioProfile) -> Result<CompiledModel> {
        let mut b = Engine::builder().network(network).profile(profile);
        if let Some(p) = self.platform.clone() {
            b = b.platform(p);
        }
        if let Some(bw) = self.bw_mult {
            b = b.bandwidth(bw);
        }
        if let Some(s) = self.sigma() {
            b = b.design_point(s);
        }
        let plan = b.plan()?;
        // One fabric for every model compiled here: pin the (possibly
        // DSE-chosen) design point for all subsequent compiles.
        *self.pinned() = Some(plan.sigma);
        CompiledModel::from_plan_at(plan, self.precision)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{resnet, squeezenet, Layer};

    fn tiny_net() -> Network {
        Network {
            name: "tiny".into(),
            layers: vec![
                Layer::conv("stem", 8, 8, 4, 8, 3, 1, 1, false),
                Layer::conv("b.conv1", 8, 8, 8, 8, 3, 1, 1, true),
                Layer::conv("b.conv2", 8, 8, 8, 16, 3, 2, 1, true),
                Layer::fc("fc", 16, 10),
            ],
        }
    }

    #[test]
    fn compiled_model_carries_shapes_keys_and_alphas() {
        let net = tiny_net();
        let profile = RatioProfile::uniform(&net, 0.5);
        let compiler = Compiler::new()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(DesignPoint::new(8, 4, 8, 4));
        let m = compiler.compile(net.clone(), profile).unwrap();
        assert_eq!(m.network_name(), "tiny");
        assert_eq!(m.input_len(), 8 * 8 * 4);
        assert_eq!(m.output_len(), 10);
        assert_eq!(m.weights_keys().len(), 2, "one key per OVSF layer");
        assert_eq!(m.weight_seeds().len(), net.layers.len());
        assert!(m.alpha_words() > 0);
        assert!(m.latency_s() > 0.0);
        // Per-layer α state exists exactly for the OVSF layers and matches
        // the simulator's own lazy synthesis (same seeds, same fit).
        let fitted = m.hw().unwrap();
        assert_eq!(fitted.len(), net.layers.len());
        for (idx, layer) in net.layers.iter().enumerate() {
            match &fitted[idx] {
                Some(hw) => {
                    assert!(layer.ovsf);
                    let lazy = synth_hw_weights("tiny", idx, layer, 0.5).unwrap();
                    assert_eq!(hw.alphas, lazy.alphas, "compiled α ≠ lazy fit");
                }
                None => assert!(!layer.ovsf),
            }
        }
    }

    #[test]
    fn compiler_pins_sigma_across_models() {
        let r18 = resnet::resnet18();
        let sqn = squeezenet::squeezenet1_1();
        let compiler = Compiler::new().platform(Platform::zu7ev()).bandwidth(12);
        assert!(compiler.sigma().is_none());
        let a = compiler
            .compile(r18.clone(), RatioProfile::ovsf50(&r18))
            .unwrap();
        let pinned = compiler.sigma().expect("first compile pins σ");
        assert_eq!(a.sigma(), pinned);
        let b = compiler
            .compile(sqn.clone(), RatioProfile::ovsf50(&sqn))
            .unwrap();
        assert_eq!(b.sigma(), pinned, "one fabric serves every model");
    }

    #[test]
    fn i8_artifact_stamps_keys_and_derives_positive_scales() {
        let net = tiny_net();
        let profile = RatioProfile::uniform(&net, 0.5);
        let compiler = Compiler::new()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(DesignPoint::new(8, 4, 8, 4))
            .precision(Precision::I8);
        let m = compiler.compile(net.clone(), profile.clone()).unwrap();
        assert_eq!(m.precision(), Precision::I8);
        for k in m.weights_keys() {
            assert_eq!(k.precision, Precision::I8, "key must carry precision");
        }
        // Scales exist exactly for OVSF layers, are positive/finite, and
        // match a direct derivation from the fitted α sets.
        let scales = m.i8_scales().unwrap();
        assert_eq!(scales.len(), net.layers.len());
        let fitted = m.hw().unwrap();
        for (idx, s) in scales.iter().enumerate() {
            match (s, &fitted[idx]) {
                (Some(scale), Some(hw)) => {
                    assert!(scale.is_finite() && *scale > 0.0);
                    assert_eq!(*scale, hw.i8_scale());
                }
                (None, None) => assert!(!net.layers[idx].ovsf),
                _ => panic!("scale/α presence mismatch at layer {idx}"),
            }
        }
        // An F32 twin of the same network lives under different keys.
        let compiler_f = Compiler::new()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(DesignPoint::new(8, 4, 8, 4));
        let mf = compiler_f.compile(net, profile).unwrap();
        assert_eq!(mf.precision(), Precision::F32);
        for (ki, kf) in m.weights_keys().iter().zip(mf.weights_keys()) {
            assert_ne!(ki, kf, "precision must split the key namespace");
        }
        // Both precisions appear in the surfaced trade-off.
        let points = m.precision_tradeoff();
        assert!(points.iter().any(|p| p.precision == Precision::F32));
        assert!(points.iter().any(|p| p.precision == Precision::I8));
    }

    #[test]
    fn compile_rejects_invalid_configs() {
        let net = tiny_net();
        let profile = RatioProfile::uniform(&net, 0.5);
        // A wgen-less σ cannot serve an OVSF model.
        let compiler = Compiler::new().design_point(DesignPoint::new(0, 4, 8, 4));
        assert!(compiler.compile(net, profile).is_err());
    }
}
