//! [`SimBackend`] — executes the plan on the cycle-level simulator
//! ([`LayerSim`] walking the tile schedule, with the OVSF generator's
//! Alg. 1 cycle counts for on-the-fly layers). Timing only; the numeric
//! TiWGen/PE-array path stays available through `sim::LayerSim` directly.

use crate::engine::backend::{
    EnginePlan, ExecutionBackend, ExecutionReport, LayerCost, LayerOutcome,
};
use crate::error::{Error, Result};
use crate::sim::engine::LayerSim;
use crate::util::ceil_div;

/// Backend over [`LayerSim`]: each layer's tile schedule is walked with
/// deterministic cycle counters at `execute_layer` time.
#[derive(Default)]
pub struct SimBackend {
    plan: Option<EnginePlan>,
    executed: Vec<LayerCost>,
}

impl SimBackend {
    /// New, unplanned backend.
    pub fn new() -> Self {
        Self::default()
    }

    fn planned(&self) -> Result<&EnginePlan> {
        self.plan
            .as_ref()
            .ok_or_else(|| Error::InvalidConfig("backend used before plan()".into()))
    }
}

impl ExecutionBackend for SimBackend {
    fn name(&self) -> &'static str {
        "simulator"
    }

    fn plan(&mut self, plan: &EnginePlan) -> Result<()> {
        self.plan = Some(plan.clone());
        self.executed.clear();
        Ok(())
    }

    fn execute_layer(&mut self, idx: usize, _input: &[f32]) -> Result<LayerOutcome> {
        let plan = self.planned()?;
        let layer = plan.network.layers.get(idx).ok_or_else(|| {
            Error::InvalidConfig(format!(
                "layer index {idx} out of range ({} layers)",
                plan.network.layers.len()
            ))
        })?;
        let sim = LayerSim::new(&plan.sigma, &plan.platform, plan.bw_mult);
        // Cycle count per Alg. 1 without materialising weights:
        // n_basis · subtiles · p_tiles (validated == WGenSim walk).
        let trace = if layer.ovsf && plan.sigma.has_wgen() {
            let cycles = layer.basis_per_chunk(plan.profile.rho(idx))
                * plan.sigma.subtiles_per_tile()
                * ceil_div(layer.gemm().p, plan.sigma.t_p);
            sim.run_timing(layer, Some(cycles))
        } else {
            sim.run_timing(layer, None)
        };
        let outcome = LayerOutcome {
            name: trace.name.clone(),
            cycles: trace.total_cycles as f64,
            bound: trace.bound,
            output: None,
        };
        self.executed.push(LayerCost {
            name: trace.name,
            cycles: trace.total_cycles as f64,
            bound: trace.bound,
        });
        Ok(outcome)
    }

    fn finish(&mut self) -> Result<ExecutionReport> {
        let clock_hz = self.planned()?.platform.clock_hz;
        let layers = std::mem::take(&mut self.executed);
        let total_cycles: f64 = layers.iter().map(|l| l.cycles).sum();
        Ok(ExecutionReport {
            backend: self.name(),
            layers,
            total_cycles,
            latency_s: total_cycles / clock_hz,
        })
    }
}
