//! [`SimBackend`] — executes the plan on the cycle-level simulator *and*
//! computes real activations through the PE array with weights generated
//! on the fly, tile by tile.
//!
//! Timing: [`LayerSim`] walks each layer's tile schedule (with the OVSF
//! generator's Alg. 1 cycle counts for on-the-fly layers), exactly as
//! before.
//!
//! Numerics: a non-empty request input is threaded layer-to-layer. Each
//! layer is lowered to its GEMM view one `T_R×P` row-strip at a time
//! ([`im2col_strip_into`]) and multiplied slab-by-slab on the PE array
//! ([`PeArraySim::execute_strip`]): OVSF layers generate one `P×T_C`
//! weight slab at a time through the shared bounded
//! [`SlabCache`](crate::engine::wcache::SlabCache) (the paper's on-chip
//! generation discipline — dense weights never exist beyond the slab
//! budget), while non-OVSF layers (stem, downsamples, classifier) stream
//! deterministic synthetic dense weights one slab at a time into scratch.
//! An empty input keeps the request timing-only — the serving convention
//! of [`Request`](crate::coordinator::server::Request).

use std::sync::Arc;

use crate::engine::backend::{
    EnginePlan, ExecutionBackend, ExecutionReport, LayerCost, LayerOutcome,
};
use crate::engine::wcache::{SlabCache, SlabKey, WeightsKey};
use crate::error::{Error, Result};
use crate::sim::engine::LayerSim;
use crate::sim::hw_weights::HwOvsfWeights;
use crate::sim::im2col::im2col_strip_into;
use crate::sim::pe_array::PeArraySim;
use crate::util::ceil_div;
use crate::util::prng::Xoshiro256;
use crate::workload::layer::Layer;

/// Deterministic per-layer seed: the repro has no trained ImageNet
/// checkpoints, so every worker must agree on the synthetic weights for
/// the shared slab cache to be coherent.
fn layer_seed(model: &str, idx: usize, layer: &Layer) -> u64 {
    let mut seed: u64 = 0xcbf2_9ce4_8422_2325;
    for b in model.bytes().chain(layer.name.bytes()) {
        seed ^= b as u64;
        seed = seed.wrapping_mul(0x1000_0000_01b3);
    }
    seed ^ (idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Deterministic compressed OVSF weights (α's) for a layer — the resident
/// model state the slab generator reads. He-style fan-in scaling is the
/// synthetic checkpoint's folded normalisation: with unit-normal α's a
/// generated weight sums `n_basis` signed α's and a layer output sums `P`
/// weighted activations, so `1/√(P·n_basis)` keeps activation magnitudes
/// O(1) through an arbitrarily deep chain.
pub fn synth_hw_weights(model: &str, idx: usize, layer: &Layer, rho: f64) -> Result<HwOvsfWeights> {
    let mut rng = Xoshiro256::seed_from_u64(layer_seed(model, idx, layer));
    let mut hw = HwOvsfWeights::random(
        &mut rng,
        layer.n_out as usize,
        layer.n_in as usize,
        layer.k as usize,
        rho,
    )?;
    let scale = 1.0 / ((hw.p_dim() * hw.n_basis).max(1) as f32).sqrt();
    for a in &mut hw.alphas {
        *a *= scale;
    }
    Ok(hw)
}

/// Deterministic dense weights for non-OVSF layers (stem, downsamples,
/// classifier): these stream from off-chip in the paper's engine, so the
/// backend synthesises them one `P×cols` slab (columns `[c0, c1)`,
/// row-major `out[p·cols + (o−c0)]`) at a time into caller scratch.
/// Per-column seeding makes the values independent of the slab partition;
/// `1/√P` fan-in scaling matches the OVSF synthesis.
///
/// Deliberately *not* routed through the slab cache: the cache (and its
/// byte budget / acceptance metric) models on-chip *generated* weights,
/// while this synthesis stands in for the DRAM stream. Re-synthesis costs
/// O(P·cols) draws per pass against the layer's O(R·P·cols) MACs — well
/// under 1% of network latency.
pub fn synth_dense_slab(
    model: &str,
    idx: usize,
    layer: &Layer,
    c0: usize,
    c1: usize,
    out: &mut Vec<f32>,
) {
    let p_dim = (layer.n_in * layer.k * layer.k) as usize;
    let cols = c1 - c0;
    out.clear();
    out.resize(p_dim * cols, 0.0);
    let seed = layer_seed(model, idx, layer);
    let scale = 1.0 / (p_dim.max(1) as f32).sqrt();
    for (oi, o) in (c0..c1).enumerate() {
        let mut rng =
            Xoshiro256::seed_from_u64(seed ^ (o as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F));
        for p in 0..p_dim {
            out[p * cols + oi] = rng.next_normal() as f32 * scale;
        }
    }
}

/// Deterministically refit an NHWC activation tensor from one geometry to
/// another. The workload's layer lists fold pooling, elementwise and
/// residual wiring away (only compute layers are scheduled), so
/// consecutive entries need not chain exactly: spatial reductions
/// box-average (the folded max/global pool — e.g. the ResNet stem's
/// 112→56 pool and the global pool before the classifier), spatial
/// expansions replicate, and channel mismatches average (fold) or tile
/// (broadcast) channel groups. Integer box ranges make the common pool
/// factors exact.
pub fn refit_activations(
    src: &[f32],
    from: (usize, usize, usize),
    to: (usize, usize, usize),
) -> Vec<f32> {
    let (h0, w0, c_from) = from;
    let (h1, w1, c_to) = to;
    assert_eq!(src.len(), h0 * w0 * c_from, "source shape mismatch");
    let mut out = vec![0.0f32; h1 * w1 * c_to];
    for y in 0..h1 {
        let ys = y * h0 / h1;
        let ye = ((y + 1) * h0).div_ceil(h1).max(ys + 1).min(h0);
        for x in 0..w1 {
            let xs = x * w0 / w1;
            let xe = ((x + 1) * w0).div_ceil(w1).max(xs + 1).min(w0);
            for c in 0..c_to {
                let mut acc = 0.0f32;
                let mut n = 0u32;
                let mut tap = |cs: usize| {
                    for yy in ys..ye {
                        for xx in xs..xe {
                            acc += src[(yy * w0 + xx) * c_from + cs];
                            n += 1;
                        }
                    }
                };
                if c_from >= c_to {
                    // Fold: average the source channels ≡ c (mod c_to).
                    for cs in (c..c_from).step_by(c_to) {
                        tap(cs);
                    }
                } else {
                    // Broadcast: tile the source channels.
                    tap(c % c_from);
                }
                out[(y * w1 + x) * c_to + c] = acc / n as f32;
            }
        }
    }
    out
}

/// Backend over [`LayerSim`]: deterministic cycle counters per layer, plus
/// the tile-streamed numeric datapath for non-empty inputs.
pub struct SimBackend {
    plan: Option<Arc<EnginePlan>>,
    executed: Vec<LayerCost>,
    cache: Arc<SlabCache>,
    /// Input-selective PE schedule (paper §4.3). On by default. Numerics
    /// are schedule-invariant — only cycle counts change.
    pub selective: bool,
    /// Per-layer compressed OVSF weights (α's): the resident model state,
    /// O(ρ·model) bytes. Dense OVSF weights only ever exist as cached
    /// slabs.
    hw: Vec<Option<Arc<HwOvsfWeights>>>,
    /// Scratch: one lowered `T_R×P` activation row-strip.
    act: Vec<f32>,
    /// Scratch: one streamed dense (non-OVSF) weight slab.
    slab_scratch: Vec<f32>,
    /// NHWC shape of the most recently produced activations (the next
    /// layer's incoming shape for refitting).
    cur_shape: Option<(usize, usize, usize)>,
}

impl Default for SimBackend {
    fn default() -> Self {
        Self {
            plan: None,
            executed: Vec::new(),
            cache: Arc::new(SlabCache::new()),
            selective: true,
            hw: Vec::new(),
            act: Vec::new(),
            slab_scratch: Vec::new(),
            cur_shape: None,
        }
    }
}

impl SimBackend {
    /// New backend with a private slab cache (default budget).
    pub fn new() -> Self {
        Self::default()
    }

    /// New backend over a shared slab cache (one cache across all pool
    /// workers ⇒ a hot slab is generated once per process, and the byte
    /// budget bounds the whole pool's resident generated weights).
    pub fn with_cache(cache: Arc<SlabCache>) -> Self {
        Self {
            cache,
            ..Self::default()
        }
    }

    /// The slab cache this backend generates through.
    pub fn cache(&self) -> &Arc<SlabCache> {
        &self.cache
    }

    fn planned(&self) -> Result<&Arc<EnginePlan>> {
        self.plan
            .as_ref()
            .ok_or_else(|| Error::InvalidConfig("backend used before plan()".into()))
    }

    /// Fetch (or generate) the weight slab for column tile `ct` of OVSF
    /// layer `idx` through the bounded cache.
    fn ovsf_slab(
        &mut self,
        plan: &EnginePlan,
        idx: usize,
        ct: usize,
        c0: usize,
        c1: usize,
    ) -> Result<Arc<Vec<f32>>> {
        let layer = &plan.network.layers[idx];
        let rho = plan.profile.rho(idx);
        if self.hw[idx].is_none() {
            let hw = synth_hw_weights(&plan.network.name, idx, layer, rho)?;
            self.hw[idx] = Some(Arc::new(hw));
        }
        let hw = Arc::clone(self.hw[idx].as_ref().expect("just populated"));
        let key = SlabKey {
            layer: WeightsKey::new(
                plan.network.name.clone(),
                idx,
                (layer.n_in, layer.n_out, layer.k),
                plan.sigma,
                rho,
            ),
            col_tile: ct as u32,
        };
        self.cache.try_get_or_generate(key, || {
            let mut scratch = Vec::new();
            let mut slab = Vec::new();
            hw.slab_into(c0, c1, &mut scratch, &mut slab)?;
            Ok(slab)
        })
    }

    /// The numeric datapath for one layer: refit/validate the incoming
    /// activations, lower them to the GEMM view, stream `(row strip ×
    /// weight slab)` pairs through the PE array, and return the output
    /// activations plus their NHWC shape.
    fn forward_layer(
        &mut self,
        plan: &Arc<EnginePlan>,
        idx: usize,
        input: &[f32],
    ) -> Result<(Vec<f32>, (usize, usize, usize))> {
        let layer = &plan.network.layers[idx];
        let to = (layer.h as usize, layer.w as usize, layer.n_in as usize);
        let expect = to.0 * to.1 * to.2;
        let refitted;
        let x: &[f32] = match self.cur_shape {
            // Mid-request the recorded incoming shape is authoritative — a
            // coincidental length match (e.g. 4·4·16 arriving at an
            // 8·8·4 layer) must not silently bypass the refit and consume
            // the tensor under a scrambled layout.
            Some(from) => {
                if from.0 * from.1 * from.2 != input.len() {
                    return Err(Error::ShapeMismatch(format!(
                        "incoming activations ({} values) do not match their \
                         recorded shape {from:?}",
                        input.len()
                    )));
                }
                if from == to {
                    input
                } else {
                    refitted = refit_activations(input, from, to);
                    &refitted
                }
            }
            // First layer of a request (or a direct driver): the input
            // must be exactly this layer's geometry.
            None => {
                if input.len() != expect {
                    return Err(Error::ShapeMismatch(format!(
                        "layer '{}' expects {expect} input activations, got {} \
                         with no known incoming shape",
                        layer.name,
                        input.len()
                    )));
                }
                input
            }
        };
        let g = layer.gemm();
        let (r, p, c) = (g.r as usize, g.p as usize, g.c as usize);
        let t_r = plan.sigma.t_r as usize;
        let t_c = plan.sigma.t_c as usize;
        // OVSF layers always compute with their OVSF-reconstructed weights:
        // σ only decides whether generation runs on the fly or the same
        // weights stream from off-chip (a timing-side distinction, handled
        // in `execute_layer`) — the numerics are design-point-invariant.
        let ovsf = layer.ovsf;
        let pe = PeArraySim::new(&plan.sigma, self.selective);
        let mut out = vec![0.0f32; r * c];
        for (ct, c0) in (0..c).step_by(t_c).enumerate() {
            let c1 = (c0 + t_c).min(c);
            // Column-tile-outer order: each slab is materialised once per
            // layer pass and every row strip consumes it before the next
            // slab is generated — the cache never needs more than the live
            // working set.
            let slab_arc;
            let slab: &[f32] = if ovsf {
                slab_arc = self.ovsf_slab(plan, idx, ct, c0, c1)?;
                &slab_arc[..]
            } else {
                synth_dense_slab(&plan.network.name, idx, layer, c0, c1, &mut self.slab_scratch);
                &self.slab_scratch
            };
            for r0 in (0..r).step_by(t_r) {
                let r1 = (r0 + t_r).min(r);
                // One activation row-strip at a time: the lowering scratch
                // stays T_R×P even for the largest layers. Re-lowering a
                // strip once per column tile costs ~1/T_C of the GEMM
                // work — the memory-for-recompute trade the slab path
                // already makes for weights.
                im2col_strip_into(layer, x, r0, r1, &mut self.act);
                pe.execute_strip(
                    &self.act,
                    slab,
                    r1 - r0,
                    p,
                    c1 - c0,
                    &mut out[r0 * c..r1 * c],
                    c,
                    c0,
                );
            }
        }
        Ok((out, (layer.out_h() as usize, layer.out_w() as usize, c)))
    }
}

impl ExecutionBackend for SimBackend {
    fn name(&self) -> &'static str {
        "simulator"
    }

    fn plan(&mut self, plan: &EnginePlan) -> Result<()> {
        self.hw = vec![None; plan.n_layers()];
        self.plan = Some(Arc::new(plan.clone()));
        self.executed.clear();
        self.cur_shape = None;
        Ok(())
    }

    fn execute_layer(&mut self, idx: usize, input: &[f32]) -> Result<LayerOutcome> {
        let plan = Arc::clone(self.planned()?);
        let layer = plan.network.layers.get(idx).ok_or_else(|| {
            Error::InvalidConfig(format!(
                "layer index {idx} out of range ({} layers)",
                plan.network.layers.len()
            ))
        })?;
        let mut sim = LayerSim::new(&plan.sigma, &plan.platform, plan.bw_mult);
        sim.selective = self.selective;
        let on_the_fly = layer.ovsf && plan.sigma.has_wgen();
        // Cycle count per Alg. 1 without materialising weights:
        // n_basis · subtiles · p_tiles (validated == WGenSim walk).
        let trace = if on_the_fly {
            let cycles = layer.basis_per_chunk(plan.profile.rho(idx))
                * plan.sigma.subtiles_per_tile()
                * ceil_div(layer.gemm().p, plan.sigma.t_p);
            sim.run_timing(layer, Some(cycles))
        } else {
            sim.run_timing(layer, None)
        };
        // Numeric datapath for non-empty inputs; an empty input is the
        // serving convention for a timing-only request, which never touches
        // the weights path at all.
        let output = if input.is_empty() {
            None
        } else {
            let (out, shape) = self.forward_layer(&plan, idx, input)?;
            self.cur_shape = Some(shape);
            Some(out)
        };
        let outcome = LayerOutcome {
            name: trace.name.clone(),
            cycles: trace.total_cycles as f64,
            bound: trace.bound,
            output,
        };
        self.executed.push(LayerCost {
            name: trace.name,
            cycles: trace.total_cycles as f64,
            bound: trace.bound,
        });
        Ok(outcome)
    }

    fn finish(&mut self) -> Result<ExecutionReport> {
        let clock_hz = self.planned()?.platform.clock_hz;
        let layers = std::mem::take(&mut self.executed);
        self.cur_shape = None;
        let total_cycles: f64 = layers.iter().map(|l| l.cycles).sum();
        Ok(ExecutionReport {
            backend: self.name(),
            layers,
            total_cycles,
            latency_s: total_cycles / clock_hz,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{DesignPoint, Platform};
    use crate::engine::Engine;
    use crate::workload::{resnet, Network, RatioProfile};

    fn test_plan() -> EnginePlan {
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf50(&net);
        Engine::builder()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(DesignPoint::new(64, 64, 16, 48))
            .network(net)
            .profile(profile)
            .plan()
            .unwrap()
    }

    /// A small network that exercises every numeric-path case: dense stem,
    /// OVSF layers (one with C < T_C for the work-stealing schedule, one
    /// strided), and a classifier fed through the folded global pool.
    fn tiny_net() -> Network {
        Network {
            name: "tiny".into(),
            layers: vec![
                Layer::conv("stem", 8, 8, 4, 8, 3, 1, 1, false),
                Layer::conv("block.conv1", 8, 8, 8, 8, 3, 1, 1, true),
                Layer::conv("block.conv2", 8, 8, 8, 16, 3, 2, 1, true),
                Layer::fc("fc", 16, 10),
            ],
        }
    }

    fn tiny_plan(sigma: DesignPoint) -> EnginePlan {
        let net = tiny_net();
        let profile = RatioProfile::uniform(&net, 0.5);
        Engine::builder()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(sigma)
            .network(net)
            .profile(profile)
            .plan()
            .unwrap()
    }

    fn tiny_input() -> Vec<f32> {
        let mut rng = Xoshiro256::seed_from_u64(99);
        rng.normal_vec(8 * 8 * 4)
    }

    fn run_numeric(backend: &mut SimBackend, plan: &EnginePlan, input: &[f32]) -> Vec<f32> {
        let mut cur = input.to_vec();
        for idx in 0..plan.n_layers() {
            cur = backend
                .execute_layer(idx, &cur)
                .unwrap()
                .output
                .expect("numeric path produces activations");
        }
        backend.finish().unwrap();
        cur
    }

    #[test]
    fn timing_only_requests_never_touch_the_weights_path() {
        let plan = test_plan();
        let mut backend = SimBackend::new();
        backend.plan(&plan).unwrap();
        for idx in 0..plan.n_layers() {
            let o = backend.execute_layer(idx, &[]).unwrap();
            assert!(o.output.is_none(), "empty input must stay timing-only");
        }
        backend.finish().unwrap();
        assert!(backend.cache().is_empty());
        assert_eq!(backend.cache().misses(), 0);
    }

    #[test]
    fn numeric_inference_is_deterministic_and_shaped() {
        let sigma = DesignPoint::new(8, 4, 8, 4);
        let plan = tiny_plan(sigma);
        let input = tiny_input();
        let mut backend = SimBackend::new();
        backend.plan(&plan).unwrap();
        let a = run_numeric(&mut backend, &plan, &input);
        assert_eq!(a.len(), 10, "classifier output");
        assert!(a.iter().all(|v| v.is_finite()));
        assert!(a.iter().any(|v| *v != 0.0));
        let b = run_numeric(&mut backend, &plan, &input);
        assert_eq!(a, b, "repeat requests are bit-identical");
    }

    #[test]
    fn slabs_generate_once_then_hit_when_the_budget_fits() {
        let sigma = DesignPoint::new(8, 4, 8, 4);
        let plan = tiny_plan(sigma);
        let input = tiny_input();
        let mut backend = SimBackend::new();
        backend.plan(&plan).unwrap();
        run_numeric(&mut backend, &plan, &input);
        // OVSF slabs: block.conv1 C=8 → 2 tiles at T_C=4; block.conv2
        // C=16 → 4 tiles.
        assert_eq!(backend.cache().misses(), 6);
        assert_eq!(backend.cache().evictions(), 0);
        let hits = backend.cache().hits();
        run_numeric(&mut backend, &plan, &input);
        assert_eq!(backend.cache().misses(), 6, "warm requests regenerate nothing");
        assert_eq!(backend.cache().hits(), hits + 6);
    }

    #[test]
    fn tight_budget_bounds_resident_bytes_without_changing_numerics() {
        let sigma = DesignPoint::new(8, 4, 8, 4);
        let plan = tiny_plan(sigma);
        let input = tiny_input();
        let mut reference = SimBackend::new();
        reference.plan(&plan).unwrap();
        let expect = run_numeric(&mut reference, &plan, &input);

        // Budget of exactly one largest slab: P×T_C×4 = 72·4·4.
        let budget = 72 * 4 * 4;
        let cache = Arc::new(SlabCache::with_budget(budget));
        let mut streamed = SimBackend::with_cache(Arc::clone(&cache));
        streamed.plan(&plan).unwrap();
        let got = run_numeric(&mut streamed, &plan, &input);
        assert_eq!(got, expect, "eviction must not change numerics");
        assert!(cache.peak_resident_bytes() <= budget);
        assert!(cache.evictions() > 0, "the tight budget must have evicted");
    }

    #[test]
    fn shared_cache_spans_backends_like_pool_workers() {
        let sigma = DesignPoint::new(8, 4, 8, 4);
        let plan = tiny_plan(sigma);
        let input = tiny_input();
        let cache = Arc::new(SlabCache::new());
        let mut a = SimBackend::with_cache(Arc::clone(&cache));
        let mut b = SimBackend::with_cache(Arc::clone(&cache));
        a.plan(&plan).unwrap();
        b.plan(&plan).unwrap();
        let out_a = run_numeric(&mut a, &plan, &input);
        let misses = cache.misses();
        let out_b = run_numeric(&mut b, &plan, &input);
        assert_eq!(cache.misses(), misses, "second worker reuses every slab");
        assert_eq!(cache.hits(), misses);
        assert_eq!(out_a, out_b, "workers agree on the numerics");
    }

    #[test]
    fn numerics_are_design_point_invariant() {
        // The model is its OVSF α's: a design point that disables on-chip
        // generation (M = 0 — weights stream from memory instead) must
        // produce the same activations as one that generates on the fly.
        // The builder refuses M = 0 for OVSF nets, so build the plan by
        // hand the way the builder would.
        let net = tiny_net();
        let profile = RatioProfile::uniform(&net, 0.5);
        let platform = Platform::z7045();
        let with_wgen = DesignPoint::new(8, 4, 8, 4);
        let without_wgen = DesignPoint::new(0, 4, 8, 4);
        let input = tiny_input();
        let mut outputs = Vec::new();
        for sigma in [with_wgen, without_wgen] {
            let schedule = crate::coordinator::scheduler::InferencePlan::build(
                &platform, 4, sigma, &net, &profile,
            );
            let plan = EnginePlan {
                platform: platform.clone(),
                bw_mult: 4,
                sigma,
                network: net.clone(),
                profile: profile.clone(),
                schedule,
            };
            let mut backend = SimBackend::new();
            backend.plan(&plan).unwrap();
            outputs.push(run_numeric(&mut backend, &plan, &input));
        }
        assert_eq!(
            outputs[0], outputs[1],
            "numerics must not depend on whether σ instantiates CNN-WGen"
        );
    }

    #[test]
    fn refit_pools_and_broadcasts_deterministically() {
        // 2×2×2 → 1×1×2: global average per channel.
        let src = vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0];
        let out = refit_activations(&src, (2, 2, 2), (1, 1, 2));
        assert_eq!(out, vec![2.5, 25.0]);
        // Channel fold 4 → 2 at 1×1: average channels {0,2} and {1,3}.
        let out = refit_activations(&[1.0, 2.0, 3.0, 4.0], (1, 1, 4), (1, 1, 2));
        assert_eq!(out, vec![2.0, 3.0]);
        // Upsample 1×1 → 2×2 replicates; channel broadcast 1 → 2 tiles.
        let out = refit_activations(&[7.0], (1, 1, 1), (2, 2, 2));
        assert_eq!(out, vec![7.0; 8]);
    }

    #[test]
    fn synthetic_weights_are_worker_independent() {
        let layer = Layer::conv("c", 8, 8, 8, 8, 3, 1, 1, true);
        let a = synth_hw_weights("net", 3, &layer, 0.5).unwrap();
        let b = synth_hw_weights("net", 3, &layer, 0.5).unwrap();
        assert_eq!(a.alphas, b.alphas);
        let c = synth_hw_weights("net", 4, &layer, 0.5).unwrap();
        assert_ne!(a.alphas, c.alphas, "layer index is part of the seed");
        // Dense slabs are partition-independent.
        let (mut s1, mut s2a, mut s2b) = (Vec::new(), Vec::new(), Vec::new());
        synth_dense_slab("net", 0, &layer, 0, 8, &mut s1);
        synth_dense_slab("net", 0, &layer, 0, 5, &mut s2a);
        synth_dense_slab("net", 0, &layer, 5, 8, &mut s2b);
        let p_dim = (layer.n_in * layer.k * layer.k) as usize;
        for p in 0..p_dim {
            for o in 0..8 {
                let whole = s1[p * 8 + o];
                let split = if o < 5 { s2a[p * 5 + o] } else { s2b[p * 3 + (o - 5)] };
                assert_eq!(whole, split, "p={p} o={o}");
            }
        }
    }
}
