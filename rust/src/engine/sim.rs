//! [`SimBackend`] — executes the plan on the cycle-level simulator
//! ([`LayerSim`] walking the tile schedule, with the OVSF generator's
//! Alg. 1 cycle counts for on-the-fly layers) and realises each OVSF
//! layer's numeric weights through the engine-level
//! [`WeightsCache`](crate::engine::wcache::WeightsCache): the dense GEMM
//! weights a layer's α's reconstruct to are generated at most once per
//! `(model, layer, σ, ρ)` and shared across requests (and, via
//! [`EngineBuilder::build_pool`](crate::engine::EngineBuilder::build_pool),
//! across pool workers).

use std::sync::Arc;

use crate::engine::backend::{
    EnginePlan, ExecutionBackend, ExecutionReport, LayerCost, LayerOutcome,
};
use crate::engine::wcache::{WeightsCache, WeightsKey};
use crate::error::{Error, Result};
use crate::sim::engine::LayerSim;
use crate::sim::hw_weights::HwOvsfWeights;
use crate::util::ceil_div;
use crate::util::prng::Xoshiro256;
use crate::workload::layer::Layer;

/// Backend over [`LayerSim`]: each layer's tile schedule is walked with
/// deterministic cycle counters at `execute_layer` time; OVSF layers
/// additionally materialise their generated weights through the cache.
#[derive(Default)]
pub struct SimBackend {
    plan: Option<EnginePlan>,
    executed: Vec<LayerCost>,
    cache: Arc<WeightsCache>,
    /// Per-layer handle onto the cached generated weights (engine `P×C`
    /// GEMM layout), populated lazily on first walk of each OVSF layer.
    generated: Vec<Option<Arc<Vec<f32>>>>,
}

impl SimBackend {
    /// New backend with a private weights cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// New backend over a shared weights cache (one cache across all pool
    /// workers ⇒ each layer's weights are reconstructed once per process).
    pub fn with_cache(cache: Arc<WeightsCache>) -> Self {
        Self {
            cache,
            ..Self::default()
        }
    }

    /// The weights cache this backend generates through.
    pub fn cache(&self) -> &Arc<WeightsCache> {
        &self.cache
    }

    /// Generated weights of layer `idx` (engine `P×C` layout), if the
    /// layer is OVSF and has been executed at least once.
    pub fn generated_weights(&self, idx: usize) -> Option<Arc<Vec<f32>>> {
        self.generated.get(idx).and_then(|w| w.clone())
    }

    fn planned(&self) -> Result<&EnginePlan> {
        self.plan
            .as_ref()
            .ok_or_else(|| Error::InvalidConfig("backend used before plan()".into()))
    }

    /// Deterministic α's for a layer (the repro has no trained ImageNet
    /// checkpoints; every worker must agree on the synthetic weights so the
    /// cache is coherent) reconstructed to dense GEMM weights through the
    /// matrix-free OVSF path.
    fn reconstruct_layer(model: &str, idx: usize, layer: &Layer, rho: f64) -> Vec<f32> {
        let mut seed: u64 = 0xcbf2_9ce4_8422_2325;
        for b in model.bytes().chain(layer.name.bytes()) {
            seed ^= b as u64;
            seed = seed.wrapping_mul(0x1000_0000_01b3);
        }
        seed ^= (idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let hw = HwOvsfWeights::random(
            &mut rng,
            layer.n_out as usize,
            layer.n_in as usize,
            layer.k as usize,
            rho,
        )
        .expect("layer geometry validated at plan time");
        hw.dense_gemm()
            .expect("chunk geometry validated at plan time")
    }
}

impl ExecutionBackend for SimBackend {
    fn name(&self) -> &'static str {
        "simulator"
    }

    fn plan(&mut self, plan: &EnginePlan) -> Result<()> {
        self.generated = vec![None; plan.n_layers()];
        self.plan = Some(plan.clone());
        self.executed.clear();
        Ok(())
    }

    fn execute_layer(&mut self, idx: usize, _input: &[f32]) -> Result<LayerOutcome> {
        let plan = self.planned()?;
        let layer = plan.network.layers.get(idx).ok_or_else(|| {
            Error::InvalidConfig(format!(
                "layer index {idx} out of range ({} layers)",
                plan.network.layers.len()
            ))
        })?;
        let sim = LayerSim::new(&plan.sigma, &plan.platform, plan.bw_mult);
        let on_the_fly = layer.ovsf && plan.sigma.has_wgen();
        // Cycle count per Alg. 1 without materialising weights:
        // n_basis · subtiles · p_tiles (validated == WGenSim walk).
        let trace = if on_the_fly {
            let cycles = layer.basis_per_chunk(plan.profile.rho(idx))
                * plan.sigma.subtiles_per_tile()
                * ceil_div(layer.gemm().p, plan.sigma.t_p);
            sim.run_timing(layer, Some(cycles))
        } else {
            sim.run_timing(layer, None)
        };
        // Realise the generated weights through the cache: at most one
        // reconstruction per (model, layer, σ, ρ) across every request —
        // and every worker, when the cache is shared. Once this backend
        // holds the Arc, repeat requests are lock- and allocation-free.
        let weights = if on_the_fly && self.generated[idx].is_none() {
            let rho = plan.profile.rho(idx);
            let shape = (layer.n_in, layer.n_out, layer.k);
            let key = WeightsKey::new(plan.network.name.clone(), idx, shape, plan.sigma, rho);
            let model = &plan.network.name;
            Some(
                self.cache
                    .get_or_generate(key, || Self::reconstruct_layer(model, idx, layer, rho)),
            )
        } else {
            None
        };
        let outcome = LayerOutcome {
            name: trace.name.clone(),
            cycles: trace.total_cycles as f64,
            bound: trace.bound,
            output: None,
        };
        if let Some(w) = weights {
            self.generated[idx] = Some(w);
        }
        self.executed.push(LayerCost {
            name: trace.name,
            cycles: trace.total_cycles as f64,
            bound: trace.bound,
        });
        Ok(outcome)
    }

    fn finish(&mut self) -> Result<ExecutionReport> {
        let clock_hz = self.planned()?.platform.clock_hz;
        let layers = std::mem::take(&mut self.executed);
        let total_cycles: f64 = layers.iter().map(|l| l.cycles).sum();
        Ok(ExecutionReport {
            backend: self.name(),
            layers,
            total_cycles,
            latency_s: total_cycles / clock_hz,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{DesignPoint, Platform};
    use crate::engine::Engine;
    use crate::workload::{resnet, RatioProfile};

    fn test_plan() -> EnginePlan {
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf50(&net);
        Engine::builder()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(DesignPoint::new(64, 64, 16, 48))
            .network(net)
            .profile(profile)
            .plan()
            .unwrap()
    }

    fn run_all_layers(backend: &mut SimBackend, plan: &EnginePlan) {
        for idx in 0..plan.n_layers() {
            backend.execute_layer(idx, &[]).unwrap();
        }
        backend.finish().unwrap();
    }

    #[test]
    fn reconstructs_each_layer_at_most_once_across_requests() {
        let plan = test_plan();
        let n_ovsf = plan.network.layers.iter().filter(|l| l.ovsf).count() as u64;
        assert!(n_ovsf > 0);
        let mut backend = SimBackend::new();
        backend.plan(&plan).unwrap();
        run_all_layers(&mut backend, &plan);
        assert_eq!(backend.cache().misses(), n_ovsf, "first request generates");
        assert_eq!(backend.cache().hits(), 0);
        for _ in 0..3 {
            run_all_layers(&mut backend, &plan);
        }
        assert_eq!(
            backend.cache().misses(),
            n_ovsf,
            "repeat requests must not regenerate"
        );
        // Warm requests short-circuit on the backend's own Arc — they never
        // even touch the shared cache lock.
        assert_eq!(backend.cache().hits(), 0);
    }

    #[test]
    fn generated_weights_have_gemm_shape_and_dense_layers_none() {
        let plan = test_plan();
        let mut backend = SimBackend::new();
        backend.plan(&plan).unwrap();
        run_all_layers(&mut backend, &plan);
        for (idx, layer) in plan.network.layers.iter().enumerate() {
            match backend.generated_weights(idx) {
                Some(w) => {
                    assert!(layer.ovsf);
                    let g = layer.gemm();
                    assert_eq!(w.len() as u64, g.p * g.c, "layer {}", layer.name);
                }
                None => assert!(!layer.ovsf, "OVSF layer {} not generated", layer.name),
            }
        }
        assert!(backend.cache().resident_bytes() > 0);
    }

    #[test]
    fn shared_cache_spans_backends_like_pool_workers() {
        let plan = test_plan();
        let n_ovsf = plan.network.layers.iter().filter(|l| l.ovsf).count() as u64;
        let cache = Arc::new(WeightsCache::new());
        let mut a = SimBackend::with_cache(Arc::clone(&cache));
        let mut b = SimBackend::with_cache(Arc::clone(&cache));
        a.plan(&plan).unwrap();
        b.plan(&plan).unwrap();
        run_all_layers(&mut a, &plan);
        run_all_layers(&mut b, &plan);
        assert_eq!(cache.misses(), n_ovsf, "second worker reuses the cache");
        assert_eq!(cache.hits(), n_ovsf);
        // Both workers see identical weights (deterministic synthesis).
        for idx in 0..plan.n_layers() {
            match (a.generated_weights(idx), b.generated_weights(idx)) {
                (Some(x), Some(y)) => assert!(Arc::ptr_eq(&x, &y)),
                (None, None) => {}
                _ => panic!("workers disagree on layer {idx}"),
            }
        }
    }
}
