//! [`SimBackend`] — executes the plan on the cycle-level simulator *and*
//! computes real activations through the PE array with weights generated
//! on the fly, tile by tile.
//!
//! Timing: [`LayerSim`] walks each layer's tile schedule (with the OVSF
//! generator's Alg. 1 cycle counts for on-the-fly layers), exactly as
//! before.
//!
//! Numerics — the **pipelined slab-prefetch datapath** (the software
//! analogue of the paper's weights generator running concurrently with the
//! compute engine): a persistent background worker generates weight slab
//! `ct+1` — OVSF slabs through the shared bounded
//! [`SlabCache`](crate::engine::wcache::SlabCache), dense (stem /
//! downsample / classifier) slabs into fresh scratch — while the compute
//! stage multiplies slab `ct` across every activation row strip
//! ([`im2col_strip_into`] + the register-blocked
//! [`PeArraySim::execute_strip`], row strips sharded over the process
//! [`ThreadPool`]). Double buffering holds exactly one slab in flight
//! beyond the cache budget, generation is deterministic, and the compute
//! order is the serial schedule's — so the pipelined path is **bit
//! identical** to the serial one (`pipelined = false`), which survives as
//! the comparison baseline. Per-layer overlap telemetry (`gen_ns`,
//! `compute_ns`, `hidden_ns`) is surfaced through
//! [`LayerOutcome`]/[`ExecutionReport`].
//!
//! Batched execution ([`execute_layer_batch`](ExecutionBackend::execute_layer_batch))
//! folds the batch dimension into GEMM rows: each generated slab is
//! multiplied against every image's row strips before the next slab
//! arrives, so a [`ServerPool`](crate::coordinator::pool::ServerPool)
//! batch amortises each slab across the whole batch.
//!
//! An empty input keeps a request timing-only — the serving convention of
//! [`Request`](crate::coordinator::server::Request).

use std::borrow::Cow;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::engine::backend::{
    EnginePlan, ExecutionBackend, ExecutionReport, LayerCost, LayerOutcome, OverlapTelemetry,
};
use crate::engine::wcache::{Slab, SlabCache, SlabKey, WeightsKey};
use crate::error::{Error, Result};
use crate::sim::engine::LayerSim;
use crate::sim::hw_weights::HwOvsfWeights;
use crate::sim::im2col::im2col_strip_into;
use crate::sim::pe_array::PeArraySim;
use crate::sim::trace::LayerTrace;
use crate::util::ceil_div;
use crate::util::fixed::Precision;
use crate::util::prng::Xoshiro256;
use crate::util::threadpool::{ScopedTask, ThreadPool};
use crate::workload::layer::Layer;

/// Deterministic per-layer seed: the repro has no trained ImageNet
/// checkpoints, so every worker must agree on the synthetic weights for
/// the shared slab cache to be coherent. Public so
/// [`CompiledModel`](crate::engine::compile::CompiledModel) can carry the
/// seed namespace as part of the artifact.
pub fn layer_seed(model: &str, idx: usize, layer: &Layer) -> u64 {
    let mut seed: u64 = 0xcbf2_9ce4_8422_2325;
    for b in model.bytes().chain(layer.name.bytes()) {
        seed ^= b as u64;
        seed = seed.wrapping_mul(0x1000_0000_01b3);
    }
    seed ^ (idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Deterministic compressed OVSF weights (α's) for a layer — the resident
/// model state the slab generator reads. He-style fan-in scaling is the
/// synthetic checkpoint's folded normalisation: with unit-normal α's a
/// generated weight sums `n_basis` signed α's and a layer output sums `P`
/// weighted activations, so `1/√(P·n_basis)` keeps activation magnitudes
/// O(1) through an arbitrarily deep chain.
pub fn synth_hw_weights(model: &str, idx: usize, layer: &Layer, rho: f64) -> Result<HwOvsfWeights> {
    let mut rng = Xoshiro256::seed_from_u64(layer_seed(model, idx, layer));
    let mut hw = HwOvsfWeights::random(
        &mut rng,
        layer.n_out as usize,
        layer.n_in as usize,
        layer.k as usize,
        rho,
    )?;
    let scale = 1.0 / ((hw.p_dim() * hw.n_basis).max(1) as f32).sqrt();
    for a in &mut hw.alphas {
        *a *= scale;
    }
    Ok(hw)
}

/// Deterministic dense weights for non-OVSF layers (stem, downsamples,
/// classifier): these stream from off-chip in the paper's engine, so the
/// backend synthesises them one `P×cols` slab (columns `[c0, c1)`,
/// row-major `out[p·cols + (o−c0)]`) at a time into caller scratch.
/// Per-column seeding makes the values independent of the slab partition;
/// `1/√P` fan-in scaling matches the OVSF synthesis.
///
/// Deliberately *not* routed through the slab cache: the cache (and its
/// byte budget / acceptance metric) models on-chip *generated* weights,
/// while this synthesis stands in for the DRAM stream. Re-synthesis costs
/// O(P·cols) draws per pass against the layer's O(R·P·cols) MACs — well
/// under 1% of network latency.
pub fn synth_dense_slab(
    model: &str,
    idx: usize,
    layer: &Layer,
    c0: usize,
    c1: usize,
    out: &mut Vec<f32>,
) {
    synth_dense_slab_seeded(layer_seed(model, idx, layer), layer, c0, c1, out)
}

/// [`synth_dense_slab`] with an explicit layer seed instead of a
/// `(model, idx)` pair. Stage artifacts from
/// [`Compiler::split`](crate::engine::compile::Compiler::split) carry
/// seeds derived in the *original* model's namespace at absolute layer
/// indices; feeding those seeds here makes a stage's dense layers
/// bit-identical to the unsplit model's.
pub fn synth_dense_slab_seeded(seed: u64, layer: &Layer, c0: usize, c1: usize, out: &mut Vec<f32>) {
    let p_dim = (layer.n_in * layer.k * layer.k) as usize;
    let cols = c1 - c0;
    out.clear();
    out.resize(p_dim * cols, 0.0);
    let scale = 1.0 / (p_dim.max(1) as f32).sqrt();
    for (oi, o) in (c0..c1).enumerate() {
        let mut rng =
            Xoshiro256::seed_from_u64(seed ^ (o as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F));
        for p in 0..p_dim {
            out[p * cols + oi] = rng.next_normal() as f32 * scale;
        }
    }
}

/// Deterministically refit an NHWC activation tensor from one geometry to
/// another. The workload's layer lists fold pooling, elementwise and
/// residual wiring away (only compute layers are scheduled), so
/// consecutive entries need not chain exactly: spatial reductions
/// box-average (the folded max/global pool — e.g. the ResNet stem's
/// 112→56 pool and the global pool before the classifier), spatial
/// expansions replicate, and channel mismatches average (fold) or tile
/// (broadcast) channel groups. Integer box ranges make the common pool
/// factors exact.
pub fn refit_activations(
    src: &[f32],
    from: (usize, usize, usize),
    to: (usize, usize, usize),
) -> Vec<f32> {
    let (h0, w0, c_from) = from;
    let (h1, w1, c_to) = to;
    assert_eq!(src.len(), h0 * w0 * c_from, "source shape mismatch");
    let mut out = vec![0.0f32; h1 * w1 * c_to];
    for y in 0..h1 {
        let ys = y * h0 / h1;
        let ye = ((y + 1) * h0).div_ceil(h1).max(ys + 1).min(h0);
        for x in 0..w1 {
            let xs = x * w0 / w1;
            let xe = ((x + 1) * w0).div_ceil(w1).max(xs + 1).min(w0);
            for c in 0..c_to {
                let mut acc = 0.0f32;
                let mut n = 0u32;
                let mut tap = |cs: usize| {
                    for yy in ys..ye {
                        for xx in xs..xe {
                            acc += src[(yy * w0 + xx) * c_from + cs];
                            n += 1;
                        }
                    }
                };
                if c_from >= c_to {
                    // Fold: average the source channels ≡ c (mod c_to).
                    for cs in (c..c_from).step_by(c_to) {
                        tap(cs);
                    }
                } else {
                    // Broadcast: tile the source channels.
                    tap(c % c_from);
                }
                out[(y * w1 + x) * c_to + c] = acc / n as f32;
            }
        }
    }
    out
}

/// One slab-generation job for the prefetch stage. Jobs are self-contained
/// (shared state travels as `Arc`s / clones) so the background worker needs
/// no access to the backend.
enum SlabJob {
    /// OVSF slab, routed through the shared bounded cache.
    Ovsf {
        cache: Arc<SlabCache>,
        key: SlabKey,
        hw: Arc<HwOvsfWeights>,
        c0: usize,
        c1: usize,
        /// Weight-datapath precision the slab is emitted at.
        precision: Precision,
        /// Per-layer symmetric i8 weight scale (only read at `I8`).
        w_scale: f32,
    },
    /// Dense (stem / downsample / classifier) slab, synthesised into fresh
    /// scratch — the DRAM stream stand-in, deliberately uncached. Carries
    /// the resolved layer seed (the artifact's for compiled/stage models,
    /// else derived from the plan's network name).
    Dense {
        seed: u64,
        layer: Layer,
        c0: usize,
        c1: usize,
    },
}

/// Run one generation job (shared by the prefetch worker and the serial
/// datapath, so both produce byte-identical slabs through identical code).
fn generate_slab(job: SlabJob) -> Result<Arc<Slab>> {
    match job {
        SlabJob::Ovsf {
            cache,
            key,
            hw,
            c0,
            c1,
            precision,
            w_scale,
        } => cache.try_get_or_generate(key, || {
            let mut scratch = Vec::new();
            match precision {
                Precision::F32 => {
                    let mut slab = Vec::new();
                    hw.slab_into(c0, c1, &mut scratch, &mut slab)?;
                    Ok(Slab::F32(slab))
                }
                // Quantise during reconstruction: the FWHT stays f32,
                // rounding happens exactly once at slab emission.
                Precision::I8 => {
                    let mut codes = Vec::new();
                    hw.slab_into_i8(c0, c1, w_scale, &mut scratch, &mut codes)?;
                    Ok(Slab::I8 {
                        codes,
                        scale: w_scale,
                    })
                }
            }
        }),
        SlabJob::Dense {
            seed,
            layer,
            c0,
            c1,
        } => {
            let mut slab = Vec::new();
            synth_dense_slab_seeded(seed, &layer, c0, c1, &mut slab);
            Ok(Arc::new(Slab::F32(slab)))
        }
    }
}

/// A generated slab (or the generation error) plus the worker-side
/// generation nanoseconds.
type PrefetchResult = (u64, Result<Arc<Slab>>);

/// The persistent background weights-generation worker — the software
/// CNN-WGen running concurrently with the PE array. One job is in flight
/// at a time (double buffering): the compute stage collects slab `ct`,
/// immediately requests `ct+1`, then multiplies — so generation of the
/// next slab hides behind compute of the current one.
struct Prefetcher {
    jobs: Option<mpsc::Sender<SlabJob>>,
    results: mpsc::Receiver<PrefetchResult>,
    handle: Option<JoinHandle<()>>,
}

impl Prefetcher {
    fn spawn() -> Result<Self> {
        let (jtx, jrx) = mpsc::channel::<SlabJob>();
        let (rtx, rrx) = mpsc::channel();
        let handle = std::thread::Builder::new()
            .name("slab-prefetch".into())
            .spawn(move || {
                while let Ok(job) = jrx.recv() {
                    let t0 = Instant::now();
                    let res = generate_slab(job);
                    let gen_ns = t0.elapsed().as_nanos() as u64;
                    if rtx.send((gen_ns, res)).is_err() {
                        break;
                    }
                }
            })
            .map_err(|e| {
                Error::Coordinator(format!("cannot spawn slab-prefetch worker: {e}"))
            })?;
        Ok(Self {
            jobs: Some(jtx),
            results: rrx,
            handle: Some(handle),
        })
    }

    fn request(&self, job: SlabJob) -> Result<()> {
        let Some(jobs) = self.jobs.as_ref() else {
            return Err(Error::Coordinator("slab-prefetch worker is gone".into()));
        };
        jobs.send(job)
            .map_err(|_| Error::Coordinator("slab-prefetch worker is gone".into()))
    }

    /// Wait for the oldest in-flight job: `(gen_ns, generated slab)`.
    fn collect(&self) -> Result<PrefetchResult> {
        self.results
            .recv()
            .map_err(|_| Error::Coordinator("slab-prefetch worker is gone".into()))
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Closing the job channel ends the worker loop; joining bounds the
        // teardown by at most one in-flight generation.
        self.jobs.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Below this many MACs per slab pass, the strip GEMM stays on the calling
/// thread — pool sharding would not amortise its task bookkeeping.
const DEFAULT_PAR_MIN_MACS: usize = 1 << 21;

/// Backend over [`LayerSim`]: deterministic cycle counters per layer, plus
/// the pipelined tile-streamed numeric datapath for non-empty inputs.
pub struct SimBackend {
    plan: Option<Arc<EnginePlan>>,
    executed: Vec<LayerCost>,
    cache: Arc<SlabCache>,
    /// Input-selective PE schedule (paper §4.3). On by default. Numerics
    /// are schedule-invariant — only cycle counts change.
    pub selective: bool,
    /// Overlap slab generation with PE compute on the background prefetch
    /// worker (on by default). `false` runs the serial
    /// generate-then-multiply schedule — numerics are bit-identical either
    /// way; only wall-clock (and `hidden_ns`) changes.
    pub pipelined: bool,
    /// Minimum MACs in one slab×strips pass before the row strips are
    /// sharded across the process thread pool (tunable for tests).
    pub par_min_macs: usize,
    /// Weight-datapath precision slabs are generated and consumed at.
    /// Adopted from the compiled artifact on
    /// [`preload`](ExecutionBackend::preload); `F32` by default. At `I8`
    /// the OVSF slabs are quantised at emission and the PE array runs the
    /// i8×i8→i32 microkernel; dense (stem / downsample / classifier)
    /// slabs stay f32 — they model the DRAM stream, not generated
    /// weights.
    pub precision: Precision,
    /// Per-layer symmetric i8 weight scales, derived lazily beside the α
    /// adoption (from the artifact's cached scales when one is preloaded).
    w_scales: Vec<Option<f32>>,
    /// Per-layer compressed OVSF weights (α's): the resident model state,
    /// O(ρ·model) bytes. Dense OVSF weights only ever exist as cached
    /// slabs.
    hw: Vec<Option<Arc<HwOvsfWeights>>>,
    /// The compiled artifact serving this plan, when one was preloaded:
    /// its per-artifact α sets are adopted on first numeric use (shared
    /// `Arc`s — fitted once per artifact across all workers and switches;
    /// timing-only traffic never triggers the fit).
    artifact: Option<Arc<crate::engine::compile::CompiledModel>>,
    /// Scratch: one lowered `T_R×P` activation row-strip (serial compute
    /// path; pool tasks own their scratch).
    act: Vec<f32>,
    /// NHWC shape of the most recently produced activations (the next
    /// layer's incoming shape for refitting).
    cur_shape: Option<(usize, usize, usize)>,
    /// Lazily spawned background generation worker.
    prefetcher: Option<Prefetcher>,
}

impl Default for SimBackend {
    fn default() -> Self {
        Self {
            plan: None,
            executed: Vec::new(),
            cache: Arc::new(SlabCache::new()),
            selective: true,
            pipelined: true,
            par_min_macs: DEFAULT_PAR_MIN_MACS,
            precision: Precision::F32,
            w_scales: Vec::new(),
            hw: Vec::new(),
            artifact: None,
            act: Vec::new(),
            cur_shape: None,
            prefetcher: None,
        }
    }
}

impl SimBackend {
    /// New backend with a private slab cache (default budget).
    pub fn new() -> Self {
        Self::default()
    }

    /// New backend over a shared slab cache (one cache across all pool
    /// workers ⇒ a hot slab is generated once per process, and the byte
    /// budget bounds the whole pool's resident generated weights).
    pub fn with_cache(cache: Arc<SlabCache>) -> Self {
        Self {
            cache,
            ..Self::default()
        }
    }

    /// The slab cache this backend generates through.
    pub fn cache(&self) -> &Arc<SlabCache> {
        &self.cache
    }

    fn planned(&self) -> Result<&Arc<EnginePlan>> {
        self.plan
            .as_ref()
            .ok_or_else(|| Error::InvalidConfig("backend used before plan()".into()))
    }

    /// Build the self-contained generation job for column tile `ct`
    /// (`[c0, c1)`) of layer `idx`: OVSF layers route through the shared
    /// bounded cache, non-OVSF layers synthesise dense slabs.
    ///
    /// OVSF layers always compute with their OVSF-reconstructed weights: σ
    /// only decides whether generation runs on the fly or the same weights
    /// stream from off-chip (a timing-side distinction, handled in
    /// `timing_trace`) — the numerics are design-point-invariant.
    fn slab_job(
        &mut self,
        plan: &EnginePlan,
        idx: usize,
        ct: usize,
        c0: usize,
        c1: usize,
    ) -> Result<SlabJob> {
        let layer = &plan.network.layers[idx];
        if layer.ovsf {
            let rho = plan.profile.rho(idx);
            if self.hw[idx].is_none() {
                // First numeric use: adopt the compiled artifact's α sets
                // (fitted once per artifact, shared across workers and
                // switches), else fit this layer's locally.
                if let Some(artifact) = &self.artifact {
                    self.hw = artifact.hw()?.to_vec();
                } else {
                    let hw = synth_hw_weights(&plan.network.name, idx, layer, rho)?;
                    self.hw[idx] = Some(Arc::new(hw));
                }
            }
            let hw = match self.hw[idx].as_ref() {
                Some(hw) => Arc::clone(hw),
                None => {
                    return Err(Error::Coordinator(format!(
                        "layer {idx} α state missing after fit"
                    )))
                }
            };
            let precision = self.precision;
            let w_scale = if precision == Precision::I8 {
                match self.w_scales[idx] {
                    Some(s) => s,
                    None => {
                        // Per-layer scale from the α sets (an upper bound on
                        // any reconstructed weight — never clips); the
                        // artifact caches the derivation across workers.
                        let s = match &self.artifact {
                            Some(artifact) => artifact.i8_scales()?[idx].ok_or_else(|| {
                                Error::Coordinator(format!(
                                    "layer {idx} has α state but no compiled i8 scale"
                                ))
                            })?,
                            None => hw.i8_scale(),
                        };
                        self.w_scales[idx] = Some(s);
                        s
                    }
                }
            } else {
                0.0
            };
            // Slab identities carry the artifact's registration generation
            // (0 for unregistered engines), so a batch outliving its
            // model's eviction re-inserts under the old generation and can
            // never alias a re-registered model's slabs.
            let key = SlabKey {
                layer: WeightsKey::new(
                    plan.network.name.clone(),
                    idx,
                    (layer.n_in, layer.n_out, layer.k),
                    plan.sigma,
                    rho,
                )
                .with_generation(self.artifact.as_ref().map_or(0, |a| a.generation()))
                .with_precision(precision),
                col_tile: ct as u32,
            };
            Ok(SlabJob::Ovsf {
                cache: Arc::clone(&self.cache),
                key,
                hw,
                c0,
                c1,
                precision,
                w_scale,
            })
        } else {
            // The artifact's seeds live in its (possibly original-model)
            // seed namespace; artifact-less engines derive from the plan.
            let seed = match &self.artifact {
                Some(artifact) => artifact.weight_seeds()[idx],
                None => layer_seed(&plan.network.name, idx, layer),
            };
            Ok(SlabJob::Dense {
                seed,
                layer: layer.clone(),
                c0,
                c1,
            })
        }
    }

    /// Refit/validate one incoming image against layer `idx`'s geometry
    /// (the per-image half of the old `forward_layer` preamble).
    fn prepare_image<'a>(
        &self,
        layer: &Layer,
        input: &'a [f32],
    ) -> Result<Cow<'a, [f32]>> {
        let to = (layer.h as usize, layer.w as usize, layer.n_in as usize);
        let expect = to.0 * to.1 * to.2;
        match self.cur_shape {
            // Mid-request the recorded incoming shape is authoritative — a
            // coincidental length match (e.g. 4·4·16 arriving at an
            // 8·8·4 layer) must not silently bypass the refit and consume
            // the tensor under a scrambled layout.
            Some(from) => {
                if from.0 * from.1 * from.2 != input.len() {
                    return Err(Error::ShapeMismatch(format!(
                        "incoming activations ({} values) do not match their \
                         recorded shape {from:?}",
                        input.len()
                    )));
                }
                if from == to {
                    Ok(Cow::Borrowed(input))
                } else {
                    Ok(Cow::Owned(refit_activations(input, from, to)))
                }
            }
            // First layer of a request (or a direct driver): the input
            // must be exactly this layer's geometry.
            None => {
                if input.len() != expect {
                    return Err(Error::ShapeMismatch(format!(
                        "layer '{}' expects {expect} input activations, got {} \
                         with no known incoming shape",
                        layer.name,
                        input.len()
                    )));
                }
                Ok(Cow::Borrowed(input))
            }
        }
    }

    /// Multiply one generated slab against every image's row strips —
    /// the compute stage of the pipeline. Dispatches on the slab's
    /// precision: f32 slabs run the 4×8 f32 microkernel, i8 slabs the
    /// widened i8×i8→i32 one (activations quantised per strip inside
    /// [`PeArraySim::execute_strip_i8`] — a pure function of the strip, so
    /// every schedule sees identical codes). Large passes shard `(image,
    /// strip)` work items across the process [`ThreadPool`]; small ones
    /// stay on the calling thread with reused lowering scratch. Either way
    /// each output element is produced by exactly one strip pass in the
    /// serial schedule's accumulation order, so the numerics are
    /// bit-identical across all execution modes at either precision.
    #[allow(clippy::too_many_arguments)]
    fn compute_slab(
        pe: &PeArraySim,
        layer: &Layer,
        images: &[Cow<'_, [f32]>],
        outs: &mut [Vec<f32>],
        slab: &Slab,
        dims: (usize, usize, usize),
        t_r: usize,
        c0: usize,
        c1: usize,
        par_min_macs: usize,
        act_scratch: &mut Vec<f32>,
    ) {
        let (r, p, c) = dims;
        let strips = r.div_ceil(t_r);
        let macs = r * p * (c1 - c0) * images.len();
        let strip_pass = |act: &[f32], rows: usize, chunk: &mut [f32]| match slab {
            Slab::F32(data) => {
                pe.execute_strip(act, data, rows, p, c1 - c0, chunk, c, c0);
            }
            Slab::I8 { codes, scale } => {
                pe.execute_strip_i8(act, codes, *scale, rows, p, c1 - c0, chunk, c, c0);
            }
        };
        if macs < par_min_macs || strips * images.len() <= 1 {
            for (x, out) in images.iter().zip(outs.iter_mut()) {
                for r0 in (0..r).step_by(t_r) {
                    let r1 = (r0 + t_r).min(r);
                    // One activation row-strip at a time: the lowering
                    // scratch stays T_R×P even for the largest layers.
                    // Re-lowering a strip once per column tile costs ~1/T_C
                    // of the GEMM work — the memory-for-recompute trade the
                    // slab path already makes for weights.
                    im2col_strip_into(layer, x, r0, r1, act_scratch);
                    strip_pass(act_scratch, r1 - r0, &mut out[r0 * c..r1 * c]);
                }
            }
            return;
        }
        let mut tasks: Vec<ScopedTask<'_>> = Vec::with_capacity(strips * images.len());
        for (x, out) in images.iter().zip(outs.iter_mut()) {
            let x: &[f32] = &x[..];
            for (si, chunk) in out.chunks_mut(t_r * c).enumerate() {
                let r0 = si * t_r;
                let r1 = (r0 + t_r).min(r);
                tasks.push(Box::new(move || {
                    let mut act = Vec::new();
                    im2col_strip_into(layer, x, r0, r1, &mut act);
                    match slab {
                        Slab::F32(data) => {
                            pe.execute_strip(&act, data, r1 - r0, p, c1 - c0, chunk, c, c0);
                        }
                        Slab::I8 { codes, scale } => {
                            pe.execute_strip_i8(
                                &act,
                                codes,
                                *scale,
                                r1 - r0,
                                p,
                                c1 - c0,
                                chunk,
                                c,
                                c0,
                            );
                        }
                    }
                }));
            }
        }
        ThreadPool::global().scope_run(tasks);
    }

    /// The numeric datapath for one layer over a batch of images:
    /// refit/validate each image, then stream the layer's weight slabs —
    /// prefetched on the background worker while the PE compute stage
    /// multiplies (double-buffered), or generated inline on the serial
    /// schedule when [`pipelined`](Self::pipelined) is off. Each slab is
    /// multiplied against **every** image's row strips before the next
    /// slab is consumed, folding the batch dimension into GEMM rows.
    /// Returns the per-image outputs, their common NHWC shape, and the
    /// layer's overlap telemetry.
    fn forward_layer_batch(
        &mut self,
        plan: &Arc<EnginePlan>,
        idx: usize,
        inputs: &[&[f32]],
    ) -> Result<(Vec<Vec<f32>>, (usize, usize, usize), OverlapTelemetry)> {
        let layer = &plan.network.layers[idx];
        let mut images: Vec<Cow<'_, [f32]>> = Vec::with_capacity(inputs.len());
        for &input in inputs {
            images.push(self.prepare_image(layer, input)?);
        }
        let g = layer.gemm();
        let (r, p, c) = (g.r as usize, g.p as usize, g.c as usize);
        let t_r = plan.sigma.t_r as usize;
        let t_c = plan.sigma.t_c as usize;
        let pe = PeArraySim::new(&plan.sigma, self.selective);
        let mut outs: Vec<Vec<f32>> = images.iter().map(|_| vec![0.0f32; r * c]).collect();
        let n_tiles = c.div_ceil(t_c);
        let out_shape = (layer.out_h() as usize, layer.out_w() as usize, c);
        let mut tel = OverlapTelemetry::default();

        if !self.pipelined {
            // Serial reference schedule: generate, then multiply — nothing
            // ever hidden.
            for ct in 0..n_tiles {
                let c0 = ct * t_c;
                let c1 = (c0 + t_c).min(c);
                let job = self.slab_job(plan, idx, ct, c0, c1)?;
                let t0 = Instant::now();
                let slab = generate_slab(job)?;
                tel.gen_ns += t0.elapsed().as_nanos() as u64;
                let t0 = Instant::now();
                Self::compute_slab(
                    &pe,
                    layer,
                    &images,
                    &mut outs,
                    &slab,
                    (r, p, c),
                    t_r,
                    c0,
                    c1,
                    self.par_min_macs,
                    &mut self.act,
                );
                tel.compute_ns += t0.elapsed().as_nanos() as u64;
            }
            return Ok((outs, out_shape, tel));
        }

        // Pipelined schedule: the prefetch worker generates slab ct+1 while
        // the compute stage multiplies slab ct — double-buffered, so
        // exactly one slab is in flight beyond the cache budget (the
        // compute stage additionally pins the one slab it is streaming
        // through its Arc). On any error the Prefetcher is dropped, which
        // joins the worker and discards in-flight state — the next request
        // spawns a fresh one.
        let mut stall_ns = 0u64;
        let pf = match self.prefetcher.take() {
            Some(pf) => pf,
            None => Prefetcher::spawn()?,
        };
        let first = self.slab_job(plan, idx, 0, 0, t_c.min(c))?;
        pf.request(first)?;
        for ct in 0..n_tiles {
            let c0 = ct * t_c;
            let c1 = (c0 + t_c).min(c);
            let wait0 = Instant::now();
            let (gen_ns, generated) = pf.collect()?;
            stall_ns += wait0.elapsed().as_nanos() as u64;
            tel.gen_ns += gen_ns;
            let slab = generated?;
            if ct + 1 < n_tiles {
                let c0n = (ct + 1) * t_c;
                let c1n = (c0n + t_c).min(c);
                let job = self.slab_job(plan, idx, ct + 1, c0n, c1n)?;
                pf.request(job)?;
            }
            let t0 = Instant::now();
            Self::compute_slab(
                &pe,
                layer,
                &images,
                &mut outs,
                &slab,
                (r, p, c),
                t_r,
                c0,
                c1,
                self.par_min_macs,
                &mut self.act,
            );
            tel.compute_ns += t0.elapsed().as_nanos() as u64;
        }
        tel.hidden_ns = tel.gen_ns.saturating_sub(stall_ns);
        self.prefetcher = Some(pf);
        Ok((outs, out_shape, tel))
    }

    /// Cycle-level timing walk for one layer: Alg. 1's per-tile generation
    /// cycle count for on-the-fly OVSF layers, off-chip weight streaming
    /// otherwise.
    fn timing_trace(&self, plan: &EnginePlan, idx: usize, layer: &Layer) -> LayerTrace {
        let mut sim = LayerSim::new(&plan.sigma, &plan.platform, plan.bw_mult);
        sim.selective = self.selective;
        if layer.ovsf && plan.sigma.has_wgen() {
            // Cycle count per Alg. 1 without materialising weights:
            // n_basis · subtiles · p_tiles (validated == WGenSim walk).
            let cycles = layer.basis_per_chunk(plan.profile.rho(idx))
                * plan.sigma.subtiles_per_tile()
                * ceil_div(layer.gemm().p, plan.sigma.t_p);
            sim.run_timing(layer, Some(cycles))
        } else {
            sim.run_timing(layer, None)
        }
    }
}

impl ExecutionBackend for SimBackend {
    fn name(&self) -> &'static str {
        "simulator"
    }

    fn plan(&mut self, plan: &EnginePlan) -> Result<()> {
        self.hw = vec![None; plan.n_layers()];
        self.w_scales = vec![None; plan.n_layers()];
        // A stale artifact must not leak α state into an unrelated plan;
        // preload re-installs it right after when the plan came from one.
        self.artifact = None;
        self.plan = Some(Arc::new(plan.clone()));
        self.executed.clear();
        self.cur_shape = None;
        Ok(())
    }

    fn preload(&mut self, model: &Arc<crate::engine::compile::CompiledModel>) -> Result<()> {
        {
            let plan = self.planned()?;
            if plan.network.name != model.plan().network.name
                || plan.n_layers() != model.plan().n_layers()
            {
                return Err(Error::InvalidConfig(format!(
                    "preload: compiled model '{}' ({} layers) does not match the \
                     planned network '{}' ({} layers)",
                    model.plan().network.name,
                    model.plan().n_layers(),
                    plan.network.name,
                    plan.n_layers()
                )));
            }
        }
        // Hold the handle only: the artifact's α sets are adopted on first
        // numeric use (`slab_job`), so timing-only traffic never pays the
        // fit and switches stay O(1). The artifact's precision is adopted
        // eagerly — it decides which microkernel and slab layout every
        // subsequent request runs.
        self.precision = model.precision();
        self.artifact = Some(Arc::clone(model));
        Ok(())
    }

    fn execute_layer(&mut self, idx: usize, input: &[f32]) -> Result<LayerOutcome> {
        let plan = Arc::clone(self.planned()?);
        let layer = plan.network.layers.get(idx).ok_or_else(|| {
            Error::InvalidConfig(format!(
                "layer index {idx} out of range ({} layers)",
                plan.network.layers.len()
            ))
        })?;
        let trace = self.timing_trace(&plan, idx, layer);
        // Numeric datapath for non-empty inputs; an empty input is the
        // serving convention for a timing-only request, which never touches
        // the weights path at all.
        let (output, overlap) = if input.is_empty() {
            (None, OverlapTelemetry::default())
        } else {
            let (mut outs, shape, tel) = self.forward_layer_batch(&plan, idx, &[input])?;
            self.cur_shape = Some(shape);
            (Some(outs.swap_remove(0)), tel)
        };
        let outcome = LayerOutcome {
            name: trace.name.clone(),
            cycles: trace.total_cycles as f64,
            bound: trace.bound,
            output,
            overlap,
        };
        self.executed.push(LayerCost {
            name: trace.name,
            cycles: trace.total_cycles as f64,
            bound: trace.bound,
            overlap,
        });
        Ok(outcome)
    }

    fn execute_layer_batch(&mut self, idx: usize, inputs: &[&[f32]]) -> Result<Vec<LayerOutcome>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        if inputs.iter().any(|i| i.is_empty()) {
            return Err(Error::InvalidConfig(
                "timing-only (empty) inputs cannot fold into a numeric batch".into(),
            ));
        }
        let plan = Arc::clone(self.planned()?);
        let layer = plan.network.layers.get(idx).ok_or_else(|| {
            Error::InvalidConfig(format!(
                "layer index {idx} out of range ({} layers)",
                plan.network.layers.len()
            ))
        })?;
        let trace = self.timing_trace(&plan, idx, layer);
        let (outs, shape, tel) = self.forward_layer_batch(&plan, idx, inputs)?;
        self.cur_shape = Some(shape);
        // The report charges the batch once per layer: every image pays its
        // engine cycles, while the layer's slabs were generated once for
        // the whole batch (the telemetry is the batch pass's).
        self.executed.push(LayerCost {
            name: trace.name.clone(),
            cycles: trace.total_cycles as f64 * outs.len() as f64,
            bound: trace.bound,
            overlap: tel,
        });
        Ok(outs
            .into_iter()
            .map(|o| LayerOutcome {
                name: trace.name.clone(),
                cycles: trace.total_cycles as f64,
                bound: trace.bound,
                output: Some(o),
                overlap: tel,
            })
            .collect())
    }

    fn finish(&mut self) -> Result<ExecutionReport> {
        let clock_hz = self.planned()?.platform.clock_hz;
        let layers = std::mem::take(&mut self.executed);
        self.cur_shape = None;
        let total_cycles: f64 = layers.iter().map(|l| l.cycles).sum();
        Ok(ExecutionReport {
            backend: self.name(),
            layers,
            total_cycles,
            latency_s: total_cycles / clock_hz,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{DesignPoint, Platform};
    use crate::engine::Engine;
    use crate::workload::{resnet, Network, RatioProfile};

    fn test_plan() -> EnginePlan {
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf50(&net);
        Engine::builder()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(DesignPoint::new(64, 64, 16, 48))
            .network(net)
            .profile(profile)
            .plan()
            .unwrap()
    }

    /// A small network that exercises every numeric-path case: dense stem,
    /// OVSF layers (one with C < T_C for the work-stealing schedule, one
    /// strided), and a classifier fed through the folded global pool.
    fn tiny_net() -> Network {
        Network {
            name: "tiny".into(),
            layers: vec![
                Layer::conv("stem", 8, 8, 4, 8, 3, 1, 1, false),
                Layer::conv("block.conv1", 8, 8, 8, 8, 3, 1, 1, true),
                Layer::conv("block.conv2", 8, 8, 8, 16, 3, 2, 1, true),
                Layer::fc("fc", 16, 10),
            ],
        }
    }

    fn tiny_plan(sigma: DesignPoint) -> EnginePlan {
        let net = tiny_net();
        let profile = RatioProfile::uniform(&net, 0.5);
        Engine::builder()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(sigma)
            .network(net)
            .profile(profile)
            .plan()
            .unwrap()
    }

    fn tiny_input() -> Vec<f32> {
        let mut rng = Xoshiro256::seed_from_u64(99);
        rng.normal_vec(8 * 8 * 4)
    }

    fn run_numeric(backend: &mut SimBackend, plan: &EnginePlan, input: &[f32]) -> Vec<f32> {
        let mut cur = input.to_vec();
        for idx in 0..plan.n_layers() {
            cur = backend
                .execute_layer(idx, &cur)
                .unwrap()
                .output
                .expect("numeric path produces activations");
        }
        backend.finish().unwrap();
        cur
    }

    #[test]
    fn timing_only_requests_never_touch_the_weights_path() {
        let plan = test_plan();
        let mut backend = SimBackend::new();
        backend.plan(&plan).unwrap();
        for idx in 0..plan.n_layers() {
            let o = backend.execute_layer(idx, &[]).unwrap();
            assert!(o.output.is_none(), "empty input must stay timing-only");
        }
        backend.finish().unwrap();
        assert!(backend.cache().is_empty());
        assert_eq!(backend.cache().misses(), 0);
    }

    #[test]
    fn numeric_inference_is_deterministic_and_shaped() {
        let sigma = DesignPoint::new(8, 4, 8, 4);
        let plan = tiny_plan(sigma);
        let input = tiny_input();
        let mut backend = SimBackend::new();
        backend.plan(&plan).unwrap();
        let a = run_numeric(&mut backend, &plan, &input);
        assert_eq!(a.len(), 10, "classifier output");
        assert!(a.iter().all(|v| v.is_finite()));
        assert!(a.iter().any(|v| *v != 0.0));
        let b = run_numeric(&mut backend, &plan, &input);
        assert_eq!(a, b, "repeat requests are bit-identical");
    }

    #[test]
    fn slabs_generate_once_then_hit_when_the_budget_fits() {
        let sigma = DesignPoint::new(8, 4, 8, 4);
        let plan = tiny_plan(sigma);
        let input = tiny_input();
        let mut backend = SimBackend::new();
        backend.plan(&plan).unwrap();
        run_numeric(&mut backend, &plan, &input);
        // OVSF slabs: block.conv1 C=8 → 2 tiles at T_C=4; block.conv2
        // C=16 → 4 tiles.
        assert_eq!(backend.cache().misses(), 6);
        assert_eq!(backend.cache().evictions(), 0);
        let hits = backend.cache().hits();
        run_numeric(&mut backend, &plan, &input);
        assert_eq!(backend.cache().misses(), 6, "warm requests regenerate nothing");
        assert_eq!(backend.cache().hits(), hits + 6);
    }

    #[test]
    fn tight_budget_bounds_resident_bytes_without_changing_numerics() {
        let sigma = DesignPoint::new(8, 4, 8, 4);
        let plan = tiny_plan(sigma);
        let input = tiny_input();
        let mut reference = SimBackend::new();
        reference.plan(&plan).unwrap();
        let expect = run_numeric(&mut reference, &plan, &input);

        // Budget of exactly one largest slab: P×T_C×4 = 72·4·4.
        let budget = 72 * 4 * 4;
        let cache = Arc::new(SlabCache::with_budget(budget));
        let mut streamed = SimBackend::with_cache(Arc::clone(&cache));
        streamed.plan(&plan).unwrap();
        let got = run_numeric(&mut streamed, &plan, &input);
        assert_eq!(got, expect, "eviction must not change numerics");
        assert!(cache.peak_resident_bytes() <= budget);
        assert!(cache.evictions() > 0, "the tight budget must have evicted");
    }

    #[test]
    fn shared_cache_spans_backends_like_pool_workers() {
        let sigma = DesignPoint::new(8, 4, 8, 4);
        let plan = tiny_plan(sigma);
        let input = tiny_input();
        let cache = Arc::new(SlabCache::new());
        let mut a = SimBackend::with_cache(Arc::clone(&cache));
        let mut b = SimBackend::with_cache(Arc::clone(&cache));
        a.plan(&plan).unwrap();
        b.plan(&plan).unwrap();
        let out_a = run_numeric(&mut a, &plan, &input);
        let misses = cache.misses();
        let out_b = run_numeric(&mut b, &plan, &input);
        assert_eq!(cache.misses(), misses, "second worker reuses every slab");
        assert_eq!(cache.hits(), misses);
        assert_eq!(out_a, out_b, "workers agree on the numerics");
    }

    #[test]
    fn numerics_are_design_point_invariant() {
        // The model is its OVSF α's: a design point that disables on-chip
        // generation (M = 0 — weights stream from memory instead) must
        // produce the same activations as one that generates on the fly.
        // The builder refuses M = 0 for OVSF nets, so build the plan by
        // hand the way the builder would.
        let net = tiny_net();
        let profile = RatioProfile::uniform(&net, 0.5);
        let platform = Platform::z7045();
        let with_wgen = DesignPoint::new(8, 4, 8, 4);
        let without_wgen = DesignPoint::new(0, 4, 8, 4);
        let input = tiny_input();
        let mut outputs = Vec::new();
        for sigma in [with_wgen, without_wgen] {
            let schedule = crate::coordinator::plan::InferencePlan::build(
                &platform, 4, sigma, &net, &profile,
            );
            let plan = EnginePlan {
                platform: platform.clone(),
                bw_mult: 4,
                sigma,
                network: net.clone(),
                profile: profile.clone(),
                schedule,
            };
            let mut backend = SimBackend::new();
            backend.plan(&plan).unwrap();
            outputs.push(run_numeric(&mut backend, &plan, &input));
        }
        assert_eq!(
            outputs[0], outputs[1],
            "numerics must not depend on whether σ instantiates CNN-WGen"
        );
    }

    #[test]
    fn pipelined_path_is_bit_identical_to_serial() {
        let sigma = DesignPoint::new(8, 4, 8, 4);
        let plan = tiny_plan(sigma);
        let input = tiny_input();
        let mut serial = SimBackend::new();
        serial.pipelined = false;
        serial.plan(&plan).unwrap();
        let expect = run_numeric(&mut serial, &plan, &input);
        let mut piped = SimBackend::new();
        assert!(piped.pipelined, "prefetch overlap is the default");
        piped.plan(&plan).unwrap();
        let got = run_numeric(&mut piped, &plan, &input);
        assert_eq!(got, expect, "prefetch overlap must not change a single bit");
    }

    #[test]
    fn pool_sharded_strips_are_bit_identical_to_serial() {
        let sigma = DesignPoint::new(8, 4, 8, 4);
        let plan = tiny_plan(sigma);
        let input = tiny_input();
        let mut serial = SimBackend::new();
        serial.pipelined = false;
        serial.plan(&plan).unwrap();
        let expect = run_numeric(&mut serial, &plan, &input);
        let mut sharded = SimBackend::new();
        sharded.par_min_macs = 0; // force pool sharding even on tiny shapes
        sharded.plan(&plan).unwrap();
        let got = run_numeric(&mut sharded, &plan, &input);
        assert_eq!(got, expect, "strip sharding must not change a single bit");
    }

    #[test]
    fn i8_schedules_are_bit_identical_and_slabs_stay_quarter_sized() {
        let sigma = DesignPoint::new(8, 4, 8, 4);
        let plan = tiny_plan(sigma);
        let input = tiny_input();
        let mut serial = SimBackend::new();
        serial.precision = Precision::I8;
        serial.pipelined = false;
        serial.plan(&plan).unwrap();
        let expect = run_numeric(&mut serial, &plan, &input);
        for sharded in [false, true] {
            let mut piped = SimBackend::new();
            piped.precision = Precision::I8;
            if sharded {
                piped.par_min_macs = 0;
            }
            piped.plan(&plan).unwrap();
            let got = run_numeric(&mut piped, &plan, &input);
            assert_eq!(
                got, expect,
                "i8 pipelined/sharded schedules must not change a bit"
            );
            // Every cached slab is an i8 payload charged at 1 byte/word:
            // both OVSF layers have P = 72, T_C = 4 ⇒ 288 B/slab.
            assert_eq!(piped.cache().resident_bytes(), 6 * 72 * 4);
        }
        // The i8 outputs track the f32 reference loosely (layer-level
        // bounds are pinned in tests/quantized_datapath.rs) but are not
        // the same numbers — the quantised kernel really ran.
        let mut f32b = SimBackend::new();
        f32b.plan(&plan).unwrap();
        let reference = run_numeric(&mut f32b, &plan, &input);
        assert_ne!(expect, reference);
        assert!(expect.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn mixed_precision_backends_share_a_cache_without_aliasing() {
        let sigma = DesignPoint::new(8, 4, 8, 4);
        let plan = tiny_plan(sigma);
        let input = tiny_input();
        let cache = Arc::new(SlabCache::new());
        let mut f32b = SimBackend::with_cache(Arc::clone(&cache));
        let mut i8b = SimBackend::with_cache(Arc::clone(&cache));
        i8b.precision = Precision::I8;
        f32b.plan(&plan).unwrap();
        i8b.plan(&plan).unwrap();
        let out_f = run_numeric(&mut f32b, &plan, &input);
        assert_eq!(cache.misses(), 6);
        let out_q = run_numeric(&mut i8b, &plan, &input);
        // The i8 twin generates its own 6 slabs — no cross-precision hits.
        assert_eq!(cache.misses(), 12, "precisions must not alias");
        assert_eq!(cache.len(), 12);
        assert_ne!(out_f, out_q);
        // Both re-serve warm from the shared cache.
        run_numeric(&mut f32b, &plan, &input);
        run_numeric(&mut i8b, &plan, &input);
        assert_eq!(cache.misses(), 12);
    }

    #[test]
    fn generation_errors_surface_and_the_next_request_serves() {
        // An out-of-range layer index mid-stream must error cleanly and
        // leave the backend (and its prefetch worker) usable.
        let sigma = DesignPoint::new(8, 4, 8, 4);
        let plan = tiny_plan(sigma);
        let input = tiny_input();
        let mut backend = SimBackend::new();
        backend.plan(&plan).unwrap();
        assert!(backend.execute_layer(99, &input).is_err());
        backend.finish().unwrap();
        let out = run_numeric(&mut backend, &plan, &input);
        assert_eq!(out.len(), 10, "backend recovered after the failed request");
    }

    #[test]
    fn overlap_telemetry_reports_generation_and_compute() {
        let sigma = DesignPoint::new(8, 4, 8, 4);
        let plan = tiny_plan(sigma);
        let input = tiny_input();
        let mut backend = SimBackend::new();
        backend.plan(&plan).unwrap();
        let mut cur = input.clone();
        for idx in 0..plan.n_layers() {
            let o = backend.execute_layer(idx, &cur).unwrap();
            assert!(
                o.overlap.hidden_ns <= o.overlap.gen_ns,
                "cannot hide more generation than ran"
            );
            assert!(o.overlap.gen_ns > 0, "cold slabs must charge generation");
            cur = o.output.expect("numeric path produces activations");
        }
        let report = backend.finish().unwrap();
        let total = report.overlap();
        assert!(total.gen_ns > 0 && total.compute_ns > 0);
        assert!(total.hidden_ns <= total.gen_ns);
        // Timing-only requests carry no telemetry.
        let o = backend.execute_layer(0, &[]).unwrap();
        assert_eq!(o.overlap, OverlapTelemetry::default());
        backend.finish().unwrap();
    }

    #[test]
    fn batched_layers_match_per_image_execution() {
        let sigma = DesignPoint::new(8, 4, 8, 4);
        let plan = tiny_plan(sigma);
        let mut rng = Xoshiro256::seed_from_u64(4242);
        let inputs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(8 * 8 * 4)).collect();
        // Per-image reference.
        let mut reference = SimBackend::new();
        reference.plan(&plan).unwrap();
        let expect: Vec<Vec<f32>> = inputs
            .iter()
            .map(|input| run_numeric(&mut reference, &plan, input))
            .collect();
        // Batched: every layer pass folds the three images.
        let mut batched = SimBackend::new();
        batched.plan(&plan).unwrap();
        let mut cur: Vec<Vec<f32>> = inputs.clone();
        for idx in 0..plan.n_layers() {
            let refs: Vec<&[f32]> = cur.iter().map(|v| v.as_slice()).collect();
            let outcomes = batched.execute_layer_batch(idx, &refs).unwrap();
            assert_eq!(outcomes.len(), 3);
            cur = outcomes
                .into_iter()
                .map(|o| o.output.expect("numeric batch produces activations"))
                .collect();
        }
        batched.finish().unwrap();
        assert_eq!(cur, expect, "batch folding must not change the numerics");
        // Mixed timing-only inputs cannot fold.
        let empty: &[f32] = &[];
        let refs: Vec<&[f32]> = vec![inputs[0].as_slice(), empty];
        assert!(batched.execute_layer_batch(0, &refs).is_err());
        batched.finish().unwrap();
    }

    #[test]
    fn refit_pools_and_broadcasts_deterministically() {
        // 2×2×2 → 1×1×2: global average per channel.
        let src = vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0];
        let out = refit_activations(&src, (2, 2, 2), (1, 1, 2));
        assert_eq!(out, vec![2.5, 25.0]);
        // Channel fold 4 → 2 at 1×1: average channels {0,2} and {1,3}.
        let out = refit_activations(&[1.0, 2.0, 3.0, 4.0], (1, 1, 4), (1, 1, 2));
        assert_eq!(out, vec![2.0, 3.0]);
        // Upsample 1×1 → 2×2 replicates; channel broadcast 1 → 2 tiles.
        let out = refit_activations(&[7.0], (1, 1, 1), (2, 2, 2));
        assert_eq!(out, vec![7.0; 8]);
    }

    #[test]
    fn synthetic_weights_are_worker_independent() {
        let layer = Layer::conv("c", 8, 8, 8, 8, 3, 1, 1, true);
        let a = synth_hw_weights("net", 3, &layer, 0.5).unwrap();
        let b = synth_hw_weights("net", 3, &layer, 0.5).unwrap();
        assert_eq!(a.alphas, b.alphas);
        let c = synth_hw_weights("net", 4, &layer, 0.5).unwrap();
        assert_ne!(a.alphas, c.alphas, "layer index is part of the seed");
        // Dense slabs are partition-independent.
        let (mut s1, mut s2a, mut s2b) = (Vec::new(), Vec::new(), Vec::new());
        synth_dense_slab("net", 0, &layer, 0, 8, &mut s1);
        synth_dense_slab("net", 0, &layer, 0, 5, &mut s2a);
        synth_dense_slab("net", 0, &layer, 5, 8, &mut s2b);
        let p_dim = (layer.n_in * layer.k * layer.k) as usize;
        for p in 0..p_dim {
            for o in 0..8 {
                let whole = s1[p * 8 + o];
                let split = if o < 5 { s2a[p * 5 + o] } else { s2b[p * 3 + (o - 5)] };
                assert_eq!(whole, split, "p={p} o={o}");
            }
        }
    }
}
