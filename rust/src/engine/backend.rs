//! The [`ExecutionBackend`] abstraction: one contract for the three ways
//! the repro can execute an inference — the analytical model, the
//! cycle-level simulator and the PJRT runtime.
//!
//! Lifecycle per backend instance:
//!
//! 1. [`plan`](ExecutionBackend::plan) — called once with the validated
//!    [`EnginePlan`]; the backend sizes its internal state (compiles
//!    artifacts, precomputes per-layer costs, …).
//! 2. [`execute_layer`](ExecutionBackend::execute_layer) — called once per
//!    network layer per inference, in layer order.
//! 3. [`finish`](ExecutionBackend::finish) — closes the inference and
//!    emits the cost/trace report; the backend resets for the next request.

use std::sync::Arc;

use crate::arch::{DesignPoint, Platform};
use crate::coordinator::plan::InferencePlan;
use crate::engine::compile::CompiledModel;
use crate::error::Result;
use crate::perf::Bound;
use crate::workload::{Network, RatioProfile};

/// The fully validated execution context shared by every backend: the
/// platform + bandwidth operating point, the design point σ, the workload
/// and its OVSF ratio profile, plus the admission-time schedule derived
/// from them.
#[derive(Clone, Debug)]
pub struct EnginePlan {
    /// Target platform.
    pub platform: Platform,
    /// Off-chip bandwidth multiplier.
    pub bw_mult: u32,
    /// Design point executed.
    pub sigma: DesignPoint,
    /// The CNN workload.
    pub network: Network,
    /// Per-layer OVSF ratio profile.
    pub profile: RatioProfile,
    /// Admission-time per-layer schedule (analytical costing).
    pub schedule: InferencePlan,
}

impl EnginePlan {
    /// Number of network layers.
    pub fn n_layers(&self) -> usize {
        self.network.layers.len()
    }
}

/// Wall-clock overlap telemetry for one layer pass on a numeric backend:
/// how much of the weights-generation (prefetch) time was hidden behind PE
/// compute. All zeros on timing-only backends/requests and on the serial
/// datapath's `hidden_ns`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OverlapTelemetry {
    /// Nanoseconds the generation stage spent producing this layer's weight
    /// slabs (cache hits cost ~0; includes inline generation on the serial
    /// path).
    pub gen_ns: u64,
    /// Nanoseconds the compute stage spent multiplying strips by slabs.
    pub compute_ns: u64,
    /// Generation nanoseconds hidden behind compute: `gen_ns` minus the
    /// time the compute stage actually stalled waiting for a slab
    /// (saturating). Always 0 on the serial datapath.
    pub hidden_ns: u64,
}

impl OverlapTelemetry {
    /// Accumulate another layer's (or tile's) telemetry into this one.
    pub fn accumulate(&mut self, other: &OverlapTelemetry) {
        self.gen_ns += other.gen_ns;
        self.compute_ns += other.compute_ns;
        self.hidden_ns += other.hidden_ns;
    }

    /// Fraction of generation time hidden behind compute (0 when no
    /// generation ran).
    pub fn hidden_frac(&self) -> f64 {
        if self.gen_ns == 0 {
            0.0
        } else {
            self.hidden_ns as f64 / self.gen_ns as f64
        }
    }
}

/// Outcome of executing one layer on a backend.
#[derive(Clone, Debug)]
pub struct LayerOutcome {
    /// Layer name.
    pub name: String,
    /// Charged cycles for the layer on this backend.
    pub cycles: f64,
    /// Dominating pipeline stage.
    pub bound: Bound,
    /// Output activations, if the backend produces numerics (`None` for
    /// timing-only backends and timing-only — empty-input — requests).
    pub output: Option<Vec<f32>>,
    /// Generation/compute overlap telemetry for this layer pass. For
    /// batched execution every per-image outcome carries the whole batch
    /// pass's telemetry (the pass runs once for the batch).
    pub overlap: OverlapTelemetry,
}

/// Per-layer cost entry of an [`ExecutionReport`].
#[derive(Clone, Debug)]
pub struct LayerCost {
    /// Layer name.
    pub name: String,
    /// Charged cycles.
    pub cycles: f64,
    /// Dominating pipeline stage.
    pub bound: Bound,
    /// Generation/compute overlap telemetry (zeros on timing-only paths).
    pub overlap: OverlapTelemetry,
}

/// The cost/trace output a backend emits when an inference finishes.
#[derive(Clone, Debug)]
pub struct ExecutionReport {
    /// Backend that produced the report.
    pub backend: &'static str,
    /// Per-layer costs in execution order.
    pub layers: Vec<LayerCost>,
    /// Total cycles for the inference.
    pub total_cycles: f64,
    /// Latency in seconds at the platform clock.
    pub latency_s: f64,
}

impl ExecutionReport {
    /// Throughput implied by the report (inferences/second).
    pub fn inf_per_s(&self) -> f64 {
        if self.latency_s == 0.0 {
            0.0
        } else {
            1.0 / self.latency_s
        }
    }

    /// Aggregate generation/compute overlap telemetry across all layers.
    pub fn overlap(&self) -> OverlapTelemetry {
        let mut total = OverlapTelemetry::default();
        for l in &self.layers {
            total.accumulate(&l.overlap);
        }
        total
    }
}

/// A pluggable execution path behind the [`Engine`](crate::engine::Engine)
/// facade. Implementations wrap the analytical model, the cycle-level
/// simulator or the PJRT runtime — and external code can provide custom
/// backends (e.g. remote devices) without touching the engine.
pub trait ExecutionBackend {
    /// Stable backend name (reports, logs, registries).
    fn name(&self) -> &'static str;

    /// Accept the validated plan and prepare internal state. Called before
    /// any [`execute_layer`](Self::execute_layer) call — and called again
    /// (between requests) when a serving worker swaps the active model onto
    /// this backend: the backend must drop all per-model state and be ready
    /// to execute the new plan.
    fn plan(&mut self, plan: &EnginePlan) -> Result<()>;

    /// Adopt a compiled model artifact. Called after [`plan`](Self::plan)
    /// with the artifact whose `plan()` was just installed — the
    /// compile-once/serve-many hook: backends that fit or synthesise
    /// per-layer weight state take the artifact's (fitted once per
    /// artifact, shared via `Arc` across workers and switches) instead of
    /// redoing the work per backend instance. Implementations must keep
    /// timing-only traffic cheap: hold the handle, defer the α fit to
    /// first numeric use ([`CompiledModel::hw`] caches it). The default
    /// ignores the artifact (timing-only backends hold no weight state).
    fn preload(&mut self, _model: &Arc<CompiledModel>) -> Result<()> {
        Ok(())
    }

    /// Execute layer `idx` of the planned network. `input` carries the
    /// current activations (the request input for layer 0, the previous
    /// layer's output afterwards). An **empty** `input` marks a
    /// timing-only request: numeric backends skip the datapath (and any
    /// weights generation) and return `output: None`, exactly like
    /// timing-only backends always do.
    fn execute_layer(&mut self, idx: usize, input: &[f32]) -> Result<LayerOutcome>;

    /// Execute layer `idx` for a whole batch of activations at once — the
    /// entry point that lets a backend amortise per-layer work (e.g. weight
    /// slab generation) across the batch by folding the batch dimension
    /// into GEMM rows. Every input must be non-empty and the outcomes are
    /// returned in input order.
    ///
    /// The default loops [`execute_layer`](Self::execute_layer) per input —
    /// correct only for backends without cross-layer per-request state.
    /// Backends that thread state between layers (shape tracking etc.) must
    /// override this to process the batch in one pass.
    fn execute_layer_batch(&mut self, idx: usize, inputs: &[&[f32]]) -> Result<Vec<LayerOutcome>> {
        inputs
            .iter()
            .map(|input| self.execute_layer(idx, input))
            .collect()
    }

    /// Complete one inference: flush per-request state and emit the
    /// cost/trace report. The backend must be ready for the next request
    /// afterwards.
    fn finish(&mut self) -> Result<ExecutionReport>;
}

/// Forwarding impl so a boxed backend is itself a backend: decorators that
/// are generic over `B: ExecutionBackend` (e.g.
/// [`FaultyBackend`](crate::engine::fault::FaultyBackend)) can wrap the
/// `Box<dyn ExecutionBackend>` a factory hands out — the seam replicated
/// serving's per-replica chaos wraps are built on.
impl ExecutionBackend for Box<dyn ExecutionBackend> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn plan(&mut self, plan: &EnginePlan) -> Result<()> {
        (**self).plan(plan)
    }

    fn preload(&mut self, model: &Arc<CompiledModel>) -> Result<()> {
        (**self).preload(model)
    }

    fn execute_layer(&mut self, idx: usize, input: &[f32]) -> Result<LayerOutcome> {
        (**self).execute_layer(idx, input)
    }

    fn execute_layer_batch(&mut self, idx: usize, inputs: &[&[f32]]) -> Result<Vec<LayerOutcome>> {
        (**self).execute_layer_batch(idx, inputs)
    }

    fn finish(&mut self) -> Result<ExecutionReport> {
        (**self).finish()
    }
}
