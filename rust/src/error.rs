//! Unified error type for the unzipFPGA crate.

use thiserror::Error;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors raised across the unzipFPGA stack.
#[derive(Error, Debug)]
pub enum Error {
    /// A requested OVSF basis length is not a power of two.
    #[error("OVSF basis length must be a power of two, got {0}")]
    InvalidBasisLength(usize),

    /// Shape mismatch when reconstructing or decomposing tensors.
    #[error("shape mismatch: {0}")]
    ShapeMismatch(String),

    /// A design point violates the platform's resource constraints.
    #[error("infeasible design point: {0}")]
    Infeasible(String),

    /// The design-space exploration found no feasible configuration.
    #[error("DSE found no feasible design for {network} on {platform}")]
    NoFeasibleDesign {
        /// Target network name.
        network: String,
        /// Target platform name.
        platform: String,
    },

    /// Invalid configuration supplied by the caller.
    #[error("invalid configuration: {0}")]
    InvalidConfig(String),

    /// An artifact file (AOT-compiled HLO) is missing.
    #[error("missing artifact {path}: run `make artifacts` first ({source})")]
    MissingArtifact {
        /// Path that was attempted.
        path: String,
        /// Underlying I/O error.
        #[source]
        source: std::io::Error,
    },

    /// Errors bubbled up from the XLA/PJRT runtime.
    #[error("XLA runtime error: {0}")]
    Xla(String),

    /// Plain I/O error.
    #[error(transparent)]
    Io(#[from] std::io::Error),

    /// Coordinator/server errors (channel shutdowns etc.).
    #[error("coordinator error: {0}")]
    Coordinator(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
