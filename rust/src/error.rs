//! Unified error type for the unzipFPGA crate.
//!
//! Hand-rolled `Display`/`Error` impls: the build environment is offline,
//! so derive crates (`thiserror`) are unavailable.

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors raised across the unzipFPGA stack.
#[derive(Debug)]
pub enum Error {
    /// A requested OVSF basis length is not a power of two.
    InvalidBasisLength(usize),

    /// Shape mismatch when reconstructing or decomposing tensors.
    ShapeMismatch(String),

    /// A design point violates the platform's resource constraints.
    Infeasible(String),

    /// The design-space exploration found no feasible configuration.
    NoFeasibleDesign {
        /// Target network name.
        network: String,
        /// Target platform name.
        platform: String,
    },

    /// Invalid configuration supplied by the caller.
    InvalidConfig(String),

    /// An artifact file (AOT-compiled HLO) is missing.
    MissingArtifact {
        /// Path that was attempted.
        path: String,
        /// Underlying I/O error.
        source: std::io::Error,
    },

    /// Errors bubbled up from the XLA/PJRT runtime.
    Xla(String),

    /// The PJRT runtime was requested but the crate was built without the
    /// `pjrt` feature (the `xla` dependency is not vendored).
    RuntimeUnavailable,

    /// Plain I/O error.
    Io(std::io::Error),

    /// Coordinator/server errors (channel shutdowns etc.).
    Coordinator(String),

    /// A bounded submission queue rejected a request (backpressure).
    QueueFull,

    /// A request could not be routed to a model: the id is not registered
    /// (never registered, or evicted while the request was queued), or the
    /// empty default route is ambiguous because the pool serves more than
    /// one model.
    UnknownModel(String),

    /// The server pool is shut down (or every worker died): the request was
    /// drained without execution instead of hanging.
    PoolShutdown,

    /// Admission control shed the request: the pool's estimated queue
    /// delay (queued service estimates ÷ workers) exceeds the configured
    /// SLO, so accepting more work would only grow tail latency. Back off
    /// and retry, or raise `PoolConfig::slo`.
    Overloaded {
        /// Estimated queue delay at admission time.
        queue_delay: std::time::Duration,
        /// The queue-delay SLO the pool is configured to defend.
        slo: std::time::Duration,
    },

    /// The request's deadline expired before a worker started executing
    /// it (or had already expired at submission): it was failed fast
    /// instead of wasting a batch slot on an answer nobody is waiting for.
    DeadlineExceeded {
        /// How far past the deadline the request was when it was failed.
        late_by: std::time::Duration,
    },

    /// A worker thread panicked while executing this request. The pool
    /// caught the panic, failed the offending request with this error,
    /// re-queued any co-batched requests and (budget permitting)
    /// respawned the worker — the panic costs one request, not pool
    /// capacity.
    WorkerPanic {
        /// Panic payload rendered to text (when it was a string).
        detail: String,
    },

    /// The per-model circuit breaker is open: the model's recent requests
    /// failed consecutively, so new requests are rejected fast instead of
    /// occupying workers that would likely fail too. Retry after
    /// `retry_after`, when the breaker admits half-open probes.
    CircuitOpen {
        /// Model id whose breaker is open.
        model: String,
        /// Time until the breaker starts admitting probe requests.
        retry_after: std::time::Duration,
    },

    /// A transient backend fault (momentary DMA/link hiccup, injected
    /// chaos, ...): retrying the same request is expected to succeed.
    /// The pool retries these automatically with jittered backoff.
    Transient(String),

    /// A pipeline stage failed a request *after* it cleared end-to-end
    /// admission: the wrapped error is what the stage's replica set
    /// reported, tagged with the stage index so callers can see where in
    /// the pipeline the request died. Transience delegates to the wrapped
    /// error (a `QueueFull` deep in the pipeline is still worth retrying;
    /// a `ShapeMismatch` is not).
    StageFailed {
        /// Zero-based pipeline stage index the failure occurred at.
        stage: usize,
        /// The stage-local failure.
        source: Box<Error>,
    },

    /// Replicated serving is running below its configured capacity floor
    /// (replicas unhealthy, draining, or rebuilding) and degraded-mode
    /// admission shed this request by priority class rather than letting
    /// queues grow unboundedly on the surviving replicas. Capacity heals
    /// as the supervisor rebuilds replicas — back off and retry.
    DegradedCapacity {
        /// Replicas currently live (healthy and accepting dispatch).
        live: usize,
        /// Replicas the set was configured with.
        configured: usize,
    },
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::InvalidBasisLength(n) => {
                write!(f, "OVSF basis length must be a power of two, got {n}")
            }
            Error::ShapeMismatch(s) => write!(f, "shape mismatch: {s}"),
            Error::Infeasible(s) => write!(f, "infeasible design point: {s}"),
            Error::NoFeasibleDesign { network, platform } => {
                write!(f, "DSE found no feasible design for {network} on {platform}")
            }
            Error::InvalidConfig(s) => write!(f, "invalid configuration: {s}"),
            Error::MissingArtifact { path, source } => {
                write!(f, "missing artifact {path}: run `make artifacts` first ({source})")
            }
            Error::Xla(s) => write!(f, "XLA runtime error: {s}"),
            Error::RuntimeUnavailable => write!(
                f,
                "PJRT runtime unavailable: built without the `pjrt` feature \
                 (vendor the `xla` crate and enable it)"
            ),
            Error::Io(e) => e.fmt(f),
            Error::Coordinator(s) => write!(f, "coordinator error: {s}"),
            Error::QueueFull => write!(f, "server pool queue is full (backpressure applied)"),
            Error::UnknownModel(m) => write!(
                f,
                "cannot route to model '{m}' (unknown id, evicted, or ambiguous \
                 default route)"
            ),
            Error::PoolShutdown => write!(
                f,
                "server pool is shut down (workers gone); request drained without execution"
            ),
            Error::Overloaded { queue_delay, slo } => write!(
                f,
                "server pool overloaded: estimated queue delay {:.1} ms exceeds the \
                 {:.1} ms SLO; request shed (back off and retry)",
                queue_delay.as_secs_f64() * 1e3,
                slo.as_secs_f64() * 1e3
            ),
            Error::DeadlineExceeded { late_by } => write!(
                f,
                "request deadline exceeded ({:.1} ms past due) before execution; \
                 failed fast instead of occupying a batch slot",
                late_by.as_secs_f64() * 1e3
            ),
            Error::WorkerPanic { detail } => write!(
                f,
                "worker panicked while executing this request ({detail}); \
                 co-batched requests were re-queued and the worker respawned"
            ),
            Error::CircuitOpen { model, retry_after } => write!(
                f,
                "circuit breaker open for model '{model}' after consecutive \
                 failures; rejecting fast — retry in {:.1} ms",
                retry_after.as_secs_f64() * 1e3
            ),
            Error::Transient(s) => write!(f, "transient backend fault (retryable): {s}"),
            Error::StageFailed { stage, source } => {
                write!(f, "pipeline stage {stage} failed: {source}")
            }
            Error::DegradedCapacity { live, configured } => write!(
                f,
                "serving capacity degraded: {live} of {configured} replicas live \
                 (below the admission floor); request shed by priority class — \
                 back off and retry while the supervisor rebuilds"
            ),
        }
    }
}

impl Error {
    /// Whether retrying the same request is expected to succeed — used by
    /// the server pool's deadline-aware retry loop. Transient backend
    /// faults, backpressure and load shedding qualify; shape/config/model
    /// errors and panics do not (retrying would fail identically or hide
    /// a real bug).
    pub fn is_transient(&self) -> bool {
        match self {
            // Stage-tagged failures are exactly as retryable as the
            // stage-local error they wrap.
            Error::StageFailed { source, .. } => source.is_transient(),
            _ => matches!(
                self,
                Error::Transient(_)
                    | Error::QueueFull
                    | Error::Overloaded { .. }
                    | Error::DegradedCapacity { .. }
            ),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::MissingArtifact { source, .. } => Some(source),
            Error::Io(e) => Some(e),
            Error::StageFailed { source, .. } => Some(source.as_ref()),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_are_actionable() {
        let e = Error::MissingArtifact {
            path: "artifacts/x.hlo.txt".into(),
            source: std::io::Error::new(std::io::ErrorKind::NotFound, "no such file"),
        };
        assert!(e.to_string().contains("make artifacts"));
        assert!(Error::RuntimeUnavailable.to_string().contains("pjrt"));
        assert!(Error::QueueFull.to_string().contains("backpressure"));
        assert!(Error::UnknownModel("r18".into()).to_string().contains("r18"));
        assert!(Error::PoolShutdown.to_string().contains("shut down"));
        let over = Error::Overloaded {
            queue_delay: std::time::Duration::from_millis(42),
            slo: std::time::Duration::from_millis(10),
        };
        assert!(over.to_string().contains("42.0 ms"), "{over}");
        assert!(over.to_string().contains("10.0 ms SLO"), "{over}");
        let late = Error::DeadlineExceeded {
            late_by: std::time::Duration::from_millis(7),
        };
        assert!(late.to_string().contains("7.0 ms past due"), "{late}");
        let wp = Error::WorkerPanic {
            detail: "index out of bounds".into(),
        };
        assert!(wp.to_string().contains("index out of bounds"), "{wp}");
        assert!(wp.to_string().contains("re-queued"), "{wp}");
        let open = Error::CircuitOpen {
            model: "r18".into(),
            retry_after: std::time::Duration::from_millis(250),
        };
        assert!(open.to_string().contains("r18"), "{open}");
        assert!(open.to_string().contains("250.0 ms"), "{open}");
        let t = Error::Transient("injected DMA hiccup".into());
        assert!(t.to_string().contains("retryable"), "{t}");
        let deg = Error::DegradedCapacity {
            live: 1,
            configured: 3,
        };
        assert!(deg.to_string().contains("1 of 3 replicas"), "{deg}");
        assert!(deg.to_string().contains("shed by priority"), "{deg}");
        let st = Error::StageFailed {
            stage: 2,
            source: Box::new(Error::PoolShutdown),
        };
        assert!(st.to_string().contains("stage 2"), "{st}");
        assert!(st.to_string().contains("shut down"), "{st}");
        assert!(std::error::Error::source(&st).is_some());
    }

    #[test]
    fn transient_classification() {
        assert!(Error::Transient("x".into()).is_transient());
        assert!(Error::QueueFull.is_transient());
        assert!(Error::Overloaded {
            queue_delay: std::time::Duration::from_millis(5),
            slo: std::time::Duration::from_millis(1),
        }
        .is_transient());
        assert!(Error::DegradedCapacity {
            live: 0,
            configured: 2,
        }
        .is_transient());
        assert!(!Error::PoolShutdown.is_transient());
        assert!(!Error::WorkerPanic { detail: "p".into() }.is_transient());
        assert!(!Error::CircuitOpen {
            model: "m".into(),
            retry_after: std::time::Duration::from_millis(1),
        }
        .is_transient());
        assert!(!Error::ShapeMismatch("bad".into()).is_transient());
        // Stage wrapping is transparent to transience.
        assert!(Error::StageFailed {
            stage: 1,
            source: Box::new(Error::QueueFull),
        }
        .is_transient());
        assert!(!Error::StageFailed {
            stage: 0,
            source: Box::new(Error::WorkerPanic { detail: "p".into() }),
        }
        .is_transient());
    }

    #[test]
    fn io_errors_convert_and_expose_source() {
        let e: Error = std::io::Error::other("boom").into();
        assert!(std::error::Error::source(&e).is_some());
    }
}
