//! OVSF (orthogonal variable spreading factor) code algebra — paper §2.2–2.3.
//!
//! OVSF codes are the rows of Sylvester–Hadamard matrices; a layer's filters
//! are reconstructed at run time as a learned linear combination of
//! `⌊ρ·L⌉` codes of length `L = N_in·K·K`.

pub mod basis;
pub mod codes;
pub mod reconstruct;
pub mod regress;

pub use basis::{BasisSelection, SelectedBasis};
pub use codes::OvsfBasis;
pub use reconstruct::{Filter3x3Mode, OvsfLayer};
