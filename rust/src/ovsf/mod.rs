//! OVSF (orthogonal variable spreading factor) code algebra — paper §2.2–2.3.
//!
//! OVSF codes are the rows of Sylvester–Hadamard matrices; a layer's filters
//! are reconstructed at run time as a learned linear combination of
//! `⌊ρ·L⌉` codes of length `L = N_in·K·K`.
//!
//! The whole module is **matrix-free**: code elements come from the closed
//! form `(−1)^popcount(j & t)` ([`codes`]), and projection/reconstruction
//! are O(L log L) fast Walsh–Hadamard transforms ([`regress`]) — the L×L
//! matrix is never materialised outside test oracles.

pub mod basis;
pub mod codes;
pub mod reconstruct;
pub mod regress;

pub use basis::{BasisSelection, SelectedBasis};
pub use codes::OvsfBasis;
pub use reconstruct::{Filter3x3Mode, OvsfLayer};
