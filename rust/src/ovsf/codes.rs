//! OVSF code construction (paper Eq. 1) — matrix-free.
//!
//! `H_1 = [1]`, `H_{2k} = H_2 ⊗ H_k` (Sylvester construction). Each row of
//! `H_L` is an OVSF code of length `L = 2^k`: binary (±1) and mutually
//! orthogonal, so the `L` rows form a basis of `R^L`.
//!
//! The Sylvester recursion closes to `H[j][t] = (−1)^popcount(j & t)`, so
//! no `L×L` matrix is ever materialised: [`OvsfBasis::new`] is O(1) and
//! element access [`OvsfBasis::sign`] is a single popcount. The former
//! dense construction (64 MB of i8 at L=8192) survives only as the
//! `#[cfg(test)]` oracle [`OvsfBasis::dense_codes`] that cross-checks the
//! closed form.
//!
//! Two on-demand representations are emitted: `i8` (±1) rows for numerics,
//! and bit-packed `u64` blocks (1 ⇒ +1, 0 ⇒ −1) mirroring how the hardware
//! OVSF FIFO stores codes on-chip (1 bit/element).

use crate::error::{Error, Result};
use crate::util::is_pow2;

/// A full OVSF basis of length `L` (all `L` codes), represented implicitly:
/// only `L` is stored; every element is computed on demand.
#[derive(Clone, Copy, Debug)]
pub struct OvsfBasis {
    len: usize,
}

impl OvsfBasis {
    /// Construct the length-`len` OVSF basis. `len` must be a power of two.
    /// O(1): nothing is materialised.
    pub fn new(len: usize) -> Result<Self> {
        if !is_pow2(len) {
            return Err(Error::InvalidBasisLength(len));
        }
        Ok(Self { len })
    }

    /// Basis length `L` (= number of codes).
    pub fn len(&self) -> usize {
        self.len
    }

    /// `true` iff the basis is empty (never for a constructed basis).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Sign of code `j` at position `t` without bounds checks on the basis
    /// geometry: `(−1)^popcount(j & t)` (Sylvester closed form).
    #[inline(always)]
    pub fn sign(j: usize, t: usize) -> i8 {
        1 - 2 * ((j & t).count_ones() & 1) as i8
    }

    /// The `j`-th code as a ±1 vector (emitted on demand).
    pub fn code(&self, j: usize) -> Vec<i8> {
        assert!(j < self.len, "code index {j} out of range (L={})", self.len);
        (0..self.len).map(|t| Self::sign(j, t)).collect()
    }

    /// Element `(j, t)` — sign of code `j` at position `t`.
    #[inline]
    pub fn at(&self, j: usize, t: usize) -> i8 {
        debug_assert!(j < self.len && t < self.len);
        Self::sign(j, t)
    }

    /// Inner product of two codes (orthogonality: `L·δ_ij`), computed on
    /// the packed-u64 representation: agreements vs disagreements fall out
    /// of `popcount(packed_i XOR packed_j)` per 64-element block.
    pub fn dot(&self, i: usize, j: usize) -> i64 {
        assert!(i < self.len && j < self.len);
        let pi = self.packed(i);
        let pj = self.packed(j);
        // packed() leaves bits ≥ len zero, so the tail word needs no mask:
        // the xor's high bits are already 0.
        let disagree: u32 = pi.iter().zip(&pj).map(|(&a, &b)| (a ^ b).count_ones()).sum();
        self.len as i64 - 2 * disagree as i64
    }

    /// Scalar reference for [`dot`](Self::dot): the i8-by-i8 O(L) loop.
    /// Kept for the equivalence test.
    #[cfg(test)]
    fn dot_scalar(&self, i: usize, j: usize) -> i64 {
        self.code(i)
            .iter()
            .zip(self.code(j))
            .map(|(&a, b)| (a as i64) * (b as i64))
            .sum()
    }

    /// Bit-packed form of code `j`: bit `t` of the result is 1 iff the
    /// element is +1. This is the on-chip storage format of the hardware
    /// OVSF FIFO (paper §4.2.2): 1 bit per element. Emitted without
    /// materialising the ±1 row.
    pub fn packed(&self, j: usize) -> Vec<u64> {
        assert!(j < self.len, "code index {j} out of range (L={})", self.len);
        let words = self.len.div_ceil(64);
        let mut out = vec![0u64; words];
        for (w, word) in out.iter_mut().enumerate() {
            let base = w * 64;
            let bits = (self.len - base).min(64);
            let mut acc = 0u64;
            for b in 0..bits {
                // +1 ⇔ even parity of j & t.
                if (j & (base + b)).count_ones() & 1 == 0 {
                    acc |= 1u64 << b;
                }
            }
            *word = acc;
        }
        out
    }

    /// Unpack a bit-packed code back to ±1.
    pub fn unpack(packed: &[u64], len: usize) -> Vec<i8> {
        (0..len)
            .map(|t| {
                if packed[t / 64] >> (t % 64) & 1 == 1 {
                    1
                } else {
                    -1
                }
            })
            .collect()
    }

    /// On-chip storage for the full basis in bits (paper Eq. 9 uses the
    /// `K²_max × K²_max`-bit OVSF FIFO term).
    pub fn storage_bits(&self) -> u64 {
        (self.len * self.len) as u64
    }

    /// Tree-structured construction (Adachi et al. [4]): code
    /// `c_{2k} = [c_k, c_k]`, `c_{2k+1} = [c_k, −c_k]`. Returns code with
    /// tree index `idx` at depth `log2(len)`. Used to cross-check the
    /// Sylvester construction.
    pub fn tree_code(len: usize, idx: usize) -> Result<Vec<i8>> {
        if !is_pow2(len) {
            return Err(Error::InvalidBasisLength(len));
        }
        assert!(idx < len);
        let mut code = vec![1i8];
        let mut bits = Vec::new();
        let mut i = idx;
        let mut l = len;
        while l > 1 {
            bits.push(i % 2);
            i /= 2;
            l /= 2;
        }
        // bits collected LSB-first == order of expansions from root.
        for &b in bits.iter().rev() {
            let mut next = Vec::with_capacity(code.len() * 2);
            next.extend_from_slice(&code);
            if b == 0 {
                next.extend_from_slice(&code);
            } else {
                next.extend(code.iter().map(|&v| -v));
            }
            code = next;
        }
        Ok(code)
    }

    /// Dense Sylvester materialisation — the O(L²) oracle the matrix-free
    /// closed form is verified against. Test-only: production code must
    /// never materialise the basis.
    #[cfg(test)]
    pub(crate) fn dense_codes(len: usize) -> Result<Vec<i8>> {
        if !is_pow2(len) {
            return Err(Error::InvalidBasisLength(len));
        }
        // Sylvester expansion, iteratively doubling.
        let mut codes = vec![1i8];
        let mut cur = 1usize;
        while cur < len {
            let next = cur * 2;
            let mut out = vec![0i8; next * next];
            for r in 0..cur {
                for c in 0..cur {
                    let v = codes[r * cur + c];
                    out[r * next + c] = v; // top-left
                    out[r * next + cur + c] = v; // top-right
                    out[(cur + r) * next + c] = v; // bottom-left
                    out[(cur + r) * next + cur + c] = -v; // bottom-right
                }
            }
            codes = out;
            cur = next;
        }
        Ok(codes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn rejects_non_pow2() {
        assert!(OvsfBasis::new(6).is_err());
        assert!(OvsfBasis::new(0).is_err());
    }

    #[test]
    fn h2_matches_paper() {
        let b = OvsfBasis::new(2).unwrap();
        assert_eq!(b.code(0), vec![1, 1]);
        assert_eq!(b.code(1), vec![1, -1]);
    }

    #[test]
    fn h4_matches_kronecker() {
        let b = OvsfBasis::new(4).unwrap();
        assert_eq!(b.code(0), vec![1, 1, 1, 1]);
        assert_eq!(b.code(1), vec![1, -1, 1, -1]);
        assert_eq!(b.code(2), vec![1, 1, -1, -1]);
        assert_eq!(b.code(3), vec![1, -1, -1, 1]);
    }

    #[test]
    fn closed_form_matches_dense_sylvester_oracle() {
        for l in [1usize, 2, 4, 16, 64, 256] {
            let dense = OvsfBasis::dense_codes(l).unwrap();
            let b = OvsfBasis::new(l).unwrap();
            for j in 0..l {
                for t in 0..l {
                    assert_eq!(
                        b.at(j, t),
                        dense[j * l + t],
                        "sign mismatch at (j={j}, t={t}), L={l}"
                    );
                }
            }
        }
    }

    #[test]
    fn rows_mutually_orthogonal() {
        for l in [2usize, 4, 8, 16, 64, 256] {
            let b = OvsfBasis::new(l).unwrap();
            for i in 0..l.min(16) {
                for j in 0..l.min(16) {
                    let d = b.dot(i, j);
                    if i == j {
                        assert_eq!(d, l as i64);
                    } else {
                        assert_eq!(d, 0, "codes {i},{j} of L={l} not orthogonal");
                    }
                }
            }
        }
    }

    #[test]
    fn packed_dot_matches_scalar_dot() {
        forall("ovsf-dot-packed-vs-scalar", 64, |rng| {
            let l = 1usize << rng.gen_range(0, 9); // 1..512
            let b = OvsfBasis::new(l).unwrap();
            let i = rng.gen_range(0, l as u64 - 1) as usize;
            let j = rng.gen_range(0, l as u64 - 1) as usize;
            assert_eq!(b.dot(i, j), b.dot_scalar(i, j), "L={l} i={i} j={j}");
        });
    }

    #[test]
    fn orthogonality_property_random_pairs() {
        forall("ovsf-orthogonal", 64, |rng| {
            let l = 1usize << rng.gen_range(1, 9); // 2..256
            let b = OvsfBasis::new(l).unwrap();
            let i = rng.gen_range(0, l as u64 - 1) as usize;
            let j = rng.gen_range(0, l as u64 - 1) as usize;
            let expect = if i == j { l as i64 } else { 0 };
            assert_eq!(b.dot(i, j), expect);
        });
    }

    #[test]
    fn packing_round_trips() {
        forall("ovsf-pack-roundtrip", 32, |rng| {
            let l = 1usize << rng.gen_range(1, 8);
            let b = OvsfBasis::new(l).unwrap();
            let j = rng.gen_range(0, l as u64 - 1) as usize;
            let packed = b.packed(j);
            assert_eq!(OvsfBasis::unpack(&packed, l), b.code(j));
        });
    }

    #[test]
    fn packed_emission_spans_multiple_words() {
        // L = 128 ⇒ two u64 words per code; cross-check against code().
        let b = OvsfBasis::new(128).unwrap();
        for j in [0usize, 1, 63, 64, 127] {
            let packed = b.packed(j);
            assert_eq!(packed.len(), 2);
            assert_eq!(OvsfBasis::unpack(&packed, 128), b.code(j), "j={j}");
        }
    }

    #[test]
    fn tree_construction_spans_same_set() {
        // The tree codes are a permutation of the Sylvester rows.
        for l in [2usize, 4, 8, 16] {
            let b = OvsfBasis::new(l).unwrap();
            let sylvester: std::collections::HashSet<Vec<i8>> =
                (0..l).map(|j| b.code(j)).collect();
            let tree: std::collections::HashSet<Vec<i8>> = (0..l)
                .map(|j| OvsfBasis::tree_code(l, j).unwrap())
                .collect();
            assert_eq!(sylvester, tree, "L={l}");
        }
    }

    #[test]
    fn storage_matches_bit_count() {
        let b = OvsfBasis::new(16).unwrap();
        assert_eq!(b.storage_bits(), 256);
    }

    #[test]
    fn construction_is_instant_at_resnet_scale() {
        // The whole point: L=8192 used to materialise 64 MB; now O(1).
        let b = OvsfBasis::new(8192).unwrap();
        assert_eq!(b.len(), 8192);
        assert_eq!(b.at(0, 0), 1);
        assert_eq!(b.at(8191, 8191), OvsfBasis::sign(8191, 8191));
    }
}
