//! Basis-subset selection for compression ratios ρ < 1 (paper §6.1).
//!
//! Two strategies, compared in the paper's Table 3:
//!
//! * **Sequential** — keep the first `⌊ρ·L⌉` codes (simpler objective,
//!   possibly less expressive filters).
//! * **IterativeDrop** — iteratively discard the code with the smallest
//!   associated `|α|` until the target ratio is reached (data-dependent,
//!   consistently better in the paper).

use crate::ovsf::codes::OvsfBasis;
use crate::util::n_basis;

/// Strategy for choosing which `⌊ρ·L⌉` of the `L` codes to keep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BasisSelection {
    /// Keep codes `0..⌊ρ·L⌉` in construction order.
    Sequential,
    /// Iteratively drop the code with the smallest `|α|` magnitude.
    IterativeDrop,
}

impl std::fmt::Display for BasisSelection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BasisSelection::Sequential => write!(f, "sequential"),
            BasisSelection::IterativeDrop => write!(f, "iterative"),
        }
    }
}

/// The kept subset of a basis for one filter: indices + their coefficients.
#[derive(Clone, Debug, PartialEq)]
pub struct SelectedBasis {
    /// Kept code indices, ascending.
    pub indices: Vec<usize>,
    /// Coefficient for each kept index (same order as `indices`).
    pub alphas: Vec<f32>,
}

impl SelectedBasis {
    /// Number of kept codes.
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    /// `true` if nothing was kept.
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }
}

/// Select a subset of `basis` for a target vector with full-basis
/// coefficients `alphas` (one per code), at ratio `rho`.
///
/// For both strategies the surviving coefficients are unchanged: the basis
/// is orthogonal, so the least-squares coefficients of the kept subset equal
/// the projections onto the kept codes.
pub fn select(
    strategy: BasisSelection,
    basis: &OvsfBasis,
    alphas: &[f32],
    rho: f64,
) -> SelectedBasis {
    let l = basis.len();
    assert_eq!(alphas.len(), l, "need one α per basis code");
    let keep = n_basis(rho, l);
    match strategy {
        BasisSelection::Sequential => SelectedBasis {
            indices: (0..keep).collect(),
            alphas: alphas[..keep].to_vec(),
        },
        BasisSelection::IterativeDrop => {
            // Dropping the smallest |α| one at a time is equivalent to
            // keeping the `keep` largest |α| (orthogonality ⇒ no re-fit
            // needed between drops), with the iterative tie rule — equal
            // |α| drops the later index first — mapping to "prefer the
            // earlier index". One O(L log L) sort instead of the former
            // O(L²) scan-and-remove loop (the §Perf regression-stage fix).
            let mut order: Vec<usize> = (0..l).collect();
            order.sort_unstable_by(|&a, &b| {
                alphas[b].abs().total_cmp(&alphas[a].abs()).then(a.cmp(&b))
            });
            let mut live = order[..keep].to_vec();
            live.sort_unstable();
            SelectedBasis {
                alphas: live.iter().map(|&i| alphas[i]).collect(),
                indices: live,
            }
        }
    }
}

/// Residual energy `E = ‖v − Σ α_j b_j‖²` of a selection against a target
/// vector (paper Eq. 2's error term).
pub fn residual_energy(
    basis: &OvsfBasis,
    sel: &SelectedBasis,
    target: &[f32],
) -> f64 {
    // Selection-aware: `E = n · mse` via the single-FWHT analytic form —
    // no O(L·|sel|) dense accumulation.
    crate::ovsf::regress::mse(basis, sel, target) * target.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ovsf::regress::project;
    use crate::util::check::forall;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn sequential_keeps_prefix() {
        let b = OvsfBasis::new(8).unwrap();
        let alphas: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let s = select(BasisSelection::Sequential, &b, &alphas, 0.5);
        assert_eq!(s.indices, vec![0, 1, 2, 3]);
        assert_eq!(s.alphas, vec![0.0, 1.0, 2.0, 3.0]);
    }

    /// The paper's literal procedure: drop the smallest |α| one at a time
    /// (tie: later index first). Oracle for the sort-based fast path.
    fn iterative_drop_reference(alphas: &[f32], keep: usize) -> Vec<usize> {
        let mut live: Vec<usize> = (0..alphas.len()).collect();
        while live.len() > keep {
            let (pos, _) = live
                .iter()
                .enumerate()
                .min_by(|(_, &a), (_, &b)| {
                    alphas[a]
                        .abs()
                        .partial_cmp(&alphas[b].abs())
                        .unwrap()
                        .then(b.cmp(&a))
                })
                .expect("non-empty");
            live.remove(pos);
        }
        live.sort_unstable();
        live
    }

    #[test]
    fn sort_based_drop_matches_iterative_reference() {
        forall("select-sort-vs-iterative", 48, |rng| {
            let l = 1usize << rng.gen_range(2, 7); // 4..64
            let b = OvsfBasis::new(l).unwrap();
            // Quantised α's to exercise the tie rule frequently.
            let alphas: Vec<f32> = (0..l)
                .map(|_| (rng.gen_range(0, 6) as f32 - 3.0) * 0.5)
                .collect();
            let rho = *rng.choose(&[0.25, 0.5, 0.75, 1.0]);
            let fast = select(BasisSelection::IterativeDrop, &b, &alphas, rho);
            let keep = crate::util::n_basis(rho, l);
            let expect = iterative_drop_reference(&alphas, keep);
            assert_eq!(fast.indices, expect, "L={l} ρ={rho} α={alphas:?}");
        });
    }

    #[test]
    fn iterative_keeps_largest_magnitude() {
        let b = OvsfBasis::new(8).unwrap();
        let alphas = vec![0.1f32, -5.0, 0.2, 4.0, -0.05, 3.0, 0.0, 2.0];
        let s = select(BasisSelection::IterativeDrop, &b, &alphas, 0.5);
        assert_eq!(s.indices, vec![1, 3, 5, 7]);
        assert_eq!(s.alphas, vec![-5.0, 4.0, 3.0, 2.0]);
    }

    #[test]
    fn iterative_never_worse_than_sequential() {
        forall("iterative-beats-sequential", 40, |rng| {
            let l = 1usize << rng.gen_range(2, 6); // 4..32
            let b = OvsfBasis::new(l).unwrap();
            let target = rng.normal_vec(l);
            let alphas = project(&b, &target);
            let rho = [0.25, 0.5, 0.75][rng.gen_range(0, 2) as usize];
            let seq = select(BasisSelection::Sequential, &b, &alphas, rho);
            let ite = select(BasisSelection::IterativeDrop, &b, &alphas, rho);
            let e_seq = residual_energy(&b, &seq, &target);
            let e_ite = residual_energy(&b, &ite, &target);
            assert!(
                e_ite <= e_seq + 1e-6,
                "iterative {e_ite} worse than sequential {e_seq}"
            );
        });
    }

    #[test]
    fn energy_monotone_in_rho() {
        // Paper Eq. 2: ε → 0 as ρ increases.
        let mut rng = Xoshiro256::seed_from_u64(3);
        let b = OvsfBasis::new(16).unwrap();
        let target = rng.normal_vec(16);
        let alphas = project(&b, &target);
        let mut prev = f64::INFINITY;
        for rho in [0.125, 0.25, 0.5, 0.75, 1.0] {
            let s = select(BasisSelection::IterativeDrop, &b, &alphas, rho);
            let e = residual_energy(&b, &s, &target);
            assert!(e <= prev + 1e-9, "energy not monotone at ρ={rho}");
            prev = e;
        }
        assert!(prev < 1e-6, "full basis must reconstruct exactly");
    }
}
