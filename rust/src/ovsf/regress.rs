//! Coefficient regression from pre-trained filters (paper §6.1, Eq. 2).
//!
//! `α* = argmin_α ‖Σ_j α_j b_j − f̂‖²`. With the full orthogonal basis the
//! solution is the exact projection `α_j = ⟨f̂, b_j⟩ / L`. The paper uses
//! this to initialise OVSF models from pre-trained CNNs (ImageNet setting).
//!
//! Because the OVSF basis is the Sylvester–Hadamard matrix, the projection
//! is a Walsh–Hadamard transform: [`project`] runs one in-place O(L log L)
//! [`fwht`] instead of `L` dense dot products, and [`reconstruct_vec`] is a
//! sparse scatter of the kept α's followed by one inverse FWHT (`H` is
//! symmetric with `H² = L·I`, so the inverse transform *is* the forward
//! butterfly). [`mse`] exploits orthogonality to avoid materialising the
//! reconstruction at all.

use crate::ovsf::basis::SelectedBasis;
use crate::ovsf::codes::OvsfBasis;

/// In-place fast Walsh–Hadamard transform in natural (Hadamard) order:
/// `data ← H_L · data` with `H[j][t] = (−1)^popcount(j & t)`. O(L log L)
/// butterflies; `data.len()` must be a power of two (or 0/1, a no-op).
pub fn fwht(data: &mut [f64]) {
    let n = data.len();
    debug_assert!(n == 0 || n.is_power_of_two(), "FWHT length must be 2^k");
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let x = data[j];
                let y = data[j + h];
                data[j] = x + y;
                data[j + h] = x - y;
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

/// Exact projection of `target` onto the full basis: one α per code, via a
/// single FWHT (`α = H·f̂ / L`).
pub fn project(basis: &OvsfBasis, target: &[f32]) -> Vec<f32> {
    let mut scratch = Vec::new();
    let mut out = Vec::new();
    project_into(basis, target, &mut scratch, &mut out);
    out
}

/// Allocation-reusing variant of [`project`]: `scratch` and `out` are
/// cleared and refilled (hot path for per-filter batch regression).
pub fn project_into(
    basis: &OvsfBasis,
    target: &[f32],
    scratch: &mut Vec<f64>,
    out: &mut Vec<f32>,
) {
    let l = basis.len();
    assert_eq!(target.len(), l, "target length must equal basis length");
    scratch.clear();
    scratch.extend(target.iter().map(|&v| v as f64));
    fwht(scratch);
    let inv_l = 1.0f64 / l as f64;
    out.clear();
    out.extend(scratch.iter().map(|&a| (a * inv_l) as f32));
}

/// Reconstruct a vector from a (possibly partial) selection: scatter the
/// α's to their code indices, then one inverse FWHT (`f = H·α`).
pub fn reconstruct_vec(basis: &OvsfBasis, sel: &SelectedBasis) -> Vec<f32> {
    let mut scratch = Vec::new();
    let mut out = Vec::new();
    reconstruct_into(basis, sel, &mut scratch, &mut out);
    out
}

/// Allocation-reusing variant of [`reconstruct_vec`].
pub fn reconstruct_into(
    basis: &OvsfBasis,
    sel: &SelectedBasis,
    scratch: &mut Vec<f64>,
    out: &mut Vec<f32>,
) {
    let l = basis.len();
    scratch.clear();
    scratch.resize(l, 0.0);
    for (k, &j) in sel.indices.iter().enumerate() {
        debug_assert!(j < l, "selected index {j} out of range (L={l})");
        scratch[j] = sel.alphas[k] as f64;
    }
    fwht(scratch);
    out.clear();
    out.extend(scratch.iter().map(|&v| v as f32));
}

/// Mean squared reconstruction error for a selection against a target.
///
/// Selection-aware: by orthogonality,
/// `‖t − Σ α_j b_j‖² = ‖t‖² − 2L·Σ α_j p_j + L·Σ α_j²` where `p = H·t/L`
/// is the full projection — one O(L log L) transform plus O(|sel|) work,
/// never materialising the reconstruction.
pub fn mse(basis: &OvsfBasis, sel: &SelectedBasis, target: &[f32]) -> f64 {
    let l = basis.len();
    assert_eq!(target.len(), l);
    let energy: f64 = target.iter().map(|&t| (t as f64).powi(2)).sum();
    let mut scratch: Vec<f64> = target.iter().map(|&v| v as f64).collect();
    fwht(&mut scratch);
    let lf = l as f64;
    let mut cross = 0.0f64; // Σ α_j · ⟨t, b_j⟩
    let mut alpha_sq = 0.0f64; // Σ α_j²
    for (k, &j) in sel.indices.iter().enumerate() {
        let a = sel.alphas[k] as f64;
        cross += a * scratch[j];
        alpha_sq += a * a;
    }
    // Cancellation can drive the analytic form slightly negative at exact
    // reconstruction; clamp to the mathematically valid range.
    ((energy - 2.0 * cross + lf * alpha_sq) / lf).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ovsf::basis::{select, BasisSelection};
    use crate::util::check::forall;

    /// Dense-matrix oracle of the projection (the pre-FWHT implementation).
    fn project_dense(basis: &OvsfBasis, target: &[f32]) -> Vec<f32> {
        let l = basis.len();
        let dense = OvsfBasis::dense_codes(l).unwrap();
        let inv_l = 1.0f64 / l as f64;
        (0..l)
            .map(|j| {
                let mut acc = 0.0f64;
                for (t, &v) in target.iter().enumerate() {
                    acc += v as f64 * dense[j * l + t] as f64;
                }
                (acc * inv_l) as f32
            })
            .collect()
    }

    /// Dense-matrix oracle of the reconstruction.
    fn reconstruct_dense(basis: &OvsfBasis, sel: &SelectedBasis) -> Vec<f32> {
        let l = basis.len();
        let dense = OvsfBasis::dense_codes(l).unwrap();
        let mut out = vec![0.0f32; l];
        for (k, &j) in sel.indices.iter().enumerate() {
            let a = sel.alphas[k];
            for (t, o) in out.iter_mut().enumerate() {
                *o += a * dense[j * l + t] as f32;
            }
        }
        out
    }

    #[test]
    fn fwht_matches_dense_hadamard_multiply() {
        forall("fwht-vs-dense", 32, |rng| {
            let l = 1usize << rng.gen_range(0, 9); // 1..256
            let dense = OvsfBasis::dense_codes(l).unwrap();
            let v = rng.normal_vec(l);
            let mut data: Vec<f64> = v.iter().map(|&x| x as f64).collect();
            fwht(&mut data);
            for j in 0..l {
                let expect: f64 = (0..l)
                    .map(|t| v[t] as f64 * dense[j * l + t] as f64)
                    .sum();
                assert!(
                    (data[j] - expect).abs() < 1e-9 * expect.abs().max(1.0),
                    "row {j} of L={l}: {} vs {expect}",
                    data[j]
                );
            }
        });
    }

    #[test]
    fn project_matches_dense_oracle() {
        forall("project-fwht-vs-dense", 24, |rng| {
            let l = 1usize << rng.gen_range(1, 9); // 2..256
            let b = OvsfBasis::new(l).unwrap();
            let target = rng.normal_vec(l);
            let fast = project(&b, &target);
            let slow = project_dense(&b, &target);
            for (j, (a, e)) in fast.iter().zip(&slow).enumerate() {
                assert!((a - e).abs() < 1e-4, "α_{j} mismatch: {a} vs {e} (L={l})");
            }
        });
    }

    #[test]
    fn reconstruct_matches_dense_oracle() {
        forall("reconstruct-fwht-vs-dense", 24, |rng| {
            let l = 1usize << rng.gen_range(1, 9);
            let b = OvsfBasis::new(l).unwrap();
            let target = rng.normal_vec(l);
            let alphas = project(&b, &target);
            let rho = *rng.choose(&[0.25, 0.5, 1.0]);
            let sel = select(BasisSelection::IterativeDrop, &b, &alphas, rho);
            let fast = reconstruct_vec(&b, &sel);
            let slow = reconstruct_dense(&b, &sel);
            for (t, (a, e)) in fast.iter().zip(&slow).enumerate() {
                assert!((a - e).abs() < 1e-4, "t={t}: {a} vs {e} (L={l}, ρ={rho})");
            }
        });
    }

    #[test]
    fn full_projection_reconstructs_exactly() {
        forall("projection-exact", 32, |rng| {
            let l = 1usize << rng.gen_range(1, 8); // 2..128
            let b = OvsfBasis::new(l).unwrap();
            let target = rng.normal_vec(l);
            let alphas = project(&b, &target);
            let sel = select(BasisSelection::Sequential, &b, &alphas, 1.0);
            let recon = reconstruct_vec(&b, &sel);
            for (t, r) in target.iter().zip(&recon) {
                assert!((t - r).abs() < 1e-4, "t={t} r={r} (L={l})");
            }
        });
    }

    #[test]
    fn projection_of_code_is_indicator() {
        let b = OvsfBasis::new(8).unwrap();
        // target = 2.5 * code 3  ⇒ α = [0,0,0,2.5,0,...]
        let target: Vec<f32> = b.code(3).iter().map(|&v| 2.5 * v as f32).collect();
        let alphas = project(&b, &target);
        for (j, &a) in alphas.iter().enumerate() {
            if j == 3 {
                assert!((a - 2.5).abs() < 1e-6);
            } else {
                assert!(a.abs() < 1e-6);
            }
        }
    }

    #[test]
    fn mse_matches_explicit_reconstruction() {
        forall("mse-analytic-vs-explicit", 32, |rng| {
            let l = 1usize << rng.gen_range(1, 8);
            let b = OvsfBasis::new(l).unwrap();
            let target = rng.normal_vec(l);
            let mut alphas = project(&b, &target);
            // Perturb so the selection-aware path sees non-projection α's.
            if rng.gen_range(0, 1) == 1 {
                let k = rng.gen_range(0, l as u64 - 1) as usize;
                alphas[k] += 0.25;
            }
            let rho = *rng.choose(&[0.25, 0.5, 1.0]);
            let sel = select(BasisSelection::IterativeDrop, &b, &alphas, rho);
            let analytic = mse(&b, &sel, &target);
            let recon = reconstruct_vec(&b, &sel);
            let explicit: f64 = target
                .iter()
                .zip(&recon)
                .map(|(&t, &r)| ((t - r) as f64).powi(2))
                .sum::<f64>()
                / l as f64;
            assert!(
                (analytic - explicit).abs() < 1e-6 * explicit.max(1.0),
                "mse {analytic} vs explicit {explicit} (L={l}, ρ={rho})"
            );
        });
    }

    #[test]
    fn partial_projection_is_least_squares_optimal() {
        // For an orthogonal basis, perturbing any kept α away from the
        // projection can only increase the error.
        forall("projection-optimal", 24, |rng| {
            let l = 16usize;
            let b = OvsfBasis::new(l).unwrap();
            let target = rng.normal_vec(l);
            let alphas = project(&b, &target);
            let sel = select(BasisSelection::IterativeDrop, &b, &alphas, 0.5);
            let base = mse(&b, &sel, &target);
            let mut worse = sel.clone();
            let k = rng.gen_range(0, worse.alphas.len() as u64 - 1) as usize;
            worse.alphas[k] += 0.1;
            assert!(mse(&b, &worse, &target) >= base - 1e-9);
        });
    }
}
