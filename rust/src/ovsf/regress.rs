//! Coefficient regression from pre-trained filters (paper §6.1, Eq. 2).
//!
//! `α* = argmin_α ‖Σ_j α_j b_j − f̂‖²`. With the full orthogonal basis the
//! solution is the exact projection `α_j = ⟨f̂, b_j⟩ / L`. The paper uses
//! this to initialise OVSF models from pre-trained CNNs (ImageNet setting).

use crate::ovsf::basis::SelectedBasis;
use crate::ovsf::codes::OvsfBasis;

/// Exact projection of `target` onto the full basis: one α per code.
pub fn project(basis: &OvsfBasis, target: &[f32]) -> Vec<f32> {
    let l = basis.len();
    assert_eq!(target.len(), l, "target length must equal basis length");
    let inv_l = 1.0f64 / l as f64;
    (0..l)
        .map(|j| {
            // Slice-wise walk (no per-element bounds re-check via `at`).
            let code = basis.code(j);
            let mut acc = 0.0f64;
            for (&v, &s) in target.iter().zip(code) {
                acc += v as f64 * s as f64;
            }
            (acc * inv_l) as f32
        })
        .collect()
}

/// Reconstruct a vector from a (possibly partial) selection.
pub fn reconstruct_vec(basis: &OvsfBasis, sel: &SelectedBasis) -> Vec<f32> {
    let l = basis.len();
    let mut out = vec![0.0f32; l];
    for (k, &j) in sel.indices.iter().enumerate() {
        let a = sel.alphas[k];
        let code = basis.code(j);
        for (o, &c) in out.iter_mut().zip(code) {
            *o += a * c as f32;
        }
    }
    out
}

/// Mean squared reconstruction error for a selection against a target.
pub fn mse(basis: &OvsfBasis, sel: &SelectedBasis, target: &[f32]) -> f64 {
    let recon = reconstruct_vec(basis, sel);
    let n = target.len() as f64;
    target
        .iter()
        .zip(&recon)
        .map(|(&t, &r)| ((t - r) as f64).powi(2))
        .sum::<f64>()
        / n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ovsf::basis::{select, BasisSelection};
    use crate::util::check::forall;

    #[test]
    fn full_projection_reconstructs_exactly() {
        forall("projection-exact", 32, |rng| {
            let l = 1usize << rng.gen_range(1, 8); // 2..128
            let b = OvsfBasis::new(l).unwrap();
            let target = rng.normal_vec(l);
            let alphas = project(&b, &target);
            let sel = select(BasisSelection::Sequential, &b, &alphas, 1.0);
            let recon = reconstruct_vec(&b, &sel);
            for (t, r) in target.iter().zip(&recon) {
                assert!((t - r).abs() < 1e-4, "t={t} r={r} (L={l})");
            }
        });
    }

    #[test]
    fn projection_of_code_is_indicator() {
        let b = OvsfBasis::new(8).unwrap();
        // target = 2.5 * code 3  ⇒ α = [0,0,0,2.5,0,...]
        let target: Vec<f32> = b.code(3).iter().map(|&v| 2.5 * v as f32).collect();
        let alphas = project(&b, &target);
        for (j, &a) in alphas.iter().enumerate() {
            if j == 3 {
                assert!((a - 2.5).abs() < 1e-6);
            } else {
                assert!(a.abs() < 1e-6);
            }
        }
    }

    #[test]
    fn partial_projection_is_least_squares_optimal() {
        // For an orthogonal basis, perturbing any kept α away from the
        // projection can only increase the error.
        forall("projection-optimal", 24, |rng| {
            let l = 16usize;
            let b = OvsfBasis::new(l).unwrap();
            let target = rng.normal_vec(l);
            let alphas = project(&b, &target);
            let sel = select(BasisSelection::IterativeDrop, &b, &alphas, 0.5);
            let base = mse(&b, &sel, &target);
            let mut worse = sel.clone();
            let k = rng.gen_range(0, worse.alphas.len() as u64 - 1) as usize;
            worse.alphas[k] += 0.1;
            assert!(mse(&b, &worse, &target) >= base - 1e-9);
        });
    }
}
