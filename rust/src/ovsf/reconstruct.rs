//! Filter reconstruction from OVSF codes (paper Fig. 1 and §6.1).
//!
//! A conv layer `N_out × N_in × K × K` is built filter-by-filter: each of
//! the `N_out` filters is a linear combination of `⌊ρ·L⌉` codes of length
//! `L = N_in·K'·K'`, reshaped to `N_in × K' × K'`. OVSF codes force `K'` to
//! be a power of two, so `K = 3` filters are *extracted* from `K' = 4`
//! reconstructions either by cropping or by 2×2 stride-1 average pooling —
//! the paper's two strategies (Table 3).

use crate::error::{Error, Result};
use crate::ovsf::basis::{select, BasisSelection, SelectedBasis};
use crate::ovsf::codes::OvsfBasis;
use crate::ovsf::regress::{project_into, reconstruct_into};
use crate::util::threadpool::{ScopedTask, ThreadPool};
use crate::util::{is_pow2, next_pow2};

/// Shard count for per-filter batch regression/reconstruction. Filters are
/// independent, so the batch is sharded over the persistent process
/// [`ThreadPool`] (zero-dep constraint: no rayon; the pool replaces the
/// old per-call `std::thread::scope` spawning). Small batches stay
/// single-threaded — scratch-buffer reuse dominates there and the task
/// bookkeeping would not amortise.
fn filter_shards(n_filters: usize, code_len: usize) -> usize {
    // ~2^18 butterfly-ops per shard keeps scheduling cost < 5% of work.
    let work = n_filters.saturating_mul(code_len.max(1));
    if work < (1 << 18) {
        return 1;
    }
    // The caller runs one shard inline, so threads + 1 shards keep the
    // whole pool and the caller busy.
    (ThreadPool::global().threads() + 1).min(n_filters)
}

/// How to obtain a `3×3` (generally non-pow2 `K×K`) filter from the
/// power-of-two OVSF reconstruction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Filter3x3Mode {
    /// Take the top-left `K×K` crop of the `K'×K'` reconstruction.
    Crop,
    /// Average-pool the `K'×K'` reconstruction down to `K×K`
    /// (window `K'−K+1`, stride 1).
    AdaptivePool,
}

impl std::fmt::Display for Filter3x3Mode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Filter3x3Mode::Crop => write!(f, "crop"),
            Filter3x3Mode::AdaptivePool => write!(f, "adaptive"),
        }
    }
}

/// An OVSF-parameterised convolutional layer: the compressed representation
/// (α coefficients + kept code indices per filter) and the geometry needed
/// to reconstruct the dense weights.
#[derive(Clone, Debug)]
pub struct OvsfLayer {
    /// Output channels (number of filters).
    pub n_out: usize,
    /// Input channels.
    pub n_in: usize,
    /// Target spatial kernel size (e.g. 3).
    pub k: usize,
    /// Power-of-two kernel size used for code construction (e.g. 4 for k=3).
    pub k_ovsf: usize,
    /// Compression ratio ρ ∈ (0, 1].
    pub rho: f64,
    /// Extraction mode when `k != k_ovsf`.
    pub mode: Filter3x3Mode,
    /// Per-filter kept basis.
    pub filters: Vec<SelectedBasis>,
}

impl OvsfLayer {
    /// Code length `L = N_in · K'²`.
    pub fn code_len(&self) -> usize {
        self.n_in * self.k_ovsf * self.k_ovsf
    }

    /// Number of α parameters stored for this layer
    /// (`N_out · ⌊ρ·K'²⌉·N_in` in the paper's accounting).
    pub fn n_alphas(&self) -> usize {
        self.filters.iter().map(|f| f.len()).sum()
    }

    /// Derive an OVSF layer from dense pre-trained weights
    /// (`weights.len() == n_out·n_in·k·k`, layout `[n_out][n_in][kh][kw]`)
    /// via exact projection + basis selection (paper §6.1 regression stage).
    pub fn from_weights(
        weights: &[f32],
        n_out: usize,
        n_in: usize,
        k: usize,
        rho: f64,
        strategy: BasisSelection,
        mode: Filter3x3Mode,
    ) -> Result<Self> {
        if weights.len() != n_out * n_in * k * k {
            return Err(Error::ShapeMismatch(format!(
                "weights len {} != {}·{}·{}·{}",
                weights.len(),
                n_out,
                n_in,
                k,
                k
            )));
        }
        if !is_pow2(n_in) {
            return Err(Error::ShapeMismatch(format!(
                "OVSF layers need power-of-two N_in, got {n_in}"
            )));
        }
        let k_ovsf = if is_pow2(k) { k } else { next_pow2(k) };
        let l = n_in * k_ovsf * k_ovsf;
        let basis = OvsfBasis::new(l)?;
        // Per-shard worker body: fit filters `[lo, lo+out.len())` into
        // `out`, reusing one scratch set across the shard.
        let fit_shard = |lo: usize, out: &mut [SelectedBasis]| {
            let mut target = vec![0.0f32; l];
            let mut scratch: Vec<f64> = Vec::with_capacity(l);
            let mut alphas: Vec<f32> = Vec::with_capacity(l);
            for (i, slot) in out.iter_mut().enumerate() {
                let o = lo + i;
                // Embed the K×K filter into the K'×K' frame (zero padding
                // at the right/bottom) so the projection targets the OVSF
                // geometry.
                target.iter_mut().for_each(|x| *x = 0.0);
                for c in 0..n_in {
                    for kh in 0..k {
                        for kw in 0..k {
                            let src = ((o * n_in + c) * k + kh) * k + kw;
                            let dst = (c * k_ovsf + kh) * k_ovsf + kw;
                            target[dst] = weights[src];
                        }
                    }
                }
                project_into(&basis, &target, &mut scratch, &mut alphas);
                *slot = select(strategy, &basis, &alphas, rho);
            }
        };
        let n_shards = filter_shards(n_out, l);
        let mut filters: Vec<SelectedBasis> = vec![
            SelectedBasis {
                indices: Vec::new(),
                alphas: Vec::new(),
            };
            n_out
        ];
        if n_shards <= 1 {
            fit_shard(0, filters.as_mut_slice());
        } else {
            let shard_len = n_out.div_ceil(n_shards);
            let fit_shard_ref = &fit_shard;
            let tasks: Vec<ScopedTask<'_>> = filters
                .chunks_mut(shard_len)
                .enumerate()
                .map(|(shard, out)| {
                    Box::new(move || fit_shard_ref(shard * shard_len, out)) as ScopedTask<'_>
                })
                .collect();
            ThreadPool::global().scope_run(tasks);
        }
        Ok(Self {
            n_out,
            n_in,
            k,
            k_ovsf,
            rho,
            mode,
            filters,
        })
    }

    /// Random OVSF layer (for synthetic workloads / tests): i.i.d. normal α
    /// on a strategy-selected subset.
    pub fn random(
        rng: &mut crate::util::prng::Xoshiro256,
        n_out: usize,
        n_in: usize,
        k: usize,
        rho: f64,
        mode: Filter3x3Mode,
    ) -> Result<Self> {
        let k_ovsf = if is_pow2(k) { k } else { next_pow2(k) };
        let l = n_in * k_ovsf * k_ovsf;
        let basis = OvsfBasis::new(l)?;
        let filters = (0..n_out)
            .map(|_| {
                let alphas = rng.normal_vec(l);
                select(BasisSelection::IterativeDrop, &basis, &alphas, rho)
            })
            .collect();
        Ok(Self {
            n_out,
            n_in,
            k,
            k_ovsf,
            rho,
            mode,
            filters,
        })
    }

    /// Tile-granular reconstruction: filters `[o0, o1)` only — one column
    /// slab of the layer in GEMM terms — written into the caller's `out`
    /// (`(o1−o0)·n_in·k·k` dense layout), with `scratch`/`frame` reused
    /// across calls. This is the bounded-memory unit the streaming engine
    /// consumes: a caller walking slabs never holds more than one slab of
    /// dense weights plus the O(L) scratch.
    pub fn reconstruct_filters_into(
        &self,
        o0: usize,
        o1: usize,
        scratch: &mut Vec<f64>,
        frame: &mut Vec<f32>,
        out: &mut [f32],
    ) -> Result<()> {
        if o0 >= o1 || o1 > self.n_out {
            return Err(Error::ShapeMismatch(format!(
                "filter slab [{o0}, {o1}) out of range for n_out = {}",
                self.n_out
            )));
        }
        let l = self.code_len();
        let basis = OvsfBasis::new(l)?;
        let filter_stride = self.n_in * self.k * self.k;
        if out.len() != (o1 - o0) * filter_stride {
            return Err(Error::ShapeMismatch(format!(
                "slab output length {} != {}·{filter_stride}",
                out.len(),
                o1 - o0
            )));
        }
        let chunk = self.k_ovsf * self.k_ovsf;
        let sels = self.filters[o0..o1].iter();
        for (sel, dst) in sels.zip(out.chunks_mut(filter_stride)) {
            reconstruct_into(&basis, sel, scratch, frame); // n_in × k' × k'
            for c in 0..self.n_in {
                let plane = &frame[c * chunk..(c + 1) * chunk];
                let extracted = extract_kxk(plane, self.k_ovsf, self.k, self.mode);
                dst[c * self.k * self.k..(c + 1) * self.k * self.k]
                    .copy_from_slice(&extracted);
            }
        }
        Ok(())
    }

    /// Per-layer symmetric int8 weight scale from the selected α sets:
    /// every reconstructed value is `Σ_j α_j·sign_j` with signs ±1, so the
    /// largest filter's `Σ_j |α_j|` bounds `|w|`; dividing by 127 gives a
    /// scale that never clips. Mirrors
    /// [`HwOvsfWeights::i8_scale`](crate::sim::hw_weights::HwOvsfWeights::i8_scale)
    /// for the layer-form representation.
    pub fn i8_scale(&self) -> f32 {
        let mut max_sum = 0.0f32;
        for sel in &self.filters {
            let sum: f32 = sel.alphas.iter().map(|a| a.abs()).sum();
            max_sum = max_sum.max(sum);
        }
        crate::util::fixed::I8Scheme::from_max_abs(max_sum).scale
    }

    /// Int8 twin of
    /// [`reconstruct_filters_into`](Self::reconstruct_filters_into): the
    /// FWHT reconstruction stays f32-exact and each dense weight is rounded
    /// exactly once as it is emitted into the WL-bit slab, using the
    /// caller's per-layer `scale` (normally [`i8_scale`](Self::i8_scale)).
    pub fn reconstruct_filters_into_i8(
        &self,
        o0: usize,
        o1: usize,
        scale: f32,
        scratch: &mut Vec<f64>,
        frame: &mut Vec<f32>,
        out: &mut [i8],
    ) -> Result<()> {
        if o0 >= o1 || o1 > self.n_out {
            return Err(Error::ShapeMismatch(format!(
                "filter slab [{o0}, {o1}) out of range for n_out = {}",
                self.n_out
            )));
        }
        if !(scale > 0.0 && scale.is_finite()) {
            return Err(Error::ShapeMismatch(format!(
                "i8 slab scale must be positive and finite, got {scale}"
            )));
        }
        let l = self.code_len();
        let basis = OvsfBasis::new(l)?;
        let filter_stride = self.n_in * self.k * self.k;
        if out.len() != (o1 - o0) * filter_stride {
            return Err(Error::ShapeMismatch(format!(
                "slab output length {} != {}·{filter_stride}",
                out.len(),
                o1 - o0
            )));
        }
        let scheme = crate::util::fixed::I8Scheme { scale };
        let chunk = self.k_ovsf * self.k_ovsf;
        let sels = self.filters[o0..o1].iter();
        for (sel, dst) in sels.zip(out.chunks_mut(filter_stride)) {
            reconstruct_into(&basis, sel, scratch, frame); // n_in × k' × k'
            for c in 0..self.n_in {
                let plane = &frame[c * chunk..(c + 1) * chunk];
                let extracted = extract_kxk(plane, self.k_ovsf, self.k, self.mode);
                for (d, w) in dst[c * self.k * self.k..(c + 1) * self.k * self.k]
                    .iter_mut()
                    .zip(&extracted)
                {
                    *d = scheme.quantise(*w);
                }
            }
        }
        Ok(())
    }

    /// Reconstruct the dense `n_out·n_in·k·k` weights (the software oracle
    /// of what CNN-WGen produces in hardware). Sharded over the persistent
    /// process [`ThreadPool`], each task streaming its contiguous filter
    /// slab through
    /// [`reconstruct_filters_into`](Self::reconstruct_filters_into).
    pub fn reconstruct(&self) -> Result<Vec<f32>> {
        let l = self.code_len();
        OvsfBasis::new(l)?; // validate geometry before sharding
        let filter_stride = self.n_in * self.k * self.k;
        let mut out = vec![0.0f32; self.n_out * filter_stride];
        let n_shards = filter_shards(self.n_out, l);
        let shard_len = self.n_out.div_ceil(n_shards);
        if n_shards <= 1 {
            let mut scratch: Vec<f64> = Vec::with_capacity(l);
            let mut frame: Vec<f32> = Vec::with_capacity(l);
            // Invariant: the 0..n_out range and `out` sizing come from the
            // same fields three lines up.
            #[allow(clippy::expect_used)]
            self.reconstruct_filters_into(0, self.n_out, &mut scratch, &mut frame, &mut out)
                .expect("full range derives from n_out");
            return Ok(out);
        }
        // Each task owns a disjoint slice of the output (contiguous filter
        // shard) plus scratch buffers reused across its filters.
        let shard_elems = (shard_len * filter_stride).max(1);
        let tasks: Vec<ScopedTask<'_>> = out
            .chunks_mut(shard_elems)
            .enumerate()
            .map(|(shard, out_shard)| {
                Box::new(move || {
                    let mut scratch: Vec<f64> = Vec::with_capacity(l);
                    let mut frame: Vec<f32> = Vec::with_capacity(l);
                    let o0 = shard * shard_len;
                    let o1 = (o0 + shard_len).min(self.n_out);
                    // Invariant: o0..o1 is clamped to n_out and out_shard
                    // is the matching chunk of the output buffer.
                    #[allow(clippy::expect_used)]
                    self.reconstruct_filters_into(o0, o1, &mut scratch, &mut frame, out_shard)
                        .expect("shard bounds derive from n_out");
                }) as ScopedTask<'_>
            })
            .collect();
        ThreadPool::global().scope_run(tasks);
        Ok(out)
    }
}

/// Extract a `k×k` filter plane from a `k'×k'` reconstruction.
pub fn extract_kxk(plane: &[f32], k_ovsf: usize, k: usize, mode: Filter3x3Mode) -> Vec<f32> {
    assert_eq!(plane.len(), k_ovsf * k_ovsf);
    assert!(k <= k_ovsf);
    if k == k_ovsf {
        return plane.to_vec();
    }
    match mode {
        Filter3x3Mode::Crop => {
            let mut out = Vec::with_capacity(k * k);
            for r in 0..k {
                for c in 0..k {
                    out.push(plane[r * k_ovsf + c]);
                }
            }
            out
        }
        Filter3x3Mode::AdaptivePool => {
            // Window w = k' − k + 1, stride 1 average pooling.
            let w = k_ovsf - k + 1;
            let inv = 1.0f32 / (w * w) as f32;
            let mut out = Vec::with_capacity(k * k);
            for r in 0..k {
                for c in 0..k {
                    let mut acc = 0.0f32;
                    for dr in 0..w {
                        for dc in 0..w {
                            acc += plane[(r + dr) * k_ovsf + (c + dc)];
                        }
                    }
                    out.push(acc * inv);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::prng::Xoshiro256;

    fn rand_weights(rng: &mut Xoshiro256, n: usize) -> Vec<f32> {
        rng.normal_vec(n)
    }

    #[test]
    fn full_rho_pow2_kernel_is_exact() {
        // ρ=1 and K already a power of two ⇒ reconstruction must be exact.
        forall("ovsf-layer-exact", 16, |rng| {
            let n_in = 1usize << rng.gen_range(0, 3); // 1..4... n_in must be pow2 ≥1
            let n_in = n_in.max(2);
            let n_out = rng.gen_range(1, 4) as usize;
            let k = [1usize, 2, 4][rng.gen_range(0, 2) as usize];
            let w = rand_weights(rng, n_out * n_in * k * k);
            let layer = OvsfLayer::from_weights(
                &w,
                n_out,
                n_in,
                k,
                1.0,
                BasisSelection::Sequential,
                Filter3x3Mode::Crop,
            )
            .unwrap();
            let r = layer.reconstruct().unwrap();
            for (a, b) in w.iter().zip(&r) {
                assert!((a - b).abs() < 1e-4, "exact reconstruction failed");
            }
        });
    }

    #[test]
    fn crop_of_full_rho_3x3_is_exact() {
        // With ρ=1 the 4×4 frame reproduces the zero-padded 3×3 exactly, so
        // the crop recovers the original 3×3 filter.
        let mut rng = Xoshiro256::seed_from_u64(5);
        let (n_out, n_in, k) = (4usize, 8usize, 3usize);
        let w = rand_weights(&mut rng, n_out * n_in * k * k);
        let layer = OvsfLayer::from_weights(
            &w,
            n_out,
            n_in,
            k,
            1.0,
            BasisSelection::IterativeDrop,
            Filter3x3Mode::Crop,
        )
        .unwrap();
        let r = layer.reconstruct().unwrap();
        for (a, b) in w.iter().zip(&r) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn reconstruction_error_decreases_with_rho() {
        let mut rng = Xoshiro256::seed_from_u64(17);
        let (n_out, n_in, k) = (2usize, 4usize, 4usize);
        let w = rand_weights(&mut rng, n_out * n_in * k * k);
        let mut prev = f64::INFINITY;
        for rho in [0.25, 0.5, 0.75, 1.0] {
            let layer = OvsfLayer::from_weights(
                &w,
                n_out,
                n_in,
                k,
                rho,
                BasisSelection::IterativeDrop,
                Filter3x3Mode::Crop,
            )
            .unwrap();
            let r = layer.reconstruct().unwrap();
            let err: f64 = w
                .iter()
                .zip(&r)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            assert!(err <= prev + 1e-9, "error not monotone at ρ={rho}");
            prev = err;
        }
    }

    #[test]
    fn filter_slabs_match_full_reconstruction() {
        forall("ovsf-filter-slabs", 8, |rng| {
            let (n_out, n_in, k) = (5usize, 4usize, 3usize);
            let w = rand_weights(rng, n_out * n_in * k * k);
            let layer = OvsfLayer::from_weights(
                &w,
                n_out,
                n_in,
                k,
                *rng.choose(&[0.5, 1.0]),
                BasisSelection::IterativeDrop,
                Filter3x3Mode::Crop,
            )
            .unwrap();
            let full = layer.reconstruct().unwrap();
            let stride = n_in * k * k;
            let slab_w = rng.gen_range(1, n_out as u64 + 1) as usize;
            let mut scratch = Vec::new();
            let mut frame = Vec::new();
            for o0 in (0..n_out).step_by(slab_w) {
                let o1 = (o0 + slab_w).min(n_out);
                let mut slab = vec![0.0f32; (o1 - o0) * stride];
                layer
                    .reconstruct_filters_into(o0, o1, &mut scratch, &mut frame, &mut slab)
                    .unwrap();
                assert_eq!(slab, full[o0 * stride..o1 * stride].to_vec());
            }
            // Bad ranges and lengths are rejected.
            let mut bad = vec![0.0f32; stride];
            assert!(layer
                .reconstruct_filters_into(n_out, n_out + 1, &mut scratch, &mut frame, &mut bad)
                .is_err());
            assert!(layer
                .reconstruct_filters_into(0, 2, &mut scratch, &mut frame, &mut bad)
                .is_err());
        });
    }

    #[test]
    fn i8_filter_slabs_match_quantised_reconstruction() {
        forall("ovsf-filter-slabs-i8", 8, |rng| {
            let (n_out, n_in, k) = (5usize, 4usize, 3usize);
            let layer = OvsfLayer::random(
                rng,
                n_out,
                n_in,
                k,
                *rng.choose(&[0.5, 1.0]),
                Filter3x3Mode::Crop,
            )
            .unwrap();
            let full = layer.reconstruct().unwrap();
            let scale = layer.i8_scale();
            assert!(scale > 0.0);
            let scheme = crate::util::fixed::I8Scheme { scale };
            let stride = n_in * k * k;
            let (mut scratch, mut frame) = (Vec::new(), Vec::new());
            let mut slab = vec![0i8; n_out * stride];
            layer
                .reconstruct_filters_into_i8(0, n_out, scale, &mut scratch, &mut frame, &mut slab)
                .unwrap();
            for (q, f) in slab.iter().zip(&full) {
                assert_eq!(*q, scheme.quantise(*f));
                assert!((scheme.dequantise(*q) - f).abs() <= scheme.max_error() + 1e-6);
            }
            let mut bad = vec![0i8; stride];
            assert!(layer
                .reconstruct_filters_into_i8(0, 1, 0.0, &mut scratch, &mut frame, &mut bad)
                .is_err());
        });
    }

    #[test]
    fn pool_extraction_shapes() {
        let plane: Vec<f32> = (0..16).map(|i| i as f32).collect(); // 4×4
        let crop = extract_kxk(&plane, 4, 3, Filter3x3Mode::Crop);
        assert_eq!(crop, vec![0.0, 1.0, 2.0, 4.0, 5.0, 6.0, 8.0, 9.0, 10.0]);
        let pool = extract_kxk(&plane, 4, 3, Filter3x3Mode::AdaptivePool);
        assert_eq!(pool.len(), 9);
        // window 2×2: pool[0] = mean(0,1,4,5) = 2.5
        assert!((pool[0] - 2.5).abs() < 1e-6);
        assert!((pool[8] - 12.5).abs() < 1e-6); // mean(10,11,14,15)
    }

    #[test]
    fn alpha_count_matches_rho() {
        let mut rng = Xoshiro256::seed_from_u64(23);
        let layer = OvsfLayer::random(&mut rng, 8, 16, 3, 0.25, Filter3x3Mode::Crop).unwrap();
        let l = layer.code_len();
        assert_eq!(l, 16 * 16);
        let per_filter = crate::util::n_basis(0.25, l);
        assert_eq!(layer.n_alphas(), 8 * per_filter);
    }

    #[test]
    fn rejects_bad_shapes() {
        let w = vec![0.0f32; 10];
        assert!(OvsfLayer::from_weights(
            &w,
            2,
            2,
            2,
            1.0,
            BasisSelection::Sequential,
            Filter3x3Mode::Crop
        )
        .is_err());
        let w = vec![0.0f32; 3 * 3 * 3 * 3];
        assert!(
            OvsfLayer::from_weights(
                &w,
                3,
                3,
                3,
                1.0,
                BasisSelection::Sequential,
                Filter3x3Mode::Crop
            )
            .is_err(),
            "non-pow2 N_in must be rejected"
        );
    }
}
