//! Per-layer bottleneck classification (paper Table 1's Bound row).
//!
//! The accelerator is a three-stage pipeline — (input transfer ∥ weights
//! generation) → engine → output transfer — whose initiation interval is
//! the max of the stage times (Eq. 8). The dominating stage classifies the
//! layer: IFM / OFM memory-bound, compute-bound, or weights-generation-bound.

/// Which pipeline stage bounds a layer's initiation interval.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bound {
    /// Memory-bound w.r.t. input feature maps.
    Ifm,
    /// Memory-bound w.r.t. output feature maps.
    Ofm,
    /// Compute-bound (processing engine).
    Compute,
    /// Weights-generation-bound (CNN-WGen).
    WGen,
}

impl Bound {
    /// The paper's single-letter labels (Table 1 footnote).
    pub fn label(&self) -> &'static str {
        match self {
            Bound::Ifm => "IFM",
            Bound::Ofm => "OFM",
            Bound::Compute => "C",
            Bound::WGen => "W",
        }
    }

    /// Classify from the four stage times (cycles).
    pub fn classify(t_mem_in: f64, t_wgen: f64, t_eng: f64, t_mem_out: f64) -> Bound {
        // Matches Eq. 8's nesting: stage 1 is max(t_mem_in, t_wgen).
        let stage1 = t_mem_in.max(t_wgen);
        let ii = stage1.max(t_eng).max(t_mem_out);
        if ii == stage1 {
            if t_mem_in >= t_wgen {
                Bound::Ifm
            } else {
                Bound::WGen
            }
        } else if ii == t_eng {
            Bound::Compute
        } else {
            Bound::Ofm
        }
    }
}

impl std::fmt::Display for Bound {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification_follows_max() {
        assert_eq!(Bound::classify(100.0, 10.0, 50.0, 5.0), Bound::Ifm);
        assert_eq!(Bound::classify(10.0, 100.0, 50.0, 5.0), Bound::WGen);
        assert_eq!(Bound::classify(10.0, 20.0, 90.0, 5.0), Bound::Compute);
        assert_eq!(Bound::classify(10.0, 20.0, 30.0, 95.0), Bound::Ofm);
    }

    #[test]
    fn ties_prefer_stage_order() {
        // Equal IFM and wgen → IFM (transfer and generation overlap; the
        // paper reports IFM when the memory stream is at least as long).
        assert_eq!(Bound::classify(50.0, 50.0, 10.0, 10.0), Bound::Ifm);
        // Stage-1 vs engine tie → stage 1 wins the max() nesting.
        assert_eq!(Bound::classify(50.0, 10.0, 50.0, 10.0), Bound::Ifm);
    }

    #[test]
    fn labels() {
        assert_eq!(Bound::Ifm.label(), "IFM");
        assert_eq!(Bound::WGen.label(), "W");
        assert_eq!(format!("{}", Bound::Compute), "C");
    }
}
