//! Dataflow variants (paper §4.2.1 "Applicability to Other Dataflows").
//!
//! The presented TiWGen instance targets output-stationary engines; the
//! paper notes that weight-stationary designs (e.g. the TPU) reuse each
//! weight tile for many cycles, so the OVSF generator "would have to
//! generate weights in longer periods" and the DSE "would automatically
//! adjust the resource allocation". This module models that: under
//! weight stationarity a generated `T_P×T_C` tile is reused across all
//! `⌈R/T_R⌉` row tiles, so the *required* generation rate — and hence the
//! pressure CNN-WGen puts on the pipeline — drops by that factor.

use crate::arch::DesignPoint;
#[cfg(test)]
use crate::arch::Platform;
use crate::perf::model::PerfModel;
use crate::util::ceil_div;
use crate::workload::{Network, RatioProfile};

/// Engine dataflow.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataflow {
    /// Output-stationary (the paper's engine; partial sums stay on-chip).
    OutputStationary,
    /// Weight-stationary (TPU-like; weights pinned, activations stream).
    WeightStationary,
}

/// Effective weights-generation cycles charged *per output tile* under a
/// dataflow: weight stationarity amortises one generation over the row
/// tiles that reuse the weight tile.
pub fn wgen_cycles_per_tile(
    model: &PerfModel,
    dataflow: Dataflow,
    sigma: &DesignPoint,
    layer: &crate::workload::layer::Layer,
    rho: f64,
) -> f64 {
    let raw = model.t_wgen(sigma, layer, rho);
    match dataflow {
        Dataflow::OutputStationary => raw,
        Dataflow::WeightStationary => {
            let row_tiles = ceil_div(layer.gemm().r, sigma.t_r).max(1);
            raw / row_tiles as f64
        }
    }
}

/// Network-level comparison of the two dataflows' wgen pressure: returns
/// `(os_bound_layers, ws_bound_layers)` — how many layers are weights-
/// generation-bound under each, at the given design point.
pub fn wgen_bound_layers(
    model: &PerfModel,
    sigma: &DesignPoint,
    net: &Network,
    profile: &RatioProfile,
) -> (usize, usize) {
    let mut os = 0usize;
    let mut ws = 0usize;
    for (i, layer) in net.layers.iter().enumerate() {
        if !layer.ovsf {
            continue;
        }
        let rho = profile.rho(i);
        let ceiling = model
            .t_mem_in(sigma, layer, 0.0)
            .max(model.t_eng(sigma, layer))
            .max(model.t_mem_out(sigma, layer));
        let os_w = wgen_cycles_per_tile(model, Dataflow::OutputStationary, sigma, layer, rho);
        let ws_w = wgen_cycles_per_tile(model, Dataflow::WeightStationary, sigma, layer, rho);
        if os_w > ceiling {
            os += 1;
        }
        if ws_w > ceiling {
            ws += 1;
        }
    }
    (os, ws)
}

/// The maximum ρ each dataflow can afford on a layer before generation
/// becomes the bottleneck — the knob the paper says the DSE would adjust.
pub fn max_affordable_rho(
    model: &PerfModel,
    dataflow: Dataflow,
    sigma: &DesignPoint,
    layer: &crate::workload::layer::Layer,
) -> f64 {
    let ceiling = model
        .t_mem_in(sigma, layer, 0.0)
        .max(model.t_eng(sigma, layer))
        .max(model.t_mem_out(sigma, layer));
    let mut best = 0.0;
    for &rho in crate::autotune::RHO_LADDER.iter() {
        if wgen_cycles_per_tile(model, dataflow, sigma, layer, rho) <= ceiling {
            best = rho;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::layer::Layer;
    use crate::workload::resnet;

    fn setup() -> (PerfModel, DesignPoint) {
        (
            PerfModel::new(Platform::z7045(), 4),
            DesignPoint::new(16, 64, 16, 96),
        )
    }

    #[test]
    fn weight_stationary_amortises_generation() {
        let (model, sigma) = setup();
        let layer = Layer::conv("t", 56, 56, 64, 64, 3, 1, 1, true);
        let os = wgen_cycles_per_tile(&model, Dataflow::OutputStationary, &sigma, &layer, 1.0);
        let ws = wgen_cycles_per_tile(&model, Dataflow::WeightStationary, &sigma, &layer, 1.0);
        let row_tiles = ceil_div(layer.gemm().r, sigma.t_r);
        assert!((os / ws - row_tiles as f64).abs() < 1e-9);
    }

    #[test]
    fn ws_never_more_wgen_bound_than_os() {
        let net = resnet::resnet18();
        let profile = RatioProfile::uniform(&net, 1.0);
        let (model, _) = setup();
        // Deliberately tiny generator to create pressure.
        let sigma = DesignPoint::new(8, 64, 16, 96);
        let (os, ws) = wgen_bound_layers(&model, &sigma, &net, &profile);
        assert!(ws <= os, "WS bound layers {ws} > OS {os}");
        assert!(os > 0, "tiny M at ρ=1 must bind some layers under OS");
    }

    #[test]
    fn ws_affords_higher_ratios() {
        let (model, _) = setup();
        let sigma = DesignPoint::new(8, 64, 16, 96);
        let layer = Layer::conv("deep", 14, 14, 256, 256, 3, 1, 1, true);
        let os = max_affordable_rho(&model, Dataflow::OutputStationary, &sigma, &layer);
        let ws = max_affordable_rho(&model, Dataflow::WeightStationary, &sigma, &layer);
        assert!(ws >= os, "WS {ws} < OS {os}");
    }

    #[test]
    fn fc_layers_identical_under_both() {
        // R = 1 for FC: nothing to amortise.
        let (model, sigma) = setup();
        let fc = Layer::fc("fc", 512, 1000);
        let os = wgen_cycles_per_tile(&model, Dataflow::OutputStationary, &sigma, &fc, 0.5);
        let ws = wgen_cycles_per_tile(&model, Dataflow::WeightStationary, &sigma, &fc, 0.5);
        assert_eq!(os, ws);
    }
}
