//! Analytical performance model (paper §5.1, Eqs. 5–8) and per-layer
//! bottleneck classification (used by Table 1 and the autotuner).

pub mod bottleneck;
pub mod dataflow;
pub mod model;

pub use bottleneck::Bound;
pub use dataflow::Dataflow;
pub use model::{LayerPerf, NetworkPerf, PerfModel, WeightsSource};
