//! Analytical performance model — paper §5.1 (Eqs. 5–8).
//!
//! All times are in fabric clock cycles. Memory bandwidths are converted to
//! bytes/cycle at the platform clock, so memory and compute stages compare
//! directly, exactly as the paper's initiation-interval analysis does.

use crate::arch::{BandwidthConfig, DesignPoint, Platform};
use crate::perf::bottleneck::Bound;
use crate::util::ceil_div;
use crate::workload::layer::Layer;
use crate::workload::{Network, RatioProfile};

/// Where a layer's weights come from during execution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WeightsSource {
    /// CNN-WGen reconstructs them on-chip (unzipFPGA; α's pre-loaded).
    OnTheFly {
        /// OVSF ratio ρ of the layer.
        rho: f64,
    },
    /// Streamed from off-chip per tile (conventional engine, Fig. 3).
    OffChip,
    /// Weights fully resident on-chip (small layers on the baseline whose
    /// weights fit the leftover BRAM; fetched once per inference).
    OnChip,
}

/// Performance figures of one layer on one design point.
#[derive(Clone, Debug)]
pub struct LayerPerf {
    /// Layer name.
    pub name: String,
    /// Input transfer time per output tile (cycles) — Eq. 6, including any
    /// off-chip weight streaming for the baseline.
    pub t_mem_in: f64,
    /// Weights-generation time per output tile (cycles) — Eq. 5 (0 when
    /// weights are not generated).
    pub t_wgen: f64,
    /// Engine time per output tile (cycles) — `t_eng` or `t_eng*` (Eq. 7).
    pub t_eng: f64,
    /// Output transfer time per output tile (cycles).
    pub t_mem_out: f64,
    /// Initiation interval (Eq. 8).
    pub ii: f64,
    /// Number of output tiles `⌈R/T_R⌉·⌈C/T_C⌉`.
    pub tiles: u64,
    /// Total cycles for the layer (`II · tiles`).
    pub total_cycles: f64,
    /// Dominating stage.
    pub bound: Bound,
}

/// Whole-network performance summary.
#[derive(Clone, Debug)]
pub struct NetworkPerf {
    /// Per-layer figures.
    pub layers: Vec<LayerPerf>,
    /// Total cycles per inference.
    pub total_cycles: f64,
    /// Throughput in inferences/second.
    pub inf_per_s: f64,
    /// Achieved MAC/cycle ÷ instantiated engine MACs (PE-array utilisation).
    pub engine_utilisation: f64,
}

/// The analytical model: platform + bandwidth point + datapath options.
#[derive(Clone, Debug)]
pub struct PerfModel {
    /// Target platform.
    pub platform: Platform,
    /// Off-chip bandwidth configuration.
    pub bw: BandwidthConfig,
    /// Wordlength in bytes (paper: 16-bit fixed ⇒ 2).
    pub wl_bytes: f64,
    /// Input-selective PEs enabled (Eq. 7 vs plain `t_eng`).
    pub selective_pes: bool,
}

impl PerfModel {
    /// Model at a given bandwidth multiplier with selective PEs on.
    pub fn new(platform: Platform, bw_mult: u32) -> Self {
        let bw = platform.bandwidth(bw_mult);
        Self {
            platform,
            bw,
            wl_bytes: 2.0,
            selective_pes: true,
        }
    }

    /// Disable the input-selective PE mechanism (ablation, Table 10).
    pub fn without_selective_pes(mut self) -> Self {
        self.selective_pes = false;
        self
    }

    /// Override the activation/weight word length in bytes (the paper's
    /// default is 16-bit fixed ⇒ 2).
    pub fn with_wl_bytes(mut self, wl_bytes: f64) -> Self {
        self.wl_bytes = wl_bytes;
        self
    }

    /// Model with the word length set by a software-datapath
    /// [`Precision`](crate::util::fixed::Precision): `F32` ⇒ 4 bytes,
    /// `I8` ⇒ 1 byte. Every memory-bound stage (input strips, baseline
    /// weight streaming, output drains) scales with this width — the
    /// analytical counterpart of the i8 slab cache's 4× density.
    pub fn for_precision(
        platform: Platform,
        bw_mult: u32,
        precision: crate::util::fixed::Precision,
    ) -> Self {
        Self::new(platform, bw_mult).with_wl_bytes(precision.word_bytes() as f64)
    }

    /// Input-stream bytes per cycle.
    fn bpc_in(&self) -> f64 {
        self.bw.bw_in() / self.platform.clock_hz
    }

    /// Output-stream bytes per cycle.
    fn bpc_out(&self) -> f64 {
        self.bw.bw_out() / self.platform.clock_hz
    }

    /// Eq. 5 — CNN-WGen cycles to generate the weights needed for one
    /// `T_R×T_C` output tile: `⌊ρ·l⌉ · ⌈T_P·T_C/M⌉ · ⌈P/T_P⌉`.
    pub fn t_wgen(&self, sigma: &DesignPoint, layer: &Layer, rho: f64) -> f64 {
        if !sigma.has_wgen() {
            return 0.0;
        }
        let g = layer.gemm();
        let n_basis = layer.basis_per_chunk(rho);
        (n_basis * sigma.subtiles_per_tile() * ceil_div(g.p, sigma.t_p)) as f64
    }

    /// Eq. 6 (input side) — cycles to stream the `T_R×P` activations strip
    /// for one output tile, plus `extra_bytes` of co-streamed data (weights
    /// for the baseline).
    pub fn t_mem_in(&self, sigma: &DesignPoint, layer: &Layer, extra_bytes: f64) -> f64 {
        let g = layer.gemm();
        self.t_mem_in_tile(layer, sigma.t_r.min(g.r), extra_bytes)
    }

    /// Eq. 6 (input side) for a tile with an explicit row count — edge row
    /// strips (`R % T_R ≠ 0`) stream fewer activations than a full tile.
    pub fn t_mem_in_tile(&self, layer: &Layer, rows: u64, extra_bytes: f64) -> f64 {
        let g = layer.gemm();
        let bytes = rows as f64 * g.p as f64 * self.wl_bytes + extra_bytes;
        bytes / self.bpc_in()
    }

    /// Eq. 6 (output side) — cycles to drain a `T_R×T_C` output tile.
    pub fn t_mem_out(&self, sigma: &DesignPoint, layer: &Layer) -> f64 {
        let g = layer.gemm();
        let rows = sigma.t_r.min(g.r) as f64;
        let cols = sigma.t_c.min(g.c) as f64;
        rows * cols * self.wl_bytes / self.bpc_out()
    }

    /// Engine cycles per output tile with `cols` live columns — `t_eng =
    /// T_R·⌈P/T_P⌉`, refined to Eq. 7 (`t_eng*`) when input-selective PEs
    /// are enabled and the tile underfills the PE array. Partial (edge)
    /// column tiles pass their actual width here.
    pub fn t_eng_cols(&self, sigma: &DesignPoint, layer: &Layer, cols: u64) -> f64 {
        let g = layer.gemm();
        self.t_eng_tile(sigma, layer, sigma.t_r.min(g.r), cols)
    }

    /// Engine cycles for a tile with explicit `rows` and `cols` — edge row
    /// and column tiles pass their actual extents here.
    pub fn t_eng_tile(&self, sigma: &DesignPoint, layer: &Layer, rows: u64, cols: u64) -> f64 {
        let g = layer.gemm();
        let t_r = rows as f64;
        let p_tiles = ceil_div(g.p, sigma.t_p) as f64;
        let plain = t_r * p_tiles;
        if !self.selective_pes || cols >= sigma.t_c {
            return plain;
        }
        // Eq. 7: partially unroll T_R across the T_C − C idle PEs.
        let t_c = sigma.t_c as f64;
        let c = cols as f64;
        let idle = t_c - c;
        let numer = t_r * c - idle * (c + 1.0);
        let refined = (idle + (numer / t_c).ceil().max(0.0)) * p_tiles;
        // Work conservation: never below the perfectly balanced floor and
        // never worse than the unmodified engine.
        let floor = (t_r * c / t_c).ceil() * p_tiles;
        refined.max(floor).min(plain)
    }

    /// Engine cycles for a full-width tile of the layer (`cols =
    /// min(C, T_C)`).
    pub fn t_eng(&self, sigma: &DesignPoint, layer: &Layer) -> f64 {
        self.t_eng_cols(sigma, layer, layer.gemm().c.min(sigma.t_c))
    }

    /// Full per-layer evaluation for a weights source.
    ///
    /// Tiles are evaluated in up to four groups — the cross product of
    /// {full-height, remainder} row strips and {full-width, remainder}
    /// column tiles. Edge column tiles are narrower, which both shortens
    /// the output drain and lets the input-selective PEs steal work
    /// (Eq. 7); edge row strips (`R % T_R ≠ 0`) stream fewer activations
    /// and occupy the PE array for fewer cycles. The reported stage
    /// times/bound are those of the dominant (full-height, full-width)
    /// group; `total_cycles` sums all groups, so it can be below
    /// `II·tiles` when edge tiles exist.
    pub fn layer_perf(
        &self,
        sigma: &DesignPoint,
        layer: &Layer,
        src: WeightsSource,
    ) -> LayerPerf {
        let g = layer.gemm();
        let row_tiles = ceil_div(g.r, sigma.t_r);
        let col_tiles = ceil_div(g.c, sigma.t_c);
        let tiles = row_tiles * col_tiles;

        // Row-strip groups: (count, live rows).
        let full_rows = g.r / sigma.t_r;
        let r_rem = g.r % sigma.t_r;
        let mut row_groups: Vec<(u64, u64)> = Vec::with_capacity(2);
        if full_rows > 0 {
            row_groups.push((full_rows, sigma.t_r));
        }
        if r_rem > 0 {
            row_groups.push((1, r_rem));
        }

        // Column-tile groups: (count, live columns).
        let full_cols = g.c / sigma.t_c;
        let c_rem = g.c % sigma.t_c;
        let mut col_groups: Vec<(u64, u64)> = Vec::with_capacity(2);
        if full_cols > 0 {
            col_groups.push((full_cols, sigma.t_c));
        }
        if c_rem > 0 {
            col_groups.push((1, c_rem));
        }

        let wgen_cycles = match src {
            WeightsSource::OnTheFly { rho } if layer.ovsf => self.t_wgen(sigma, layer, rho),
            _ => 0.0,
        };

        let mut total = 0.0f64;
        let mut dominant: Option<(f64, f64, f64, f64, f64)> = None;
        for (ri, &(rcount, rows)) in row_groups.iter().enumerate() {
            for (ci, &(ccount, cols)) in col_groups.iter().enumerate() {
                let extra_in_bytes = match src {
                    WeightsSource::OnTheFly { .. } if layer.ovsf => 0.0,
                    // Dense weights stream per tile (baseline / non-OVSF layer).
                    WeightsSource::OnTheFly { .. } | WeightsSource::OffChip => {
                        (g.p * cols) as f64 * self.wl_bytes
                    }
                    WeightsSource::OnChip => {
                        // Fetched once per inference; amortise over all tiles.
                        (g.p * g.c) as f64 * self.wl_bytes / tiles as f64
                    }
                };
                let t_mem_in = self.t_mem_in_tile(layer, rows, extra_in_bytes);
                let t_eng = self.t_eng_tile(sigma, layer, rows, cols);
                let t_mem_out = (rows * cols) as f64 * self.wl_bytes / self.bpc_out();
                let ii = t_mem_in.max(wgen_cycles).max(t_eng).max(t_mem_out);
                total += ii * (rcount * ccount) as f64;
                if ri == 0 && ci == 0 {
                    dominant = Some((t_mem_in, wgen_cycles, t_eng, t_mem_out, ii));
                }
            }
        }
        // Invariant: the loop above runs at least once for any validated
        // layer geometry (rcount/ccount ≥ 1), so `dominant` was set.
        #[allow(clippy::expect_used)]
        let (t_mem_in, t_wgen, t_eng, t_mem_out, ii) =
            dominant.expect("at least one tile group");
        LayerPerf {
            name: layer.name.clone(),
            t_mem_in,
            t_wgen,
            t_eng,
            t_mem_out,
            ii,
            tiles,
            total_cycles: total,
            bound: Bound::classify(t_mem_in, t_wgen, t_eng, t_mem_out),
        }
    }

    /// Evaluate a whole network under unzipFPGA's on-the-fly execution with
    /// a ratio profile.
    pub fn network_perf(
        &self,
        sigma: &DesignPoint,
        net: &Network,
        profile: &RatioProfile,
    ) -> NetworkPerf {
        let layers: Vec<LayerPerf> = net
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                self.layer_perf(
                    sigma,
                    l,
                    WeightsSource::OnTheFly {
                        rho: profile.rho(i),
                    },
                )
            })
            .collect();
        self.summarise(sigma, net, layers)
    }

    /// Evaluate a network with an explicit per-layer weights source
    /// (used by the faithful baseline).
    pub fn network_perf_with_sources(
        &self,
        sigma: &DesignPoint,
        net: &Network,
        sources: &[WeightsSource],
    ) -> NetworkPerf {
        assert_eq!(sources.len(), net.layers.len());
        let layers: Vec<LayerPerf> = net
            .layers
            .iter()
            .zip(sources)
            .map(|(l, &src)| self.layer_perf(sigma, l, src))
            .collect();
        self.summarise(sigma, net, layers)
    }

    fn summarise(&self, sigma: &DesignPoint, net: &Network, layers: Vec<LayerPerf>) -> NetworkPerf {
        let total_cycles: f64 = layers.iter().map(|l| l.total_cycles).sum();
        let inf_per_s = self.platform.clock_hz / total_cycles;
        let macs: f64 = net.macs() as f64;
        let engine_utilisation = macs / (total_cycles * sigma.engine_macs() as f64);
        NetworkPerf {
            layers,
            total_cycles,
            inf_per_s,
            engine_utilisation,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::resnet;

    fn setup() -> (PerfModel, DesignPoint, Layer) {
        let m = PerfModel::new(Platform::z7045(), 4);
        let sigma = DesignPoint::new(64, 64, 16, 48);
        let layer = Layer::conv("t", 28, 28, 128, 128, 3, 1, 1, true);
        (m, sigma, layer)
    }

    #[test]
    fn eq5_wgen_cycles() {
        let (m, sigma, layer) = setup();
        // ρ=0.5 ⇒ 8 basis vectors; subtiles = ⌈16·48/64⌉ = 12;
        // P tiles = ⌈1152/16⌉ = 72 ⇒ 8·12·72 = 6912 cycles.
        assert_eq!(m.t_wgen(&sigma, &layer, 0.5), 8.0 * 12.0 * 72.0);
    }

    #[test]
    fn eq6_memory_cycles_scale_inversely_with_bw() {
        let (m4, sigma, layer) = setup();
        let m1 = PerfModel::new(Platform::z7045(), 1);
        let t4 = m4.t_mem_in(&sigma, &layer, 0.0);
        let t1 = m1.t_mem_in(&sigma, &layer, 0.0);
        assert!(
            (t1 / t4 - 4.0).abs() < 0.05,
            "1× should be ~4× slower than 4×: {t1} vs {t4}"
        );
    }

    #[test]
    fn eq7_selective_pes_speed_up_underfilled_layers() {
        let m = PerfModel::new(Platform::z7045(), 4);
        // C = 64 on a 128-PE engine: the paper's motivating example.
        let sigma = DesignPoint::new(64, 128, 4, 128);
        let layer = Layer::conv("u", 14, 14, 64, 64, 3, 1, 1, true);
        let with = m.t_eng(&sigma, &layer);
        let without = m.clone().without_selective_pes().t_eng(&sigma, &layer);
        assert!(with < without, "selective PEs must help: {with} vs {without}");
        // Never better than perfect balancing.
        let g = layer.gemm();
        let floor = ((sigma.t_r.min(g.r) as f64 * g.c as f64) / sigma.t_c as f64).ceil()
            * ceil_div(g.p, sigma.t_p) as f64;
        assert!(with >= floor - 1e-9);
    }

    #[test]
    fn eq7_noop_when_array_filled() {
        let m = PerfModel::new(Platform::z7045(), 4);
        let sigma = DesignPoint::new(64, 64, 16, 48);
        let layer = Layer::conv("f", 28, 28, 128, 128, 3, 1, 1, true); // C=128 ≥ 48
        let with = m.t_eng(&sigma, &layer);
        let without = m.clone().without_selective_pes().t_eng(&sigma, &layer);
        assert_eq!(with, without);
    }

    #[test]
    fn eq8_ii_is_max_of_stages() {
        let (m, sigma, layer) = setup();
        let p = m.layer_perf(&sigma, &layer, WeightsSource::OnTheFly { rho: 0.5 });
        let expect = p.t_mem_in.max(p.t_wgen).max(p.t_eng).max(p.t_mem_out);
        assert_eq!(p.ii, expect);
        // Edge column tiles are narrower, so the total is bounded by the
        // full-tile II and can fall below it when C % T_C ≠ 0.
        assert!(p.total_cycles <= p.ii * p.tiles as f64 + 1e-9);
        assert!(p.total_cycles >= 0.5 * p.ii * p.tiles as f64);
    }

    #[test]
    fn edge_column_tiles_accounted() {
        // C = 128 on T_C = 48: 2 full tiles + a 32-wide edge tile whose
        // selective-PE schedule is shorter ⇒ total < II·tiles.
        let m = PerfModel::new(Platform::z7045(), 4);
        let sigma = DesignPoint::new(64, 64, 16, 48);
        let layer = Layer::conv("t", 28, 28, 128, 128, 3, 1, 1, true);
        let with = m.layer_perf(&sigma, &layer, WeightsSource::OnTheFly { rho: 0.5 });
        let without = m
            .clone()
            .without_selective_pes()
            .layer_perf(&sigma, &layer, WeightsSource::OnTheFly { rho: 0.5 });
        assert!(
            with.total_cycles <= without.total_cycles,
            "selective PEs must help on the edge tile when compute-bound"
        );
    }

    #[test]
    fn edge_row_strips_accounted() {
        // R = 784 with T_R = 64: 12 full strips + one 16-row edge strip.
        // Every stage of the edge strip is cheaper (fewer rows), so the
        // layer total falls strictly below II·tiles.
        let m = PerfModel::new(Platform::z7045(), 4);
        let sigma = DesignPoint::new(64, 64, 16, 48);
        let layer = Layer::conv("t", 28, 28, 128, 128, 3, 1, 1, true);
        let g = layer.gemm();
        assert_ne!(g.r % sigma.t_r, 0);
        let p = m.layer_perf(&sigma, &layer, WeightsSource::OffChip);
        assert!(
            p.total_cycles < p.ii * p.tiles as f64,
            "edge row strip must be cheaper: total {} vs II·tiles {}",
            p.total_cycles,
            p.ii * p.tiles as f64
        );
    }

    #[test]
    fn on_the_fly_strictly_beats_offchip_at_low_bandwidth() {
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf50(&net);
        let m = PerfModel::new(Platform::z7045(), 1);
        let sigma = DesignPoint::new(64, 64, 16, 48);
        let otf = m.network_perf(&sigma, &net, &profile);
        let off: Vec<WeightsSource> = net.layers.iter().map(|_| WeightsSource::OffChip).collect();
        let base = m.network_perf_with_sources(&sigma, &net, &off);
        assert!(
            otf.inf_per_s > base.inf_per_s,
            "on-the-fly {} ≤ off-chip {} at 1× bandwidth",
            otf.inf_per_s,
            base.inf_per_s
        );
    }

    #[test]
    fn gains_shrink_as_bandwidth_grows() {
        // The paper's headline trend (Fig. 8): speedup decays with bandwidth.
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf50(&net);
        let sigma = DesignPoint::new(64, 64, 16, 48);
        let off: Vec<WeightsSource> = net.layers.iter().map(|_| WeightsSource::OffChip).collect();
        let mut prev = f64::INFINITY;
        for mult in [1u32, 2, 4] {
            let m = PerfModel::new(Platform::z7045(), mult);
            let otf = m.network_perf(&sigma, &net, &profile).inf_per_s;
            let base = m
                .network_perf_with_sources(&sigma, &net, &off)
                .inf_per_s;
            let speedup = otf / base;
            assert!(
                speedup <= prev + 0.05,
                "speedup should not grow with bandwidth: {speedup} at {mult}×"
            );
            prev = speedup;
        }
    }

    #[test]
    fn narrower_words_shrink_memory_stages_only() {
        use crate::util::fixed::Precision;
        let sigma = DesignPoint::new(64, 64, 16, 48);
        let layer = Layer::conv("t", 28, 28, 128, 128, 3, 1, 1, true);
        let f32m = PerfModel::for_precision(Platform::z7045(), 1, Precision::F32);
        let i8m = PerfModel::for_precision(Platform::z7045(), 1, Precision::I8);
        assert_eq!(f32m.wl_bytes, 4.0);
        assert_eq!(i8m.wl_bytes, 1.0);
        let tf = f32m.t_mem_in(&sigma, &layer, 0.0);
        let ti = i8m.t_mem_in(&sigma, &layer, 0.0);
        assert!((tf / ti - 4.0).abs() < 1e-9, "mem-in must scale 4×: {tf} vs {ti}");
        // Compute cycles are word-length independent (one MAC/PE/cycle).
        assert_eq!(f32m.t_eng(&sigma, &layer), i8m.t_eng(&sigma, &layer));
        // Memory-bound at 1× bandwidth: the i8 network point is faster.
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf50(&net);
        let pf = f32m.network_perf(&sigma, &net, &profile);
        let pi = i8m.network_perf(&sigma, &net, &profile);
        assert!(pi.inf_per_s > pf.inf_per_s);
    }

    #[test]
    fn utilisation_bounded() {
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf50(&net);
        let m = PerfModel::new(Platform::z7045(), 4);
        let sigma = DesignPoint::new(64, 64, 16, 48);
        let p = m.network_perf(&sigma, &net, &profile);
        assert!(p.engine_utilisation > 0.0 && p.engine_utilisation <= 1.0 + 1e-9);
    }

    use crate::util::ceil_div;
}
