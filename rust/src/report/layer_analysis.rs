//! Per-layer analysis report — the engineering tool behind Table 1 and the
//! autotuner: for any network/design/bandwidth, the GEMM view, traffic,
//! stage times, bound and utilisation of every layer.

use crate::arch::{DesignPoint, Platform};
use crate::error::Result;
use crate::perf::model::{PerfModel, WeightsSource};
use crate::util::table::{f, Table};
use crate::workload::{Network, RatioProfile};

/// Build the per-layer analysis table for a configuration.
pub fn layer_analysis(
    platform: &Platform,
    bw_mult: u32,
    sigma: &DesignPoint,
    net: &Network,
    profile: &RatioProfile,
) -> Result<Table> {
    let model = PerfModel::new(platform.clone(), bw_mult);
    let mut t = Table::new(
        format!(
            "Per-layer analysis — {} on {} @ {}x, σ = {}",
            net.name, platform.name, bw_mult, sigma
        ),
        &[
            "layer", "R", "P", "C", "ρ", "MMACs", "t_in", "t_wgen", "t_eng", "t_out", "II",
            "tiles", "bound", "util%",
        ],
    );
    for (i, layer) in net.layers.iter().enumerate() {
        let rho = profile.rho(i);
        let src = if layer.ovsf {
            WeightsSource::OnTheFly { rho }
        } else {
            WeightsSource::OffChip
        };
        let p = model.layer_perf(sigma, layer, src);
        let g = layer.gemm();
        let util = layer.macs() as f64 / (p.total_cycles * sigma.engine_macs() as f64);
        t.row(vec![
            layer.name.clone(),
            g.r.to_string(),
            g.p.to_string(),
            g.c.to_string(),
            if layer.ovsf { format!("{rho:.3}") } else { "-".into() },
            f(layer.macs() as f64 / 1e6, 1),
            f(p.t_mem_in, 0),
            f(p.t_wgen, 0),
            f(p.t_eng, 0),
            f(p.t_mem_out, 0),
            f(p.ii, 0),
            p.tiles.to_string(),
            p.bound.label().into(),
            f(100.0 * util, 1),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::resnet;

    #[test]
    fn covers_every_layer_with_sane_fields() {
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf50(&net);
        let t = layer_analysis(
            &Platform::z7045(),
            4,
            &DesignPoint::new(64, 64, 16, 48),
            &net,
            &profile,
        )
        .unwrap();
        assert_eq!(t.len(), net.layers.len());
        let rendered = t.render();
        assert!(rendered.contains("conv1"));
        assert!(rendered.contains("fc"));
        // Bounds column uses the paper's labels.
        assert!(rendered.contains("IFM") || rendered.contains("C"));
    }

    #[test]
    fn dense_layers_show_no_rho() {
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf25(&net);
        let t = layer_analysis(
            &Platform::z7045(),
            1,
            &DesignPoint::new(64, 64, 16, 48),
            &net,
            &profile,
        )
        .unwrap();
        let csv = t.render_csv();
        let first = csv.lines().nth(1).unwrap(); // conv1 row
        assert!(first.contains(",-,"), "stem shows '-' for ρ: {first}");
    }
}
