//! Figure regeneration (paper Figs. 8–10) as CSV series.

use crate::accuracy::AccuracyModel;
use crate::arch::Platform;
use crate::autotune::autotune;
use crate::baselines::faithful::evaluate_faithful;
use crate::baselines::gpu::Tx2Model;
use crate::baselines::pruning::TaylorPruner;
use crate::dse::search::{optimise, DseConfig};
use crate::error::Result;
use crate::util::table::{f, Table};
use crate::workload::{Network, RatioProfile};

/// **Fig. 8** — speedup over the vanilla baseline vs off-chip bandwidth
/// (1×…12×) for Tay82 and the unzipFPGA OVSF variants, on both platforms.
pub fn fig8() -> Result<Table> {
    let mut t = Table::new(
        "Fig. 8 — speedup over optimised vanilla baseline vs bandwidth",
        &["platform", "network", "bandwidth_x", "method", "speedup"],
    );
    let cfg = DseConfig::default();
    for plat in Platform::all() {
        for net in Network::benchmarks() {
            for bw in [1u32, 2, 4, 12] {
                if bw > plat.peak_bw_mult {
                    continue;
                }
                let vanilla = evaluate_faithful(&plat, bw, &net)?.perf.inf_per_s;
                // Tay82 baseline.
                let pruner = TaylorPruner::new(0.82);
                let pruned = pruner.prune(&net);
                let tay = evaluate_faithful(&plat, bw, &pruned)?.perf.inf_per_s;
                t.row(vec![
                    plat.name.into(),
                    net.name.clone(),
                    bw.to_string(),
                    "Tay82".into(),
                    f(tay / vanilla, 3),
                ]);
                for profile in [RatioProfile::ovsf50(&net), RatioProfile::ovsf25(&net)] {
                    let unzip = optimise(&cfg, &plat, bw, &net, &profile, true)?
                        .perf
                        .inf_per_s;
                    t.row(vec![
                        plat.name.into(),
                        net.name.clone(),
                        bw.to_string(),
                        format!("unzipFPGA-{}", profile.name),
                        f(unzip / vanilla, 3),
                    ]);
                }
            }
        }
    }
    Ok(t)
}

/// **Fig. 9** — accuracy vs execution time for the ratio-selection methods
/// (ResNet18/34 on Z7045 at 1×/2×/4×).
pub fn fig9() -> Result<Table> {
    let mut t = Table::new(
        "Fig. 9 — accuracy vs execution time per ratio-selection method",
        &["network", "bandwidth_x", "method", "exec_ms", "top1_pct"],
    );
    let plat = Platform::z7045();
    let cfg = DseConfig::default();
    for net in [crate::workload::resnet::resnet18(), crate::workload::resnet::resnet34()] {
        let acc = AccuracyModel::for_network(&net);
        for bw in [1u32, 2, 4] {
            let mut methods: Vec<(String, RatioProfile)> = vec![
                ("manual-OVSF50".into(), RatioProfile::ovsf50(&net)),
                ("manual-OVSF25".into(), RatioProfile::ovsf25(&net)),
                ("uniform-0.5".into(), RatioProfile::uniform(&net, 0.5)),
                ("uniform-0.25".into(), RatioProfile::uniform(&net, 0.25)),
            ];
            let tuned = autotune(&cfg, &plat, bw, &net)?;
            methods.push(("hw-aware-autotuning".into(), tuned.profile.clone()));
            for (name, profile) in methods {
                let r = optimise(&cfg, &plat, bw, &net, &profile, true)?;
                t.row(vec![
                    net.name.clone(),
                    bw.to_string(),
                    name,
                    f(1e3 / r.perf.inf_per_s, 2),
                    f(acc.top1(&net, &profile), 2),
                ]);
            }
        }
    }
    Ok(t)
}

/// **Fig. 10** — energy efficiency (inf/s/W) of unzipFPGA vs Jetson TX2
/// (Max-Q), OVSF50 variants.
pub fn fig10() -> Result<Table> {
    let mut t = Table::new(
        "Fig. 10 — energy efficiency vs embedded GPU (TX2, Max-Q)",
        &["network", "platform", "inf_s", "power_w", "inf_s_per_w", "gain_vs_tx2"],
    );
    let cfg = DseConfig::default();
    let tx2 = Tx2Model::default();
    let mut gains = Vec::new();
    for net in Network::benchmarks() {
        let plat = if net.name == "SqueezeNet" {
            Platform::zu7ev()
        } else {
            Platform::z7045()
        };
        let profile = RatioProfile::ovsf50(&net);
        let bw = plat.peak_bw_mult;
        let unzip = optimise(&cfg, &plat, bw, &net, &profile, true)?;
        let fpga_eff = unzip.perf.inf_per_s / plat.dynamic_power_w;
        let gpu_inf = tx2.inf_per_s(&net.name, net.gops());
        let gpu_eff = tx2.inf_per_s_per_w(&net.name, net.gops());
        let gain = fpga_eff / gpu_eff;
        gains.push(gain);
        t.row(vec![
            net.name.clone(),
            plat.name.into(),
            f(unzip.perf.inf_per_s, 1),
            f(plat.dynamic_power_w, 1),
            f(fpga_eff, 2),
            format!("{gain:.2}x"),
        ]);
        t.row(vec![
            net.name.clone(),
            "TX2".into(),
            f(gpu_inf, 1),
            f(tx2.dynamic_power_w, 1),
            f(gpu_eff, 2),
            "1.00x".into(),
        ]);
    }
    let avg = crate::util::stats::mean(&gains);
    let geo = crate::util::stats::geo_mean(&gains);
    t.row(vec![
        "Average".into(),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
        format!("{avg:.2}x / {geo:.2}x geo"),
    ]);
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_covers_both_platforms() {
        let t = fig8().unwrap();
        let csv = t.render_csv();
        assert!(csv.contains("Z7045") && csv.contains("ZU7EV"));
        assert!(csv.contains("unzipFPGA-OVSF50"));
        // Z7045: 1/2/4 × 4 nets × 3 methods = 36; ZU7EV adds 12× ⇒ 48.
        assert_eq!(t.len(), 36 + 48);
    }

    #[test]
    fn fig9_has_five_methods_per_point() {
        let t = fig9().unwrap();
        assert_eq!(t.len(), 2 * 3 * 5);
    }

    #[test]
    fn fig10_fpga_wins_on_average() {
        let t = fig10().unwrap();
        let rendered = t.render();
        // 4 networks × 2 rows + average.
        assert_eq!(t.len(), 9);
        // The average gain row should show a >1 multiple.
        let avg_line = rendered.lines().last().unwrap().to_string();
        assert!(avg_line.contains('x'), "{avg_line}");
    }
}
