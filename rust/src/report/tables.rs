//! Table regeneration (paper Tables 1, 3–10).

use crate::accuracy::AccuracyModel;
use crate::arch::Platform;
use crate::autotune::autotune;
use crate::baselines::faithful::evaluate_faithful;
use crate::baselines::prior_work;
use crate::baselines::pruning::TaylorPruner;
use crate::dse::search::{optimise, DseConfig, DseResult};
use crate::error::Result;
use crate::util::table::{f, Table};
use crate::workload::{resnet, squeezenet, Network, RatioProfile};

/// Interaction penalty when stacking pruning and OVSF (calibrated on the
/// paper's Tay+OVSF rows; see EXPERIMENTS.md).
const STACK_PENALTY_PP: f64 = 0.5;

fn acc_for(net: &Network, profile: &RatioProfile) -> f64 {
    AccuracyModel::for_network(net).top1(net, profile)
}

/// Throughput of unzipFPGA for a net/profile at several bandwidths.
fn unzip_perfs(
    platform: &Platform,
    net: &Network,
    profile: &RatioProfile,
    bws: &[u32],
) -> Result<Vec<f64>> {
    let cfg = DseConfig::default();
    bws.iter()
        .map(|&bw| Ok(optimise(&cfg, platform, bw, net, profile, true)?.perf.inf_per_s))
        .collect()
}

/// Throughput of the faithful baseline at several bandwidths.
fn baseline_perfs(platform: &Platform, net: &Network, bws: &[u32]) -> Result<Vec<f64>> {
    bws.iter()
        .map(|&bw| Ok(evaluate_faithful(platform, bw, net)?.perf.inf_per_s))
        .collect()
}

fn fmt_perfs(perfs: &[f64]) -> String {
    let cells: Vec<String> = perfs.iter().map(|p| f(*p, 1)).collect();
    format!("({})", cells.join(", "))
}

/// **Table 1** — OVSF ratio-selection methods vs per-layer bound for
/// ResNet18 on Z7045 at 1×/2×/4× bandwidth.
pub fn table1() -> Result<Table> {
    let net = resnet::resnet18();
    let plat = Platform::z7045();
    let mut t = Table::new(
        "Table 1 — ratio selection vs bottleneck (ResNet18, Z7045)",
        &["Bandwidth", "Method", "Top-1 (%)", "inf/s", "Per-layer bound", "Per-layer ρ"],
    );
    let cfg = DseConfig::default();
    for bw in [1u32, 2, 4] {
        let tuned = autotune(&cfg, &plat, bw, &net)?;
        let methods: Vec<(String, RatioProfile)> = vec![
            ("OVSF25".into(), RatioProfile::ovsf25(&net)),
            ("uniform-1.0".into(), RatioProfile::uniform(&net, 1.0)),
            ("hw-aware-autotuning".into(), tuned.profile.clone()),
        ];
        for (name, profile) in methods {
            let perf = crate::perf::model::PerfModel::new(plat.clone(), bw).network_perf(
                &tuned.sigma,
                &net,
                &profile,
            );
            let bounds: Vec<String> = perf
                .layers
                .iter()
                .zip(&net.layers)
                .filter(|(_, l)| l.kind == crate::workload::LayerKind::Conv)
                .map(|(lp, _)| lp.bound.label().to_string())
                .collect();
            let rhos: Vec<String> = net
                .layers
                .iter()
                .enumerate()
                .filter(|(_, l)| l.kind == crate::workload::LayerKind::Conv)
                .map(|(i, _)| format!("{:.3}", profile.rho(i)))
                .collect();
            t.row(vec![
                format!("{bw}x"),
                name,
                f(acc_for(&net, &profile), 1),
                f(perf.inf_per_s, 1),
                bounds.join(" "),
                rhos.join(" "),
            ]);
        }
    }
    Ok(t)
}

/// **Table 3** — basis-selection × 3×3-extraction strategies. The accuracy
/// numbers are *measured* by `python/compile/train.py` on a synthetic
/// dataset (written to `artifacts/table3_results.csv`); if that file is
/// missing, the paper's reference rows are shown instead.
pub fn table3() -> Result<Table> {
    let path = crate::runtime::artifacts_dir().join("table3_results.csv");
    let mut t = Table::new(
        "Table 3 — basis selection and 3×3 extraction",
        &["Model", "Basis", "3×3", "OVSF100 acc", "OVSF50 acc", "OVSF25 acc", "Source"],
    );
    if let Ok(csv) = std::fs::read_to_string(&path) {
        for line in csv.lines().skip(1) {
            let cells: Vec<&str> = line.split(',').collect();
            if cells.len() >= 6 {
                let mut row: Vec<String> = cells[..6].iter().map(|s| s.to_string()).collect();
                row.push("measured (synthetic)".into());
                t.row(row);
            }
        }
    }
    if t.is_empty() {
        // Paper reference (ImageNet-scale CIFAR-10 runs are out of budget;
        // run `make table3_train` to produce measured synthetic trends).
        for (model, basis, filt, a100, a50, a25) in [
            ("ResNet18", "Sequential", "Crop", 93.9, 93.7, 92.9),
            ("ResNet18", "Sequential", "Adaptive", 93.7, 93.8, 93.0),
            ("ResNet18", "Iterative", "Crop", 94.1, 93.6, 93.6),
            ("ResNet18", "Iterative", "Adaptive", 94.0, 93.8, 92.3),
            ("ResNet34", "Sequential", "Crop", 94.1, 93.9, 93.4),
            ("ResNet34", "Sequential", "Adaptive", 94.3, 94.0, 93.4),
            ("ResNet34", "Iterative", "Crop", 94.1, 93.8, 94.3),
            ("ResNet34", "Iterative", "Adaptive", 93.8, 93.7, 93.2),
        ] {
            t.row(vec![
                model.into(),
                basis.into(),
                filt.into(),
                f(a100, 1),
                f(a50, 1),
                f(a25, 1),
                "paper reference".into(),
            ]);
        }
    }
    Ok(t)
}

/// Shared builder for Tables 4 and 5.
fn compression_table(net: &Network, title: &str) -> Result<Table> {
    let plat = Platform::z7045();
    let bws = [1u32, 2, 4];
    let acc = AccuracyModel::for_network(net);
    let mut t = Table::new(
        title,
        &["Model", "Method", "Params (M)", "Top-1 (%)", "inf/s (1x, 2x, 4x)"],
    );
    // Vanilla.
    t.row(vec![
        net.name.clone(),
        "-".into(),
        f(net.params() as f64 / 1e6, 1),
        f(acc.dense_top1, 1),
        fmt_perfs(&baseline_perfs(&plat, net, &bws)?),
    ]);
    // Taylor-pruned variants.
    let keeps: &[f64] = if net.name == "ResNet18" {
        &[0.88, 0.82, 0.72, 0.56]
    } else {
        &[0.82, 0.72, 0.56, 0.45]
    };
    for &keep in keeps {
        let pruner = TaylorPruner::new(keep);
        let pruned = pruner.prune(net);
        t.row(vec![
            net.name.clone(),
            pruner.name(),
            f(pruned.params() as f64 / 1e6, 1),
            f(pruner.top1(net).unwrap_or(f64::NAN), 1),
            fmt_perfs(&baseline_perfs(&plat, &pruned, &bws)?),
        ]);
    }
    // OVSF variants on unzipFPGA.
    for profile in [RatioProfile::ovsf50(net), RatioProfile::ovsf25(net)] {
        t.row(vec![
            net.name.clone(),
            profile.name.clone(),
            f(net.params_compressed(&profile) as f64 / 1e6, 1),
            f(acc.top1(net, &profile), 1),
            fmt_perfs(&unzip_perfs(&plat, net, &profile, &bws)?),
        ]);
    }
    // Stacked Tay + OVSF.
    for (keep, ovsf50) in [(0.82f64, true), (0.82, false), (0.72, true), (0.72, false)] {
        // ResNet18 table shows only the Tay82 combinations.
        if net.name == "ResNet18" && (keep - 0.82).abs() > 1e-9 {
            continue;
        }
        let pruner = TaylorPruner::new(keep);
        let pruned = pruner.prune(net);
        let profile = if ovsf50 {
            RatioProfile::ovsf50(&pruned)
        } else {
            RatioProfile::ovsf25(&pruned)
        };
        let acc_stack = pruner.top1(net).unwrap_or(acc.dense_top1)
            + (acc.top1(net, &if ovsf50 {
                RatioProfile::ovsf50(net)
            } else {
                RatioProfile::ovsf25(net)
            }) - acc.dense_top1)
            - STACK_PENALTY_PP;
        t.row(vec![
            net.name.clone(),
            format!("{}+{}", pruner.name(), profile.name),
            f(pruned.params_compressed(&profile) as f64 / 1e6, 1),
            f(acc_stack, 1),
            fmt_perfs(&unzip_perfs(&plat, &pruned, &profile, &bws)?),
        ]);
    }
    Ok(t)
}

/// **Table 4** — ResNet34 compression schemes on ZC706.
pub fn table4() -> Result<Table> {
    compression_table(
        &resnet::resnet34(),
        "Table 4 — ResNet34 compression schemes (ZC706)",
    )
}

/// **Table 5** — ResNet18 compression schemes on ZC706.
pub fn table5() -> Result<Table> {
    compression_table(
        &resnet::resnet18(),
        "Table 5 — ResNet18 compression schemes (ZC706)",
    )
}

/// **Table 6** — SqueezeNet on ZCU104 at 1×/2×/4×/12×.
pub fn table6() -> Result<Table> {
    let net = squeezenet::squeezenet1_1();
    let plat = Platform::zu7ev();
    let bws = [1u32, 2, 4, 12];
    let acc = AccuracyModel::for_network(&net);
    let mut t = Table::new(
        "Table 6 — SqueezeNet (ZCU104)",
        &["Model", "Method", "Params (M)", "Top-1 (%)", "inf/s (1x, 2x, 4x, 12x)"],
    );
    t.row(vec![
        net.name.clone(),
        "-".into(),
        f(net.params() as f64 / 1e6, 2),
        f(acc.dense_top1, 1),
        fmt_perfs(&baseline_perfs(&plat, &net, &bws)?),
    ]);
    for profile in [RatioProfile::ovsf50(&net), RatioProfile::ovsf25(&net)] {
        t.row(vec![
            net.name.clone(),
            profile.name.clone(),
            f(net.params_compressed(&profile) as f64 / 1e6, 2),
            f(acc.top1(&net, &profile), 1),
            fmt_perfs(&unzip_perfs(&plat, &net, &profile, &bws)?),
        ]);
    }
    Ok(t)
}

/// Density metrics of one of our designs.
fn our_density_row(
    label: &str,
    net: &Network,
    plat: &Platform,
    bw: u32,
) -> Result<(String, DseResult, f64, f64)> {
    let profile = RatioProfile::ovsf50(net);
    let r = optimise(&DseConfig::default(), plat, bw, net, &profile, true)?;
    let inf_s = r.perf.inf_per_s;
    let inf_s_dsp = inf_s / plat.dsp as f64;
    let inf_s_klut = inf_s / (plat.luts as f64 / 1e3);
    Ok((label.to_string(), r, inf_s_dsp, inf_s_klut))
}

/// **Table 7** — comparison with prior FPGA work (ResNet18/34, SqueezeNet).
pub fn table7() -> Result<Table> {
    let mut t = Table::new(
        "Table 7 — prior FPGA work (ResNet18/34 + SqueezeNet)",
        &["Design", "Network", "FPGA", "inf/s", "inf/s/DSP", "inf/s/kLUT"],
    );
    for row in prior_work::table7_rows() {
        t.row(vec![
            row.name.into(),
            row.network.into(),
            row.fpga.into(),
            f(row.inf_s, 2),
            f(row.inf_s_dsp, 4),
            f(row.inf_s_logic, 4),
        ]);
    }
    let z = Platform::z7045();
    let u = Platform::zu7ev();
    for (label, net, plat, bw) in [
        ("unzipFPGA: ResNet18*", resnet::resnet18(), &z, 4u32),
        ("unzipFPGA: ResNet34*", resnet::resnet34(), &z, 4),
        ("unzipFPGA: SqueezeNet*", squeezenet::squeezenet1_1(), &u, 12),
    ] {
        let (label, r, d, l) = our_density_row(label, &net, plat, bw)?;
        t.row(vec![
            label,
            net.name.clone(),
            plat.name.into(),
            f(r.perf.inf_per_s, 2),
            f(d, 4),
            f(l, 4),
        ]);
    }
    Ok(t)
}

/// **Table 8** — comparison with prior FPGA work (ResNet50).
pub fn table8() -> Result<Table> {
    let mut t = Table::new(
        "Table 8 — prior FPGA work (ResNet50)",
        &["Design", "FPGA", "inf/s", "inf/s/DSP", "inf/s/kLUT"],
    );
    for row in prior_work::table8_rows() {
        t.row(vec![
            row.name.into(),
            row.fpga.into(),
            f(row.inf_s, 2),
            f(row.inf_s_dsp, 4),
            f(row.inf_s_logic, 4),
        ]);
    }
    let net = resnet::resnet50();
    for (label, plat, bw) in [
        ("unzipFPGA: ResNet50* (Z7045)", Platform::z7045(), 4u32),
        ("unzipFPGA: ResNet50* (ZU7EV)", Platform::zu7ev(), 12),
    ] {
        let (label, r, d, l) = our_density_row(label, &net, &plat, bw)?;
        t.row(vec![
            label,
            plat.name.into(),
            f(r.perf.inf_per_s, 2),
            f(d, 4),
            f(l, 4),
        ]);
    }
    Ok(t)
}

/// **Table 9** — resource breakdown between CNN-WGen and the engine.
pub fn table9() -> Result<Table> {
    let plat = Platform::z7045();
    let rsc = crate::rsc::model::ResourceModel::new(plat.clone());
    let mut t = Table::new(
        "Table 9 — resource breakdown (ZC706, OVSF50)",
        &["Design", "DSPs WGen", "DSPs Engine", "LUTs WGen", "LUTs Engine"],
    );
    for net in [resnet::resnet18(), resnet::resnet34(), resnet::resnet50()] {
        let profile = RatioProfile::ovsf50(&net);
        let r = optimise(&DseConfig::default(), &plat, 4, &net, &profile, true)?;
        let (d_wgen, d_eng) = rsc.dsp_split(&r.sigma);
        let total_dsp = (d_wgen + d_eng) as f64;
        let l_wgen = rsc.luts_wgen(&r.sigma) as f64;
        let l_total = rsc.luts(&r.sigma) as f64;
        t.row(vec![
            format!("{}-OVSF50 {}", net.name, r.sigma),
            format!("{:.1}%", 100.0 * d_wgen as f64 / total_dsp),
            format!("{:.1}%", 100.0 * d_eng as f64 / total_dsp),
            format!("{:.1}%", 100.0 * l_wgen / plat.luts as f64),
            format!(
                "{:.1}%",
                100.0 * (l_total - l_wgen) / plat.luts as f64
            ),
        ]);
    }
    Ok(t)
}

/// **Table 10** — input-selective PE ablation across all benchmarks.
pub fn table10() -> Result<Table> {
    let mut t = Table::new(
        "Table 10 — input-selective PE ablation",
        &["Model", "Profile", "Platform", "without (inf/s)", "with (inf/s)", "Gain"],
    );
    let cfg = DseConfig::default();
    let mut gains = Vec::new();
    for net in Network::benchmarks() {
        for profile in [RatioProfile::ovsf50(&net), RatioProfile::ovsf25(&net)] {
            let plats = if net.name == "SqueezeNet" {
                vec![Platform::zu7ev()]
            } else {
                vec![Platform::z7045(), Platform::zu7ev()]
            };
            for plat in plats {
                let bw = plat.peak_bw_mult;
                let with = optimise(&cfg, &plat, bw, &net, &profile, true)?;
                // Ablation: same design point, switches removed.
                let mut model = crate::perf::model::PerfModel::new(plat.clone(), bw);
                model.selective_pes = false;
                let without = model.network_perf(&with.sigma, &net, &profile);
                let gain = with.perf.inf_per_s / without.inf_per_s;
                gains.push(gain);
                t.row(vec![
                    net.name.clone(),
                    profile.name.clone(),
                    plat.name.into(),
                    f(without.inf_per_s, 1),
                    f(with.perf.inf_per_s, 1),
                    format!("{gain:.2}x"),
                ]);
            }
        }
    }
    let avg = crate::util::stats::mean(&gains);
    let geo = crate::util::stats::geo_mean(&gains);
    t.row(vec![
        "Average".into(),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
        format!("{avg:.2}x / {geo:.2}x geo"),
    ]);
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_nine_method_rows() {
        let t = table1().unwrap();
        assert_eq!(t.len(), 9); // 3 bandwidths × 3 methods
    }

    #[test]
    fn table4_and_5_render() {
        let t4 = table4().unwrap();
        assert!(t4.len() >= 9, "vanilla + 4 pruned + 2 OVSF + ≥2 stacked");
        let t5 = table5().unwrap();
        assert!(t5.len() >= 8);
        assert!(t5.render().contains("OVSF50"));
    }

    #[test]
    fn table6_rows() {
        let t = table6().unwrap();
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn table7_8_include_ours_and_prior() {
        let t7 = table7().unwrap();
        assert_eq!(t7.len(), 5 + 3);
        let t8 = table8().unwrap();
        assert_eq!(t8.len(), 10 + 2);
        assert!(t8.render().contains("unzipFPGA"));
    }

    #[test]
    fn table9_three_designs() {
        let t = table9().unwrap();
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn table10_gains_at_least_one() {
        let t = table10().unwrap();
        assert_eq!(t.len(), 14 + 1); // 14 configs + average row
        let rendered = t.render();
        assert!(!rendered.contains("0.9"), "no sub-1.0 gains expected");
    }

    #[test]
    fn table3_renders() {
        // 8 paper-reference rows without the measured CSV, or 4 measured
        // rows (basis × extraction) once `make table3_train` has run.
        let t = table3().unwrap();
        assert!(t.len() >= 4);
    }
}
