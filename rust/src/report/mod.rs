//! Report harnesses: regenerate every table and figure of the paper's
//! evaluation section (§7) from this repo's models, DSE and simulator.
//! Used by the CLI (`unzipfpga table4` etc.), the benches and
//! EXPERIMENTS.md.

pub mod figures;
pub mod layer_analysis;
pub mod tables;
