//! Hardware-aware tuning of OVSF ratios (paper §6.2, Fig. 7).
//!
//! Insight: when a layer is memory- or compute-bound, the weights-generation
//! stage has slack — its OVSF ratio can be raised (better weight
//! approximation ⇒ better accuracy) *without* moving the layer's initiation
//! interval, i.e. at zero throughput cost.
//!
//! The scheme: ① run the design flow at the OVSF25 ratios and fix the
//! resulting accelerator configuration; ② classify every layer's bottleneck;
//! ③ for layers not bound by CNN-WGen, raise ρ step-by-step up to (but not
//! past) the point where weights generation would become the bottleneck;
//! ④ emit the converged profile (the model is then retrained and the DSE
//! re-run — steps the caller drives).

use crate::arch::{DesignPoint, Platform};
use crate::dse::search::{optimise, DseConfig};
use crate::error::Result;
use crate::perf::model::{PerfModel, WeightsSource};
use crate::perf::Bound;
use crate::workload::{Network, RatioProfile};

/// The ratio ladder the tuner climbs (superset of every value appearing in
/// the paper's Table 1: 0.125 … 1.0).
pub const RHO_LADDER: [f64; 7] = [0.125, 0.25, 0.333, 0.4, 0.5, 0.75, 1.0];

/// Outcome of the autotuning pass.
#[derive(Clone, Debug)]
pub struct AutotuneResult {
    /// The converged per-layer profile.
    pub profile: RatioProfile,
    /// The accelerator configuration the tuning was performed against.
    pub sigma: DesignPoint,
    /// Per-layer bound classification at the initial (OVSF25) profile.
    pub initial_bounds: Vec<Bound>,
    /// Per-layer bound classification at the converged profile.
    pub final_bounds: Vec<Bound>,
    /// Throughput at the initial profile (inf/s).
    pub initial_inf_per_s: f64,
    /// Throughput at the converged profile (inf/s).
    pub final_inf_per_s: f64,
}

/// Raise one layer's ρ as far as the pipeline slack allows: the largest
/// ladder value whose `t_wgen` does not exceed the layer's II from the
/// other stages. Only increases over `rho_now` are permitted (the paper's
/// lower-bound guarantee).
fn max_rho_within_slack(
    perf: &PerfModel,
    sigma: &DesignPoint,
    layer: &crate::workload::layer::Layer,
    rho_now: f64,
) -> f64 {
    let base = perf.layer_perf(sigma, layer, WeightsSource::OnTheFly { rho: rho_now });
    // Slack ceiling: the II set by the non-wgen stages.
    let ceiling = base.t_mem_in.max(base.t_eng).max(base.t_mem_out);
    let mut best = rho_now;
    for &rho in RHO_LADDER.iter() {
        if rho <= rho_now {
            continue;
        }
        let t_wgen = perf.t_wgen(sigma, layer, rho);
        if t_wgen <= ceiling {
            best = rho;
        }
    }
    best
}

/// Run the full hardware-aware autotuning flow for a CNN–platform pair at a
/// given bandwidth. Starts from the OVSF25 profile (paper step ①).
pub fn autotune(
    cfg: &DseConfig,
    platform: &Platform,
    bw_mult: u32,
    net: &Network,
) -> Result<AutotuneResult> {
    let initial = RatioProfile::ovsf25(net);
    autotune_from(cfg, platform, bw_mult, net, initial)
}

/// Autotune from an explicit starting profile.
pub fn autotune_from(
    cfg: &DseConfig,
    platform: &Platform,
    bw_mult: u32,
    net: &Network,
    initial: RatioProfile,
) -> Result<AutotuneResult> {
    // ① derive the accelerator configuration at the starting ratios.
    let dse = optimise(cfg, platform, bw_mult, net, &initial, true)?;
    let sigma = dse.sigma;
    let perf = PerfModel::new(platform.clone(), bw_mult);

    // ② bottleneck analysis at the starting profile.
    let initial_perf = perf.network_perf(&sigma, net, &initial);
    let initial_bounds: Vec<Bound> = initial_perf.layers.iter().map(|l| l.bound).collect();

    // ③ per-layer ratio raise within pipeline slack.
    let mut rhos = initial.rhos.clone();
    for (i, layer) in net.layers.iter().enumerate() {
        if !layer.ovsf {
            continue;
        }
        rhos[i] = max_rho_within_slack(&perf, &sigma, layer, rhos[i]);
    }
    let profile = RatioProfile {
        name: "hw-aware-autotuned".to_string(),
        rhos,
    };

    // ④ converged evaluation.
    let final_perf = perf.network_perf(&sigma, net, &profile);
    let final_bounds: Vec<Bound> = final_perf.layers.iter().map(|l| l.bound).collect();
    Ok(AutotuneResult {
        profile,
        sigma,
        initial_bounds,
        final_bounds,
        initial_inf_per_s: initial_perf.inf_per_s,
        final_inf_per_s: final_perf.inf_per_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::resnet;

    fn run(bw: u32) -> (Network, AutotuneResult) {
        let net = resnet::resnet18();
        let cfg = DseConfig::default();
        let r = autotune(&cfg, &Platform::z7045(), bw, &net).unwrap();
        (net, r)
    }

    #[test]
    fn ratios_only_increase() {
        let (net, r) = run(1);
        let initial = RatioProfile::ovsf25(&net);
        for (i, (&a, &b)) in initial.rhos.iter().zip(&r.profile.rhos).enumerate() {
            assert!(b >= a - 1e-12, "layer {i} decreased: {a} → {b}");
        }
    }

    #[test]
    fn throughput_is_preserved() {
        // The paper's guarantee: accuracy gain at no processing-speed cost.
        for bw in [1u32, 2, 4] {
            let (_, r) = run(bw);
            let ratio = r.final_inf_per_s / r.initial_inf_per_s;
            assert!(
                ratio > 0.98,
                "autotuning lost {:.1}% throughput at {bw}×",
                (1.0 - ratio) * 100.0
            );
        }
    }

    #[test]
    fn memory_bound_layers_get_higher_ratios() {
        // At 1× bandwidth ResNet18 is severely memory-bound (Table 1):
        // the tuner should raise many ratios above OVSF25.
        let (net, r) = run(1);
        let initial = RatioProfile::ovsf25(&net);
        let raised = initial
            .rhos
            .iter()
            .zip(&r.profile.rhos)
            .filter(|(&a, &b)| b > a + 1e-12)
            .count();
        assert!(raised >= 4, "only {raised} layers raised at 1×");
        let e_init = initial.effective_rho(&net);
        let e_final = r.profile.effective_rho(&net);
        assert!(e_final > e_init, "effective ρ must rise: {e_init} → {e_final}");
    }

    #[test]
    fn never_creates_wgen_bottleneck() {
        for bw in [1u32, 2, 4] {
            let (_, r) = run(bw);
            for (i, (&before, &after)) in
                r.initial_bounds.iter().zip(&r.final_bounds).enumerate()
            {
                if before != Bound::WGen {
                    assert_ne!(
                        after,
                        Bound::WGen,
                        "layer {i} became wgen-bound at {bw}× after tuning"
                    );
                }
            }
        }
    }
}
