//! Banked Alpha buffer (paper §4.2.2 "Memory Customisation in Alpha
//! Buffer", Eqs. 3–4).
//!
//! Each TiWGen subtile straddles weights of `N_f` distinct filters, so
//! `N_f` α values must be fetched *in the same cycle*. The unified buffer
//! is split into `N_P^Alpha = N_f` independent sub-buffers; filter `o` of
//! any layer lives in bank `o mod N_f`, making the per-cycle accesses of a
//! subtile (consecutive filters) conflict-free by construction. The
//! simulator checks that property on every read.

use crate::sim::hw_weights::HwOvsfWeights;
use std::collections::HashMap;

/// Address of one layer's α block inside the banked buffer.
#[derive(Clone, Copy, Debug)]
struct LayerMeta {
    n_in: usize,
    n_basis: usize,
}

/// The banked α store of CNN-WGen.
#[derive(Clone, Debug)]
pub struct AlphaBufferSim {
    /// Number of parallel ports / banks (`N_f`).
    pub n_ports: usize,
    /// Bank contents: `banks[b]` holds α words in write order.
    banks: Vec<Vec<f32>>,
    /// Per-bank base offset of each layer.
    layer_base: HashMap<usize, (Vec<usize>, LayerMeta)>,
    /// Reads issued (for port-pressure accounting).
    pub reads: u64,
    /// Peak simultaneous same-bank accesses observed (must stay 1).
    pub max_bank_conflict: usize,
}

impl AlphaBufferSim {
    /// Create an empty buffer with `n_ports` banks.
    pub fn new(n_ports: usize) -> Self {
        assert!(n_ports >= 1);
        Self {
            n_ports,
            banks: vec![Vec::new(); n_ports],
            layer_base: HashMap::new(),
            reads: 0,
            max_bank_conflict: 1,
        }
    }

    /// Load one layer's α values (done upfront, before inference — the
    /// paper transfers α "upfront" so they are excluded from the per-tile
    /// memory time).
    pub fn write_layer(&mut self, layer_id: usize, w: &HwOvsfWeights) {
        let bases: Vec<usize> = self.banks.iter().map(|b| b.len()).collect();
        for o in 0..w.n_out {
            let bank = o % self.n_ports;
            for c in 0..w.n_in {
                for j in 0..w.n_basis {
                    self.banks[bank].push(w.alpha(o, c, j));
                }
            }
        }
        self.layer_base.insert(
            layer_id,
            (
                bases,
                LayerMeta {
                    n_in: w.n_in,
                    n_basis: w.n_basis,
                },
            ),
        );
    }

    /// Per-bank depth (paper Eq. 4's `D^Alpha`, as built).
    pub fn depth(&self) -> usize {
        self.banks.iter().map(|b| b.len()).max().unwrap_or(0)
    }

    /// One-cycle parallel fetch: α of basis `j`, channel `c` for a set of
    /// filters. Panics if two requested filters collide on a bank — the
    /// hardware guarantee the banking scheme exists to provide.
    pub fn fetch(&mut self, layer_id: usize, filters: &[usize], c: usize, j: usize) -> Vec<f32> {
        // Documented contract (see doc comment): fetching an unloaded
        // layer is a simulator-driver bug, panicking is the spec.
        #[allow(clippy::expect_used)]
        let (bases, meta) = self.layer_base.get(&layer_id).expect("layer not loaded");
        let mut used = vec![false; self.n_ports];
        let mut out = Vec::with_capacity(filters.len());
        let mut conflict = 1usize;
        for &o in filters {
            let bank = o % self.n_ports;
            if used[bank] {
                conflict += 1;
            }
            used[bank] = true;
            // Word index of filter o inside its bank for this layer:
            // filters land in the bank in ascending order, o / n_ports-th
            // block of n_in·n_basis words.
            let block = o / self.n_ports;
            let idx =
                bases[bank] + block * meta.n_in * meta.n_basis + c * meta.n_basis + j;
            out.push(self.banks[bank][idx]);
        }
        self.reads += 1;
        self.max_bank_conflict = self.max_bank_conflict.max(conflict);
        assert_eq!(
            self.max_bank_conflict, 1,
            "bank conflict: filters {filters:?} on {} ports",
            self.n_ports
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn sample_weights(seed: u64) -> HwOvsfWeights {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        HwOvsfWeights::random(&mut rng, 8, 4, 3, 0.5).unwrap()
    }

    #[test]
    fn round_trips_alphas() {
        let w = sample_weights(1);
        let mut buf = AlphaBufferSim::new(4);
        buf.write_layer(0, &w);
        for o in 0..w.n_out {
            for c in 0..w.n_in {
                for j in 0..w.n_basis {
                    let got = buf.fetch(0, &[o], c, j);
                    assert_eq!(got[0], w.alpha(o, c, j), "o={o} c={c} j={j}");
                }
            }
        }
    }

    #[test]
    fn parallel_fetch_of_consecutive_filters() {
        let w = sample_weights(2);
        let mut buf = AlphaBufferSim::new(4);
        buf.write_layer(0, &w);
        // A subtile straddling filters 4..8 — one per bank, no conflicts.
        let got = buf.fetch(0, &[4, 5, 6, 7], 1, 2);
        for (i, o) in (4..8).enumerate() {
            assert_eq!(got[i], w.alpha(o, 1, 2));
        }
        assert_eq!(buf.max_bank_conflict, 1);
    }

    #[test]
    #[should_panic(expected = "bank conflict")]
    fn conflicting_filters_panic() {
        let w = sample_weights(3);
        let mut buf = AlphaBufferSim::new(4);
        buf.write_layer(0, &w);
        buf.fetch(0, &[0, 4], 0, 0); // both map to bank 0
    }

    #[test]
    fn multiple_layers_coexist() {
        let w0 = sample_weights(4);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let w1 = HwOvsfWeights::random(&mut rng, 6, 2, 2, 1.0).unwrap();
        let mut buf = AlphaBufferSim::new(2);
        buf.write_layer(0, &w0);
        buf.write_layer(7, &w1);
        assert_eq!(buf.fetch(7, &[3], 1, 2)[0], w1.alpha(3, 1, 2));
        assert_eq!(buf.fetch(0, &[5], 2, 0)[0], w0.alpha(5, 2, 0));
    }

    #[test]
    fn depth_matches_eq4_shape() {
        let w = sample_weights(6);
        let mut buf = AlphaBufferSim::new(4);
        buf.write_layer(0, &w);
        // 8 filters × 4 ch × 8 basis = 256 α over 4 banks ⇒ 64 deep.
        assert_eq!(buf.depth(), 64);
    }
}
