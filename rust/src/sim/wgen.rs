//! CNN-WGen / TiWGen simulator (paper Alg. 1, §4.2).
//!
//! Walks the exact loop nest of Alg. 1 — tiles → subtiles → basis vectors,
//! with the M-wide vector datapath unrolled — producing both the cycle
//! count (pipelined: one basis vector per cycle per subtile) and the actual
//! numeric weights, which are checked against the software OVSF oracle.
//!
//! Tile layout: a weights tile is `T_P×T_C`, flattened column-major
//! (filters are columns), so an M-element subtile spans
//! `⌈min(T_P,M)/K'²⌉·⌊M/T_P⌋ + …` filter-chunks — paper Eq. 3's `N_f`,
//! which the simulator verifies as the peak per-cycle α-port demand.

use crate::arch::DesignPoint;
use crate::ovsf::codes::OvsfBasis;
use crate::sim::hw_weights::HwOvsfWeights;
use crate::util::ceil_div;

/// Result of generating one layer's full weights matrix.
#[derive(Clone, Debug)]
pub struct WGenResult {
    /// Generated `P×C` weights, row-major `w[p·C + o]`
    /// (`P = N_in·K'²`, `C = N_out`).
    pub weights: Vec<f32>,
    /// Cycles consumed per *output tile* (Eq. 5's quantity).
    pub cycles_per_output_tile: u64,
    /// Peak distinct (filter, chunk) α reads needed in any single cycle.
    pub peak_alpha_ports: usize,
    /// Total multiply-accumulate operations issued by the vector datapath.
    pub vector_macs: u64,
}

/// Simulate TiWGen for one layer.
pub struct WGenSim<'a> {
    sigma: &'a DesignPoint,
    w: &'a HwOvsfWeights,
}

impl<'a> WGenSim<'a> {
    /// New simulator over hardware-form weights.
    pub fn new(sigma: &'a DesignPoint, w: &'a HwOvsfWeights) -> Self {
        assert!(sigma.has_wgen(), "WGen disabled in this design point");
        Self { sigma, w }
    }

    /// Generate the full `P×C` weights matrix, walking every weight tile of
    /// every column tile exactly as Alg. 1 schedules them. `P = N_in·K²`
    /// (engine layout); non-pow2 kernels read the cropped frame positions
    /// of the `K'²`-length codes via the aligner's per-layer shift options.
    pub fn generate(&self) -> WGenResult {
        let ek = self.w.engine_chunk();
        let p_dim = self.w.p_dim();
        let c_dim = self.w.n_out;
        let (m, t_p, t_c) = (
            self.sigma.m as usize,
            self.sigma.t_p as usize,
            self.sigma.t_c as usize,
        );
        let p_tiles = ceil_div(p_dim as u64, t_p as u64);
        let subtiles = self.sigma.subtiles_per_tile();
        let n_basis = self.w.n_basis;

        let mut weights = vec![0.0f32; p_dim * c_dim];
        let mut cycles_one_tile = 0u64;
        let mut peak_ports = 0usize;
        let mut vector_macs = 0u64;

        // Hoisted lookups (§Perf): the basis sign at engine position
        // `p % K²` does not depend on the tile walk — pack one cropped sign
        // row per basis vector into u64 words (bit `kpos` ⇔ +1), mirroring
        // the 1-bit on-chip FIFO format. One word covers every evaluated
        // kernel (K ≤ 8 ⇒ K² ≤ 64); larger kernels just take more words.
        // Signs come from the matrix-free popcount closed form — no basis
        // materialisation.
        let sign_words = ek.div_ceil(64).max(1);
        let mut packed_signs = vec![0u64; n_basis * sign_words];
        for j in 0..n_basis {
            for kpos in 0..ek {
                if OvsfBasis::sign(j, self.w.frame_pos(kpos)) > 0 {
                    packed_signs[j * sign_words + (kpos >> 6)] |= 1u64 << (kpos & 63);
                }
            }
        }

        let col_tiles = ceil_div(c_dim as u64, t_c as u64);
        let n_basis_stride = self.w.n_basis;
        let mut ports: Vec<(usize, usize)> = Vec::with_capacity(16);
        // Reusable per-subtile lane descriptors: (weights index, α base
        // index, engine kernel position) — all the div/mod address math of
        // the M-wide datapath hoisted out of the per-cycle basis loop
        // (§Perf: the hardware computes these with wiring, not per cycle).
        let mut lanes: Vec<(u32, u32, u16)> = Vec::with_capacity(m);
        for ct in 0..col_tiles {
            let col_base = (ct as usize) * t_c;
            for t in 0..p_tiles {
                // tiles loop (Alg. 1 line 1) — PIPELINE
                let p_base = (t as usize) * t_p;
                for i in 0..subtiles {
                    // subtiles loop (line 2) — PIPELINE
                    let g_base = (i as usize) * m;
                    // Lane addressing + the per-cycle α-port set depend only
                    // on the subtile geometry, not on the basis index j:
                    // compute them once per subtile.
                    ports.clear();
                    lanes.clear();
                    for e in 0..m {
                        let g = g_base + e;
                        if g >= t_p * t_c {
                            break; // last subtile may overhang the tile
                        }
                        let o = col_base + g / t_p;
                        let p = p_base + g % t_p;
                        if o >= c_dim || p >= p_dim {
                            continue; // edge tiles: lanes idle
                        }
                        let c = p / ek;
                        lanes.push((
                            (p * c_dim + o) as u32,
                            ((o * self.w.n_in + c) * n_basis_stride) as u32,
                            (p % ek) as u16,
                        ));
                        let pair = (o, c);
                        if ports.last() != Some(&pair) && !ports.contains(&pair) {
                            ports.push(pair);
                        }
                    }
                    peak_ports = peak_ports.max(ports.len());
                    for (j, sign_row) in packed_signs.chunks_exact(sign_words).enumerate() {
                        // basis vectors loop (line 4) — PIPELINE (1 cycle)
                        if ct == 0 {
                            cycles_one_tile += 1;
                        }
                        for &(w_idx, a_base, kpos) in &lanes {
                            // inner M-wide loop (line 5) — UNROLL:
                            // ±1 sign application is a bit test on the
                            // packed word (add/sub select, no multiply)
                            let a = self.w.alphas[a_base as usize + j];
                            let bit = sign_row[(kpos >> 6) as usize] >> (kpos & 63) & 1;
                            weights[w_idx as usize] += if bit == 1 { a } else { -a };
                        }
                        vector_macs += lanes.len() as u64;
                    }
                }
            }
        }
        WGenResult {
            weights,
            cycles_per_output_tile: cycles_one_tile,
            peak_alpha_ports: peak_ports,
            vector_macs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Platform;
    use crate::perf::model::PerfModel;
    use crate::rsc::model::AlphaBufferGeometry;
    use crate::util::check::forall;
    use crate::util::prng::Xoshiro256;
    use crate::workload::layer::Layer;

    fn sim_layer(
        rng: &mut Xoshiro256,
        n_out: usize,
        n_in: usize,
        k: usize,
        rho: f64,
        sigma: &DesignPoint,
    ) -> (HwOvsfWeights, WGenResult) {
        let w = HwOvsfWeights::random(rng, n_out, n_in, k, rho).unwrap();
        let r = WGenSim::new(sigma, &w).generate();
        (w, r)
    }

    #[test]
    fn generated_weights_match_oracle() {
        forall("tiwgen-matches-oracle", 20, |rng| {
            let sigma = DesignPoint::new(
                1 << rng.gen_range(2, 6),  // M ∈ 4..32
                16,
                1 << rng.gen_range(2, 5),  // T_P ∈ 4..16
                1 << rng.gen_range(2, 5),  // T_C ∈ 4..16
            );
            let (w, r) = sim_layer(rng, 8, 4, 3, 0.5, &sigma);
            let oracle = w.dense_gemm().unwrap();
            assert_eq!(r.weights.len(), oracle.len());
            for (i, (a, b)) in r.weights.iter().zip(&oracle).enumerate() {
                assert!((a - b).abs() < 1e-4, "idx {i}: {a} vs {b} ({sigma})");
            }
        });
    }

    #[test]
    fn cycle_count_equals_eq5() {
        // The simulator's walked cycle count must equal the closed form
        // t_wgen = ⌊ρ·K'²⌉ · ⌈T_P·T_C/M⌉ · ⌈P/T_P⌉ (Eq. 5).
        forall("tiwgen-eq5", 20, |rng| {
            let sigma = DesignPoint::new(
                1 << rng.gen_range(3, 6),
                32,
                1 << rng.gen_range(2, 5),
                1 << rng.gen_range(3, 6),
            );
            let n_in = 1usize << rng.gen_range(2, 4); // 4..8
            let rho = *rng.choose(&[0.25, 0.5, 1.0]);
            let (w, r) = sim_layer(rng, 16, n_in, 3, rho, &sigma);
            let layer = Layer::conv("t", 8, 8, n_in as u64, w.n_out as u64, 3, 1, 1, true);
            let model = PerfModel::new(Platform::z7045(), 4);
            let expect = model.t_wgen(&sigma, &layer, rho);
            assert_eq!(
                r.cycles_per_output_tile as f64, expect,
                "sim vs Eq.5 at {sigma}, ρ={rho}"
            );
        });
    }

    #[test]
    fn alpha_port_demand_bounded_by_eq3() {
        forall("tiwgen-eq3-ports", 20, |rng| {
            let m = 1u64 << rng.gen_range(2, 6);
            let t_p = 1u64 << rng.gen_range(2, 5);
            let sigma = DesignPoint::new(m, 16, t_p, 16);
            let (w, r) = sim_layer(rng, 16, 4, 3, 0.5, &sigma);
            // Port demand is set by the *engine* chunk width (9 for K=3):
            // that is the granularity at which a subtile straddles filters.
            // Eq. 3 assumes aligned tiling; the worst-case bound covers
            // arbitrary (M, T_P, K²) alignment.
            let k2 = w.engine_chunk() as u64;
            let n_f = AlphaBufferGeometry::n_f_worst_case(m, t_p, k2) as usize;
            assert!(
                r.peak_alpha_ports <= n_f,
                "peak ports {} exceed worst-case N_f {} (M={m}, T_P={t_p})",
                r.peak_alpha_ports,
                n_f
            );
        });
    }

    #[test]
    fn vector_macs_match_alpha_volume() {
        // Every weight element accumulates n_basis products; lanes covering
        // out-of-range elements idle.
        let mut rng = Xoshiro256::seed_from_u64(9);
        let sigma = DesignPoint::new(16, 16, 8, 8);
        let (w, r) = sim_layer(&mut rng, 8, 4, 4, 0.5, &sigma);
        let expect = w.p_dim() as u64 * w.n_out as u64 * w.n_basis as u64;
        assert_eq!(r.vector_macs, expect);
    }

    #[test]
    fn full_rho_reconstruction_is_exact_for_pow2() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        let dense: Vec<f32> = rng.normal_vec(8 * 4 * 16);
        let hw = HwOvsfWeights::from_dense(&dense, 8, 4, 4, 1.0).unwrap();
        let sigma = DesignPoint::new(32, 16, 16, 8);
        let r = WGenSim::new(&sigma, &hw).generate();
        for o in 0..8 {
            for c in 0..4 {
                for pos in 0..16 {
                    let orig = dense[((o * 4 + c) * 4 + pos / 4) * 4 + pos % 4];
                    let got = r.weights[(c * 16 + pos) * 8 + o];
                    assert!((orig - got).abs() < 1e-4);
                }
            }
        }
    }
}
