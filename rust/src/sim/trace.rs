//! Per-layer simulation records.

use crate::perf::Bound;

/// What the cycle-level simulator measured for one layer.
#[derive(Clone, Debug)]
pub struct LayerTrace {
    /// Layer name.
    pub name: String,
    /// Input DMA cycles per output tile.
    pub t_mem_in: u64,
    /// Weights-generation cycles per output tile.
    pub t_wgen: u64,
    /// Engine cycles per output tile.
    pub t_eng: u64,
    /// Output DMA cycles per output tile.
    pub t_mem_out: u64,
    /// Initiation interval (max of stages).
    pub ii: u64,
    /// Output tiles processed.
    pub tiles: u64,
    /// Total cycles (`II·tiles` in steady state).
    pub total_cycles: u64,
    /// Dominating stage.
    pub bound: Bound,
    /// Input bytes moved.
    pub bytes_in: u64,
    /// Output bytes moved.
    pub bytes_out: u64,
}

impl LayerTrace {
    /// Pretty one-line summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<24} II={:>8} tiles={:>5} total={:>10} bound={}",
            self.name, self.ii, self.tiles, self.total_cycles, self.bound
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_contains_fields() {
        let t = LayerTrace {
            name: "conv1".into(),
            t_mem_in: 10,
            t_wgen: 5,
            t_eng: 8,
            t_mem_out: 2,
            ii: 10,
            tiles: 4,
            total_cycles: 40,
            bound: Bound::Ifm,
            bytes_in: 100,
            bytes_out: 20,
        };
        let s = t.summary();
        assert!(s.contains("conv1") && s.contains("IFM") && s.contains("40"));
    }
}
