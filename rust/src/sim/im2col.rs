//! im2col lowering — the paper's engine executes convolutions as GEMM
//! (§4.1): an `R×P` activations matrix is built from the NHWC feature map
//! with `R = out_h·out_w` patch rows and `P = N_in·K²` columns ordered
//! channel-major (`p = c·K² + kh·K + kw`), matching both the TiWGen weight
//! layout and JAX's HWIO convolution semantics so the simulator's layer
//! output can be bit-compared with the PJRT conv artifact.

use crate::workload::layer::Layer;

/// Lower one NHWC feature map (`h×w×c_in`, batch 1) to the layer's `R×P`
/// GEMM activations with SAME-style padding described by the layer.
pub fn im2col(layer: &Layer, x: &[f32]) -> Vec<f32> {
    let r = (layer.out_h() * layer.out_w()) as usize;
    let mut out = Vec::new();
    im2col_strip_into(layer, x, 0, r, &mut out);
    out
}

/// Lower only patch rows `[r0, r1)` — one activation row-strip of the
/// `R×P` GEMM view — into caller scratch (`out` is cleared and refilled to
/// `(r1−r0)·P`). The tile-streamed engine builds activations a `T_R`-strip
/// at a time with this entry point, so activation lowering never costs
/// more scratch than one strip.
pub fn im2col_strip_into(layer: &Layer, x: &[f32], r0: usize, r1: usize, out: &mut Vec<f32>) {
    let (h, w, c_in) = (layer.h as usize, layer.w as usize, layer.n_in as usize);
    assert_eq!(x.len(), h * w * c_in, "input must be h·w·c_in NHWC");
    let out_w = layer.out_w() as usize;
    let r_dim = layer.out_h() as usize * out_w;
    assert!(r0 < r1 && r1 <= r_dim, "strip [{r0}, {r1}) out of R = {r_dim}");
    let k = layer.k as usize;
    let s = layer.stride as usize;
    let pad = layer.pad as usize;
    let p_dim = c_in * k * k;
    out.clear();
    out.resize((r1 - r0) * p_dim, 0.0);
    for r in r0..r1 {
        let (oy, ox) = (r / out_w, r % out_w);
        let row = &mut out[(r - r0) * p_dim..(r - r0 + 1) * p_dim];
        for c in 0..c_in {
            for kh in 0..k {
                for kw in 0..k {
                    let iy = (oy * s + kh) as isize - pad as isize;
                    let ix = (ox * s + kw) as isize - pad as isize;
                    let v = if iy >= 0 && ix >= 0 && (iy as usize) < h && (ix as usize) < w {
                        x[(iy as usize * w + ix as usize) * c_in + c]
                    } else {
                        0.0 // zero padding
                    };
                    row[c * k * k + kh * k + kw] = v;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_1x1_conv_is_a_reshape() {
        let layer = Layer::conv("pw", 3, 3, 2, 4, 1, 1, 0, false);
        let x: Vec<f32> = (0..18).map(|i| i as f32).collect();
        let m = im2col(&layer, &x);
        // R=9, P=2: row r = pixel r's 2 channels.
        assert_eq!(m.len(), 9 * 2);
        assert_eq!(m[0], x[0]);
        assert_eq!(m[1], x[1]);
        assert_eq!(m[2 * 4], x[8]); // pixel 4, channel 0
    }

    #[test]
    fn padding_zeroes_the_border_taps() {
        let layer = Layer::conv("c", 4, 4, 1, 1, 3, 1, 1, false);
        let x = vec![1.0f32; 16];
        let m = im2col(&layer, &x);
        // Top-left output: the (0,0) tap falls on padding.
        assert_eq!(m[0], 0.0, "kh=0,kw=0 of corner patch is padded");
        assert_eq!(m[4], 1.0, "centre tap is real data");
        // Interior patch (1,1): all taps real.
        let r = 1 * 4 + 1;
        assert!(m[r * 9..r * 9 + 9].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn strided_conv_shrinks_rows() {
        let layer = Layer::conv("s", 8, 8, 2, 4, 3, 2, 1, false);
        let x = vec![0.5f32; 8 * 8 * 2];
        let m = im2col(&layer, &x);
        let g = layer.gemm();
        assert_eq!(m.len(), (g.r * g.p) as usize);
        assert_eq!(g.r, 16); // 4×4 outputs
    }

    #[test]
    fn strips_tile_the_full_lowering() {
        let layer = Layer::conv("c", 6, 6, 2, 4, 3, 1, 1, false);
        let mut rng = crate::util::prng::Xoshiro256::seed_from_u64(7);
        let x = rng.normal_vec(6 * 6 * 2);
        let full = im2col(&layer, &x);
        let g = layer.gemm();
        let p = g.p as usize;
        let mut strip = Vec::new();
        for t_r in [1usize, 4, 7, g.r as usize] {
            for r0 in (0..g.r as usize).step_by(t_r) {
                let r1 = (r0 + t_r).min(g.r as usize);
                im2col_strip_into(&layer, &x, r0, r1, &mut strip);
                assert_eq!(strip.as_slice(), &full[r0 * p..r1 * p], "T_R={t_r} r0={r0}");
            }
        }
    }

    #[test]
    fn conv_via_gemm_matches_direct_convolution() {
        // Small direct conv reference.
        let layer = Layer::conv("c", 5, 5, 2, 3, 3, 1, 1, false);
        let mut rng = crate::util::prng::Xoshiro256::seed_from_u64(3);
        let x = rng.normal_vec(5 * 5 * 2);
        let wts = rng.normal_vec(2 * 9 * 3); // P×C
        let m = im2col(&layer, &x);
        let g = layer.gemm();
        // GEMM path.
        let mut via_gemm = vec![0.0f32; (g.r * g.c) as usize];
        for r in 0..g.r as usize {
            for p in 0..g.p as usize {
                for c in 0..g.c as usize {
                    via_gemm[r * g.c as usize + c] +=
                        m[r * g.p as usize + p] * wts[p * g.c as usize + c];
                }
            }
        }
        // Direct convolution.
        for oy in 0..5usize {
            for ox in 0..5usize {
                for co in 0..3usize {
                    let mut acc = 0.0f32;
                    for ci in 0..2usize {
                        for kh in 0..3usize {
                            for kw in 0..3usize {
                                let iy = oy as isize + kh as isize - 1;
                                let ix = ox as isize + kw as isize - 1;
                                if iy < 0 || ix < 0 || iy >= 5 || ix >= 5 {
                                    continue;
                                }
                                let xv = x[(iy as usize * 5 + ix as usize) * 2 + ci];
                                let wv = wts[(ci * 9 + kh * 3 + kw) * 3 + co];
                                acc += xv * wv;
                            }
                        }
                    }
                    let got = via_gemm[(oy * 5 + ox) * 3 + co];
                    assert!((got - acc).abs() < 1e-4, "({oy},{ox},{co})");
                }
            }
        }
    }
}
