//! Fixed-point datapath simulation (paper §7.1: all designs evaluated at
//! 16-bit fixed point; "unzipFPGA provides support for both custom
//! fixed-point and floating-point precisions").
//!
//! Models the quantised hardware path end-to-end: α coefficients and
//! activations quantised to a QFormat, TiWGen's multiplier/adder arrays
//! operating on quantised values (binary codes are exact), and the PE
//! array accumulating in wide registers (no intermediate rounding — the
//! usual DSP-slice accumulator behaviour).

use crate::arch::DesignPoint;
use crate::sim::hw_weights::HwOvsfWeights;
use crate::sim::pe_array::PeArraySim;
use crate::sim::wgen::WGenSim;
use crate::util::fixed::QFormat;

/// Outcome of a quantised layer execution.
#[derive(Clone, Debug)]
pub struct QuantResult {
    /// Output activations (real values of the fixed-point results).
    pub out: Vec<f32>,
    /// Max |quantised − float| over the outputs.
    pub max_error: f32,
    /// The analytic error bound used by the verification
    /// (per-weight α rounding × accumulation depth).
    pub error_bound: f32,
}

/// Execute one OVSF layer with a quantised datapath and compare against
/// the float reference.
pub fn execute_quantised(
    sigma: &DesignPoint,
    w: &HwOvsfWeights,
    act: &[f32],
    r: usize,
    fmt: QFormat,
) -> QuantResult {
    let p = w.p_dim();
    let c = w.n_out;
    assert_eq!(act.len(), r * p);

    // Float reference path.
    let wg_f = WGenSim::new(sigma, w).generate();
    let pe = PeArraySim::new(sigma, true);
    let ref_out = pe.execute(act, &wg_f.weights, r, p, c).out;

    // Quantised path: α and activations to fmt; weights re-quantised after
    // generation (the weights buffer is WL-bit, §5.2).
    let mut wq = w.clone();
    for a in wq.alphas.iter_mut() {
        *a = fmt.quantise(*a);
    }
    let mut wg_q = WGenSim::new(sigma, &wq).generate();
    for v in wg_q.weights.iter_mut() {
        *v = fmt.quantise(*v);
    }
    let act_q: Vec<f32> = act.iter().map(|&a| fmt.quantise(a)).collect();
    let out = pe.execute(&act_q, &wg_q.weights, r, p, c).out;

    let max_error = out
        .iter()
        .zip(&ref_out)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    // Error budget: weight error ≤ n_basis·step/2 (α rounding through ±1
    // codes) + step/2 (weight-buffer rounding); activation error ≤ step/2.
    // Each of the P accumulation terms contributes
    // |w|·εa + |a|·εw + εa·εw; bound with the observed magnitudes.
    let step = fmt.step();
    let eps_w = w.n_basis as f32 * step / 2.0 + step / 2.0;
    let eps_a = step / 2.0;
    let max_w = wg_f.weights.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let max_a = act.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let error_bound = p as f32 * (max_w * eps_a + max_a * eps_w + eps_a * eps_w) + 1e-4;
    QuantResult {
        out,
        max_error,
        error_bound,
    }
}

/// Analytic per-element error bound for the **int8** datapath, the i8
/// counterpart of the budget inside [`execute_quantised`]: each of the `p`
/// accumulation terms contributes `|w|·εa + |a|·εw + εa·εw`, where the i8
/// scheme's rounding errors are half a step of each scale. Weights are
/// rounded exactly once at slab emission (`eps_w = w_scale/2` — no α-path
/// rounding, the FWHT stays f32) and activations once per strip
/// (`eps_a ≤ a_scale/2` with `a_scale ≤ max_a/127`); i32 accumulation adds
/// nothing. `max_w` may be the α-derived upper bound `127·w_scale` when
/// the true dense maximum is not at hand.
pub fn i8_error_bound(p: usize, max_w: f32, max_a: f32, w_scale: f32) -> f32 {
    let eps_w = w_scale / 2.0;
    let eps_a = crate::util::fixed::I8Scheme::from_max_abs(max_a).max_error();
    p as f32 * (max_w * eps_a + max_a * eps_w + eps_a * eps_w) + 1e-4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn quantised_path_stays_within_bound() {
        forall("quant-error-bound", 12, |rng| {
            let w = HwOvsfWeights::random(rng, 6, 4, 3, 0.5).unwrap();
            let r = 10usize;
            let act = rng.normal_vec(r * w.p_dim());
            let sigma = DesignPoint::new(16, 16, 8, 8);
            let q = execute_quantised(&sigma, &w, &act, r, QFormat::Q16);
            assert!(
                q.max_error <= q.error_bound,
                "error {} exceeds bound {}",
                q.max_error,
                q.error_bound
            );
        });
    }

    #[test]
    fn wider_formats_reduce_error() {
        let mut rng = crate::util::prng::Xoshiro256::seed_from_u64(4);
        let w = HwOvsfWeights::random(&mut rng, 4, 4, 3, 0.5).unwrap();
        let r = 8usize;
        let act = rng.normal_vec(r * w.p_dim());
        let sigma = DesignPoint::new(16, 16, 8, 8);
        let coarse = execute_quantised(
            &sigma,
            &w,
            &act,
            r,
            QFormat {
                int_bits: 8,
                frac_bits: 3,
            },
        );
        let fine = execute_quantised(&sigma, &w, &act, r, QFormat::Q16);
        assert!(
            fine.max_error < coarse.max_error,
            "Q16 {} !< Q12 {}",
            fine.max_error,
            coarse.max_error
        );
    }

    #[test]
    fn q16_error_is_small_in_practice() {
        // The paper's 16-bit designs lose <1pp accuracy; at layer level
        // the numeric error should be far below activation magnitudes.
        let mut rng = crate::util::prng::Xoshiro256::seed_from_u64(5);
        let w = HwOvsfWeights::random(&mut rng, 8, 4, 3, 1.0).unwrap();
        let r = 12usize;
        let act = rng.normal_vec(r * w.p_dim());
        let sigma = DesignPoint::new(32, 16, 8, 8);
        let q = execute_quantised(&sigma, &w, &act, r, QFormat::Q16);
        let out_scale = q.out.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert!(
            q.max_error < 0.02 * out_scale.max(1.0),
            "relative error {} too large",
            q.max_error / out_scale
        );
    }
}
