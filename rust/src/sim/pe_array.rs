//! Output-stationary PE array (paper §4.1) with input-selective PEs
//! (§4.3, Fig. 6).
//!
//! `T_C` PEs, each a `T_P`-wide dot-product circuit. An output tile is
//! produced by accumulating `⌈P/T_P⌉` depth tiles; within a depth tile the
//! `T_R` activation rows stream through the array one per cycle.
//!
//! When a layer's `C < T_C`, the idle `T_C − C` PEs are fed forwarded
//! weights from their neighbours (the input-selective switches) and process
//! *extra rows* of the same columns — a work-stealing schedule whose cycle
//! count the simulator derives by walking the schedule, cross-checked
//! against the closed-form `t_eng*` (Eq. 7).

use crate::arch::DesignPoint;

/// Result of computing one layer's full output with the PE array.
#[derive(Clone, Debug)]
pub struct PeArrayResult {
    /// Output matrix `R×C`, row-major.
    pub out: Vec<f32>,
    /// Engine cycles per output tile (steady-state, full tiles).
    pub cycles_per_tile: u64,
    /// Total MAC operations performed (useful work only).
    pub macs: u64,
}

/// The PE-array simulator.
pub struct PeArraySim<'a> {
    sigma: &'a DesignPoint,
    /// Selective-PE switches instantiated.
    pub selective: bool,
}

impl<'a> PeArraySim<'a> {
    /// New array for a design point.
    pub fn new(sigma: &'a DesignPoint, selective: bool) -> Self {
        Self { sigma, selective }
    }

    /// Engine cycles to produce one `T_R×T_C` output tile of a layer with
    /// `c_cols` live columns — the schedule walk.
    ///
    /// Plain schedule: `T_R` rows per depth tile ⇒ `T_R·⌈P/T_P⌉`.
    /// Selective schedule (c_cols < T_C): the array first streams rows with
    /// the `c+1`-deep forwarding chain filling the idle PEs (the chain head
    /// costs `T_C − c` fill cycles), then rows proceed `⌈T_C/c⌉`-at-a-time —
    /// the paper's Eq. 7 closed form, which the tests verify against a
    /// discrete-event walk of the same schedule.
    pub fn tile_cycles(&self, rows: u64, p_tiles: u64, c_cols: u64) -> u64 {
        let t_c = self.sigma.t_c;
        let plain = rows * p_tiles;
        if !self.selective || c_cols >= t_c {
            return plain;
        }
        let idle = t_c - c_cols;
        let numer = (rows * c_cols) as i64 - (idle * (c_cols + 1)) as i64;
        let steady = if numer <= 0 {
            0
        } else {
            (numer as u64).div_ceil(t_c)
        };
        let refined = (idle + steady) * p_tiles;
        let floor = (rows * c_cols).div_ceil(t_c) * p_tiles;
        refined.max(floor).min(plain)
    }

    /// Streamed tile-GEMM entry point: multiply one activation row-strip
    /// (`act`, `rows×p` row-major) by one weight *slab* (`slab`, `p×cols`
    /// row-major — columns `[col_offset, col_offset+cols)` of the layer's
    /// `P×C` weights) and accumulate into the matching columns of the
    /// output strip `out` (`rows×out_stride` row-major). This is what the
    /// engine backend drives per `(row strip, weight slab)` pair while
    /// slabs are generated on the fly, so dense weights never need to
    /// exist beyond one slab.
    ///
    /// Returns the engine cycles charged for this strip×slab pass under
    /// the active schedule (plain, or input-selective work-stealing when
    /// the slab has fewer live columns than `T_C`). Numerics are identical
    /// under both schedules — only the cycle count differs.
    #[allow(clippy::too_many_arguments)]
    pub fn execute_strip(
        &self,
        act: &[f32],
        slab: &[f32],
        rows: usize,
        p: usize,
        cols: usize,
        out: &mut [f32],
        out_stride: usize,
        col_offset: usize,
    ) -> u64 {
        assert_eq!(act.len(), rows * p, "activation strip shape");
        assert_eq!(slab.len(), p * cols, "weight slab shape");
        assert_eq!(out.len(), rows * out_stride, "output strip shape");
        assert!(col_offset + cols <= out_stride, "slab overruns output");
        gemm_strip(act, slab, rows, p, cols, out, out_stride, col_offset);
        let p_tiles = (p as u64).div_ceil(self.sigma.t_p);
        self.tile_cycles(rows as u64, p_tiles, cols as u64)
    }

    /// The original scalar depth-tiled inner loop, kept as the numerics
    /// oracle for the register-blocked microkernel: every output element
    /// accumulates its products in ascending-`p` order starting from the
    /// incoming `out` value, which is exactly the order the microkernel
    /// preserves — the two must agree **bit-for-bit**.
    #[cfg(test)]
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn execute_strip_reference(
        &self,
        act: &[f32],
        slab: &[f32],
        rows: usize,
        p: usize,
        cols: usize,
        out: &mut [f32],
        out_stride: usize,
        col_offset: usize,
    ) -> u64 {
        assert_eq!(act.len(), rows * p, "activation strip shape");
        assert_eq!(slab.len(), p * cols, "weight slab shape");
        assert_eq!(out.len(), rows * out_stride, "output strip shape");
        assert!(col_offset + cols <= out_stride, "slab overruns output");
        let t_p = self.sigma.t_p as usize;
        for p0 in (0..p).step_by(t_p) {
            let p1 = (p0 + t_p).min(p);
            for ri in 0..rows {
                let arow = &act[ri * p..(ri + 1) * p];
                let obase = ri * out_stride + col_offset;
                let orow = &mut out[obase..obase + cols];
                for pi in p0..p1 {
                    let a = arow[pi];
                    let wrow = &slab[pi * cols..(pi + 1) * cols];
                    for (o, &wv) in orow.iter_mut().zip(wrow) {
                        *o += a * wv;
                    }
                }
            }
        }
        let p_tiles = (p as u64).div_ceil(self.sigma.t_p);
        self.tile_cycles(rows as u64, p_tiles, cols as u64)
    }

    /// Int8 strip entry point: the i8×i8→i32 twin of
    /// [`execute_strip`](Self::execute_strip), driven when the generated
    /// slab is [`Precision::I8`](crate::util::fixed::Precision).
    ///
    /// The f32 activation strip is quantised symmetrically **per strip**
    /// (scale = max|act|/127 — a pure function of the strip's contents, so
    /// serial, pipelined and sharded schedules all see identical codes),
    /// products accumulate exactly in i32 (the DSP-accumulator behaviour
    /// `sim/quant.rs` models; safe from overflow for `p` up to ~130k at
    /// ±127 codes), and each output element is dequantised **once** at
    /// strip end with `acc · (a_scale · w_scale)`. Because slabs span the
    /// full depth `p`, every output element completes its entire reduction
    /// inside one strip×slab pass — there is no cross-slab i32 state, so
    /// the f32 output buffer is the only accumulator that crosses passes.
    ///
    /// Cycle accounting is precision-independent (the modelled fixed-point
    /// hardware retires one MAC per PE per cycle at any WL), so the same
    /// schedule walk prices both paths; the i8 win in *this* simulator is
    /// wall-clock (denser registers, ¼ slab bytes) and cache hit rate.
    #[allow(clippy::too_many_arguments)]
    pub fn execute_strip_i8(
        &self,
        act: &[f32],
        slab: &[i8],
        w_scale: f32,
        rows: usize,
        p: usize,
        cols: usize,
        out: &mut [f32],
        out_stride: usize,
        col_offset: usize,
    ) -> u64 {
        assert_eq!(act.len(), rows * p, "activation strip shape");
        assert_eq!(slab.len(), p * cols, "weight slab shape");
        assert_eq!(out.len(), rows * out_stride, "output strip shape");
        assert!(col_offset + cols <= out_stride, "slab overruns output");
        let max_abs = act.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let a_scheme = crate::util::fixed::I8Scheme::from_max_abs(max_abs);
        let act_q: Vec<i8> = act.iter().map(|&v| a_scheme.quantise(v)).collect();
        let deq = a_scheme.scale * w_scale;
        gemm_strip_i8(&act_q, slab, rows, p, cols, out, out_stride, col_offset, deq);
        let p_tiles = (p as u64).div_ceil(self.sigma.t_p);
        self.tile_cycles(rows as u64, p_tiles, cols as u64)
    }

    /// Scalar i8 oracle for the register-blocked int8 kernel: one i32
    /// accumulator per output element over the whole `p` reduction, one
    /// dequantise at the end — integer accumulation is exact, so the
    /// blocked kernel must agree **bit-for-bit**.
    #[cfg(test)]
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn execute_strip_i8_reference(
        &self,
        act: &[f32],
        slab: &[i8],
        w_scale: f32,
        rows: usize,
        p: usize,
        cols: usize,
        out: &mut [f32],
        out_stride: usize,
        col_offset: usize,
    ) -> u64 {
        assert_eq!(act.len(), rows * p, "activation strip shape");
        assert_eq!(slab.len(), p * cols, "weight slab shape");
        let max_abs = act.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let a_scheme = crate::util::fixed::I8Scheme::from_max_abs(max_abs);
        let act_q: Vec<i8> = act.iter().map(|&v| a_scheme.quantise(v)).collect();
        let deq = a_scheme.scale * w_scale;
        for ri in 0..rows {
            let arow = &act_q[ri * p..(ri + 1) * p];
            for ci in 0..cols {
                let mut acc = 0i32;
                for (pi, &a) in arow.iter().enumerate() {
                    acc += a as i32 * slab[pi * cols + ci] as i32;
                }
                out[ri * out_stride + col_offset + ci] += acc as f32 * deq;
            }
        }
        let p_tiles = (p as u64).div_ceil(self.sigma.t_p);
        self.tile_cycles(rows as u64, p_tiles, cols as u64)
    }

    /// Full numeric execution of one layer's GEMM
    /// (`act`: `R×P` row-major, `weights`: `P×C` row-major) with exact tile
    /// walking — a driver looping [`execute_strip`](Self::execute_strip)
    /// over every `(row strip, column tile)` pair. Returns the output and
    /// the steady-state tile cycle count.
    pub fn execute(&self, act: &[f32], weights: &[f32], r: usize, p: usize, c: usize) -> PeArrayResult {
        assert_eq!(act.len(), r * p);
        assert_eq!(weights.len(), p * c);
        let t_r = self.sigma.t_r as usize;
        let t_c = self.sigma.t_c as usize;
        let mut out = vec![0.0f32; r * c];
        // One preallocated scratch slab, sized for the widest (first)
        // column tile and refilled per tile with straight row copies — no
        // per-row growth bookkeeping in the oracle path.
        let mut slab = vec![0.0f32; p * t_c.min(c)];
        for c0 in (0..c).step_by(t_c) {
            let c1 = (c0 + t_c).min(c);
            let cols = c1 - c0;
            // Slice the column tile out of the dense matrix — standing in
            // for a generated slab.
            slab.truncate(p * cols);
            for (dst, row) in slab.chunks_exact_mut(cols).zip(weights.chunks_exact(c)) {
                dst.copy_from_slice(&row[c0..c1]);
            }
            for r0 in (0..r).step_by(t_r) {
                let r1 = (r0 + t_r).min(r);
                self.execute_strip(
                    &act[r0 * p..r1 * p],
                    &slab,
                    r1 - r0,
                    p,
                    c1 - c0,
                    &mut out[r0 * c..r1 * c],
                    c,
                    c0,
                );
            }
        }
        let p_tiles = (p as u64).div_ceil(self.sigma.t_p);
        let rows = (r as u64).min(self.sigma.t_r);
        let cycles_per_tile = self.tile_cycles(rows, p_tiles, (c as u64).min(self.sigma.t_c));
        PeArrayResult {
            out,
            cycles_per_tile,
            macs: (r * p * c) as u64,
        }
    }

    /// Discrete-event walk of the work-stealing schedule, the cycle-level
    /// derivation of Eq. 7: the forwarding chain spends `T_C − c` cycles
    /// feeding the idle PEs (during which `c+1` dot-product slots retire
    /// per cycle — the live columns plus the newly-fed neighbour), after
    /// which all `T_C` PEs retire slots every cycle. Used to validate
    /// `tile_cycles` in its applicable regime.
    pub fn steal_schedule_walk(&self, rows: u64, c_cols: u64) -> u64 {
        let t_c = self.sigma.t_c;
        if c_cols >= t_c {
            return rows;
        }
        let idle = t_c - c_cols;
        let mut remaining = (rows * c_cols) as i64;
        let mut cycles = 0u64;
        // Fill phase: the chain keeps forwarding until every PE is fed.
        for _ in 0..idle {
            remaining -= (c_cols + 1) as i64;
            cycles += 1;
        }
        // Steady phase: full-array retirement.
        if remaining > 0 {
            cycles += (remaining as u64).div_ceil(t_c);
        }
        cycles.max((rows * c_cols).div_ceil(t_c))
    }
}

/// Microkernel row blocking: rows of output accumulated per register block.
const MR: usize = 4;
/// Microkernel column blocking: output columns per register block — with
/// `MR`, a 4×8 f32 accumulator tile that fits the vector register file and
/// autovectorises on any 128/256-bit SIMD target.
const NR: usize = 8;

/// Register-blocked strip GEMM: `out[r][col_offset + c] += Σ_p act[r][p] ·
/// slab[p][c]` over `rows×cols`, walked in `MR×NR` register tiles with the
/// depth loop innermost-but-one so the `MR·NR` accumulators stay live in
/// registers across the whole `p` reduction.
///
/// Numerics contract: every output element starts from its incoming value
/// and accumulates its products in ascending-`p` order — the same f32
/// operation sequence as the scalar reference loop, so results are
/// bit-identical regardless of blocking (edge blocks fall back to the
/// same-order generic kernel).
#[allow(clippy::too_many_arguments)]
fn gemm_strip(
    act: &[f32],
    slab: &[f32],
    rows: usize,
    p: usize,
    cols: usize,
    out: &mut [f32],
    out_stride: usize,
    col_offset: usize,
) {
    let mut r0 = 0;
    while r0 < rows {
        let mr = MR.min(rows - r0);
        if mr == MR {
            let mut c0 = 0;
            while c0 + NR <= cols {
                block_mrxnr(act, slab, r0, p, cols, c0, out, out_stride, col_offset);
                c0 += NR;
            }
            if c0 < cols {
                block_generic(
                    act, slab, r0, MR, p, cols, c0, out, out_stride, col_offset,
                );
            }
        } else {
            block_generic(act, slab, r0, mr, p, cols, 0, out, out_stride, col_offset);
        }
        r0 += mr;
    }
}

/// One full `MR×NR` register block at rows `[r0, r0+MR)`, columns
/// `[c0, c0+NR)` of the slab.
#[allow(clippy::too_many_arguments)]
#[inline]
fn block_mrxnr(
    act: &[f32],
    slab: &[f32],
    r0: usize,
    p: usize,
    cols: usize,
    c0: usize,
    out: &mut [f32],
    out_stride: usize,
    col_offset: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for (i, row) in acc.iter_mut().enumerate() {
        let ob = (r0 + i) * out_stride + col_offset + c0;
        row.copy_from_slice(&out[ob..ob + NR]);
    }
    let a0 = &act[r0 * p..(r0 + 1) * p];
    let a1 = &act[(r0 + 1) * p..(r0 + 2) * p];
    let a2 = &act[(r0 + 2) * p..(r0 + 3) * p];
    let a3 = &act[(r0 + 3) * p..(r0 + 4) * p];
    for pi in 0..p {
        let base = pi * cols + c0;
        // Invariant: the slice is exactly NR long by construction of
        // `base`, so the array conversion cannot fail.
        #[allow(clippy::expect_used)]
        let w: &[f32; NR] = slab[base..base + NR]
            .try_into()
            .expect("slab block is NR wide");
        let (x0, x1, x2, x3) = (a0[pi], a1[pi], a2[pi], a3[pi]);
        for j in 0..NR {
            let wv = w[j];
            acc[0][j] += x0 * wv;
            acc[1][j] += x1 * wv;
            acc[2][j] += x2 * wv;
            acc[3][j] += x3 * wv;
        }
    }
    for (i, row) in acc.iter().enumerate() {
        let ob = (r0 + i) * out_stride + col_offset + c0;
        out[ob..ob + NR].copy_from_slice(row);
    }
}

/// Int8 microkernel column blocking: i8 codes pack 4× denser than f32, so
/// the register tile widens to `MR×16` i32 accumulators — the same
/// register-file budget as the 4×8 f32 tile at twice the output width.
const NR_I8: usize = 16;

/// Register-blocked int8 strip GEMM: i8×i8 products accumulate exactly in
/// `MR×NR_I8` i32 register tiles across the whole `p` reduction, then each
/// element applies one `acc · deq` f32 fused step into `out`. Integer
/// accumulation is associative-exact, so any blocking of the same products
/// is bit-identical — the generic edge kernel trivially agrees with the
/// register block.
#[allow(clippy::too_many_arguments)]
fn gemm_strip_i8(
    act: &[i8],
    slab: &[i8],
    rows: usize,
    p: usize,
    cols: usize,
    out: &mut [f32],
    out_stride: usize,
    col_offset: usize,
    deq: f32,
) {
    let mut r0 = 0;
    while r0 < rows {
        let mr = MR.min(rows - r0);
        if mr == MR {
            let mut c0 = 0;
            while c0 + NR_I8 <= cols {
                block_mrxnr_i8(act, slab, r0, p, cols, c0, out, out_stride, col_offset, deq);
                c0 += NR_I8;
            }
            if c0 < cols {
                block_generic_i8(
                    act, slab, r0, MR, p, cols, c0, out, out_stride, col_offset, deq,
                );
            }
        } else {
            block_generic_i8(
                act, slab, r0, mr, p, cols, 0, out, out_stride, col_offset, deq,
            );
        }
        r0 += mr;
    }
}

/// One full `MR×NR_I8` int8 register block at rows `[r0, r0+MR)`, columns
/// `[c0, c0+NR_I8)` of the slab.
#[allow(clippy::too_many_arguments)]
#[inline]
fn block_mrxnr_i8(
    act: &[i8],
    slab: &[i8],
    r0: usize,
    p: usize,
    cols: usize,
    c0: usize,
    out: &mut [f32],
    out_stride: usize,
    col_offset: usize,
    deq: f32,
) {
    let mut acc = [[0i32; NR_I8]; MR];
    let a0 = &act[r0 * p..(r0 + 1) * p];
    let a1 = &act[(r0 + 1) * p..(r0 + 2) * p];
    let a2 = &act[(r0 + 2) * p..(r0 + 3) * p];
    let a3 = &act[(r0 + 3) * p..(r0 + 4) * p];
    for pi in 0..p {
        let base = pi * cols + c0;
        // Invariant: the slice is exactly NR_I8 long by construction of
        // `base`, so the array conversion cannot fail.
        #[allow(clippy::expect_used)]
        let w: &[i8; NR_I8] = slab[base..base + NR_I8]
            .try_into()
            .expect("slab block is NR_I8 wide");
        let (x0, x1, x2, x3) = (
            a0[pi] as i32,
            a1[pi] as i32,
            a2[pi] as i32,
            a3[pi] as i32,
        );
        for j in 0..NR_I8 {
            let wv = w[j] as i32;
            acc[0][j] += x0 * wv;
            acc[1][j] += x1 * wv;
            acc[2][j] += x2 * wv;
            acc[3][j] += x3 * wv;
        }
    }
    for (i, row) in acc.iter().enumerate() {
        let ob = (r0 + i) * out_stride + col_offset + c0;
        for (o, &a) in out[ob..ob + NR_I8].iter_mut().zip(row) {
            *o += a as f32 * deq;
        }
    }
}

/// Edge kernel for partial int8 row/column blocks — same exact-i32
/// accumulation + single dequantise per element as the register block.
#[allow(clippy::too_many_arguments)]
fn block_generic_i8(
    act: &[i8],
    slab: &[i8],
    r0: usize,
    mr: usize,
    p: usize,
    cols: usize,
    c0: usize,
    out: &mut [f32],
    out_stride: usize,
    col_offset: usize,
    deq: f32,
) {
    let width = cols - c0;
    for i in 0..mr {
        let arow = &act[(r0 + i) * p..(r0 + i + 1) * p];
        let ob = (r0 + i) * out_stride + col_offset + c0;
        for ci in 0..width {
            let mut acc = 0i32;
            for (pi, &a) in arow.iter().enumerate() {
                acc += a as i32 * slab[pi * cols + c0 + ci] as i32;
            }
            out[ob + ci] += acc as f32 * deq;
        }
    }
}

/// Edge kernel for partial row/column blocks — same ascending-`p`
/// accumulation order per element as the register block.
#[allow(clippy::too_many_arguments)]
fn block_generic(
    act: &[f32],
    slab: &[f32],
    r0: usize,
    mr: usize,
    p: usize,
    cols: usize,
    c0: usize,
    out: &mut [f32],
    out_stride: usize,
    col_offset: usize,
) {
    let width = cols - c0;
    for i in 0..mr {
        let arow = &act[(r0 + i) * p..(r0 + i + 1) * p];
        let ob = (r0 + i) * out_stride + col_offset + c0;
        let orow = &mut out[ob..ob + width];
        for (pi, &a) in arow.iter().enumerate() {
            let wrow = &slab[pi * cols + c0..pi * cols + cols];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += a * wv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::prng::Xoshiro256;

    fn ref_matmul(a: &[f32], b: &[f32], r: usize, p: usize, c: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; r * c];
        for ri in 0..r {
            for pi in 0..p {
                let av = a[ri * p + pi];
                for ci in 0..c {
                    out[ri * c + ci] += av * b[pi * c + ci];
                }
            }
        }
        out
    }

    #[test]
    fn tiled_gemm_matches_reference() {
        forall("pe-array-gemm", 16, |rng| {
            let r = rng.gen_range(3, 20) as usize;
            let p = rng.gen_range(3, 24) as usize;
            let c = rng.gen_range(2, 18) as usize;
            let a = rng.normal_vec(r * p);
            let b = rng.normal_vec(p * c);
            let sigma = DesignPoint::new(
                8,
                rng.gen_range(2, 8),
                rng.gen_range(2, 8),
                rng.gen_range(2, 8),
            );
            let sim = PeArraySim::new(&sigma, true);
            let got = sim.execute(&a, &b, r, p, c);
            let expect = ref_matmul(&a, &b, r, p, c);
            for (g, e) in got.out.iter().zip(&expect) {
                assert!((g - e).abs() < 1e-3 * e.abs().max(1.0), "{g} vs {e}");
            }
            assert_eq!(got.macs, (r * p * c) as u64);
        });
    }

    #[test]
    fn plain_cycles_are_tr_times_ptiles() {
        let sigma = DesignPoint::new(8, 64, 16, 32);
        let sim = PeArraySim::new(&sigma, false);
        assert_eq!(sim.tile_cycles(64, 9, 32), 64 * 9);
        // Selective on but array filled: no change.
        let sim2 = PeArraySim::new(&sigma, true);
        assert_eq!(sim2.tile_cycles(64, 9, 32), 64 * 9);
    }

    #[test]
    fn paper_example_half_filled_array() {
        // §4.3: C=64 on T_C=128 — idle 50%; Eq. 7 with T_R=128, ⌈P/T_P⌉=1:
        // (128−64) + ⌈(128·64 − 64·65)/128⌉ = 64 + 32 = 96 (vs 128 plain).
        let sigma = DesignPoint::new(8, 128, 16, 128);
        let sim = PeArraySim::new(&sigma, true);
        assert_eq!(sim.tile_cycles(128, 1, 64), 96);
    }

    #[test]
    fn closed_form_matches_schedule_walk() {
        forall("eq7-vs-walk", 60, |rng| {
            let t_c = rng.gen_range(8, 128);
            let sigma = DesignPoint::new(8, 256, 16, t_c);
            let sim = PeArraySim::new(&sigma, true);
            let rows = rng.gen_range(t_c, 512); // T_R ≥ T_C keeps Eq.7 regime
            let c = rng.gen_range(1, t_c - 1);
            let closed = sim.tile_cycles(rows, 1, c);
            if closed == rows {
                return; // min(plain) clamp active — Eq. 7 out of regime
            }
            let walked = sim.steal_schedule_walk(rows, c);
            assert_eq!(closed, walked, "T_C={t_c}, rows={rows}, C={c}");
        });
    }

    #[test]
    fn selective_never_slower_never_subwork() {
        forall("eq7-bounds", 80, |rng| {
            let t_c = rng.gen_range(4, 256);
            let sigma = DesignPoint::new(8, 64, 8, t_c);
            let sim = PeArraySim::new(&sigma, true);
            let rows = rng.gen_range(1, 512);
            let c = rng.gen_range(1, t_c);
            let p_tiles = rng.gen_range(1, 16);
            let got = sim.tile_cycles(rows, p_tiles, c);
            let plain = rows * p_tiles;
            let floor = (rows * c).div_ceil(t_c) * p_tiles;
            assert!(got <= plain, "slower than plain");
            assert!(got >= floor, "beats perfect balancing");
        });
    }

    #[test]
    fn up_to_20_pct_gain_regime_exists() {
        // The paper reports up to ~20–33% gains on suboptimally mapped
        // layers; check a representative point lands in that band.
        let sigma = DesignPoint::new(8, 128, 16, 128);
        let sim = PeArraySim::new(&sigma, true);
        let plain = 128u64;
        let sel = sim.tile_cycles(128, 1, 96);
        let gain = plain as f64 / sel as f64;
        assert!(gain > 1.05 && gain < 1.4, "gain {gain}");
    }

    #[test]
    fn strip_entry_point_matches_reference_and_schedules_agree_numerically() {
        forall("pe-strip-gemm", 16, |rng| {
            let rows = rng.gen_range(1, 12) as usize;
            let p = rng.gen_range(2, 20) as usize;
            let c = rng.gen_range(1, 10) as usize;
            let act = rng.normal_vec(rows * p);
            let dense = rng.normal_vec(p * c);
            // T_C > C so the input-selective schedule actually engages.
            let sigma = DesignPoint::new(8, 16, rng.gen_range(2, 6), c as u64 + 4);
            let plain = PeArraySim::new(&sigma, false);
            let selective = PeArraySim::new(&sigma, true);
            let mut out_p = vec![0.0f32; rows * c];
            let mut out_s = vec![0.0f32; rows * c];
            let cyc_p = plain.execute_strip(&act, &dense, rows, p, c, &mut out_p, c, 0);
            let cyc_s = selective.execute_strip(&act, &dense, rows, p, c, &mut out_s, c, 0);
            assert_eq!(out_p, out_s, "schedules must not change numerics");
            assert!(cyc_s <= cyc_p, "work stealing can only help");
            let expect = ref_matmul(&act, &dense, rows, p, c);
            for (g, e) in out_p.iter().zip(&expect) {
                assert!((g - e).abs() < 1e-3 * e.abs().max(1.0), "{g} vs {e}");
            }
        });
    }

    #[test]
    fn strip_accumulates_at_column_offset() {
        // Two slabs written at their offsets reproduce the full GEMM.
        let mut rng = Xoshiro256::seed_from_u64(5);
        let (rows, p, c) = (4usize, 6usize, 5usize);
        let act = rng.normal_vec(rows * p);
        let dense = rng.normal_vec(p * c);
        let sigma = DesignPoint::new(8, 4, 4, 3);
        let sim = PeArraySim::new(&sigma, true);
        let mut out = vec![0.0f32; rows * c];
        for (c0, c1) in [(0usize, 3usize), (3, 5)] {
            let slab: Vec<f32> = (0..p)
                .flat_map(|pi| dense[pi * c + c0..pi * c + c1].to_vec())
                .collect();
            sim.execute_strip(&act, &slab, rows, p, c1 - c0, &mut out, c, c0);
        }
        let expect = ref_matmul(&act, &dense, rows, p, c);
        for (g, e) in out.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-4 * e.abs().max(1.0));
        }
    }

    #[test]
    fn microkernel_is_bit_identical_to_the_scalar_reference() {
        // The register-blocked kernel must reproduce the retired scalar
        // loop bit-for-bit (same ascending-p accumulation order per output
        // element), across row/column tails and offset output windows,
        // starting from nonzero incoming accumulators.
        forall("pe-microkernel-bitexact", 24, |rng| {
            let rows = rng.gen_range(1, 20) as usize; // covers MR tails
            let p = rng.gen_range(1, 40) as usize;
            let cols = rng.gen_range(1, 24) as usize; // covers NR tails
            let act = rng.normal_vec(rows * p);
            let slab = rng.normal_vec(p * cols);
            let pad = rng.gen_range(0, 4) as usize;
            let out_stride = cols + pad;
            let col_offset = rng.gen_range(0, pad as u64 + 1) as usize;
            let sigma = DesignPoint::new(8, 32, rng.gen_range(2, 8), 8);
            let sim = PeArraySim::new(&sigma, true);
            let base = rng.normal_vec(rows * out_stride);
            let mut a = base.clone();
            let mut b = base;
            let cyc_a =
                sim.execute_strip(&act, &slab, rows, p, cols, &mut a, out_stride, col_offset);
            let cyc_b = sim.execute_strip_reference(
                &act, &slab, rows, p, cols, &mut b, out_stride, col_offset,
            );
            assert_eq!(a, b, "microkernel must be bit-identical to the oracle");
            assert_eq!(cyc_a, cyc_b, "cycle accounting must not change");
        });
    }

    #[test]
    fn i8_microkernel_is_bit_identical_to_the_scalar_i8_oracle() {
        // Integer accumulation is exact, so the register-blocked i8 kernel
        // must agree with the one-accumulator-per-element oracle
        // bit-for-bit across row/column tails and offset output windows.
        forall("pe-microkernel-i8-bitexact", 24, |rng| {
            let rows = rng.gen_range(1, 20) as usize; // covers MR tails
            let p = rng.gen_range(1, 40) as usize;
            let cols = rng.gen_range(1, 40) as usize; // covers NR_I8 tails
            let act = rng.normal_vec(rows * p);
            let slab: Vec<i8> = (0..p * cols)
                .map(|_| (rng.gen_range(0, 255) as i32 - 127) as i8)
                .collect();
            let w_scale = 0.01 + rng.gen_range(1, 100) as f32 / 1000.0;
            let pad = rng.gen_range(0, 4) as usize;
            let out_stride = cols + pad;
            let col_offset = rng.gen_range(0, pad as u64 + 1) as usize;
            let sigma = DesignPoint::new(8, 32, rng.gen_range(2, 8), 8);
            let sim = PeArraySim::new(&sigma, true);
            let base = rng.normal_vec(rows * out_stride);
            let mut a = base.clone();
            let mut b = base;
            let cyc_a = sim.execute_strip_i8(
                &act, &slab, w_scale, rows, p, cols, &mut a, out_stride, col_offset,
            );
            let cyc_b = sim.execute_strip_i8_reference(
                &act, &slab, w_scale, rows, p, cols, &mut b, out_stride, col_offset,
            );
            assert_eq!(a, b, "i8 microkernel must be bit-identical to the oracle");
            assert_eq!(cyc_a, cyc_b, "cycle accounting must not change");
        });
    }

    #[test]
    fn i8_strip_tracks_f32_strip_within_quantisation_bound() {
        // Quantise a random f32 slab with its own max-abs scale, run both
        // paths on the same strip, and pin the divergence to the analytic
        // per-element bound p·(max_w·eps_a + max_a·eps_w + eps_a·eps_w).
        forall("pe-strip-i8-vs-f32", 16, |rng| {
            let rows = rng.gen_range(1, 10) as usize;
            let p = rng.gen_range(2, 30) as usize;
            let cols = rng.gen_range(1, 20) as usize;
            let act = rng.normal_vec(rows * p);
            let dense = rng.normal_vec(p * cols);
            let max_w = dense.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let max_a = act.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let w_scheme = crate::util::fixed::I8Scheme::from_max_abs(max_w);
            let slab_q: Vec<i8> = dense.iter().map(|&w| w_scheme.quantise(w)).collect();
            let sigma = DesignPoint::new(8, 16, 4, 8);
            let sim = PeArraySim::new(&sigma, true);
            let mut out_f = vec![0.0f32; rows * cols];
            let mut out_q = vec![0.0f32; rows * cols];
            sim.execute_strip(&act, &dense, rows, p, cols, &mut out_f, cols, 0);
            sim.execute_strip_i8(
                &act, &slab_q, w_scheme.scale, rows, p, cols, &mut out_q, cols, 0,
            );
            let bound = crate::sim::quant::i8_error_bound(p, max_w, max_a, w_scheme.scale);
            for (q, f) in out_q.iter().zip(&out_f) {
                assert!((q - f).abs() <= bound, "{q} vs {f}, bound {bound}");
            }
        });
    }

    #[test]
    fn numeric_gemm_determinism() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let a = rng.normal_vec(6 * 8);
        let b = rng.normal_vec(8 * 4);
        let sigma = DesignPoint::new(8, 4, 4, 4);
        let sim = PeArraySim::new(&sigma, true);
        let o1 = sim.execute(&a, &b, 6, 8, 4);
        let o2 = sim.execute(&a, &b, 6, 8, 4);
        assert_eq!(o1.out, o2.out);
    }
}
