//! Whole-layer simulation: the three-stage pipeline of Fig. 4 —
//! (input DMA ∥ CNN-WGen) → PE array → output DMA — walked tile-by-tile
//! with deterministic cycle counters. Cross-checked against the analytical
//! model (Eqs. 5–8): the simulator executes the same schedules the closed
//! forms describe, so the counts must agree up to DMA burst rounding.

use crate::arch::{DesignPoint, Platform};
use crate::perf::Bound;
use crate::sim::hw_weights::HwOvsfWeights;
use crate::sim::memory::DmaStream;
use crate::sim::pe_array::PeArraySim;
use crate::sim::trace::LayerTrace;
use crate::sim::wgen::WGenSim;
use crate::util::ceil_div;
use crate::workload::layer::Layer;

/// Cycle-level simulator for one layer on one design point.
pub struct LayerSim<'a> {
    /// Design point.
    pub sigma: &'a DesignPoint,
    /// Platform (clock + bandwidth).
    pub platform: &'a Platform,
    /// Bandwidth multiplier.
    pub bw_mult: u32,
    /// Input-selective PEs.
    pub selective: bool,
    /// Wordlength bytes.
    pub wl_bytes: u64,
}

impl<'a> LayerSim<'a> {
    /// New simulator.
    pub fn new(sigma: &'a DesignPoint, platform: &'a Platform, bw_mult: u32) -> Self {
        Self {
            sigma,
            platform,
            bw_mult,
            selective: true,
            wl_bytes: 2,
        }
    }

    /// Walk a layer's tile schedule and return the timing trace.
    /// `wgen_cycles_per_tile` supplies Alg. 1's count for OVSF layers
    /// (`None` ⇒ weights stream off-chip with the activations).
    pub fn run_timing(&self, layer: &Layer, wgen_cycles_per_tile: Option<u64>) -> LayerTrace {
        let g = layer.gemm();
        let bw = self.platform.bandwidth(self.bw_mult);
        let mut dma_in = DmaStream::new(bw.bw_in(), self.platform.clock_hz);
        let mut dma_out = DmaStream::new(bw.bw_out(), self.platform.clock_hz);
        let pe = PeArraySim::new(self.sigma, self.selective);

        let row_tiles = ceil_div(g.r, self.sigma.t_r);
        let col_tiles = ceil_div(g.c, self.sigma.t_c);
        let p_tiles = ceil_div(g.p, self.sigma.t_p);

        let mut total = 0u64;
        let mut ii_steady = 0u64;
        let (mut t_in_s, mut t_wg_s, mut t_eng_s, mut t_out_s) = (0u64, 0u64, 0u64, 0u64);
        for rt in 0..row_tiles {
            // The trailing row strip is narrower when R % T_R ≠ 0: it moves
            // fewer activation/output bytes and occupies the PE array for
            // fewer cycles than a full-height strip.
            let rows = (g.r - rt * self.sigma.t_r).min(self.sigma.t_r);
            for ct in 0..col_tiles {
                // Edge column tiles are narrower than T_C.
                let cols = (g.c - ct * self.sigma.t_c).min(self.sigma.t_c);
                // Stage 1a: input strip rows×P (+ weights when streamed).
                let mut in_bytes = rows * g.p * self.wl_bytes;
                if wgen_cycles_per_tile.is_none() {
                    in_bytes += g.p * cols * self.wl_bytes;
                }
                let t_in = dma_in.transfer(in_bytes);
                // Stage 1b: concurrent weights generation.
                let t_wg = wgen_cycles_per_tile.unwrap_or(0);
                // Stage 2: PE array.
                let t_eng = pe.tile_cycles(rows, p_tiles, cols);
                // Stage 3: output drain.
                let t_out = dma_out.transfer(rows * cols * self.wl_bytes);
                let ii = t_in.max(t_wg).max(t_eng).max(t_out);
                total += ii;
                // Steady-state reporting tracks the dominant (full-height,
                // full-width) tile group — the first tile.
                if rt == 0 && ct == 0 {
                    ii_steady = ii;
                    t_in_s = t_in;
                    t_wg_s = t_wg;
                    t_eng_s = t_eng;
                    t_out_s = t_out;
                }
            }
        }
        LayerTrace {
            name: layer.name.clone(),
            t_mem_in: t_in_s,
            t_wgen: t_wg_s,
            t_eng: t_eng_s,
            t_mem_out: t_out_s,
            ii: ii_steady,
            tiles: row_tiles * col_tiles,
            total_cycles: total,
            bound: Bound::classify(
                t_in_s as f64,
                t_wg_s as f64,
                t_eng_s as f64,
                t_out_s as f64,
            ),
            bytes_in: dma_in.total_bytes,
            bytes_out: dma_out.total_bytes,
        }
    }

    /// Timing for an OVSF layer: runs the TiWGen simulator for the cycle
    /// count, then the tile walk.
    pub fn run_ovsf_timing(&self, layer: &Layer, w: &HwOvsfWeights) -> LayerTrace {
        let wg = WGenSim::new(self.sigma, w).generate();
        self.run_timing(layer, Some(wg.cycles_per_output_tile))
    }

    /// Full numeric execution of a (small) OVSF layer: generate weights
    /// with TiWGen, run the GEMM on the PE array, return `(trace, output)`
    /// for an `R×P` activations matrix.
    pub fn execute_ovsf(
        &self,
        layer: &Layer,
        w: &HwOvsfWeights,
        act: &[f32],
    ) -> (LayerTrace, Vec<f32>) {
        let g = layer.gemm();
        assert_eq!(act.len(), (g.r * g.p) as usize, "activations shape");
        assert_eq!(w.p_dim() as u64, g.p, "hw weights match layer P");
        assert_eq!(w.n_out as u64, g.c, "hw weights match layer C");
        let wg = WGenSim::new(self.sigma, w).generate();
        let pe = PeArraySim::new(self.sigma, self.selective);
        let r = pe.execute(act, &wg.weights, g.r as usize, g.p as usize, g.c as usize);
        let trace = self.run_timing(layer, Some(wg.cycles_per_output_tile));
        (trace, r.out)
    }

    /// Full numeric execution of an OVSF layer **without ever
    /// materialising the dense weights**: one `P×T_C` slab is generated
    /// per column tile ([`HwOvsfWeights::slab_into`]) and streamed through
    /// the PE array row-strip by row-strip
    /// ([`PeArraySim::execute_strip`]) — the software mirror of the
    /// paper's on-chip dataflow. Peak live dense weights are one slab.
    /// Output matches [`execute_ovsf`](Self::execute_ovsf) up to FWHT
    /// rounding. This is the *uncached* reference form of the loop the
    /// engine's `SimBackend` pipelined datapath drives (which adds the
    /// slab cache, prefetch overlap and activation refitting); the test
    /// below keeps the two dataflows honest against the
    /// full-materialisation path.
    pub fn execute_ovsf_streamed(
        &self,
        layer: &Layer,
        w: &HwOvsfWeights,
        act: &[f32],
    ) -> (LayerTrace, Vec<f32>) {
        let g = layer.gemm();
        assert_eq!(act.len(), (g.r * g.p) as usize, "activations shape");
        assert_eq!(w.p_dim() as u64, g.p, "hw weights match layer P");
        assert_eq!(w.n_out as u64, g.c, "hw weights match layer C");
        let (r, p, c) = (g.r as usize, g.p as usize, g.c as usize);
        let (t_r, t_c) = (self.sigma.t_r as usize, self.sigma.t_c as usize);
        let pe = PeArraySim::new(self.sigma, self.selective);
        let mut out = vec![0.0f32; r * c];
        let mut scratch = Vec::new();
        let mut slab = Vec::new();
        for c0 in (0..c).step_by(t_c) {
            let c1 = (c0 + t_c).min(c);
            // Invariant: c0..c1 is clamped to C three lines up.
            #[allow(clippy::expect_used)]
            w.slab_into(c0, c1, &mut scratch, &mut slab)
                .expect("column range derives from C");
            for r0 in (0..r).step_by(t_r) {
                let r1 = (r0 + t_r).min(r);
                pe.execute_strip(
                    &act[r0 * p..r1 * p],
                    &slab,
                    r1 - r0,
                    p,
                    c1 - c0,
                    &mut out[r0 * c..r1 * c],
                    c,
                    c0,
                );
            }
        }
        // Alg. 1's per-tile generation cycles: `w.n_basis` is exactly the
        // layer's ⌊ρ·K'²⌉ basis count.
        let wg_cycles = w.n_basis as u64
            * self.sigma.subtiles_per_tile()
            * ceil_div(g.p, self.sigma.t_p);
        let trace = self.run_timing(layer, Some(wg_cycles));
        (trace, out)
    }
}

/// Simulate a whole network (timing only) under on-the-fly execution.
pub fn simulate_network_timing(
    sigma: &DesignPoint,
    platform: &Platform,
    bw_mult: u32,
    selective: bool,
    net: &crate::workload::Network,
    profile: &crate::workload::RatioProfile,
) -> Vec<LayerTrace> {
    let mut sim = LayerSim::new(sigma, platform, bw_mult);
    sim.selective = selective;
    net.layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            if l.ovsf && sigma.has_wgen() {
                // Cycle count per Alg. 1 without materialising weights:
                // n_basis · subtiles · p_tiles (validated == WGenSim walk).
                let cycles = l.basis_per_chunk(profile.rho(i))
                    * sigma.subtiles_per_tile()
                    * ceil_div(l.gemm().p, sigma.t_p);
                sim.run_timing(l, Some(cycles))
            } else {
                sim.run_timing(l, None)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::model::PerfModel;
    use crate::util::prng::Xoshiro256;
    use crate::workload::{resnet, RatioProfile};

    #[test]
    fn simulator_matches_analytical_model() {
        // For every ResNet18 layer the walked cycle counts must match the
        // closed forms (Eqs. 5–8) up to DMA burst ceilings (≤1 cycle/stage).
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf50(&net);
        let platform = Platform::z7045();
        let sigma = DesignPoint::new(64, 64, 16, 48);
        let model = PerfModel::new(platform.clone(), 4);
        let traces = simulate_network_timing(&sigma, &platform, 4, true, &net, &profile);
        let perf = model.network_perf(&sigma, &net, &profile);
        for (t, p) in traces.iter().zip(&perf.layers) {
            let src = crate::perf::model::WeightsSource::OnTheFly {
                rho: 1.0, // unused: compare stage-by-stage below
            };
            let _ = src;
            assert!(
                (t.t_wgen as f64 - p.t_wgen).abs() <= 1.0,
                "{}: wgen {} vs {}",
                t.name,
                t.t_wgen,
                p.t_wgen
            );
            assert!(
                (t.t_eng as f64 - p.t_eng).abs() <= 1.0,
                "{}: eng {} vs {}",
                t.name,
                t.t_eng,
                p.t_eng
            );
            assert!(
                (t.t_mem_in as f64 - p.t_mem_in).abs() <= 1.0,
                "{}: mem_in {} vs {}",
                t.name,
                t.t_mem_in,
                p.t_mem_in
            );
            assert!(
                (t.t_mem_out as f64 - p.t_mem_out).abs() <= 1.0,
                "{}: mem_out {} vs {}",
                t.name,
                t.t_mem_out,
                p.t_mem_out
            );
            let rel = (t.total_cycles as f64 - p.total_cycles).abs() / p.total_cycles;
            assert!(rel < 0.01, "{}: total {} vs {}", t.name, t.total_cycles, p.total_cycles);
        }
    }

    #[test]
    fn numeric_execution_matches_dense_reference() {
        // End-to-end: TiWGen-generated weights × PE-array GEMM equals the
        // dense-oracle GEMM.
        let mut rng = Xoshiro256::seed_from_u64(21);
        let layer = Layer::conv("small", 6, 6, 4, 8, 3, 1, 1, true);
        let g = layer.gemm();
        let w = HwOvsfWeights::random(&mut rng, 8, 4, 3, 0.5).unwrap();
        let act = rng.normal_vec((g.r * g.p) as usize);
        let sigma = DesignPoint::new(16, 8, 8, 8);
        let platform = Platform::z7045();
        let sim = LayerSim::new(&sigma, &platform, 4);
        let (trace, out) = sim.execute_ovsf(&layer, &w, &act);
        assert!(trace.total_cycles > 0);
        // Reference: dense oracle weights.
        let dense = w.dense_gemm().unwrap();
        let mut expect = vec![0.0f32; (g.r * g.c) as usize];
        for r in 0..g.r as usize {
            for p in 0..g.p as usize {
                let a = act[r * g.p as usize + p];
                for c in 0..g.c as usize {
                    expect[r * g.c as usize + c] += a * dense[p * g.c as usize + c];
                }
            }
        }
        for (o, e) in out.iter().zip(&expect) {
            assert!((o - e).abs() < 1e-3 * e.abs().max(1.0), "{o} vs {e}");
        }
    }

    #[test]
    fn streamed_execution_matches_full_materialisation() {
        // Slab-streamed numerics and cycle counts must agree with the
        // full-weights TiWGen path (up to FWHT rounding on the weights).
        let mut rng = Xoshiro256::seed_from_u64(33);
        let layer = Layer::conv("small", 6, 6, 4, 10, 3, 1, 1, true);
        let g = layer.gemm();
        let w = HwOvsfWeights::random(&mut rng, 10, 4, 3, 0.5).unwrap();
        let act = rng.normal_vec((g.r * g.p) as usize);
        let sigma = DesignPoint::new(16, 8, 8, 4); // T_C=4 ⇒ 3 slabs, edge tile
        let platform = Platform::z7045();
        let sim = LayerSim::new(&sigma, &platform, 4);
        let (trace_full, out_full) = sim.execute_ovsf(&layer, &w, &act);
        let (trace_streamed, out_streamed) = sim.execute_ovsf_streamed(&layer, &w, &act);
        assert_eq!(trace_full.total_cycles, trace_streamed.total_cycles);
        assert_eq!(out_full.len(), out_streamed.len());
        for (a, b) in out_full.iter().zip(&out_streamed) {
            assert!((a - b).abs() < 1e-3 * a.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn overlapped_accounting_charges_max_of_wgen_and_engine() {
        // Memory-wall regime: a generation-dominated layer (small M ⇒ many
        // subtile passes per weight tile) must be charged
        // `max(t_wgen, t_eng)` per tile — the paper's pipelined timing
        // model, where CNN-WGen runs concurrently with the PE array — and
        // never their sum.
        let platform = Platform::z7045();
        let sigma = DesignPoint::new(4, 8, 8, 8); // M = 4 ⇒ 16 subtiles/tile
        let layer = Layer::conv("wbound", 8, 8, 16, 16, 3, 1, 1, true);
        let g = layer.gemm();
        let wgen = layer.basis_per_chunk(1.0)
            * sigma.subtiles_per_tile()
            * ceil_div(g.p, sigma.t_p);
        let pe = PeArraySim::new(&sigma, true);
        let t_eng =
            pe.tile_cycles(sigma.t_r.min(g.r), ceil_div(g.p, sigma.t_p), sigma.t_c.min(g.c));
        assert!(wgen > t_eng, "test layer must be wgen-dominated");
        let sim = LayerSim::new(&sigma, &platform, 4);
        let trace = sim.run_timing(&layer, Some(wgen));
        assert_eq!(trace.t_wgen, wgen);
        assert_eq!(trace.bound, Bound::WGen);
        assert_eq!(
            trace.ii,
            trace
                .t_mem_in
                .max(trace.t_wgen)
                .max(trace.t_eng)
                .max(trace.t_mem_out),
            "II is the stage max (Eq. 8), not a stage sum"
        );
        assert_eq!(trace.ii, wgen, "t_wgen dominates every stage here");
        // Per-tile charge is exactly the max: the engine time hides fully
        // behind generation, so the layer total pins to wgen·tiles and an
        // additive model would overcharge by t_eng·tiles.
        assert_eq!(trace.total_cycles, wgen * trace.tiles);
        assert!(
            trace.total_cycles < (wgen + t_eng) * trace.tiles,
            "generation and compute must overlap, not add"
        );
    }

    #[test]
    fn traffic_accounting() {
        let platform = Platform::z7045();
        let sigma = DesignPoint::new(32, 32, 8, 16);
        let layer = Layer::conv("t", 14, 14, 32, 32, 3, 1, 1, true);
        let sim = LayerSim::new(&sigma, &platform, 4);
        let trace = sim.run_timing(&layer, Some(100));
        let g = layer.gemm();
        // Edge tiles are narrowed in both dimensions, so the per-tile sums
        // telescope to exact totals: every activation row streams once per
        // column tile, every output element drains exactly once.
        let col_tiles = ceil_div(g.c, sigma.t_c);
        assert_eq!(trace.bytes_in, g.r * g.p * 2 * col_tiles, "input strips");
        assert_eq!(trace.bytes_out, g.r * g.c * 2, "each output element once");
    }

    #[test]
    fn trailing_row_tile_not_overcounted() {
        // Regression: R = 14·14 = 196 on T_R = 32 leaves a 4-row edge strip
        // (196 = 6·32 + 4). The simulator used to charge it full T_R DMA
        // bytes and PE cycles; it must agree with the analytical model.
        let platform = Platform::z7045();
        let sigma = DesignPoint::new(32, 32, 8, 16);
        let layer = Layer::conv("t", 14, 14, 32, 32, 3, 1, 1, true);
        let g = layer.gemm();
        assert_ne!(g.r % sigma.t_r, 0, "test layer must have a row remainder");

        let rho = 0.5;
        let wgen_cycles = layer.basis_per_chunk(rho)
            * sigma.subtiles_per_tile()
            * ceil_div(g.p, sigma.t_p);
        let sim = LayerSim::new(&sigma, &platform, 4);
        let trace = sim.run_timing(&layer, Some(wgen_cycles));

        let model = PerfModel::new(platform, 4);
        let perf = model.layer_perf(
            &sigma,
            &layer,
            crate::perf::model::WeightsSource::OnTheFly { rho },
        );
        let rel = (trace.total_cycles as f64 - perf.total_cycles).abs() / perf.total_cycles;
        assert!(
            rel < 0.01,
            "sim {} vs model {} ({rel:.4}) on a non-divisible layer",
            trace.total_cycles,
            perf.total_cycles
        );
        // The exact-traffic invariant only holds with narrowed edge strips.
        assert_eq!(trace.bytes_in, g.r * g.p * 2 * ceil_div(g.c, sigma.t_c));
        assert_eq!(trace.bytes_out, g.r * g.c * 2);
    }

    #[test]
    fn offchip_weights_increase_input_traffic() {
        let platform = Platform::z7045();
        let sigma = DesignPoint::new(32, 32, 8, 16);
        let layer = Layer::conv("t", 14, 14, 32, 32, 3, 1, 1, true);
        let sim = LayerSim::new(&sigma, &platform, 4);
        let otf = sim.run_timing(&layer, Some(1));
        let off = sim.run_timing(&layer, None);
        assert!(off.bytes_in > otf.bytes_in);
        assert!(off.t_mem_in >= otf.t_mem_in);
    }
}
