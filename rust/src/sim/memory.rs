//! Bandwidth-modelled DMA streams for the off-chip memory interface.
//!
//! The paper controls bandwidth with memory-port count and word packing
//! (§7.1); the simulator models each direction as a stream delivering
//! `bytes_per_cycle`, with cycle costs rounded up to whole cycles per
//! burst. Totals are tracked for the traffic accounting in the reports.

/// One direction of the off-chip interface.
#[derive(Clone, Debug)]
pub struct DmaStream {
    /// Deliverable bytes per fabric cycle.
    pub bytes_per_cycle: f64,
    /// Total bytes moved.
    pub total_bytes: u64,
    /// Total cycles spent (sum of per-burst ceilings).
    pub total_cycles: u64,
}

impl DmaStream {
    /// Stream at a bandwidth (bytes/s) and fabric clock (Hz).
    pub fn new(bandwidth_bytes_per_s: f64, clock_hz: f64) -> Self {
        assert!(bandwidth_bytes_per_s > 0.0 && clock_hz > 0.0);
        Self {
            bytes_per_cycle: bandwidth_bytes_per_s / clock_hz,
            total_bytes: 0,
            total_cycles: 0,
        }
    }

    /// Cycles to move a burst of `bytes` (no state change).
    pub fn burst_cycles(&self, bytes: u64) -> u64 {
        (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }

    /// Issue a burst; returns its cycle cost and updates totals.
    pub fn transfer(&mut self, bytes: u64) -> u64 {
        let cycles = self.burst_cycles(bytes);
        self.total_bytes += bytes;
        self.total_cycles += cycles;
        cycles
    }

    /// Achieved bytes/cycle so far (≤ `bytes_per_cycle` due to ceilings).
    pub fn achieved_bytes_per_cycle(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            self.total_bytes as f64 / self.total_cycles as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_cost_rounds_up() {
        let s = DmaStream::new(3e9, 1.5e8); // 20 bytes/cycle
        assert_eq!(s.burst_cycles(20), 1);
        assert_eq!(s.burst_cycles(21), 2);
        assert_eq!(s.burst_cycles(0), 0);
    }

    #[test]
    fn totals_accumulate() {
        let mut s = DmaStream::new(2e9, 2e8); // 10 bytes/cycle
        s.transfer(100);
        s.transfer(5);
        assert_eq!(s.total_bytes, 105);
        assert_eq!(s.total_cycles, 11);
        assert!(s.achieved_bytes_per_cycle() <= 10.0);
    }

    #[test]
    fn bandwidth_scaling_halves_cycles() {
        let s1 = DmaStream::new(1.1e9, 1.5e8);
        let s2 = DmaStream::new(2.2e9, 1.5e8);
        let big = 1_000_000;
        let ratio = s1.burst_cycles(big) as f64 / s2.burst_cycles(big) as f64;
        assert!((ratio - 2.0).abs() < 0.01);
    }
}
