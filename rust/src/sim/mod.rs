//! Cycle-level simulator of the unzipFPGA architecture (paper §4).
//!
//! The simulator executes the actual schedules — TiWGen's loop nest
//! (Alg. 1), the OVSF FIFO + basis-vector aligner rate matching, the banked
//! Alpha buffer, the output-stationary PE array with input-selective
//! work-stealing, and the bandwidth-modelled DMA streams — with
//! deterministic cycle counters *and* real numerics. Its cycle counts are
//! cross-checked against the paper's closed-form model (Eqs. 5–8) and its
//! generated weights against the software OVSF oracle.
//!
//! ### Hardware weight form
//!
//! §2.3 formulates filters over length-`L = N_in·K'²` codes while the
//! hardware stores `N_in·N_out·⌈ρK'²⌉` α values and a `K'²`-deep FIFO.
//! The two are equivalent: Sylvester structure gives
//! `H_{N_in·K'²} = H_{N_in} ⊗ H_{K'²}`, so any linear combination over
//! length-L codes regroups into per-(channel, filter) combinations over the
//! `K'²`-length chunk basis. The simulator (and the L1 Pallas kernel) use
//! this per-chunk form directly.

pub mod alpha_buffer;
pub mod engine;
pub mod hw_weights;
pub mod im2col;
pub mod memory;
pub mod ovsf_gen;
pub mod ovsf_storage;
pub mod pe_array;
pub mod quant;
pub mod trace;
pub mod wgen;

pub use engine::LayerSim;
pub use hw_weights::HwOvsfWeights;
pub use ovsf_gen::OvsfGenerator;
pub use trace::LayerTrace;
