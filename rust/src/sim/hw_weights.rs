//! Hardware-form OVSF weights: per-(filter, channel) coefficients over the
//! `K'²`-length OVSF chunk basis (see `sim` module docs for why this is
//! equivalent to the paper's length-`L` formulation).

use crate::error::{Error, Result};
use crate::ovsf::basis::SelectedBasis;
use crate::ovsf::codes::OvsfBasis;
use crate::ovsf::regress::reconstruct_into;
use crate::util::{is_pow2, n_basis, next_pow2};
use crate::util::prng::Xoshiro256;

/// The compressed representation CNN-WGen consumes: for every filter `o`
/// and channel `c`, `n_basis` α coefficients over the first `n_basis` codes
/// of the `K'²` OVSF basis (Sequential selection — the hardware layout).
#[derive(Clone, Debug)]
pub struct HwOvsfWeights {
    /// Output channels (filters).
    pub n_out: usize,
    /// Input channels.
    pub n_in: usize,
    /// Power-of-two kernel frame (4 for K=3).
    pub k_ovsf: usize,
    /// Target kernel size.
    pub k: usize,
    /// Basis vectors per chunk (`⌈ρ·K'²⌉`).
    pub n_basis: usize,
    /// α values, layout `[n_out][n_in][n_basis]`.
    pub alphas: Vec<f32>,
}

impl HwOvsfWeights {
    /// Chunk length `K'²` (the OVSF code length per chunk).
    pub fn chunk_len(&self) -> usize {
        self.k_ovsf * self.k_ovsf
    }

    /// Engine positions per chunk (`K²` — the GEMM view's share of `P`).
    pub fn engine_chunk(&self) -> usize {
        self.k * self.k
    }

    /// Engine `P` dimension (`N_in·K²`).
    pub fn p_dim(&self) -> usize {
        self.n_in * self.engine_chunk()
    }

    /// Map an engine kernel position (`0..K²`) to its OVSF frame position
    /// (`0..K'²`) — the top-left crop the hardware extracts for non-pow2
    /// kernels (paper §6.1; Table 3 selects Crop for ImageNet).
    #[inline]
    pub fn frame_pos(&self, kpos: usize) -> usize {
        (kpos / self.k) * self.k_ovsf + kpos % self.k
    }

    /// Random instance for simulation/tests.
    pub fn random(
        rng: &mut Xoshiro256,
        n_out: usize,
        n_in: usize,
        k: usize,
        rho: f64,
    ) -> Result<Self> {
        let k_ovsf = if is_pow2(k) { k } else { next_pow2(k) };
        let chunk = k_ovsf * k_ovsf;
        let nb = n_basis(rho, chunk);
        let alphas = rng.normal_vec(n_out * n_in * nb);
        Ok(Self {
            n_out,
            n_in,
            k_ovsf,
            k,
            n_basis: nb,
            alphas,
        })
    }

    /// Derive hardware-form coefficients from dense weights by projecting
    /// each `(o, c)` chunk on the `K'²` basis and keeping the first
    /// `⌈ρ·K'²⌉` codes (the hardware's Sequential layout).
    pub fn from_dense(weights: &[f32], n_out: usize, n_in: usize, k: usize, rho: f64) -> Result<Self> {
        if weights.len() != n_out * n_in * k * k {
            return Err(Error::ShapeMismatch(format!(
                "weights len {} != {n_out}·{n_in}·{k}²",
                weights.len()
            )));
        }
        let k_ovsf = if is_pow2(k) { k } else { next_pow2(k) };
        let chunk = k_ovsf * k_ovsf;
        let nb = n_basis(rho, chunk);
        OvsfBasis::new(chunk)?; // validate the chunk geometry
        let mut alphas = Vec::with_capacity(n_out * n_in * nb);
        // One FWHT over the zero-padded K'×K' frame yields all chunk α's at
        // once (O(chunk log chunk) per (o, c) instead of nb dense dots);
        // the hardware's Sequential layout keeps the first nb.
        let mut frame = vec![0.0f64; chunk];
        let inv = 1.0f64 / chunk as f64;
        for o in 0..n_out {
            for c in 0..n_in {
                frame.iter_mut().for_each(|x| *x = 0.0);
                for kh in 0..k {
                    for kw in 0..k {
                        frame[kh * k_ovsf + kw] =
                            weights[((o * n_in + c) * k + kh) * k + kw] as f64;
                    }
                }
                crate::ovsf::regress::fwht(&mut frame);
                alphas.extend(frame[..nb].iter().map(|&a| (a * inv) as f32));
            }
        }
        Ok(Self {
            n_out,
            n_in,
            k_ovsf,
            k,
            n_basis: nb,
            alphas,
        })
    }

    /// α for `(filter o, channel c, basis j)`.
    #[inline]
    pub fn alpha(&self, o: usize, c: usize, j: usize) -> f32 {
        self.alphas[(o * self.n_in + c) * self.n_basis + j]
    }

    /// Software oracle: reconstruct the dense weights in the engine's
    /// `P × C` GEMM layout (`P = N_in·K²`, `C = N_out`, row
    /// `p = c·K² + kpos`, column = filter). Non-pow2 kernels take the
    /// top-left crop of the `K'×K'` OVSF frame.
    pub fn dense_gemm(&self) -> Result<Vec<f32>> {
        let chunk = self.chunk_len();
        let ek = self.engine_chunk();
        OvsfBasis::new(chunk)?; // validate the chunk geometry
        let p_dim = self.p_dim();
        let mut out = vec![0.0f32; p_dim * self.n_out];
        // Matrix-free signs, hoisted as packed u64 words per basis vector
        // over the cropped engine positions (one word for every paper
        // kernel: K ≤ 8 ⇒ ek ≤ 64; larger kernels take more words).
        let sign_words = ek.div_ceil(64).max(1);
        let mut packed = vec![0u64; self.n_basis * sign_words];
        for j in 0..self.n_basis {
            for kpos in 0..ek {
                if OvsfBasis::sign(j, self.frame_pos(kpos)) > 0 {
                    packed[j * sign_words + (kpos >> 6)] |= 1u64 << (kpos & 63);
                }
            }
        }
        for o in 0..self.n_out {
            for c in 0..self.n_in {
                let base = (o * self.n_in + c) * self.n_basis;
                let alphas = &self.alphas[base..base + self.n_basis];
                for kpos in 0..ek {
                    let (word, bit) = (kpos >> 6, kpos & 63);
                    let mut acc = 0.0f32;
                    for (j, &a) in alphas.iter().enumerate() {
                        let row = packed[j * sign_words + word];
                        acc += if row >> bit & 1 == 1 { a } else { -a };
                    }
                    out[(c * ek + kpos) * self.n_out + o] = acc;
                }
            }
        }
        Ok(out)
    }

    /// Number of α parameters.
    pub fn n_alphas(&self) -> usize {
        self.alphas.len()
    }

    /// Tile-granular generation: reconstruct weight columns `[c0, c1)` of
    /// the engine `P×C` GEMM matrix — one `P×(c1−c0)` slab, row-major
    /// `out[p·cols + (o−c0)]` — into caller scratch via the FWHT
    /// [`reconstruct_into`] path (one inverse transform per `(o, c)`
    /// chunk). This is the unit the engine's
    /// [`SlabCache`](crate::engine::wcache::SlabCache) stores: peak
    /// resident generated weights stay O(slab), never O(layer).
    pub fn slab_into(
        &self,
        c0: usize,
        c1: usize,
        scratch: &mut Vec<f64>,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        if c0 >= c1 || c1 > self.n_out {
            return Err(Error::ShapeMismatch(format!(
                "slab columns [{c0}, {c1}) out of range for C = {}",
                self.n_out
            )));
        }
        let chunk = self.chunk_len();
        let basis = OvsfBasis::new(chunk)?;
        let ek = self.engine_chunk();
        let cols = c1 - c0;
        out.clear();
        out.resize(self.p_dim() * cols, 0.0);
        // The hardware's Sequential layout keeps codes 0..n_basis; reuse
        // one SelectedBasis, swapping each chunk's α's in.
        let mut sel = SelectedBasis {
            indices: (0..self.n_basis).collect(),
            alphas: vec![0.0f32; self.n_basis],
        };
        let mut frame: Vec<f32> = Vec::with_capacity(chunk);
        for (oi, o) in (c0..c1).enumerate() {
            for c in 0..self.n_in {
                let base = (o * self.n_in + c) * self.n_basis;
                sel.alphas.copy_from_slice(&self.alphas[base..base + self.n_basis]);
                reconstruct_into(&basis, &sel, scratch, &mut frame);
                for kpos in 0..ek {
                    out[(c * ek + kpos) * cols + oi] = frame[self.frame_pos(kpos)];
                }
            }
        }
        Ok(())
    }

    /// Per-layer symmetric int8 weight scale, derived from the α sets: a
    /// reconstructed weight is `Σ_j α_j·sign_j` with signs ±1, so
    /// `|w| ≤ max_{(o,c)} Σ_j |α_{o,c,j}|`. Dividing that bound by 127
    /// yields a scale under which quantisation **never clips** — no dense
    /// reconstruction needed to derive it, which is what lets the
    /// `Compiler` pick the scale at compile time from the fitted α's
    /// alone. Degenerate (all-zero) layers fall back to scale 1.0.
    pub fn i8_scale(&self) -> f32 {
        let mut max_sum = 0.0f32;
        for chunk in self.alphas.chunks(self.n_basis.max(1)) {
            let sum: f32 = chunk.iter().map(|a| a.abs()).sum();
            max_sum = max_sum.max(sum);
        }
        crate::util::fixed::I8Scheme::from_max_abs(max_sum).scale
    }

    /// Int8 twin of [`slab_into`](Self::slab_into): reconstruct columns
    /// `[c0, c1)` through the same FWHT path (the transform stays f32-exact)
    /// and quantise **once at slab emission** with the caller's per-layer
    /// `scale` — the software analogue of the paper's WL-bit weights buffer
    /// (§5.2), where rounding happens when the generated word is written,
    /// not inside the generator. Layout matches `slab_into`.
    pub fn slab_into_i8(
        &self,
        c0: usize,
        c1: usize,
        scale: f32,
        scratch: &mut Vec<f64>,
        out: &mut Vec<i8>,
    ) -> Result<()> {
        if c0 >= c1 || c1 > self.n_out {
            return Err(Error::ShapeMismatch(format!(
                "slab columns [{c0}, {c1}) out of range for C = {}",
                self.n_out
            )));
        }
        if !(scale > 0.0 && scale.is_finite()) {
            return Err(Error::ShapeMismatch(format!(
                "i8 slab scale must be positive and finite, got {scale}"
            )));
        }
        let chunk = self.chunk_len();
        let basis = OvsfBasis::new(chunk)?;
        let ek = self.engine_chunk();
        let cols = c1 - c0;
        let scheme = crate::util::fixed::I8Scheme { scale };
        out.clear();
        out.resize(self.p_dim() * cols, 0);
        let mut sel = SelectedBasis {
            indices: (0..self.n_basis).collect(),
            alphas: vec![0.0f32; self.n_basis],
        };
        let mut frame: Vec<f32> = Vec::with_capacity(chunk);
        for (oi, o) in (c0..c1).enumerate() {
            for c in 0..self.n_in {
                let base = (o * self.n_in + c) * self.n_basis;
                sel.alphas.copy_from_slice(&self.alphas[base..base + self.n_basis]);
                reconstruct_into(&basis, &sel, scratch, &mut frame);
                for kpos in 0..ek {
                    out[(c * ek + kpos) * cols + oi] =
                        scheme.quantise(frame[self.frame_pos(kpos)]);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn from_dense_full_rho_is_exact() {
        // ρ=1 must reproduce the original weights exactly — for pow2
        // kernels directly, for K=3 via the zero-padded frame + crop.
        forall("hw-weights-exact", 16, |rng| {
            let n_out = 3usize;
            let n_in = 4usize;
            let k = *rng.choose(&[2usize, 3, 4]);
            let w = rng.normal_vec(n_out * n_in * k * k);
            let hw = HwOvsfWeights::from_dense(&w, n_out, n_in, k, 1.0).unwrap();
            let dense = hw.dense_gemm().unwrap();
            let ek = k * k;
            for o in 0..n_out {
                for c in 0..n_in {
                    for kpos in 0..ek {
                        let orig = w[((o * n_in + c) * k + kpos / k) * k + kpos % k];
                        let got = dense[(c * ek + kpos) * n_out + o];
                        assert!((orig - got).abs() < 1e-4, "k={k} o={o} c={c} kpos={kpos}");
                    }
                }
            }
        });
    }

    #[test]
    fn alpha_counts() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let hw = HwOvsfWeights::random(&mut rng, 8, 4, 3, 0.5).unwrap();
        assert_eq!(hw.k_ovsf, 4);
        assert_eq!(hw.n_basis, 8); // ⌊0.5·16⌉
        assert_eq!(hw.n_alphas(), 8 * 4 * 8);
    }

    #[test]
    fn slab_into_matches_dense_gemm_columns() {
        // The tile-granular slabs, stitched together at any column-tile
        // width, must reproduce the dense oracle exactly.
        forall("hw-weights-slabs", 16, |rng| {
            let n_out = rng.gen_range(2, 10) as usize;
            let n_in = 1usize << rng.gen_range(0, 3);
            let k = *rng.choose(&[1usize, 2, 3, 4]);
            let rho = *rng.choose(&[0.25, 0.5, 1.0]);
            let hw = HwOvsfWeights::random(rng, n_out, n_in, k, rho).unwrap();
            let dense = hw.dense_gemm().unwrap();
            let t_c = rng.gen_range(1, n_out as u64 + 2) as usize;
            let mut scratch = Vec::new();
            let mut slab = Vec::new();
            let p_dim = hw.p_dim();
            for c0 in (0..n_out).step_by(t_c) {
                let c1 = (c0 + t_c).min(n_out);
                hw.slab_into(c0, c1, &mut scratch, &mut slab).unwrap();
                assert_eq!(slab.len(), p_dim * (c1 - c0));
                for p in 0..p_dim {
                    for (oi, o) in (c0..c1).enumerate() {
                        let got = slab[p * (c1 - c0) + oi];
                        let expect = dense[p * n_out + o];
                        assert!(
                            (got - expect).abs() < 1e-4,
                            "p={p} o={o}: {got} vs {expect}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn slab_into_rejects_bad_ranges() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let hw = HwOvsfWeights::random(&mut rng, 4, 2, 3, 0.5).unwrap();
        let (mut s, mut o) = (Vec::new(), Vec::new());
        assert!(hw.slab_into(0, 5, &mut s, &mut o).is_err());
        assert!(hw.slab_into(2, 2, &mut s, &mut o).is_err());
        assert!(hw.slab_into(3, 4, &mut s, &mut o).is_ok());
    }

    #[test]
    fn i8_slab_matches_quantised_f32_slab_and_never_clips() {
        forall("hw-weights-i8-slabs", 16, |rng| {
            let n_out = rng.gen_range(2, 10) as usize;
            let n_in = 1usize << rng.gen_range(0, 3);
            let k = *rng.choose(&[2usize, 3, 4]);
            let rho = *rng.choose(&[0.25, 0.5, 1.0]);
            let hw = HwOvsfWeights::random(rng, n_out, n_in, k, rho).unwrap();
            let scale = hw.i8_scale();
            assert!(scale > 0.0);
            let scheme = crate::util::fixed::I8Scheme { scale };
            let t_c = rng.gen_range(1, n_out as u64 + 2) as usize;
            let mut scratch = Vec::new();
            let (mut f_slab, mut q_slab) = (Vec::new(), Vec::new());
            for c0 in (0..n_out).step_by(t_c) {
                let c1 = (c0 + t_c).min(n_out);
                hw.slab_into(c0, c1, &mut scratch, &mut f_slab).unwrap();
                hw.slab_into_i8(c0, c1, scale, &mut scratch, &mut q_slab)
                    .unwrap();
                assert_eq!(q_slab.len(), f_slab.len());
                for (q, f) in q_slab.iter().zip(&f_slab) {
                    // Element-wise: the i8 code is exactly the scheme's
                    // quantisation of the f32 word (rounding at emission,
                    // nowhere else), and the α-derived scale never clips.
                    assert_eq!(*q, scheme.quantise(*f));
                    assert!(
                        (scheme.dequantise(*q) - f).abs() <= scheme.max_error() + 1e-6,
                        "q={q} f={f} scale={scale}"
                    );
                }
            }
        });
    }

    #[test]
    fn i8_slab_rejects_bad_scale() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let hw = HwOvsfWeights::random(&mut rng, 4, 2, 3, 0.5).unwrap();
        let (mut s, mut o) = (Vec::new(), Vec::new());
        assert!(hw.slab_into_i8(0, 2, 0.0, &mut s, &mut o).is_err());
        assert!(hw.slab_into_i8(0, 2, f32::NAN, &mut s, &mut o).is_err());
        assert!(hw.slab_into_i8(0, 2, hw.i8_scale(), &mut s, &mut o).is_ok());
    }

    #[test]
    fn gemm_layout_dimensions() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let hw = HwOvsfWeights::random(&mut rng, 5, 2, 2, 1.0).unwrap();
        let dense = hw.dense_gemm().unwrap();
        assert_eq!(dense.len(), 2 * 4 * 5); // P=8, C=5
    }

    use crate::util::prng::Xoshiro256;
}
