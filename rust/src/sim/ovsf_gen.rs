//! The OVSF generator — FIFO + basis-vector aligner (paper §4.2.2, Fig. 5).
//!
//! The FIFO holds the layer's `n_basis` chunk codes (`K'²` bits each). Each
//! cycle the generator emits an `M`-bit slice of the *periodic* extension
//! of the current basis vector, then writes the rotated vector back so
//! that, when the same code is read again for the next subtile, it is
//! already aligned to TiWGen's tiling — no selection multiplexers, no
//! replicated storage:
//!
//! * `M ≤ K'²`: emit the `M` LSBs, rotate left by `M`.
//! * `M > K'²`: self-concatenate `⌊M/K'²⌋` times plus `M mod K'²` bits,
//!   rotate left by `M mod K'²`.
//!
//! Both cases advance the code's phase by `M mod K'²` — the invariant the
//! tests check.

use crate::ovsf::codes::OvsfBasis;

/// One stored basis vector with its rotation state (bit `t` = element `t`;
/// 1 ⇒ +1, 0 ⇒ −1). `K'² ≤ 64` for every kernel the paper evaluates
/// (K ≤ 8), so one word suffices; the constructor enforces it.
#[derive(Clone, Debug)]
struct FifoEntry {
    bits: u64,
}

/// The rate-matching OVSF generator.
#[derive(Clone, Debug)]
pub struct OvsfGenerator {
    /// Chunk length `K'²` in bits.
    chunk: usize,
    /// Output width `M` in bits (vector-unit width).
    m: usize,
    /// FIFO of basis vectors, front = next to read.
    fifo: std::collections::VecDeque<FifoEntry>,
    /// Cycles elapsed (1 emit per cycle).
    pub cycles: u64,
    /// Accumulated phase advance per full FIFO rotation (for invariants).
    reads: u64,
}

impl OvsfGenerator {
    /// Build the generator for a layer: `n_basis` codes of length `chunk`
    /// from the OVSF basis, output width `m`. The packed words are emitted
    /// straight from the matrix-free closed form — loading the FIFO never
    /// materialises the basis.
    pub fn new(basis: &OvsfBasis, n_basis: usize, m: usize) -> Self {
        let chunk = basis.len();
        assert!(
            chunk <= 64,
            "chunk codes are ≤64 bits for all evaluated kernels (K' ≤ 8)"
        );
        assert!(n_basis >= 1 && n_basis <= chunk);
        assert!(m >= 1);
        let fifo = (0..n_basis)
            .map(|j| FifoEntry {
                bits: basis.packed(j)[0],
            })
            .collect();
        Self {
            chunk,
            m,
            fifo,
            cycles: 0,
            reads: 0,
        }
    }

    /// Number of codes resident in the FIFO.
    pub fn n_basis(&self) -> usize {
        self.fifo.len()
    }

    /// FIFO storage in bits (Eq. 9's `K²_max·K²_max` term caps this).
    pub fn storage_bits(&self) -> u64 {
        (self.fifo.len() * self.chunk) as u64
    }

    /// Emit one `M`-bit slice of the front code as ±1 signs, perform the
    /// aligner rotation and recycle the code to the FIFO back. One call =
    /// one hardware cycle.
    pub fn emit(&mut self) -> Vec<i8> {
        let mut out = Vec::with_capacity(self.m);
        self.emit_into(&mut out);
        out
    }

    /// Allocation-free variant of [`emit`](Self::emit): overwrites `out`
    /// (hot path for the benches/simulator).
    pub fn emit_into(&mut self, out: &mut Vec<i8>) {
        // Invariant: the FIFO is filled at construction and every emit
        // recycles its entry to the back — it can never drain.
        #[allow(clippy::expect_used)]
        let entry = self.fifo.pop_front().expect("FIFO empty");
        let bits = entry.bits;
        let k2 = self.chunk;
        // Periodic extension: element e of the output is code bit
        // (e mod K'²) of the current rotation.
        out.clear();
        out.extend((0..self.m).map(|e| {
            if bits >> (e % k2) & 1 == 1 {
                1i8
            } else {
                -1i8
            }
        }));
        // Aligner: advance the phase by M mod K'² (left circular shift in
        // element order: new bit t = old bit (t + M) mod K'²).
        let shift = self.m % k2;
        let rotated = if shift == 0 {
            bits
        } else {
            let mask = if k2 == 64 { u64::MAX } else { (1u64 << k2) - 1 };
            ((bits >> shift) | (bits << (k2 - shift))) & mask
        };
        self.fifo.push_back(FifoEntry { bits: rotated });
        self.cycles += 1;
        self.reads += 1;
    }

    /// Current phase (elements consumed so far, mod `K'²`) of the code that
    /// is `idx` positions from the FIFO front — derived from read counts,
    /// used by the alignment-invariant tests.
    pub fn expected_phase(&self, total_reads_of_code: u64) -> usize {
        ((total_reads_of_code * self.m as u64) % self.chunk as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    /// Reference: the element stream of code `j` is its infinite periodic
    /// extension; subtile `s` needs elements `s·M .. s·M+M`.
    fn reference_slice(basis: &OvsfBasis, j: usize, s: usize, m: usize) -> Vec<i8> {
        let k2 = basis.len();
        (0..m).map(|e| basis.at(j, (s * m + e) % k2)).collect()
    }

    #[test]
    fn emits_correctly_aligned_slices_small_m() {
        // M ≤ K'²: LSB slice + rotate by M.
        let basis = OvsfBasis::new(16).unwrap();
        let n_basis = 8;
        let m = 4;
        let mut g = OvsfGenerator::new(&basis, n_basis, m);
        // Walk 6 subtiles; each subtile reads all n_basis codes once.
        for s in 0..6 {
            for j in 0..n_basis {
                let out = g.emit();
                assert_eq!(
                    out,
                    reference_slice(&basis, j, s, m),
                    "code {j}, subtile {s}"
                );
            }
        }
        assert_eq!(g.cycles, 6 * n_basis as u64);
    }

    #[test]
    fn emits_correctly_with_m_larger_than_chunk() {
        // M > K'²: self-concatenation + remainder, rotate by M mod K'².
        let basis = OvsfBasis::new(4).unwrap();
        let n_basis = 4;
        let m = 10; // ⌊10/4⌋ = 2 copies + 2 extra bits, phase advances by 2
        let mut g = OvsfGenerator::new(&basis, n_basis, m);
        for s in 0..5 {
            for j in 0..n_basis {
                assert_eq!(g.emit(), reference_slice(&basis, j, s, m), "j={j} s={s}");
            }
        }
    }

    #[test]
    fn alignment_invariant_random_configs() {
        // For random (K', M, n_basis), the emitted stream always equals the
        // periodic reference — the FIFO/aligner never needs mux selection.
        forall("ovsf-gen-aligned", 60, |rng| {
            let k = 1usize << rng.gen_range(1, 3); // K' ∈ {2, 4, 8}
            let chunk = k * k;
            let basis = OvsfBasis::new(chunk).unwrap();
            let n_basis = rng.gen_range(1, chunk as u64) as usize;
            let m = rng.gen_range(1, 40) as usize;
            let mut g = OvsfGenerator::new(&basis, n_basis, m);
            for s in 0..8 {
                for j in 0..n_basis {
                    assert_eq!(
                        g.emit(),
                        reference_slice(&basis, j, s, m),
                        "k²={chunk} M={m} nb={n_basis} j={j} s={s}"
                    );
                }
            }
        });
    }

    #[test]
    fn phase_returns_home_after_full_period() {
        // After lcm(M, K'²)/M reads of one code its rotation is back to the
        // original — the "correctly aligned for the next tile" property.
        let basis = OvsfBasis::new(16).unwrap();
        let m = 6;
        let mut g = OvsfGenerator::new(&basis, 1, m);
        let original = g.emit(); // read 0 (phase 0)
        // period: lcm(6,16)=48 ⇒ 8 reads per period.
        for _ in 0..7 {
            g.emit();
        }
        let after_period = g.emit(); // read 8 ⇒ phase 48 mod 16 = 0 again
        assert_eq!(original, after_period);
    }

    #[test]
    fn storage_is_one_bit_per_element() {
        let basis = OvsfBasis::new(16).unwrap();
        let g = OvsfGenerator::new(&basis, 8, 32);
        assert_eq!(g.storage_bits(), 8 * 16);
    }

    #[test]
    fn cycle_counting() {
        let basis = OvsfBasis::new(4).unwrap();
        let mut g = OvsfGenerator::new(&basis, 2, 8);
        for _ in 0..10 {
            g.emit();
        }
        assert_eq!(g.cycles, 10, "one emit per cycle (pipelined II=1)");
    }
}
