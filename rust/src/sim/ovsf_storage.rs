//! OVSF basis-vector storage design ablation (paper §4.2.2).
//!
//! The paper weighs three ways of feeding the M-wide vector datapath with
//! basis bits and argues for the FIFO + aligner. This module models all
//! three so the trade-off can be regenerated quantitatively:
//!
//! 1. **Monolithic buffer** — statically lay out every M-bit slice each
//!    subtile will read: M ports, depth = #basis-vectors × #subtiles per
//!    tile period. Rotated copies are materialised ⇒ heavy replication.
//! 2. **K²-deep memory + selection mux** — one K'²-bit word per code plus
//!    an M-output barrel-rotator built from K'²-to-1 muxes: minimal
//!    storage, but the selection network's LUT cost (≈ one 6-LUT per
//!    2×2-to-1 mux slice per output bit) scales with `M·log₂(K'²)` and
//!    lengthens the critical path.
//! 3. **FIFO + aligner** (the paper's design, `sim::ovsf_gen`): one
//!    K'²-bit word per code and a fixed per-layer circular shift — no
//!    generic mux tree, 1 vector/cycle.

use crate::util::ceil_div;

/// Cost estimate of one storage design.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StorageCost {
    /// On-chip bits dedicated to basis storage.
    pub storage_bits: u64,
    /// LUTs for selection/alignment logic (estimate; 0.5 LUT per 2-to-1
    /// mux bit-slice as on 6-LUT fabrics).
    pub selection_luts: u64,
    /// Read rate in basis vectors per cycle delivered to the datapath.
    pub vectors_per_cycle: f64,
}

/// Design 1: monolithic pre-rotated slice buffer.
///
/// Each tile period reads `n_basis · subtiles` M-bit slices; every slice is
/// stored explicitly (replication of rotated copies), no selection logic.
pub fn monolithic(m: u64, t_p: u64, t_c: u64, _k2: u64, n_basis: u64) -> StorageCost {
    let subtiles = ceil_div(t_p * t_c, m);
    // Distinct rotations repeat with period lcm(M, K'²)/M subtiles, but a
    // static layout stores every slice of the schedule (the paper's
    // "replicated either in the same address or in multiple addresses").
    let slices = n_basis * subtiles;
    StorageCost {
        storage_bits: slices * m,
        selection_luts: 0,
        vectors_per_cycle: 1.0,
    }
}

/// Design 2: minimal `K'²`-deep memory + generic barrel rotator.
pub fn mux_based(m: u64, k2: u64, n_basis: u64) -> StorageCost {
    // log2(K'²) rotation stages, each M bit-slices of 2-to-1 muxes.
    let stages = (64 - (k2.max(2) - 1).leading_zeros()) as u64;
    // Self-concatenation for M > K'² adds a replication stage per copy.
    let concat = if m > k2 { ceil_div(m, k2) } else { 1 };
    StorageCost {
        storage_bits: n_basis * k2,
        selection_luts: (m * stages).div_ceil(2) + concat * 8,
        vectors_per_cycle: 1.0, // but with a longer critical path
    }
}

/// Design 3: the FIFO + basis-vector aligner (paper's choice).
///
/// Storage equals the minimal design; alignment needs only the fixed
/// per-layer circular-shift wiring (one shift option per distinct K in the
/// CNN — pure routing plus a register, modelled at ~M/8 LUTs of fan-out
/// buffering).
pub fn fifo_aligner(m: u64, k2: u64, n_basis: u64, distinct_kernel_sizes: u64) -> StorageCost {
    StorageCost {
        storage_bits: n_basis * k2,
        selection_luts: (m / 8).max(1) * distinct_kernel_sizes,
        vectors_per_cycle: 1.0,
    }
}

/// Compare the three designs for a configuration; returns
/// `(monolithic, mux, fifo)`.
pub fn compare(
    m: u64,
    t_p: u64,
    t_c: u64,
    k2: u64,
    n_basis: u64,
    distinct_kernel_sizes: u64,
) -> (StorageCost, StorageCost, StorageCost) {
    (
        monolithic(m, t_p, t_c, k2, n_basis),
        mux_based(m, k2, n_basis),
        fifo_aligner(m, k2, n_basis, distinct_kernel_sizes),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn fifo_matches_minimal_storage() {
        let (_, mux, fifo) = compare(64, 16, 48, 16, 8, 2);
        assert_eq!(fifo.storage_bits, mux.storage_bits, "both store 1 bit/element");
    }

    #[test]
    fn monolithic_replicates_heavily() {
        let (mono, _, fifo) = compare(64, 16, 48, 16, 8, 2);
        assert!(
            mono.storage_bits > 20 * fifo.storage_bits,
            "monolithic {} vs fifo {} bits",
            mono.storage_bits,
            fifo.storage_bits
        );
    }

    #[test]
    fn fifo_needs_far_less_selection_logic_than_mux() {
        forall("storage-ablation", 40, |rng| {
            let m = 1u64 << rng.gen_range(3, 8);
            let k2 = [4u64, 16, 64][rng.gen_range(0, 2) as usize];
            let nb = rng.gen_range(1, k2);
            let (_, mux, fifo) = compare(m, 16, 64, k2, nb, 2);
            assert!(
                fifo.selection_luts < mux.selection_luts,
                "fifo {} !< mux {} (M={m}, K²={k2})",
                fifo.selection_luts,
                mux.selection_luts
            );
        });
    }

    #[test]
    fn all_designs_sustain_rate() {
        let (mono, mux, fifo) = compare(32, 8, 32, 16, 4, 1);
        for d in [mono, mux, fifo] {
            assert!(d.vectors_per_cycle >= 1.0, "rate matching required");
        }
    }

    #[test]
    fn paper_tradeoff_holds_at_paper_scale() {
        // The dominance argument of §4.2.2: vs design 1 the FIFO removes
        // replicated storage; vs design 2 it removes the mux tree.
        let (mono, mux, fifo) = compare(128, 8, 96, 16, 16, 2);
        assert!(fifo.storage_bits <= mono.storage_bits / 10);
        assert!(fifo.selection_luts * 2 <= mux.selection_luts);
    }
}
