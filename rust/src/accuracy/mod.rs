//! Accuracy model for OVSF/pruned variants.

pub mod model;

pub use model::AccuracyModel;
