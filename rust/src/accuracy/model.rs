//! Paper-anchored accuracy model.
//!
//! ImageNet-scale training is outside this reproduction's budget (see
//! DESIGN.md §Substitutions). Hardware results need only the workload
//! shapes and ρ profiles, which we use exactly; the *accuracy columns* of
//! Tables 1 and 4–6 are regenerated from a monotone interpolation anchored
//! on the paper's own reported (effective-ρ → top-1) points per network.
//! Trend-level accuracy (Table 3-style strategy comparisons and the e2e
//! loss curve) is *measured* by training real OVSF models on synthetic data
//! in `python/compile/train.py`.

use crate::engine::backend::EnginePlan;
use crate::perf::model::PerfModel;
use crate::util::fixed::Precision;
use crate::workload::{Network, RatioProfile};

/// Accuracy anchors for one network: `(effective ρ over OVSF layers,
/// top-1 %)`, plus the dense reference accuracy.
#[derive(Clone, Debug)]
pub struct AccuracyModel {
    /// Network name the anchors belong to.
    pub network: String,
    /// Dense (uncompressed) top-1 accuracy.
    pub dense_top1: f64,
    /// Anchor points, ascending in ρ.
    anchors: Vec<(f64, f64)>,
}

impl AccuracyModel {
    /// Build the anchored model for one of the paper's benchmarks.
    ///
    /// Anchors come from Tables 4–6 (ImageNet top-1 of the OVSF50/OVSF25
    /// variants) and §7.2.2 (ResNet50); effective ρ is computed from the
    /// same hand-tuned profiles with this crate's own profile arithmetic,
    /// so interpolation queries and anchors share one scale.
    pub fn for_network(net: &Network) -> Self {
        let e50 = RatioProfile::ovsf50(net).effective_rho(net);
        let e25 = RatioProfile::ovsf25(net).effective_rho(net);
        let (dense, a50, a25) = match net.name.as_str() {
            "ResNet18" => (69.8, 69.2, 67.3),
            "ResNet34" => (73.3, 72.8, 71.5),
            "ResNet50" => (76.15, 76.23, 74.6), // OVSF50 slightly *above* dense (§7.2.2)
            "SqueezeNet" => (58.2, 57.6, 57.1),
            // Unknown nets: generic gentle degradation curve.
            _ => (70.0, 69.3, 67.5),
        };
        AccuracyModel {
            network: net.name.clone(),
            dense_top1: dense,
            anchors: vec![(e25, a25), (e50, a50), (1.0, dense.max(a50))],
        }
    }

    /// Top-1 accuracy for an arbitrary ratio profile: monotone piecewise-
    /// linear interpolation on effective ρ (clamped at the ends).
    pub fn top1(&self, net: &Network, profile: &RatioProfile) -> f64 {
        let e = profile.effective_rho(net);
        self.top1_at_effective_rho(e)
    }

    /// Interpolate at a raw effective-ρ value.
    pub fn top1_at_effective_rho(&self, e: f64) -> f64 {
        let a = &self.anchors;
        if e <= a[0].0 {
            // Extrapolate below the lowest anchor with the first segment's
            // slope (accuracy keeps degrading with compression).
            let (x0, y0) = a[0];
            let (x1, y1) = a[1];
            let slope = (y1 - y0) / (x1 - x0);
            return y0 - slope * (x0 - e);
        }
        for w in a.windows(2) {
            let (x0, y0) = w[0];
            let (x1, y1) = w[1];
            if e <= x1 {
                return y0 + (y1 - y0) * (e - x0) / (x1 - x0);
            }
        }
        a[a.len() - 1].1
    }
}

/// Representative post-training-quantisation top-1 penalty (percentage
/// points) of a symmetric per-layer int8 weight scheme, per network.
/// Deeper/over-parameterised residual nets quantise gracefully; the
/// parameter-starved SqueezeNet is the classic PTQ outlier.
pub fn i8_top1_penalty(network: &str) -> f64 {
    match network {
        "ResNet18" | "ResNet34" => 0.4,
        "ResNet50" => 0.6,
        "SqueezeNet" => 1.0,
        _ => 0.5,
    }
}

/// One point on a model's accuracy/throughput trade-off curve — what the
/// [`Compiler`](crate::engine::compile::Compiler) surfaces per artifact so
/// a deployment can pick its precision with both axes in view.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrecisionPoint {
    /// The weight-datapath precision this point describes.
    pub precision: Precision,
    /// Modelled ImageNet top-1 (%) at the artifact's ρ profile, including
    /// the PTQ penalty at `I8`.
    pub top1: f64,
    /// Analytical throughput (inf/s) at the plan's platform/bandwidth
    /// point with the word length set to this precision's byte width.
    pub inf_per_s: f64,
    /// Throughput relative to the `F32` point (1.0 for `F32` itself).
    pub rel_throughput: f64,
}

/// The accuracy/throughput point of a compiled plan at each supported
/// precision. Accuracy comes from the paper-anchored [`AccuracyModel`]
/// minus the per-network [`i8_top1_penalty`]; throughput from the
/// analytical [`PerfModel`] with `wl_bytes` set per precision — compute
/// cycles are word-length independent, so the gap is exactly the
/// memory-wall relief the narrower words buy.
pub fn precision_tradeoff(plan: &EnginePlan) -> Vec<PrecisionPoint> {
    let acc = AccuracyModel::for_network(&plan.network);
    let top1_f32 = acc.top1(&plan.network, &plan.profile);
    let f32_perf = PerfModel::for_precision(plan.platform.clone(), plan.bw_mult, Precision::F32)
        .network_perf(&plan.sigma, &plan.network, &plan.profile);
    let i8_perf = PerfModel::for_precision(plan.platform.clone(), plan.bw_mult, Precision::I8)
        .network_perf(&plan.sigma, &plan.network, &plan.profile);
    vec![
        PrecisionPoint {
            precision: Precision::F32,
            top1: top1_f32,
            inf_per_s: f32_perf.inf_per_s,
            rel_throughput: 1.0,
        },
        PrecisionPoint {
            precision: Precision::I8,
            top1: top1_f32 - i8_top1_penalty(&plan.network.name),
            inf_per_s: i8_perf.inf_per_s,
            rel_throughput: i8_perf.inf_per_s / f32_perf.inf_per_s,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::resnet;

    #[test]
    fn anchors_reproduce_paper_numbers() {
        let net = resnet::resnet18();
        let m = AccuracyModel::for_network(&net);
        let a50 = m.top1(&net, &RatioProfile::ovsf50(&net));
        let a25 = m.top1(&net, &RatioProfile::ovsf25(&net));
        assert!((a50 - 69.2).abs() < 0.05, "OVSF50 anchor: {a50}");
        assert!((a25 - 67.3).abs() < 0.05, "OVSF25 anchor: {a25}");
    }

    #[test]
    fn monotone_in_effective_rho() {
        let net = resnet::resnet34();
        let m = AccuracyModel::for_network(&net);
        let mut prev = 0.0;
        for i in 0..50 {
            let e = 0.05 + 0.95 * i as f64 / 49.0;
            let a = m.top1_at_effective_rho(e);
            assert!(a >= prev - 1e-9, "not monotone at e={e}");
            prev = a;
        }
    }

    #[test]
    fn autotuned_profiles_land_between_anchors() {
        // A profile between OVSF25 and OVSF50 must land between their
        // accuracies — the mechanism behind Table 1's +1.2pp gains.
        let net = resnet::resnet18();
        let m = AccuracyModel::for_network(&net);
        let mut mid = RatioProfile::ovsf25(&net);
        for (i, l) in net.layers.iter().enumerate() {
            if l.ovsf && mid.rhos[i] < 0.4 {
                mid.rhos[i] = 0.4;
            }
        }
        let a_mid = m.top1(&net, &mid);
        let a25 = m.top1(&net, &RatioProfile::ovsf25(&net));
        let a50 = m.top1(&net, &RatioProfile::ovsf50(&net));
        assert!(a_mid > a25 && a_mid <= a50 + 1e-9, "{a25} < {a_mid} ≤ {a50}");
    }

    #[test]
    fn precision_tradeoff_trades_accuracy_for_throughput() {
        use crate::arch::{DesignPoint, Platform};
        let net = resnet::resnet18();
        let profile = RatioProfile::ovsf50(&net);
        let plan = crate::engine::Engine::builder()
            .platform(Platform::z7045())
            .bandwidth(1)
            .design_point(DesignPoint::new(64, 64, 16, 48))
            .network(net)
            .profile(profile)
            .plan()
            .unwrap();
        let points = precision_tradeoff(&plan);
        assert_eq!(points.len(), 2);
        let f = points
            .iter()
            .find(|p| p.precision == Precision::F32)
            .unwrap();
        let i = points.iter().find(|p| p.precision == Precision::I8).unwrap();
        // i8 gives up the PTQ penalty and buys memory-wall relief.
        assert!((f.top1 - i.top1 - i8_top1_penalty("ResNet18")).abs() < 1e-9);
        assert_eq!(f.rel_throughput, 1.0);
        assert!(i.rel_throughput > 1.0, "i8 must be faster at 1× bandwidth");
        assert!(i.inf_per_s > f.inf_per_s);
    }

    #[test]
    fn uniform_1_matches_or_exceeds_dense_reference() {
        let net = resnet::resnet50();
        let m = AccuracyModel::for_network(&net);
        let full = m.top1(&net, &RatioProfile::uniform(&net, 1.0));
        assert!(full >= m.dense_top1 - 1e-9);
    }
}
