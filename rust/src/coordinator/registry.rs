//! [`ModelRegistry`] — runtime registration of [`CompiledModel`] artifacts
//! under string ids, all sharing **one** bounded
//! [`SlabCache`](crate::engine::wcache::SlabCache) — plus
//! [`ServerPool::serve`], the registry-routed serving entry point.
//!
//! This is the paper's multi-model premise made operational: a single
//! computation engine (one design point σ, one pool of workers, one
//! generated-weights byte budget) serves several CNNs concurrently.
//! Resident weight slabs from different models compete under the shared
//! budget exactly like co-resident models would compete for on-chip BRAM;
//! switching the model a worker serves swaps only the plan and the
//! compiled α state (dense weights are re-generated on the fly), mirroring
//! the α-reload-only switch cost of the time-shared engine.
//!
//! Lifecycle:
//!
//! ```no_run
//! use std::sync::Arc;
//! use unzipfpga::coordinator::pool::{PoolConfig, ServerPool};
//! use unzipfpga::coordinator::registry::ModelRegistry;
//! use unzipfpga::coordinator::server::Request;
//! use unzipfpga::engine::{BackendKind, Compiler};
//! use unzipfpga::workload::{resnet, squeezenet, RatioProfile};
//!
//! let compiler = Compiler::new();
//! let registry = Arc::new(ModelRegistry::with_budget(8 << 20));
//! let r18 = resnet::resnet18();
//! let sqn = squeezenet::squeezenet1_1();
//! registry.register("resnet18", compiler.compile(r18.clone(), RatioProfile::ovsf50(&r18))?)?;
//! registry.register("squeezenet", compiler.compile(sqn.clone(), RatioProfile::ovsf50(&sqn))?)?;
//! let pool = ServerPool::serve(Arc::clone(&registry), BackendKind::Simulator, PoolConfig::default())?;
//! let handle = pool.submit(Request::for_model(0, "resnet18", vec![]))?;
//! let _response = handle.wait()?;
//! # Ok::<(), unzipfpga::Error>(())
//! ```

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use crate::coordinator::pool::{PoolConfig, RequestExecutor, ServerPool};
use crate::coordinator::server::Request;
use crate::engine::compile::CompiledModel;
use crate::engine::wcache::SlabCache;
use crate::engine::{BackendKind, Engine, ExecutionBackend, Precision};
use crate::error::{Error, Result};

/// Decorator applied to each worker's freshly constructed backend before
/// it is planned: receives the raw backend and the worker index, returns
/// the backend to serve through. This is the fault seam replicated serving
/// exposes — chaos tests wrap one replica's backends in
/// [`FaultyBackend`](crate::engine::fault::FaultyBackend) while production
/// code pays nothing (the hook is `None`).
pub type BackendWrap =
    Arc<dyn Fn(Box<dyn ExecutionBackend>, usize) -> Box<dyn ExecutionBackend> + Send + Sync>;

/// Process-wide registration-generation counter. Generations are unique
/// across *all* registries because registries can share one `SlabCache`:
/// two registries must never stamp the same generation onto the same
/// network name. Generation 0 is reserved for unregistered artifacts.
static NEXT_GENERATION: AtomicU64 = AtomicU64::new(1);

/// Thread-safe registry of compiled models sharing one slab cache.
/// Registration and eviction are runtime operations: a model can be added
/// to (or removed from) a live [`ServerPool`] between requests.
pub struct ModelRegistry {
    cache: Arc<SlabCache>,
    models: Mutex<BTreeMap<String, Arc<CompiledModel>>>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for ModelRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelRegistry")
            .field("models", &self.ids())
            .field("cache", &self.cache)
            .finish()
    }
}

impl ModelRegistry {
    /// Registry over a fresh slab cache with the default byte budget.
    pub fn new() -> Self {
        Self::with_cache(Arc::new(SlabCache::new()))
    }

    /// Registry over a fresh slab cache bounded to `bytes` — the single
    /// budget every registered model's generated weights compete under.
    pub fn with_budget(bytes: usize) -> Self {
        Self::with_cache(Arc::new(SlabCache::with_budget(bytes)))
    }

    /// Registry over an existing (possibly already shared) slab cache.
    pub fn with_cache(cache: Arc<SlabCache>) -> Self {
        Self {
            cache,
            models: Mutex::new(BTreeMap::new()),
        }
    }

    fn lock(&self) -> MutexGuard<'_, BTreeMap<String, Arc<CompiledModel>>> {
        self.models.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// The shared slab cache all registered models generate through.
    pub fn cache(&self) -> &Arc<SlabCache> {
        &self.cache
    }

    /// Register a compiled model under `id`. Errors on an empty id, a
    /// duplicate id, or a duplicate *network name*
    /// ([`evict`](Self::evict) first to replace a model): generated-weight
    /// slabs are keyed by network name, so two resident models sharing one
    /// name could alias each other's cached slabs. Returns the shared
    /// handle to the registered artifact.
    pub fn register(
        &self,
        id: impl Into<String>,
        mut model: CompiledModel,
    ) -> Result<Arc<CompiledModel>> {
        let id = id.into();
        if id.is_empty() {
            return Err(Error::InvalidConfig(
                "ModelRegistry: model id must be non-empty".into(),
            ));
        }
        let mut m = self.lock();
        if m.contains_key(&id) {
            return Err(Error::InvalidConfig(format!(
                "ModelRegistry: model id '{id}' is already registered (evict it first)"
            )));
        }
        let clash = m
            .iter()
            .find(|(_, v)| v.network_name() == model.network_name());
        if let Some((other, _)) = clash {
            return Err(Error::InvalidConfig(format!(
                "ModelRegistry: network '{}' is already registered under id \
                 '{other}' — weight slabs are keyed by network name, so two \
                 resident models may not share one",
                model.network_name()
            )));
        }
        // Stamp a fresh generation into the artifact's slab identities
        // before the artifact is shared: slabs generated for any earlier
        // registration of this network (including stragglers re-inserted
        // after an evict) live under a different generation and can never
        // alias this registration's cache entries.
        model.assign_generation(NEXT_GENERATION.fetch_add(1, Ordering::Relaxed));
        let model = Arc::new(model);
        m.insert(id, Arc::clone(&model));
        Ok(model)
    }

    /// Evict a model: unregister it and drop its resident weight slabs
    /// from the shared cache (the bytes are immediately reusable by the
    /// remaining models). Requests already queued for the id fail with
    /// [`Error::UnknownModel`] when a worker reaches them; a batch already
    /// **executing** the model completes (it holds the artifact `Arc`) but
    /// cannot re-seed the cache after the purge: the registration's
    /// generation is *retired*
    /// ([`SlabCache::retire_generation`](crate::engine::wcache::SlabCache::retire_generation))
    /// before the sweep, so a straggler's insert is refused at the cache —
    /// under the same lock as the sweep, leaving no window. Returns the
    /// evicted artifact.
    pub fn evict(&self, id: &str) -> Result<Arc<CompiledModel>> {
        let model = self
            .lock()
            .remove(id)
            .ok_or_else(|| Error::UnknownModel(id.to_string()))?;
        // Retire FIRST, then sweep: any straggler insert either landed
        // before the watermark (swept below) or arrives after (refused).
        self.cache
            .retire_generation(model.network_name(), model.generation());
        for key in model.weights_keys() {
            self.cache.evict_layer(key);
        }
        Ok(model)
    }

    /// Look up a registered model.
    pub fn get(&self, id: &str) -> Result<Arc<CompiledModel>> {
        self.lock()
            .get(id)
            .map(Arc::clone)
            .ok_or_else(|| Error::UnknownModel(id.to_string()))
    }

    /// Resolve a request's model id to a concrete `(id, model)` pair. An
    /// empty id is the default route: valid only while exactly one model
    /// is registered.
    pub fn resolve(&self, id: &str) -> Result<(String, Arc<CompiledModel>)> {
        let m = self.lock();
        if id.is_empty() {
            return match m.iter().next() {
                Some((k, v)) if m.len() == 1 => Ok((k.clone(), Arc::clone(v))),
                _ => Err(Error::UnknownModel(format!(
                    "(default route: {} models registered, name one of them)",
                    m.len()
                ))),
            };
        }
        m.get(id)
            .map(|v| (id.to_string(), Arc::clone(v)))
            .ok_or_else(|| Error::UnknownModel(id.to_string()))
    }

    /// Registered model ids (sorted).
    pub fn ids(&self) -> Vec<String> {
        self.lock().keys().cloned().collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.lock().len()
    }

    /// `true` when no model is registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Reconstruct a typed copy of an activation error so every request of a
/// batch can carry it (Error is not `Clone`).
fn clone_typed(e: &Error) -> Error {
    match e {
        Error::UnknownModel(m) => Error::UnknownModel(m.clone()),
        Error::PoolShutdown => Error::PoolShutdown,
        Error::InvalidConfig(s) => Error::InvalidConfig(s.clone()),
        Error::ShapeMismatch(s) => Error::ShapeMismatch(s.clone()),
        Error::Overloaded { queue_delay, slo } => Error::Overloaded {
            queue_delay: *queue_delay,
            slo: *slo,
        },
        Error::DeadlineExceeded { late_by } => Error::DeadlineExceeded { late_by: *late_by },
        Error::QueueFull => Error::QueueFull,
        Error::WorkerPanic { detail } => Error::WorkerPanic {
            detail: detail.clone(),
        },
        Error::CircuitOpen { model, retry_after } => Error::CircuitOpen {
            model: model.clone(),
            retry_after: *retry_after,
        },
        Error::Transient(s) => Error::Transient(s.clone()),
        Error::DegradedCapacity { live, configured } => Error::DegradedCapacity {
            live: *live,
            configured: *configured,
        },
        Error::StageFailed { stage, source } => Error::StageFailed {
            stage: *stage,
            source: Box::new(clone_typed(source)),
        },
        other => Error::Coordinator(other.to_string()),
    }
}

/// Per-worker model-routing executor: one backend instance serves every
/// registered model, re-planned (and handed the compiled α state) whenever
/// consecutive batches name different models. The batch path folds
/// same-shape numeric requests into one `Engine::infer_batch` call so each
/// generated weight slab is amortised across the whole (model-pure) batch.
struct RegistryExecutor {
    registry: Arc<ModelRegistry>,
    kind: BackendKind,
    engine: Option<Engine>,
    active: Option<(String, Arc<CompiledModel>)>,
    switches: u64,
    /// This worker's index within its pool (passed to `wrap`).
    worker: usize,
    /// Optional backend decorator (the chaos/fault seam).
    wrap: Option<BackendWrap>,
}

impl RegistryExecutor {
    /// Route `id`: re-resolve against the registry (an evicted model must
    /// fail typed even if it is still the active plan), swap the backend's
    /// active plan when the model — or its re-registered artifact —
    /// changed, and return the serving engine.
    fn activate(&mut self, id: &str) -> Result<&mut Engine> {
        let model = self.registry.get(id)?;
        let current = matches!(
            &self.active,
            Some((aid, am)) if aid == id && Arc::ptr_eq(am, &model)
        );
        if !current {
            // A PJRT backend executes one fixed AOT artifact: re-planning
            // it for a different model would silently serve the wrong
            // network's numerics, so refuse the switch with a typed error
            // (ServerPool::serve also rejects multi-model PJRT up front;
            // this guards models registered after the pool started).
            // Guard on the engine, not `active`: even after a failed swap
            // cleared `active`, a planned PJRT backend must never be
            // re-planned onto another model.
            if self.engine.is_some() && matches!(self.kind, BackendKind::Pjrt(_)) {
                return Err(Error::InvalidConfig(format!(
                    "PJRT pools serve a single fixed artifact; cannot re-plan the \
                     worker's backend for model '{id}'"
                )));
            }
            // The backend's state is indeterminate while the swap runs: a
            // failed `plan`/`preload` must not leave `active` naming the
            // old model over a half-swapped backend, so clear it first —
            // on error the next activation re-plans from scratch.
            let was_active = self.active.take().is_some();
            match self.engine.as_mut() {
                Some(e) => e.activate(&model)?,
                None => {
                    let engine = match &self.wrap {
                        Some(wrap) => {
                            let raw = crate::engine::make_backend(
                                &self.kind,
                                self.registry.cache(),
                                model.precision(),
                            )?;
                            Engine::from_compiled_with(&model, wrap(raw, self.worker))?
                        }
                        None => {
                            Engine::from_compiled(&model, &self.kind, self.registry.cache())?
                        }
                    };
                    self.engine = Some(engine);
                }
            }
            if was_active {
                self.switches += 1;
            }
            self.active = Some((id.to_string(), model));
        }
        self.engine.as_mut().ok_or_else(|| {
            Error::Coordinator("worker backend missing after activation".into())
        })
    }
}

impl RequestExecutor for RegistryExecutor {
    fn execute(&mut self, req: &Request) -> Result<Vec<f32>> {
        let engine = self.activate(&req.model)?;
        engine.infer(&req.input).map(|o| o.output)
    }

    fn execute_batch(&mut self, batch: &[Request]) -> Vec<Result<Vec<f32>>> {
        let Some(first) = batch.first() else {
            return Vec::new();
        };
        // Batches are model-pure by construction: route once per batch.
        debug_assert!(batch.iter().all(|r| r.model == first.model));
        let engine = match self.activate(&first.model) {
            Ok(e) => e,
            Err(e) => return batch.iter().map(|_| Err(clone_typed(&e))).collect(),
        };
        let expect = engine
            .plan()
            .network
            .layers
            .first()
            .map(|l| (l.h * l.w * l.n_in) as usize)
            .unwrap_or(0);
        let foldable: Vec<usize> = batch
            .iter()
            .enumerate()
            .filter(|(_, r)| expect > 0 && r.input.len() == expect)
            .map(|(i, _)| i)
            .collect();
        if foldable.len() < 2 {
            return batch
                .iter()
                .map(|r| engine.infer(&r.input).map(|o| o.output))
                .collect();
        }
        // One clone per request (requests are borrowed); `infer_batch`
        // takes ownership, so no further copies happen.
        let inputs: Vec<Vec<f32>> = foldable.iter().map(|&i| batch[i].input.clone()).collect();
        let mut results: Vec<Option<Result<Vec<f32>>>> =
            (0..batch.len()).map(|_| None).collect();
        match engine.infer_batch(inputs) {
            Ok((outs, _report)) => {
                for (&i, out) in foldable.iter().zip(outs) {
                    results[i] = Some(Ok(out));
                }
            }
            Err(e) => {
                let msg = format!("batched inference failed: {e}");
                for &i in &foldable {
                    results[i] = Some(Err(Error::Coordinator(msg.clone())));
                }
            }
        }
        for (i, slot) in results.iter_mut().enumerate() {
            if slot.is_none() {
                *slot = Some(engine.infer(&batch[i].input).map(|o| o.output));
            }
        }
        results
            .into_iter()
            .map(|r| {
                r.unwrap_or_else(|| {
                    Err(Error::Coordinator("batch slot left unfilled".into()))
                })
            })
            .collect()
    }

    fn device_latency_s(&self, req: &Request) -> Option<f64> {
        // The batch that produced this response activated its model, so
        // the common case reads the held handle — no registry lock on the
        // per-response path (and an eviction racing the response still
        // reports the latency the request was actually served at).
        match &self.active {
            Some((id, model)) if id == &req.model => Some(model.latency_s()),
            _ => self.registry.get(&req.model).ok().map(|m| m.latency_s()),
        }
    }

    fn model_switches(&self) -> u64 {
        self.switches
    }
}

impl ServerPool {
    /// Start a **registry-routed** pool: `cfg.workers` threads serving
    /// every model registered in `registry` (now or later) on `kind`
    /// backends. Each worker owns one backend and swaps its active plan on
    /// model switch; all workers generate weight slabs through the
    /// registry's shared bounded cache. `submit` validates requests
    /// against the registry (typed fail-fast errors for unknown ids and
    /// wrong input lengths).
    pub fn serve(
        registry: Arc<ModelRegistry>,
        kind: BackendKind,
        cfg: PoolConfig,
    ) -> Result<Self> {
        Self::serve_with_wrap(registry, kind, cfg, None)
    }

    /// [`serve`](Self::serve) with an optional backend decorator: every
    /// worker's backend is passed through `wrap` (with its worker index)
    /// before planning. Replicated serving's chaos tests use this to
    /// confine injected faults to one replica; `None` is exactly
    /// [`serve`](Self::serve).
    pub fn serve_with_wrap(
        registry: Arc<ModelRegistry>,
        kind: BackendKind,
        cfg: PoolConfig,
        wrap: Option<BackendWrap>,
    ) -> Result<Self> {
        // Fail fast on the caller thread: a broken runtime should error
        // here, not inside a worker. (Compiled models were validated at
        // compile time; analytical/simulator backends cannot fail to
        // construct.)
        if let BackendKind::Pjrt(pjrt) = &kind {
            // A PJRT backend runs one fixed AOT **f32** artifact — it can
            // neither route between models (workers also refuse switches at
            // runtime) nor serve a quantised artifact's numerics.
            for id in registry.ids() {
                if let Ok(m) = registry.get(&id) {
                    if m.precision() != Precision::F32 {
                        return Err(Error::InvalidConfig(format!(
                            "PJRT pools execute a fixed AOT f32 artifact, but model \
                             '{id}' is compiled at {}",
                            m.precision()
                        )));
                    }
                }
            }
            if registry.len() > 1 {
                return Err(Error::InvalidConfig(format!(
                    "PJRT pools serve a single fixed artifact, but {} models are \
                     registered",
                    registry.len()
                )));
            }
            if !cfg!(feature = "pjrt") {
                return Err(Error::RuntimeUnavailable);
            }
            let reg = crate::runtime::ArtifactRegistry::new(pjrt.artifacts_dir.clone())?;
            if !reg.has(&pjrt.artifact) {
                return Err(Error::MissingArtifact {
                    path: reg.path_of(&pjrt.artifact).display().to_string(),
                    source: std::io::Error::new(std::io::ErrorKind::NotFound, "no such file"),
                });
            }
        }
        let factory_registry = Arc::clone(&registry);
        ServerPool::start_inner(None, Some(registry), cfg, move |worker| RegistryExecutor {
            registry: Arc::clone(&factory_registry),
            kind: kind.clone(),
            engine: None,
            active: None,
            switches: 0,
            worker,
            wrap: wrap.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{DesignPoint, Platform};
    use crate::engine::Compiler;
    use crate::workload::{Layer, Network, RatioProfile};

    fn tiny_net(name: &str) -> Network {
        Network {
            name: name.into(),
            layers: vec![
                Layer::conv("stem", 8, 8, 4, 8, 3, 1, 1, false),
                Layer::conv("b.conv1", 8, 8, 8, 8, 3, 1, 1, true),
                Layer::fc("fc", 8, 5),
            ],
        }
    }

    fn compiler() -> Compiler {
        Compiler::new()
            .platform(Platform::z7045())
            .bandwidth(4)
            .design_point(DesignPoint::new(8, 4, 8, 4))
    }

    fn compile(name: &str) -> CompiledModel {
        let net = tiny_net(name);
        let profile = RatioProfile::uniform(&net, 0.5);
        compiler().compile(net, profile).unwrap()
    }

    #[test]
    fn register_get_evict_lifecycle() {
        let reg = ModelRegistry::with_budget(1 << 20);
        assert!(reg.is_empty());
        reg.register("a", compile("a")).unwrap();
        reg.register("b", compile("b")).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.ids(), vec!["a".to_string(), "b".to_string()]);
        assert!(reg.get("a").is_ok());
        // Duplicate ids, duplicate network names (the slab-cache
        // namespace) and empty ids are rejected.
        assert!(reg.register("a", compile("a")).is_err());
        assert!(reg.register("alias", compile("a")).is_err());
        assert!(reg.register("", compile("x")).is_err());
        // Unknown lookups are typed.
        let err = reg.get("zzz").err().expect("unknown id");
        assert!(matches!(err, Error::UnknownModel(_)), "{err}");
        // Eviction removes the model; a second evict is typed too.
        let evicted = reg.evict("a").unwrap();
        assert_eq!(evicted.network_name(), "a");
        assert!(matches!(reg.evict("a"), Err(Error::UnknownModel(_))));
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn evict_purges_resident_slabs_from_the_shared_cache() {
        let reg = ModelRegistry::with_budget(1 << 20);
        let model = reg.register("a", compile("a")).unwrap();
        // Generate one slab under the model's namespace.
        let key = crate::engine::SlabKey {
            layer: model.weights_keys()[0].clone(),
            col_tile: 0,
        };
        reg.cache()
            .try_get_or_generate(key, || Ok(crate::engine::Slab::F32(vec![1.0; 16])))
            .unwrap();
        assert_eq!(reg.cache().len(), 1);
        reg.evict("a").unwrap();
        assert_eq!(reg.cache().len(), 0, "eviction must purge the model's slabs");
        assert!(reg.cache().evictions() >= 1);
    }

    #[test]
    fn reregistration_gets_a_fresh_generation_and_stragglers_cannot_alias_it() {
        // The evict-vs-in-flight race from PR 5: a batch still executing an
        // evicted model holds the old artifact Arc and may re-insert slabs
        // *after* the purge. With generation-stamped keys the straggler's
        // entries live under the old generation, so a re-registered model
        // with the same id/network name regenerates instead of adopting
        // stale slabs.
        let reg = ModelRegistry::with_budget(1 << 20);
        let old = reg.register("a", compile("a")).unwrap();
        let g_old = old.generation();
        assert!(g_old > 0, "registration must stamp a nonzero generation");
        assert!(
            old.weights_keys().iter().all(|k| k.generation == g_old),
            "every weights key carries the registration generation"
        );
        reg.evict("a").unwrap();
        // Straggler: the in-flight batch tries to re-insert a slab under
        // the OLD key after the purge. Eviction retired the old generation,
        // so the insert is refused at the cache — the straggler still gets
        // its own copy back, but nothing lands in the map.
        let straggler_key = crate::engine::SlabKey {
            layer: old.weights_keys()[0].clone(),
            col_tile: 0,
        };
        reg.cache()
            .try_get_or_generate(straggler_key, || {
                Ok(crate::engine::Slab::F32(vec![f32::NAN; 16]))
            })
            .unwrap();
        assert_eq!(
            reg.cache().retired_inserts(),
            1,
            "the straggler's insert must be refused, not merely aged out"
        );
        assert_eq!(reg.cache().len(), 0, "no stale slab may be resident");
        // Re-register the same id + network name.
        let new = reg.register("a", compile("a")).unwrap();
        assert!(new.generation() > g_old, "re-registration bumps the generation");
        let new_key = crate::engine::SlabKey {
            layer: new.weights_keys()[0].clone(),
            col_tile: 0,
        };
        let hits_before = reg.cache().hits();
        let v = reg
            .cache()
            .try_get_or_generate(new_key, || Ok(crate::engine::Slab::F32(vec![1.0; 16])))
            .unwrap();
        assert_eq!(reg.cache().hits(), hits_before, "must NOT adopt the straggler");
        assert_eq!(v.f32_data(), &[1.0; 16], "fresh numerics, not the stale NaNs");
    }

    #[test]
    fn serve_rejects_pjrt_pools_holding_i8_models() {
        let reg = Arc::new(ModelRegistry::new());
        let net = tiny_net("quant");
        let profile = RatioProfile::uniform(&net, 0.5);
        let model = compiler()
            .precision(Precision::I8)
            .compile(net, profile)
            .unwrap();
        assert_eq!(model.precision(), Precision::I8);
        reg.register("quant", model).unwrap();
        let cfg = crate::engine::PjrtConfig::new("/nonexistent", "model_fwd", vec![1]);
        let err = ServerPool::serve(
            Arc::clone(&reg),
            BackendKind::Pjrt(cfg),
            PoolConfig::default(),
        )
        .err()
        .expect("PJRT cannot serve an i8 artifact");
        assert!(err.to_string().contains("f32 artifact"), "{err}");
        // The simulator pool serves the same registry fine.
        let pool =
            ServerPool::serve(reg, BackendKind::Simulator, PoolConfig::default()).unwrap();
        let handle = pool
            .submit(crate::coordinator::server::Request::for_model(
                0,
                "quant",
                vec![0.5; 8 * 8 * 4],
            ))
            .unwrap();
        let resp = handle.wait().unwrap();
        assert_eq!(
            resp.output.len(),
            5,
            "i8 model serves numerics through the pool"
        );
        assert!(resp.output.iter().all(|v| v.is_finite()));
        let _ = pool.shutdown();
    }

    #[test]
    fn resolve_handles_the_default_route() {
        let reg = ModelRegistry::new();
        // Empty registry: nothing to route to.
        assert!(matches!(reg.resolve(""), Err(Error::UnknownModel(_))));
        reg.register("only", compile("only")).unwrap();
        let (id, m) = reg.resolve("").unwrap();
        assert_eq!(id, "only");
        assert_eq!(m.network_name(), "only");
        reg.register("second", compile("second")).unwrap();
        // Ambiguous default route once two models are registered.
        assert!(matches!(reg.resolve(""), Err(Error::UnknownModel(_))));
        assert!(reg.resolve("second").is_ok());
    }
}
