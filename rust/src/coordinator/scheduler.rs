//! SLO-aware scheduling policy for the serving pool.
//!
//! This module defines the *policy* primitives the
//! [`ServerPool`](crate::coordinator::pool::ServerPool) applies:
//!
//! * [`SchedKey`] — the total order the pool pops queued requests in:
//!   **priority first** (higher [`Request::priority`](crate::coordinator::server::Request)
//!   wins), then **earliest deadline first** (requests without a deadline
//!   sort after every request with one), then FIFO arrival order as the
//!   tie-break. Requests that carry neither a deadline nor a priority
//!   therefore pop in exactly the pre-v0.4 FIFO order — the default
//!   behavior is bit-compatible.
//! * [`estimated_queue_delay`] — the admission-control estimate: the sum
//!   of the queued requests' per-model service estimates
//!   ([`InferencePlan::latency_s`](crate::coordinator::plan::InferencePlan)
//!   for the routed model) divided by the worker count. When a
//!   [`PoolConfig::slo`](crate::coordinator::pool::PoolConfig) is set and
//!   this estimate exceeds it, `submit` sheds the request with the typed
//!   [`Error::Overloaded`](crate::Error::Overloaded) instead of letting
//!   queue delay grow without bound.
//!
//! Model-purity of batches is preserved under EDF: a batch is the maximal
//! *prefix* of the key-sorted queue that names one model, so a batch never
//! skips over an earlier-sorted request for another model to gather
//! batch-mates — which is also what keeps a minority model from starving
//! under a flood of deadline traffic.
//!
//! (Until v0.4 this path hosted the per-layer admission-time costing;
//! that lives in [`coordinator::plan`](crate::coordinator::plan).)

use std::cmp::Ordering;
use std::time::{Duration, Instant};

/// The pop order of the pool's queue: priority ↓, deadline ↑ (`None`
/// after every `Some`), then arrival sequence ↑. `min` = pop next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SchedKey {
    /// Request priority (higher pops first).
    pub priority: u8,
    /// Absolute completion deadline, if any (earlier pops first; `None`
    /// sorts after every concrete deadline).
    pub deadline: Option<Instant>,
    /// Arrival sequence number (FIFO tie-break).
    pub seq: u64,
}

impl Ord for SchedKey {
    fn cmp(&self, other: &Self) -> Ordering {
        // Higher priority first ⇒ compare reversed.
        other
            .priority
            .cmp(&self.priority)
            .then_with(|| match (self.deadline, other.deadline) {
                (Some(a), Some(b)) => a.cmp(&b),
                (Some(_), None) => Ordering::Less,
                (None, Some(_)) => Ordering::Greater,
                (None, None) => Ordering::Equal,
            })
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for SchedKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Admission-time queue-delay estimate: total estimated service seconds of
/// the queued requests, spread across the pool's workers.
pub fn estimated_queue_delay(est_service_s: f64, workers: usize) -> Duration {
    let s = est_service_s / workers.max(1) as f64;
    Duration::from_secs_f64(s.max(0.0))
}

/// Model-affinity placement: the (deterministic) subset of `replicas`
/// replica indices a model's traffic is pinned to. The subset is `spread`
/// consecutive indices (mod `replicas`) starting from an FNV-1a hash of
/// the model name, so (a) a hot model's slabs warm at most `spread`
/// replica caches instead of churning all of them, (b) distinct models
/// land on rotated subsets that even out load, and (c) every dispatcher
/// computes the same subset with no coordination. `spread == 0` (or ≥ the
/// replica count) means no affinity — every replica serves the model.
pub fn affinity_subset(model: &str, replicas: usize, spread: usize) -> Vec<usize> {
    if replicas == 0 {
        return Vec::new();
    }
    if spread == 0 || spread >= replicas {
        return (0..replicas).collect();
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in model.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    let primary = (h % replicas as u64) as usize;
    (0..spread).map(|i| (primary + i) % replicas).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(priority: u8, deadline: Option<Instant>, seq: u64) -> SchedKey {
        SchedKey {
            priority,
            deadline,
            seq,
        }
    }

    #[test]
    fn default_keys_sort_fifo() {
        let a = key(0, None, 1);
        let b = key(0, None, 2);
        assert!(a < b, "no deadline, equal priority ⇒ FIFO");
    }

    #[test]
    fn earliest_deadline_pops_first() {
        let now = Instant::now();
        let soon = key(0, Some(now + Duration::from_millis(10)), 5);
        let late = key(0, Some(now + Duration::from_millis(90)), 1);
        assert!(soon < late, "EDF beats arrival order");
        // A deadline always beats deadline-less traffic…
        let none = key(0, None, 0);
        assert!(late < none);
        // …but FIFO still orders the deadline-less tail.
        assert!(key(0, None, 3) < key(0, None, 4));
    }

    #[test]
    fn priority_dominates_deadline() {
        let now = Instant::now();
        let urgent = key(2, None, 9);
        let deadline = key(0, Some(now), 0);
        assert!(urgent < deadline, "higher priority preempts EDF order");
    }

    #[test]
    fn queue_delay_spreads_over_workers() {
        let d = estimated_queue_delay(4.0, 4);
        assert!((d.as_secs_f64() - 1.0).abs() < 1e-9);
        // Degenerate worker counts never divide by zero or go negative.
        assert_eq!(estimated_queue_delay(-1.0, 0), Duration::ZERO);
    }

    #[test]
    fn affinity_subsets_are_deterministic_and_sized() {
        let a = affinity_subset("resnet18", 4, 2);
        let b = affinity_subset("resnet18", 4, 2);
        assert_eq!(a, b, "placement must be a pure function of the name");
        assert_eq!(a.len(), 2);
        assert!(a.iter().all(|&r| r < 4));
        // Consecutive (mod n) so a replica loss degrades to the neighbour.
        assert_eq!(a[1], (a[0] + 1) % 4);
        // spread 0 or >= replicas disables affinity.
        assert_eq!(affinity_subset("resnet18", 4, 0), vec![0, 1, 2, 3]);
        assert_eq!(affinity_subset("resnet18", 4, 9), vec![0, 1, 2, 3]);
        assert!(affinity_subset("resnet18", 0, 2).is_empty());
        // Different models spread over different primaries (not a proof,
        // but these three names must not all collide on 8 replicas).
        let primaries: std::collections::BTreeSet<usize> =
            ["resnet18", "squeezenet", "vgg16"]
                .iter()
                .map(|m| affinity_subset(m, 8, 1)[0])
                .collect();
        assert!(primaries.len() > 1, "hash must spread models: {primaries:?}");
    }

}
