//! Multi-model time-sharing — the defining property of single computation
//! engines (paper §1: "the accelerator's resources are reused across both
//! layers and CNN models, without the need to reconfigure the fabric").
//!
//! One engine configuration `σ` serves several CNNs. Switching models
//! costs only the α-coefficient (re)load for the incoming model's OVSF
//! layers — dense weights never move because they are generated on-chip;
//! a conventional engine would re-stream its entire weights at first use
//! of every layer regardless. The manager tracks which model's α set is
//! resident and charges switch cycles accordingly.

use crate::arch::{DesignPoint, Platform};
use crate::coordinator::scheduler::InferencePlan;
use crate::engine::Engine;
use crate::error::{Error, Result};
use crate::workload::{Network, RatioProfile};
use std::collections::HashMap;

/// A registered model: plan + α volume.
#[derive(Clone, Debug)]
pub struct RegisteredModel {
    /// Inference plan on the shared engine configuration.
    pub plan: InferencePlan,
    /// α words that must be resident for this model.
    pub alpha_words: u64,
    /// Inference count served.
    pub served: u64,
}

/// Time-sharing manager for one engine configuration.
pub struct MultiModelManager {
    platform: Platform,
    sigma: DesignPoint,
    bw_mult: u32,
    models: HashMap<String, RegisteredModel>,
    /// Name of the model whose α set is currently resident.
    resident: Option<String>,
    /// Cumulative cycles spent on model switches (α reload).
    pub switch_cycles: f64,
    /// Cumulative cycles spent on inference.
    pub inference_cycles: f64,
}

impl MultiModelManager {
    /// Manager over a fixed engine configuration.
    pub fn new(platform: Platform, bw_mult: u32, sigma: DesignPoint) -> Self {
        Self {
            platform,
            sigma,
            bw_mult,
            models: HashMap::new(),
            resident: None,
            switch_cycles: 0.0,
            inference_cycles: 0.0,
        }
    }

    /// Register a network with a ratio profile, validated through the
    /// unified [`Engine`] builder. The same σ serves all models — no
    /// fabric reconfiguration.
    pub fn register(&mut self, net: &Network, profile: &RatioProfile) -> Result<()> {
        let plan = Engine::builder()
            .platform(self.platform.clone())
            .bandwidth(self.bw_mult)
            .design_point(self.sigma)
            .network(net.clone())
            .profile(profile.clone())
            .plan()?
            .schedule;
        let alpha_words: u64 = net
            .layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.ovsf)
            .map(|(i, l)| l.n_in * l.n_out * l.basis_per_chunk(profile.rho(i)))
            .sum();
        self.models.insert(
            net.name.clone(),
            RegisteredModel {
                plan,
                alpha_words,
                served: 0,
            },
        );
        Ok(())
    }

    /// Cycles to load a model's α set (16-bit words over the input stream).
    fn alpha_load_cycles(&self, words: u64) -> f64 {
        let bw = self.platform.bandwidth(self.bw_mult);
        (words * 2) as f64 / (bw.bw_in() / self.platform.clock_hz)
    }

    /// Serve one inference of `model`; returns the charged cycles
    /// (switch + inference).
    pub fn infer(&mut self, model: &str) -> Result<f64> {
        let m = self
            .models
            .get(model)
            .ok_or_else(|| Error::Coordinator(format!("model '{model}' not registered")))?
            .clone();
        let mut cycles = 0.0;
        if self.resident.as_deref() != Some(model) {
            let sw = self.alpha_load_cycles(m.alpha_words);
            self.switch_cycles += sw;
            cycles += sw;
            self.resident = Some(model.to_string());
        }
        cycles += m.plan.total_cycles;
        self.inference_cycles += m.plan.total_cycles;
        self.models.get_mut(model).unwrap().served += 1;
        Ok(cycles)
    }

    /// Fraction of total cycles lost to model switching.
    pub fn switch_overhead(&self) -> f64 {
        let total = self.switch_cycles + self.inference_cycles;
        if total == 0.0 {
            0.0
        } else {
            self.switch_cycles / total
        }
    }

    /// Per-model served counts.
    pub fn served(&self) -> Vec<(String, u64)> {
        let mut v: Vec<(String, u64)> = self
            .models
            .iter()
            .map(|(k, m)| (k.clone(), m.served))
            .collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{resnet, squeezenet};

    fn manager() -> MultiModelManager {
        let mut mm = MultiModelManager::new(
            Platform::zu7ev(),
            12,
            DesignPoint::new(128, 256, 8, 96),
        );
        let r18 = resnet::resnet18();
        let sqn = squeezenet::squeezenet1_1();
        mm.register(&r18, &RatioProfile::ovsf50(&r18)).unwrap();
        mm.register(&sqn, &RatioProfile::ovsf50(&sqn)).unwrap();
        mm
    }

    #[test]
    fn same_engine_serves_both_models() {
        let mut mm = manager();
        let c1 = mm.infer("ResNet18").unwrap();
        let c2 = mm.infer("SqueezeNet").unwrap();
        assert!(c1 > 0.0 && c2 > 0.0);
        assert_eq!(mm.served(), vec![("ResNet18".into(), 1), ("SqueezeNet".into(), 1)]);
    }

    #[test]
    fn switching_charges_alpha_reload_only_once_per_run() {
        let mut mm = manager();
        let first = mm.infer("ResNet18").unwrap();
        let repeat = mm.infer("ResNet18").unwrap();
        assert!(
            first > repeat,
            "first inference pays the α load: {first} vs {repeat}"
        );
        let back = mm.infer("SqueezeNet").unwrap();
        let back2 = mm.infer("SqueezeNet").unwrap();
        assert!(back > back2);
    }

    #[test]
    fn batched_scheduling_amortises_switches() {
        // Round-robin (A B A B ...) pays a switch per request; batching
        // (A A A A B B B B) pays two — the scheduling insight time-shared
        // engines rely on.
        let mut rr = manager();
        for _ in 0..4 {
            rr.infer("ResNet18").unwrap();
            rr.infer("SqueezeNet").unwrap();
        }
        let mut batched = manager();
        for _ in 0..4 {
            batched.infer("ResNet18").unwrap();
        }
        for _ in 0..4 {
            batched.infer("SqueezeNet").unwrap();
        }
        assert!(
            batched.switch_cycles < rr.switch_cycles,
            "batched {} !< round-robin {}",
            batched.switch_cycles,
            rr.switch_cycles
        );
        assert!(batched.switch_overhead() < rr.switch_overhead());
    }

    #[test]
    fn unknown_model_is_an_error() {
        let mut mm = manager();
        assert!(mm.infer("VGG19").is_err());
    }

    #[test]
    fn switch_cost_is_small_vs_inference() {
        // The on-the-fly advantage: switching models costs only the α set
        // (≈ MBs/compression), far less than an inference.
        let mut mm = manager();
        let first = mm.infer("ResNet18").unwrap();
        let steady = mm.infer("ResNet18").unwrap();
        let switch = first - steady;
        assert!(
            switch < steady,
            "α reload ({switch}) should be below one inference ({steady})"
        );
    }
}
