//! Legacy analytical multi-model time-sharing — now a **thin adapter**
//! over the first-class multi-model serving API
//! ([`Compiler`](crate::engine::compile::Compiler) +
//! [`ModelRegistry`](crate::coordinator::registry::ModelRegistry)).
//!
//! The manager keeps only what the new API deliberately does not model:
//! closed-form α-reload switch-cost accounting (switching models costs
//! only the incoming model's α set — dense weights never move because
//! they are generated on-chip; a conventional engine would re-stream its
//! entire weights). Everything else — validation, compilation, the model
//! table — delegates to the registry. For actually *serving* several
//! models (real numerics, batching, shared slab budget), use
//! [`ServerPool::serve`](crate::coordinator::pool::ServerPool::serve).

#![allow(deprecated)]

use crate::arch::{DesignPoint, Platform};
use crate::coordinator::registry::ModelRegistry;
use crate::engine::compile::Compiler;
use crate::error::Result;
use crate::workload::{Network, RatioProfile};
use std::collections::HashMap;

/// Analytical time-sharing cost model for one engine configuration.
#[deprecated(
    since = "0.3.0",
    note = "use engine::compile::Compiler + coordinator::registry::ModelRegistry \
            + ServerPool::serve for real multi-model serving; this adapter only \
            keeps the closed-form α-reload switch accounting"
)]
pub struct MultiModelManager {
    platform: Platform,
    bw_mult: u32,
    compiler: Compiler,
    registry: ModelRegistry,
    served: HashMap<String, u64>,
    /// Name of the model whose α set is currently resident.
    resident: Option<String>,
    /// Cumulative cycles spent on model switches (α reload).
    pub switch_cycles: f64,
    /// Cumulative cycles spent on inference.
    pub inference_cycles: f64,
}

impl MultiModelManager {
    /// Manager over a fixed engine configuration.
    pub fn new(platform: Platform, bw_mult: u32, sigma: DesignPoint) -> Self {
        Self {
            compiler: Compiler::new()
                .platform(platform.clone())
                .bandwidth(bw_mult)
                .design_point(sigma),
            registry: ModelRegistry::new(),
            platform,
            bw_mult,
            served: HashMap::new(),
            resident: None,
            switch_cycles: 0.0,
            inference_cycles: 0.0,
        }
    }

    /// Compile and register a network under its own name. The same σ
    /// serves all models — no fabric reconfiguration.
    pub fn register(&mut self, net: &Network, profile: &RatioProfile) -> Result<()> {
        let compiled = self.compiler.compile(net.clone(), profile.clone())?;
        self.registry.register(net.name.clone(), compiled)?;
        self.served.insert(net.name.clone(), 0);
        Ok(())
    }

    /// Cycles to load a model's α set (16-bit words over the input stream).
    fn alpha_load_cycles(&self, words: u64) -> f64 {
        let bw = self.platform.bandwidth(self.bw_mult);
        (words * 2) as f64 / (bw.bw_in() / self.platform.clock_hz)
    }

    /// Serve one inference of `model` analytically; returns the charged
    /// cycles (switch + inference).
    pub fn infer(&mut self, model: &str) -> Result<f64> {
        let m = self.registry.get(model)?;
        let mut cycles = 0.0;
        if self.resident.as_deref() != Some(model) {
            let sw = self.alpha_load_cycles(m.alpha_words());
            self.switch_cycles += sw;
            cycles += sw;
            self.resident = Some(model.to_string());
        }
        let inference = m.plan().schedule.total_cycles;
        cycles += inference;
        self.inference_cycles += inference;
        *self.served.entry(model.to_string()).or_insert(0) += 1;
        Ok(cycles)
    }

    /// Fraction of total cycles lost to model switching.
    pub fn switch_overhead(&self) -> f64 {
        let total = self.switch_cycles + self.inference_cycles;
        if total == 0.0 {
            0.0
        } else {
            self.switch_cycles / total
        }
    }

    /// Per-model served counts.
    pub fn served(&self) -> Vec<(String, u64)> {
        let mut v: Vec<(String, u64)> =
            self.served.iter().map(|(k, n)| (k.clone(), *n)).collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{resnet, squeezenet};

    fn manager() -> MultiModelManager {
        let mut mm = MultiModelManager::new(
            Platform::zu7ev(),
            12,
            DesignPoint::new(128, 256, 8, 96),
        );
        let r18 = resnet::resnet18();
        let sqn = squeezenet::squeezenet1_1();
        mm.register(&r18, &RatioProfile::ovsf50(&r18)).unwrap();
        mm.register(&sqn, &RatioProfile::ovsf50(&sqn)).unwrap();
        mm
    }

    #[test]
    fn same_engine_serves_both_models() {
        let mut mm = manager();
        let c1 = mm.infer("ResNet18").unwrap();
        let c2 = mm.infer("SqueezeNet").unwrap();
        assert!(c1 > 0.0 && c2 > 0.0);
        assert_eq!(mm.served(), vec![("ResNet18".into(), 1), ("SqueezeNet".into(), 1)]);
    }

    #[test]
    fn switching_charges_alpha_reload_only_once_per_run() {
        let mut mm = manager();
        let first = mm.infer("ResNet18").unwrap();
        let repeat = mm.infer("ResNet18").unwrap();
        assert!(
            first > repeat,
            "first inference pays the α load: {first} vs {repeat}"
        );
        let back = mm.infer("SqueezeNet").unwrap();
        let back2 = mm.infer("SqueezeNet").unwrap();
        assert!(back > back2);
    }

    #[test]
    fn batched_scheduling_amortises_switches() {
        // Round-robin (A B A B ...) pays a switch per request; batching
        // (A A A A B B B B) pays two — the scheduling insight the model-pure
        // batcher of `ServerPool::serve` exploits.
        let mut rr = manager();
        for _ in 0..4 {
            rr.infer("ResNet18").unwrap();
            rr.infer("SqueezeNet").unwrap();
        }
        let mut batched = manager();
        for _ in 0..4 {
            batched.infer("ResNet18").unwrap();
        }
        for _ in 0..4 {
            batched.infer("SqueezeNet").unwrap();
        }
        assert!(
            batched.switch_cycles < rr.switch_cycles,
            "batched {} !< round-robin {}",
            batched.switch_cycles,
            rr.switch_cycles
        );
        assert!(batched.switch_overhead() < rr.switch_overhead());
    }

    #[test]
    fn unknown_model_is_a_typed_error() {
        let mut mm = manager();
        let err = mm.infer("VGG19").err().expect("unregistered model");
        assert!(matches!(err, crate::Error::UnknownModel(_)), "{err}");
    }

    #[test]
    fn switch_cost_is_small_vs_inference() {
        // The on-the-fly advantage: switching models costs only the α set
        // (≈ MBs/compression), far less than an inference.
        let mut mm = manager();
        let first = mm.infer("ResNet18").unwrap();
        let steady = mm.infer("ResNet18").unwrap();
        let switch = first - steady;
        assert!(
            switch < steady,
            "α reload ({switch}) should be below one inference ({steady})"
        );
    }
}
